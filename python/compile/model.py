"""Layer-2 JAX models: the numeric steps of the paper's workloads.

Each function is a single jitted graph calling the Layer-1 Pallas kernels,
returning *sufficient statistics* so the rust coordinator (Layer 3) can
reduce across batches and nodes with the Blaze MapReduce machinery. Lowered
once by ``aot.py``; never executed from python at run time.

All functions take a ``valid`` mask so rust can pad the final partial batch
to the fixed AOT batch size without polluting the statistics.
"""

import jax
import jax.numpy as jnp

from .kernels.gmm import gmm_logpdf
from .kernels.pairwise import pairwise_dist2


def kmeans_assign(points, centers, valid):
    """K-means assignment step over one batch.

    Args:
      points: (B, D) f32.
      centers: (K, D) f32.
      valid: (B,) f32 — 1.0 for real rows, 0.0 for padding.

    Returns:
      assign: (B,) i32 — nearest center per point.
      counts: (K,) f32 — masked points per center.
      sums: (K, D) f32 — masked coordinate sums per center.
      inertia: () f32 — masked sum of min squared distances.
    """
    d2 = pairwise_dist2(points, centers)  # L1 kernel
    assign = jnp.argmin(d2, axis=1)
    k = centers.shape[0]
    one_hot = (assign[:, None] == jnp.arange(k)[None, :]).astype(jnp.float32)
    one_hot = one_hot * valid[:, None]
    counts = jnp.sum(one_hot, axis=0)
    sums = jax.lax.dot_general(
        one_hot, points, dimension_numbers=(((0,), (0,)), ((), ()))
    )  # (K, D)
    inertia = jnp.sum(jnp.min(d2, axis=1) * valid)
    return assign.astype(jnp.int32), counts, sums, inertia


def gmm_estep(points, means, precisions, logdets, logweights, valid):
    """GMM E-step sufficient statistics over one batch (paper Eqs. 2-7).

    Args:
      points: (B, D) f32.
      means: (K, D) f32.
      precisions: (K, D, D) f32 — inverse covariances (rust computes them
        from the M-step covariances with a small Cholesky, D is tiny).
      logdets: (K,) f32 — log |Sigma_k|.
      logweights: (K,) f32 — log alpha_k.
      valid: (B,) f32 mask.

    Returns:
      nk: (K,) f32 — responsibility masses (Eq. 3 summed).
      mu_sums: (K, D) f32 — responsibility-weighted coordinate sums (Eq. 5).
      cov_sums: (K, D, D) f32 — responsibility-weighted outer products (Eq. 6).
      loglik: () f32 — masked log-likelihood (Eq. 7).
    """
    logp = gmm_logpdf(points, means, precisions, logdets, logweights)  # L1
    m = jnp.max(logp, axis=1)
    lse = jnp.log(jnp.sum(jnp.exp(logp - m[:, None]), axis=1)) + m
    resp = jnp.exp(logp - lse[:, None]) * valid[:, None]  # (B, K)
    nk = jnp.sum(resp, axis=0)
    mu_sums = jax.lax.dot_general(
        resp, points, dimension_numbers=(((0,), (0,)), ((), ()))
    )  # (K, D)
    # (K, D, D): sum_i r_ik x_i x_i^T, as one einsum (fused by XLA).
    cov_sums = jnp.einsum("nk,nd,ne->kde", resp, points, points)
    loglik = jnp.sum(lse * valid)
    return nk, mu_sums, cov_sums, loglik


def knn_dist(points, queries):
    """Squared distances from every point to every query (k-NN scoring).

    Args:
      points: (B, D) f32.
      queries: (Q, D) f32.

    Returns:
      d2: (B, Q) f32.
    """
    return pairwise_dist2(points, queries)
