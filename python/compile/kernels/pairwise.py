"""Tiled pairwise squared-distance Pallas kernel.

The compute hot-spot shared by k-means (assignment step), k-NN (distance
scoring) and, indirectly, the GMM E-step. For a tile of points ``x``
(TILE_N, D) against a resident center block ``c`` (K, D):

    dist2[i, k] = |x_i|^2 - 2 x_i . c_k + |c_k|^2

The expansion maps the inner product onto the MXU systolic array (a plain
matmul) instead of an elementwise subtract-square-reduce loop — the TPU
rethink of the paper's cache-blocked CPU inner loop (DESIGN.md
§Hardware-Adaptation). BlockSpecs express the HBM->VMEM schedule: points
stream tile-by-tile over a 1-D grid, centers stay resident (K*D is small in
all of the paper's workloads).

VMEM footprint per grid step (f32): TILE_N*D (points) + K*D (centers)
+ TILE_N*K (out) + TILE_N + K (norms) — for TILE_N=512, D=8, K=64:
~180 KiB, comfortably under the ~16 MiB/core budget, leaving room for
double-buffering the point stream.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Tile of points processed per grid step.
TILE_N = 512


def _pairwise_kernel(x_ref, c_ref, o_ref):
    """One grid step: distances for a (TILE_N, D) point tile."""
    x = x_ref[...]  # (TILE_N, D) VMEM
    c = c_ref[...]  # (K, D) VMEM, resident
    # Row norms. keepdims so broadcasting stays 2-D (TPU-friendly).
    x2 = jnp.sum(x * x, axis=1, keepdims=True)  # (TILE_N, 1)
    c2 = jnp.sum(c * c, axis=1, keepdims=True).T  # (1, K)
    # The MXU part: -2 x c^T.
    xc = jax.lax.dot_general(
        x,
        c,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (TILE_N, K)
    # Distances are non-negative; clamp the cancellation error floor.
    o_ref[...] = jnp.maximum(x2 - 2.0 * xc + c2, 0.0)


def _pairwise_kernel_2d(x_ref, c_ref, o_ref):
    """Two-axis grid step: (TILE_N, D) points x (TILE_K, D) centers."""
    x = x_ref[...]
    c = c_ref[...]
    x2 = jnp.sum(x * x, axis=1, keepdims=True)
    c2 = jnp.sum(c * c, axis=1, keepdims=True).T
    xc = jax.lax.dot_general(
        x,
        c,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    o_ref[...] = jnp.maximum(x2 - 2.0 * xc + c2, 0.0)


# Center tile for the large-K variant: K*D no longer fits VMEM comfortably
# past a few thousand centers, so centers stream too.
TILE_K = 128


@functools.partial(jax.jit, static_argnames=("interpret",))
def pairwise_dist2_tiled(points, centers, *, interpret=True):
    """Large-K variant of [`pairwise_dist2`]: 2-D grid tiling both the
    point stream *and* the center set (k-NN against big reference sets,
    vector-database-style scoring).

    VMEM per grid step: TILE_N*D + TILE_K*D + TILE_N*TILE_K floats — for
    TILE_N=512, TILE_K=128, D=64: ~420 KiB, independent of total K. Each
    center tile is re-streamed once per point tile (HBM traffic K*D *
    N/TILE_N), the classic tall-skinny matmul schedule.

    Requires N % TILE_N == 0 and K % TILE_K == 0 (AOT wrappers pad).
    """
    n, d = points.shape
    k, d2 = centers.shape
    assert d == d2, f"dim mismatch {d} vs {d2}"
    assert n % TILE_N == 0, f"N={n} must be a multiple of TILE_N={TILE_N}"
    assert k % TILE_K == 0, f"K={k} must be a multiple of TILE_K={TILE_K}"
    grid = (n // TILE_N, k // TILE_K)
    return pl.pallas_call(
        _pairwise_kernel_2d,
        grid=grid,
        in_specs=[
            pl.BlockSpec((TILE_N, d), lambda i, j: (i, 0)),
            pl.BlockSpec((TILE_K, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((TILE_N, TILE_K), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, k), jnp.float32),
        interpret=interpret,
    )(points, centers)


@functools.partial(jax.jit, static_argnames=("interpret",))
def pairwise_dist2(points, centers, *, interpret=True):
    """Squared Euclidean distances ``(N, K)`` between ``points`` ``(N, D)``
    and ``centers`` ``(K, D)``.

    ``N`` must be a multiple of ``TILE_N`` (the AOT wrapper pads); ``K`` and
    ``D`` are free.
    """
    n, d = points.shape
    k, d2 = centers.shape
    assert d == d2, f"dim mismatch {d} vs {d2}"
    assert n % TILE_N == 0, f"N={n} must be a multiple of TILE_N={TILE_N}"
    grid = (n // TILE_N,)
    return pl.pallas_call(
        _pairwise_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((TILE_N, d), lambda i: (i, 0)),  # stream point tiles
            pl.BlockSpec((k, d), lambda i: (0, 0)),  # centers resident
        ],
        out_specs=pl.BlockSpec((TILE_N, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, k), jnp.float32),
        interpret=interpret,
    )(points, centers)
