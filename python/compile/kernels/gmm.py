"""Gaussian log-density Pallas kernel (GMM E-step hot-spot).

For a tile of points ``x`` (TILE_N, D) and K full-covariance Gaussian
components (means ``mu`` (K, D), precisions ``prec`` (K, D, D), log-dets
``logdet`` (K,), log-weights ``logw`` (K,)):

    out[i, k] = logw[k] - 0.5 * (D log 2pi + logdet[k]
                + (x_i - mu_k) prec_k (x_i - mu_k)^T)

The K loop is unrolled at trace time (K=5 in the paper's workload); each
component's quadratic form is a (TILE_N, D) @ (D, D) matmul followed by a
row-wise weighted sum — again MXU-shaped work rather than scalar loops.

VMEM per grid step (f32, TILE_N=512, D=8, K=8): points 16 KiB + params
~2.5 KiB + out 16 KiB — trivially resident; the point stream double-buffers.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .pairwise import TILE_N

_LOG_2PI = 1.8378770664093453


def _gmm_kernel(x_ref, mu_ref, prec_ref, logdet_ref, logw_ref, o_ref):
    x = x_ref[...]  # (TILE_N, D)
    mu = mu_ref[...]  # (K, D)
    prec = prec_ref[...]  # (K, D, D)
    logdet = logdet_ref[...]  # (K,)
    logw = logw_ref[...]  # (K,)
    k, d = mu.shape
    cols = []
    for j in range(k):  # unrolled: K is small and static
        diff = x - mu[j][None, :]  # (TILE_N, D)
        # Quadratic form via MXU: (TILE_N, D) @ (D, D), then row-dot.
        pd = jax.lax.dot_general(
            diff,
            prec[j],
            dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        quad = jnp.sum(pd * diff, axis=1)  # (TILE_N,)
        cols.append(logw[j] - 0.5 * (d * _LOG_2PI + logdet[j] + quad))
    o_ref[...] = jnp.stack(cols, axis=1)


@functools.partial(jax.jit, static_argnames=("interpret",))
def gmm_logpdf(points, means, precisions, logdets, logweights, *, interpret=True):
    """Weighted Gaussian log-densities ``(N, K)``.

    ``points`` (N, D) with N a multiple of TILE_N; ``means`` (K, D);
    ``precisions`` (K, D, D) = inverse covariances; ``logdets`` (K,) =
    log|Sigma_k|; ``logweights`` (K,) = log alpha_k.
    """
    n, d = points.shape
    k = means.shape[0]
    assert n % TILE_N == 0, f"N={n} must be a multiple of TILE_N={TILE_N}"
    grid = (n // TILE_N,)
    return pl.pallas_call(
        _gmm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((TILE_N, d), lambda i: (i, 0)),
            pl.BlockSpec((k, d), lambda i: (0, 0)),
            pl.BlockSpec((k, d, d), lambda i: (0, 0, 0)),
            pl.BlockSpec((k,), lambda i: (0,)),
            pl.BlockSpec((k,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((TILE_N, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, k), jnp.float32),
        interpret=interpret,
    )(points, means, precisions, logdets, logweights)
