"""Layer-1 Pallas kernels (build-time only; never on the request path).

Kernels are lowered with ``interpret=True`` — the CPU PJRT plugin cannot run
Mosaic custom-calls, so interpret mode is the correctness path and real-TPU
performance is estimated analytically in DESIGN.md §Perf.
"""

from .pairwise import pairwise_dist2, pairwise_dist2_tiled
from .gmm import gmm_logpdf

__all__ = ["pairwise_dist2", "pairwise_dist2_tiled", "gmm_logpdf"]
