"""Pure-jnp oracles for the Pallas kernels (the correctness ground truth).

Every kernel in this package must match its oracle to float32 tolerance on
arbitrary shapes; ``python/tests/test_kernels.py`` sweeps shapes and dtypes
with hypothesis.
"""

import jax.numpy as jnp

_LOG_2PI = 1.8378770664093453


def pairwise_dist2_ref(points, centers):
    """Naive (N, K) squared distances: materialize the (N, K, D) diff."""
    diff = points[:, None, :] - centers[None, :, :]
    return jnp.sum(diff * diff, axis=-1)


def gmm_logpdf_ref(points, means, precisions, logdets, logweights):
    """Naive weighted Gaussian log-densities (N, K)."""
    diff = points[:, None, :] - means[None, :, :]  # (N, K, D)
    quad = jnp.einsum("nkd,kde,nke->nk", diff, precisions, diff)
    d = points.shape[1]
    return logweights[None, :] - 0.5 * (d * _LOG_2PI + logdets[None, :] + quad)


def kmeans_assign_ref(points, centers, valid):
    """Oracle for the L2 k-means assignment step."""
    d2 = pairwise_dist2_ref(points, centers)
    assign = jnp.argmin(d2, axis=1)
    k = centers.shape[0]
    one_hot = (assign[:, None] == jnp.arange(k)[None, :]).astype(jnp.float32)
    one_hot = one_hot * valid[:, None]
    counts = jnp.sum(one_hot, axis=0)
    sums = one_hot.T @ points
    sq = jnp.sum(points * points, axis=1)
    inertia = jnp.sum(jnp.min(d2, axis=1) * valid)
    del sq
    return assign.astype(jnp.int32), counts, sums, inertia


def gmm_estep_ref(points, means, precisions, logdets, logweights, valid):
    """Oracle for the L2 GMM E-step sufficient statistics."""
    logp = gmm_logpdf_ref(points, means, precisions, logdets, logweights)
    lse = jnp.log(jnp.sum(jnp.exp(logp - logp.max(axis=1, keepdims=True)), axis=1))
    lse = lse + logp.max(axis=1)
    resp = jnp.exp(logp - lse[:, None]) * valid[:, None]  # (N, K)
    nk = jnp.sum(resp, axis=0)
    mu_sums = resp.T @ points  # (K, D)
    cov_sums = jnp.einsum("nk,nd,ne->kde", resp, points, points)
    loglik = jnp.sum(lse * valid)
    return nk, mu_sums, cov_sums, loglik
