"""AOT driver: lower the Layer-2 models to HLO text for the rust runtime.

HLO *text* (not ``.serialize()``) is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which the published xla
crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text
parser reassigns ids and round-trips cleanly. See
/opt/xla-example/README.md.

Usage: ``cd python && python -m compile.aot --out ../artifacts``

Shapes are fixed at AOT time (PJRT executables are monomorphic); the
manifest records them so the rust runtime can pad and validate.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# Fixed AOT shapes (overridable for experimentation via env).
BATCH = int(os.environ.get("BLAZE_AOT_BATCH", 4096))
DIM = int(os.environ.get("BLAZE_AOT_DIM", 4))
K = int(os.environ.get("BLAZE_AOT_K", 5))
QUERIES = int(os.environ.get("BLAZE_AOT_QUERIES", 1))


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def build_artifacts():
    """(name, lowered) for every model entry point."""
    lowerings = {
        "kmeans_assign": jax.jit(model.kmeans_assign).lower(
            _spec(BATCH, DIM), _spec(K, DIM), _spec(BATCH)
        ),
        "gmm_estep": jax.jit(model.gmm_estep).lower(
            _spec(BATCH, DIM),
            _spec(K, DIM),
            _spec(K, DIM, DIM),
            _spec(K),
            _spec(K),
            _spec(BATCH),
        ),
        "knn_dist": jax.jit(model.knn_dist).lower(
            _spec(BATCH, DIM), _spec(QUERIES, DIM)
        ),
        "pairwise_dist": jax.jit(
            lambda p, c: (model.knn_dist(p, c),)  # tuple for uniform unwrap
        ).lower(_spec(BATCH, DIM), _spec(K, DIM)),
    }
    return lowerings


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out", default="../artifacts", help="output directory")
    args = parser.parse_args()
    os.makedirs(args.out, exist_ok=True)

    manifest = {
        "batch": BATCH,
        "dim": DIM,
        "k": K,
        "queries": QUERIES,
        "tile_n": __import__(
            "compile.kernels.pairwise", fromlist=["TILE_N"]
        ).TILE_N,
        "artifacts": {},
    }
    for name, lowered in build_artifacts().items():
        text = to_hlo_text(lowered)
        path = os.path.join(args.out, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"][name] = {
            "file": f"{name}.hlo.txt",
            "hlo_bytes": len(text),
        }
        print(f"wrote {path} ({len(text)} chars)")
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {os.path.join(args.out, 'manifest.json')}")


if __name__ == "__main__":
    main()
