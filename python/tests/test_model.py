"""Layer-2 model correctness: jitted graphs vs oracles, mask semantics,
and AOT lowering sanity (the exact graphs the rust runtime executes)."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels.pairwise import TILE_N
from compile.kernels import ref


def _batch(rng, n, d):
    return jnp.asarray(rng.standard_normal((n, d)) * 4, dtype=jnp.float32)


def test_kmeans_assign_matches_ref():
    rng = np.random.default_rng(0)
    x = _batch(rng, TILE_N * 2, 4)
    c = _batch(rng, 5, 4)
    valid = jnp.ones((TILE_N * 2,), dtype=jnp.float32)
    a, counts, sums, inertia = jax.jit(model.kmeans_assign)(x, c, valid)
    ra, rc, rs, ri = ref.kmeans_assign_ref(x, c, valid)
    np.testing.assert_array_equal(a, ra)
    np.testing.assert_allclose(counts, rc, rtol=1e-6)
    np.testing.assert_allclose(sums, rs, rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(inertia, ri, rtol=1e-4)


def test_kmeans_mask_excludes_padding():
    rng = np.random.default_rng(1)
    x = _batch(rng, TILE_N, 3)
    c = _batch(rng, 4, 3)
    full = jnp.ones((TILE_N,), dtype=jnp.float32)
    half = full.at[TILE_N // 2 :].set(0.0)
    _, counts_full, _, _ = model.kmeans_assign(x, c, full)
    _, counts_half, sums_half, _ = model.kmeans_assign(x, c, half)
    assert float(counts_full.sum()) == TILE_N
    assert float(counts_half.sum()) == TILE_N // 2
    # Masked stats equal stats of the unmasked prefix.
    _, counts_prefix, sums_prefix, _ = model.kmeans_assign(
        jnp.concatenate([x[: TILE_N // 2], jnp.zeros_like(x[: TILE_N // 2])]),
        c,
        half,
    )
    del counts_prefix, sums_prefix  # zero-padding changes assignments of pad rows only
    np.testing.assert_allclose(
        counts_half.sum(), TILE_N // 2, rtol=0
    )
    assert np.isfinite(np.asarray(sums_half)).all()


def test_kmeans_converges_on_separated_clusters():
    # Full Lloyd iterations driven from python using only the AOT-shape fn.
    rng = np.random.default_rng(2)
    true_centers = np.array([[-8.0, -8.0], [8.0, 8.0], [8.0, -8.0]], dtype=np.float32)
    n = TILE_N * 2
    labels = rng.integers(0, 3, n)
    pts = true_centers[labels] + rng.standard_normal((n, 2)).astype(np.float32) * 0.5
    x = jnp.asarray(pts)
    valid = jnp.ones((n,), dtype=jnp.float32)
    # Perturbed init (k-means++ style seeding is out of scope for the test).
    centers = jnp.asarray(
        true_centers + rng.standard_normal(true_centers.shape).astype(np.float32) * 1.5
    )
    for _ in range(20):
        _, counts, sums, _ = model.kmeans_assign(x, centers, valid)
        centers = sums / jnp.maximum(counts[:, None], 1e-6)
    got = np.asarray(centers)
    # Each true center must be recovered by some estimated center.
    for tc in true_centers:
        best = np.min(np.linalg.norm(got - tc[None], axis=1))
        assert best < 0.3, f"center {tc} unrecovered (best {best})"


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
def test_gmm_estep_matches_ref(seed):
    rng = np.random.default_rng(seed)
    k, d = 5, 4
    x = _batch(rng, TILE_N, d)
    means = _batch(rng, k, d)
    a = rng.standard_normal((k, d, d)) * 0.3
    covs = a @ a.transpose(0, 2, 1) + np.eye(d)[None]
    precs = jnp.asarray(np.linalg.inv(covs), dtype=jnp.float32)
    logdets = jnp.asarray(np.linalg.slogdet(covs)[1], dtype=jnp.float32)
    w = rng.random(k) + 0.1
    logw = jnp.asarray(np.log(w / w.sum()), dtype=jnp.float32)
    valid = jnp.ones((TILE_N,), dtype=jnp.float32)
    nk, mu_s, cov_s, ll = jax.jit(model.gmm_estep)(x, means, precs, logdets, logw, valid)
    rnk, rmu, rcov, rll = ref.gmm_estep_ref(x, means, precs, logdets, logw, valid)
    np.testing.assert_allclose(nk, rnk, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(mu_s, rmu, rtol=1e-3, atol=1e-2)
    np.testing.assert_allclose(cov_s, rcov, rtol=1e-3, atol=5e-2)
    np.testing.assert_allclose(ll, rll, rtol=1e-4)
    # Responsibilities sum to the number of valid points.
    np.testing.assert_allclose(float(nk.sum()), TILE_N, rtol=1e-4)


def test_gmm_estep_mask_zeroes_contributions():
    rng = np.random.default_rng(3)
    k, d = 3, 2
    x = _batch(rng, TILE_N, d)
    means = _batch(rng, k, d)
    precs = jnp.stack([jnp.eye(d, dtype=jnp.float32)] * k)
    logdets = jnp.zeros((k,), dtype=jnp.float32)
    logw = jnp.full((k,), -np.log(k), dtype=jnp.float32)
    none = jnp.zeros((TILE_N,), dtype=jnp.float32)
    nk, mu_s, cov_s, ll = model.gmm_estep(x, means, precs, logdets, logw, none)
    assert float(nk.sum()) == 0.0
    assert float(jnp.abs(mu_s).sum()) == 0.0
    assert float(jnp.abs(cov_s).sum()) == 0.0
    assert float(ll) == 0.0


def test_knn_dist_matches_ref():
    rng = np.random.default_rng(4)
    x = _batch(rng, TILE_N, 4)
    q = _batch(rng, 1, 4)
    got = model.knn_dist(x, q)
    want = ref.pairwise_dist2_ref(x, q)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-3)


def test_aot_lowering_produces_hlo_text():
    # The exact path `make artifacts` runs, at the real AOT shapes.
    from compile import aot

    lowerings = aot.build_artifacts()
    assert set(lowerings) == {"kmeans_assign", "gmm_estep", "knn_dist", "pairwise_dist"}
    for name, lowered in lowerings.items():
        text = aot.to_hlo_text(lowered)
        assert text.startswith("HloModule"), f"{name}: not HLO text"
        assert "ENTRY" in text, f"{name}: no entry computation"
