"""Layer-1 kernel correctness: Pallas (interpret) vs pure-jnp oracle.

Hypothesis sweeps shapes; every case must match to float32 tolerance.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.gmm import gmm_logpdf
from compile.kernels.pairwise import TILE_K, TILE_N, pairwise_dist2, pairwise_dist2_tiled
from compile.kernels import ref


def _points(rng, n, d, scale=5.0):
    return jnp.asarray(rng.standard_normal((n, d)) * scale, dtype=jnp.float32)


@settings(max_examples=25, deadline=None)
@given(
    tiles=st.integers(min_value=1, max_value=3),
    d=st.integers(min_value=1, max_value=16),
    k=st.integers(min_value=1, max_value=32),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_pairwise_matches_ref(tiles, d, k, seed):
    rng = np.random.default_rng(seed)
    x = _points(rng, tiles * TILE_N, d)
    c = _points(rng, k, d)
    got = pairwise_dist2(x, c)
    want = ref.pairwise_dist2_ref(x, c)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-3)


@settings(max_examples=20, deadline=None)
@given(
    tiles=st.integers(min_value=1, max_value=2),
    d=st.integers(min_value=1, max_value=12),
    k=st.integers(min_value=1, max_value=24),
    dtype=st.sampled_from(["float32", "bfloat16", "float64"]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_pairwise_dtype_sweep(tiles, d, k, dtype, seed):
    # The kernel must be numerically faithful across input dtypes: f32
    # exact-ish, bf16 to its ~3-decimal-digit mantissa, f64 inputs accepted
    # (accumulated in f32 per preferred_element_type).
    rng = np.random.default_rng(seed)
    jdt = jnp.dtype(dtype)
    x = jnp.asarray(rng.standard_normal((tiles * TILE_N, d)) * 3, dtype=jdt)
    c = jnp.asarray(rng.standard_normal((k, d)) * 3, dtype=jdt)
    got = pairwise_dist2(x.astype(jnp.float32), c.astype(jnp.float32))
    want = ref.pairwise_dist2_ref(
        np.asarray(x, dtype=np.float64), np.asarray(c, dtype=np.float64)
    )
    tol = {"float32": 2e-3, "bfloat16": 0.15, "float64": 2e-3}[dtype]
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol * 10)


@settings(max_examples=15, deadline=None)
@given(
    n_tiles=st.integers(min_value=1, max_value=2),
    k_tiles=st.integers(min_value=1, max_value=3),
    d=st.integers(min_value=1, max_value=16),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_pairwise_tiled_matches_ref_and_flat(n_tiles, k_tiles, d, seed):
    # Large-K 2-D-grid variant: must agree with both the oracle and the
    # centers-resident kernel.
    rng = np.random.default_rng(seed)
    x = _points(rng, n_tiles * TILE_N, d)
    c = _points(rng, k_tiles * TILE_K, d)
    got = pairwise_dist2_tiled(x, c)
    want = ref.pairwise_dist2_ref(x, c)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-3)
    flat = pairwise_dist2(x, c)
    np.testing.assert_allclose(got, flat, rtol=1e-6, atol=1e-5)


def test_pairwise_tiled_rejects_ragged_k():
    x = jnp.zeros((TILE_N, 4), dtype=jnp.float32)
    c = jnp.zeros((TILE_K + 1, 4), dtype=jnp.float32)
    with pytest.raises(AssertionError):
        pairwise_dist2_tiled(x, c)


def test_pairwise_zero_distance_on_identical_points():
    x = jnp.ones((TILE_N, 4), dtype=jnp.float32) * 3.5
    c = jnp.ones((2, 4), dtype=jnp.float32) * 3.5
    d2 = pairwise_dist2(x, c)
    np.testing.assert_allclose(d2, np.zeros((TILE_N, 2)), atol=1e-4)


def test_pairwise_is_nonnegative_under_cancellation():
    # Far-from-origin points: |x|^2 - 2xc + |c|^2 cancels catastrophically;
    # the kernel clamps at zero.
    rng = np.random.default_rng(0)
    x = _points(rng, TILE_N, 8, scale=1e3)
    d2 = pairwise_dist2(x, x[:4])
    assert (np.asarray(d2) >= 0).all()


def test_pairwise_rejects_non_multiple_of_tile():
    x = jnp.zeros((TILE_N + 1, 4), dtype=jnp.float32)
    c = jnp.zeros((3, 4), dtype=jnp.float32)
    with pytest.raises(AssertionError):
        pairwise_dist2(x, c)


def _random_gmm(rng, k, d):
    means = jnp.asarray(rng.standard_normal((k, d)) * 3, dtype=jnp.float32)
    # Random SPD covariances: A A^T + eps I.
    a = rng.standard_normal((k, d, d)) * 0.5
    covs = a @ a.transpose(0, 2, 1) + np.eye(d)[None] * 0.5
    precs = jnp.asarray(np.linalg.inv(covs), dtype=jnp.float32)
    logdets = jnp.asarray(np.linalg.slogdet(covs)[1], dtype=jnp.float32)
    w = rng.random(k) + 0.1
    logw = jnp.asarray(np.log(w / w.sum()), dtype=jnp.float32)
    return means, precs, logdets, logw


@settings(max_examples=15, deadline=None)
@given(
    tiles=st.integers(min_value=1, max_value=2),
    d=st.integers(min_value=1, max_value=8),
    k=st.integers(min_value=1, max_value=8),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_gmm_logpdf_matches_ref(tiles, d, k, seed):
    rng = np.random.default_rng(seed)
    x = _points(rng, tiles * TILE_N, d, scale=2.0)
    means, precs, logdets, logw = _random_gmm(rng, k, d)
    got = gmm_logpdf(x, means, precs, logdets, logw)
    want = ref.gmm_logpdf_ref(x, means, precs, logdets, logw)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-3)


def test_gmm_logpdf_standard_normal_closed_form():
    # K=1, mu=0, Sigma=I, alpha=1: logpdf = -0.5*(d log 2pi + |x|^2).
    d = 3
    rng = np.random.default_rng(1)
    x = _points(rng, TILE_N, d, scale=1.0)
    means = jnp.zeros((1, d), dtype=jnp.float32)
    precs = jnp.eye(d, dtype=jnp.float32)[None]
    logdets = jnp.zeros((1,), dtype=jnp.float32)
    logw = jnp.zeros((1,), dtype=jnp.float32)
    got = gmm_logpdf(x, means, precs, logdets, logw)[:, 0]
    want = -0.5 * (d * np.log(2 * np.pi) + np.sum(np.asarray(x) ** 2, axis=1))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
