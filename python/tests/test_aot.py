"""AOT driver tests: the artifact bundle the rust runtime consumes."""

import json
import subprocess
import sys
from pathlib import Path

from compile import aot
from compile.kernels.pairwise import TILE_N


def test_batch_is_tile_multiple():
    # The Pallas grid requires it; the rust runtime pads to BATCH.
    assert aot.BATCH % TILE_N == 0


def test_aot_main_writes_bundle(tmp_path):
    # Run the real entry point into a temp dir and validate the bundle.
    out = tmp_path / "artifacts"
    proc = subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out", str(out)],
        cwd=Path(__file__).resolve().parents[1],
        capture_output=True,
        text=True,
        timeout=600,
    )
    assert proc.returncode == 0, proc.stderr
    manifest = json.loads((out / "manifest.json").read_text())
    assert manifest["batch"] == aot.BATCH
    assert manifest["dim"] == aot.DIM
    assert manifest["k"] == aot.K
    assert set(manifest["artifacts"]) == {
        "kmeans_assign",
        "gmm_estep",
        "knn_dist",
        "pairwise_dist",
    }
    for name, info in manifest["artifacts"].items():
        text = (out / info["file"]).read_text()
        assert text.startswith("HloModule"), name
        assert len(text) == info["hlo_bytes"], name
        # Id-safe interchange: jax >= 0.5 proto ids overflow the crate's
        # XLA; text must carry the module instead (see aot.py docstring).
        assert "ENTRY" in text


def test_hlo_text_has_expected_io_shapes():
    lowered = aot.build_artifacts()["kmeans_assign"]
    text = aot.to_hlo_text(lowered)
    # Inputs: points (B, D), centers (K, D), valid (B,).
    assert f"f32[{aot.BATCH},{aot.DIM}]" in text
    assert f"f32[{aot.K},{aot.DIM}]" in text
    assert f"f32[{aot.BATCH}]" in text
    # Output tuple includes the assignment vector.
    assert f"s32[{aot.BATCH}]" in text
