//! Cross-module integration tests: full workloads over the simulated
//! cluster, engine equivalence, PJRT-vs-scalar app paths, metric sanity.

use blaze::apps::{gmm, kmeans, knn, pagerank, pi, wordcount};
use blaze::containers::{collect_hashmap, DistVector};
use blaze::coordinator::cluster::{Cluster, ClusterConfig, EngineKind};
use blaze::data::{corpus_lines, Graph, PointSet};
use blaze::runtime::Runtime;

fn runtime() -> Option<Runtime> {
    Runtime::load(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts")).ok()
}

fn conv_cluster(nodes: usize, workers: usize) -> Cluster {
    Cluster::new(ClusterConfig::sized(nodes, workers).with_engine(EngineKind::Conventional))
}

#[test]
fn wordcount_identical_across_cluster_shapes() {
    let lines = corpus_lines(1500, 9, 5);
    let mut reference: Option<std::collections::HashMap<String, u64>> = None;
    for (nodes, workers) in [(1, 1), (2, 4), (8, 2)] {
        let c = Cluster::local(nodes, workers);
        let dv = DistVector::from_vec(&c, lines.clone());
        let (_, words) = wordcount::wordcount(&c, &dv);
        let collected = collect_hashmap(&words);
        match &reference {
            None => reference = Some(collected),
            Some(want) => assert_eq!(&collected, want, "shape {nodes}x{workers} differs"),
        }
    }
}

#[test]
fn pi_identical_across_engines_and_matches_hand() {
    let c = Cluster::local(4, 4);
    let r1 = pi::pi_blaze(&c, 400_000);
    let r2 = pi::pi_hand_optimized(&Cluster::local(4, 4), 400_000);
    assert_eq!(r1.result, r2.result);
}

#[test]
fn kmeans_pjrt_path_matches_scalar_path() {
    let Some(rt) = runtime() else {
        eprintln!("SKIP: no artifacts");
        return;
    };
    let (dim, k) = (rt.dim(), rt.k());
    let ps = PointSet::clustered(3 * rt.batch() / 2, dim, k, 0.5, 31);
    let init = kmeans::init_first_k(&ps, k);

    let c1 = Cluster::local(2, 2);
    let b1 = kmeans::distribute_blocks(&c1, &ps, rt.batch());
    let (_, with_rt) =
        kmeans::kmeans(&c1, &b1, ps.n, dim, k, init.clone(), 1e-4, 15, Some(&rt));

    let c2 = Cluster::local(2, 2);
    let b2 = kmeans::distribute_blocks(&c2, &ps, rt.batch());
    let (_, scalar) = kmeans::kmeans(&c2, &b2, ps.n, dim, k, init, 1e-4, 15, None);

    assert_eq!(with_rt.iterations, scalar.iterations, "iteration counts differ");
    for (a, b) in with_rt.centers.iter().zip(&scalar.centers) {
        assert!((a - b).abs() < 2e-2, "center coord {a} vs {b}");
    }
    let rel = (with_rt.inertia - scalar.inertia).abs() / scalar.inertia.max(1.0);
    assert!(rel < 1e-2, "inertia {} vs {}", with_rt.inertia, scalar.inertia);
}

#[test]
fn gmm_pjrt_path_matches_scalar_path() {
    let Some(rt) = runtime() else {
        eprintln!("SKIP: no artifacts");
        return;
    };
    let (dim, k) = (rt.dim(), rt.k());
    let ps = PointSet::clustered(rt.batch(), dim, k, 0.6, 37);

    let c1 = Cluster::local(2, 2);
    let (_, with_rt) = gmm::gmm_from_points(&c1, &ps, k, 1e-7, 10, Some(&rt));
    let c2 = Cluster::local(2, 2);
    let (_, scalar) = gmm::gmm_from_points(&c2, &ps, k, 1e-7, 10, None);

    let rel = (with_rt.loglik - scalar.loglik).abs() / scalar.loglik.abs().max(1.0);
    assert!(rel < 5e-3, "loglik {} vs {}", with_rt.loglik, scalar.loglik);
}

#[test]
fn knn_pjrt_path_matches_scalar_path() {
    let Some(rt) = runtime() else {
        eprintln!("SKIP: no artifacts");
        return;
    };
    let dim = rt.dim();
    let ps = PointSet::uniform(3 * rt.batch(), dim, 41);
    let query = vec![0.25f32; dim];
    let c1 = Cluster::local(3, 2);
    let (_, with_rt) = knn::knn(&c1, &ps, &query, 100, Some(&rt));
    let c2 = Cluster::local(3, 2);
    let (_, scalar) = knn::knn(&c2, &ps, &query, 100, None);
    let da: Vec<f32> = with_rt.iter().map(|n| n.0).collect();
    let db: Vec<f32> = scalar.iter().map(|n| n.0).collect();
    for (a, b) in da.iter().zip(&db) {
        assert!((a - b).abs() < 1e-4, "{a} vs {b}");
    }
}

#[test]
fn pagerank_engine_parity_on_real_graph() {
    let g = Graph::graph500(9, 8, 3);
    let (_, eager) = pagerank::pagerank(&Cluster::local(4, 2), &g, 1e-6, 60);
    let (_, conv) = pagerank::pagerank(&conv_cluster(4, 2), &g, 1e-6, 60);
    assert_eq!(eager.iterations, conv.iterations);
    for (a, b) in eager.scores.iter().zip(&conv.scores) {
        assert!((a - b).abs() < 1e-10);
    }
}

#[test]
fn eager_beats_conventional_on_shuffle_bytes_everywhere() {
    // The paper's core claim, mechanically: locally-reduced shuffles are
    // smaller. Check across all five workloads at 4 nodes.
    let lines = corpus_lines(3000, 10, 7);
    let g = Graph::graph500(9, 8, 7);
    let ps = PointSet::clustered(4000, 4, 5, 0.5, 7);

    // wordcount
    let ce = Cluster::local(4, 2);
    let (re, _) = wordcount::wordcount(&ce, &DistVector::from_vec(&ce, lines.clone()));
    let cc = conv_cluster(4, 2);
    let (rc, _) = wordcount::wordcount(&cc, &DistVector::from_vec(&cc, lines));
    assert!(
        re.shuffle_bytes < rc.shuffle_bytes,
        "wordcount eager {} vs conv {}",
        re.shuffle_bytes,
        rc.shuffle_bytes
    );

    // pagerank
    let (pe, _) = pagerank::pagerank(&Cluster::local(4, 2), &g, 1e-5, 10);
    let (pc, _) = pagerank::pagerank(&conv_cluster(4, 2), &g, 1e-5, 10);
    assert!(pe.shuffle_bytes < pc.shuffle_bytes, "pagerank {} vs {}", pe.shuffle_bytes, pc.shuffle_bytes);

    // kmeans (single-key stats: eager tree-reduces, conventional ships all)
    let c1 = Cluster::local(4, 2);
    let b1 = kmeans::distribute_blocks(&c1, &ps, 256);
    let init = kmeans::init_first_k(&ps, 5);
    let (ke, _) = kmeans::kmeans(&c1, &b1, ps.n, 4, 5, init.clone(), 1e-4, 5, None);
    let c2 = conv_cluster(4, 2);
    let b2 = kmeans::distribute_blocks(&c2, &ps, 256);
    let (kc, _) = kmeans::kmeans(&c2, &b2, ps.n, 4, 5, init, 1e-4, 5, None);
    assert!(ke.shuffle_bytes <= kc.shuffle_bytes, "kmeans {} vs {}", ke.shuffle_bytes, kc.shuffle_bytes);
}

#[test]
fn memory_gap_matches_fig9_shape() {
    // Fig 9: Spark uses ~10x the memory of Blaze on the keyed workloads.
    let lines = corpus_lines(4000, 10, 9);
    let ce = Cluster::local(1, 4);
    let (re, _) = wordcount::wordcount(&ce, &DistVector::from_vec(&ce, lines.clone()));
    let cc = conv_cluster(1, 4);
    let (rc, _) = wordcount::wordcount(&cc, &DistVector::from_vec(&cc, lines));
    let ratio = rc.peak_bytes as f64 / re.peak_bytes.max(1) as f64;
    assert!(ratio > 3.0, "conventional/eager memory ratio {ratio:.1} too small");
}

#[test]
fn virtual_time_scales_with_nodes() {
    // Same workload on 1 vs 8 nodes: virtual makespan must shrink
    // substantially (the Fig 4-8 x-axis behaviour). Run the comparison a
    // few times and take the best ratio — wall-clock-derived makespans are
    // noisy when the test harness runs suites in parallel on one core.
    let lines = corpus_lines(16_000, 10, 11);
    let mut best = 0.0f64;
    for _ in 0..3 {
        let c1 = Cluster::local(1, 4);
        let (r1, _) = wordcount::wordcount(&c1, &DistVector::from_vec(&c1, lines.clone()));
        let c8 = Cluster::local(8, 4);
        let (r8, _) = wordcount::wordcount(&c8, &DistVector::from_vec(&c8, lines.clone()));
        best = best.max(r1.makespan_sec / r8.makespan_sec);
        if best > 2.5 {
            break;
        }
    }
    assert!(best > 2.5, "8-node speedup only {best:.2}x");
}

#[test]
fn rebalance_after_skewed_ingest() {
    use blaze::containers::DistHashMap;
    use blaze::mapreduce::Reducer;
    let c = Cluster::local(4, 1);
    let mut m: DistHashMap<String, u64> = DistHashMap::new(&c);
    let red = Reducer::sum();
    // Skew: many distinct keys sharing a handful of slots is impossible to
    // construct portably, so approximate with heavy weight on few keys plus
    // uniform tail — rebalance must not *worsen* balance and must keep data.
    for i in 0..2000u64 {
        m.merge(format!("key{i}"), 1, &red);
    }
    let before = m.imbalance();
    m.rebalance();
    let after = m.imbalance();
    assert!(after <= before * 1.05, "imbalance {before} -> {after}");
    assert_eq!(m.len(), 2000);
}
