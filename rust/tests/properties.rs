//! Property-based tests over coordinator invariants (routing, batching,
//! state) and the serialization codecs.
//!
//! The offline build has no proptest, so cases are generated with the
//! in-tree deterministic [`SplitRng`]: hundreds of random cases per
//! property, reproducible by seed.

use blaze::containers::{DistHashMap, DistVector};
use blaze::coordinator::cluster::{Cluster, ClusterConfig, EngineKind};
use blaze::coordinator::rebalance::{self, SlotMap, NUM_SLOTS};
use blaze::coordinator::scheduler::{block_owner, block_ranges, weighted_contiguous_ranges};
use blaze::mapreduce::{mapreduce, Reducer};
use blaze::ser::fastser::{
    decode_pairs, decode_pairs_exact, encode_pairs, varint_len, FastSer, Reader, Writer,
};
use blaze::ser::tagged::{decode_pairs_tagged, encode_pairs_tagged};
use blaze::util::rng::SplitRng;

// ---------- serialization properties ------------------------------------

#[test]
fn prop_fastser_roundtrip_random_pairs() {
    let mut rng = SplitRng::new(0xF00D, 0);
    for case in 0..300 {
        let n = rng.below(64) as usize;
        let pairs: Vec<(String, i64)> = (0..n)
            .map(|_| {
                let len = rng.below(24) as usize;
                let s: String = (0..len)
                    .map(|_| char::from(b'a' + rng.below(26) as u8))
                    .collect();
                let v = rng.next_u64() as i64;
                (s, v)
            })
            .collect();
        let buf = encode_pairs(&pairs);
        let back = decode_pairs::<String, i64>(&buf).unwrap();
        assert_eq!(back, pairs, "case {case}");
        // Tagged codec round-trips the same data.
        let tbuf = encode_pairs_tagged(&pairs);
        assert_eq!(decode_pairs_tagged::<String, i64>(&tbuf).unwrap(), pairs);
        // And is never smaller than the fast codec (for non-empty batches;
        // the fast codec spends one byte on the batch count).
        if !pairs.is_empty() {
            assert!(tbuf.len() >= buf.len(), "case {case}: tagged smaller than fast");
        }
    }
}

#[test]
fn prop_fastser_encoded_len_is_exact() {
    let mut rng = SplitRng::new(0xBEEF, 1);
    for _ in 0..500 {
        let v = (rng.next_u64(), rng.next_u64() as i64, rng.uniform());
        let mut w = Writer::new();
        v.write(&mut w);
        assert_eq!(w.len(), v.encoded_len());
        let mut r = Reader::new(w.as_bytes());
        let back = <(u64, i64, f64)>::read(&mut r).unwrap();
        assert_eq!(back.0, v.0);
        assert_eq!(back.1, v.1);
        assert_eq!(back.2.to_bits(), v.2.to_bits());
        assert!(r.is_at_end());
    }
}

/// Hostile varint shapes: for random values, every *overlong* re-encoding
/// (extra continuation bytes ending in a terminal 0x00) must be rejected by
/// `get_varint`, while the minimal encoding round-trips. LEB128 without a
/// minimality rule maps many byte strings to one value — poison for the
/// byte-identity gates — so the decoder enforces canonical form.
#[test]
fn prop_overlong_varints_rejected_minimal_accepted() {
    let mut rng = SplitRng::new(0x0B5C_E4E, 10);
    for case in 0..300 {
        // Bias toward small values (short encodings leave room to pad).
        let v = if case % 3 == 0 { rng.below(128) } else { rng.next_u64() >> rng.below(60) };
        let mut w = Writer::new();
        w.put_varint(v);
        let minimal = w.as_bytes().to_vec();
        assert_eq!(minimal.len(), varint_len(v), "case {case}");
        let mut r = Reader::new(&minimal);
        assert_eq!(r.get_varint().unwrap(), v, "case {case}: minimal form must decode");

        // Overlong form: set the continuation bit on the last byte and
        // append a terminal zero. Same value, one byte longer — the
        // decoder must reject it (10-byte cap keeps the shape in range).
        if minimal.len() < 10 {
            let mut overlong = minimal.clone();
            *overlong.last_mut().unwrap() |= 0x80;
            overlong.push(0x00);
            let mut r = Reader::new(&overlong);
            let err = r.get_varint().unwrap_err();
            assert_eq!(err.what, "varint overlong encoding", "case {case}: v={v}");
        }
    }
}

/// Retry-path buffer hygiene: the lossy transport re-encodes frames into
/// recycled [`BufferPool`] buffers, so a *shorter* frame written over a
/// buffer that previously held a longer one must leave no stale tail —
/// `encode_frame_into` resets the length, the header's length field is
/// exact, and the checksum verifies over exactly the payload. Covers both
/// direct in-place reuse (the retransmit path) and a pool round-trip.
#[test]
fn prop_frame_reencode_into_recycled_buffers_has_no_stale_tail() {
    use blaze::ser::fastser::{decode_frame, encode_frame_into, FRAME_HEADER_BYTES};
    use blaze::util::alloc::BufferPool;

    let mut rng = SplitRng::new(0xF4A_3E6, 12);
    let pool: BufferPool = BufferPool::new();
    for case in 0..200 {
        let long: Vec<u8> = (0..64 + rng.below(900)).map(|_| rng.below(256) as u8).collect();
        let short: Vec<u8> = (0..rng.below(60)).map(|_| rng.below(256) as u8).collect();

        // Direct reuse: the same buffer carries attempt 1 (long), then is
        // re-encoded in place for a different, shorter frame.
        let buf = pool.get(FRAME_HEADER_BYTES + long.len());
        let buf = encode_frame_into(&long, buf);
        assert_eq!(decode_frame(&buf).unwrap(), &long[..], "case {case}: long frame");
        let buf = encode_frame_into(&short, buf);
        assert_eq!(
            buf.len(),
            FRAME_HEADER_BYTES + short.len(),
            "case {case}: stale tail survived in-place re-encode"
        );
        assert_eq!(decode_frame(&buf).unwrap(), &short[..], "case {case}: short frame");

        // Pool round-trip: recycle, reacquire (same class ⇒ same buffer),
        // and encode the short frame into whatever came back.
        pool.put(buf);
        let buf = pool.get(FRAME_HEADER_BYTES + long.len());
        let buf = encode_frame_into(&short, buf);
        assert_eq!(buf.len(), FRAME_HEADER_BYTES + short.len(), "case {case}");
        assert_eq!(decode_frame(&buf).unwrap(), &short[..], "case {case}: pooled reuse");
        pool.put(buf);
    }
    let (hits, _) = pool.stats();
    assert!(hits > 0, "the pool round-trip really recycled buffers");
}

/// Frame-level rejection: a batch whose count varint (or any interior
/// varint) is re-encoded overlong must fail `decode_pairs_exact`, and
/// truncating a frame at every byte boundary must error — never panic,
/// never silently return a shorter batch.
#[test]
fn prop_decode_pairs_exact_rejects_overlong_and_truncated_frames() {
    let mut rng = SplitRng::new(0xF4A_3E5, 11);
    for case in 0..100 {
        let n = 1 + rng.below(20) as usize;
        let pairs: Vec<(u64, u64)> = (0..n)
            .map(|_| (rng.below(1 << 14), rng.below(1 << 14)))
            .collect();
        let buf = encode_pairs(&pairs);
        assert_eq!(decode_pairs_exact::<u64, u64>(&buf).unwrap(), pairs, "case {case}");

        // Overlong count varint: same count, padded encoding.
        let count_len = varint_len(pairs.len() as u64);
        let mut padded = buf.clone();
        padded[count_len - 1] |= 0x80;
        padded.insert(count_len, 0x00);
        assert_eq!(
            decode_pairs_exact::<u64, u64>(&padded).unwrap_err().what,
            "varint overlong encoding",
            "case {case}: padded count accepted"
        );

        // Overlong *interior* varint: pad the first key's encoding.
        let key_len = varint_len(pairs[0].0);
        let mut padded_key = buf.clone();
        padded_key[count_len + key_len - 1] |= 0x80;
        padded_key.insert(count_len + key_len, 0x00);
        assert_eq!(
            decode_pairs_exact::<u64, u64>(&padded_key).unwrap_err().what,
            "varint overlong encoding",
            "case {case}: padded key accepted"
        );

        // Every truncation errors (the frame is self-delimiting).
        for cut in 0..buf.len() {
            assert!(
                decode_pairs_exact::<u64, u64>(&buf[..cut]).is_err(),
                "case {case}: cut {cut} accepted"
            );
        }
    }
}

// ---------- hashing properties -------------------------------------------

/// Batched hashing is a pure unroll of the scalar hash: for random key
/// sets of every awkward length (empty, sub-lane, lane-straddling),
/// `hash_batch` and `shard_batch` must agree with per-key `fxhash` —
/// the flush-routing byte-identity contract rides on this.
#[test]
fn prop_hash_batch_matches_scalar_fxhash() {
    use blaze::util::hash::{fxhash, hash_batch, hash_batch_by, shard_batch};
    let mut rng = SplitRng::new(0x4A58, 12);
    let mut hashes = Vec::new();
    let mut shards = Vec::new();
    for case in 0..200 {
        // Lengths biased around the 4-lane boundary: 0..=9 plus larger.
        let n = if case % 2 == 0 { rng.below(10) } else { rng.below(500) } as usize;
        let keys: Vec<u64> = (0..n).map(|_| rng.next_u64()).collect();
        hash_batch(&keys, &mut hashes);
        assert_eq!(hashes.len(), n, "case {case}");
        for (k, h) in keys.iter().zip(&hashes) {
            assert_eq!(*h, fxhash(k), "case {case}: lane diverged from scalar");
        }
        // Mask edge cases: 0 (one shard) through 255, always 2^k - 1.
        let mask = (1usize << rng.below(9)) - 1;
        shard_batch(&keys, mask, &mut shards);
        assert_eq!(shards.len(), n, "case {case}");
        for (k, s) in keys.iter().zip(&shards) {
            assert_eq!(*s, (fxhash(k) as usize) & mask, "case {case} mask {mask}");
        }

        // Projected keys (the flush path hashes `&pair.0`, not the pair):
        // string keys of random length, hashed through the extractor.
        let m = rng.below(40) as usize;
        let pairs: Vec<(String, u64)> = (0..m)
            .map(|_| {
                let len = rng.below(16) as usize;
                let s: String =
                    (0..len).map(|_| char::from(b'a' + rng.below(26) as u8)).collect();
                (s, rng.next_u64())
            })
            .collect();
        hash_batch_by(&pairs, |p| &p.0, &mut hashes);
        assert_eq!(hashes.len(), m, "case {case}");
        for (p, h) in pairs.iter().zip(&hashes) {
            assert_eq!(*h, fxhash(&p.0), "case {case}: projected lane diverged");
        }
    }
}

// ---------- scheduler / routing properties ------------------------------

#[test]
fn prop_block_partition_complete_and_owner_consistent() {
    let mut rng = SplitRng::new(0xCAFE, 2);
    for _ in 0..200 {
        let n = rng.below(10_000) as usize;
        let parts = 1 + rng.below(32) as usize;
        let ranges = block_ranges(n, parts);
        assert_eq!(ranges.iter().map(|r| r.len()).sum::<usize>(), n);
        // Spot-check owner agreement.
        for _ in 0..20 {
            if n == 0 {
                break;
            }
            let i = rng.below(n as u64) as usize;
            let owner = block_owner(n, parts, i);
            assert!(ranges[owner].contains(&i));
        }
    }
}

#[test]
fn prop_weighted_ranges_never_worse_than_2x_optimal() {
    let mut rng = SplitRng::new(0xD1CE, 3);
    for case in 0..100 {
        let n = 1 + rng.below(300) as usize;
        let parts = 1 + rng.below(8) as usize;
        let weights: Vec<u64> = (0..n).map(|_| 1 + rng.below(1000)).collect();
        let ranges = weighted_contiguous_ranges(&weights, parts);
        assert_eq!(ranges.len(), parts);
        assert_eq!(ranges.iter().map(|r| r.len()).sum::<usize>(), n, "case {case}");
        let total: u64 = weights.iter().sum();
        let wmax = *weights.iter().max().unwrap();
        let optimal_bound = (total as f64 / parts as f64).max(wmax as f64);
        let worst: u64 = ranges
            .iter()
            .map(|r| weights[r.clone()].iter().sum::<u64>())
            .max()
            .unwrap();
        assert!(
            (worst as f64) <= 2.0 * optimal_bound + 1.0,
            "case {case}: worst {worst} vs bound {optimal_bound}"
        );
    }
}

#[test]
fn prop_rebalance_always_covers_all_slots_and_helps() {
    let mut rng = SplitRng::new(0xF1FE, 4);
    for case in 0..100 {
        let nodes = 1 + rng.below(12) as usize;
        let map = SlotMap::even(nodes);
        let weights: Vec<u64> = (0..NUM_SLOTS)
            .map(|_| if rng.uniform() < 0.05 { rng.below(10_000) } else { rng.below(10) })
            .collect();
        let bytes: Vec<u64> = weights.iter().map(|w| w * 12).collect();
        let plan = rebalance::plan(&map, &weights, &bytes, nodes);
        // Every slot still has exactly one owner in range.
        for slot in 0..NUM_SLOTS {
            assert!(plan.new_map.node_of(slot) < nodes, "case {case}");
        }
        let before = rebalance::imbalance(&weights, &map, nodes);
        let after = rebalance::imbalance(&weights, &plan.new_map, nodes);
        assert!(after <= before * 1.01, "case {case}: {before} -> {after}");
    }
}

// ---------- engine state properties --------------------------------------

/// Word count as a model-checked state machine: whatever the cluster shape,
/// engine, or cache size, the result equals a serial HashMap fold.
#[test]
fn prop_mapreduce_equals_serial_fold() {
    let mut rng = SplitRng::new(0x5EED, 5);
    for case in 0..25 {
        let nodes = 1 + rng.below(8) as usize;
        let workers = 1 + rng.below(4) as usize;
        let engine = if rng.uniform() < 0.5 { EngineKind::Eager } else { EngineKind::Conventional };
        let cache = 1 << (2 + rng.below(12)); // 4 .. 32768 entries
        let n_lines = rng.below(400) as usize;
        let lines: Vec<String> = (0..n_lines)
            .map(|_| {
                let words = rng.below(12) as usize;
                (0..words)
                    .map(|_| format!("w{}", rng.below(50)))
                    .collect::<Vec<_>>()
                    .join(" ")
            })
            .collect();

        // Serial oracle.
        let mut oracle: std::collections::HashMap<String, u64> =
            std::collections::HashMap::new();
        for line in &lines {
            for w in line.split_whitespace() {
                *oracle.entry(w.to_string()).or_insert(0) += 1;
            }
        }

        let mut config = ClusterConfig::sized(nodes, workers).with_engine(engine);
        config.thread_cache_entries = cache;
        let c = Cluster::new(config);
        let dv = DistVector::from_vec(&c, lines);
        let mut words: DistHashMap<String, u64> = DistHashMap::new(&c);
        mapreduce(
            &dv,
            |_, line: &String, emit| {
                for w in line.split_whitespace() {
                    emit(w.to_string(), 1u64);
                }
            },
            "sum",
            &mut words,
        );
        assert_eq!(
            words.collect(),
            oracle,
            "case {case}: nodes={nodes} workers={workers} engine={engine:?} cache={cache}"
        );
    }
}

/// Dense small-key path equals the generic hash path for any key range.
#[test]
fn prop_dense_path_equals_hash_path() {
    let mut rng = SplitRng::new(0xDE45E, 6);
    for case in 0..40 {
        let nodes = 1 + rng.below(6) as usize;
        let range = 1 + rng.below(64) as usize;
        let n = 200 + rng.below(2000) as usize;
        let keys: Vec<usize> = (0..n).map(|_| rng.below(range as u64) as usize).collect();
        let vals: Vec<u64> = (0..n).map(|_| rng.below(1000)).collect();

        // Dense path: Vec target (eager engine).
        let c1 = Cluster::local(nodes, 2);
        let dv1 = DistVector::from_vec(&c1, keys.iter().copied().zip(vals.iter().copied()).collect::<Vec<(usize, u64)>>());
        let mut dense = vec![0u64; range];
        mapreduce(
            &dv1,
            |_, kv: &(usize, u64), emit| emit(kv.0, kv.1),
            "sum",
            &mut dense,
        );

        // Hash path: DistHashMap target.
        let c2 = Cluster::local(nodes, 2);
        let dv2 = DistVector::from_vec(&c2, keys.iter().copied().zip(vals.iter().copied()).collect::<Vec<(usize, u64)>>());
        let mut hashed: DistHashMap<usize, u64> = DistHashMap::new(&c2);
        mapreduce(
            &dv2,
            |_, kv: &(usize, u64), emit| emit(kv.0, kv.1),
            "sum",
            &mut hashed,
        );

        for (k, want) in dense.iter().enumerate() {
            let got = hashed.get(&k).unwrap_or(0);
            assert_eq!(got, *want, "case {case} key {k}");
        }
    }
}

/// Non-sum reducers behave identically across engines.
#[test]
fn prop_minmax_reducers_engine_parity() {
    let mut rng = SplitRng::new(0x313, 7);
    for _ in 0..20 {
        let n = 100 + rng.below(500) as usize;
        let data: Vec<(u64, i64)> = (0..n)
            .map(|_| (rng.below(20), rng.next_u64() as i64 >> 32))
            .collect();
        let run = |engine: EngineKind, red: fn() -> Reducer<i64>| {
            let c = Cluster::new(ClusterConfig::sized(3, 2).with_engine(engine));
            let dv = DistVector::from_vec(&c, data.clone());
            let mut out: DistHashMap<u64, i64> = DistHashMap::new(&c);
            mapreduce(&dv, |_, kv: &(u64, i64), emit| emit(kv.0, kv.1), red(), &mut out);
            out.collect()
        };
        assert_eq!(
            run(EngineKind::Eager, Reducer::min),
            run(EngineKind::Conventional, Reducer::min)
        );
        assert_eq!(
            run(EngineKind::Eager, Reducer::max),
            run(EngineKind::Conventional, Reducer::max)
        );
    }
}

/// Metrics invariants: pairs_shuffled ≤ pairs_emitted for eager; equal for
/// conventional. Shuffle bytes zero on one node.
#[test]
fn prop_metrics_invariants() {
    let mut rng = SplitRng::new(0x9999, 8);
    for _ in 0..20 {
        let nodes = 1 + rng.below(8) as usize;
        for engine in [EngineKind::Eager, EngineKind::Conventional] {
            let c = Cluster::new(ClusterConfig::sized(nodes, 2).with_engine(engine));
            let dv = DistVector::from_vec(
                &c,
                (0..500u64).map(|i| (i % 17, 1u64)).collect::<Vec<(u64, u64)>>(),
            );
            let mut out: DistHashMap<u64, u64> = DistHashMap::new(&c);
            mapreduce(&dv, |_, kv: &(u64, u64), emit| emit(kv.0, kv.1), "sum", &mut out);
            let m = c.metrics();
            let run = m.last_run().unwrap();
            match engine {
                EngineKind::Eager => assert!(run.pairs_shuffled <= run.pairs_emitted),
                EngineKind::Conventional => {
                    assert_eq!(run.pairs_shuffled, run.pairs_emitted)
                }
            }
            if nodes == 1 {
                assert_eq!(run.shuffle_bytes, 0, "single node must not shuffle");
            }
            assert!(run.makespan_sec > 0.0);
        }
    }
}
