//! Edge cases and failure injection across the stack: empty inputs,
//! degenerate cluster shapes, cache-boundary behaviour, hostile bytes into
//! the decoders, and misuse panics.

use blaze::containers::{DistHashMap, DistRange, DistVector};
use blaze::coordinator::cluster::{Cluster, ClusterConfig, EngineKind};
use blaze::mapreduce::{mapreduce, mapreduce_range, Reducer};
use blaze::ser::fastser::{decode_pairs, Reader};
use blaze::ser::tagged::decode_pairs_tagged;
use blaze::util::rng::SplitRng;

// ---------- degenerate inputs -------------------------------------------

#[test]
fn empty_input_all_engines_all_paths() {
    for engine in [EngineKind::Eager, EngineKind::Conventional] {
        let c = Cluster::new(ClusterConfig::sized(3, 2).with_engine(engine));
        // Generic hash path.
        let dv: DistVector<String> = DistVector::from_vec(&c, vec![]);
        let mut words: DistHashMap<String, u64> = DistHashMap::new(&c);
        mapreduce(
            &dv,
            |_, l: &String, emit| emit(l.clone(), 1u64),
            "sum",
            &mut words,
        );
        assert_eq!(words.len(), 0);
        // Dense path.
        let range = DistRange::new(&c, 0, 0);
        let mut count = vec![0u64; 1];
        mapreduce_range(&range, |_, emit| emit(0usize, 1u64), "sum", &mut count);
        assert_eq!(count[0], 0);
    }
}

#[test]
fn single_element_single_node_single_worker() {
    let c = Cluster::local(1, 1);
    let dv = DistVector::from_vec(&c, vec!["one".to_string()]);
    let mut out: DistHashMap<String, u64> = DistHashMap::new(&c);
    mapreduce(&dv, |_, l: &String, emit| emit(l.clone(), 1), "sum", &mut out);
    assert_eq!(out.get(&"one".to_string()), Some(1));
}

#[test]
fn more_nodes_than_items() {
    let c = Cluster::local(8, 4);
    let dv = DistVector::from_vec(&c, vec![1u64, 2, 3]);
    let mut out: DistHashMap<u64, u64> = DistHashMap::new(&c);
    mapreduce(&dv, |_, v: &u64, emit| emit(*v, *v), "sum", &mut out);
    assert_eq!(out.len(), 3);
    assert_eq!(out.get(&2), Some(2));
}

#[test]
fn mapper_emitting_nothing_is_fine() {
    let c = Cluster::local(2, 2);
    let dv = DistVector::from_vec(&c, (0..100u64).collect::<Vec<u64>>());
    let mut out: DistHashMap<u64, u64> = DistHashMap::new(&c);
    mapreduce(&dv, |_, _: &u64, _emit| {}, "sum", &mut out);
    assert!(out.is_empty());
    assert_eq!(c.metrics().last_run().unwrap().pairs_emitted, 0);
}

#[test]
fn mapper_emitting_many_per_item() {
    let c = Cluster::local(2, 2);
    let dv = DistVector::from_vec(&c, vec![1u64; 10]);
    let mut count = vec![0u64; 4];
    mapreduce(
        &dv,
        |_, _: &u64, emit| {
            for k in 0..4usize {
                emit(k, 1u64);
            }
        },
        "sum",
        &mut count,
    );
    assert_eq!(count, vec![10, 10, 10, 10]);
}

// ---------- cache boundary behaviour -------------------------------------

#[test]
fn thread_cache_of_one_still_correct() {
    // Every emit overflows the worker cache immediately — maximal flush
    // churn, same answer.
    let mut cfg = ClusterConfig::sized(3, 2);
    cfg.thread_cache_entries = 1;
    let c = Cluster::new(cfg);
    let data: Vec<u64> = (0..2000).map(|i| i % 7).collect();
    let dv = DistVector::from_vec(&c, data);
    let mut out: DistHashMap<u64, u64> = DistHashMap::new(&c);
    mapreduce(&dv, |_, v: &u64, emit| emit(*v, 1u64), "sum", &mut out);
    let total: u64 = (0..7).map(|k| out.get(&k).unwrap_or(0)).sum();
    assert_eq!(total, 2000);
}

#[test]
fn dense_key_at_range_boundary() {
    let c = Cluster::local(2, 1);
    let range = DistRange::new(&c, 0, 100);
    let mut out = vec![0u64; 10];
    mapreduce_range(&range, |v, emit| emit((v % 10) as usize, 1u64), "sum", &mut out);
    assert_eq!(out, vec![10u64; 10]);
}

#[test]
#[should_panic(expected = "outside fixed key range")]
fn dense_key_beyond_range_panics() {
    let c = Cluster::local(1, 1);
    let range = DistRange::new(&c, 0, 10);
    let mut out = vec![0u64; 2];
    mapreduce_range(&range, |_, emit| emit(5usize, 1u64), "sum", &mut out);
}

// ---------- hostile bytes into the decoders ------------------------------

#[test]
fn random_bytes_never_panic_decoders() {
    let mut rng = SplitRng::new(0xFFFF, 0);
    for _ in 0..2000 {
        let len = rng.below(64) as usize;
        let bytes: Vec<u8> = (0..len).map(|_| rng.next_u64() as u8).collect();
        // Must return Ok or Err, never panic, never allocate absurdly.
        let _ = decode_pairs::<String, u64>(&bytes);
        let _ = decode_pairs::<u64, f64>(&bytes);
        let _ = decode_pairs_tagged::<String, u64>(&bytes);
        let _ = decode_pairs_tagged::<u64, u64>(&bytes);
        let mut r = Reader::new(&bytes);
        let _ = r.get_varint();
    }
}

#[test]
fn hostile_length_prefix_does_not_oom() {
    // Claim 2^62 pairs; decoder must fail gracefully, not reserve memory.
    let mut bytes = vec![0xff, 0xff, 0xff, 0xff, 0xff, 0xff, 0xff, 0xff, 0x3f];
    bytes.extend_from_slice(&[1, 2, 3]);
    assert!(decode_pairs::<u64, u64>(&bytes).is_err());
}

// ---------- misuse panics (documented contracts) --------------------------

#[test]
#[should_panic(expected = "zip length mismatch")]
fn zip_length_mismatch_panics() {
    let c = Cluster::local(2, 1);
    let a = DistVector::from_vec(&c, vec![1u64, 2]);
    let b = DistVector::from_vec(&c, vec![1u64]);
    let _ = DistVector::zip(&a, &b);
}

#[test]
#[should_panic(expected = "one shard per node")]
fn from_shards_wrong_count_panics() {
    let c = Cluster::local(3, 1);
    let _ = DistVector::from_shards(&c, vec![vec![1u64]]);
}

#[test]
#[should_panic(expected = "unknown built-in reducer")]
fn unknown_reducer_name_panics() {
    let c = Cluster::local(1, 1);
    let dv = DistVector::from_vec(&c, vec![1u64]);
    let mut out: DistHashMap<u64, u64> = DistHashMap::new(&c);
    mapreduce(&dv, |_, v: &u64, emit| emit(*v, 1u64), "mean", &mut out);
}

// ---------- cross-shape determinism ---------------------------------------

#[test]
fn pagerank_deterministic_across_worker_counts() {
    use blaze::apps::pagerank::pagerank;
    use blaze::data::Graph;
    let g = Graph::graph500(8, 8, 5);
    let (_, a) = pagerank(&Cluster::local(4, 1), &g, 1e-8, 40);
    let (_, b) = pagerank(&Cluster::local(4, 8), &g, 1e-8, 40);
    assert_eq!(a.iterations, b.iterations);
    for (x, y) in a.scores.iter().zip(&b.scores) {
        assert!((x - y).abs() < 1e-12);
    }
}

#[test]
fn custom_reducer_with_custom_value_type() {
    // Paper §2.2: custom types as values need only FastSer (+TaggedSer for
    // the baseline). Keep the longest string per key.
    let c = Cluster::local(2, 2);
    let data = vec![
        ("a".to_string(), "x".to_string()),
        ("a".to_string(), "xxx".to_string()),
        ("b".to_string(), "yy".to_string()),
        ("a".to_string(), "xx".to_string()),
    ];
    let dv = DistVector::from_vec(&c, data);
    let mut out: DistHashMap<String, String> = DistHashMap::new(&c);
    mapreduce(
        &dv,
        |_, kv: &(String, String), emit| emit(kv.0.clone(), kv.1.clone()),
        Reducer::custom(|a: &mut String, b: &String| {
            if b.len() > a.len() {
                a.clone_from(b);
            }
        }),
        &mut out,
    );
    assert_eq!(out.get(&"a".to_string()), Some("xxx".to_string()));
    assert_eq!(out.get(&"b".to_string()), Some("yy".to_string()));
}

#[test]
fn foreach_then_mapreduce_composes() {
    // Paper §2.1: foreach can mutate elements in place; follow with MR.
    let c = Cluster::local(3, 2);
    let mut dv = DistVector::from_vec(&c, (0..90u64).collect::<Vec<u64>>());
    dv.foreach(|_, v| *v %= 3);
    let mut hist = vec![0u64; 3];
    mapreduce(
        &dv,
        |_, v: &u64, emit| emit(*v as usize, 1u64),
        "sum",
        &mut hist,
    );
    assert_eq!(hist, vec![30, 30, 30]);
}

#[test]
fn topk_with_ties_returns_k() {
    let c = Cluster::local(4, 2);
    let dv = DistVector::from_vec(&c, vec![7u64; 100]);
    let top = dv.topk(10, |a, b| a.cmp(b));
    assert_eq!(top, vec![7u64; 10]);
}

#[test]
fn distrange_step_mapreduce() {
    let c = Cluster::local(2, 2);
    let range = DistRange::with_step(&c, 0, 100, 10); // 0,10,...,90
    let mut sum = vec![0u64; 1];
    mapreduce_range(&range, |v, emit| emit(0usize, v), "sum", &mut sum);
    assert_eq!(sum[0], 450);
}
