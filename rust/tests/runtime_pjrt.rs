//! PJRT bridge integration tests: the python-AOT artifacts must load,
//! compile and produce numerics matching the in-rust scalar oracles.
//!
//! Requires `make artifacts` to have run; every test is skipped (with a
//! loud message) when the artifacts directory is absent so `cargo test`
//! stays green in a fresh checkout.

use blaze::data::points::PointSet;
use blaze::runtime::Runtime;
use blaze::util::linalg;

fn runtime() -> Option<Runtime> {
    match Runtime::load(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts")) {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("SKIP: artifacts not built ({e:#}); run `make artifacts`");
            None
        }
    }
}

#[test]
fn runtime_loads_all_artifacts() {
    let Some(rt) = runtime() else { return };
    let mut names = rt.artifact_names();
    names.sort_unstable();
    assert_eq!(names, vec!["gmm_estep", "kmeans_assign", "knn_dist", "pairwise_dist"]);
    assert!(rt.batch() >= 512);
    assert_eq!(rt.platform(), "cpu");
}

#[test]
fn pairwise_kernel_matches_scalar_oracle() {
    let Some(rt) = runtime() else { return };
    let (b, d, k) = (rt.batch(), rt.dim(), rt.k());
    let ps = PointSet::clustered(b, d, k, 1.0, 7);
    let centers = ps.true_centers.clone();
    let got = rt.pairwise_dist(&ps.coords, &centers).unwrap();
    assert_eq!(got.len(), b * k);
    for i in (0..b).step_by(97) {
        for c in 0..k {
            let want = ps.dist2(i, &centers[c * d..(c + 1) * d]);
            let have = got[i * k + c];
            assert!(
                (want - have).abs() <= 1e-2 + 1e-3 * want.abs(),
                "point {i} center {c}: pallas {have} vs scalar {want}"
            );
        }
    }
}

#[test]
fn kmeans_assign_matches_scalar_oracle() {
    let Some(rt) = runtime() else { return };
    let (b, d, k) = (rt.batch(), rt.dim(), rt.k());
    let ps = PointSet::clustered(b, d, k, 0.8, 11);
    let centers = ps.true_centers.clone();
    let valid = vec![1.0f32; b];
    let out = rt.kmeans_assign(&ps.coords, &centers, &valid).unwrap();

    // Scalar oracle.
    let mut counts = vec![0.0f64; k];
    let mut sums = vec![0.0f64; k * d];
    let mut inertia = 0.0f64;
    for i in 0..b {
        let (mut best, mut best_d2) = (0usize, f32::INFINITY);
        for c in 0..k {
            let d2 = ps.dist2(i, &centers[c * d..(c + 1) * d]);
            if d2 < best_d2 {
                best_d2 = d2;
                best = c;
            }
        }
        assert_eq!(out.assign[i] as usize, best, "assignment differs at {i}");
        counts[best] += 1.0;
        inertia += f64::from(best_d2);
        for dd in 0..d {
            sums[best * d + dd] += f64::from(ps.coords[i * d + dd]);
        }
    }
    for c in 0..k {
        assert!((f64::from(out.counts[c]) - counts[c]).abs() < 0.5);
        for dd in 0..d {
            let have = f64::from(out.sums[c * d + dd]);
            assert!(
                (have - sums[c * d + dd]).abs() < 0.05 * sums[c * d + dd].abs().max(10.0),
                "sum [{c},{dd}]: {have} vs {}",
                sums[c * d + dd]
            );
        }
    }
    assert!((f64::from(out.inertia) - inertia).abs() < 0.02 * inertia.max(1.0));
}

#[test]
fn kmeans_assign_mask_excludes_padding() {
    let Some(rt) = runtime() else { return };
    let (b, d, k) = (rt.batch(), rt.dim(), rt.k());
    let ps = PointSet::clustered(b, d, k, 0.8, 13);
    let centers = ps.true_centers.clone();
    let mut valid = vec![0.0f32; b];
    for v in valid.iter_mut().take(b / 4) {
        *v = 1.0;
    }
    let out = rt.kmeans_assign(&ps.coords, &centers, &valid).unwrap();
    let total: f32 = out.counts.iter().sum();
    assert!((total - (b / 4) as f32).abs() < 0.5, "masked count {total}");
}

#[test]
fn gmm_estep_matches_scalar_oracle() {
    let Some(rt) = runtime() else { return };
    let (b, d, k) = (rt.batch(), rt.dim(), rt.k());
    let ps = PointSet::clustered(b, d, k, 0.7, 17);

    // Model: true centers, identity-ish covariances, uniform weights.
    let means: Vec<f64> = ps.true_centers.iter().map(|&v| f64::from(v)).collect();
    let mut covs = vec![0.0f64; k * d * d];
    for c in 0..k {
        for i in 0..d {
            covs[c * d * d + i * d + i] = 1.0 + 0.1 * c as f64;
        }
    }
    let mut precs = vec![0.0f64; k * d * d];
    let mut logdets = vec![0.0f64; k];
    for c in 0..k {
        let cov = &covs[c * d * d..(c + 1) * d * d];
        let l = linalg::cholesky(cov, d).unwrap();
        logdets[c] = linalg::logdet_from_cholesky(&l, d);
        precs[c * d * d..(c + 1) * d * d]
            .copy_from_slice(&linalg::spd_inverse(cov, d).unwrap());
    }
    let logw = vec![-(k as f64).ln(); k];
    let to32 = |v: &[f64]| v.iter().map(|&x| x as f32).collect::<Vec<f32>>();
    let valid = vec![1.0f32; b];
    let means32 = to32(&means);
    let out = rt
        .gmm_estep(&ps.coords, &means32, &to32(&precs), &to32(&logdets), &to32(&logw), &valid)
        .unwrap();

    // Masses must sum to the batch and be finite.
    let total: f32 = out.nk.iter().sum();
    assert!((total - b as f32).abs() < 0.05 * b as f32, "nk total {total}");
    assert!(out.loglik.is_finite());

    // Cross-check against the scalar E-step used by the no-runtime path.
    let model = blaze::apps::gmm::GmmModel {
        weights: vec![1.0 / k as f64; k],
        means,
        covs,
        dim: d,
    };
    let scalar = blaze::apps::gmm::scalar_estep_for_tests(
        &ps.coords, &model, &precs, &logdets, &logw,
    );
    assert!(
        (f64::from(out.loglik) - scalar[scalar.len() - 1]).abs()
            < 1e-3 * scalar[scalar.len() - 1].abs(),
        "loglik pjrt {} vs scalar {}",
        out.loglik,
        scalar[scalar.len() - 1]
    );
    for c in 0..k {
        assert!(
            (f64::from(out.nk[c]) - scalar[c]).abs() < 0.02 * scalar[c].max(1.0),
            "nk[{c}] {} vs {}",
            out.nk[c],
            scalar[c]
        );
    }
}

#[test]
fn knn_dist_matches_scalar() {
    let Some(rt) = runtime() else { return };
    let (b, d) = (rt.batch(), rt.dim());
    let ps = PointSet::uniform(b, d, 23);
    let query = vec![0.5f32; d];
    let got = rt.knn_dist(&ps.coords, &query).unwrap();
    assert_eq!(got.len(), b);
    for i in (0..b).step_by(131) {
        let want = ps.dist2(i, &query);
        assert!((got[i] - want).abs() < 1e-4 + 1e-4 * want, "{} vs {want}", got[i]);
    }
}
