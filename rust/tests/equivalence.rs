//! Cross-engine equivalence harness.
//!
//! The paper's core claim — one highly-optimized in-memory `mapreduce` can
//! match hand-optimized parallel code — only holds if every engine computes
//! the same answer while the hot paths keep getting faster. This harness
//! generates SplitRng-seeded workloads in the paper's three shapes
//! (wordcount over duplicate-heavy string keys, Monte-Carlo π over a
//! `DistRange` with worker-stream RNG, a k-means assignment step over
//! fixed-point points) across varying cluster shapes — including empty
//! partitions and a 1×1 degenerate cluster — and asserts **byte-identical**
//! targets across:
//!
//! * eager × small-key-range (dense `Vec` target) × conventional,
//! * each engine under the recoverable fault layer: checkpoint-only,
//!   injected failures with hot-standby recovery, and injected failures
//!   with `--evacuate`-style slot re-homing, and
//! * the threaded backend (`Backend::Threaded`) at 1, 2, and 4 worker
//!   threads against the pinned-simulated reference — covering the
//!   threaded eager path (hash/vector targets), the threaded small-key
//!   path (dense `Vec` targets), and the full threaded × {ckpt, fail,
//!   fail+evac} recovery grid (the recoverable engine runs its map side —
//!   replays included — on the live pool, with shuffle bytes moving
//!   through the real channel transport).
//!
//! Values are integers (exact under any reduce order), so equality is
//! required bit-for-bit, with no float tolerance. (Threaded-vs-simulated
//! *float* bit-identity is additionally locked in by `rust/tests/exec.rs`
//! and `rust/tests/transport.rs` for single-stage jobs, where input
//! iteration order is pinned.) Canonical trace logs are gated the same
//! way — single-stage, chained two-stage, and iterative jobs must be
//! byte-identical across backends. Every future engine change is gated by
//! this file.

use blaze::containers::{DistHashMap, DistRange, DistVector};
use blaze::coordinator::cluster::{Backend, Cluster, ClusterConfig, EngineKind};
use blaze::exec::transport::TransportFaultPlan;
use blaze::fault::{FailurePlan, FaultConfig};
use blaze::mapreduce::{mapreduce, mapreduce_range, Reducer};
use blaze::util::SplitRng;

/// Cluster shapes: degenerate 1×1, more nodes than some inputs (empty
/// partitions), and mixed node/worker counts.
const SHAPES: &[(usize, usize)] = &[(1, 1), (2, 3), (3, 2), (5, 4)];

/// Engine × fault × recovery-policy grid for one cluster shape. The
/// failure plan is drawn deterministically from the workload seed; on a
/// 1-node shape it is empty (the driver is never killed), which still
/// routes the job through the recoverable engine.
fn configs(seed: u64, nodes: usize, workers: usize) -> Vec<(String, ClusterConfig)> {
    let mut out = Vec::new();
    for engine in [EngineKind::Eager, EngineKind::Conventional] {
        // Pin the simulated backend explicitly so the reference rows stay
        // the simulated engines even when `BLAZE_BACKEND` flips the
        // session default (the CI threaded leg).
        let base = ClusterConfig::sized(nodes, workers)
            .with_engine(engine)
            .with_backend(Backend::Simulated)
            .with_seed(seed);
        let plan = FailurePlan::random(seed ^ 0x5EED, nodes, 2, nodes * workers);
        out.push((format!("{engine}/plain"), base.clone()));
        out.push((
            format!("{engine}/ckpt"),
            base.clone().with_fault(FaultConfig::default().with_checkpoint_every(3)),
        ));
        out.push((
            format!("{engine}/fail"),
            base.clone().with_fault(
                FaultConfig::default().with_checkpoint_every(3).with_plan(plan.clone()),
            ),
        ));
        out.push((
            format!("{engine}/fail+evac"),
            base.clone().with_fault(
                FaultConfig::default()
                    .with_checkpoint_every(3)
                    .with_plan(plan.clone())
                    .with_evacuation(true),
            ),
        ));
        // Threaded backend axis (eager engine only — the conventional
        // baseline is never threaded): 1/2/4 OS threads run the real
        // threaded engines, shuffle bytes through the channel transport.
        // The dense-target workload (π) exercises the threaded small-key
        // path, the rest the threaded eager path. The full recovery grid
        // repeats under each thread count: fault-enabled jobs run their
        // map side — kill-induced replays included — on the live pool.
        if engine == EngineKind::Eager {
            for threads in [1usize, 2, 4] {
                let tb = base.clone().with_backend(Backend::Threaded(threads));
                out.push((format!("threaded{threads}/plain"), tb.clone()));
                out.push((
                    format!("threaded{threads}/ckpt"),
                    tb.clone()
                        .with_fault(FaultConfig::default().with_checkpoint_every(3)),
                ));
                out.push((
                    format!("threaded{threads}/fail"),
                    tb.clone().with_fault(
                        FaultConfig::default()
                            .with_checkpoint_every(3)
                            .with_plan(plan.clone()),
                    ),
                ));
                out.push((
                    format!("threaded{threads}/fail+evac"),
                    tb.with_fault(
                        FaultConfig::default()
                            .with_checkpoint_every(3)
                            .with_plan(plan.clone())
                            .with_evacuation(true),
                    ),
                ));
            }
        }
    }
    out
}

/// Assert every config produces the same result for one generated case.
fn assert_equivalent<R, F>(label: &str, seed: u64, run: F)
where
    R: PartialEq + std::fmt::Debug,
    F: Fn(&ClusterConfig) -> R,
{
    for &(nodes, workers) in SHAPES {
        let mut reference: Option<(String, R)> = None;
        for (name, cfg) in configs(seed, nodes, workers) {
            let got = run(&cfg);
            match &reference {
                None => reference = Some((name, got)),
                Some((ref_name, want)) => assert_eq!(
                    want, &got,
                    "{label}: {name} diverged from {ref_name} \
                     (shape {nodes}x{workers}, seed {seed:#x})"
                ),
            }
        }
    }
}

// ---- Wordcount shape ---------------------------------------------------

/// Duplicate-heavy lines over a small vocabulary; empty lines included.
fn gen_lines(seed: u64, n_lines: usize) -> Vec<String> {
    const VOCAB: &[&str] = &[
        "alpha", "beta", "gamma", "delta", "epsilon", "the", "a", "of", "and", "x", "yy",
        "zzz", "blaze",
    ];
    let mut rng = SplitRng::new(seed, 0x11E5);
    (0..n_lines)
        .map(|_| {
            let words = rng.below(9) as usize; // 0..=8 — empty lines included
            (0..words)
                .map(|_| VOCAB[rng.below(VOCAB.len() as u64) as usize])
                .collect::<Vec<_>>()
                .join(" ")
        })
        .collect()
}

/// Two chained MapReduces: lines → word counts (vector input, hash
/// target), then the hash map itself as input (hash-cursor coverage) →
/// a histogram keyed by (word length class, count residue).
fn run_wordcount(
    cfg: &ClusterConfig,
    lines: &[String],
) -> (Vec<(String, u64)>, Vec<(u64, u64)>) {
    let c = Cluster::new(cfg.clone());
    let dv = DistVector::from_vec(&c, lines.to_vec());
    let mut words: DistHashMap<String, u64> = DistHashMap::new(&c);
    mapreduce(
        &dv,
        |_, line: &String, emit| {
            for w in line.split_whitespace() {
                emit(w.to_string(), 1u64);
            }
        },
        "sum",
        &mut words,
    );
    let mut hist: DistHashMap<u64, u64> = DistHashMap::new(&c);
    mapreduce(
        &words,
        |w: &String, n: &u64, emit| emit((w.len() as u64 % 5) * 100 + n % 7, *n),
        "sum",
        &mut hist,
    );
    let mut counts: Vec<(String, u64)> = words.collect().into_iter().collect();
    counts.sort_unstable();
    let mut classes: Vec<(u64, u64)> = hist.collect().into_iter().collect();
    classes.sort_unstable();
    (counts, classes)
}

#[test]
fn wordcount_byte_identical_across_engines_and_policies() {
    for (i, n_lines) in [0usize, 3, 90].into_iter().enumerate() {
        let seed = 0xE0_0001 + i as u64;
        let lines = gen_lines(seed, n_lines);
        assert_equivalent("wordcount", seed, |cfg| run_wordcount(cfg, &lines));
    }
}

// ---- Monte-Carlo π shape (DistRange input, dense Vec target) -----------

/// π-style sampling: the mapper draws from the worker's published random
/// stream, so this also locks in cross-engine stream alignment. The dense
/// `Vec` target selects the small-key-range path on the eager engine.
fn run_pi(cfg: &ClusterConfig, n: u64, buckets: usize) -> Vec<u64> {
    let c = Cluster::new(cfg.clone());
    let r = DistRange::new(&c, 0, n);
    let mut hits = vec![0u64; buckets];
    mapreduce_range(
        &r,
        |v, emit| {
            let (x, y) = blaze::util::random::uniform2();
            let inside = u64::from(x * x + y * y <= 1.0);
            emit((v % buckets as u64) as usize, inside);
        },
        "sum",
        &mut hits,
    );
    hits
}

#[test]
fn pi_byte_identical_across_engines_and_policies() {
    for (i, n) in [0u64, 5, 400].into_iter().enumerate() {
        let seed = 0xF1_0001 + i as u64;
        assert_equivalent("pi", seed, |cfg| run_pi(cfg, n, 6));
    }
}

// ---- K-means assignment step (fixed-point, custom reducer) -------------

/// Per-cluster sufficient statistics: (count, (Σx, Σy)) in fixed point.
type Stat = (u64, (i64, i64));

fn add_stat(a: &mut Stat, b: &Stat) {
    a.0 += b.0;
    a.1 .0 += b.1 .0;
    a.1 .1 += b.1 .1;
}

fn gen_points(seed: u64, n: usize) -> Vec<(i64, i64)> {
    let mut rng = SplitRng::new(seed, 0x4A11);
    (0..n)
        .map(|_| (rng.below(2001) as i64 - 1000, rng.below(2001) as i64 - 1000))
        .collect()
}

fn run_kmeans_step(cfg: &ClusterConfig, points: &[(i64, i64)]) -> Vec<(u64, Stat)> {
    const CENTERS: &[(i64, i64)] = &[(-500, -500), (0, 0), (400, 300), (-200, 800)];
    let c = Cluster::new(cfg.clone());
    let dv = DistVector::from_vec(&c, points.to_vec());
    let mut stats: DistHashMap<u64, Stat> = DistHashMap::new(&c);
    mapreduce(
        &dv,
        |_, p: &(i64, i64), emit| {
            let mut best = 0u64;
            let mut best_d = i64::MAX;
            for (i, ctr) in CENTERS.iter().enumerate() {
                let (dx, dy) = (p.0 - ctr.0, p.1 - ctr.1);
                let d = dx * dx + dy * dy;
                if d < best_d {
                    best_d = d;
                    best = i as u64;
                }
            }
            emit(best, (1u64, (p.0, p.1)));
        },
        Reducer::custom_fn(add_stat),
        &mut stats,
    );
    let mut out: Vec<(u64, Stat)> = stats.collect().into_iter().collect();
    out.sort_unstable();
    out
}

#[test]
fn kmeans_step_byte_identical_across_engines_and_policies() {
    for (i, n) in [0usize, 4, 150].into_iter().enumerate() {
        let seed = 0xCA_0001 + i as u64;
        let points = gen_points(seed, n);
        assert_equivalent("kmeans-step", seed, |cfg| run_kmeans_step(cfg, &points));
    }
}

// ---- Trace determinism (structured event log gate) ---------------------

/// Failure-free seeded runs must produce **byte-identical** canonical
/// event logs (virtual-time order, measured durations excluded) across
/// the simulated engine and the threaded backend at 1/2/4 threads. The
/// gate covers the two single-stage shapes where block identity is
/// pinned: π on the dense small-key path, and a k-means assignment step
/// on the hash eager path with a tiny cache capacity so overflow flushes
/// actually occur at every backend. (Chained and iterative jobs get their
/// own canonical-trace gate below.)
#[test]
fn trace_logs_byte_identical_across_backends() {
    let backends = [
        ("simulated", Backend::Simulated),
        ("threaded1", Backend::Threaded(1)),
        ("threaded2", Backend::Threaded(2)),
        ("threaded4", Backend::Threaded(4)),
    ];
    let points = gen_points(0x7ACE, 120);
    for &(nodes, workers) in SHAPES {
        // π: dense Vec target → the small-key tree-reduce path.
        let mut reference: Option<(&str, String)> = None;
        for (name, backend) in backends {
            let cfg = ClusterConfig::sized(nodes, workers)
                .with_backend(backend)
                .with_seed(0x7ACE_0001)
                .with_trace(true);
            let c = Cluster::new(cfg.clone());
            let r = DistRange::new(&c, 0, 300);
            let mut hits = vec![0u64; 6];
            mapreduce_range(
                &r,
                |v, emit| {
                    let (x, y) = blaze::util::random::uniform2();
                    emit((v % 6) as usize, u64::from(x * x + y * y <= 1.0));
                },
                "sum",
                &mut hits,
            );
            let log = c.trace().canonical_jsonl();
            assert!(!log.is_empty(), "pi trace empty under {name}");
            match &reference {
                None => reference = Some((name, log)),
                Some((ref_name, want)) => assert_eq!(
                    want, &log,
                    "pi trace: {name} diverged from {ref_name} (shape {nodes}x{workers})"
                ),
            }
        }
        // k-means step: hash target → the eager path. Cache capacity 4
        // forces overflow flushes (the default 64Ki cap would record none
        // at these sizes, leaving CacheFlush untested).
        let mut reference: Option<(&str, String)> = None;
        for (name, backend) in backends {
            let mut cfg = ClusterConfig::sized(nodes, workers)
                .with_backend(backend)
                .with_seed(0x7ACE_0002)
                .with_trace(true);
            cfg.thread_cache_entries = 4;
            let c = Cluster::new(cfg.clone());
            let dv = DistVector::from_vec(&c, points.clone());
            let mut stats: DistHashMap<u64, Stat> = DistHashMap::new(&c);
            mapreduce(
                &dv,
                |_, p: &(i64, i64), emit| {
                    emit((p.0.unsigned_abs() % 4) as u64, (1u64, (p.0, p.1)));
                },
                Reducer::custom_fn(add_stat),
                &mut stats,
            );
            let log = c.trace().canonical_jsonl();
            assert!(!log.is_empty(), "kmeans trace empty under {name}");
            assert!(
                log.contains("\"ev\":\"CacheFlush\""),
                "cap-4 cache must overflow under {name}"
            );
            match &reference {
                None => reference = Some((name, log)),
                Some((ref_name, want)) => assert_eq!(
                    want, &log,
                    "kmeans trace: {name} diverged from {ref_name} (shape {nodes}x{workers})"
                ),
            }
        }
    }
}

/// The deterministic latency histograms on `RunStats::histograms` must be
/// byte-identical — compared through the canonical `Histogram::encode()`
/// string — across the simulated engines and the threaded backend at
/// 1/2/4 threads, on the same two single-stage shapes as the trace gate:
/// π on the dense small-key path and a k-means assignment step on the
/// hash eager path with a cap-4 cache so `cache.flush_entries` actually
/// records. `wall.`-prefixed series are real measured latencies
/// (threaded-only, advisory by design) and are excluded, exactly as
/// `blaze report --deterministic-only` excludes `hist.wall.*` fields.
#[test]
fn histograms_byte_identical_across_backends() {
    fn gated_histograms(c: &Cluster) -> Vec<(String, String)> {
        let m = c.metrics();
        let run = m.last_run().expect("run stats recorded");
        run.histograms
            .iter()
            .filter(|(name, _)| !name.starts_with("wall."))
            .map(|(name, h)| (name.clone(), h.encode()))
            .collect()
    }
    let backends = [
        ("simulated", Backend::Simulated),
        ("threaded1", Backend::Threaded(1)),
        ("threaded2", Backend::Threaded(2)),
        ("threaded4", Backend::Threaded(4)),
    ];
    let points = gen_points(0x7ACE, 120);
    for &(nodes, workers) in SHAPES {
        // π: dense Vec target → small-key tree reduce. Cross-node rounds
        // exist whenever nodes > 1, so the frame-size series must too.
        let mut reference: Option<(&str, Vec<(String, String)>)> = None;
        for (name, backend) in backends {
            let cfg = ClusterConfig::sized(nodes, workers)
                .with_backend(backend)
                .with_seed(0x7ACE_0001);
            let got = {
                let c = Cluster::new(cfg.clone());
                let r = DistRange::new(&c, 0, 300);
                let mut hits = vec![0u64; 6];
                mapreduce_range(
                    &r,
                    |v, emit| {
                        let (x, y) = blaze::util::random::uniform2();
                        emit((v % 6) as usize, u64::from(x * x + y * y <= 1.0));
                    },
                    "sum",
                    &mut hits,
                );
                gated_histograms(&c)
            };
            assert!(
                got.iter().any(|(n, _)| n == "map.block_items"),
                "pi histograms missing map.block_items under {name}"
            );
            if nodes > 1 {
                assert!(
                    got.iter().any(|(n, _)| n == "shuffle.frame_bytes"),
                    "pi histograms missing shuffle.frame_bytes under {name}"
                );
            }
            match &reference {
                None => reference = Some((name, got)),
                Some((ref_name, want)) => assert_eq!(
                    want, &got,
                    "pi histograms: {name} diverged from {ref_name} \
                     (shape {nodes}x{workers})"
                ),
            }
        }
        // k-means step: hash target → eager path; cap-4 caches overflow,
        // so the flush-size series records at every backend.
        let mut reference: Option<(&str, Vec<(String, String)>)> = None;
        for (name, backend) in backends {
            let mut cfg = ClusterConfig::sized(nodes, workers)
                .with_backend(backend)
                .with_seed(0x7ACE_0002);
            cfg.thread_cache_entries = 4;
            let got = {
                let c = Cluster::new(cfg.clone());
                let dv = DistVector::from_vec(&c, points.clone());
                let mut stats: DistHashMap<u64, Stat> = DistHashMap::new(&c);
                mapreduce(
                    &dv,
                    |_, p: &(i64, i64), emit| {
                        emit((p.0.unsigned_abs() % 4) as u64, (1u64, (p.0, p.1)));
                    },
                    Reducer::custom_fn(add_stat),
                    &mut stats,
                );
                gated_histograms(&c)
            };
            assert!(
                got.iter().any(|(n, _)| n == "cache.flush_entries"),
                "cap-4 cache must record flush sizes under {name}"
            );
            match &reference {
                None => reference = Some((name, got)),
                Some((ref_name, want)) => assert_eq!(
                    want, &got,
                    "kmeans histograms: {name} diverged from {ref_name} \
                     (shape {nodes}x{workers})"
                ),
            }
        }
    }
}

/// Canonical-trace byte-identity for **chained and iterative** jobs: a
/// two-stage hashmap pipeline (vector → word counts, then the hash map
/// itself as stage-2 input) and a two-iteration k-means loop where
/// iteration 2's mapper depends on iteration 1's reduced output. The
/// cluster trace concatenates per-job logs, so this locks in that block
/// identity, event ordering, *and* cross-job data handoff are all
/// transport- and thread-count-invariant — not just within one job.
#[test]
fn chained_and_iterative_trace_logs_byte_identical_across_backends() {
    let backends = [
        ("simulated", Backend::Simulated),
        ("threaded1", Backend::Threaded(1)),
        ("threaded2", Backend::Threaded(2)),
        ("threaded4", Backend::Threaded(4)),
    ];
    let lines = gen_lines(0x7ACE_C4A1, 60);
    let points = gen_points(0x7ACE_C4A2, 90);
    for &(nodes, workers) in SHAPES {
        // Two-stage pipeline: wordcount, then a histogram over the word
        // map (stage 2 iterates a DistHashMap input).
        let mut reference: Option<(&str, String)> = None;
        for (name, backend) in backends {
            let cfg = ClusterConfig::sized(nodes, workers)
                .with_backend(backend)
                .with_seed(0x7ACE_0003)
                .with_trace(true);
            let c = Cluster::new(cfg.clone());
            let dv = DistVector::from_vec(&c, lines.clone());
            let mut words: DistHashMap<String, u64> = DistHashMap::new(&c);
            mapreduce(
                &dv,
                |_, line: &String, emit| {
                    for w in line.split_whitespace() {
                        emit(w.to_string(), 1u64);
                    }
                },
                "sum",
                &mut words,
            );
            let mut hist: DistHashMap<u64, u64> = DistHashMap::new(&c);
            mapreduce(
                &words,
                |w: &String, n: &u64, emit| emit((w.len() as u64 % 5) * 100 + n % 7, *n),
                "sum",
                &mut hist,
            );
            let log = c.trace().canonical_jsonl();
            assert!(!log.is_empty(), "pipeline trace empty under {name}");
            match &reference {
                None => reference = Some((name, log)),
                Some((ref_name, want)) => assert_eq!(
                    want, &log,
                    "pipeline trace: {name} diverged from {ref_name} \
                     (shape {nodes}x{workers})"
                ),
            }
        }
        // Two-iteration k-means: integer centroid update between the
        // iterations, so iteration 2's block outputs (and trace) depend
        // on iteration 1 being byte-identical.
        let mut reference: Option<(&str, String)> = None;
        for (name, backend) in backends {
            let cfg = ClusterConfig::sized(nodes, workers)
                .with_backend(backend)
                .with_seed(0x7ACE_0004)
                .with_trace(true);
            let c = Cluster::new(cfg.clone());
            let dv = DistVector::from_vec(&c, points.clone());
            let mut centers: Vec<(i64, i64)> =
                vec![(-500, -500), (0, 0), (400, 300), (-200, 800)];
            for _iter in 0..2 {
                let ctrs = centers.clone();
                let mut stats: DistHashMap<u64, Stat> = DistHashMap::new(&c);
                mapreduce(
                    &dv,
                    move |_, p: &(i64, i64), emit| {
                        let mut best = 0u64;
                        let mut best_d = i64::MAX;
                        for (i, ctr) in ctrs.iter().enumerate() {
                            let (dx, dy) = (p.0 - ctr.0, p.1 - ctr.1);
                            let d = dx * dx + dy * dy;
                            if d < best_d {
                                best_d = d;
                                best = i as u64;
                            }
                        }
                        emit(best, (1u64, (p.0, p.1)));
                    },
                    Reducer::custom_fn(add_stat),
                    &mut stats,
                );
                for (k, (n, (sx, sy))) in stats.collect() {
                    if n > 0 {
                        centers[k as usize] = (sx / n as i64, sy / n as i64);
                    }
                }
            }
            let log = c.trace().canonical_jsonl();
            assert!(!log.is_empty(), "kmeans-iter trace empty under {name}");
            match &reference {
                None => reference = Some((name, log)),
                Some((ref_name, want)) => assert_eq!(
                    want, &log,
                    "kmeans-iter trace: {name} diverged from {ref_name} \
                     (shape {nodes}x{workers})"
                ),
            }
        }
    }
}

// ---- Chaos leg: mid-block kills × lossy transport ----------------------

/// Run the two-stage wordcount pipeline under `cfg`, returning the sorted
/// results, the canonical trace log, and the summed `transport.*` run
/// counters.
fn run_wordcount_chaos(
    cfg: &ClusterConfig,
    lines: &[String],
) -> ((Vec<(String, u64)>, Vec<(u64, u64)>), String, Vec<(String, u64)>) {
    let c = Cluster::new(cfg.clone());
    let dv = DistVector::from_vec(&c, lines.to_vec());
    let mut words: DistHashMap<String, u64> = DistHashMap::new(&c);
    mapreduce(
        &dv,
        |_, line: &String, emit| {
            for w in line.split_whitespace() {
                emit(w.to_string(), 1u64);
            }
        },
        "sum",
        &mut words,
    );
    let mut hist: DistHashMap<u64, u64> = DistHashMap::new(&c);
    mapreduce(
        &words,
        |w: &String, n: &u64, emit| emit((w.len() as u64 % 5) * 100 + n % 7, *n),
        "sum",
        &mut hist,
    );
    let mut counts: Vec<(String, u64)> = words.collect().into_iter().collect();
    counts.sort_unstable();
    let mut classes: Vec<(u64, u64)> = hist.collect().into_iter().collect();
    classes.sort_unstable();
    let log = c.trace().canonical_jsonl();
    let m = c.metrics();
    let mut totals: std::collections::BTreeMap<String, u64> = Default::default();
    for run in m.runs() {
        for (name, v) in &run.counters {
            if name.starts_with("transport.") || name.starts_with("fault.") {
                *totals.entry(name.clone()).or_insert(0) += v;
            }
        }
    }
    ((counts, classes), log, totals.into_iter().collect())
}

/// Full-spectrum chaos grid: {mid-block kill, lossy transport, both} ×
/// {simulated, threaded 1/2/4} × {hot-standby, evacuate}. Every leg's
/// *results* must be byte-identical to the failure-free reference.
/// Canonical traces are gated per failure mode: lossy-only legs must match
/// the lossless reference log byte-for-byte (retries, drops, and backoff
/// are chrome-only observability), and each kill config's log must be
/// byte-identical across all four backends (the `MidblockAbort` / `Kill`
/// timeline is part of the canonical record).
#[test]
fn chaos_midblock_kills_and_lossy_transport_byte_identical() {
    let backends = [
        ("simulated", Backend::Simulated),
        ("threaded1", Backend::Threaded(1)),
        ("threaded2", Backend::Threaded(2)),
        ("threaded4", Backend::Threaded(4)),
    ];
    let lines = gen_lines(0xC4A0_5EED, 90);
    for &(nodes, workers) in &[(3usize, 2usize), (5usize, 4usize)] {
        let base = ClusterConfig::sized(nodes, workers)
            .with_backend(Backend::Simulated)
            .with_seed(0xC4A0_0001)
            .with_trace(true);
        let (ref_result, ref_log, _) = run_wordcount_chaos(&base, &lines);

        // Mid-block kill: node 1 dies while its first block's map is two
        // items in; the prefix partials must never leak into any shard.
        let kill = FailurePlan::kill_at_item(1, workers, 2);
        // Lossy transport, the chaos rates from the bench matrix; the
        // retry budget is generous so no leg exhausts it here.
        let lossy = TransportFaultPlan::new(0.2, 0.05, 0xC4A0_1055).with_retry_max(16);

        // Lossy-only legs (ordinary engines, channel transport under the
        // threaded backend; the simulated backend ignores the plan).
        let mut kept: Option<(&str, String)> = None;
        for (name, backend) in backends {
            let cfg = base.clone().with_backend(backend).with_net_fault(lossy);
            let (result, log, _) = run_wordcount_chaos(&cfg, &lines);
            assert_eq!(
                ref_result, result,
                "lossy/{name} result diverged (shape {nodes}x{workers})"
            );
            assert_eq!(
                ref_log, log,
                "lossy/{name} canonical trace diverged from lossless \
                 (shape {nodes}x{workers})"
            );
            kept = kept.or(Some((name, log)));
        }
        drop(kept);

        // Kill legs (and kill+lossy legs) × recovery policy: the
        // recoverable engine's shuffle is flow-model by design, so the
        // lossy plan is inert there — the combined leg locks that in.
        for evac in [false, true] {
            for lossy_too in [false, true] {
                let mut reference: Option<(&str, String)> = None;
                for (name, backend) in backends {
                    let mut cfg = base.clone().with_backend(backend).with_fault(
                        FaultConfig::default()
                            .with_checkpoint_every(3)
                            .with_plan(kill.clone())
                            .with_evacuation(evac),
                    );
                    if lossy_too {
                        cfg = cfg.with_net_fault(lossy);
                    }
                    let (result, log, counters) = run_wordcount_chaos(&cfg, &lines);
                    assert_eq!(
                        ref_result, result,
                        "kill(evac={evac},lossy={lossy_too})/{name} result diverged \
                         (shape {nodes}x{workers})"
                    );
                    assert!(
                        log.contains("\"ev\":\"MidblockAbort\""),
                        "kill leg must record the abort under {name} \
                         (shape {nodes}x{workers})"
                    );
                    assert!(
                        counters.iter().any(|(n, v)| n == "fault.midblock_aborts" && *v > 0),
                        "kill leg must count the abort under {name}"
                    );
                    match &reference {
                        None => reference = Some((name, log)),
                        Some((ref_name, want)) => assert_eq!(
                            want, &log,
                            "kill(evac={evac},lossy={lossy_too}) trace: {name} diverged \
                             from {ref_name} (shape {nodes}x{workers})"
                        ),
                    }
                }
            }
        }
    }
}

/// The lossy legs really exercise the retry machinery: under aggressive
/// loss rates the threaded backends must record retransmissions (the
/// fates are a pure function of the plan seed, so the counts are exact
/// and identical at every thread count) while results and canonical
/// traces still match the lossless reference.
#[test]
fn lossy_transport_retries_observed_and_results_identical() {
    let lines = gen_lines(0xC4A0_5EED, 90);
    let (nodes, workers) = (3usize, 2usize);
    let base = ClusterConfig::sized(nodes, workers)
        .with_backend(Backend::Simulated)
        .with_seed(0xC4A0_0002)
        .with_trace(true);
    let (ref_result, ref_log, _) = run_wordcount_chaos(&base, &lines);
    // Half the attempts fail; a deep retry budget and an effectively
    // unbounded deadline keep every frame deliverable.
    let plan = TransportFaultPlan::new(0.4, 0.1, 0xC4A0_2066)
        .with_retry_max(64)
        .with_timeout_ns(u64::MAX);
    let mut retry_counts = Vec::new();
    for threads in [1usize, 2, 4] {
        let cfg = base.clone().with_backend(Backend::Threaded(threads)).with_net_fault(plan);
        let (result, log, counters) = run_wordcount_chaos(&cfg, &lines);
        assert_eq!(ref_result, result, "threaded{threads} lossy result diverged");
        assert_eq!(ref_log, log, "threaded{threads} lossy canonical trace diverged");
        let retries = counters
            .iter()
            .find(|(n, _)| n == "transport.retries")
            .map(|&(_, v)| v)
            .unwrap_or(0);
        assert!(retries > 0, "threaded{threads} must observe retransmissions");
        retry_counts.push(retries);
    }
    // The mirror resolves fates coordinator-side: identical counts at
    // every thread count.
    assert_eq!(retry_counts[0], retry_counts[1]);
    assert_eq!(retry_counts[1], retry_counts[2]);
}

// ---- Harness self-check ------------------------------------------------

#[test]
fn failure_configs_actually_inject_failures() {
    // Guard against the harness silently testing nothing: on a multi-node
    // shape the random plan must fire real kills, and the evacuation
    // config must charge migration traffic.
    let seed = 0xE0_0003; // the 90-line wordcount case
    let lines = gen_lines(seed, 90);
    let (nodes, workers) = (3usize, 2usize);
    let plan = FailurePlan::random(seed ^ 0x5EED, nodes, 2, nodes * workers);
    assert!(!plan.is_empty());
    let cfg = ClusterConfig::sized(nodes, workers).with_seed(seed).with_fault(
        FaultConfig::default()
            .with_checkpoint_every(3)
            .with_plan(plan)
            .with_evacuation(true),
    );
    let c = Cluster::new(cfg.clone());
    let dv = DistVector::from_vec(&c, lines.clone());
    let mut words: DistHashMap<String, u64> = DistHashMap::new(&c);
    mapreduce(
        &dv,
        |_, line: &String, emit| {
            for w in line.split_whitespace() {
                emit(w.to_string(), 1u64);
            }
        },
        "sum",
        &mut words,
    );
    let m = c.metrics();
    let note = m
        .notes()
        .iter()
        .find(|n| n.starts_with("fault["))
        .expect("fault note recorded");
    assert!(!note.contains("failures=0"), "plan must kill someone: {note}");
    assert!(
        !note.contains("evacuations=0"),
        "hash targets must evacuate under the policy: {note}"
    );
}
