//! Threaded-backend stress tests.
//!
//! The equivalence harness (`equivalence.rs`) gates threaded runs on
//! integer workloads across the full config grid; this file turns the
//! screws on the parts that can only break under *real* concurrency:
//!
//! * hostile key skew (one hot key hammering one shard stripe) with a
//!   tiny eager cache — a flush storm where a dropped or double-applied
//!   flush shows up as a wrong exact count;
//! * **float bit-identity**: for single-stage jobs (input iteration order
//!   pinned by the container) threaded runs must be bit-identical to the
//!   simulated engines even for non-associative f64 sums, at 1, 2, and 4
//!   threads, repeated so different interleavings get a chance to
//!   disagree;
//! * worker-stream RNG alignment on the threaded small-key path;
//! * thread counts above and below the block count, degenerate shapes.

use blaze::containers::{DistHashMap, DistRange, DistVector};
use blaze::coordinator::cluster::{Backend, Cluster, ClusterConfig};
use blaze::mapreduce::{mapreduce, mapreduce_range};
use blaze::util::SplitRng;

const SHAPES: &[(usize, usize)] = &[(1, 1), (2, 3), (3, 2), (4, 4)];
const THREADS: &[usize] = &[1, 2, 4];

/// Skewed `(key, value)` stream: ~70% of items hit the hot key 0, the
/// rest spread over a small vocabulary; values mix magnitudes wildly so
/// f64 addition order is observable in the low bits.
fn gen_skewed(seed: u64, n: usize) -> Vec<(u64, f64)> {
    let mut rng = SplitRng::new(seed, 0xEC_5EED);
    (0..n)
        .map(|_| {
            let key = if rng.below(10) < 7 { 0 } else { 1 + rng.below(96) };
            let mantissa = rng.below(1 << 40) as f64;
            let scale = -(rng.below(60) as i32);
            (key, mantissa * 2f64.powi(scale))
        })
        .collect()
}

/// One single-stage f64 wordcount-shaped job; result as sorted key→bits.
fn run_sum_f64(cfg: &ClusterConfig, items: &[(u64, f64)]) -> Vec<(u64, u64)> {
    let c = Cluster::new(cfg.clone());
    let dv = DistVector::from_vec(&c, items.to_vec());
    let mut out: DistHashMap<u64, f64> = DistHashMap::new(&c);
    mapreduce(&dv, |_, kv: &(u64, f64), emit| emit(kv.0, kv.1), "sum", &mut out);
    let mut bits: Vec<(u64, u64)> =
        out.collect().into_iter().map(|(k, v)| (k, v.to_bits())).collect();
    bits.sort_unstable();
    bits
}

#[test]
fn threaded_eager_bit_identical_to_simulated_under_skew_and_flush_storm() {
    for (case, &n) in [0usize, 50, 4000].iter().enumerate() {
        let seed = 0xEC_0001 + case as u64;
        let items = gen_skewed(seed, n);
        for &(nodes, workers) in SHAPES {
            // Tiny cache: every few emits overflow-flush into the shard map.
            let mut base = ClusterConfig::sized(nodes, workers).with_seed(seed);
            base.thread_cache_entries = 4;
            let reference =
                run_sum_f64(&base.clone().with_backend(Backend::Simulated), &items);
            for &threads in THREADS {
                // Repeat: different interleavings must not be able to differ.
                for rep in 0..3 {
                    let got = run_sum_f64(
                        &base.clone().with_backend(Backend::Threaded(threads)),
                        &items,
                    );
                    assert_eq!(
                        reference, got,
                        "threaded:{threads} rep {rep} diverged from simulated \
                         (shape {nodes}x{workers}, n={n}, seed {seed:#x})"
                    );
                }
            }
        }
    }
}

#[test]
fn threaded_smallkey_bit_identical_with_worker_rng() {
    // π-shaped: dense Vec target (threaded small-key path) and a mapper
    // that draws from the published worker stream, so this also locks in
    // stream alignment when blocks run on arbitrary OS threads.
    for (case, &n) in [0u64, 7, 2500].iter().enumerate() {
        let seed = 0xEC_1001 + case as u64;
        for &(nodes, workers) in SHAPES {
            let base = ClusterConfig::sized(nodes, workers).with_seed(seed);
            let run = |cfg: &ClusterConfig| -> Vec<u64> {
                let c = Cluster::new(cfg.clone());
                let r = DistRange::new(&c, 0, n);
                let mut sums = vec![0.0f64; 5];
                mapreduce_range(
                    &r,
                    |v, emit| {
                        let (x, y) = blaze::util::random::uniform2();
                        emit((v % 5) as usize, x * x + y);
                    },
                    "sum",
                    &mut sums,
                );
                sums.into_iter().map(f64::to_bits).collect()
            };
            let reference = run(&base.clone().with_backend(Backend::Simulated));
            for &threads in THREADS {
                let got = run(&base.clone().with_backend(Backend::Threaded(threads)));
                assert_eq!(
                    reference, got,
                    "threaded:{threads} smallkey diverged \
                     (shape {nodes}x{workers}, n={n}, seed {seed:#x})"
                );
            }
        }
    }
}

#[test]
fn threaded_pinned_pooled_bit_identical_to_simulated() {
    // The hot-path knobs together: pooled scratch buffers on the flush
    // path AND pinned pool workers. Neither may perturb results — the
    // simulated reference runs with the same alloc mode but no pinning.
    use blaze::util::alloc::AllocMode;
    for (case, &n) in [0usize, 50, 4000].iter().enumerate() {
        let seed = 0xEC_2001 + case as u64;
        let items = gen_skewed(seed, n);
        for &(nodes, workers) in SHAPES {
            let mut base = ClusterConfig::sized(nodes, workers)
                .with_seed(seed)
                .with_alloc(AllocMode::Pool);
            base.thread_cache_entries = 4;
            let reference =
                run_sum_f64(&base.clone().with_backend(Backend::Simulated), &items);
            for &threads in THREADS {
                let got = run_sum_f64(
                    &base
                        .clone()
                        .with_backend(Backend::Threaded(threads))
                        .with_pin_threads(true),
                    &items,
                );
                assert_eq!(
                    reference, got,
                    "threaded:{threads} pinned+pooled diverged from simulated \
                     (shape {nodes}x{workers}, n={n}, seed {seed:#x})"
                );
            }
        }
    }
}

#[test]
fn flush_storm_neither_drops_nor_double_applies() {
    // Cache capacity 1: every single emit overflow-flushes. All items hit
    // one key (one shard stripe), so any lost or duplicated flush changes
    // the exact integer total.
    const N: u64 = 20_000;
    for &threads in THREADS {
        let mut cfg = ClusterConfig::sized(3, 4).with_backend(Backend::Threaded(threads));
        cfg.thread_cache_entries = 1;
        let c = Cluster::new(cfg);
        let dv = DistVector::from_vec(&c, vec![1u64; N as usize]);
        let mut out: DistHashMap<u64, u64> = DistHashMap::new(&c);
        mapreduce(&dv, |_, one: &u64, emit| emit(7u64, *one), "sum", &mut out);
        assert_eq!(out.get(&7), Some(N), "threads={threads}: exact count violated");
        assert_eq!(out.len(), 1);
    }
}

#[test]
fn chained_hashmap_input_runs_threaded() {
    // Stage 1 output (a DistHashMap) feeds stage 2 as input — covers the
    // hash block cursor through the threaded feeder. Integer values, so
    // equality with simulated is exact regardless of map iteration order.
    let lines: Vec<String> = (0..200)
        .map(|i| match i % 4 {
            0 => "a b c".to_string(),
            1 => "a a".to_string(),
            2 => String::new(),
            _ => "c c c c".to_string(),
        })
        .collect();
    let run = |backend: Backend| -> Vec<(u64, u64)> {
        let c = Cluster::new(ClusterConfig::sized(3, 2).with_backend(backend));
        let dv = DistVector::from_vec(&c, lines.clone());
        let mut words: DistHashMap<String, u64> = DistHashMap::new(&c);
        mapreduce(
            &dv,
            |_, line: &String, emit| {
                for w in line.split_whitespace() {
                    emit(w.to_string(), 1u64);
                }
            },
            "sum",
            &mut words,
        );
        let mut hist: DistHashMap<u64, u64> = DistHashMap::new(&c);
        mapreduce(&words, |w: &String, n: &u64, emit| emit(w.len() as u64, *n), "sum", &mut hist);
        let mut out: Vec<(u64, u64)> = hist.collect().into_iter().collect();
        out.sort_unstable();
        out
    };
    let reference = run(Backend::Simulated);
    for &threads in THREADS {
        assert_eq!(reference, run(Backend::Threaded(threads)), "threads={threads}");
    }
}

#[test]
fn more_threads_than_blocks_and_empty_inputs() {
    // 1×1 cluster has a single block; 8 threads must idle gracefully.
    let run = |n: usize| {
        let c = Cluster::new(
            ClusterConfig::sized(1, 1).with_backend(Backend::Threaded(8)),
        );
        let dv = DistVector::from_vec(&c, (0..n as u64).collect());
        let mut out: DistHashMap<u64, u64> = DistHashMap::new(&c);
        mapreduce(&dv, |_, v: &u64, emit| emit(v % 3, 1u64), "sum", &mut out);
        out.collect().values().sum::<u64>()
    };
    assert_eq!(run(0), 0);
    assert_eq!(run(100), 100);
}

#[test]
fn threaded_runs_record_hybrid_accounting() {
    let c = Cluster::new(ClusterConfig::sized(2, 2).with_backend(Backend::Threaded(2)));
    let dv = DistVector::from_vec(&c, (0..500u64).collect());
    let mut out: DistHashMap<u64, u64> = DistHashMap::new(&c);
    mapreduce(&dv, |_, v: &u64, emit| emit(v % 17, 1u64), "sum", &mut out);
    let metrics = c.metrics();
    let run = metrics.last_run().expect("run recorded");
    assert_eq!(run.backend, "threaded:2");
    assert_eq!(run.engine, "blaze");
    assert!(run.makespan_sec > 0.0, "virtual accounting still present");
    assert!(run.wall_ns("map+local-reduce").is_some());
    assert!(run.wall_ns("canonical-merge").is_some());
    assert!(run.wall_ns("shuffle+absorb").is_some());
    assert!(run.wall_ns_total() > 0, "real wall clock recorded");
    assert_eq!(run.pairs_emitted, 500);
}

#[test]
fn pooled_threaded_run_surfaces_hot_path_counters() {
    use blaze::util::alloc::AllocMode;
    let mut cfg = ClusterConfig::sized(2, 2)
        .with_backend(Backend::Threaded(2))
        .with_alloc(AllocMode::Pool);
    cfg.thread_cache_entries = 4; // force repeated flush-buffer round-trips
    let c = Cluster::new(cfg);
    let dv = DistVector::from_vec(&c, (0..2000u64).collect());
    let mut out: DistHashMap<u64, u64> = DistHashMap::new(&c);
    mapreduce(&dv, |_, v: &u64, emit| emit(v % 17, 1u64), "sum", &mut out);
    let metrics = c.metrics();
    let run = metrics.last_run().expect("run recorded");
    let hits = run.counter("alloc.pool.hits").expect("alloc.pool.hits recorded");
    assert!(run.counter("alloc.pool.misses").is_some());
    assert!(run.counter("alloc.pool.pooled_bytes").is_some());
    assert!(hits > 0, "flush scratch buffers must recycle through the pool");
    let stripes = run.counter("shard.stripes").expect("stripe count recorded");
    assert!(stripes.is_power_of_two() && stripes >= 2);
    // Not pinned: the counter exists (0) rather than being absent.
    assert_eq!(run.counter("pool.pinned_threads"), Some(0));
}

#[test]
fn threaded_dense_run_records_its_phases() {
    let c = Cluster::new(ClusterConfig::sized(2, 2).with_backend(Backend::Threaded(2)));
    let r = DistRange::new(&c, 0, 300);
    let mut sums = vec![0u64; 3];
    mapreduce_range(&r, |v, emit| emit((v % 3) as usize, 1u64), "sum", &mut sums);
    assert_eq!(sums, vec![100, 100, 100]);
    let metrics = c.metrics();
    let run = metrics.last_run().expect("run recorded");
    assert_eq!(run.backend, "threaded:2");
    assert!(run.wall_ns("map+dense-local-reduce").is_some());
    assert!(run.wall_ns("tree-reduce").is_some());
}
