//! Block-cursor regression tests.
//!
//! A counting [`DistInput`] test double wraps a real container and counts,
//! per node, how many cursors are created and how many item visits happen.
//! Every engine — eager, small-key-range, conventional, and the
//! recoverable fault engine on its failure-free path — must walk each
//! node's partition **exactly once per job**, locking in the block-cursor
//! win over the old once-per-worker-block rescan (O(workers · items) host
//! overhead). Only recovery replays may re-walk, and only their own
//! blocks.

use std::cell::RefCell;

use blaze::containers::{DistHashMap, DistVector};
use blaze::coordinator::cluster::{Cluster, ClusterConfig, EngineKind};
use blaze::fault::{FailurePlan, FaultConfig};
use blaze::mapreduce::{mapreduce, BlockCursor, DistInput};

/// Transparent `DistInput` wrapper counting cursor creations and item
/// visits per node (skip-walk visits included — they are real work).
struct CountingInput<I> {
    inner: I,
    cursors_created: RefCell<Vec<usize>>,
    items_visited: RefCell<Vec<usize>>,
}

impl<I: DistInput> CountingInput<I> {
    fn new(inner: I) -> Self {
        let nodes = inner.cluster().nodes();
        Self {
            inner,
            cursors_created: RefCell::new(vec![0; nodes]),
            items_visited: RefCell::new(vec![0; nodes]),
        }
    }

    fn visits(&self, node: usize) -> usize {
        self.items_visited.borrow()[node]
    }

    fn total_visits(&self) -> usize {
        self.items_visited.borrow().iter().sum()
    }

    fn cursors(&self, node: usize) -> usize {
        self.cursors_created.borrow()[node]
    }
}

struct CountingCursor<'a, I: DistInput + 'a> {
    inner: I::Cursor<'a>,
    node: usize,
    visited: &'a RefCell<Vec<usize>>,
}

impl<'a, I: DistInput> BlockCursor<I::K, I::V> for CountingCursor<'a, I> {
    fn next_block<F: FnMut(&I::K, &I::V)>(&mut self, mut f: F) -> bool {
        let node = self.node;
        let visited = self.visited;
        self.inner.next_block(|k, v| {
            visited.borrow_mut()[node] += 1;
            f(k, v);
        })
    }
}

impl<I: DistInput> DistInput for CountingInput<I> {
    type K = I::K;
    type V = I::V;
    type Cursor<'a>
        = CountingCursor<'a, I>
    where
        Self: 'a;

    fn cluster(&self) -> &Cluster {
        self.inner.cluster()
    }

    fn node_len(&self, node: usize) -> usize {
        self.inner.node_len(node)
    }

    fn block_cursor(&self, node: usize, workers: usize) -> CountingCursor<'_, I> {
        self.cursors_created.borrow_mut()[node] += 1;
        CountingCursor {
            inner: self.inner.block_cursor(node, workers),
            node,
            visited: &self.items_visited,
        }
    }
}

const NODES: usize = 3;
const WORKERS: usize = 2;

fn engine_configs() -> Vec<(&'static str, ClusterConfig)> {
    let base = ClusterConfig::sized(NODES, WORKERS);
    let ft = FaultConfig::default().with_checkpoint_every(3);
    vec![
        ("eager", base.clone()),
        ("conventional", base.clone().with_engine(EngineKind::Conventional)),
        ("eager+ft", base.clone().with_fault(ft.clone())),
        (
            "conventional+ft",
            base.with_engine(EngineKind::Conventional).with_fault(ft),
        ),
    ]
}

#[test]
fn every_engine_walks_each_partition_exactly_once() {
    for (name, cfg) in engine_configs() {
        let c = Cluster::new(cfg);
        let input = CountingInput::new(DistVector::from_vec(&c, (0..60u64).collect()));
        let mut target: DistHashMap<u64, u64> = DistHashMap::new(&c);
        mapreduce(&input, |_, v: &u64, emit| emit(*v % 13, 1u64), "sum", &mut target);
        for node in 0..NODES {
            assert_eq!(
                input.visits(node),
                input.node_len(node),
                "{name}: node {node} items not visited exactly once"
            );
            assert_eq!(input.cursors(node), 1, "{name}: node {node} partition re-scanned");
        }
        assert_eq!(target.collect().values().sum::<u64>(), 60);
    }
}

#[test]
fn smallkey_path_walks_each_partition_exactly_once() {
    // Dense Vec target selects the small-key-range engine under eager.
    let c = Cluster::new(ClusterConfig::sized(NODES, WORKERS));
    let input = CountingInput::new(DistVector::from_vec(&c, (0..60u64).collect()));
    let mut hits = vec![0u64; 8];
    mapreduce(&input, |_, v: &u64, emit| emit((*v % 8) as usize, 1u64), "sum", &mut hits);
    assert_eq!(hits.iter().sum::<u64>(), 60);
    for node in 0..NODES {
        assert_eq!(input.visits(node), input.node_len(node), "smallkey re-walked node {node}");
        assert_eq!(input.cursors(node), 1);
    }
}

#[test]
fn hash_map_input_walks_each_partition_exactly_once() {
    let c = Cluster::new(ClusterConfig::sized(NODES, WORKERS));
    let mut m: DistHashMap<u64, u64> = DistHashMap::new(&c);
    for i in 0..50 {
        m.insert(i, i);
    }
    let input = CountingInput::new(m);
    let mut target: DistHashMap<u64, u64> = DistHashMap::new(&c);
    mapreduce(&input, |k: &u64, v: &u64, emit| emit(*k % 7, *v), "sum", &mut target);
    for node in 0..NODES {
        assert_eq!(input.visits(node), input.node_len(node), "hash input re-walked node {node}");
        assert_eq!(input.cursors(node), 1);
    }
}

#[test]
fn recovery_replays_rewalk_only_their_blocks() {
    // 60 items over 3 nodes × 2 workers → 6 blocks of 10. The DistVector
    // target (6 slots, 2 per node) guarantees every block emits a partial
    // for every shard (10 consecutive values mod 6 cover all residues), so
    // killing node 1 after block 2 commits — with no periodic checkpoint —
    // must roll back and replay exactly blocks {0, 1, 2}: 30 extra visits,
    // with no skip-walk overhead (replays start at each home's block 0).
    let run = |fault: FaultConfig| {
        let c = Cluster::new(ClusterConfig::sized(NODES, WORKERS).with_fault(fault));
        let input = CountingInput::new(DistVector::from_vec(&c, (0..60u64).collect()));
        let mut target: DistVector<u64> = DistVector::filled(&c, 6, 0u64);
        mapreduce(&input, |_, v: &u64, emit| emit((*v % 6) as usize, 1u64), "sum", &mut target);
        (target.collect(), input.total_visits())
    };
    let (base, base_visits) = run(FaultConfig::default().with_checkpoint_every(1000));
    assert_eq!(base_visits, 60, "failure-free recoverable run must be single-pass");
    let (failed, fail_visits) = run(
        FaultConfig::default()
            .with_checkpoint_every(1000)
            .with_plan(FailurePlan::kill_at_block(1, 3)),
    );
    assert_eq!(base, failed, "recovery diverged");
    assert_eq!(
        fail_visits, 90,
        "exactly the three rolled-back blocks re-walk (30 extra visits)"
    );
}
