//! Transport stress suite: the real bounded-channel shuffle under
//! hostile conditions.
//!
//! `rust/tests/exec.rs` gates the threaded backend's *results*; this
//! file gates the transport itself, end-to-end through `Cluster` runs:
//!
//! * **stall storms** — `transport_window_bytes = 1` makes every
//!   cross-node frame overflow the window, so the deterministic
//!   window-accounting mirror must report *exactly* one stall per frame
//!   (`transport.stalls == transport.frames`), at any thread count,
//!   while results stay byte-identical to the simulated engine;
//! * **hostile key skew** — one hot key concentrating ~70% of traffic
//!   on one shard stripe, with non-associative f64 values whose low
//!   bits expose any reordering the channels might introduce;
//! * **degenerate shapes** — more threads than blocks, zero-item
//!   partitions, and fully empty inputs still carry the `transport.*`
//!   counter family and the `transport` wall-clock phase;
//! * **counter hygiene** — frames/bytes are functions of the payload
//!   matrix alone (identical across thread counts and window sizes);
//!   simulated runs carry no `transport.*` counters at all.

use blaze::containers::{DistHashMap, DistRange, DistVector};
use blaze::coordinator::cluster::{Backend, Cluster, ClusterConfig};
use blaze::exec::transport::TransportFaultPlan;
use blaze::mapreduce::{mapreduce, mapreduce_range};
use blaze::util::SplitRng;

const THREADS: &[usize] = &[1, 2, 4];

/// Skewed `(key, value)` stream: ~70% of items hit the hot key 0, the
/// rest spread over a vocabulary wide enough to touch every shard;
/// values mix magnitudes so f64 addition order shows in the low bits.
fn gen_skewed(seed: u64, n: usize) -> Vec<(u64, f64)> {
    let mut rng = SplitRng::new(seed, 0x7A_5EED);
    (0..n)
        .map(|_| {
            let key = if rng.below(10) < 7 { 0 } else { 1 + rng.below(96) };
            let mantissa = rng.below(1 << 40) as f64;
            let scale = -(rng.below(60) as i32);
            (key, mantissa * 2f64.powi(scale))
        })
        .collect()
}

/// Run one f64 sum job and return `(sorted key→bits, last RunStats)`.
fn run_sum_f64(
    cfg: &ClusterConfig,
    items: &[(u64, f64)],
) -> (Vec<(u64, u64)>, blaze::coordinator::metrics::RunStats) {
    let c = Cluster::new(cfg.clone());
    let dv = DistVector::from_vec(&c, items.to_vec());
    let mut out: DistHashMap<u64, f64> = DistHashMap::new(&c);
    mapreduce(&dv, |_, kv: &(u64, f64), emit| emit(kv.0, kv.1), "sum", &mut out);
    let mut bits: Vec<(u64, u64)> =
        out.collect().into_iter().map(|(k, v)| (k, v.to_bits())).collect();
    bits.sort_unstable();
    let run = c.metrics().last_run().expect("run recorded").clone();
    (bits, run)
}

/// A one-byte window makes every cross-node frame (always ≥ 2 serialized
/// bytes) overflow: the deterministic stall mirror must charge exactly
/// one stall per frame, and the storm must not perturb results.
#[test]
fn capacity_one_window_forces_exact_stall_per_frame() {
    for &(nodes, workers) in &[(3usize, 2usize), (4, 4)] {
        let items = gen_skewed(0x7A_0001 + nodes as u64, 3000);
        let base = ClusterConfig::sized(nodes, workers).with_seed(0x7A_0002);
        let (reference, sim_run) =
            run_sum_f64(&base.clone().with_backend(Backend::Simulated), &items);
        assert!(sim_run.counter("transport.frames").is_none());

        let mut frames_seen: Option<u64> = None;
        for &threads in THREADS {
            let cfg = base
                .clone()
                .with_backend(Backend::Threaded(threads))
                .with_transport_window(1);
            let (got, run) = run_sum_f64(&cfg, &items);
            assert_eq!(reference, got, "stall storm changed results (threads={threads})");
            assert_eq!(run.backend, format!("threaded:{threads}"));

            let frames = run.counter("transport.frames").expect("frames counted");
            let stalls = run.counter("transport.stalls").expect("stalls counted");
            let bytes = run.counter("transport.bytes").expect("bytes counted");
            assert!(frames > 0, "{nodes}x{workers} must shuffle cross-node frames");
            assert_eq!(
                stalls, frames,
                "window=1: every frame must stall exactly once (threads={threads})"
            );
            assert!(bytes > frames, "frames carry multi-byte payloads");
            assert!(
                run.counter("transport.queue_peak_bytes").expect("peak counted") > 0,
                "moved frames must have sat in a destination queue"
            );
            assert!(run.wall_ns("transport").is_some(), "transport phase recorded");

            // Frames are a function of the payload matrix alone.
            match frames_seen {
                None => frames_seen = Some(frames),
                Some(f) => assert_eq!(f, frames, "frame count drifted with thread count"),
            }

            // A roomy window moves the same frames with zero stalls.
            let (got_wide, run_wide) = run_sum_f64(
                &base.clone().with_backend(Backend::Threaded(threads)),
                &items,
            );
            assert_eq!(reference, got_wide);
            assert_eq!(run_wide.counter("transport.frames"), Some(frames));
            assert_eq!(run_wide.counter("transport.bytes"), Some(bytes));
            assert_eq!(
                run_wide.counter("transport.stalls"),
                Some(0),
                "default 4 MiB window never stalls on this payload"
            );
        }
    }
}

/// Hostile skew + tiny eager cache + narrow window: flush storm and
/// stall storm together, repeated so scheduler interleavings get a
/// chance to break f64 bit-identity with the simulated reference.
#[test]
fn skewed_f64_bit_identity_survives_narrow_windows() {
    let items = gen_skewed(0x7A_1001, 2500);
    for &(nodes, workers) in &[(2usize, 3usize), (4, 2)] {
        let mut base = ClusterConfig::sized(nodes, workers).with_seed(0x7A_1002);
        base.thread_cache_entries = 4;
        let (reference, _) =
            run_sum_f64(&base.clone().with_backend(Backend::Simulated), &items);
        for &threads in THREADS {
            for window in [1u64, 64, 4 << 20] {
                for rep in 0..2 {
                    let cfg = base
                        .clone()
                        .with_backend(Backend::Threaded(threads))
                        .with_transport_window(window);
                    let (got, _) = run_sum_f64(&cfg, &items);
                    assert_eq!(
                        reference, got,
                        "threaded:{threads} window={window} rep={rep} diverged \
                         (shape {nodes}x{workers})"
                    );
                }
            }
        }
    }
}

/// Degenerate shapes: more threads than blocks, zero-item partitions,
/// and an entirely empty input. The transport counters must exist (at
/// zero where nothing moved) and results must match simulated.
#[test]
fn degenerate_shapes_keep_transport_accounting() {
    // 4x4 cluster, 3 items: most partitions are empty.
    for &n in &[0usize, 3] {
        let items: Vec<(u64, f64)> = (0..n as u64).map(|i| (i * 31, 1.5 + i as f64)).collect();
        let base = ClusterConfig::sized(4, 4).with_seed(0x7A_2001);
        let (reference, _) =
            run_sum_f64(&base.clone().with_backend(Backend::Simulated), &items);
        let cfg = base
            .clone()
            .with_backend(Backend::Threaded(8))
            .with_transport_window(1);
        let (got, run) = run_sum_f64(&cfg, &items);
        assert_eq!(reference, got, "n={n}");
        let frames = run.counter("transport.frames").expect("family present even idle");
        assert_eq!(run.counter("transport.stalls"), Some(frames), "window=1 contract");
        assert!(run.wall_ns("transport").is_some());
        if n == 0 {
            assert_eq!(frames, 0, "empty input moves nothing");
            assert_eq!(run.counter("transport.bytes"), Some(0));
        }
    }

    // Single-node cluster: all payloads are node-local, the channel
    // layer must stay idle but still report.
    let items = gen_skewed(0x7A_2002, 400);
    let base = ClusterConfig::sized(1, 2).with_seed(0x7A_2003);
    let (reference, _) = run_sum_f64(&base.clone().with_backend(Backend::Simulated), &items);
    let cfg = base.with_backend(Backend::Threaded(4)).with_transport_window(1);
    let (got, run) = run_sum_f64(&cfg, &items);
    assert_eq!(reference, got);
    assert_eq!(run.counter("transport.frames"), Some(0), "locals bypass channels");
    assert_eq!(run.counter("transport.stalls"), Some(0));
}

/// Lossy transport, exact counts: the per-attempt fates are a pure
/// function of `(plan seed, src, dst, seq, attempt)`, so the reliability
/// counters are *exactly* reproducible — identical across thread counts,
/// across repeat runs, and internally consistent (`retries = drops +
/// corrupt` when nothing times out) — while the skewed-f64 results stay
/// bit-identical to the lossless simulated reference.
#[test]
fn lossy_counters_exact_and_thread_invariant() {
    let items = gen_skewed(0x7A_4001, 3000);
    let base = ClusterConfig::sized(3, 2).with_seed(0x7A_4002);
    let (reference, sim_run) =
        run_sum_f64(&base.clone().with_backend(Backend::Simulated), &items);
    assert!(sim_run.counter("transport.retries").is_none());

    // Aggressive loss so retransmissions are certain, with a retry budget
    // and deadline deep enough that no frame can exhaust them.
    let plan = TransportFaultPlan::new(0.5, 0.1, 0x7A_4003)
        .with_retry_max(64)
        .with_timeout_ns(u64::MAX);
    let mut seen: Option<(u64, u64, u64, u64)> = None;
    for &threads in THREADS {
        for rep in 0..2 {
            let cfg =
                base.clone().with_backend(Backend::Threaded(threads)).with_net_fault(plan);
            let (got, run) = run_sum_f64(&cfg, &items);
            assert_eq!(reference, got, "lossy run diverged (threads={threads}, rep={rep})");
            let retries = run.counter("transport.retries").expect("retries counted");
            let drops = run.counter("transport.drops").expect("drops counted");
            let corrupt = run.counter("transport.corrupt").expect("corruptions counted");
            let backoff = run.counter("transport.backoff_ns").expect("backoff counted");
            assert_eq!(run.counter("transport.timeouts"), Some(0), "budget never exhausts");
            assert!(retries > 0, "half the attempts fail: retransmissions are certain");
            assert_eq!(
                retries,
                drops + corrupt,
                "every lost attempt retries exactly once (threads={threads})"
            );
            assert!(backoff > 0, "retries pay virtual backoff");
            match seen {
                None => seen = Some((retries, drops, corrupt, backoff)),
                Some(want) => assert_eq!(
                    want,
                    (retries, drops, corrupt, backoff),
                    "reliability counters drifted (threads={threads}, rep={rep})"
                ),
            }
        }
    }

    // A lossless threaded run records none of the reliability counters.
    let (_, clean) = run_sum_f64(&base.clone().with_backend(Backend::Threaded(2)), &items);
    assert!(clean.counter("transport.retries").is_none());
    assert!(clean.counter("transport.timeouts").is_none());
}

/// Retry exhaustion is a structured error, not a hang: with every attempt
/// dropped and a 3-retry budget the first cross-node frame fails after
/// exactly 4 sends and 100+200+400 µs of virtual backoff, the transport
/// declares the destination dead, and the shuffle degrades onto the flow
/// model — results still bit-identical, `transport.timeouts` and
/// `transport.backoff_ns` exact.
#[test]
fn retry_exhaustion_degrades_gracefully_with_exact_counts() {
    let items = gen_skewed(0x7A_5001, 2000);
    let base = ClusterConfig::sized(3, 2).with_seed(0x7A_5002);
    let (reference, _) = run_sum_f64(&base.clone().with_backend(Backend::Simulated), &items);
    let plan = TransportFaultPlan::new(1.0, 0.0, 0x7A_5003).with_retry_max(3);
    for &threads in THREADS {
        let cfg = base.clone().with_backend(Backend::Threaded(threads)).with_net_fault(plan);
        let (got, run) = run_sum_f64(&cfg, &items);
        assert_eq!(reference, got, "degraded run diverged (threads={threads})");
        assert_eq!(
            run.counter("transport.timeouts"),
            Some(1),
            "one structured failure per phase (threads={threads})"
        );
        // The fatal frame retried 3 times: backoff 100k + 200k + 400k ns.
        assert_eq!(run.counter("transport.backoff_ns"), Some(700_000));
        assert_eq!(run.counter("transport.retries"), Some(0), "failure path records no retry");
        assert!(run.wall_ns("transport").is_some(), "transport phase still recorded");
    }
}

/// The dense small-key path moves tree-reduce rounds through the same
/// transport: window=1 stalls every round's frame, and the reduced f64
/// sums stay bit-identical to the simulated binomial tree.
#[test]
fn smallkey_tree_reduce_stalls_and_stays_bit_identical() {
    const KEYS: usize = 5;
    let run = |cfg: &ClusterConfig| -> (Vec<u64>, blaze::coordinator::metrics::RunStats) {
        let c = Cluster::new(cfg.clone());
        let r = DistRange::new(&c, 0, 4000);
        let mut sums = vec![0.0f64; KEYS];
        mapreduce_range(
            &r,
            |v, emit| {
                let x = (v as f64 * 0.73).sin();
                emit((v % KEYS as u64) as usize, x * x);
            },
            "sum",
            &mut sums,
        );
        let run = c.metrics().last_run().expect("run recorded").clone();
        (sums.into_iter().map(f64::to_bits).collect(), run)
    };
    let base = ClusterConfig::sized(4, 2).with_seed(0x7A_3001);
    let (reference, sim_run) = run(&base.clone().with_backend(Backend::Simulated));
    assert!(sim_run.counter("transport.frames").is_none());
    for &threads in THREADS {
        let cfg = base
            .clone()
            .with_backend(Backend::Threaded(threads))
            .with_transport_window(1);
        let (got, stats) = run(&cfg);
        assert_eq!(reference, got, "threads={threads} tree-reduce diverged");
        let frames = stats.counter("transport.frames").expect("frames counted");
        assert!(frames > 0, "4-node binomial tree must move partials");
        assert_eq!(
            stats.counter("transport.stalls"),
            Some(frames),
            "window=1: one stall per tree-reduce frame"
        );
        assert!(stats.wall_ns("transport").is_some());
        assert!(stats.wall_ns("tree-reduce").is_some());
    }
}
