//! Fault-tolerance integration tests: the ISSUE acceptance criteria.
//!
//! A seeded run with an injected mid-job worker failure must produce
//! byte-identical final results (wordcount counts, k-means centroids) to
//! the failure-free run, for both the eager and conventional engines, and
//! recovery cost must be visible in the virtual makespan.

use std::collections::HashMap;

use blaze::apps::{kmeans, wordcount::wordcount};
use blaze::containers::{DistHashMap, DistVector};
use blaze::coordinator::cluster::{Cluster, ClusterConfig, EngineKind};
use blaze::data::PointSet;
use blaze::fault::{FailurePlan, FaultConfig};
use blaze::mapreduce::{mapreduce, Reducer};

const NODES: usize = 4;
const WORKERS: usize = 2;

fn cluster(engine: EngineKind, fault: FaultConfig) -> Cluster {
    Cluster::new(ClusterConfig::sized(NODES, WORKERS).with_engine(engine).with_fault(fault))
}

fn ckpt() -> FaultConfig {
    FaultConfig::default().with_checkpoint_every(3)
}

fn run_wordcount(engine: EngineKind, fault: FaultConfig) -> (HashMap<String, u64>, f64) {
    let c = cluster(engine, fault);
    let lines = blaze::data::corpus_lines(600, 8, 7);
    let dv = DistVector::from_vec(&c, lines);
    let (report, words) = wordcount(&c, &dv);
    (words.collect(), report.makespan_sec)
}

#[test]
fn wordcount_failure_is_byte_identical_both_engines() {
    for engine in [EngineKind::Eager, EngineKind::Conventional] {
        let (base, _) = run_wordcount(engine, ckpt());
        let (failed, _) =
            run_wordcount(engine, ckpt().with_plan(FailurePlan::kill_at_block(1, 3)));
        assert_eq!(base, failed, "{engine}: counts diverged after recovery");
        // And identical to the ordinary (fault-disabled) engines.
        let (plain, _) = run_wordcount(engine, FaultConfig::disabled());
        assert_eq!(base, plain, "{engine}: ft engine diverged from ordinary engine");
    }
}

#[test]
fn kmeans_centroids_byte_identical_both_engines() {
    let ps = PointSet::clustered(3000, 4, 5, 0.6, 11);
    let init = kmeans::init_first_k(&ps, 5);
    for engine in [EngineKind::Eager, EngineKind::Conventional] {
        let run = |fault: FaultConfig| {
            let c = cluster(engine, fault);
            let blocks = kmeans::distribute_blocks(&c, &ps, 256);
            let (report, result) =
                kmeans::kmeans(&c, &blocks, ps.n, 4, 5, init.clone(), 1e-4, 8, None);
            (result.centers, result.iterations, report.makespan_sec)
        };
        let (base_centers, base_iters, base_s) = run(ckpt());
        let (fail_centers, fail_iters, fail_s) =
            run(ckpt().with_plan(FailurePlan::kill_at_block(2, 4)));
        assert_eq!(base_iters, fail_iters, "{engine}: iteration count diverged");
        assert_eq!(base_centers, fail_centers, "{engine}: centroids not bit-identical");
        assert!(base_s > 0.0 && fail_s > 0.0);
    }
}

#[test]
fn multiple_failures_and_time_trigger_recover() {
    let plan = FailurePlan::kill_at_block(1, 2)
        .and_kill_at_block(3, 5)
        .and_kill_at_time(2, 0.0); // fires at the first boundary
    let (base, _) = run_wordcount(EngineKind::Eager, ckpt());
    let (failed, _) = run_wordcount(EngineKind::Eager, ckpt().with_plan(plan));
    assert_eq!(base, failed, "three deaths (all but the driver) still exact");
}

#[test]
fn failure_without_periodic_checkpoints_still_recovers() {
    // Only the mandatory epoch-0 checkpoint exists: every commit into the
    // lost shard must be rolled back and replayed.
    let fault = FaultConfig::default()
        .with_plan(FailurePlan::kill_at_block(2, 6))
        .with_checkpoint_every(1000); // cadence never reached mid-job
    let (base, _) = run_wordcount(EngineKind::Eager, ckpt());
    let (failed, _) = run_wordcount(EngineKind::Eager, fault);
    assert_eq!(base, failed);
}

#[test]
fn preexisting_target_state_survives_failure() {
    // Targets are merged into, never cleared (paper §2.2) — recovery must
    // preserve state that predates the job.
    let run = |fault: FaultConfig| {
        let c = cluster(EngineKind::Eager, fault);
        let lines = DistVector::from_vec(
            &c,
            vec!["alpha beta".to_string(); 12],
        );
        let mut words: DistHashMap<String, u64> = DistHashMap::new(&c);
        let red = Reducer::sum();
        // Pre-existing state on every node's key space.
        for i in 0..40u64 {
            words.merge(format!("seed{i}"), 1000 + i, &red);
        }
        mapreduce(
            &lines,
            |_, l: &String, emit| {
                for w in l.split_whitespace() {
                    emit(w.to_string(), 1u64);
                }
            },
            "sum",
            &mut words,
        );
        words.collect()
    };
    let base = run(ckpt());
    let failed = run(ckpt().with_plan(FailurePlan::kill_at_block(3, 2)));
    assert_eq!(base, failed);
    assert_eq!(base.get("alpha"), Some(&12));
    assert_eq!(base.get("seed7"), Some(&1007));
}

#[test]
fn recovery_cost_shows_in_metrics() {
    let c = cluster(EngineKind::Eager, ckpt().with_plan(FailurePlan::kill_at_block(1, 3)));
    let lines = blaze::data::corpus_lines(600, 8, 7);
    let dv = DistVector::from_vec(&c, lines);
    let _ = wordcount(&c, &dv);
    let m = c.metrics();
    let run = m.runs().iter().find(|r| r.label == "wordcount.mr").expect("run recorded");
    assert!(run.engine.ends_with("+ft"), "engine tag {}", run.engine);
    assert!(run.shuffle_bytes > 0, "checkpoint/restore traffic must be counted");
    let note = m
        .notes()
        .iter()
        .find(|n| n.starts_with("fault[wordcount.mr]"))
        .expect("fault note recorded");
    assert!(note.contains("failures=1"), "{note}");
    assert!(note.contains("checkpoints="), "{note}");
    // A real restore happened: bytes moved and blocks replayed or reassigned.
    assert!(note.contains("restore_bytes="), "{note}");
}

#[test]
fn driver_and_out_of_range_kills_are_ignored() {
    let plan = FailurePlan::kill_at_block(0, 1).and_kill_at_block(99, 1);
    let (base, _) = run_wordcount(EngineKind::Eager, ckpt());
    let (failed, _) = run_wordcount(EngineKind::Eager, ckpt().with_plan(plan));
    assert_eq!(base, failed, "ignored kills must not perturb results");
}

#[test]
fn dist_vector_target_recovers() {
    // PageRank-style job: DistVector as the reduce target, owner shard dies.
    let run = |fault: FaultConfig| {
        let c = cluster(EngineKind::Eager, fault);
        let input = DistVector::from_vec(&c, (0..64u64).collect::<Vec<u64>>());
        let mut scores: DistVector<f64> = DistVector::filled(&c, 16, 1.0);
        mapreduce(
            &input,
            |_, v: &u64, emit| emit((*v % 16) as usize, (*v as f64) * 0.25),
            "sum",
            &mut scores,
        );
        scores.collect()
    };
    let base = run(ckpt());
    let failed = run(ckpt().with_plan(FailurePlan::kill_at_block(2, 3)));
    assert_eq!(base, failed, "DistVector shard recovery diverged");
    // Merged-into semantics: the initial 1.0 values are part of the result.
    assert!(failed.iter().all(|&s| s >= 1.0));
}

#[test]
fn seeded_random_plan_is_reproducible_end_to_end() {
    let plan = FailurePlan::random(0xB1A2E, NODES, 2, 6);
    assert!(!plan.is_empty());
    let (a, _) = run_wordcount(EngineKind::Eager, ckpt().with_plan(plan.clone()));
    let (b, _) = run_wordcount(EngineKind::Eager, ckpt().with_plan(plan));
    let (base, _) = run_wordcount(EngineKind::Eager, ckpt());
    assert_eq!(a, b);
    assert_eq!(a, base);
}
