//! Fault-tolerance integration tests: the ISSUE acceptance criteria.
//!
//! A seeded run with an injected mid-job worker failure must produce
//! byte-identical final results (wordcount counts, k-means centroids) to
//! the failure-free run, for both the eager and conventional engines, and
//! recovery cost must be visible in the virtual makespan.

use std::collections::HashMap;

use blaze::apps::{kmeans, wordcount::wordcount};
use blaze::containers::{DistHashMap, DistVector};
use blaze::coordinator::cluster::{Backend, Cluster, ClusterConfig, EngineKind};
use blaze::data::PointSet;
use blaze::fault::{FailurePlan, FaultConfig};
use blaze::mapreduce::{mapreduce, Reducer};

const NODES: usize = 4;
const WORKERS: usize = 2;

fn cluster(engine: EngineKind, fault: FaultConfig) -> Cluster {
    Cluster::new(ClusterConfig::sized(NODES, WORKERS).with_engine(engine).with_fault(fault))
}

fn ckpt() -> FaultConfig {
    FaultConfig::default().with_checkpoint_every(3)
}

fn run_wordcount(engine: EngineKind, fault: FaultConfig) -> (HashMap<String, u64>, f64) {
    let c = cluster(engine, fault);
    let lines = blaze::data::corpus_lines(600, 8, 7);
    let dv = DistVector::from_vec(&c, lines);
    let (report, words) = wordcount(&c, &dv);
    (words.collect(), report.makespan_sec)
}

#[test]
fn wordcount_failure_is_byte_identical_both_engines() {
    for engine in [EngineKind::Eager, EngineKind::Conventional] {
        let (base, _) = run_wordcount(engine, ckpt());
        let (failed, _) =
            run_wordcount(engine, ckpt().with_plan(FailurePlan::kill_at_block(1, 3)));
        assert_eq!(base, failed, "{engine}: counts diverged after recovery");
        // And identical to the ordinary (fault-disabled) engines.
        let (plain, _) = run_wordcount(engine, FaultConfig::disabled());
        assert_eq!(base, plain, "{engine}: ft engine diverged from ordinary engine");
    }
}

#[test]
fn kmeans_centroids_byte_identical_both_engines() {
    let ps = PointSet::clustered(3000, 4, 5, 0.6, 11);
    let init = kmeans::init_first_k(&ps, 5);
    for engine in [EngineKind::Eager, EngineKind::Conventional] {
        let run = |fault: FaultConfig| {
            let c = cluster(engine, fault);
            let blocks = kmeans::distribute_blocks(&c, &ps, 256);
            let (report, result) =
                kmeans::kmeans(&c, &blocks, ps.n, 4, 5, init.clone(), 1e-4, 8, None);
            (result.centers, result.iterations, report.makespan_sec)
        };
        let (base_centers, base_iters, base_s) = run(ckpt());
        let (fail_centers, fail_iters, fail_s) =
            run(ckpt().with_plan(FailurePlan::kill_at_block(2, 4)));
        assert_eq!(base_iters, fail_iters, "{engine}: iteration count diverged");
        assert_eq!(base_centers, fail_centers, "{engine}: centroids not bit-identical");
        assert!(base_s > 0.0 && fail_s > 0.0);
    }
}

/// Sum of `failures=N` fields across all `fault[...]` notes — the number
/// of kills actually injected over a whole job sequence.
fn total_failures_in_notes(c: &Cluster) -> usize {
    let metrics = c.metrics();
    metrics
        .notes()
        .iter()
        .filter(|n| n.starts_with("fault["))
        .filter_map(|n| {
            let rest = n.split(" failures=").nth(1)?;
            rest.split_whitespace().next()?.parse::<usize>().ok()
        })
        .sum()
}

#[test]
fn once_per_sequence_kills_once_across_kmeans_iterations() {
    // Two-iteration k-means = two MapReduce jobs on one shared cluster.
    // A per-job plan re-fires the same kill every iteration; a
    // once-per-sequence plan injects it exactly once. Results stay
    // byte-identical to the failure-free baseline in all three cases.
    let ps = PointSet::clustered(800, 4, 3, 0.6, 23);
    let init = kmeans::init_first_k(&ps, 3);
    let run = |fault: FaultConfig| {
        let c = cluster(EngineKind::Eager, fault);
        let blocks = kmeans::distribute_blocks(&c, &ps, 64);
        // tol = 0 never converges early: exactly 2 iterations.
        let (_, result) = kmeans::kmeans(&c, &blocks, ps.n, 4, 3, init.clone(), 0.0, 2, None);
        assert_eq!(result.iterations, 2, "two-iteration sequence expected");
        (result.centers, total_failures_in_notes(&c))
    };

    let (base_centers, base_failures) = run(ckpt());
    assert_eq!(base_failures, 0);

    let plan = FailurePlan::kill_at_block(1, 2);
    let (per_job_centers, per_job_failures) = run(ckpt().with_plan(plan.clone()));
    assert_eq!(per_job_failures, 2, "per-job plans re-fire every iteration");
    assert_eq!(per_job_centers, base_centers, "per-job kills still byte-identical");

    let (once_centers, once_failures) = run(ckpt().with_plan(plan.once_per_sequence()));
    assert_eq!(once_failures, 1, "once-per-sequence fires exactly one kill");
    assert_eq!(once_centers, base_centers, "single kill still byte-identical");
}

#[test]
fn multiple_failures_and_time_trigger_recover() {
    let plan = FailurePlan::kill_at_block(1, 2)
        .and_kill_at_block(3, 5)
        .and_kill_at_time(2, 0.0); // fires at the first boundary
    let (base, _) = run_wordcount(EngineKind::Eager, ckpt());
    let (failed, _) = run_wordcount(EngineKind::Eager, ckpt().with_plan(plan));
    assert_eq!(base, failed, "three deaths (all but the driver) still exact");
}

#[test]
fn failure_without_periodic_checkpoints_still_recovers() {
    // Only the mandatory epoch-0 checkpoint exists: every commit into the
    // lost shard must be rolled back and replayed.
    let fault = FaultConfig::default()
        .with_plan(FailurePlan::kill_at_block(2, 6))
        .with_checkpoint_every(1000); // cadence never reached mid-job
    let (base, _) = run_wordcount(EngineKind::Eager, ckpt());
    let (failed, _) = run_wordcount(EngineKind::Eager, fault);
    assert_eq!(base, failed);
}

#[test]
fn preexisting_target_state_survives_failure() {
    // Targets are merged into, never cleared (paper §2.2) — recovery must
    // preserve state that predates the job.
    let run = |fault: FaultConfig| {
        let c = cluster(EngineKind::Eager, fault);
        let lines = DistVector::from_vec(
            &c,
            vec!["alpha beta".to_string(); 12],
        );
        let mut words: DistHashMap<String, u64> = DistHashMap::new(&c);
        let red = Reducer::sum();
        // Pre-existing state on every node's key space.
        for i in 0..40u64 {
            words.merge(format!("seed{i}"), 1000 + i, &red);
        }
        mapreduce(
            &lines,
            |_, l: &String, emit| {
                for w in l.split_whitespace() {
                    emit(w.to_string(), 1u64);
                }
            },
            "sum",
            &mut words,
        );
        words.collect()
    };
    let base = run(ckpt());
    let failed = run(ckpt().with_plan(FailurePlan::kill_at_block(3, 2)));
    assert_eq!(base, failed);
    assert_eq!(base.get("alpha"), Some(&12));
    assert_eq!(base.get("seed7"), Some(&1007));
}

#[test]
fn recovery_cost_shows_in_metrics() {
    let c = cluster(EngineKind::Eager, ckpt().with_plan(FailurePlan::kill_at_block(1, 3)));
    let lines = blaze::data::corpus_lines(600, 8, 7);
    let dv = DistVector::from_vec(&c, lines);
    let _ = wordcount(&c, &dv);
    let m = c.metrics();
    let run = m.runs().iter().find(|r| r.label == "wordcount.mr").expect("run recorded");
    assert!(run.engine.ends_with("+ft"), "engine tag {}", run.engine);
    assert!(run.shuffle_bytes > 0, "checkpoint/restore traffic must be counted");
    let note = m
        .notes()
        .iter()
        .find(|n| n.starts_with("fault[wordcount.mr]"))
        .expect("fault note recorded");
    assert!(note.contains("failures=1"), "{note}");
    assert!(note.contains("checkpoints="), "{note}");
    // A real restore happened: bytes moved and blocks replayed or reassigned.
    assert!(note.contains("restore_bytes="), "{note}");
}

#[test]
fn driver_and_out_of_range_kills_are_ignored() {
    let plan = FailurePlan::kill_at_block(0, 1).and_kill_at_block(99, 1);
    let (base, _) = run_wordcount(EngineKind::Eager, ckpt());
    let (failed, _) = run_wordcount(EngineKind::Eager, ckpt().with_plan(plan));
    assert_eq!(base, failed, "ignored kills must not perturb results");
}

#[test]
fn dist_vector_target_recovers() {
    // PageRank-style job: DistVector as the reduce target, owner shard dies.
    let run = |fault: FaultConfig| {
        let c = cluster(EngineKind::Eager, fault);
        let input = DistVector::from_vec(&c, (0..64u64).collect::<Vec<u64>>());
        let mut scores: DistVector<f64> = DistVector::filled(&c, 16, 1.0);
        mapreduce(
            &input,
            |_, v: &u64, emit| emit((*v % 16) as usize, (*v as f64) * 0.25),
            "sum",
            &mut scores,
        );
        scores.collect()
    };
    let base = run(ckpt());
    let failed = run(ckpt().with_plan(FailurePlan::kill_at_block(2, 3)));
    assert_eq!(base, failed, "DistVector shard recovery diverged");
    // Merged-into semantics: the initial 1.0 values are part of the result.
    assert!(failed.iter().all(|&s| s >= 1.0));
}

#[test]
fn seeded_random_plan_is_reproducible_end_to_end() {
    let plan = FailurePlan::random(0xB1A2E, NODES, 2, 6);
    assert!(!plan.is_empty());
    let (a, _) = run_wordcount(EngineKind::Eager, ckpt().with_plan(plan.clone()));
    let (b, _) = run_wordcount(EngineKind::Eager, ckpt().with_plan(plan));
    let (base, _) = run_wordcount(EngineKind::Eager, ckpt());
    assert_eq!(a, b);
    assert_eq!(a, base);
}

// ---- Recovery-time slot evacuation ------------------------------------

#[test]
fn evacuation_is_byte_identical_both_engines() {
    for engine in [EngineKind::Eager, EngineKind::Conventional] {
        let (base, _) = run_wordcount(engine, ckpt());
        let (evac, _) = run_wordcount(
            engine,
            ckpt().with_plan(FailurePlan::kill_at_block(1, 3)).with_evacuation(true),
        );
        assert_eq!(base, evac, "{engine}: evacuation changed results");
        // And identical to the hot-standby recovery policy.
        let (standby, _) =
            run_wordcount(engine, ckpt().with_plan(FailurePlan::kill_at_block(1, 3)));
        assert_eq!(evac, standby, "{engine}: the two recovery policies diverged");
    }
}

#[test]
fn evacuation_reroutes_dead_shard_and_charges_migration() {
    let fault = ckpt().with_plan(FailurePlan::kill_at_block(1, 3)).with_evacuation(true);
    let c = cluster(EngineKind::Eager, fault);
    let lines = blaze::data::corpus_lines(600, 8, 7);
    let dv = DistVector::from_vec(&c, lines);
    let (_, words) = wordcount(&c, &dv);
    // The dead node's shard was drained and no key routes to it anymore.
    assert!(words.shard(1).is_empty(), "dead shard must be evacuated");
    for node in 0..NODES {
        for (k, _) in words.shard(node) {
            assert_ne!(words.owner_of(k), 1, "key {k:?} still routed to dead node 1");
        }
    }
    // Migration bytes are visible in RunStats and the fault note.
    let m = c.metrics();
    let run = m.runs().iter().find(|r| r.label == "wordcount.mr").expect("run recorded");
    assert!(run.evac_bytes > 0, "migration traffic must be charged");
    assert!(run.shuffle_bytes >= run.evac_bytes, "evac bytes fold into shuffle bytes");
    let note = m
        .notes()
        .iter()
        .find(|n| n.starts_with("fault[wordcount.mr]"))
        .expect("fault note recorded");
    assert!(note.contains("evacuations=1"), "{note}");
    assert!(!note.contains("evac_bytes=0 "), "{note}");
}

#[test]
fn evacuation_without_plan_changes_nothing() {
    // The policy toggle alone (no failure) must be a no-op: same results,
    // no evacuation recorded.
    let (base, _) = run_wordcount(EngineKind::Eager, ckpt());
    let c = cluster(EngineKind::Eager, ckpt().with_evacuation(true));
    let lines = blaze::data::corpus_lines(600, 8, 7);
    let dv = DistVector::from_vec(&c, lines);
    let (_, words) = wordcount(&c, &dv);
    assert_eq!(base, words.collect());
    let m = c.metrics();
    let run = m.runs().iter().find(|r| r.label == "wordcount.mr").expect("run recorded");
    assert_eq!(run.evac_bytes, 0, "no failure → no evacuation traffic");
    let note = m
        .notes()
        .iter()
        .find(|n| n.starts_with("fault[wordcount.mr]"))
        .expect("fault note recorded");
    assert!(note.contains("evacuations=0"), "{note}");
}

#[test]
fn evacuation_survives_multiple_failures() {
    // A second failure after an evacuation must roll back against the
    // post-evacuation routing (re-stabilization checkpoint) and still be
    // byte-identical — including when the second victim adopted keys.
    let plan = FailurePlan::kill_at_block(1, 2).and_kill_at_block(3, 5);
    let (base, _) = run_wordcount(EngineKind::Eager, ckpt());
    let (evac, _) = run_wordcount(EngineKind::Eager, ckpt().with_plan(plan).with_evacuation(true));
    assert_eq!(base, evac, "two evacuations diverged from failure-free run");
}

#[test]
fn evacuation_falls_back_for_block_addressed_targets() {
    // DistVector targets cannot re-home keys: the engine keeps hot-standby
    // recovery, notes the fallback, and results stay exact.
    let run = |fault: FaultConfig| {
        let c = cluster(EngineKind::Eager, fault);
        let input = DistVector::from_vec(&c, (0..64u64).collect::<Vec<u64>>());
        let mut scores: DistVector<u64> = DistVector::filled(&c, 16, 1u64);
        mapreduce(
            &input,
            |_, v: &u64, emit| emit((*v % 16) as usize, *v),
            "sum",
            &mut scores,
        );
        let notes: Vec<String> = c.metrics().notes().to_vec();
        (scores.collect(), notes)
    };
    let (base, _) = run(ckpt());
    let (evac, notes) =
        run(ckpt().with_plan(FailurePlan::kill_at_block(2, 3)).with_evacuation(true));
    assert_eq!(base, evac, "fallback recovery diverged");
    assert!(
        notes.iter().any(|n| n.contains("cannot re-home keys")),
        "fallback must be noted: {notes:?}"
    );
}

// ---- Structured fault timeline (trace events) --------------------------

/// Run wordcount on a trace-enabled cluster and return the event-kind
/// names of the `wordcount.mr` job, in canonical order, plus the cluster.
fn traced_wordcount(fault: FaultConfig) -> (Vec<&'static str>, Cluster) {
    let c = Cluster::new(
        ClusterConfig::sized(NODES, WORKERS)
            .with_engine(EngineKind::Eager)
            .with_fault(fault)
            .with_trace(true),
    );
    let lines = blaze::data::corpus_lines(600, 8, 7);
    let dv = DistVector::from_vec(&c, lines);
    let _ = wordcount(&c, &dv);
    let kinds: Vec<&'static str> = {
        let trace = c.trace();
        let job = trace
            .jobs()
            .iter()
            .find(|j| j.label == "wordcount.mr")
            .expect("wordcount.mr trace recorded");
        job.events.iter().map(|e| e.kind.name()).collect()
    };
    (kinds, c)
}

#[test]
fn fault_trace_orders_kill_rollback_replay() {
    // Kill at commit 4 with checkpoints every 3: the post-checkpoint
    // commit must roll back and replay. (A kill at a checkpoint boundary
    // would roll back nothing and leave the timeline untested.)
    let (kinds, _c) = traced_wordcount(ckpt().with_plan(FailurePlan::kill_at_block(1, 4)));
    let kill = kinds.iter().position(|k| *k == "Kill").expect("Kill event");
    let rollbacks: Vec<usize> =
        (0..kinds.len()).filter(|&i| kinds[i] == "Rollback").collect();
    let replays: Vec<usize> = (0..kinds.len()).filter(|&i| kinds[i] == "Replay").collect();
    assert!(!rollbacks.is_empty(), "post-checkpoint commit must roll back: {kinds:?}");
    assert!(!replays.is_empty(), "rolled-back blocks must replay: {kinds:?}");
    assert!(rollbacks.iter().all(|&i| i > kill), "rollbacks follow the kill");
    assert!(
        replays.iter().min() > rollbacks.iter().max(),
        "replays run after every rollback: {kinds:?}"
    );
    assert!(!kinds.contains(&"Evacuate"), "hot-standby run must not evacuate");
    assert_eq!(kinds.last(), Some(&"FaultSummary"), "summary closes the job");
    assert!(kinds.contains(&"Checkpoint"), "epoch-0 + cadence checkpoints recorded");
}

#[test]
fn fault_trace_orders_evacuation_after_replays_drain() {
    // Evacuation is deferred: the victim's rollback replays must drain
    // before its key space re-homes, so the timeline reads
    // Kill -> Rollback(s) -> Replay(s) -> Migrate(s) -> Evacuate.
    let (kinds, _c) = traced_wordcount(
        ckpt().with_plan(FailurePlan::kill_at_block(1, 4)).with_evacuation(true),
    );
    let kill = kinds.iter().position(|k| *k == "Kill").expect("Kill event");
    let evac = kinds.iter().position(|k| *k == "Evacuate").expect("Evacuate event");
    let migrates: Vec<usize> = (0..kinds.len()).filter(|&i| kinds[i] == "Migrate").collect();
    let replays: Vec<usize> = (0..kinds.len()).filter(|&i| kinds[i] == "Replay").collect();
    assert!(evac > kill, "evacuation follows the kill");
    assert!(!replays.is_empty(), "rolled-back blocks must replay");
    assert!(
        replays.iter().all(|&i| kill < i && i < evac),
        "replays drain between the kill and the evacuation: {kinds:?}"
    );
    assert!(
        migrates.iter().all(|&i| kill < i && i < evac),
        "migrations immediately precede the evacuate event"
    );
    assert_eq!(kinds.last(), Some(&"FaultSummary"));
}

#[test]
fn fault_summary_event_renders_the_recorded_note() {
    // The typed FaultSummary event is the source of truth; the legacy
    // free-form note is its rendered view, byte-for-byte.
    let (_kinds, c) = traced_wordcount(ckpt().with_plan(FailurePlan::kill_at_block(1, 4)));
    let rendered = {
        let trace = c.trace();
        let job = trace
            .jobs()
            .iter()
            .find(|j| j.label == "wordcount.mr")
            .expect("wordcount.mr trace recorded");
        let summary = job
            .events
            .iter()
            .find(|e| e.kind.name() == "FaultSummary")
            .expect("FaultSummary event");
        summary.render_note("wordcount.mr").expect("summary renders a note")
    };
    let m = c.metrics();
    let note = m
        .notes()
        .iter()
        .find(|n| n.starts_with("fault[wordcount.mr]"))
        .expect("fault note recorded");
    assert_eq!(&rendered, note, "rendered summary must equal the legacy note");
}

// ---- Threaded recovery: replay on the live pool ------------------------

/// Wordcount on an explicitly pinned backend; returns the counts and the
/// job's RunStats (cloned out of the registry).
fn run_wordcount_on(
    backend: Backend,
    fault: FaultConfig,
) -> (HashMap<String, u64>, blaze::coordinator::metrics::RunStats) {
    let c = Cluster::new(
        ClusterConfig::sized(NODES, WORKERS)
            .with_engine(EngineKind::Eager)
            .with_backend(backend)
            .with_fault(fault),
    );
    let lines = blaze::data::corpus_lines(600, 8, 7);
    let dv = DistVector::from_vec(&c, lines);
    let (_, words) = wordcount(&c, &dv);
    let stats = c
        .metrics()
        .runs()
        .iter()
        .find(|r| r.label == "wordcount.mr")
        .expect("run recorded")
        .clone();
    (words.collect(), stats)
}

#[test]
fn threaded_recovery_byte_identical_to_simulated() {
    // The kill fires at a block-boundary commit while speculative map
    // results for later blocks are already buffered from the live pool:
    // rollback, replay (re-executed on the pool), and the final counts
    // must match the simulated recoverable engine exactly.
    for plan in [
        ckpt().with_plan(FailurePlan::kill_at_block(1, 4)),
        ckpt().with_plan(FailurePlan::kill_at_block(1, 4)).with_evacuation(true),
    ] {
        let (reference, sim_stats) = run_wordcount_on(Backend::Simulated, plan.clone());
        assert_eq!(sim_stats.backend, "simulated");
        for threads in [2usize, 4] {
            let (got, stats) = run_wordcount_on(Backend::Threaded(threads), plan.clone());
            assert_eq!(
                reference, got,
                "threaded:{threads} recovery diverged (evac={})",
                plan.evacuate
            );
            assert_eq!(stats.backend, format!("threaded:{threads}"));
            assert!(stats.engine.ends_with("+ft"), "engine tag {}", stats.engine);
            // The map side (replays included) really ran on the pool.
            assert!(stats.counter("pool.queue_peak").is_some(), "pool accounting");
            let pool_blocks: u64 = (0..threads)
                .map(|t| stats.counter(&format!("pool.thread{t}.blocks")).unwrap_or(0))
                .sum();
            assert!(pool_blocks > 0, "blocks must execute on pool threads");
            assert!(stats.shuffle_bytes > 0, "checkpoint/restore traffic counted");
        }
    }
}

#[test]
fn threaded_evacuation_reroutes_dead_shard() {
    let fault = ckpt().with_plan(FailurePlan::kill_at_block(1, 3)).with_evacuation(true);
    let c = Cluster::new(
        ClusterConfig::sized(NODES, WORKERS)
            .with_engine(EngineKind::Eager)
            .with_backend(Backend::Threaded(2))
            .with_fault(fault),
    );
    let lines = blaze::data::corpus_lines(600, 8, 7);
    let dv = DistVector::from_vec(&c, lines);
    let (_, words) = wordcount(&c, &dv);
    // Post-evacuation routing applied to replayed partials too: nothing
    // may land on (or route to) the dead shard.
    assert!(words.shard(1).is_empty(), "dead shard must be evacuated");
    for node in 0..NODES {
        for (k, _) in words.shard(node) {
            assert_ne!(words.owner_of(k), 1, "key {k:?} still routed to dead node 1");
        }
    }
    let m = c.metrics();
    let run = m.runs().iter().find(|r| r.label == "wordcount.mr").expect("run recorded");
    assert!(run.evac_bytes > 0, "migration traffic must be charged");
    assert_eq!(run.backend, "threaded:2");
}

#[test]
fn threaded_fault_trace_keeps_kill_rollback_replay_order() {
    // Same timeline contract as the simulated engine, with the map side
    // on real threads: commits are serialized, so the canonical order
    // Kill -> Rollback(s) -> Replay(s) -> FaultSummary must hold.
    let c = Cluster::new(
        ClusterConfig::sized(NODES, WORKERS)
            .with_engine(EngineKind::Eager)
            .with_backend(Backend::Threaded(4))
            .with_fault(ckpt().with_plan(FailurePlan::kill_at_block(1, 4)))
            .with_trace(true),
    );
    let lines = blaze::data::corpus_lines(600, 8, 7);
    let dv = DistVector::from_vec(&c, lines);
    let _ = wordcount(&c, &dv);
    let trace = c.trace();
    let job = trace
        .jobs()
        .iter()
        .find(|j| j.label == "wordcount.mr")
        .expect("wordcount.mr trace recorded");
    let kinds: Vec<&'static str> = job.events.iter().map(|e| e.kind.name()).collect();
    let kill = kinds.iter().position(|k| *k == "Kill").expect("Kill event");
    let rollbacks: Vec<usize> =
        (0..kinds.len()).filter(|&i| kinds[i] == "Rollback").collect();
    let replays: Vec<usize> = (0..kinds.len()).filter(|&i| kinds[i] == "Replay").collect();
    assert!(!rollbacks.is_empty(), "post-checkpoint commit must roll back: {kinds:?}");
    assert!(!replays.is_empty(), "rolled-back blocks must replay: {kinds:?}");
    assert!(rollbacks.iter().all(|&i| i > kill), "rollbacks follow the kill");
    assert!(
        replays.iter().min() > rollbacks.iter().max(),
        "replays run after every rollback: {kinds:?}"
    );
    assert_eq!(kinds.last(), Some(&"FaultSummary"), "summary closes the job");
}

#[test]
fn conventional_ft_never_threads() {
    // The conventional baseline models a serial system; a threaded
    // backend request must not change its execution or its accounting.
    let fault = ckpt().with_plan(FailurePlan::kill_at_block(1, 3));
    let run = |backend: Backend| {
        let c = Cluster::new(
            ClusterConfig::sized(NODES, WORKERS)
                .with_engine(EngineKind::Conventional)
                .with_backend(backend)
                .with_fault(fault.clone()),
        );
        let lines = blaze::data::corpus_lines(600, 8, 7);
        let dv = DistVector::from_vec(&c, lines);
        let (_, words) = wordcount(&c, &dv);
        let stats = c
            .metrics()
            .runs()
            .iter()
            .find(|r| r.label == "wordcount.mr")
            .expect("run recorded")
            .clone();
        (words.collect(), stats)
    };
    let (reference, sim) = run(Backend::Simulated);
    let (got, thr) = run(Backend::Threaded(4));
    assert_eq!(reference, got);
    assert_eq!(sim.backend, "simulated");
    assert_eq!(thr.backend, "simulated", "conventional+ft always executes serial");
    assert!(thr.counter("pool.queue_peak").is_none(), "no pool accounting");
}

// ---- Conventional-mode serialization parity ---------------------------

#[test]
fn conventional_ft_charges_local_serialization_like_ordinary_engine() {
    // ROADMAP divergence (PR 1): the recoverable conventional engine
    // skipped node-local serialization. Both engines materialize the same
    // raw pair multiset and tag-encode each record independently, so on a
    // no-failure run their serialized byte totals must now match exactly.
    let lines = blaze::data::corpus_lines(400, 8, 7);
    let run = |fault: FaultConfig| {
        let c = cluster(EngineKind::Conventional, fault);
        let dv = DistVector::from_vec(&c, lines.clone());
        let (_, words) = wordcount(&c, &dv);
        let stats = c
            .metrics()
            .runs()
            .iter()
            .find(|r| r.label == "wordcount.mr")
            .expect("run recorded")
            .clone();
        (words.collect(), stats)
    };
    let (base, plain) = run(FaultConfig::disabled());
    // Cadence beyond the job's block count: recoverable engine, epoch-0
    // checkpoint only, no failures.
    let (ft_res, ft) = run(FaultConfig::default().with_checkpoint_every(1000));
    assert_eq!(base, ft_res);
    assert_eq!(plain.pairs_emitted, ft.pairs_emitted);
    assert_eq!(plain.pairs_shuffled, ft.pairs_shuffled, "conventional never combines");
    assert!(plain.ser_bytes > plain.shuffle_bytes, "local spills must be charged");
    assert_eq!(
        plain.ser_bytes, ft.ser_bytes,
        "recoverable conventional engine must charge node-local serialization \
         exactly like the ordinary conventional engine"
    );
}
