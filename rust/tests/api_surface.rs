//! Paper-API surface coverage: every container as MapReduce input/target,
//! every utility (`distribute`, `collect`, `load_file`, `topk`, `foreach`),
//! chained jobs, and the collectives kernel underneath.

use blaze::containers::{
    collect_hashmap, collect_vector, distribute, load_file, DistHashMap, DistRange, DistVector,
};
use blaze::coordinator::collectives;
use blaze::mapreduce::{mapreduce, mapreduce_range, Reducer};
use blaze::prelude::*;

#[test]
fn dist_hashmap_as_mapreduce_input() {
    // Paper §2.2: "When the input is a DistVector or a DistHashMap, the
    // mapper should be a function that accepts three parameters".
    let c = Cluster::local(3, 2);
    let mut words: DistHashMap<String, u64> = DistHashMap::new(&c);
    let red = Reducer::sum();
    for (w, n) in [("a", 3u64), ("bb", 3), ("ccc", 2), ("dddd", 2), ("e", 1)] {
        words.merge(w.to_string(), n, &red);
    }
    // Histogram of counts: MR over the hash map into a dense Vec target.
    let mut hist = vec![0u64; 5];
    mapreduce(
        &words,
        |_word: &String, count: &u64, emit| emit(*count as usize, 1u64),
        "sum",
        &mut hist,
    );
    assert_eq!(hist, vec![0, 1, 2, 2, 0]); // one word seen once, two twice, two thrice
}

#[test]
fn chained_mapreduce_jobs() {
    // Word count → filter rare words via foreach → second MR over the map.
    let c = Cluster::local(2, 2);
    let lines = distribute(
        &c,
        vec![
            "x x x y y z".to_string(),
            "x y w".to_string(),
        ],
    );
    let mut counts: DistHashMap<String, u64> = DistHashMap::new(&c);
    mapreduce(
        &lines,
        |_, l: &String, emit| {
            for w in l.split_whitespace() {
                emit(w.to_string(), 1u64);
            }
        },
        "sum",
        &mut counts,
    );
    // Second job: total mass of words with count >= 2.
    let mut mass = vec![0u64; 1];
    mapreduce(
        &counts,
        |_w: &String, n: &u64, emit| {
            if *n >= 2 {
                emit(0usize, *n);
            }
        },
        "sum",
        &mut mass,
    );
    assert_eq!(mass[0], 4 + 3); // x:4, y:3
}

#[test]
fn distribute_collect_utilities() {
    let c = Cluster::local(4, 1);
    let dv = distribute(&c, (0..57u64).collect::<Vec<u64>>());
    assert_eq!(collect_vector(&dv), (0..57).collect::<Vec<u64>>());
    let m = DistHashMap::from_hashmap(
        &c,
        [("k".to_string(), 9u64)].into_iter().collect(),
    );
    assert_eq!(collect_hashmap(&m).get("k"), Some(&9));
}

#[test]
fn load_file_splits_lines() {
    let c = Cluster::local(2, 1);
    let path = std::env::temp_dir().join("blaze_api_surface_test.txt");
    std::fs::write(&path, "alpha beta\ngamma\n\ndelta").unwrap();
    let lines = load_file(&c, &path).unwrap();
    assert_eq!(
        collect_vector(&lines),
        vec!["alpha beta", "gamma", "", "delta"]
    );
    std::fs::remove_file(&path).ok();
}

#[test]
fn load_file_missing_is_io_error() {
    let c = Cluster::local(1, 1);
    assert!(load_file(&c, "/nonexistent/blaze/file.txt").is_err());
}

#[test]
fn distrange_foreach_and_mapreduce_consistent() {
    let c = Cluster::local(3, 2);
    let r = DistRange::new(&c, 5, 105);
    let mut via_foreach = 0u64;
    r.foreach(|v| via_foreach += v);
    let mut via_mr = vec![0u64; 1];
    mapreduce_range(&r, |v, emit| emit(0usize, v), "sum", &mut via_mr);
    assert_eq!(via_foreach, via_mr[0]);
    assert_eq!(via_foreach, (5..105).sum::<u64>());
}

#[test]
fn prod_reducer_end_to_end() {
    let c = Cluster::local(2, 2);
    let dv = DistVector::from_vec(&c, vec![2u64, 3, 4]);
    let mut acc = vec![1u64; 1];
    mapreduce(&dv, |_, v: &u64, emit| emit(0usize, *v), "prod", &mut acc);
    assert_eq!(acc[0], 24);
}

#[test]
fn min_reducer_finds_global_min_across_nodes() {
    let c = Cluster::local(8, 1);
    let data: Vec<i64> = (0..800).map(|i| ((i * 37) % 997) - 500).collect();
    let expect = *data.iter().min().unwrap();
    let dv = DistVector::from_vec(&c, data);
    let mut out: DistHashMap<u64, i64> = DistHashMap::new(&c);
    mapreduce(&dv, |_, v: &i64, emit| emit(0u64, *v), Reducer::min(), &mut out);
    assert_eq!(out.get(&0), Some(expect));
}

#[test]
fn collectives_compose_with_mapreduce() {
    // Per-node partial sums via MR, then all_reduce to every node.
    let c = Cluster::local(4, 1);
    let partials: Vec<u64> = (0..4).map(|n| (n as u64 + 1) * 100).collect();
    let everywhere = collectives::all_reduce(&c, &partials, &Reducer::sum());
    assert_eq!(everywhere, vec![1000; 4]);
    // And a broadcast of a model-like payload.
    let model = vec![0.5f64; 64];
    let copies = collectives::broadcast(&c, 0, &model);
    assert!(copies.iter().all(|m| m == &model));
}

#[test]
fn non_power_of_two_nodes_smallkey_tree() {
    // The binomial tree reduce must be correct for 3, 5, 6, 7 nodes.
    for nodes in [3usize, 5, 6, 7] {
        let c = Cluster::local(nodes, 2);
        let r = DistRange::new(&c, 0, 10_000);
        let mut out = vec![0u64; 1];
        mapreduce_range(&r, |_, emit| emit(0usize, 1u64), "sum", &mut out);
        assert_eq!(out[0], 10_000, "nodes={nodes}");
    }
}

#[test]
fn target_merging_is_cumulative_across_containers() {
    // Vec target accumulates across jobs from *different* inputs.
    let c = Cluster::local(2, 1);
    let mut acc = vec![0u64; 1];
    let r1 = DistRange::new(&c, 0, 100);
    mapreduce_range(&r1, |_, emit| emit(0usize, 1u64), "sum", &mut acc);
    let dv = DistVector::from_vec(&c, vec![1u64; 50]);
    mapreduce(&dv, |_, _: &u64, emit| emit(0usize, 1u64), "sum", &mut acc);
    assert_eq!(acc[0], 150);
}
