//! Trace subsystem integration: exporter round-trips on empty and
//! single-event collectors, gating semantics, and end-to-end collection
//! plus file export on a live cluster. The cross-backend byte-identity
//! gate lives in `rust/tests/equivalence.rs`; the fault timelines in
//! `rust/tests/fault.rs`.

use blaze::containers::DistRange;
use blaze::coordinator::cluster::{Backend, Cluster, ClusterConfig};
use blaze::mapreduce::mapreduce_range_labeled;
use blaze::trace::{TraceBuf, TraceCollector, TraceEvent, TraceEventKind};

fn tmp(name: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("blaze-trace-test-{}-{name}", std::process::id()))
}

fn chrome_sibling(path: &std::path::Path) -> std::path::PathBuf {
    std::path::PathBuf::from(format!("{}.chrome.json", path.display()))
}

fn read_and_remove(path: &std::path::Path) -> String {
    let s = std::fs::read_to_string(path).expect("export file readable");
    let _ = std::fs::remove_file(path);
    s
}

/// Count π-style hits over a small range on `c`, labeled `trace.pi`.
fn run_small_job(c: &Cluster) -> u64 {
    let samples = DistRange::new(c, 0, 400);
    let mut count = vec![0u64; 1];
    mapreduce_range_labeled(
        "trace.pi",
        &samples,
        |i, emit| {
            if i % 3 == 0 {
                emit(0usize, 1u64);
            }
        },
        "sum",
        &mut count,
    );
    count[0]
}

// ---- Exporter round-trips ----------------------------------------------

#[test]
fn empty_collector_exports_empty_views() {
    let col = TraceCollector::new(true);
    assert_eq!(col.event_count(), 0);
    assert_eq!(col.canonical_jsonl(), "");
    let chrome = col.chrome_json();
    assert!(chrome.starts_with("{\"traceEvents\":["), "chrome view is a traceEvents object");
    assert!(chrome.trim_end().ends_with("]}"), "empty chrome view closes its array");

    let path = tmp("empty.jsonl");
    col.export(&path).expect("export of an empty collector succeeds");
    assert_eq!(read_and_remove(&path), "", "empty JSONL file");
    assert_eq!(read_and_remove(&chrome_sibling(&path)), chrome, "chrome file matches the view");
}

#[test]
fn single_event_canonical_line_is_exact() {
    let mut buf = TraceBuf::new(true);
    buf.push(TraceEvent::new(
        0,
        Some(1),
        "map",
        TraceEventKind::MapBlock { items: 3, emitted: 2, exec_node: 0, epoch: 1 },
    ));
    let mut col = TraceCollector::new(true);
    col.absorb_job("t.job", buf);
    assert_eq!(col.event_count(), 1);
    assert_eq!(
        col.canonical_jsonl(),
        "{\"job\":\"t.job\",\"ev\":\"MapBlock\",\"node\":0,\"worker\":1,\
         \"phase\":\"map\",\"phase_ix\":0,\"items\":3,\"emitted\":2,\
         \"exec_node\":0,\"epoch\":1}\n"
    );

    let path = tmp("single.jsonl");
    col.export(&path).expect("export succeeds");
    assert_eq!(read_and_remove(&path), col.canonical_jsonl(), "file round-trips the view");
    let chrome = read_and_remove(&chrome_sibling(&path));
    assert_eq!(chrome, col.chrome_json());
    assert!(chrome.contains("MapBlock"), "chrome view names the event");
}

// ---- Gating ------------------------------------------------------------

#[test]
fn disabled_buffers_and_collectors_record_nothing() {
    let ev = || {
        TraceEvent::new(0, None, "map", TraceEventKind::Checkpoint { commit: 1, bytes: 10 })
    };

    // Disabled buffer: pushes are dropped before they reach a collector.
    let mut buf = TraceBuf::new(false);
    buf.push(ev());
    assert!(buf.is_empty());
    let mut col = TraceCollector::new(true);
    col.absorb_job("t.job", buf);
    assert_eq!(col.event_count(), 0);
    assert!(col.jobs().is_empty());

    // Disabled collector: enabled buffers are absorbed into nothing.
    let mut buf = TraceBuf::new(true);
    buf.push(ev());
    assert_eq!(buf.len(), 1);
    let mut col = TraceCollector::new(false);
    col.absorb_job("t.job", buf);
    assert_eq!(col.event_count(), 0);
    assert!(col.jobs().is_empty());
}

#[test]
fn untraced_cluster_collects_nothing() {
    let c = Cluster::new(ClusterConfig::sized(2, 2).with_trace(false));
    assert!(run_small_job(&c) > 0);
    assert_eq!(c.trace().event_count(), 0);
    assert!(c.trace().jobs().is_empty());
}

// ---- End-to-end on a live cluster --------------------------------------

#[test]
fn cluster_trace_round_trips_through_export() {
    let c = Cluster::new(ClusterConfig::sized(2, 2).with_trace(true));
    assert!(run_small_job(&c) > 0);

    let canonical = c.trace().canonical_jsonl();
    assert!(!canonical.is_empty(), "traced run must record events");
    assert!(canonical.contains("\"job\":\"trace.pi\""), "events carry the job label");
    for line in canonical.lines() {
        assert!(
            line.starts_with('{') && line.ends_with('}'),
            "each JSONL line is one object: {line}"
        );
    }

    let path = tmp("cluster.jsonl");
    c.export_trace(&path).expect("cluster export succeeds");
    assert_eq!(read_and_remove(&path), canonical, "JSONL file matches the in-memory view");
    let chrome = read_and_remove(&chrome_sibling(&path));
    assert!(chrome.starts_with("{\"traceEvents\":["));
    assert!(chrome.contains("MapBlock"), "chrome view carries the map events");
}

#[test]
fn threaded_trace_exports_occupancy_counter_tracks() {
    // The threaded backend samples real scheduling state — pool queue
    // depth per stolen block and the transport's in-flight window — and
    // the Chrome view renders those as counter tracks ("ph":"C").
    let c = Cluster::new(
        ClusterConfig::sized(2, 2).with_trace(true).with_backend(Backend::Threaded(2)),
    );
    let hits = run_small_job(&c);
    assert!(hits > 0);

    let chrome = c.trace().chrome_json();
    assert!(chrome.contains("\"ph\":\"C\""), "threaded traced run emits counter events");
    assert!(chrome.contains("pool.queue_depth"), "pool queue-depth track present");
    assert!(chrome.contains("pool.busy_threads"), "pool busy-threads track present");
    assert!(
        chrome.contains("transport.in_flight_bytes"),
        "transport in-flight track present (multi-node run moves cross-node frames)"
    );

    // Occupancy is real-scheduling state: the canonical JSONL — the
    // byte-identity surface across backends — must never see it.
    let canonical = c.trace().canonical_jsonl();
    assert!(!canonical.contains("queue_depth"), "samples are chrome-only");
    assert!(!canonical.contains("in_flight_bytes"), "samples are chrome-only");

    // The simulated engines have no real pool or wire, so the same job
    // untraced-by-occupancy stays counter-free.
    let sim = Cluster::new(ClusterConfig::sized(2, 2).with_trace(true));
    assert_eq!(run_small_job(&sim), hits, "backends agree on the result");
    assert!(
        !sim.trace().chrome_json().contains("\"ph\":\"C\""),
        "simulated runs emit no counter tracks"
    );
}
