//! Reducers (paper §2.2): built-in `sum`/`prod`/`min`/`max` plus custom
//! reduce functions.
//!
//! A reducer folds a new value into an existing one in place:
//! `fn(&mut existing, &new)` — exactly the paper's custom-reducer signature
//! ("the first one is a reference to the existing value which needs to be
//! updated, and the second one is a constant reference to the new value").

/// Values the built-in reducers understand.
pub trait Numeric: Clone {
    /// `self += other`.
    fn add_assign(&mut self, other: &Self);
    /// `self *= other`.
    fn mul_assign(&mut self, other: &Self);
    /// `self = min(self, other)`.
    fn min_assign(&mut self, other: &Self);
    /// `self = max(self, other)`.
    fn max_assign(&mut self, other: &Self);
}

macro_rules! impl_numeric {
    ($($t:ty),*) => {$(
        impl Numeric for $t {
            #[inline]
            fn add_assign(&mut self, other: &Self) { *self += *other; }
            #[inline]
            fn mul_assign(&mut self, other: &Self) { *self *= *other; }
            #[inline]
            fn min_assign(&mut self, other: &Self) {
                if *other < *self { *self = *other; }
            }
            #[inline]
            fn max_assign(&mut self, other: &Self) {
                if *other > *self { *self = *other; }
            }
        }
    )*};
}

impl_numeric!(u8, u16, u32, u64, usize, i8, i16, i32, i64, isize, f32, f64);

/// Element-wise numeric vectors (GMM sufficient statistics are `Vec<f64>`).
impl<T: Numeric + Default> Numeric for Vec<T> {
    fn add_assign(&mut self, other: &Self) {
        self.resize_with(self.len().max(other.len()), T::default);
        for (a, b) in self.iter_mut().zip(other) {
            a.add_assign(b);
        }
    }
    fn mul_assign(&mut self, other: &Self) {
        self.resize_with(self.len().max(other.len()), T::default);
        for (a, b) in self.iter_mut().zip(other) {
            a.mul_assign(b);
        }
    }
    fn min_assign(&mut self, other: &Self) {
        for (a, b) in self.iter_mut().zip(other) {
            a.min_assign(b);
        }
    }
    fn max_assign(&mut self, other: &Self) {
        for (a, b) in self.iter_mut().zip(other) {
            a.max_assign(b);
        }
    }
}

enum ReduceFn<V> {
    Plain(fn(&mut V, &V)),
    // `Send + Sync` so a `&Reducer` can be shared across the threaded
    // backend's worker pool; built-ins are fn pointers and unaffected.
    Boxed(Box<dyn Fn(&mut V, &V) + Send + Sync>),
}

/// A reduce function handle. Built-ins are function pointers (no allocation,
/// no indirection beyond one call); custom closures are boxed once.
pub struct Reducer<V> {
    f: ReduceFn<V>,
    name: &'static str,
}

impl<V: Numeric> Reducer<V> {
    /// `existing += new` — covers "most use cases" per the paper.
    pub fn sum() -> Self {
        Self { f: ReduceFn::Plain(|a, b| a.add_assign(b)), name: "sum" }
    }

    /// `existing *= new`.
    pub fn prod() -> Self {
        Self { f: ReduceFn::Plain(|a, b| a.mul_assign(b)), name: "prod" }
    }

    /// Keep the smaller.
    pub fn min() -> Self {
        Self { f: ReduceFn::Plain(|a, b| a.min_assign(b)), name: "min" }
    }

    /// Keep the larger.
    pub fn max() -> Self {
        Self { f: ReduceFn::Plain(|a, b| a.max_assign(b)), name: "max" }
    }

    /// Reducer by name, mirroring the paper's string interface
    /// (`blaze::mapreduce(lines, mapper, "sum", words)`).
    ///
    /// # Panics
    /// On an unknown name — the paper's API contract.
    pub fn by_name(name: &str) -> Self {
        match name {
            "sum" => Self::sum(),
            "prod" => Self::prod(),
            "min" => Self::min(),
            "max" => Self::max(),
            other => panic!("unknown built-in reducer {other:?} (sum|prod|min|max)"),
        }
    }
}

impl<V> Reducer<V> {
    /// Custom reduce function `f(&mut existing, &new)`. `Send + Sync`
    /// because reducers run concurrently on the threaded backend's worker
    /// pool; pure reduce closures (the paper's contract) satisfy this
    /// automatically.
    pub fn custom(f: impl Fn(&mut V, &V) + Send + Sync + 'static) -> Self {
        Self { f: ReduceFn::Boxed(Box::new(f)), name: "custom" }
    }

    /// Custom reducer from a plain function pointer (no allocation).
    pub fn custom_fn(f: fn(&mut V, &V)) -> Self {
        Self { f: ReduceFn::Plain(f), name: "custom" }
    }

    /// Fold `new` into `existing`.
    #[inline]
    pub fn apply(&self, existing: &mut V, new: &V) {
        match &self.f {
            ReduceFn::Plain(f) => f(existing, new),
            ReduceFn::Boxed(f) => f(existing, new),
        }
    }

    /// Reducer name for reporting.
    pub fn name(&self) -> &'static str {
        self.name
    }
}

impl<V> std::fmt::Debug for Reducer<V> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Reducer({})", self.name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtins() {
        let mut v = 10u64;
        Reducer::sum().apply(&mut v, &5);
        assert_eq!(v, 15);
        Reducer::prod().apply(&mut v, &2);
        assert_eq!(v, 30);
        Reducer::min().apply(&mut v, &7);
        assert_eq!(v, 7);
        Reducer::max().apply(&mut v, &100);
        assert_eq!(v, 100);
    }

    #[test]
    fn by_name_matches_paper_interface() {
        let mut v = 1.5f64;
        Reducer::by_name("sum").apply(&mut v, &2.5);
        assert_eq!(v, 4.0);
        assert_eq!(Reducer::<f64>::by_name("max").name(), "max");
    }

    #[test]
    #[should_panic(expected = "unknown built-in reducer")]
    fn unknown_name_panics() {
        let _ = Reducer::<u64>::by_name("avg");
    }

    #[test]
    fn custom_closure() {
        // Keep the lexicographically-smaller string.
        let red = Reducer::custom(|a: &mut String, b: &String| {
            if b < a {
                a.clone_from(b);
            }
        });
        let mut v = "zebra".to_string();
        red.apply(&mut v, &"apple".to_string());
        assert_eq!(v, "apple");
    }

    #[test]
    fn vec_elementwise_sum_resizes() {
        let mut a = vec![1.0f64, 2.0];
        Reducer::sum().apply(&mut a, &vec![10.0, 20.0, 30.0]);
        assert_eq!(a, vec![11.0, 22.0, 30.0]);
    }
}
