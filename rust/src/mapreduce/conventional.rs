//! Conventional MapReduce — the Spark-analog baseline.
//!
//! What the paper's Figure 3 (left) shows: the map phase **materializes
//! every emitted pair** (no map-side combining), the shuffle serializes the
//! raw pair stream with the tagged protobuf-style codec, a barrier separates
//! transfer from reduce, and the destination groups-then-reduces. On top of
//! the mechanical costs, a calibrated per-record executor overhead and
//! per-job scheduling latency model the JVM/Spark task machinery the paper's
//! baseline carries (constants in [`ClusterConfig`], rationale in DESIGN.md
//! §Substitutions).
//!
//! This engine exists so every workload can run identically under both
//! engines; the Blaze-vs-conventional gap in the Fig 4–9 benches isolates
//! exactly the paper's three optimizations.

use std::hash::Hash;
use std::time::Instant;

use crate::coordinator::metrics::RunStats;
use crate::coordinator::shuffle::{self, ShufflePayloads};
use crate::net::vtime::VirtualTime;
use crate::ser::fastser::FastSer;
use crate::ser::tagged::{decode_pairs_tagged, encode_pairs_tagged, TaggedSer};
use crate::trace::histogram::Histograms;
use crate::trace::{Counters, TraceBuf, TraceEvent, TraceEventKind};
use crate::util::hash::FxHashMap;

use super::reducers::Reducer;
use super::{BlockCursor, DistInput, Emit, ReduceTarget, RunRecorder};

/// Modeled heap bytes per materialized record on top of its encoded
/// payload: boxed key + boxed value + tuple + pointer (JVM-analog).
pub const RECORD_OVERHEAD: u64 = 64;

/// Run one MapReduce with the conventional engine.
///
/// Requires `TaggedSer` in addition to the engine-common bounds — the
/// baseline shuffles protobuf-style messages.
pub fn run<I, F, K2, V2, T>(label: &str, input: &I, mapper: &F, red: &Reducer<V2>, target: &mut T)
where
    I: DistInput,
    F: Fn(&I::K, &I::V, Emit<'_, K2, V2>),
    K2: Hash + Eq + Clone + FastSer + TaggedSer,
    V2: Clone + FastSer + TaggedSer,
    T: ReduceTarget<K2, V2>,
{
    let rec = RunRecorder::new(label);
    let cluster = input.cluster().clone();
    let cfg = cluster.config().clone();
    let (nodes, workers) = (cfg.nodes, cfg.workers_per_node);

    let mut trace = TraceBuf::new(cfg.trace);
    let mut counters = Counters::new(nodes);
    let mut hist = Histograms::new(nodes);
    let mut vt = VirtualTime::new();
    // Spark-analog job launch latency (driver → executors scheduling).
    vt.fixed_phase("job-launch", cfg.conventional_job_latency_sec);

    // ---- Map: materialize every pair, partitioned by destination --------
    let mut per_node_map_secs = vec![0.0f64; nodes];
    let mut node_partitions: Vec<Vec<Vec<(K2, V2)>>> = Vec::with_capacity(nodes);
    let mut pairs_emitted = 0u64;
    let mut materialized_bytes = 0u64;

    for node in 0..nodes {
        let t0 = Instant::now();
        let mut partitions: Vec<Vec<(K2, V2)>> = (0..nodes).map(|_| Vec::new()).collect();
        let mut emitted = 0u64;
        let mut bytes = 0u64;
        // Single pass over the node's partition, one cursor block per worker.
        let mut cur = input.block_cursor(node, workers);
        for w in 0..workers {
            crate::util::random::set_stream(cfg.seed, (node * workers + w) as u64);
            let emitted_before = emitted;
            let mut w_items = 0u64;
            let advanced = cur.next_block(|k, v| {
                w_items += 1;
                let mut emit = |k2: K2, v2: V2| {
                    emitted += 1;
                    bytes += RECORD_OVERHEAD + k2.encoded_len() as u64 + v2.encoded_len() as u64;
                    let dst = target.shard_of(&k2, nodes);
                    partitions[dst].push((k2, v2));
                };
                mapper(k, v, &mut emit);
            });
            debug_assert!(advanced, "cursor yields one block per worker");
            trace.push(TraceEvent::new(
                node,
                Some(w),
                "map-materialize",
                TraceEventKind::MapBlock {
                    items: w_items,
                    emitted: emitted - emitted_before,
                    exec_node: node,
                    epoch: 1,
                },
            ));
            counters.add_node(node, "map.items", w_items);
            hist.record_node(node, "map.block_items", w_items);
        }
        counters.add_node(node, "map.emitted", emitted);
        let measured = t0.elapsed().as_secs_f64();
        // Calibrated per-record executor overhead (JVM analog).
        per_node_map_secs[node] = measured + emitted as f64 * cfg.conventional_overhead_sec;
        pairs_emitted += emitted;
        materialized_bytes += bytes;
        node_partitions.push(partitions);
    }
    vt.compute_phase("map-materialize", &per_node_map_secs, workers);

    // ---- Serialize everything with the tagged codec ---------------------
    let mut payloads: ShufflePayloads =
        (0..nodes).map(|_| (0..nodes).map(|_| Vec::new()).collect()).collect();
    let mut per_node_ser_secs = vec![0.0f64; nodes];
    let mut serialized_bytes = 0u64;
    for (node, partitions) in node_partitions.into_iter().enumerate() {
        let t0 = Instant::now();
        for (dst, part) in partitions.into_iter().enumerate() {
            if part.is_empty() {
                continue;
            }
            // Even node-local partitions serialize: conventional shuffle
            // writes every block (Spark spills local blocks too).
            let buf = encode_pairs_tagged(&part);
            serialized_bytes += buf.len() as u64;
            counters.add_node(node, "ser.bytes", buf.len() as u64);
            trace.push(TraceEvent::new(
                node,
                None,
                "serialize",
                TraceEventKind::Shuffle {
                    dst,
                    bytes: buf.len() as u64,
                    pairs: part.len() as u64,
                },
            ));
            if dst != node {
                // Cross-node payloads move as bounded frames; local ones
                // never hit the wire (same framing as the eager engine).
                super::eager::record_frame_chunks(&mut hist, node, buf.len());
            }
            payloads[node][dst] = buf;
        }
        per_node_ser_secs[node] = t0.elapsed().as_secs_f64();
    }
    let ser_cpu = per_node_ser_secs
        .iter()
        .map(|s| VirtualTime::scaled_compute(*s, workers))
        .fold(0.0f64, f64::max);
    vt.fixed_phase("serialize", ser_cpu);

    // ---- Barrier shuffle (no overlap, no backpressure window) -----------
    // Local payloads are delivered without crossing the network, but unlike
    // the eager engine they still pay serialization above.
    let sres = shuffle::execute(payloads, u64::MAX);

    // ---- Group then reduce at destinations ------------------------------
    let mut per_node_reduce_secs = vec![0.0f64; nodes];
    let mut grouped_peak = 0u64;
    for (dst, received) in sres.delivered.into_iter().enumerate() {
        if received.is_empty() {
            continue;
        }
        let t0 = Instant::now();
        let mut by_src: FxHashMap<usize, Vec<u8>> = FxHashMap::default();
        for (src, chunk) in received {
            by_src.entry(src).or_default().extend_from_slice(&chunk);
        }
        let mut grouped: FxHashMap<K2, V2> = FxHashMap::default();
        let mut grouped_bytes = 0u64;
        for (src, buf) in by_src {
            let pairs =
                decode_pairs_tagged::<K2, V2>(&buf).expect("conventional payload must decode");
            trace.push(TraceEvent::new(
                dst,
                None,
                "shuffle-barrier+reduce",
                TraceEventKind::Reduce { from: src, pairs: pairs.len() as u64 },
            ));
            for (k, v) in pairs {
                match grouped.entry(k) {
                    std::collections::hash_map::Entry::Occupied(mut e) => {
                        red.apply(e.get_mut(), &v);
                    }
                    std::collections::hash_map::Entry::Vacant(e) => {
                        grouped_bytes += RECORD_OVERHEAD
                            + e.key().encoded_len() as u64
                            + v.encoded_len() as u64;
                        e.insert(v);
                    }
                }
            }
        }
        grouped_peak += grouped_bytes;
        target.absorb(dst, grouped.into_iter().collect(), red);
        per_node_reduce_secs[dst] = t0.elapsed().as_secs_f64();
    }
    let reduce_cpu = per_node_reduce_secs
        .iter()
        .map(|s| VirtualTime::scaled_compute(*s, workers))
        .fold(0.0f64, f64::max);
    let shuffle_bytes = sres.flows.cross_node_bytes();
    vt.shuffle_barrier("shuffle-barrier+reduce", &sres.flows, &cfg.network, reduce_cpu);

    // ---- Record ----------------------------------------------------------
    let compute_sec = vt.compute_sec();
    let makespan = vt.makespan();
    trace.stamp_phases(&vt);
    cluster.trace().absorb_job(&rec.label, trace);
    let (run_counters, node_counters) = counters.finish();
    // Measure once: host_wall_sec must bound the "total" phase entry.
    let host_wall = rec.started.elapsed();
    cluster.metrics().record_run(RunStats {
        label: rec.label,
        engine: "conventional".into(),
        // The conventional baseline models Spark; it always runs
        // simulated regardless of the configured backend.
        backend: "simulated".into(),
        nodes,
        workers_per_node: workers,
        makespan_sec: makespan,
        compute_sec,
        shuffle_sec: makespan - compute_sec,
        shuffle_bytes,
        // Conventional spills every block, node-local ones included.
        ser_bytes: serialized_bytes,
        pairs_emitted,
        pairs_shuffled: pairs_emitted, // no map-side combine
        // Everything is resident at once at the barrier: raw materialized
        // pairs + all serialized blocks + destination grouped map.
        peak_intermediate_bytes: materialized_bytes + serialized_bytes + grouped_peak,
        host_wall_sec: host_wall.as_secs_f64(),
        // One whole-job entry: the baseline's phases are dominated by
        // modeled (not executed) costs, so a per-phase wall split would
        // suggest precision the numbers don't have.
        phase_wall_ns: vec![("total".into(), host_wall.as_nanos() as u64)],
        counters: run_counters,
        node_counters,
        histograms: hist.finish(),
        ..Default::default()
    });
}
