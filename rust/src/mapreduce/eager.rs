//! The eager-reduction engine (paper §2.3.1) — Blaze's general path.
//!
//! Per node: each worker reduces emitted pairs into a *bounded* thread-local
//! cache the moment they are emitted; a full cache flushes into the
//! machine-local map (popular keys effectively never leave their worker
//! cache). The shuffle then moves only the locally-reduced data, serialized
//! with the tag-less fast codec, and destination-side reduce runs
//! overlapped with the transfer (async reduce). Compare
//! [`super::conventional`], which materializes every raw pair.

use std::collections::hash_map::Entry;
use std::hash::Hash;
use std::time::Instant;

use crate::coordinator::cluster::Cluster;
use crate::coordinator::metrics::RunStats;
use crate::coordinator::shuffle::{self, ShufflePayloads, Transport};
use crate::exec::transport::{FrameFault, TransportTotals};
use crate::net::vtime::VirtualTime;
use crate::ser::fastser::{decode_pairs, encode_pairs_into, FastSer};
use crate::trace::histogram::Histograms;
use crate::trace::{Counters, TraceBuf, TraceEvent, TraceEventKind};
use crate::util::alloc::Scratch;
use crate::util::hash::FxHashMap;

use super::reducers::Reducer;
use super::{BlockCursor, DistInput, Emit, ReduceTarget, RunRecorder};

/// Modeled heap overhead per hash-map entry (bucket slot, control bytes,
/// alignment) added on top of encoded payload bytes in the memory
/// accounting.
pub const HASH_ENTRY_OVERHEAD: u64 = 32;

/// Run one MapReduce with the eager engine.
pub fn run<I, F, K2, V2, T>(label: &str, input: &I, mapper: &F, red: &Reducer<V2>, target: &mut T)
where
    I: DistInput,
    F: Fn(&I::K, &I::V, Emit<'_, K2, V2>),
    K2: Hash + Eq + Clone + FastSer,
    V2: Clone + FastSer,
    T: ReduceTarget<K2, V2>,
{
    let rec = RunRecorder::new(label);
    let cluster = input.cluster().clone();
    let cfg = cluster.config().clone();
    let (nodes, workers) = (cfg.nodes, cfg.workers_per_node);
    let cache_cap = cfg.thread_cache_entries.max(1);

    let mut trace = TraceBuf::new(cfg.trace);
    let mut counters = Counters::new(nodes);
    let mut hist = Histograms::new(nodes);
    let mut vt = VirtualTime::new();
    let t_map = Instant::now();
    let mut per_node_map_secs = vec![0.0f64; nodes];
    let mut node_maps: Vec<FxHashMap<K2, V2>> = Vec::with_capacity(nodes);
    let mut pairs_emitted = 0u64;
    let mut map_peak_bytes = 0u64;

    // ---- Map + eager local reduce (measured per node) ------------------
    for node in 0..nodes {
        let t0 = Instant::now();
        // NOTE(perf): pre-sizing these caches to `cache_cap` was measured
        // 2.1x *slower* on the Fig-4 corpus (16 x 64Ki-slot map zeroing per
        // run dwarfs the rehash churn) — see EXPERIMENTS.md §Perf; grow
        // organically instead.
        let mut caches: Vec<FxHashMap<K2, V2>> =
            (0..workers).map(|_| FxHashMap::default()).collect();
        let mut local: FxHashMap<K2, V2> = FxHashMap::default();
        // Byte accounting: encoded payload + per-entry overhead, tracked
        // incrementally so the flush high-water mark is visible.
        let mut worker_bytes = vec![0u64; workers];
        let mut total_cache_bytes = 0u64;
        let mut local_bytes = 0u64;
        let mut node_peak = 0u64;
        let mut emitted = 0u64;

        // Single pass over the node's partition: one cursor, one block per
        // worker, in block order.
        //
        // LOCKSTEP CONTRACT: the cache/flush policy in the emit closure
        // below (entry-apply vs vacant-insert, byte formula, whole-cache
        // drain once `len >= cache_cap` checked after *every* emit) is
        // replicated by `crate::exec::cache::EagerCache` for the threaded
        // backend; threaded-vs-simulated byte-identity (equivalence/exec
        // test suites) depends on the two staying identical. Change them
        // together — or better, port this loop onto `EagerCache` (the
        // accounting of `node_peak` across concurrently-live worker
        // caches is what has kept that port from being mechanical).
        let mut cur = input.block_cursor(node, workers);
        for (w, cache) in caches.iter_mut().enumerate() {
            // Publish the worker's random stream (paper's `blaze::random`
            // is worker-local) before its block runs.
            crate::util::random::set_stream(cfg.seed, (node * workers + w) as u64);
            let wb = &mut worker_bytes[w];
            let emitted_before = emitted;
            let mut w_items = 0u64;
            let mut w_flushes = 0u64;
            let mut w_flush_entries = 0u64;
            let trace_ref = &mut trace;
            let hist_ref = &mut hist;
            let advanced = cur.next_block(|k, v| {
                w_items += 1;
                let mut emit = |k2: K2, v2: V2| {
                    emitted += 1;
                    match cache.entry(k2) {
                        Entry::Occupied(mut e) => red.apply(e.get_mut(), &v2),
                        Entry::Vacant(e) => {
                            let sz = HASH_ENTRY_OVERHEAD
                                + e.key().encoded_len() as u64
                                + v2.encoded_len() as u64;
                            *wb += sz;
                            total_cache_bytes += sz;
                            e.insert(v2);
                        }
                    }
                    if cache.len() >= cache_cap {
                        // Overflow: flush the worker cache into the machine-local
                        // map (popular keys re-enter the cache immediately after).
                        w_flushes += 1;
                        w_flush_entries += cache.len() as u64;
                        hist_ref.record_node(node, "cache.flush_entries", cache.len() as u64);
                        trace_ref.push(TraceEvent::new(
                            node,
                            Some(w),
                            "map+local-reduce",
                            TraceEventKind::CacheFlush {
                                entries: cache.len() as u64,
                                bytes: *wb,
                            },
                        ));
                        node_peak = node_peak.max(total_cache_bytes + local_bytes);
                        for (fk, fv) in cache.drain() {
                            match local.entry(fk) {
                                Entry::Occupied(mut e) => red.apply(e.get_mut(), &fv),
                                Entry::Vacant(e) => {
                                    local_bytes += HASH_ENTRY_OVERHEAD
                                        + e.key().encoded_len() as u64
                                        + fv.encoded_len() as u64;
                                    e.insert(fv);
                                }
                            }
                        }
                        total_cache_bytes -= *wb;
                        *wb = 0;
                    }
                };
                mapper(k, v, &mut emit);
            });
            debug_assert!(advanced, "cursor yields one block per worker");
            trace.push(TraceEvent::new(
                node,
                Some(w),
                "map+local-reduce",
                TraceEventKind::MapBlock {
                    items: w_items,
                    emitted: emitted - emitted_before,
                    exec_node: node,
                    epoch: 1,
                },
            ));
            counters.add_node(node, "map.items", w_items);
            counters.add_node(node, "cache.flushes", w_flushes);
            counters.add_node(node, "cache.flush_entries", w_flush_entries);
            hist.record_node(node, "map.block_items", w_items);
        }

        // Merge worker caches into the machine-local map.
        node_peak = node_peak.max(total_cache_bytes + local_bytes);
        for cache in caches {
            for (k, v) in cache {
                match local.entry(k) {
                    Entry::Occupied(mut e) => red.apply(e.get_mut(), &v),
                    Entry::Vacant(e) => {
                        local_bytes += HASH_ENTRY_OVERHEAD
                            + e.key().encoded_len() as u64
                            + v.encoded_len() as u64;
                        e.insert(v);
                    }
                }
            }
        }
        node_peak = node_peak.max(local_bytes);
        counters.add_node(node, "map.emitted", emitted);
        counters.max_node(node, "cache.peak_bytes", node_peak);

        per_node_map_secs[node] = t0.elapsed().as_secs_f64();
        pairs_emitted += emitted;
        map_peak_bytes += node_peak;
        node_maps.push(local);
    }
    vt.compute_phase("map+local-reduce", &per_node_map_secs, workers);
    let map_wall_ns = t_map.elapsed().as_nanos() as u64;

    // ---- Partition, serialize, shuffle, absorb (shared pipeline) --------
    let out = shuffle_and_absorb(
        &cluster,
        node_maps,
        red,
        target,
        &mut vt,
        &mut trace,
        &mut hist,
        Transport::FlowModel,
    );

    // ---- Record ----------------------------------------------------------
    let compute_sec = vt.compute_sec();
    let makespan = vt.makespan();
    trace.stamp_phases(&vt);
    cluster.trace().absorb_job(&rec.label, trace);
    let (run_counters, node_counters) = counters.finish();
    cluster.metrics().record_run(RunStats {
        label: rec.label,
        engine: "blaze".into(),
        backend: "simulated".into(),
        nodes,
        workers_per_node: workers,
        makespan_sec: makespan,
        compute_sec,
        shuffle_sec: makespan - compute_sec,
        shuffle_bytes: out.shuffle_bytes,
        // Eager semantics: only cross-node partials ever serialize.
        ser_bytes: out.shuffle_bytes,
        pairs_emitted,
        pairs_shuffled: out.pairs_shuffled,
        peak_intermediate_bytes: map_peak_bytes + out.peak_bytes,
        host_wall_sec: rec.started.elapsed().as_secs_f64(),
        phase_wall_ns: vec![
            ("map+local-reduce".into(), map_wall_ns),
            ("shuffle+absorb".into(), out.wall_ns),
        ],
        counters: run_counters,
        node_counters,
        histograms: hist.finish(),
        ..Default::default()
    });
}

/// Outcome of [`shuffle_and_absorb`] — the stats the caller folds into its
/// [`RunStats`].
pub(crate) struct ShuffleOutcome {
    /// Pairs leaving the node-local maps (after eager combine).
    pub pairs_shuffled: u64,
    /// Cross-node bytes actually serialized and moved.
    pub shuffle_bytes: u64,
    /// Peak in-flight shuffle bytes + largest absorb buffer.
    pub peak_bytes: u64,
    /// Host wall nanoseconds of the whole pipeline.
    pub wall_ns: u64,
    /// Real-transport measurements (`Transport::Channels` only).
    pub transport: Option<TransportTotals>,
}

/// Everything after the per-node machine-local maps exist: partition by
/// the target's sharding, serialize cross-node partials with the fast
/// codec, move them (simulated network or real bounded channels, per
/// `transport`), and absorb with the reduce overlapped. Shared verbatim
/// by the simulated eager engine and the threaded backend
/// ([`crate::exec`]), which is what keeps the two backends' downstream
/// behavior — and therefore their results — identical by construction:
/// both transports hand back element-identical `delivered` buffers.
pub(crate) fn shuffle_and_absorb<K2, V2, T>(
    cluster: &Cluster,
    node_maps: Vec<FxHashMap<K2, V2>>,
    red: &Reducer<V2>,
    target: &mut T,
    vt: &mut VirtualTime,
    trace: &mut TraceBuf,
    hist: &mut Histograms,
    transport: Transport,
) -> ShuffleOutcome
where
    K2: Hash + Eq + Clone + FastSer,
    V2: Clone + FastSer,
    T: ReduceTarget<K2, V2>,
{
    let t_start = Instant::now();
    let cfg = cluster.config();
    let (nodes, workers) = (cfg.nodes, cfg.workers_per_node);
    // Shuffle scratch buffers honour the allocator toggle ("Blaze TCM").
    let scratch = Scratch::new(cfg.alloc, cluster.pool());

    // ---- Partition, serialize (fast codec), local absorb ---------------
    let mut payloads: ShufflePayloads =
        (0..nodes).map(|_| (0..nodes).map(|_| Vec::new()).collect()).collect();
    let mut per_node_ser_secs = vec![0.0f64; nodes];
    let mut pairs_shuffled = 0u64;

    for (node, local) in node_maps.into_iter().enumerate() {
        let t0 = Instant::now();
        let mut partitions: Vec<Vec<(K2, V2)>> = (0..nodes).map(|_| Vec::new()).collect();
        for (k, v) in local {
            let dst = target.shard_of(&k, nodes);
            partitions[dst].push((k, v));
        }
        for (dst, part) in partitions.into_iter().enumerate() {
            if part.is_empty() {
                continue;
            }
            pairs_shuffled += part.len() as u64;
            if dst == node {
                // Machine-local results never serialize: reduce straight in.
                trace.push(TraceEvent::new(
                    dst,
                    None,
                    "shuffle+async-reduce",
                    TraceEventKind::Reduce { from: node, pairs: part.len() as u64 },
                ));
                target.absorb(dst, part, red);
            } else {
                let n_pairs = part.len() as u64;
                payloads[node][dst] = encode_pairs_into(&part, scratch.get(part.len() * 4));
                // Frame-size histogram: one record per transport chunk,
                // derived from the payload length alone — identical for
                // the flow model and the channel transport.
                record_frame_chunks(hist, node, payloads[node][dst].len());
                trace.push(TraceEvent::new(
                    node,
                    None,
                    "shuffle+async-reduce",
                    TraceEventKind::Shuffle {
                        dst,
                        bytes: payloads[node][dst].len() as u64,
                        pairs: n_pairs,
                    },
                ));
            }
        }
        per_node_ser_secs[node] = t0.elapsed().as_secs_f64();
    }

    // ---- Shuffle with asynchronous reduce (overlapped) ------------------
    let window = cfg.transport_window_bytes;
    let (sres, transport_totals) = match transport {
        Transport::FlowModel => (shuffle::execute(payloads, window), None),
        Transport::Channels => {
            // Under a lossy plan, stage an untouched copy first: when the
            // retry/timeout budget is exhausted the transport returns a
            // structured error (never a hang) and the shuffle degrades
            // gracefully onto the flow model, so results stay identical.
            let net_fault = cfg.net_fault;
            let lossy_fallback = net_fault.is_some().then(|| payloads.clone());
            let attempt = match net_fault {
                None => Ok(crate::exec::transport::execute_pooled(payloads, window, &scratch)),
                Some(plan) => {
                    crate::exec::transport::execute_lossy(payloads, window, &plan, &scratch)
                }
            };
            match attempt {
                Ok(tres) => {
                    // Occupancy gauge + per-frame wait: Chrome-only /
                    // wall-only observability from the real transport.
                    for &(src, in_flight) in &tres.in_flight_samples {
                        trace.push_sample(
                            src,
                            "shuffle+async-reduce",
                            0,
                            "transport.in_flight_bytes",
                            in_flight,
                        );
                    }
                    hist.merge_global("wall.transport.frame_wait_ns", &tres.frame_wait);
                    // Chrome-only transport events, in deterministic
                    // src-major pair order (they never reach the
                    // canonical export).
                    for ps in &tres.pair_stats {
                        trace.push(TraceEvent::new(
                            ps.src,
                            None,
                            "shuffle+async-reduce",
                            TraceEventKind::FrameSent {
                                dst: ps.dst,
                                frames: ps.frames,
                                bytes: ps.bytes,
                            },
                        ));
                        if ps.stalls > 0 {
                            trace.push(TraceEvent::new(
                                ps.src,
                                None,
                                "shuffle+async-reduce",
                                TraceEventKind::TransportStall { dst: ps.dst, stalls: ps.stalls },
                            ));
                        }
                    }
                    // Injected frame fates, in the mirror's deterministic
                    // resolution order (Chrome-only, like FrameSent).
                    for fault in &tres.faults {
                        match *fault {
                            FrameFault::Dropped { src, dst, seq, attempt, corrupt } => {
                                trace.push(TraceEvent::new(
                                    src,
                                    None,
                                    "shuffle+async-reduce",
                                    TraceEventKind::FrameDropped { dst, seq, attempt, corrupt },
                                ));
                            }
                            FrameFault::Retried { src, dst, seq, attempt, backoff_ns } => {
                                trace.push(TraceEvent::new(
                                    src,
                                    None,
                                    "shuffle+async-reduce",
                                    TraceEventKind::FrameRetried { dst, seq, attempt, backoff_ns },
                                ));
                            }
                        }
                    }
                    // The deterministic backoff mirror extends the
                    // virtual clock; no trace event carries this label,
                    // so the canonical export is untouched.
                    if tres.backoff_ns > 0 {
                        vt.fixed_phase("transport-backoff", tres.backoff_ns as f64 * 1e-9);
                    }
                    let totals = tres.totals();
                    let sres = shuffle::ShuffleResult {
                        flows: tres.flows,
                        delivered: tres.delivered,
                        peak_in_flight_bytes: tres.peak_in_flight_bytes,
                        stalls: tres.stalls,
                    };
                    (sres, Some(totals))
                }
                Err(err) => {
                    trace.push(TraceEvent::new(
                        err.src,
                        None,
                        "shuffle+async-reduce",
                        TraceEventKind::NodeTimedOut { dst: err.node, attempts: err.attempts },
                    ));
                    let totals = TransportTotals {
                        timeouts: 1,
                        backoff_ns: err.backoff_ns,
                        faulted: true,
                        ..Default::default()
                    };
                    let fallback =
                        lossy_fallback.expect("fallback staged for every lossy transport run");
                    (shuffle::execute(fallback, window), Some(totals))
                }
            }
        }
    };
    let mut per_node_reduce_secs = vec![0.0f64; nodes];
    let mut absorb_buffer_peak = 0u64;
    for (dst, received) in sres.delivered.into_iter().enumerate() {
        if received.is_empty() {
            continue;
        }
        let t0 = Instant::now();
        // Chunks from one source arrive in order; concatenate per source,
        // then decode each source's batch.
        let mut by_src: FxHashMap<usize, Vec<u8>> = FxHashMap::default();
        for (src, chunk) in received {
            by_src.entry(src).or_default().extend_from_slice(&chunk);
            scratch.put(chunk); // recycle under the pool allocator
        }
        for (src, buf) in by_src {
            absorb_buffer_peak = absorb_buffer_peak.max(buf.len() as u64);
            let pairs =
                decode_pairs::<K2, V2>(&buf).expect("eager shuffle payload must decode");
            scratch.put(buf); // recycle under the pool allocator
            trace.push(TraceEvent::new(
                dst,
                None,
                "shuffle+async-reduce",
                TraceEventKind::Reduce { from: src, pairs: pairs.len() as u64 },
            ));
            target.absorb(dst, pairs, red);
        }
        per_node_reduce_secs[dst] = t0.elapsed().as_secs_f64();
    }

    // CPU work overlapped with the transfer: sender-side serialization and
    // receiver-side async reduce, both parallel across workers.
    let cpu_overlap = per_node_ser_secs
        .iter()
        .zip(&per_node_reduce_secs)
        .map(|(s, r)| VirtualTime::scaled_compute(s + r, workers))
        .fold(0.0f64, f64::max);
    let shuffle_bytes = sres.flows.cross_node_bytes();
    vt.shuffle_overlapped("shuffle+async-reduce", &sres.flows, &cfg.network, cpu_overlap);

    ShuffleOutcome {
        pairs_shuffled,
        shuffle_bytes,
        peak_bytes: sres.peak_in_flight_bytes + absorb_buffer_peak,
        wall_ns: t_start.elapsed().as_nanos() as u64,
        transport: transport_totals,
    }
}

/// Record one `shuffle.frame_bytes` histogram entry per transport chunk
/// of a `payload_len`-byte cross-node payload — the same 1 MiB chunking
/// both transports apply, computed from the length alone so the series
/// is byte-identical across backends.
pub(crate) fn record_frame_chunks(hist: &mut Histograms, src: usize, payload_len: usize) {
    let mut rem = payload_len;
    while rem > 0 {
        let chunk = rem.min(shuffle::CHUNK_BYTES);
        hist.record_node(src, "shuffle.frame_bytes", chunk as u64);
        rem -= chunk;
    }
}
