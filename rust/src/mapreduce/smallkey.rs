//! Small fixed key range optimization (paper §2.3.3).
//!
//! When the target is a `Vec<V>` the key range is small and known up front.
//! Each worker gets a dense per-key cache (`Vec<Option<V>>`) created *at the
//! start* and set as the reduce target during the local map/reduce phase —
//! no hashing, no entry lookups. Afterwards a parallel **binomial tree
//! reduce** combines partials: first worker caches within a node, then
//! across machines (`log2 N` rounds), landing at the driver. The execution
//! plan is identical to a hand-optimized MPI+OpenMP parallel for-loop with
//! thread-local intermediates — which is why Table 1 shows parity.

use std::hash::Hash;
use std::time::Instant;

use crate::coordinator::cluster::Cluster;
use crate::coordinator::metrics::RunStats;
use crate::coordinator::shuffle::{ShufflePayloads, Transport};
use crate::exec::transport::{FrameFault, TransportTotals};
use crate::net::sim::FlowMatrix;
use crate::net::vtime::VirtualTime;
use crate::ser::fastser::{decode_pairs, encode_pairs_into, FastSer};
use crate::util::alloc::Scratch;
use crate::trace::histogram::Histograms;
use crate::trace::{Counters, TraceBuf, TraceEvent, TraceEventKind};

use super::reducers::Reducer;
use super::{BlockCursor, DenseKey, DistInput, Emit, ReduceTarget, RunRecorder};

/// Run one MapReduce through the dense small-key-range path.
///
/// # Panics
/// If a mapper emits a key without a dense index inside the target's fixed
/// range — the contract of a `Vec<V>` target (paper §2.2: the target defines
/// the key range).
pub fn run<I, F, K2, V2, T>(label: &str, input: &I, mapper: &F, red: &Reducer<V2>, target: &mut T)
where
    I: DistInput,
    F: Fn(&I::K, &I::V, Emit<'_, K2, V2>),
    K2: Hash + Eq + Clone + FastSer + DenseKey,
    V2: Clone + FastSer,
    T: ReduceTarget<K2, V2>,
{
    let rec = RunRecorder::new(label);
    let cluster = input.cluster().clone();
    let cfg = cluster.config().clone();
    let (nodes, workers) = (cfg.nodes, cfg.workers_per_node);
    let range = target.dense_len().expect("smallkey path requires a dense target");

    let mut trace = TraceBuf::new(cfg.trace);
    let mut counters = Counters::new(nodes);
    let mut hist = Histograms::new(nodes);
    let mut vt = VirtualTime::new();
    let t_map = Instant::now();
    let mut per_node_secs = vec![0.0f64; nodes];
    let mut node_partials: Vec<Vec<Option<V2>>> = Vec::with_capacity(nodes);
    let mut pairs_emitted = 0u64;

    // ---- Map with per-worker dense caches + in-node tree reduce ---------
    for node in 0..nodes {
        let t0 = Instant::now();
        let mut caches: Vec<Vec<Option<V2>>> =
            (0..workers).map(|_| vec![None; range]).collect();
        let mut emitted = 0u64;

        // Single pass over the node's partition, one cursor block per worker.
        let mut cur = input.block_cursor(node, workers);
        for (w, cache) in caches.iter_mut().enumerate() {
            // Publish the worker's random stream (paper's `blaze::random`
            // is worker-local).
            crate::util::random::set_stream(cfg.seed, (node * workers + w) as u64);
            let emitted_before = emitted;
            let mut w_items = 0u64;
            let advanced = cur.next_block(|k, v| {
                w_items += 1;
                let mut emit = |k2: K2, v2: V2| {
                    emitted += 1;
                    dense_reduce(cache, range, &k2, v2, red);
                };
                mapper(k, v, &mut emit);
            });
            debug_assert!(advanced, "cursor yields one block per worker");
            trace.push(TraceEvent::new(
                node,
                Some(w),
                "map+dense-local-reduce",
                TraceEventKind::MapBlock {
                    items: w_items,
                    emitted: emitted - emitted_before,
                    exec_node: node,
                    epoch: 1,
                },
            ));
            counters.add_node(node, "map.items", w_items);
            hist.record_node(node, "map.block_items", w_items);
        }

        // Local tree reduce over worker caches (log2 W combining steps on a
        // real machine; serial here, the combine work is identical).
        let mut iter = caches.into_iter();
        let mut acc = iter.next().expect("at least one worker");
        for cache in iter {
            merge_dense(&mut acc, cache, red);
        }

        counters.add_node(node, "map.emitted", emitted);
        per_node_secs[node] = t0.elapsed().as_secs_f64();
        pairs_emitted += emitted;
        node_partials.push(acc);
    }
    vt.compute_phase("map+dense-local-reduce", &per_node_secs, workers);
    let map_wall_ns = t_map.elapsed().as_nanos() as u64;

    // ---- Tree reduce + driver absorb (shared pipeline) ------------------
    let out = tree_reduce_into_target(
        &cluster,
        node_partials,
        red,
        target,
        &mut vt,
        &mut trace,
        &mut hist,
        Transport::FlowModel,
    );

    // ---- Record ----------------------------------------------------------
    let compute_sec = vt.compute_sec();
    let makespan = vt.makespan();
    trace.stamp_phases(&vt);
    cluster.trace().absorb_job(&rec.label, trace);
    let (run_counters, node_counters) = counters.finish();
    let (pairs_shuffled, dense_cache_bytes) = dense_stats::<V2>(nodes, workers, range);
    cluster.metrics().record_run(RunStats {
        label: rec.label,
        engine: "blaze".into(),
        backend: "simulated".into(),
        nodes,
        workers_per_node: workers,
        makespan_sec: makespan,
        compute_sec,
        shuffle_sec: makespan - compute_sec,
        shuffle_bytes: out.shuffle_bytes,
        // Tree-reduce candidate buffers are the only serialized payloads.
        ser_bytes: out.shuffle_bytes,
        pairs_emitted,
        pairs_shuffled,
        peak_intermediate_bytes: dense_cache_bytes + out.round_flow_peak,
        host_wall_sec: rec.started.elapsed().as_secs_f64(),
        phase_wall_ns: vec![
            ("map+dense-local-reduce".into(), map_wall_ns),
            ("tree-reduce".into(), out.wall_ns),
        ],
        counters: run_counters,
        node_counters,
        histograms: hist.finish(),
        ..Default::default()
    });
}

/// Outcome of [`tree_reduce_into_target`].
pub(crate) struct TreeReduceOutcome {
    /// Serialized tree-reduce bytes moved across nodes.
    pub shuffle_bytes: u64,
    /// Largest single tree-reduce payload (memory accounting).
    pub round_flow_peak: u64,
    /// Host wall nanoseconds of the whole tree reduce.
    pub wall_ns: u64,
    /// Real-transport measurements accumulated over all rounds
    /// (`Transport::Channels` only).
    pub transport: Option<TransportTotals>,
}

/// The cross-machine binomial tree reduce over per-node dense partials,
/// landing the total at the driver's target. Round r: node i with
/// `i % 2^(r+1) == 2^r` sends its partial to `i - 2^r`; after
/// `ceil(log2 nodes)` rounds node 0 holds the total. Shared verbatim by
/// the simulated small-key engine and the threaded backend
/// ([`crate::exec`]) so both land bit-identical results. Each round
/// serializes every send (Shuffle events), moves the bytes — by hand
/// under [`Transport::FlowModel`], through real bounded channels under
/// [`Transport::Channels`] — then decodes and folds (Reduce events), so
/// the canonical event order is transport-invariant by construction.
pub(crate) fn tree_reduce_into_target<K2, V2, T>(
    cluster: &Cluster,
    node_partials: Vec<Vec<Option<V2>>>,
    red: &Reducer<V2>,
    target: &mut T,
    vt: &mut VirtualTime,
    trace: &mut TraceBuf,
    hist: &mut Histograms,
    transport: Transport,
) -> TreeReduceOutcome
where
    V2: Clone + FastSer,
    T: ReduceTarget<K2, V2>,
{
    let t_start = Instant::now();
    let cfg = cluster.config();
    let nodes = cfg.nodes;
    // Frame + transport-chunk scratch honours the allocator toggle
    // ("Blaze TCM"), like the eager shuffle.
    let scratch = Scratch::new(cfg.alloc, cluster.pool());
    let mut shuffle_bytes = 0u64;
    let mut round_flow_peak = 0u64;
    let mut transport_totals = match transport {
        Transport::FlowModel => None,
        Transport::Channels => Some(TransportTotals::default()),
    };
    let mut partials: Vec<Option<Vec<Option<V2>>>> =
        node_partials.into_iter().map(Some).collect();
    let mut stride = 1usize;
    let mut round = 0u16;
    while stride < nodes {
        let mut flows = FlowMatrix::new(nodes);
        let mut reduce_secs = 0.0f64;
        let mut sends: Vec<(usize, usize)> = Vec::new();
        for src in (stride..nodes).step_by(stride * 2) {
            sends.push((src, src - stride));
        }
        // Serialize + Shuffle events for the whole round. The round's
        // flow matrix records one message per payload (un-chunked),
        // whatever the transport — virtual time is mode-invariant.
        let mut bufs: Vec<(usize, usize, Vec<u8>)> = Vec::new();
        for &(src, dst) in &sends {
            let Some(partial) = partials[src].take() else { continue };
            // Serialize only present entries (sparse pair encoding).
            let pairs: Vec<(u32, V2)> = partial
                .into_iter()
                .enumerate()
                .filter_map(|(i, v)| v.map(|v| (i as u32, v)))
                .collect();
            let buf = encode_pairs_into(&pairs, scratch.get(pairs.len() * 4));
            flows.record(src, dst, buf.len() as u64);
            shuffle_bytes += buf.len() as u64;
            round_flow_peak = round_flow_peak.max(buf.len() as u64);
            super::eager::record_frame_chunks(hist, src, buf.len());
            trace.push(
                TraceEvent::new(
                    src,
                    None,
                    "tree-reduce-round",
                    TraceEventKind::Shuffle {
                        dst,
                        bytes: buf.len() as u64,
                        pairs: pairs.len() as u64,
                    },
                )
                .at_phase_ix(round),
            );
            bufs.push((src, dst, buf));
        }
        // Move the round's bytes.
        let moved: Vec<(usize, usize, Vec<u8>)> = match transport {
            Transport::FlowModel => bufs,
            Transport::Channels => {
                let order: Vec<(usize, usize)> = bufs.iter().map(|&(s, d, _)| (s, d)).collect();
                let mut matrix: ShufflePayloads =
                    (0..nodes).map(|_| (0..nodes).map(|_| Vec::new()).collect()).collect();
                for (src, dst, buf) in bufs {
                    matrix[src][dst] = buf;
                }
                // Under a lossy plan, stage an untouched copy: retry
                // exhaustion degrades this round onto the flow model
                // (structured error, never a hang) with identical bytes.
                let net_fault = cfg.net_fault;
                let mut lossy_fallback = net_fault.is_some().then(|| matrix.clone());
                let attempt = match net_fault {
                    None => Ok(crate::exec::transport::execute_pooled(
                        matrix,
                        cfg.transport_window_bytes,
                        &scratch,
                    )),
                    Some(plan) => crate::exec::transport::execute_lossy(
                        matrix,
                        cfg.transport_window_bytes,
                        &plan,
                        &scratch,
                    ),
                };
                match attempt {
                    Ok(tres) => {
                        for &(src, in_flight) in &tres.in_flight_samples {
                            trace.push_sample(
                                src,
                                "tree-reduce-round",
                                round,
                                "transport.in_flight_bytes",
                                in_flight,
                            );
                        }
                        hist.merge_global("wall.transport.frame_wait_ns", &tres.frame_wait);
                        for ps in &tres.pair_stats {
                            trace.push(
                                TraceEvent::new(
                                    ps.src,
                                    None,
                                    "tree-reduce-round",
                                    TraceEventKind::FrameSent {
                                        dst: ps.dst,
                                        frames: ps.frames,
                                        bytes: ps.bytes,
                                    },
                                )
                                .at_phase_ix(round),
                            );
                            if ps.stalls > 0 {
                                trace.push(
                                    TraceEvent::new(
                                        ps.src,
                                        None,
                                        "tree-reduce-round",
                                        TraceEventKind::TransportStall {
                                            dst: ps.dst,
                                            stalls: ps.stalls,
                                        },
                                    )
                                    .at_phase_ix(round),
                                );
                            }
                        }
                        // Injected frame fates, in the mirror's
                        // deterministic resolution order (Chrome-only).
                        for fault in &tres.faults {
                            match *fault {
                                FrameFault::Dropped { src, dst, seq, attempt, corrupt } => {
                                    trace.push(
                                        TraceEvent::new(
                                            src,
                                            None,
                                            "tree-reduce-round",
                                            TraceEventKind::FrameDropped {
                                                dst,
                                                seq,
                                                attempt,
                                                corrupt,
                                            },
                                        )
                                        .at_phase_ix(round),
                                    );
                                }
                                FrameFault::Retried { src, dst, seq, attempt, backoff_ns } => {
                                    trace.push(
                                        TraceEvent::new(
                                            src,
                                            None,
                                            "tree-reduce-round",
                                            TraceEventKind::FrameRetried {
                                                dst,
                                                seq,
                                                attempt,
                                                backoff_ns,
                                            },
                                        )
                                        .at_phase_ix(round),
                                    );
                                }
                            }
                        }
                        // The deterministic backoff mirror extends the
                        // virtual clock; no trace event carries this
                        // label, so the canonical export is untouched.
                        if tres.backoff_ns > 0 {
                            vt.fixed_phase("transport-backoff", tres.backoff_ns as f64 * 1e-9);
                        }
                        if let Some(t) = transport_totals.as_mut() {
                            t.merge(tres.totals());
                        }
                        // Each destination hears from exactly one source
                        // per round; its (src, seq)-sorted frames
                        // concatenate back into the original payload.
                        let mut per_dst = tres.delivered;
                        order
                            .into_iter()
                            .map(|(src, dst)| {
                                let mut buf = Vec::new();
                                for (s, chunk) in std::mem::take(&mut per_dst[dst]) {
                                    debug_assert_eq!(s, src, "one sender per dst per round");
                                    if buf.is_empty() {
                                        buf = chunk;
                                    } else {
                                        buf.extend_from_slice(&chunk);
                                        scratch.put(chunk); // recycle the copied tail
                                    }
                                }
                                (src, dst, buf)
                            })
                            .collect()
                    }
                    Err(err) => {
                        trace.push(
                            TraceEvent::new(
                                err.src,
                                None,
                                "tree-reduce-round",
                                TraceEventKind::NodeTimedOut {
                                    dst: err.node,
                                    attempts: err.attempts,
                                },
                            )
                            .at_phase_ix(round),
                        );
                        if let Some(t) = transport_totals.as_mut() {
                            t.merge(TransportTotals {
                                timeouts: 1,
                                backoff_ns: err.backoff_ns,
                                faulted: true,
                                ..Default::default()
                            });
                        }
                        // Degraded round: the staged payloads move by the
                        // flow model instead — byte-identical outcome.
                        let mut fb = lossy_fallback
                            .take()
                            .expect("fallback staged for every lossy transport run");
                        order
                            .into_iter()
                            .map(|(src, dst)| (src, dst, std::mem::take(&mut fb[src][dst])))
                            .collect()
                    }
                }
            }
        };
        // Decode + fold, in send order (Reduce events).
        for (src, dst, buf) in moved {
            let t0 = Instant::now();
            let decoded = decode_pairs::<u32, V2>(&buf).expect("tree-reduce payload");
            scratch.put(buf); // recycle under the pool allocator
            trace.push(
                TraceEvent::new(
                    dst,
                    None,
                    "tree-reduce-round",
                    TraceEventKind::Reduce { from: src, pairs: decoded.len() as u64 },
                )
                .at_phase_ix(round),
            );
            let acc = partials[dst].as_mut().expect("tree reduce destination");
            for (idx, v) in decoded {
                match &mut acc[idx as usize] {
                    Some(a) => red.apply(a, &v),
                    slot @ None => *slot = Some(v),
                }
            }
            reduce_secs = reduce_secs.max(t0.elapsed().as_secs_f64());
        }
        vt.shuffle_overlapped("tree-reduce-round", &flows, &cfg.network, reduce_secs);
        stride *= 2;
        round += 1;
    }

    // Land at the driver.
    let final_partial = partials[0].take().expect("driver partial");
    target.absorb_dense(final_partial, red);

    TreeReduceOutcome {
        shuffle_bytes,
        round_flow_peak,
        wall_ns: t_start.elapsed().as_nanos() as u64,
        transport: transport_totals,
    }
}

/// Reduce one emitted pair into a dense per-worker cache — the dense
/// path's emit body, shared by the simulated and threaded engines so the
/// byte-identity contract between backends cannot drift.
///
/// # Panics
/// If `k2` has no dense index, or it falls outside the target's fixed
/// `range` (paper §2.2: the target defines the key range).
#[inline]
pub(crate) fn dense_reduce<K2: DenseKey, V2>(
    cache: &mut [Option<V2>],
    range: usize,
    k2: &K2,
    v2: V2,
    red: &Reducer<V2>,
) {
    let idx = k2
        .dense_index()
        .unwrap_or_else(|| panic!("key has no dense index for Vec target"));
    assert!(idx < range, "key {idx} outside fixed key range {range}");
    match &mut cache[idx] {
        Some(acc) => red.apply(acc, &v2),
        slot @ None => *slot = Some(v2),
    }
}

/// Derived dense-path stats shared by the simulated and threaded engines
/// for an `nodes × workers` job over a `range`-slot dense target:
/// `(pairs_shuffled, dense_cache_bytes)` — each non-driver node ships one
/// `range`-slot partial up the tree, and every worker holds one
/// `range`-slot cache during the map.
pub(crate) fn dense_stats<V>(nodes: usize, workers: usize, range: usize) -> (u64, u64) {
    let slot_bytes = (std::mem::size_of::<Option<V>>() as u64).max(1);
    (
        (nodes.saturating_sub(1)) as u64 * range as u64,
        (nodes * workers * range) as u64 * slot_bytes,
    )
}

/// Element-wise merge of one dense worker cache into the accumulator, in
/// slot order (shared with the threaded backend's canonical worker-order
/// merge).
pub(crate) fn merge_dense<V: Clone>(acc: &mut [Option<V>], other: Vec<Option<V>>, red: &Reducer<V>) {
    for (slot, v) in acc.iter_mut().zip(other) {
        match (slot.as_mut(), v) {
            (Some(a), Some(b)) => red.apply(a, &b),
            (None, Some(b)) => *slot = Some(b),
            _ => {}
        }
    }
}
