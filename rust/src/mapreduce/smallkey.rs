//! Small fixed key range optimization (paper §2.3.3).
//!
//! When the target is a `Vec<V>` the key range is small and known up front.
//! Each worker gets a dense per-key cache (`Vec<Option<V>>`) created *at the
//! start* and set as the reduce target during the local map/reduce phase —
//! no hashing, no entry lookups. Afterwards a parallel **binomial tree
//! reduce** combines partials: first worker caches within a node, then
//! across machines (`log2 N` rounds), landing at the driver. The execution
//! plan is identical to a hand-optimized MPI+OpenMP parallel for-loop with
//! thread-local intermediates — which is why Table 1 shows parity.

use std::hash::Hash;
use std::time::Instant;

use crate::coordinator::metrics::RunStats;
use crate::net::sim::FlowMatrix;
use crate::net::vtime::VirtualTime;
use crate::ser::fastser::{decode_pairs, encode_pairs, FastSer};

use super::reducers::Reducer;
use super::{BlockCursor, DenseKey, DistInput, Emit, ReduceTarget, RunRecorder};

/// Run one MapReduce through the dense small-key-range path.
///
/// # Panics
/// If a mapper emits a key without a dense index inside the target's fixed
/// range — the contract of a `Vec<V>` target (paper §2.2: the target defines
/// the key range).
pub fn run<I, F, K2, V2, T>(label: &str, input: &I, mapper: &F, red: &Reducer<V2>, target: &mut T)
where
    I: DistInput,
    F: Fn(&I::K, &I::V, Emit<'_, K2, V2>),
    K2: Hash + Eq + Clone + FastSer + DenseKey,
    V2: Clone + FastSer,
    T: ReduceTarget<K2, V2>,
{
    let rec = RunRecorder::new(label);
    let cluster = input.cluster().clone();
    let cfg = cluster.config().clone();
    let (nodes, workers) = (cfg.nodes, cfg.workers_per_node);
    let range = target.dense_len().expect("smallkey path requires a dense target");

    let mut vt = VirtualTime::new();
    let mut per_node_secs = vec![0.0f64; nodes];
    let mut node_partials: Vec<Vec<Option<V2>>> = Vec::with_capacity(nodes);
    let mut pairs_emitted = 0u64;

    // ---- Map with per-worker dense caches + in-node tree reduce ---------
    for node in 0..nodes {
        let t0 = Instant::now();
        let mut caches: Vec<Vec<Option<V2>>> =
            (0..workers).map(|_| vec![None; range]).collect();
        let mut emitted = 0u64;

        // Single pass over the node's partition, one cursor block per worker.
        let mut cur = input.block_cursor(node, workers);
        for (w, cache) in caches.iter_mut().enumerate() {
            // Publish the worker's random stream (paper's `blaze::random`
            // is worker-local).
            crate::util::random::set_stream(cfg.seed, (node * workers + w) as u64);
            let advanced = cur.next_block(|k, v| {
                let mut emit = |k2: K2, v2: V2| {
                    emitted += 1;
                    let idx = k2
                        .dense_index()
                        .unwrap_or_else(|| panic!("key has no dense index for Vec target"));
                    assert!(idx < range, "key {idx} outside fixed key range {range}");
                    match &mut cache[idx] {
                        Some(acc) => red.apply(acc, &v2),
                        slot @ None => *slot = Some(v2),
                    }
                };
                mapper(k, v, &mut emit);
            });
            debug_assert!(advanced, "cursor yields one block per worker");
        }

        // Local tree reduce over worker caches (log2 W combining steps on a
        // real machine; serial here, the combine work is identical).
        let mut iter = caches.into_iter();
        let mut acc = iter.next().expect("at least one worker");
        for cache in iter {
            merge_dense(&mut acc, cache, red);
        }

        per_node_secs[node] = t0.elapsed().as_secs_f64();
        pairs_emitted += emitted;
        node_partials.push(acc);
    }
    vt.compute_phase("map+dense-local-reduce", &per_node_secs, workers);

    // ---- Cross-machine binomial tree reduce -----------------------------
    // Round r: node i with i % 2^(r+1) == 2^r sends its partial to
    // i - 2^r. After ceil(log2 nodes) rounds node 0 holds the total.
    let mut shuffle_bytes = 0u64;
    let mut round_flow_peak = 0u64;
    let mut partials: Vec<Option<Vec<Option<V2>>>> =
        node_partials.into_iter().map(Some).collect();
    let mut stride = 1usize;
    while stride < nodes {
        let mut flows = FlowMatrix::new(nodes);
        let mut reduce_secs = 0.0f64;
        let mut sends: Vec<(usize, usize)> = Vec::new();
        for src in (stride..nodes).step_by(stride * 2) {
            sends.push((src, src - stride));
        }
        for (src, dst) in sends {
            let Some(partial) = partials[src].take() else { continue };
            // Serialize only present entries (sparse pair encoding).
            let pairs: Vec<(u32, V2)> = partial
                .into_iter()
                .enumerate()
                .filter_map(|(i, v)| v.map(|v| (i as u32, v)))
                .collect();
            let buf = encode_pairs(&pairs);
            flows.record(src, dst, buf.len() as u64);
            shuffle_bytes += buf.len() as u64;
            round_flow_peak = round_flow_peak.max(buf.len() as u64);
            let t0 = Instant::now();
            let decoded = decode_pairs::<u32, V2>(&buf).expect("tree-reduce payload");
            let acc = partials[dst].as_mut().expect("tree reduce destination");
            for (idx, v) in decoded {
                match &mut acc[idx as usize] {
                    Some(a) => red.apply(a, &v),
                    slot @ None => *slot = Some(v),
                }
            }
            reduce_secs = reduce_secs.max(t0.elapsed().as_secs_f64());
        }
        vt.shuffle_overlapped("tree-reduce-round", &flows, &cfg.network, reduce_secs);
        stride *= 2;
    }

    // ---- Land at the driver ---------------------------------------------
    let final_partial = partials[0].take().expect("driver partial");
    target.absorb_dense(final_partial, red);

    // ---- Record ----------------------------------------------------------
    let compute_sec: f64 = vt
        .phases()
        .iter()
        .filter(|p| matches!(p.kind, crate::net::vtime::PhaseKind::Compute))
        .map(|p| p.seconds)
        .sum();
    let makespan = vt.makespan();
    // Dense caches: range slots per worker per node.
    let slot_bytes = (std::mem::size_of::<Option<V2>>() as u64).max(1);
    cluster.metrics().record_run(RunStats {
        label: rec.label,
        engine: "blaze".into(),
        nodes,
        workers_per_node: workers,
        makespan_sec: makespan,
        compute_sec,
        shuffle_sec: makespan - compute_sec,
        shuffle_bytes,
        // Tree-reduce candidate buffers are the only serialized payloads.
        ser_bytes: shuffle_bytes,
        pairs_emitted,
        pairs_shuffled: (nodes.saturating_sub(1)) as u64 * range as u64,
        peak_intermediate_bytes: (nodes * workers * range) as u64 * slot_bytes
            + round_flow_peak,
        host_wall_sec: rec.started.elapsed().as_secs_f64(),
        ..Default::default()
    });
}

fn merge_dense<V: Clone>(acc: &mut [Option<V>], other: Vec<Option<V>>, red: &Reducer<V>) {
    for (slot, v) in acc.iter_mut().zip(other) {
        match (slot.as_mut(), v) {
            (Some(a), Some(b)) => red.apply(a, &b),
            (None, Some(b)) => *slot = Some(b),
            _ => {}
        }
    }
}
