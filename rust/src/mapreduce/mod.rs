//! The Blaze MapReduce function (paper §2.2–2.3).
//!
//! ```text
//! blaze::mapreduce(input, mapper, reducer, target)
//! ```
//!
//! * **input** — a distributed container ([`crate::containers`]).
//! * **mapper** — `|key, value, emit| { ... emit(k2, v2); ... }`.
//! * **reducer** — built-in by name (`"sum"`, `"prod"`, `"min"`, `"max"`),
//!   a [`Reducer`] handle, or a custom closure.
//! * **target** — a distributed container or a `Vec<V>`; *not cleared*:
//!   new results are reduced into whatever the target already holds.
//!
//! Three execution paths implement the paper's three optimizations:
//!
//! * [`eager`] — the general engine: eager reduction into bounded
//!   thread-local caches, machine-local combine, fast (tag-less)
//!   serialization, shuffle with the reduce running asynchronously.
//! * [`smallkey`] — when the target is a `Vec<V>` (small *fixed* key
//!   range), per-worker dense caches and a binomial tree reduce, matching
//!   hand-optimized `MPI_Reduce`-style loops.
//! * [`conventional`] — the Spark-analog baseline: materialize every pair,
//!   tagged serialization, barrier shuffle, group-then-reduce. Selected via
//!   [`EngineKind::Conventional`] so every workload can run both ways.
//!
//! Orthogonally, `ClusterConfig::backend` picks the execution *backend*
//! for the eager and small-key paths: `Simulated` (serial walk, virtual
//! parallelism accounted) or `Threaded(n)` ([`crate::exec`] — real OS
//! threads for the map+combine, byte-identical results, wall clock
//! recorded alongside virtual time).

pub mod conventional;
pub mod eager;
pub mod reducers;
pub mod smallkey;

pub use reducers::{Numeric, Reducer};

use crate::containers::DistRange;
use crate::coordinator::cluster::{Backend, Cluster, EngineKind};
use crate::ser::fastser::FastSer;
use crate::ser::tagged::TaggedSer;
use std::hash::Hash;

/// Emit handler handed to mappers.
pub type Emit<'a, K, V> = &'a mut dyn FnMut(K, V);

/// Single-pass cursor over one node's partition, split into worker blocks.
///
/// Created by [`DistInput::block_cursor`] with a fixed `workers` count; each
/// [`BlockCursor::next_block`] call visits the *next* worker block's items
/// (block 0, then 1, … then `workers - 1`) and advances the cursor, walking
/// the underlying partition exactly once across all calls. Empty blocks
/// still count: `next_block` returns `true` without visiting anything until
/// all `workers` blocks have been yielded, then `false`.
///
/// Engines that execute blocks in order (all of them, on the failure-free
/// path) therefore touch every input item exactly once per job; the
/// recoverable engine only rebuilds a cursor (re-walking a prefix) when a
/// recovery replay revisits an already-executed block out of order.
pub trait BlockCursor<K, V> {
    /// Visit every item of the next worker block in partition order.
    /// Returns `false` (calling `f` on nothing) once all blocks are done.
    fn next_block<F: FnMut(&K, &V)>(&mut self, f: F) -> bool;
}

/// Distributed MapReduce input: anything that can iterate its per-node
/// partition as a sequence of per-worker blocks.
pub trait DistInput {
    /// Input key type (element index for vectors, key for hash maps).
    type K;
    /// Input value type.
    type V;
    /// Cursor over one node's partition (borrows the input).
    type Cursor<'a>: BlockCursor<Self::K, Self::V>
    where
        Self: 'a;

    /// Owning cluster.
    fn cluster(&self) -> &Cluster;

    /// Item count on `node`.
    fn node_len(&self, node: usize) -> usize;

    /// Single-pass cursor over `node`'s partition split into `workers`
    /// contiguous blocks (the same block partitioning every engine uses).
    fn block_cursor(&self, node: usize, workers: usize) -> Self::Cursor<'_>;

    /// Visit every item on `node`, tagged with the worker (0..workers) that
    /// would process it under block partitioning. One pass, built on
    /// [`Self::block_cursor`].
    fn for_each_worker_item<F: FnMut(usize, &Self::K, &Self::V)>(
        &self,
        node: usize,
        workers: usize,
        mut f: F,
    ) {
        let mut cur = self.block_cursor(node, workers);
        let mut w = 0usize;
        while cur.next_block(|k, v| f(w, k, v)) {
            w += 1;
        }
    }
}

/// Keys that may map onto a dense `[0, n)` index space, enabling the
/// small-key-range path when the target is a `Vec<V>`.
pub trait DenseKey {
    /// Dense index of this key, if it has one.
    fn dense_index(&self) -> Option<usize>;
}

macro_rules! impl_dense_int {
    ($($t:ty),*) => {$(
        impl DenseKey for $t {
            #[inline]
            fn dense_index(&self) -> Option<usize> {
                usize::try_from(*self).ok()
            }
        }
    )*};
}

impl_dense_int!(u8, u16, u32, u64, usize);

macro_rules! impl_dense_none {
    ($($t:ty),*) => {$(
        impl DenseKey for $t {
            #[inline]
            fn dense_index(&self) -> Option<usize> { None }
        }
    )*};
}

impl_dense_none!(i8, i16, i32, i64, isize, String, f32, f64);

impl<A, B> DenseKey for (A, B) {
    #[inline]
    fn dense_index(&self) -> Option<usize> {
        None
    }
}

/// Where reduced results land. Targets are *merged into*, never cleared.
pub trait ReduceTarget<K, V> {
    /// `Some(n)` when keys are dense indices in `[0, n)` gathered at the
    /// driver — triggers the small-key-range path on the eager engine.
    fn dense_len(&self) -> Option<usize> {
        None
    }

    /// Destination node for `key` on an `nodes`-node cluster.
    fn shard_of(&self, key: &K, nodes: usize) -> usize;

    /// Reduce `pairs` (already routed to `node`) into the target.
    fn absorb(&mut self, node: usize, pairs: Vec<(K, V)>, red: &Reducer<V>);

    /// Reduce a dense per-index value array into the target (small-key path).
    fn absorb_dense(&mut self, values: Vec<Option<V>>, red: &Reducer<V>) {
        let _ = (values, red);
        unimplemented!("dense absorb not supported by this target")
    }
}

/// `Vec<V>` target: the paper's π example reduces a `DistRange` into a
/// plain `std::vector`. Keys are dense indices; results gather to the
/// driver via a tree reduce.
impl<V: Clone> ReduceTarget<usize, V> for Vec<V> {
    fn dense_len(&self) -> Option<usize> {
        Some(self.len())
    }

    fn shard_of(&self, _key: &usize, _nodes: usize) -> usize {
        0 // driver gathers
    }

    fn absorb(&mut self, _node: usize, pairs: Vec<(usize, V)>, red: &Reducer<V>) {
        for (k, v) in pairs {
            assert!(k < self.len(), "key {k} outside fixed key range {}", self.len());
            red.apply(&mut self[k], &v);
        }
    }

    fn absorb_dense(&mut self, values: Vec<Option<V>>, red: &Reducer<V>) {
        assert!(values.len() <= self.len(), "dense range exceeds target length");
        for (slot, v) in self.iter_mut().zip(values) {
            if let Some(v) = v {
                red.apply(slot, &v);
            }
        }
    }
}

/// Anything convertible into a [`Reducer`]: a handle, or a built-in's name
/// (the paper's `"sum"` string interface).
pub trait IntoReducer<V> {
    /// Convert into a reducer handle.
    fn into_reducer(self) -> Reducer<V>;
}

impl<V> IntoReducer<V> for Reducer<V> {
    fn into_reducer(self) -> Reducer<V> {
        self
    }
}

impl<V: Numeric> IntoReducer<V> for &str {
    fn into_reducer(self) -> Reducer<V> {
        Reducer::by_name(self)
    }
}

/// MapReduce over a keyed container (`DistVector`, `DistHashMap`):
/// the mapper receives `(key, value, emit)` (paper §2.2).
///
/// Targets additionally implement [`crate::fault::Recover`] so any job can
/// run through the recoverable engine when the cluster's
/// [`crate::fault::FaultConfig`] is enabled.
///
/// The `Send`/`Sync` bounds exist for the threaded backend
/// ([`crate::exec`], selected by `ClusterConfig::backend`): input items
/// are cloned into owned blocks handed to worker threads, and the mapper
/// is shared across the pool. Pure mappers over plain data — every
/// paper workload — satisfy them automatically.
pub fn mapreduce<I, F, K2, V2, R, T>(input: &I, mapper: F, reducer: R, target: &mut T)
where
    I: DistInput,
    I::K: Clone + Send,
    I::V: Clone + Send,
    F: Fn(&I::K, &I::V, Emit<'_, K2, V2>) + Sync,
    K2: Hash + Eq + Clone + FastSer + TaggedSer + DenseKey + Send,
    V2: Clone + FastSer + TaggedSer + Send,
    R: IntoReducer<V2>,
    T: ReduceTarget<K2, V2> + crate::fault::Recover,
{
    mapreduce_labeled("mapreduce", input, mapper, reducer, target);
}

/// [`mapreduce`] with an explicit metrics label (used by apps and benches).
pub fn mapreduce_labeled<I, F, K2, V2, R, T>(
    label: &str,
    input: &I,
    mapper: F,
    reducer: R,
    target: &mut T,
) where
    I: DistInput,
    I::K: Clone + Send,
    I::V: Clone + Send,
    F: Fn(&I::K, &I::V, Emit<'_, K2, V2>) + Sync,
    K2: Hash + Eq + Clone + FastSer + TaggedSer + DenseKey + Send,
    V2: Clone + FastSer + TaggedSer + Send,
    R: IntoReducer<V2>,
    T: ReduceTarget<K2, V2> + crate::fault::Recover,
{
    let red = reducer.into_reducer();
    let cfg = input.cluster().config();
    if cfg.fault.enabled() {
        // Fault tolerance on: block-granular recoverable execution
        // (respects the engine kind for codec and cost modeling). Under
        // `Backend::Threaded(n)` the map side — replays included — runs
        // on the live pool; commits stay serial, so results and canonical
        // traces are byte-identical across backends.
        crate::fault::engine::run(label, input, &mapper, &red, target);
        return;
    }
    match cfg.engine {
        EngineKind::Eager => match (cfg.backend, target.dense_len()) {
            (Backend::Threaded(threads), Some(_)) => {
                crate::exec::engine::run_smallkey(label, input, &mapper, &red, target, threads);
            }
            (Backend::Threaded(threads), None) => {
                crate::exec::engine::run_eager(label, input, &mapper, &red, target, threads);
            }
            (Backend::Simulated, Some(_)) => smallkey::run(label, input, &mapper, &red, target),
            (Backend::Simulated, None) => eager::run(label, input, &mapper, &red, target),
        },
        // The conventional engine models the Spark baseline; it is never
        // threaded (the backend accelerates Blaze's own paths).
        EngineKind::Conventional => conventional::run(label, input, &mapper, &red, target),
    }
}

/// MapReduce over a [`DistRange`]: the mapper receives `(value, emit)`
/// (paper §2.2 — two-parameter mapper for ranges).
pub fn mapreduce_range<F, K2, V2, R, T>(input: &DistRange, mapper: F, reducer: R, target: &mut T)
where
    F: Fn(u64, Emit<'_, K2, V2>) + Sync,
    K2: Hash + Eq + Clone + FastSer + TaggedSer + DenseKey + Send,
    V2: Clone + FastSer + TaggedSer + Send,
    R: IntoReducer<V2>,
    T: ReduceTarget<K2, V2> + crate::fault::Recover,
{
    mapreduce_range_labeled("mapreduce_range", input, mapper, reducer, target);
}

/// [`mapreduce_range`] with an explicit metrics label.
pub fn mapreduce_range_labeled<F, K2, V2, R, T>(
    label: &str,
    input: &DistRange,
    mapper: F,
    reducer: R,
    target: &mut T,
) where
    F: Fn(u64, Emit<'_, K2, V2>) + Sync,
    K2: Hash + Eq + Clone + FastSer + TaggedSer + DenseKey + Send,
    V2: Clone + FastSer + TaggedSer + Send,
    R: IntoReducer<V2>,
    T: ReduceTarget<K2, V2> + crate::fault::Recover,
{
    mapreduce_labeled(label, input, |_, v: &u64, emit| mapper(*v, emit), reducer, target);
}

/// Internal: shared per-run bookkeeping for the engines.
pub(crate) struct RunRecorder {
    pub label: String,
    pub started: std::time::Instant,
}

impl RunRecorder {
    pub(crate) fn new(label: &str) -> Self {
        Self { label: label.to_string(), started: std::time::Instant::now() }
    }
}
