//! Deterministic FxHash-style hasher.
//!
//! `std::collections::HashMap`'s default hasher is randomized per process;
//! Blaze needs key→shard routing to be identical across runs and across the
//! virtual nodes, so containers and engines hash with this fixed-seed
//! multiply-rotate hasher (the rustc FxHash construction).

use std::hash::{BuildHasherDefault, Hash, Hasher};

const SEED: u64 = 0x51_7c_c1_b7_27_22_0a_95;

/// FxHash: fold each 8-byte chunk with multiply-rotate.
#[derive(Default, Clone)]
pub struct FxHasher {
    hash: u64,
}

impl FxHasher {
    #[inline]
    fn add_to_hash(&mut self, word: u64) {
        self.hash = (self.hash.rotate_left(5) ^ word).wrapping_mul(SEED);
    }
}

impl Hasher for FxHasher {
    #[inline]
    fn finish(&self) -> u64 {
        self.hash
    }

    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        let mut chunks = bytes.chunks_exact(8);
        for c in &mut chunks {
            self.add_to_hash(u64::from_le_bytes(c.try_into().unwrap()));
        }
        let rem = chunks.remainder();
        if !rem.is_empty() {
            let mut word = [0u8; 8];
            word[..rem.len()].copy_from_slice(rem);
            self.add_to_hash(u64::from_le_bytes(word));
        }
    }

    #[inline]
    fn write_u8(&mut self, i: u8) {
        self.add_to_hash(u64::from(i));
    }

    #[inline]
    fn write_u16(&mut self, i: u16) {
        self.add_to_hash(u64::from(i));
    }

    #[inline]
    fn write_u32(&mut self, i: u32) {
        self.add_to_hash(u64::from(i));
    }

    #[inline]
    fn write_u64(&mut self, i: u64) {
        self.add_to_hash(i);
    }

    #[inline]
    fn write_usize(&mut self, i: usize) {
        self.add_to_hash(i as u64);
    }
}

/// `BuildHasher` for [`FxHasher`].
pub type FxBuildHasher = BuildHasherDefault<FxHasher>;

/// `HashMap` keyed with the deterministic hasher.
pub type FxHashMap<K, V> = std::collections::HashMap<K, V, FxBuildHasher>;

/// Hash one value deterministically.
#[inline]
pub fn fxhash<T: Hash + ?Sized>(value: &T) -> u64 {
    let mut h = FxHasher::default();
    value.hash(&mut h);
    h.finish()
}

/// Batched hashing width: the unrolled loop runs this many independent
/// hash lanes per iteration so the multiply-rotate chains have no
/// cross-element dependency (auto-vectorizable for fixed-width keys).
const BATCH_LANES: usize = 4;

/// Hash a batch of items through a key accessor into `out` (cleared
/// first). `out[i]` is bit-identical to `fxhash(key(&items[i]))` — the
/// batch form only amortizes loop overhead and removes the per-element
/// dependency chain; it never changes the hash function. Used by the
/// threaded backend's flush routing ([`crate::exec::cache`]) and stripe
/// selection ([`crate::exec::shard`]), where keys live inside `(K, V)`
/// pairs.
#[inline]
pub fn hash_batch_by<T, K, F>(items: &[T], key: F, out: &mut Vec<u64>)
where
    K: Hash + ?Sized,
    F: Fn(&T) -> &K,
{
    out.clear();
    out.reserve(items.len());
    let mut chunks = items.chunks_exact(BATCH_LANES);
    for c in &mut chunks {
        // Four independent lanes: no lane reads another's state.
        let h0 = fxhash(key(&c[0]));
        let h1 = fxhash(key(&c[1]));
        let h2 = fxhash(key(&c[2]));
        let h3 = fxhash(key(&c[3]));
        out.extend_from_slice(&[h0, h1, h2, h3]);
    }
    for item in chunks.remainder() {
        out.push(fxhash(key(item)));
    }
}

/// Hash a slice of keys into `out` (cleared first), element-for-element
/// identical to scalar [`fxhash`]. See [`hash_batch_by`].
#[inline]
pub fn hash_batch<K: Hash>(keys: &[K], out: &mut Vec<u64>) {
    hash_batch_by(keys, |k| k, out);
}

/// Map a slice of keys to shard/stripe indices under a power-of-two
/// `mask` in one batched pass: `out[i] == (fxhash(&keys[i]) as usize) &
/// mask`, exactly the scalar stripe-selection formula.
#[inline]
pub fn shard_batch<K: Hash>(keys: &[K], mask: usize, out: &mut Vec<usize>) {
    out.clear();
    out.reserve(keys.len());
    let mut chunks = keys.chunks_exact(BATCH_LANES);
    for c in &mut chunks {
        let s0 = (fxhash(&c[0]) as usize) & mask;
        let s1 = (fxhash(&c[1]) as usize) & mask;
        let s2 = (fxhash(&c[2]) as usize) & mask;
        let s3 = (fxhash(&c[3]) as usize) & mask;
        out.extend_from_slice(&[s0, s1, s2, s3]);
    }
    for k in chunks.remainder() {
        out.push((fxhash(k) as usize) & mask);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        assert_eq!(fxhash("blaze"), fxhash("blaze"));
        assert_eq!(fxhash(&42u64), fxhash(&42u64));
        assert_ne!(fxhash("blaze"), fxhash("spark"));
    }

    #[test]
    fn spreads_sequential_keys() {
        // Sequential u64 keys must land on different slot values.
        let slots: std::collections::HashSet<u64> =
            (0..1000u64).map(|k| fxhash(&k) % 256).collect();
        assert!(slots.len() > 200, "only {} distinct slots", slots.len());
    }

    #[test]
    fn string_tail_bytes_matter() {
        assert_ne!(fxhash("abcdefghi"), fxhash("abcdefghj"));
    }

    #[test]
    fn batch_matches_scalar_u64() {
        // Lengths straddling the 4-lane unroll: empty, sub-lane, exact
        // multiples, and remainders all must agree with scalar fxhash.
        for len in [0usize, 1, 3, 4, 5, 8, 17, 100] {
            let keys: Vec<u64> = (0..len as u64).map(|k| k.wrapping_mul(0x9e37)).collect();
            let mut out = Vec::new();
            hash_batch(&keys, &mut out);
            assert_eq!(out.len(), keys.len());
            for (k, h) in keys.iter().zip(&out) {
                assert_eq!(*h, fxhash(k), "len={len} key={k}");
            }
        }
    }

    #[test]
    fn batch_by_extracts_pair_keys() {
        let pairs: Vec<(String, u64)> =
            (0..13).map(|i| (format!("key-{i}"), i)).collect();
        let mut out = Vec::new();
        hash_batch_by(&pairs, |p| p.0.as_str(), &mut out);
        for (p, h) in pairs.iter().zip(&out) {
            assert_eq!(*h, fxhash(p.0.as_str()));
        }
    }

    #[test]
    fn shard_batch_matches_scalar_mask() {
        let keys: Vec<u64> = (0..37).collect();
        let mut out = Vec::new();
        for mask in [0usize, 1, 7, 255] {
            shard_batch(&keys, mask, &mut out);
            for (k, s) in keys.iter().zip(&out) {
                assert_eq!(*s, (fxhash(k) as usize) & mask);
                assert!(*s <= mask);
            }
        }
    }

    #[test]
    fn batch_clears_previous_output() {
        let mut out = vec![99u64; 8];
        hash_batch::<u64>(&[], &mut out);
        assert!(out.is_empty());
    }
}
