//! Deterministic FxHash-style hasher.
//!
//! `std::collections::HashMap`'s default hasher is randomized per process;
//! Blaze needs key→shard routing to be identical across runs and across the
//! virtual nodes, so containers and engines hash with this fixed-seed
//! multiply-rotate hasher (the rustc FxHash construction).

use std::hash::{BuildHasherDefault, Hash, Hasher};

const SEED: u64 = 0x51_7c_c1_b7_27_22_0a_95;

/// FxHash: fold each 8-byte chunk with multiply-rotate.
#[derive(Default, Clone)]
pub struct FxHasher {
    hash: u64,
}

impl FxHasher {
    #[inline]
    fn add_to_hash(&mut self, word: u64) {
        self.hash = (self.hash.rotate_left(5) ^ word).wrapping_mul(SEED);
    }
}

impl Hasher for FxHasher {
    #[inline]
    fn finish(&self) -> u64 {
        self.hash
    }

    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        let mut chunks = bytes.chunks_exact(8);
        for c in &mut chunks {
            self.add_to_hash(u64::from_le_bytes(c.try_into().unwrap()));
        }
        let rem = chunks.remainder();
        if !rem.is_empty() {
            let mut word = [0u8; 8];
            word[..rem.len()].copy_from_slice(rem);
            self.add_to_hash(u64::from_le_bytes(word));
        }
    }

    #[inline]
    fn write_u8(&mut self, i: u8) {
        self.add_to_hash(u64::from(i));
    }

    #[inline]
    fn write_u16(&mut self, i: u16) {
        self.add_to_hash(u64::from(i));
    }

    #[inline]
    fn write_u32(&mut self, i: u32) {
        self.add_to_hash(u64::from(i));
    }

    #[inline]
    fn write_u64(&mut self, i: u64) {
        self.add_to_hash(i);
    }

    #[inline]
    fn write_usize(&mut self, i: usize) {
        self.add_to_hash(i as u64);
    }
}

/// `BuildHasher` for [`FxHasher`].
pub type FxBuildHasher = BuildHasherDefault<FxHasher>;

/// `HashMap` keyed with the deterministic hasher.
pub type FxHashMap<K, V> = std::collections::HashMap<K, V, FxBuildHasher>;

/// Hash one value deterministically.
#[inline]
pub fn fxhash<T: Hash + ?Sized>(value: &T) -> u64 {
    let mut h = FxHasher::default();
    value.hash(&mut h);
    h.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        assert_eq!(fxhash("blaze"), fxhash("blaze"));
        assert_eq!(fxhash(&42u64), fxhash(&42u64));
        assert_ne!(fxhash("blaze"), fxhash("spark"));
    }

    #[test]
    fn spreads_sequential_keys() {
        // Sequential u64 keys must land on different slot values.
        let slots: std::collections::HashSet<u64> =
            (0..1000u64).map(|k| fxhash(&k) % 256).collect();
        assert!(slots.len() > 200, "only {} distinct slots", slots.len());
    }

    #[test]
    fn string_tail_bytes_matter() {
        assert_ne!(fxhash("abcdefghi"), fxhash("abcdefghj"));
    }
}
