//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The build vendors no external crates; the runtime layer still wants
//! ergonomic string-context errors. This module provides the small subset
//! the codebase uses: a message-carrying [`Error`], a [`Result`] alias, the
//! [`anyhow!`]/[`bail!`] macros, and a [`Context`] extension trait with
//! `context`/`with_context`. Context is prepended `"context: cause"` so
//! messages read like `anyhow`'s single-line `{:#}` rendering.

/// String-backed error with accumulated context.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Error {
    msg: String,
}

impl Error {
    /// Error from a preformatted message.
    pub fn msg(msg: impl Into<String>) -> Self {
        Self { msg: msg.into() }
    }

    /// Wrap with an outer context layer.
    pub fn context(self, ctx: impl Into<String>) -> Self {
        Self { msg: format!("{}: {}", ctx.into(), self.msg) }
    }
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        // `{:#}` (anyhow's chain rendering) and `{}` both print the full
        // accumulated message.
        write!(f, "{}", self.msg)
    }
}

impl std::error::Error for Error {}

/// `Result` defaulting to [`Error`], mirroring `anyhow::Result`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Format an [`Error`] in place: `anyhow!("parsing {path}: {e}")`.
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::util::error::Error::msg(format!($($arg)*))
    };
}

/// Early-return an [`Error`]: `bail!("manifest lists no artifacts")`.
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::util::error::Error::msg(format!($($arg)*)))
    };
}

pub(crate) use {anyhow, bail};

/// Attach context to any displayable error, like `anyhow::Context`.
pub trait Context<T> {
    /// Wrap the error with a fixed context message.
    fn context(self, ctx: impl Into<String>) -> Result<T>;
    /// Wrap the error with a lazily-built context message.
    fn with_context<S: Into<String>>(self, f: impl FnOnce() -> S) -> Result<T>;
}

impl<T, E: std::fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context(self, ctx: impl Into<String>) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", ctx.into())))
    }

    fn with_context<S: Into<String>>(self, f: impl FnOnce() -> S) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f().into())))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn macro_formats() {
        let e = anyhow!("bad {} at {}", "value", 7);
        assert_eq!(e.to_string(), "bad value at 7");
    }

    #[test]
    fn context_prepends() {
        let r: std::result::Result<(), std::io::Error> =
            Err(std::io::Error::new(std::io::ErrorKind::NotFound, "gone"));
        let e = r.context("reading manifest").unwrap_err();
        assert!(e.to_string().starts_with("reading manifest: "));
        let layered = e.context("loading runtime");
        assert!(layered.to_string().starts_with("loading runtime: reading manifest:"));
    }

    #[test]
    fn bail_returns_early() {
        fn f(x: u32) -> Result<u32> {
            if x == 0 {
                bail!("zero not allowed");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert_eq!(f(0).unwrap_err().to_string(), "zero not allowed");
    }

    #[test]
    fn alternate_format_matches_plain() {
        let e = anyhow!("oops");
        assert_eq!(format!("{e:#}"), format!("{e}"));
    }
}
