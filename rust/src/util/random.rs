//! Worker-stream random numbers — the paper's `blaze::random::uniform()`.
//!
//! The paper's π mapper notes "Random function in std is not thread safe"
//! and calls Blaze's own `random::uniform()`, which is thread-local. Here
//! the engines publish the *current worker stream* (derived from `(seed,
//! node, worker)`) before running a worker's items; mappers just call
//! [`uniform`]. Deterministic: the same sample always sees the same stream
//! position regardless of engine or cluster shape, which is what lets the
//! Table-1 test assert bit-identical π against the hand-written loop.

use std::cell::Cell;

use super::rng::SplitRng;

thread_local! {
    // xoshiro state of the active worker stream (Cell<[u64;4]> copies are
    // 32 bytes — cheaper than RefCell book-keeping on the hot path).
    static STATE: Cell<[u64; 4]> = const { Cell::new([0; 4]) };
}

/// Install the stream for `(seed, stream_id)` as the active one.
/// Engines call this whenever the executing worker changes.
pub fn set_stream(seed: u64, stream_id: u64) {
    let rng = SplitRng::new(seed, stream_id);
    STATE.with(|s| s.set(rng.state()));
}

/// Uniform f64 in [0, 1) from the active worker stream.
#[inline]
pub fn uniform() -> f64 {
    STATE.with(|s| {
        let mut rng = SplitRng::from_state(s.get());
        let v = rng.uniform();
        s.set(rng.state());
        v
    })
}

/// Two uniforms in [0, 1) with a single stream-state access — the 2-D
/// sampling fast path (Monte-Carlo π draws pairs).
#[inline]
pub fn uniform2() -> (f64, f64) {
    STATE.with(|s| {
        let mut rng = SplitRng::from_state(s.get());
        let a = rng.uniform();
        let b = rng.uniform();
        s.set(rng.state());
        (a, b)
    })
}

/// Raw u64 from the active worker stream.
#[inline]
pub fn next_u64() -> u64 {
    STATE.with(|s| {
        let mut rng = SplitRng::from_state(s.get());
        let v = rng.next_u64();
        s.set(rng.state());
        v
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stream_matches_splitrng() {
        set_stream(42, 7);
        let mut reference = SplitRng::new(42, 7);
        for _ in 0..100 {
            assert_eq!(next_u64(), reference.next_u64());
        }
    }

    #[test]
    fn set_stream_resets_position() {
        set_stream(1, 0);
        let a = uniform();
        set_stream(1, 0);
        let b = uniform();
        assert_eq!(a, b);
    }
}
