//! Cognitive-load accounting (paper §3.4, Fig. 10).
//!
//! The paper measures "cognitive load" as the number of *distinct parallel
//! APIs* a user must know to implement each task: Blaze needs `mapreduce`
//! plus ≤5 utilities, Spark's official implementations use ~30 distinct
//! primitives. We reproduce the figure by statically counting distinct
//! Blaze-API identifiers in our own app sources and comparing against the
//! Spark primitive inventory recorded from the paper's referenced
//! implementations (Spark core / MLlib / GraphX).

/// The complete user-facing Blaze API surface (what `prelude` exports).
pub const BLAZE_API: &[&str] = &[
    "mapreduce",
    "mapreduce_range",
    "distribute",
    "collect",
    "load_file",
    "topk",
    "foreach",
];

/// Distinct Spark parallel primitives used by the official implementations
/// of the five tasks (inventoried from the paper's referenced Spark 2.4
/// sources: core RDD ops + MLlib KMeans/GaussianMixture + GraphX PageRank).
pub const SPARK_PRIMITIVES: &[(&str, &[&str])] = &[
    (
        "wordcount",
        &["textFile", "flatMap", "map", "reduceByKey", "collect"],
    ),
    (
        "pagerank",
        &[
            "GraphLoader.edgeListFile",
            "Graph.outerJoinVertices",
            "aggregateMessages",
            "mapVertices",
            "joinVertices",
            "Pregel",
            "mapReduceTriplets",
            "vertices.map",
            "cache",
        ],
    ),
    (
        "kmeans",
        &[
            "map",
            "mapPartitions",
            "aggregate",
            "treeAggregate",
            "broadcast",
            "persist",
            "takeSample",
            "zip",
            "count",
        ],
    ),
    (
        "gmm",
        &[
            "treeAggregate",
            "broadcast",
            "map",
            "aggregate",
            "sample",
            "persist",
            "mapPartitions",
        ],
    ),
    (
        "knn",
        &["map", "takeOrdered", "parallelize"],
    ),
];

/// Count distinct Blaze-API identifiers appearing in `source`.
pub fn count_blaze_apis(source: &str) -> usize {
    BLAZE_API
        .iter()
        .filter(|api| {
            source
                .match_indices(*api)
                .any(|(i, _)| is_call_site(source, i, api))
        })
        .count()
}

/// Distinct Blaze APIs used, by name.
pub fn blaze_apis_used(source: &str) -> Vec<&'static str> {
    BLAZE_API
        .iter()
        .copied()
        .filter(|api| {
            source
                .match_indices(*api)
                .any(|(i, _)| is_call_site(source, i, api))
        })
        .collect()
}

// A match is a call site if not embedded in a longer identifier.
fn is_call_site(source: &str, at: usize, api: &str) -> bool {
    let before_ok = at == 0
        || !source.as_bytes()[at - 1].is_ascii_alphanumeric() && source.as_bytes()[at - 1] != b'_';
    let end = at + api.len();
    let after_ok = end >= source.len()
        || (!source.as_bytes()[end].is_ascii_alphanumeric() && source.as_bytes()[end] != b'_');
    before_ok && after_ok
}

/// Total distinct Spark primitives across all five tasks.
pub fn spark_distinct_total() -> usize {
    let mut set: std::collections::HashSet<&str> = std::collections::HashSet::new();
    for (_, prims) in SPARK_PRIMITIVES {
        set.extend(prims.iter());
    }
    set.len()
}

/// Distinct Spark primitives for one task.
pub fn spark_distinct_for(task: &str) -> usize {
    SPARK_PRIMITIVES
        .iter()
        .find(|(name, _)| *name == task)
        .map(|(_, prims)| prims.len())
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_call_sites_not_substrings() {
        let src = "blaze::mapreduce(&v, m, Reducer::Sum, &mut t); let mapreduce_count = 1;";
        // `mapreduce` appears as a call; `mapreduce_count` must not count as
        // a second API, and `mapreduce_range` is absent.
        assert_eq!(blaze_apis_used(src), vec!["mapreduce"]);
    }

    #[test]
    fn spark_totals_match_paper_scale() {
        // Paper: "almost 30 different parallel primitives".
        let total: usize = SPARK_PRIMITIVES.iter().map(|(_, p)| p.len()).sum();
        assert!(total >= 25 && total <= 40, "total {total}");
        assert!(spark_distinct_total() >= 20);
    }

    #[test]
    fn blaze_surface_is_small() {
        // Paper: MapReduce + ≤5 utility functions.
        assert!(BLAZE_API.len() <= 8);
    }

    #[test]
    fn per_task_lookup() {
        assert_eq!(spark_distinct_for("wordcount"), 5);
        assert_eq!(spark_distinct_for("nope"), 0);
    }
}
