//! Small dense linear algebra for the GMM M-step.
//!
//! The GMM covariances are tiny (`D ≤ 16`), so the coordinator inverts them
//! with an in-tree Cholesky instead of shipping a LAPACK dependency (the
//! AOT graphs take precisions as *inputs* — `jnp.linalg.inv` would lower to
//! a LAPACK custom-call the rust PJRT CPU client cannot run).

/// Cholesky factor `L` (lower-triangular, row-major) of SPD `a` (`d × d`).
/// Returns `None` if `a` is not positive-definite.
pub fn cholesky(a: &[f64], d: usize) -> Option<Vec<f64>> {
    assert_eq!(a.len(), d * d);
    let mut l = vec![0.0f64; d * d];
    for i in 0..d {
        for j in 0..=i {
            let mut sum = a[i * d + j];
            for k in 0..j {
                sum -= l[i * d + k] * l[j * d + k];
            }
            if i == j {
                if sum <= 0.0 {
                    return None;
                }
                l[i * d + i] = sum.sqrt();
            } else {
                l[i * d + j] = sum / l[j * d + j];
            }
        }
    }
    Some(l)
}

/// `log |A|` from a Cholesky factor: `2 Σ log L_ii`.
pub fn logdet_from_cholesky(l: &[f64], d: usize) -> f64 {
    (0..d).map(|i| l[i * d + i].ln()).sum::<f64>() * 2.0
}

/// Inverse of SPD `a` via Cholesky: solve `L Lᵀ X = I` column by column.
/// Returns `None` if not positive-definite.
pub fn spd_inverse(a: &[f64], d: usize) -> Option<Vec<f64>> {
    let l = cholesky(a, d)?;
    let mut inv = vec![0.0f64; d * d];
    for col in 0..d {
        // Forward solve L y = e_col.
        let mut y = vec![0.0f64; d];
        for i in 0..d {
            let mut sum = if i == col { 1.0 } else { 0.0 };
            for k in 0..i {
                sum -= l[i * d + k] * y[k];
            }
            y[i] = sum / l[i * d + i];
        }
        // Back solve Lᵀ x = y.
        for i in (0..d).rev() {
            let mut sum = y[i];
            for k in i + 1..d {
                sum -= l[k * d + i] * inv[k * d + col];
            }
            inv[i * d + col] = sum / l[i * d + i];
        }
    }
    Some(inv)
}

/// `a @ b` for row-major `(n × m) @ (m × p)`.
pub fn matmul(a: &[f64], b: &[f64], n: usize, m: usize, p: usize) -> Vec<f64> {
    let mut out = vec![0.0f64; n * p];
    for i in 0..n {
        for k in 0..m {
            let aik = a[i * m + k];
            for j in 0..p {
                out[i * p + j] += aik * b[k * p + j];
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn approx(a: &[f64], b: &[f64], tol: f64) {
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(b) {
            assert!((x - y).abs() < tol, "{x} vs {y}");
        }
    }

    #[test]
    fn cholesky_of_identity() {
        let eye = vec![1.0, 0.0, 0.0, 1.0];
        let l = cholesky(&eye, 2).unwrap();
        approx(&l, &eye, 1e-12);
        assert_eq!(logdet_from_cholesky(&l, 2), 0.0);
    }

    #[test]
    fn inverse_times_original_is_identity() {
        // SPD: A = M Mᵀ + I.
        let m = [1.0, 2.0, 0.5, 3.0, -1.0, 0.25, 0.0, 1.0, 2.0];
        let d = 3;
        let mut a = vec![0.0; 9];
        for i in 0..d {
            for j in 0..d {
                for k in 0..d {
                    a[i * d + j] += m[i * d + k] * m[j * d + k];
                }
            }
            a[i * d + i] += 1.0;
        }
        let inv = spd_inverse(&a, d).unwrap();
        let prod = matmul(&a, &inv, d, d, d);
        let eye: Vec<f64> =
            (0..9).map(|i| if i % 4 == 0 { 1.0 } else { 0.0 }).collect();
        approx(&prod, &eye, 1e-9);
    }

    #[test]
    fn logdet_matches_2x2_closed_form() {
        let a = vec![4.0, 1.0, 1.0, 3.0]; // det = 11
        let l = cholesky(&a, 2).unwrap();
        assert!((logdet_from_cholesky(&l, 2) - 11.0f64.ln()).abs() < 1e-12);
    }

    #[test]
    fn non_spd_rejected() {
        let a = vec![1.0, 2.0, 2.0, 1.0]; // indefinite
        assert!(cholesky(&a, 2).is_none());
        assert!(spd_inverse(&a, 2).is_none());
    }
}
