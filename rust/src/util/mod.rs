//! Utilities: deterministic RNG, bounded top-k, allocator pools,
//! cognitive-load accounting.

pub mod alloc;
pub mod cognitive;
pub mod error;
pub mod hash;
pub mod linalg;
pub mod random;
pub mod rng;
pub mod topk;

pub use hash::{fxhash, FxHashMap};
pub use rng::SplitRng;
pub use topk::TopK;
