//! Bounded top-k selection (paper §2.1, `DistVector::topk`).
//!
//! Keeps the best `k` of a stream in `O(n + k log k)` time and `O(k)` space:
//! a bounded binary heap ordered so the *worst* retained element sits at the
//! root and is evicted first. A custom comparator defines priority, exactly
//! like the paper's custom comparison function for 100-NN.

/// Bounded top-k accumulator over a custom ordering.
///
/// `cmp(a, b) == Ordering::Greater` means `a` has higher priority (is
/// "better") and will be kept over `b`.
pub struct TopK<T, F>
where
    F: Fn(&T, &T) -> std::cmp::Ordering,
{
    k: usize,
    cmp: F,
    // Min-heap on priority: root = worst of the retained elements.
    heap: Vec<T>,
}

impl<T, F> TopK<T, F>
where
    F: Fn(&T, &T) -> std::cmp::Ordering,
{
    /// New accumulator retaining the best `k` elements under `cmp`.
    pub fn new(k: usize, cmp: F) -> Self {
        Self { k, cmp, heap: Vec::with_capacity(k.min(1 << 20)) }
    }

    /// Number currently retained (≤ k).
    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// True if nothing retained.
    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Offer one element; drops it immediately if it can't beat the current
    /// worst (the `O(1)` fast path that makes the whole pass `O(n)`).
    #[inline]
    pub fn push(&mut self, item: T) {
        if self.k == 0 {
            return;
        }
        if self.heap.len() < self.k {
            self.heap.push(item);
            self.sift_up(self.heap.len() - 1);
        } else if (self.cmp)(&item, &self.heap[0]) == std::cmp::Ordering::Greater {
            self.heap[0] = item;
            self.sift_down(0);
        }
    }

    /// Merge another accumulator into this one (tree reduce across nodes).
    pub fn merge(&mut self, other: TopK<T, F>) {
        for item in other.heap {
            self.push(item);
        }
    }

    /// Consume and return the retained elements sorted best-first
    /// (`O(k log k)`).
    pub fn into_sorted(self) -> Vec<T> {
        let cmp = self.cmp;
        let mut v = self.heap;
        v.sort_by(|a, b| cmp(b, a));
        v
    }

    #[inline]
    fn worse(&self, a: &T, b: &T) -> bool {
        (self.cmp)(a, b) == std::cmp::Ordering::Less
    }

    fn sift_up(&mut self, mut i: usize) {
        while i > 0 {
            let parent = (i - 1) / 2;
            if self.worse(&self.heap[i], &self.heap[parent]) {
                self.heap.swap(i, parent);
                i = parent;
            } else {
                break;
            }
        }
    }

    fn sift_down(&mut self, mut i: usize) {
        let n = self.heap.len();
        loop {
            let (l, r) = (2 * i + 1, 2 * i + 2);
            let mut worst = i;
            if l < n && self.worse(&self.heap[l], &self.heap[worst]) {
                worst = l;
            }
            if r < n && self.worse(&self.heap[r], &self.heap[worst]) {
                worst = r;
            }
            if worst == i {
                return;
            }
            self.heap.swap(i, worst);
            i = worst;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::SplitRng;

    fn desc(a: &u64, b: &u64) -> std::cmp::Ordering {
        a.cmp(b) // Greater = better → keeps the largest k.
    }

    #[test]
    fn keeps_largest_k() {
        let mut t = TopK::new(3, desc);
        for v in [5u64, 1, 9, 3, 7, 2, 8] {
            t.push(v);
        }
        assert_eq!(t.into_sorted(), vec![9, 8, 7]);
    }

    #[test]
    fn fewer_than_k() {
        let mut t = TopK::new(10, desc);
        t.push(2);
        t.push(1);
        assert_eq!(t.into_sorted(), vec![2, 1]);
    }

    #[test]
    fn k_zero_is_empty() {
        let mut t = TopK::new(0, desc);
        t.push(1);
        assert!(t.is_empty());
    }

    #[test]
    fn custom_comparator_keeps_smallest() {
        // Reverse priority: smaller is better (k-NN by distance).
        let mut t = TopK::new(2, |a: &u64, b: &u64| b.cmp(a));
        for v in [5u64, 1, 9, 3] {
            t.push(v);
        }
        assert_eq!(t.into_sorted(), vec![1, 3]);
    }

    #[test]
    fn merge_matches_single_pass() {
        let mut rng = SplitRng::new(3, 0);
        let data: Vec<u64> = (0..10_000).map(|_| rng.next_u64() % 1_000_000).collect();
        let mut whole = TopK::new(100, desc);
        for &v in &data {
            whole.push(v);
        }
        // Split into 4 "nodes", then tree-merge.
        let mut parts: Vec<TopK<u64, _>> =
            (0..4).map(|_| TopK::new(100, desc)).collect();
        for (i, &v) in data.iter().enumerate() {
            parts[i % 4].push(v);
        }
        let mut merged = parts.pop().unwrap();
        for p in parts {
            merged.merge(p);
        }
        assert_eq!(merged.into_sorted(), whole.into_sorted());
    }

    #[test]
    fn against_full_sort_oracle() {
        let mut rng = SplitRng::new(7, 1);
        for k in [1usize, 5, 50] {
            let data: Vec<u64> = (0..500).map(|_| rng.next_u64() % 1000).collect();
            let mut t = TopK::new(k, desc);
            for &v in &data {
                t.push(v);
            }
            let mut oracle = data.clone();
            oracle.sort_unstable_by(|a, b| b.cmp(a));
            oracle.truncate(k);
            assert_eq!(t.into_sorted(), oracle, "k={k}");
        }
    }
}
