//! Deterministic splittable RNG.
//!
//! The paper's π mapper notes "Random function in std is not thread safe";
//! Blaze exposes `blaze::random::uniform()` backed by thread-local state. We
//! reproduce that with an explicit splittable generator: every virtual
//! worker derives an independent stream from `(seed, node, worker)` via
//! SplitMix64, then iterates xoshiro256++. Deterministic across runs and
//! across cluster shapes, which the reproduction harness relies on.

/// xoshiro256++ seeded through SplitMix64.
#[derive(Debug, Clone)]
pub struct SplitRng {
    s: [u64; 4],
}

impl SplitRng {
    /// Stream for a `(seed, stream_id)` pair; distinct ids give
    /// statistically independent streams.
    pub fn new(seed: u64, stream_id: u64) -> Self {
        // SplitMix64 over seed ^ golden-ratio-scrambled stream id.
        let mut x = seed ^ stream_id.wrapping_mul(0x9e37_79b9_7f4a_7c15);
        let mut next = || {
            x = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
            z ^ (z >> 31)
        };
        let s = [next(), next(), next(), next()];
        Self { s }
    }

    /// Stream for a `(seed, node, worker)` triple — one per virtual worker.
    pub fn for_worker(seed: u64, node: usize, worker: usize) -> Self {
        Self::new(seed, ((node as u64) << 20) | worker as u64)
    }

    /// Raw xoshiro state (for the thread-local stream cache).
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Rebuild from raw state.
    #[inline]
    pub fn from_state(s: [u64; 4]) -> Self {
        Self { s }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = (self.s[0].wrapping_add(self.s[3]))
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1) with 53 bits of precision.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform u64 in [0, n) (Lemire rejection-free multiply-shift; tiny
    /// bias below 2^-64 is irrelevant for workload generation).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        ((u128::from(self.next_u64()) * u128::from(n)) >> 64) as u64
    }

    /// Standard normal via Box–Muller (one value per call, cheap enough for
    /// data generation).
    #[inline]
    pub fn normal(&mut self) -> f64 {
        let u1 = self.uniform().max(f64::MIN_POSITIVE);
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = SplitRng::new(42, 7);
        let mut b = SplitRng::new(42, 7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn distinct_streams_differ() {
        let mut a = SplitRng::new(42, 0);
        let mut b = SplitRng::new(42, 1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn uniform_in_unit_interval_and_roughly_uniform() {
        let mut r = SplitRng::new(1, 0);
        let n = 100_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let v = r.uniform();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn below_bounds() {
        let mut r = SplitRng::new(9, 3);
        for _ in 0..10_000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = SplitRng::new(5, 0);
        let n = 200_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let v = r.normal();
            s += v;
            s2 += v * v;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }
}
