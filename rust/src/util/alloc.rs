//! Pool allocator toggle — the paper's "Blaze TCM" (TCMalloc) analogue.
//!
//! The paper links Blaze against TCMalloc and finds throughput ≈unchanged
//! but variance lower (and one case with 40% more memory). TCMalloc's win is
//! thread-caching of small allocations; the Blaze hot path allocates pair
//! buffers and serialization scratch. We reproduce the *mechanism* with a
//! worker-local slab pool for the engines' scratch buffers: `AllocMode::Pool`
//! recycles buffers through a size-classed free list, `AllocMode::System`
//! hits the global allocator every time. The Fig-4..9 benches run both.
//!
//! The pool is generic over the element type so the threaded engines can
//! recycle typed flush-batch buffers (`Vec<(K, V)>`, `Vec<u64>` hash lanes)
//! with the same mechanism as the byte scratch used by serialization and
//! transport. Size classes are measured in *elements*; [`BufferPool::pooled_bytes`]
//! converts to bytes for the `alloc.pool.pooled_bytes` counter.

use std::cell::{Cell, RefCell};

/// Allocation strategy for engine scratch buffers.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum AllocMode {
    /// Global system allocator on every buffer (paper's plain "Blaze").
    #[default]
    System,
    /// Worker-local size-classed slab pool (paper's "Blaze TCM").
    Pool,
}

impl std::fmt::Display for AllocMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AllocMode::System => write!(f, "blaze"),
            AllocMode::Pool => write!(f, "blaze-tcm"),
        }
    }
}

/// Size classes: powers of two from 64 to 1 Mi elements.
const MIN_CLASS_SHIFT: u32 = 6; // 64
const MAX_CLASS_SHIFT: u32 = 20; // 1 Mi
const N_CLASSES: usize = (MAX_CLASS_SHIFT - MIN_CLASS_SHIFT + 1) as usize;

/// Max buffers parked per size class; beyond this, returns are dropped.
const CLASS_DEPTH: usize = 64;

/// Worker-local buffer pool (thread-caching malloc analogue).
///
/// Not a global allocator: the engines route their scratch `Vec`s through
/// this explicitly so both modes are measurable under identical workloads.
/// Single-threaded by design (`RefCell`); each pool worker owns its own
/// instance, mirroring TCMalloc's thread caches.
pub struct BufferPool<T = u8> {
    classes: RefCell<[Vec<Vec<T>>; N_CLASSES]>,
    hits: Cell<u64>,
    misses: Cell<u64>,
}

impl<T> Default for BufferPool<T> {
    fn default() -> Self {
        Self {
            classes: RefCell::new(std::array::from_fn(|_| Vec::new())),
            hits: Cell::new(0),
            misses: Cell::new(0),
        }
    }
}

impl<T> BufferPool<T> {
    /// Empty pool.
    pub fn new() -> Self {
        Self::default()
    }

    fn class_for(cap: usize) -> usize {
        let cap = cap.max(1 << MIN_CLASS_SHIFT);
        let shift = usize::BITS - (cap - 1).leading_zeros(); // ceil log2
        (shift.clamp(MIN_CLASS_SHIFT, MAX_CLASS_SHIFT) - MIN_CLASS_SHIFT) as usize
    }

    /// Get a cleared buffer with at least `cap` capacity (in elements).
    pub fn get(&self, cap: usize) -> Vec<T> {
        if cap > 1 << MAX_CLASS_SHIFT {
            self.misses.set(self.misses.get() + 1);
            return Vec::with_capacity(cap);
        }
        let class = Self::class_for(cap);
        if let Some(buf) = self.classes.borrow_mut()[class].pop() {
            self.hits.set(self.hits.get() + 1);
            buf
        } else {
            self.misses.set(self.misses.get() + 1);
            Vec::with_capacity(1 << (class as u32 + MIN_CLASS_SHIFT))
        }
    }

    /// Return a buffer to the pool for reuse.
    ///
    /// Contents are dropped immediately (`clear`), and non-power-of-two
    /// capacities are normalized up to the next class boundary before
    /// parking. Without the normalization a capacity-100 buffer parks in
    /// the 64-element class, where a `get(100)` (which rounds *up* to the
    /// 128 class) can never find it — the buffer strands in the pool and
    /// every matching request misses.
    pub fn put(&self, mut buf: Vec<T>) {
        buf.clear();
        let cap = buf.capacity();
        if cap < 1 << MIN_CLASS_SHIFT || cap > 1 << MAX_CLASS_SHIFT {
            return; // outside pooled classes; let it drop
        }
        if !cap.is_power_of_two() {
            // len == 0, so this requests exactly next_power_of_two(cap).
            buf.reserve_exact(cap.next_power_of_two());
        }
        let cap = buf.capacity();
        if cap > 1 << MAX_CLASS_SHIFT {
            return;
        }
        // A buffer of capacity c serves class floor(log2 c) requests.
        let shift = usize::BITS - 1 - cap.leading_zeros(); // floor log2
        let class = (shift.min(MAX_CLASS_SHIFT) - MIN_CLASS_SHIFT) as usize;
        let mut classes = self.classes.borrow_mut();
        if classes[class].len() < CLASS_DEPTH {
            classes[class].push(buf);
        }
    }

    /// (hits, misses) counters — used by the allocator ablation bench.
    pub fn stats(&self) -> (u64, u64) {
        (self.hits.get(), self.misses.get())
    }

    /// Bytes currently parked in the pool.
    pub fn pooled_bytes(&self) -> usize {
        self.classes
            .borrow()
            .iter()
            .flat_map(|c| c.iter().map(Vec::capacity))
            .sum::<usize>()
            * std::mem::size_of::<T>()
    }
}

/// Scratch-buffer source honouring an [`AllocMode`].
pub struct Scratch<'a, T = u8> {
    mode: AllocMode,
    pool: &'a BufferPool<T>,
}

impl<'a, T> Scratch<'a, T> {
    /// Scratch source over `pool` in `mode`.
    pub fn new(mode: AllocMode, pool: &'a BufferPool<T>) -> Self {
        Self { mode, pool }
    }

    /// Acquire a buffer of at least `cap` elements.
    pub fn get(&self, cap: usize) -> Vec<T> {
        match self.mode {
            AllocMode::System => Vec::with_capacity(cap),
            AllocMode::Pool => self.pool.get(cap),
        }
    }

    /// Release a buffer (no-op under `System`).
    pub fn put(&self, buf: Vec<T>) {
        if self.mode == AllocMode::Pool {
            self.pool.put(buf);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pool_reuses_buffers() {
        let pool: BufferPool = BufferPool::new();
        let b = pool.get(100);
        let cap = b.capacity();
        assert!(cap >= 100);
        pool.put(b);
        let b2 = pool.get(100);
        assert_eq!(b2.capacity(), cap, "second get should reuse");
        let (hits, misses) = pool.stats();
        assert_eq!((hits, misses), (1, 1));
    }

    #[test]
    fn class_rounding() {
        assert_eq!(BufferPool::<u8>::class_for(1), 0);
        assert_eq!(BufferPool::<u8>::class_for(64), 0);
        assert_eq!(BufferPool::<u8>::class_for(65), 1);
        assert_eq!(BufferPool::<u8>::class_for(128), 1);
        assert_eq!(BufferPool::<u8>::class_for(1 << 20), N_CLASSES - 1);
    }

    #[test]
    fn oversized_bypasses_pool() {
        let pool: BufferPool = BufferPool::new();
        let b = pool.get((1 << 20) + 1);
        assert!(b.capacity() > 1 << 20);
        pool.put(b);
        assert_eq!(pool.pooled_bytes(), 0);
    }

    #[test]
    fn returned_buffer_serves_smaller_class() {
        let pool: BufferPool = BufferPool::new();
        // Capacity 256 buffer parked in class floor(log2 256)=8 → class 2.
        pool.put(Vec::with_capacity(256));
        let b = pool.get(200); // class_for(200)=ceil → 256 → class 2
        assert!(b.capacity() >= 200);
        assert_eq!(pool.stats().0, 1);
    }

    #[test]
    fn odd_capacity_put_is_findable_again() {
        // Regression: a capacity-100 buffer used to park in the 64 class
        // (floor log2), where get(100) — which rounds up to the 128 class —
        // could never find it. put now normalizes to the next power of two.
        let pool: BufferPool = BufferPool::new();
        pool.put(Vec::with_capacity(100));
        assert!(pool.pooled_bytes() >= 128, "normalized up to a full class");
        let b = pool.get(100);
        assert!(b.capacity() >= 100);
        assert_eq!(pool.stats(), (1, 0), "round-trip must be a pool hit");
    }

    #[test]
    fn put_clears_contents() {
        let pool: BufferPool<u64> = BufferPool::new();
        let mut b = pool.get(64);
        b.extend(0..10u64);
        pool.put(b);
        let b2 = pool.get(64);
        assert!(b2.is_empty(), "pooled buffers come back cleared");
    }

    #[test]
    fn typed_pool_counts_bytes_not_elements() {
        let pool: BufferPool<u64> = BufferPool::new();
        pool.put(Vec::with_capacity(64));
        assert_eq!(pool.pooled_bytes(), 64 * std::mem::size_of::<u64>());
    }

    #[test]
    fn system_mode_never_pools() {
        let pool: BufferPool = BufferPool::new();
        let scratch = Scratch::new(AllocMode::System, &pool);
        let b = scratch.get(128);
        scratch.put(b);
        assert_eq!(pool.pooled_bytes(), 0);
    }
}
