//! Pool allocator toggle — the paper's "Blaze TCM" (TCMalloc) analogue.
//!
//! The paper links Blaze against TCMalloc and finds throughput ≈unchanged
//! but variance lower (and one case with 40% more memory). TCMalloc's win is
//! thread-caching of small allocations; the Blaze hot path allocates pair
//! buffers and serialization scratch. We reproduce the *mechanism* with a
//! worker-local slab pool for the engines' scratch buffers: `AllocMode::Pool`
//! recycles buffers through a size-classed free list, `AllocMode::System`
//! hits the global allocator every time. The Fig-4..9 benches run both.

use std::cell::RefCell;

/// Allocation strategy for engine scratch buffers.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum AllocMode {
    /// Global system allocator on every buffer (paper's plain "Blaze").
    #[default]
    System,
    /// Worker-local size-classed slab pool (paper's "Blaze TCM").
    Pool,
}

impl std::fmt::Display for AllocMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AllocMode::System => write!(f, "blaze"),
            AllocMode::Pool => write!(f, "blaze-tcm"),
        }
    }
}

/// Size classes: powers of two from 64 B to 1 MiB.
const MIN_CLASS_SHIFT: u32 = 6; // 64 B
const MAX_CLASS_SHIFT: u32 = 20; // 1 MiB
const N_CLASSES: usize = (MAX_CLASS_SHIFT - MIN_CLASS_SHIFT + 1) as usize;

/// Worker-local buffer pool (thread-caching malloc analogue).
///
/// Not a global allocator: the engines route their `Vec<u8>` scratch through
/// this explicitly so both modes are measurable under identical workloads.
#[derive(Default)]
pub struct BufferPool {
    classes: RefCell<[Vec<Vec<u8>>; N_CLASSES]>,
    hits: RefCell<u64>,
    misses: RefCell<u64>,
}

impl BufferPool {
    /// Empty pool.
    pub fn new() -> Self {
        Self::default()
    }

    fn class_for(cap: usize) -> usize {
        let cap = cap.max(1 << MIN_CLASS_SHIFT);
        let shift = usize::BITS - (cap - 1).leading_zeros(); // ceil log2
        (shift.clamp(MIN_CLASS_SHIFT, MAX_CLASS_SHIFT) - MIN_CLASS_SHIFT) as usize
    }

    /// Get a cleared buffer with at least `cap` capacity.
    pub fn get(&self, cap: usize) -> Vec<u8> {
        if cap > 1 << MAX_CLASS_SHIFT {
            *self.misses.borrow_mut() += 1;
            return Vec::with_capacity(cap);
        }
        let class = Self::class_for(cap);
        if let Some(mut buf) = self.classes.borrow_mut()[class].pop() {
            buf.clear();
            *self.hits.borrow_mut() += 1;
            buf
        } else {
            *self.misses.borrow_mut() += 1;
            Vec::with_capacity(1 << (class as u32 + MIN_CLASS_SHIFT))
        }
    }

    /// Return a buffer to the pool for reuse.
    pub fn put(&self, buf: Vec<u8>) {
        let cap = buf.capacity();
        if cap == 0 || cap > 1 << MAX_CLASS_SHIFT {
            return; // outside pooled classes; let it drop
        }
        // A buffer of capacity c serves class floor(log2 c) requests.
        let shift = usize::BITS - 1 - cap.leading_zeros(); // floor log2
        if shift < MIN_CLASS_SHIFT {
            return;
        }
        let class = (shift.min(MAX_CLASS_SHIFT) - MIN_CLASS_SHIFT) as usize;
        let mut classes = self.classes.borrow_mut();
        if classes[class].len() < 64 {
            classes[class].push(buf);
        }
    }

    /// (hits, misses) counters — used by the allocator ablation bench.
    pub fn stats(&self) -> (u64, u64) {
        (*self.hits.borrow(), *self.misses.borrow())
    }

    /// Bytes currently parked in the pool.
    pub fn pooled_bytes(&self) -> usize {
        self.classes
            .borrow()
            .iter()
            .flat_map(|c| c.iter().map(Vec::capacity))
            .sum()
    }
}

/// Scratch-buffer source honouring an [`AllocMode`].
pub struct Scratch<'a> {
    mode: AllocMode,
    pool: &'a BufferPool,
}

impl<'a> Scratch<'a> {
    /// Scratch source over `pool` in `mode`.
    pub fn new(mode: AllocMode, pool: &'a BufferPool) -> Self {
        Self { mode, pool }
    }

    /// Acquire a buffer of at least `cap` bytes.
    pub fn get(&self, cap: usize) -> Vec<u8> {
        match self.mode {
            AllocMode::System => Vec::with_capacity(cap),
            AllocMode::Pool => self.pool.get(cap),
        }
    }

    /// Release a buffer (no-op under `System`).
    pub fn put(&self, buf: Vec<u8>) {
        if self.mode == AllocMode::Pool {
            self.pool.put(buf);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pool_reuses_buffers() {
        let pool = BufferPool::new();
        let b = pool.get(100);
        let cap = b.capacity();
        assert!(cap >= 100);
        pool.put(b);
        let b2 = pool.get(100);
        assert_eq!(b2.capacity(), cap, "second get should reuse");
        let (hits, misses) = pool.stats();
        assert_eq!((hits, misses), (1, 1));
    }

    #[test]
    fn class_rounding() {
        assert_eq!(BufferPool::class_for(1), 0);
        assert_eq!(BufferPool::class_for(64), 0);
        assert_eq!(BufferPool::class_for(65), 1);
        assert_eq!(BufferPool::class_for(128), 1);
        assert_eq!(BufferPool::class_for(1 << 20), N_CLASSES - 1);
    }

    #[test]
    fn oversized_bypasses_pool() {
        let pool = BufferPool::new();
        let b = pool.get((1 << 20) + 1);
        assert!(b.capacity() > 1 << 20);
        pool.put(b);
        assert_eq!(pool.pooled_bytes(), 0);
    }

    #[test]
    fn returned_buffer_serves_smaller_class() {
        let pool = BufferPool::new();
        // Capacity 256 buffer parked in class floor(log2 256)=8 → class 2.
        pool.put(Vec::with_capacity(256));
        let b = pool.get(200); // class_for(200)=ceil → 256 → class 2
        assert!(b.capacity() >= 200);
        assert_eq!(pool.stats().0, 1);
    }

    #[test]
    fn system_mode_never_pools() {
        let pool = BufferPool::new();
        let scratch = Scratch::new(AllocMode::System, &pool);
        let b = scratch.get(128);
        scratch.put(b);
        assert_eq!(pool.pooled_bytes(), 0);
    }
}
