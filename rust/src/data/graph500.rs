//! Graph500 Kronecker (R-MAT) graph generator (PageRank workload).
//!
//! Same generator family as the paper's input ("we use the graph500
//! generator to generate the input graph which contains 10 million links"):
//! recursive-matrix sampling with the reference parameters A=0.57, B=0.19,
//! C=0.19, D=0.05, which yields the heavy power-law degree skew that
//! stresses the shuffle. Scale and edge factor are knobs.

use crate::util::rng::SplitRng;

/// A directed graph as an edge list plus out-degree index.
#[derive(Debug, Clone)]
pub struct Graph {
    /// Number of vertices (2^scale).
    pub n_vertices: usize,
    /// Directed edges (src, dst).
    pub edges: Vec<(u32, u32)>,
    /// Out-degree per vertex.
    pub out_degree: Vec<u32>,
}

/// Graph500 R-MAT parameters.
const A: f64 = 0.57;
const B: f64 = 0.19;
const C: f64 = 0.19;
// D implied: 0.05

impl Graph {
    /// Generate a Kronecker graph: `2^scale` vertices, `edge_factor *
    /// 2^scale` edges (graph500 default edge factor is 16).
    pub fn graph500(scale: u32, edge_factor: usize, seed: u64) -> Self {
        let n = 1usize << scale;
        let m = edge_factor * n;
        let mut rng = SplitRng::new(seed, 0x64AF4);
        let mut edges = Vec::with_capacity(m);
        for _ in 0..m {
            let (mut src, mut dst) = (0usize, 0usize);
            for level in 0..scale {
                let u = rng.uniform();
                let (si, di) = if u < A {
                    (0, 0)
                } else if u < A + B {
                    (0, 1)
                } else if u < A + B + C {
                    (1, 0)
                } else {
                    (1, 1)
                };
                src |= si << level;
                dst |= di << level;
            }
            edges.push((src as u32, dst as u32));
        }
        let mut out_degree = vec![0u32; n];
        for &(src, _) in &edges {
            out_degree[src as usize] += 1;
        }
        Self { n_vertices: n, edges, out_degree }
    }

    /// Number of edges.
    pub fn n_edges(&self) -> usize {
        self.edges.len()
    }

    /// Vertices with no outbound links ("sinks" — the paper connects them
    /// to every page).
    pub fn sinks(&self) -> Vec<u32> {
        self.out_degree
            .iter()
            .enumerate()
            .filter(|(_, &d)| d == 0)
            .map(|(v, _)| v as u32)
            .collect()
    }

    /// Max out-degree (skew indicator).
    pub fn max_out_degree(&self) -> u32 {
        self.out_degree.iter().copied().max().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sizes_match_scale() {
        let g = Graph::graph500(10, 16, 1);
        assert_eq!(g.n_vertices, 1024);
        assert_eq!(g.n_edges(), 16 * 1024);
        assert_eq!(g.out_degree.len(), 1024);
        let total: u32 = g.out_degree.iter().sum();
        assert_eq!(total as usize, g.n_edges());
    }

    #[test]
    fn deterministic() {
        let a = Graph::graph500(8, 8, 42);
        let b = Graph::graph500(8, 8, 42);
        assert_eq!(a.edges, b.edges);
        let c = Graph::graph500(8, 8, 43);
        assert_ne!(a.edges, c.edges);
    }

    #[test]
    fn degree_distribution_is_skewed() {
        let g = Graph::graph500(12, 16, 7);
        // R-MAT concentrates edges: max out-degree far above the mean (16).
        assert!(
            g.max_out_degree() > 16 * 8,
            "max degree {} not skewed",
            g.max_out_degree()
        );
        // And there must be sinks for the PageRank sink handling to matter.
        assert!(!g.sinks().is_empty(), "expected sink vertices");
    }

    #[test]
    fn edges_in_range() {
        let g = Graph::graph500(6, 4, 3);
        for &(s, d) in &g.edges {
            assert!((s as usize) < g.n_vertices);
            assert!((d as usize) < g.n_vertices);
        }
    }
}
