//! Zipf-distributed English-like corpus generator (word-count workload).
//!
//! The paper word-counts the Bible + Shakespeare repeated 200× (≈0.4 G
//! words). Word-count performance is governed by (a) the word-length
//! distribution (hashing/serialization cost per token) and (b) the key
//! skew (combining effectiveness). Both are preserved by sampling a
//! vocabulary under a Zipf(s≈1.07) law — the classic fit for English text —
//! seeded with real high-frequency English words and padded with
//! morphologically plausible synthetic words.

use crate::util::rng::SplitRng;

/// The most frequent English words, in rank order (head of the Zipf law —
/// these carry most of the token mass, exactly as in the Bible corpus).
const HEAD_WORDS: &[&str] = &[
    "the", "and", "of", "to", "a", "in", "that", "he", "shall", "unto", "for", "i", "his",
    "lord", "they", "be", "is", "him", "not", "them", "it", "with", "all", "thou", "was",
    "god", "which", "my", "me", "said", "but", "ye", "their", "have", "will", "thy", "man",
    "from", "were", "as", "are", "when", "this", "out", "who", "upon", "so", "you", "by",
    "up", "there", "hath", "then", "people", "came", "had", "house", "into", "on", "her",
    "come", "one", "we", "children", "s", "king", "before", "your", "also", "day", "land",
    "men", "israel", "against", "went", "saying", "no", "made", "if", "even", "do", "now",
    "us", "down", "great", "may", "what", "son", "our", "o", "thee", "because", "go", "or",
    "things", "good", "saith", "every", "did", "let",
];

/// Consonant/vowel fragments for synthetic tail words.
const ONSETS: &[&str] = &["b", "br", "c", "ch", "d", "f", "g", "gr", "h", "k", "l", "m", "n",
    "p", "pr", "r", "s", "sh", "st", "t", "th", "tr", "v", "w"];
const NUCLEI: &[&str] = &["a", "e", "i", "o", "u", "ai", "ea", "ou"];
const CODAS: &[&str] = &["", "d", "k", "l", "m", "n", "r", "s", "t", "th", "ng", "st"];

/// Vocabulary with Zipf rank weights.
pub struct Vocabulary {
    words: Vec<String>,
    /// Cumulative Zipf weights for O(log V) sampling.
    cdf: Vec<f64>,
}

impl Vocabulary {
    /// `size` words under Zipf exponent `s` (English ≈ 1.07).
    pub fn new(size: usize, s: f64, seed: u64) -> Self {
        assert!(size > 0);
        let mut rng = SplitRng::new(seed, 0xC0595);
        let mut words: Vec<String> = Vec::with_capacity(size);
        for w in HEAD_WORDS.iter().take(size) {
            words.push((*w).to_string());
        }
        let mut seen: std::collections::HashSet<String> =
            words.iter().cloned().collect();
        while words.len() < size {
            // 1-3 syllables, longer words further down the rank order.
            let syllables = 1 + (rng.below(3)) as usize;
            let mut w = String::new();
            for _ in 0..=syllables {
                w.push_str(ONSETS[rng.below(ONSETS.len() as u64) as usize]);
                w.push_str(NUCLEI[rng.below(NUCLEI.len() as u64) as usize]);
            }
            w.push_str(CODAS[rng.below(CODAS.len() as u64) as usize]);
            if seen.insert(w.clone()) {
                words.push(w);
            }
        }
        // Zipf CDF over ranks.
        let mut cdf = Vec::with_capacity(size);
        let mut acc = 0.0;
        for rank in 1..=size {
            acc += 1.0 / (rank as f64).powf(s);
            cdf.push(acc);
        }
        let total = *cdf.last().unwrap();
        for c in &mut cdf {
            *c /= total;
        }
        Self { words, cdf }
    }

    /// Vocabulary size.
    pub fn len(&self) -> usize {
        self.words.len()
    }

    /// True if empty (never — constructor asserts).
    pub fn is_empty(&self) -> bool {
        self.words.is_empty()
    }

    /// Sample one word (Zipf-distributed rank).
    pub fn sample<'a>(&'a self, rng: &mut SplitRng) -> &'a str {
        let u = rng.uniform();
        let idx = self.cdf.partition_point(|&c| c < u);
        &self.words[idx.min(self.words.len() - 1)]
    }
}

/// Generate `n_lines` lines of `words_per_line` Zipf-sampled words.
pub fn corpus_lines(n_lines: usize, words_per_line: usize, seed: u64) -> Vec<String> {
    let vocab = Vocabulary::new(30_000, 1.07, seed);
    let mut rng = SplitRng::new(seed, 0x11735);
    let mut out = Vec::with_capacity(n_lines);
    for _ in 0..n_lines {
        let mut line = String::with_capacity(words_per_line * 6);
        for i in 0..words_per_line {
            if i > 0 {
                line.push(' ');
            }
            line.push_str(vocab.sample(&mut rng));
        }
        out.push(line);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vocabulary_is_unique_and_sized() {
        let v = Vocabulary::new(5000, 1.07, 1);
        assert_eq!(v.len(), 5000);
        let set: std::collections::HashSet<&String> = v.words.iter().collect();
        assert_eq!(set.len(), 5000, "duplicate words");
    }

    #[test]
    fn zipf_head_dominates() {
        let v = Vocabulary::new(10_000, 1.07, 2);
        let mut rng = SplitRng::new(3, 0);
        let mut counts = std::collections::HashMap::new();
        let n = 200_000;
        for _ in 0..n {
            *counts.entry(v.sample(&mut rng).to_string()).or_insert(0u64) += 1;
        }
        // "the" (rank 1) should be ~7% of tokens under Zipf(1.07)/H(10k).
        let the = counts.get("the").copied().unwrap_or(0) as f64 / n as f64;
        assert!(the > 0.04 && the < 0.18, "P(the)={the}");
        // Top-100 words should carry the majority of the mass.
        let mut freqs: Vec<u64> = counts.values().copied().collect();
        freqs.sort_unstable_by(|a, b| b.cmp(a));
        let top100: u64 = freqs.iter().take(100).sum();
        assert!(top100 as f64 / n as f64 > 0.5, "top100 mass {top100}");
    }

    #[test]
    fn corpus_deterministic() {
        let a = corpus_lines(50, 10, 7);
        let b = corpus_lines(50, 10, 7);
        assert_eq!(a, b);
        let c = corpus_lines(50, 10, 8);
        assert_ne!(a, c);
    }

    #[test]
    fn corpus_shape() {
        let lines = corpus_lines(100, 12, 1);
        assert_eq!(lines.len(), 100);
        for line in &lines {
            assert_eq!(line.split(' ').count(), 12);
        }
    }
}
