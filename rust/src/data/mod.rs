//! Deterministic workload generators for the paper's five tasks.
//!
//! The paper's datasets (Bible+Shakespeare ×200, graph500, random clustered
//! points) are substituted with scale-parameterized generators that preserve
//! the statistical shape the workloads stress — see DESIGN.md
//! §Substitutions. Everything is seeded through [`crate::util::SplitRng`],
//! so every run of every bench sees identical data.

pub mod graph500;
pub mod points;
pub mod text_gen;

pub use graph500::Graph;
pub use points::PointSet;
pub use text_gen::corpus_lines;
