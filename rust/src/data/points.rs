//! Gaussian-cluster point generator (k-means / GMM / k-NN workloads).
//!
//! Matches the paper's setup: "100 million random points around 5
//! clustering centers" — points are sampled from an isotropic Gaussian
//! mixture with configurable cluster count, dimension and spread. Stored
//! flat (`f32`, row-major) so the PJRT kernels consume them zero-copy.

use crate::util::rng::SplitRng;

/// A flat row-major point set with ground-truth centers.
#[derive(Debug, Clone)]
pub struct PointSet {
    /// Point count.
    pub n: usize,
    /// Dimension.
    pub dim: usize,
    /// Row-major coordinates, `n * dim` values.
    pub coords: Vec<f32>,
    /// The generating mixture centers (`k * dim`, row-major).
    pub true_centers: Vec<f32>,
}

impl PointSet {
    /// `n` points in `dim` dimensions around `k` Gaussian centers with
    /// standard deviation `sigma`; centers drawn uniformly in `[-10, 10]^d`.
    pub fn clustered(n: usize, dim: usize, k: usize, sigma: f64, seed: u64) -> Self {
        assert!(k > 0 && dim > 0);
        let mut rng = SplitRng::new(seed, 0x90145);
        let mut true_centers = Vec::with_capacity(k * dim);
        for _ in 0..k * dim {
            true_centers.push((rng.uniform() * 20.0 - 10.0) as f32);
        }
        let mut coords = Vec::with_capacity(n * dim);
        for _ in 0..n {
            let c = rng.below(k as u64) as usize;
            for d in 0..dim {
                let center = f64::from(true_centers[c * dim + d]);
                coords.push((center + sigma * rng.normal()) as f32);
            }
        }
        Self { n, dim, coords, true_centers }
    }

    /// Uniform points in `[0, 1]^dim` (the k-NN workload's "random points").
    pub fn uniform(n: usize, dim: usize, seed: u64) -> Self {
        let mut rng = SplitRng::new(seed, 0xA11CE);
        let coords = (0..n * dim).map(|_| rng.uniform() as f32).collect();
        Self { n, dim, coords, true_centers: Vec::new() }
    }

    /// Point `i` as a slice.
    #[inline]
    pub fn point(&self, i: usize) -> &[f32] {
        &self.coords[i * self.dim..(i + 1) * self.dim]
    }

    /// Squared Euclidean distance between point `i` and an external vector.
    #[inline]
    pub fn dist2(&self, i: usize, other: &[f32]) -> f32 {
        self.point(i)
            .iter()
            .zip(other)
            .map(|(a, b)| {
                let d = a - b;
                d * d
            })
            .sum()
    }

    /// Number of generating clusters (0 for uniform sets).
    pub fn k(&self) -> usize {
        if self.true_centers.is_empty() {
            0
        } else {
            self.true_centers.len() / self.dim
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes() {
        let ps = PointSet::clustered(1000, 3, 5, 0.5, 1);
        assert_eq!(ps.coords.len(), 3000);
        assert_eq!(ps.k(), 5);
        assert_eq!(ps.point(10).len(), 3);
    }

    #[test]
    fn deterministic() {
        let a = PointSet::clustered(100, 2, 3, 1.0, 9);
        let b = PointSet::clustered(100, 2, 3, 1.0, 9);
        assert_eq!(a.coords, b.coords);
    }

    #[test]
    fn points_cluster_near_centers() {
        let sigma = 0.3;
        let ps = PointSet::clustered(2000, 2, 4, sigma, 5);
        // Each point should be within 5 sigma of *some* center.
        let mut far = 0;
        for i in 0..ps.n {
            let min_d2 = (0..ps.k())
                .map(|c| ps.dist2(i, &ps.true_centers[c * 2..(c + 1) * 2]))
                .fold(f32::INFINITY, f32::min);
            if f64::from(min_d2).sqrt() > 5.0 * sigma {
                far += 1;
            }
        }
        assert!(far < ps.n / 100, "{far} points far from all centers");
    }

    #[test]
    fn uniform_in_unit_cube() {
        let ps = PointSet::uniform(500, 4, 2);
        assert!(ps.coords.iter().all(|&v| (0.0..1.0).contains(&v)));
        assert_eq!(ps.k(), 0);
    }

    #[test]
    fn dist2_matches_manual() {
        let ps = PointSet { n: 1, dim: 2, coords: vec![1.0, 2.0], true_centers: vec![] };
        assert_eq!(ps.dist2(0, &[4.0, 6.0]), 25.0);
    }
}
