//! Bench harness support (the offline build has no criterion).
//!
//! Each `benches/*.rs` is a `harness = false` binary that regenerates one
//! of the paper's tables or figures. This module provides the shared
//! measurement loop (warmup + repeated timed runs, mean ± std) and tabular
//! printing so the bench outputs read like the paper's artifacts.

use std::time::Instant;

/// Mean ± standard deviation of repeated measurements.
#[derive(Debug, Clone, Copy)]
pub struct Sample {
    /// Mean of the measurements.
    pub mean: f64,
    /// Sample standard deviation.
    pub std: f64,
    /// Number of measurements.
    pub n: usize,
}

impl std::fmt::Display for Sample {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{:.4} ± {:.4}", self.mean, self.std)
    }
}

/// Summarize raw measurements.
pub fn summarize(xs: &[f64]) -> Sample {
    let n = xs.len();
    let mean = xs.iter().sum::<f64>() / n as f64;
    let var = if n > 1 {
        xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / (n - 1) as f64
    } else {
        0.0
    };
    Sample { mean, std: var.sqrt(), n }
}

/// Run `f` once as warmup (discarded, mirroring the paper's warmup runs),
/// then `reps` timed runs; returns host-wall seconds per run.
pub fn time_host<T>(reps: usize, mut f: impl FnMut() -> T) -> Sample {
    let _ = f(); // warmup
    let mut xs = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        std::hint::black_box(f());
        xs.push(t0.elapsed().as_secs_f64());
    }
    summarize(&xs)
}

/// Repetition count from `BLAZE_BENCH_REPS` (default 3).
pub fn reps() -> usize {
    std::env::var("BLAZE_BENCH_REPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(3)
}

/// Workload scale from `BLAZE_BENCH_SCALE` (default 1).
pub fn scale() -> usize {
    std::env::var("BLAZE_BENCH_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1)
}

/// Node counts to sweep (the paper's x-axis), from `BLAZE_BENCH_NODES`
/// (comma separated) or the default `1,2,4,8,16`.
pub fn node_sweep() -> Vec<usize> {
    std::env::var("BLAZE_BENCH_NODES")
        .ok()
        .map(|s| {
            s.split(',')
                .map(|p| p.trim().parse().expect("node count"))
                .collect()
        })
        .unwrap_or_else(|| vec![1, 2, 4, 8, 16])
}

/// Print a figure header in a recognizable block.
pub fn figure_header(name: &str, paper_claim: &str) {
    println!("==============================================================");
    println!("{name}");
    println!("paper: {paper_claim}");
    println!("==============================================================");
}

/// Human-format bytes.
pub fn fmt_bytes(b: u64) -> String {
    if b >= 1 << 30 {
        format!("{:.2} GiB", b as f64 / (1u64 << 30) as f64)
    } else if b >= 1 << 20 {
        format!("{:.2} MiB", b as f64 / (1u64 << 20) as f64)
    } else if b >= 1 << 10 {
        format!("{:.2} KiB", b as f64 / (1u64 << 10) as f64)
    } else {
        format!("{b} B")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summarize_mean_std() {
        let s = summarize(&[1.0, 2.0, 3.0]);
        assert!((s.mean - 2.0).abs() < 1e-12);
        assert!((s.std - 1.0).abs() < 1e-12);
        assert_eq!(s.n, 3);
    }

    #[test]
    fn fmt_bytes_units() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(2048), "2.00 KiB");
        assert_eq!(fmt_bytes(3 << 20), "3.00 MiB");
    }

    #[test]
    fn time_host_counts_reps() {
        let s = time_host(5, || 1 + 1);
        assert_eq!(s.n, 5);
        assert!(s.mean >= 0.0);
    }
}
