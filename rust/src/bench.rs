//! Bench harness support (the offline build has no criterion).
//!
//! Each `benches/*.rs` is a `harness = false` binary that regenerates one
//! of the paper's tables or figures. This module provides the shared
//! measurement loop (warmup + repeated timed runs, mean ± std) and tabular
//! printing so the bench outputs read like the paper's artifacts.

use std::time::Instant;

/// Mean ± standard deviation plus order statistics of repeated
/// measurements.
#[derive(Debug, Clone, Copy)]
pub struct Sample {
    /// Mean of the measurements.
    pub mean: f64,
    /// Sample standard deviation.
    pub std: f64,
    /// Smallest measurement.
    pub min: f64,
    /// Median (nearest-rank).
    pub p50: f64,
    /// 95th percentile (nearest-rank).
    pub p95: f64,
    /// Number of measurements.
    pub n: usize,
}

impl std::fmt::Display for Sample {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:.4} ± {:.4} [min {:.4} p50 {:.4} p95 {:.4}]",
            self.mean, self.std, self.min, self.p50, self.p95
        )
    }
}

/// Summarize raw measurements. An empty slice (e.g. `BLAZE_BENCH_REPS=0`)
/// yields an all-zero sample rather than NaNs, so reports stay diffable.
pub fn summarize(xs: &[f64]) -> Sample {
    let n = xs.len();
    if n == 0 {
        return Sample { mean: 0.0, std: 0.0, min: 0.0, p50: 0.0, p95: 0.0, n: 0 };
    }
    let mean = xs.iter().sum::<f64>() / n as f64;
    let var = if n > 1 {
        xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / (n - 1) as f64
    } else {
        0.0
    };
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).expect("finite measurements"));
    let rank = |q: f64| sorted[((q * n as f64).ceil() as usize).clamp(1, n) - 1];
    Sample { mean, std: var.sqrt(), min: sorted[0], p50: rank(0.50), p95: rank(0.95), n }
}

/// Run `f` once as warmup (discarded, mirroring the paper's warmup runs),
/// then `reps` timed runs; returns host-wall seconds per run.
pub fn time_host<T>(reps: usize, mut f: impl FnMut() -> T) -> Sample {
    let _ = f(); // warmup
    let mut xs = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        std::hint::black_box(f());
        xs.push(t0.elapsed().as_secs_f64());
    }
    summarize(&xs)
}

/// Execution backend for a bench run: the `--backend <spec>` argv flag
/// (usable after `cargo bench --bench <name> -- --backend threaded:4`)
/// wins, else the `BLAZE_BACKEND` environment variable, else simulated.
pub fn backend() -> crate::coordinator::cluster::Backend {
    use crate::coordinator::cluster::Backend;
    let args: Vec<String> = std::env::args().collect();
    for pair in args.windows(2) {
        if pair[0] == "--backend" {
            return Backend::parse(&pair[1])
                .unwrap_or_else(|e| panic!("--backend: {e}"));
        }
    }
    // A dangling trailing `--backend` would otherwise silently run
    // simulated — the misconfiguration Backend::from_env panics to avoid.
    assert!(
        args.last().map(String::as_str) != Some("--backend"),
        "--backend needs a spec (simulated|threaded[:N])"
    );
    Backend::from_env()
}

/// Trace output path for a bench run: the `--trace PATH` argv flag wins,
/// else the `BLAZE_TRACE` environment variable, else `None` (tracing
/// off). Mirrors [`backend`]'s argv-then-env precedence.
pub fn trace_path() -> Option<String> {
    let args: Vec<String> = std::env::args().collect();
    for pair in args.windows(2) {
        if pair[0] == "--trace" {
            return Some(pair[1].clone());
        }
    }
    assert!(
        args.last().map(String::as_str) != Some("--trace"),
        "--trace needs a path"
    );
    std::env::var("BLAZE_TRACE").ok().filter(|p| !p.is_empty())
}

/// Repetition count from `BLAZE_BENCH_REPS` (default 3).
pub fn reps() -> usize {
    std::env::var("BLAZE_BENCH_REPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(3)
}

/// Workload scale from `BLAZE_BENCH_SCALE` (default 1).
pub fn scale() -> usize {
    std::env::var("BLAZE_BENCH_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1)
}

/// Node counts to sweep (the paper's x-axis), from `BLAZE_BENCH_NODES`
/// (comma separated) or the default `1,2,4,8,16`.
pub fn node_sweep() -> Vec<usize> {
    std::env::var("BLAZE_BENCH_NODES")
        .ok()
        .map(|s| {
            s.split(',')
                .map(|p| p.trim().parse().expect("node count"))
                .collect()
        })
        .unwrap_or_else(|| vec![1, 2, 4, 8, 16])
}

/// Print a figure header in a recognizable block.
pub fn figure_header(name: &str, paper_claim: &str) {
    println!("==============================================================");
    println!("{name}");
    println!("paper: {paper_claim}");
    println!("timings: mean ± std [min p50 p95] over {} reps", reps());
    println!("==============================================================");
}

/// Human-format bytes.
pub fn fmt_bytes(b: u64) -> String {
    if b >= 1 << 30 {
        format!("{:.2} GiB", b as f64 / (1u64 << 30) as f64)
    } else if b >= 1 << 20 {
        format!("{:.2} MiB", b as f64 / (1u64 << 20) as f64)
    } else if b >= 1 << 10 {
        format!("{:.2} KiB", b as f64 / (1u64 << 10) as f64)
    } else {
        format!("{b} B")
    }
}

/// Machine-readable bench artifacts: each bench accumulates rows and
/// writes `BENCH_<name>.json` next to the working directory (or under
/// `BLAZE_BENCH_DIR`), so the perf trajectory — virtual makespans *and*
/// the threaded backend's real wall-clock fields — accumulates across
/// runs instead of scrolling away in stdout.
///
/// The JSON is hand-rolled (the build is offline, no serde): one object
/// with `name`, `created_unix_ms`, a string-valued `meta` map (backend,
/// scale, …), and `rows` — flat objects of one `series` string, string
/// tags, and numeric fields. Non-finite numbers serialize as `null`.
pub mod report {
    use std::io::Write;
    use std::path::PathBuf;

    /// One datapoint: a series label plus tags and numeric fields.
    #[derive(Debug, Clone)]
    pub struct Row {
        series: String,
        tags: Vec<(String, String)>,
        nums: Vec<(String, f64)>,
    }

    impl Row {
        /// Row in `series` (e.g. `"blaze"`, `"conventional"`).
        pub fn new(series: impl Into<String>) -> Self {
            Self { series: series.into(), tags: Vec::new(), nums: Vec::new() }
        }

        /// Attach a string tag (builder style).
        pub fn tag(mut self, key: &str, value: impl std::fmt::Display) -> Self {
            self.tags.push((key.to_string(), value.to_string()));
            self
        }

        /// Attach a numeric field (builder style).
        pub fn num(mut self, key: &str, value: f64) -> Self {
            self.nums.push((key.to_string(), value));
            self
        }

        /// Fold a run's counter registry into numeric fields: global
        /// counters under their own names, per-node counters as
        /// `node{i}.{name}` (builder style).
        pub fn counters(mut self, stats: &crate::coordinator::metrics::RunStats) -> Self {
            for (k, v) in &stats.counters {
                self.nums.push((k.clone(), *v as f64));
            }
            for (node, cs) in stats.node_counters.iter().enumerate() {
                for (k, v) in cs {
                    self.nums.push((format!("node{node}.{k}"), *v as f64));
                }
            }
            // Histogram digests: `hist.<series>.<stat>`. Non-`wall.`
            // series are deterministic and exact-gated by `blaze report`;
            // `hist.wall.*` fields are wall-time and advisory.
            for (name, h) in &stats.histograms {
                self.nums.push((format!("hist.{name}.count"), h.count() as f64));
                self.nums.push((format!("hist.{name}.p50"), h.p50() as f64));
                self.nums.push((format!("hist.{name}.p95"), h.p95() as f64));
                self.nums.push((format!("hist.{name}.p99"), h.p99() as f64));
                self.nums.push((format!("hist.{name}.max"), h.max_value() as f64));
            }
            self
        }
    }

    /// Accumulates rows for one bench and writes `BENCH_<name>.json`.
    #[derive(Debug, Clone)]
    pub struct Report {
        name: String,
        meta: Vec<(String, String)>,
        rows: Vec<Row>,
    }

    impl Report {
        /// Report for the bench called `name` (`fig4_wordcount`, …).
        pub fn new(name: &str) -> Self {
            Self { name: name.to_string(), meta: Vec::new(), rows: Vec::new() }
        }

        /// Record run-level provenance (backend, scale, …).
        pub fn meta(&mut self, key: &str, value: impl std::fmt::Display) {
            self.meta.push((key.to_string(), value.to_string()));
        }

        /// Append one datapoint.
        pub fn push(&mut self, row: Row) {
            self.rows.push(row);
        }

        /// Serialize to a JSON string.
        pub fn to_json(&self) -> String {
            let created_ms = std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .map(|d| d.as_millis())
                .unwrap_or(0);
            let mut out = String::from("{");
            out.push_str(&format!("\"name\":{}", json_str(&self.name)));
            out.push_str(&format!(",\"created_unix_ms\":{created_ms}"));
            out.push_str(",\"meta\":{");
            for (i, (k, v)) in self.meta.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                out.push_str(&format!("{}:{}", json_str(k), json_str(v)));
            }
            out.push_str("},\"rows\":[");
            for (i, row) in self.rows.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                out.push_str(&format!("{{\"series\":{}", json_str(&row.series)));
                for (k, v) in &row.tags {
                    out.push_str(&format!(",{}:{}", json_str(k), json_str(v)));
                }
                for (k, v) in &row.nums {
                    out.push_str(&format!(",{}:{}", json_str(k), json_num(*v)));
                }
                out.push('}');
            }
            out.push_str("]}");
            out
        }

        /// Write `BENCH_<name>.json` into `dir`; returns the path.
        pub fn write_to(&self, dir: impl AsRef<std::path::Path>) -> std::io::Result<PathBuf> {
            let path = dir.as_ref().join(format!("BENCH_{}.json", self.name));
            let mut f = std::fs::File::create(&path)?;
            f.write_all(self.to_json().as_bytes())?;
            f.write_all(b"\n")?;
            Ok(path)
        }

        /// Write into `BLAZE_BENCH_DIR` (default: current directory).
        pub fn write(&self) -> std::io::Result<PathBuf> {
            let dir = std::env::var("BLAZE_BENCH_DIR").unwrap_or_else(|_| ".".into());
            self.write_to(dir)
        }
    }

    fn json_str(s: &str) -> String {
        let mut out = String::with_capacity(s.len() + 2);
        out.push('"');
        for c in s.chars() {
            match c {
                '"' => out.push_str("\\\""),
                '\\' => out.push_str("\\\\"),
                '\n' => out.push_str("\\n"),
                '\r' => out.push_str("\\r"),
                '\t' => out.push_str("\\t"),
                c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
                c => out.push(c),
            }
        }
        out.push('"');
        out
    }

    fn json_num(v: f64) -> String {
        if v.is_finite() {
            // Rust's shortest-roundtrip Display is valid JSON for finite
            // values (including exponent forms like 1e-6).
            format!("{v}")
        } else {
            "null".into()
        }
    }

    #[cfg(test)]
    mod tests {
        use super::*;

        #[test]
        fn json_shape_and_escaping() {
            let mut rep = Report::new("unit_test");
            rep.meta("backend", "threaded:2");
            rep.push(
                Row::new("bla\"ze")
                    .tag("nodes", 4)
                    .num("throughput", 1.5)
                    .num("broken", f64::NAN),
            );
            let js = rep.to_json();
            assert!(js.starts_with("{\"name\":\"unit_test\""), "{js}");
            assert!(js.contains("\"meta\":{\"backend\":\"threaded:2\"}"), "{js}");
            assert!(js.contains("\"series\":\"bla\\\"ze\""), "{js}");
            assert!(js.contains("\"nodes\":\"4\""), "{js}");
            assert!(js.contains("\"throughput\":1.5"), "{js}");
            assert!(js.contains("\"broken\":null"), "{js}");
            assert!(js.ends_with("]}"), "{js}");
        }

        #[test]
        fn counters_fold_into_row_nums() {
            let mut h = crate::trace::histogram::Histogram::new();
            for v in [1u64, 2, 3, 4] {
                h.record(v);
            }
            let stats = crate::coordinator::metrics::RunStats {
                counters: vec![("ckpt.count".into(), 3)],
                node_counters: vec![vec![], vec![("map.items".into(), 7)]],
                histograms: vec![("map.block_items".into(), h)],
                ..Default::default()
            };
            let mut rep = Report::new("counter_fold");
            rep.push(Row::new("s").counters(&stats));
            let js = rep.to_json();
            assert!(js.contains("\"ckpt.count\":3"), "{js}");
            assert!(js.contains("\"node1.map.items\":7"), "{js}");
            assert!(js.contains("\"hist.map.block_items.count\":4"), "{js}");
            assert!(js.contains("\"hist.map.block_items.max\":4"), "{js}");
        }

        #[test]
        fn write_to_creates_bench_file() {
            let dir = std::env::temp_dir();
            let mut rep = Report::new("write_roundtrip");
            rep.push(Row::new("s").num("x", 2.0));
            let path = rep.write_to(&dir).expect("write bench json");
            assert!(path.ends_with("BENCH_write_roundtrip.json"));
            let body = std::fs::read_to_string(&path).expect("read back");
            assert!(body.contains("\"x\":2"));
            std::fs::remove_file(path).ok();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summarize_mean_std() {
        let s = summarize(&[1.0, 2.0, 3.0]);
        assert!((s.mean - 2.0).abs() < 1e-12);
        assert!((s.std - 1.0).abs() < 1e-12);
        assert_eq!(s.n, 3);
    }

    #[test]
    fn summarize_order_statistics() {
        let s = summarize(&[3.0, 1.0, 2.0, 4.0]);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.p50, 2.0, "nearest-rank median of 4");
        assert_eq!(s.p95, 4.0);
        // Singleton: every statistic collapses to the one value.
        let one = summarize(&[7.0]);
        assert_eq!((one.min, one.p50, one.p95), (7.0, 7.0, 7.0));
    }

    #[test]
    fn summarize_empty_is_all_zero() {
        let s = summarize(&[]);
        assert_eq!(s.n, 0);
        assert_eq!((s.mean, s.std, s.min, s.p50, s.p95), (0.0, 0.0, 0.0, 0.0, 0.0));
    }

    #[test]
    fn fmt_bytes_units() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(2048), "2.00 KiB");
        assert_eq!(fmt_bytes(3 << 20), "3.00 MiB");
    }

    #[test]
    fn time_host_counts_reps() {
        let s = time_host(5, || 1 + 1);
        assert_eq!(s.n, 5);
        assert!(s.mean >= 0.0);
    }
}
