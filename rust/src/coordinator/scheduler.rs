//! Block scheduling: how a node's items are split across its workers and
//! how container elements are partitioned across nodes.
//!
//! Blaze (like the paper's MPI+OpenMP substrate) block-partitions data:
//! contiguous ranges, remainder spread one-per-part from the front. The
//! scheduler also provides a size-weighted partitioner used by shard
//! rebalancing when key skew makes block partitions uneven.

use std::ops::Range;

/// Split `n_items` into `parts` contiguous ranges, sizes differing by ≤1.
pub fn block_ranges(n_items: usize, parts: usize) -> Vec<Range<usize>> {
    assert!(parts > 0, "parts must be > 0");
    let base = n_items / parts;
    let extra = n_items % parts;
    let mut out = Vec::with_capacity(parts);
    let mut start = 0;
    for p in 0..parts {
        let len = base + usize::from(p < extra);
        out.push(start..start + len);
        start += len;
    }
    out
}

/// Owner part of item `i` under [`block_ranges`] partitioning — O(1),
/// no range scan.
pub fn block_owner(n_items: usize, parts: usize, i: usize) -> usize {
    debug_assert!(i < n_items);
    let base = n_items / parts;
    let extra = n_items % parts;
    let big = (base + 1) * extra; // items covered by the `extra` bigger parts
    if base == 0 || i < big {
        i / (base + 1)
    } else {
        extra + (i - big) / base
    }
}

/// Split weighted items into `parts` contiguous groups minimizing the max
/// group weight (greedy longest-processing-time would break contiguity;
/// rebalancing wants contiguity so shard moves stay cheap). Returns ranges
/// over the item indices.
pub fn weighted_contiguous_ranges(weights: &[u64], parts: usize) -> Vec<Range<usize>> {
    assert!(parts > 0);
    let total: u64 = weights.iter().sum();
    let target = total as f64 / parts as f64;
    let mut out = Vec::with_capacity(parts);
    let mut start = 0usize;
    let mut acc = 0u64;
    let mut budget = target;
    for (i, &w) in weights.iter().enumerate() {
        acc += w;
        // Close the current group once it reaches its cumulative budget,
        // keeping enough items for the remaining groups.
        let groups_left = parts - out.len();
        let items_left = weights.len() - i - 1;
        if out.len() < parts - 1 && (acc as f64 >= budget || items_left < groups_left - 1) {
            out.push(start..i + 1);
            start = i + 1;
            budget += target;
        }
    }
    out.push(start..weights.len());
    while out.len() < parts {
        out.push(weights.len()..weights.len());
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn block_ranges_cover_exactly() {
        for n in [0usize, 1, 7, 100, 101] {
            for p in [1usize, 2, 3, 8] {
                let ranges = block_ranges(n, p);
                assert_eq!(ranges.len(), p);
                let mut next = 0;
                for r in &ranges {
                    assert_eq!(r.start, next);
                    next = r.end;
                }
                assert_eq!(next, n);
                let sizes: Vec<usize> = ranges.iter().map(|r| r.len()).collect();
                let (min, max) =
                    (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
                assert!(max - min <= 1, "n={n} p={p} sizes={sizes:?}");
            }
        }
    }

    #[test]
    fn block_owner_agrees_with_ranges() {
        for n in [1usize, 7, 100, 101] {
            for p in [1usize, 2, 3, 8] {
                let ranges = block_ranges(n, p);
                for i in 0..n {
                    let owner = block_owner(n, p, i);
                    assert!(
                        ranges[owner].contains(&i),
                        "n={n} p={p} i={i} owner={owner}"
                    );
                }
            }
        }
    }

    #[test]
    fn weighted_ranges_balance_skew() {
        // One huge item at the front; the rest tiny.
        let mut w = vec![1u64; 100];
        w[0] = 100;
        let ranges = weighted_contiguous_ranges(&w, 4);
        assert_eq!(ranges.len(), 4);
        assert_eq!(ranges[0], 0..1, "huge item isolated");
        // Coverage.
        assert_eq!(ranges.last().unwrap().end, 100);
        let covered: usize = ranges.iter().map(|r| r.len()).sum();
        assert_eq!(covered, 100);
    }

    #[test]
    fn weighted_ranges_more_parts_than_items() {
        let ranges = weighted_contiguous_ranges(&[5, 5], 4);
        assert_eq!(ranges.len(), 4);
        assert_eq!(ranges.iter().map(|r| r.len()).sum::<usize>(), 2);
    }
}
