//! Shard rebalancing.
//!
//! `DistHashMap` routes keys through 256 hash *slots*; a slot→node map owned
//! by the coordinator assigns slots to nodes. When key skew piles entries
//! onto a few slots, [`plan`] recomputes the slot→node map from measured
//! slot weights ([`crate::coordinator::scheduler::weighted_contiguous_ranges`])
//! and [`MovePlan::cost_bytes`] charges the real serialized bytes of the
//! entries that change owner. This is the mechanism that keeps the paper's
//! skewed workloads (Zipf words, power-law graph degrees) balanced.

use super::scheduler::weighted_contiguous_ranges;

/// Number of hash slots (fixed; 256 slots over ≤64 nodes gives ≤2% quantization).
pub const NUM_SLOTS: usize = 256;

/// Slot→node assignment.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SlotMap {
    owner: Vec<usize>,
}

impl SlotMap {
    /// Even initial assignment over `nodes`.
    pub fn even(nodes: usize) -> Self {
        assert!(nodes > 0);
        let ranges = weighted_contiguous_ranges(&vec![1u64; NUM_SLOTS], nodes);
        let mut owner = vec![0usize; NUM_SLOTS];
        for (node, range) in ranges.iter().enumerate() {
            for slot in range.clone() {
                owner[slot] = node;
            }
        }
        Self { owner }
    }

    /// Owning node of `slot`.
    #[inline]
    pub fn node_of(&self, slot: usize) -> usize {
        self.owner[slot]
    }

    /// Number of nodes referenced.
    pub fn nodes(&self) -> usize {
        self.owner.iter().copied().max().unwrap_or(0) + 1
    }

    /// Per-node slot counts.
    pub fn slots_per_node(&self, nodes: usize) -> Vec<usize> {
        let mut counts = vec![0usize; nodes];
        for &n in &self.owner {
            counts[n] += 1;
        }
        counts
    }
}

/// A planned slot move.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SlotMove {
    /// Slot being reassigned.
    pub slot: usize,
    /// Current owner.
    pub from: usize,
    /// New owner.
    pub to: usize,
    /// Bytes that must move (serialized entries in the slot).
    pub bytes: u64,
}

/// Rebalance plan: the new map plus the moves to get there.
#[derive(Debug, Clone)]
pub struct MovePlan {
    /// Assignment after rebalancing.
    pub new_map: SlotMap,
    /// Slots changing owner.
    pub moves: Vec<SlotMove>,
}

impl MovePlan {
    /// Total bytes crossing the network to execute this plan.
    pub fn cost_bytes(&self) -> u64 {
        self.moves.iter().map(|m| m.bytes).sum()
    }
}

/// Imbalance of a weight distribution: max node load / mean node load.
pub fn imbalance(slot_weights: &[u64], map: &SlotMap, nodes: usize) -> f64 {
    let mut loads = vec![0u64; nodes];
    for (slot, &w) in slot_weights.iter().enumerate() {
        loads[map.node_of(slot)] += w;
    }
    let total: u64 = loads.iter().sum();
    if total == 0 {
        return 1.0;
    }
    let mean = total as f64 / nodes as f64;
    loads.iter().copied().max().unwrap() as f64 / mean
}

/// Plan a rebalance from measured per-slot weights (entry counts) and
/// per-slot serialized byte sizes.
pub fn plan(
    current: &SlotMap,
    slot_weights: &[u64],
    slot_bytes: &[u64],
    nodes: usize,
) -> MovePlan {
    assert_eq!(slot_weights.len(), NUM_SLOTS);
    assert_eq!(slot_bytes.len(), NUM_SLOTS);
    let ranges = weighted_contiguous_ranges(slot_weights, nodes);
    let mut owner = vec![0usize; NUM_SLOTS];
    for (node, range) in ranges.iter().enumerate() {
        for slot in range.clone() {
            owner[slot] = node;
        }
    }
    let new_map = SlotMap { owner };
    // The contiguous-range heuristic can lose to the incumbent map on
    // adversarial weight patterns; never ship a plan that makes things
    // worse.
    if imbalance(slot_weights, &new_map, nodes) >= imbalance(slot_weights, current, nodes) {
        return MovePlan { new_map: current.clone(), moves: Vec::new() };
    }
    let moves = (0..NUM_SLOTS)
        .filter(|&s| current.node_of(s) != new_map.node_of(s))
        .map(|s| SlotMove {
            slot: s,
            from: current.node_of(s),
            to: new_map.node_of(s),
            bytes: slot_bytes[s],
        })
        .collect();
    MovePlan { new_map, moves }
}

/// Plan a rebalance when some nodes are dead: every slot a dead node owns
/// must move, and no slot may be assigned to a dead node. Used after a
/// failure to evacuate a lost worker's key range onto the survivors.
///
/// Unlike [`plan`], this never keeps the incumbent map while any dead node
/// still owns slots — evacuation is mandatory even when it worsens the
/// imbalance metric.
pub fn plan_with_dead(
    current: &SlotMap,
    slot_weights: &[u64],
    slot_bytes: &[u64],
    nodes: usize,
    dead: &[usize],
) -> MovePlan {
    assert_eq!(slot_weights.len(), NUM_SLOTS);
    assert_eq!(slot_bytes.len(), NUM_SLOTS);
    if dead.is_empty() {
        return plan(current, slot_weights, slot_bytes, nodes);
    }
    let live: Vec<usize> = (0..nodes).filter(|n| !dead.contains(n)).collect();
    assert!(!live.is_empty(), "cannot rebalance with every node dead");
    let ranges = weighted_contiguous_ranges(slot_weights, live.len());
    let mut owner = vec![0usize; NUM_SLOTS];
    for (group, range) in ranges.iter().enumerate() {
        for slot in range.clone() {
            owner[slot] = live[group];
        }
    }
    let new_map = SlotMap { owner };
    let incumbent_clean = !current.owner.iter().any(|n| dead.contains(n));
    if incumbent_clean
        && imbalance(slot_weights, &new_map, nodes) >= imbalance(slot_weights, current, nodes)
    {
        // Nothing to evacuate and the contiguous heuristic lost: keep what
        // we have.
        return MovePlan { new_map: current.clone(), moves: Vec::new() };
    }
    let moves = (0..NUM_SLOTS)
        .filter(|&s| current.node_of(s) != new_map.node_of(s))
        .map(|s| SlotMove {
            slot: s,
            from: current.node_of(s),
            to: new_map.node_of(s),
            bytes: slot_bytes[s],
        })
        .collect();
    MovePlan { new_map, moves }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn even_map_covers_all_nodes() {
        let map = SlotMap::even(4);
        let counts = map.slots_per_node(4);
        assert_eq!(counts.iter().sum::<usize>(), NUM_SLOTS);
        assert!(counts.iter().all(|&c| c == NUM_SLOTS / 4));
    }

    #[test]
    fn plan_reduces_imbalance_under_skew() {
        let nodes = 4;
        let map = SlotMap::even(nodes);
        // Heavy skew: slot 0 has 1000 entries, everything else 1.
        let mut weights = vec![1u64; NUM_SLOTS];
        weights[0] = 1000;
        let bytes: Vec<u64> = weights.iter().map(|w| w * 16).collect();
        let before = imbalance(&weights, &map, nodes);
        let plan = plan(&map, &weights, &bytes, nodes);
        let after = imbalance(&weights, &plan.new_map, nodes);
        assert!(after < before, "imbalance {before} -> {after}");
        // The heavy slot's node should end up with few other slots.
        let heavy_node = plan.new_map.node_of(0);
        let counts = plan.new_map.slots_per_node(nodes);
        assert!(counts[heavy_node] < NUM_SLOTS / nodes);
    }

    #[test]
    fn no_moves_when_already_balanced() {
        let nodes = 2;
        let map = SlotMap::even(nodes);
        let weights = vec![10u64; NUM_SLOTS];
        let bytes = vec![100u64; NUM_SLOTS];
        let plan = plan(&map, &weights, &bytes, nodes);
        assert_eq!(plan.cost_bytes(), 0, "balanced load should not move slots");
    }

    #[test]
    fn dead_node_slots_all_evacuated() {
        let nodes = 4;
        let map = SlotMap::even(nodes);
        let weights = vec![10u64; NUM_SLOTS];
        let bytes = vec![16u64; NUM_SLOTS];
        let dead = [2usize];
        let p = plan_with_dead(&map, &weights, &bytes, nodes, &dead);
        // No slot may stay on (or move to) the dead node.
        for slot in 0..NUM_SLOTS {
            assert_ne!(p.new_map.node_of(slot), 2, "slot {slot} assigned to dead node");
        }
        // Every slot the dead node owned moves, and its bytes are charged.
        let owned: Vec<usize> = (0..NUM_SLOTS).filter(|&s| map.node_of(s) == 2).collect();
        assert!(!owned.is_empty());
        for s in &owned {
            assert!(
                p.moves.iter().any(|m| m.slot == *s && m.from == 2),
                "dead slot {s} not moved"
            );
        }
        assert!(p.cost_bytes() >= owned.len() as u64 * 16);
        // Survivors stay balanced.
        let counts = p.new_map.slots_per_node(nodes);
        assert_eq!(counts[2], 0);
        for n in [0usize, 1, 3] {
            assert!(counts[n] >= NUM_SLOTS / 4, "survivor {n} underloaded: {counts:?}");
        }
    }

    #[test]
    fn plan_with_dead_skews_by_weight_among_survivors() {
        let nodes = 3;
        let map = SlotMap::even(nodes);
        let mut weights = vec![1u64; NUM_SLOTS];
        weights[0] = 500; // heavy head slot
        let bytes = vec![8u64; NUM_SLOTS];
        let p = plan_with_dead(&map, &weights, &bytes, nodes, &[1]);
        let after = imbalance(&weights, &p.new_map, nodes);
        // Heavy slot isolated on one survivor; dead node owns nothing.
        assert_eq!(p.new_map.slots_per_node(nodes)[1], 0);
        assert!(after < 2.0, "imbalance {after}");
    }

    #[test]
    fn plan_with_dead_no_dead_delegates() {
        let nodes = 2;
        let map = SlotMap::even(nodes);
        let weights = vec![5u64; NUM_SLOTS];
        let bytes = vec![4u64; NUM_SLOTS];
        let p = plan_with_dead(&map, &weights, &bytes, nodes, &[]);
        assert_eq!(p.cost_bytes(), 0, "balanced + no deaths = no moves");
    }

    #[test]
    #[should_panic(expected = "every node dead")]
    fn all_dead_panics() {
        let map = SlotMap::even(2);
        let _ = plan_with_dead(&map, &[1; NUM_SLOTS], &[1; NUM_SLOTS], 2, &[0, 1]);
    }

    #[test]
    fn move_cost_is_sum_of_slot_bytes() {
        let nodes = 2;
        let map = SlotMap::even(nodes);
        let mut weights = vec![1u64; NUM_SLOTS];
        for w in weights.iter_mut().take(NUM_SLOTS / 2) {
            *w = 100; // first half heavy → boundary shifts
        }
        let bytes = vec![8u64; NUM_SLOTS];
        let p = plan(&map, &weights, &bytes, nodes);
        assert_eq!(p.cost_bytes(), 8 * p.moves.len() as u64);
    }
}
