//! Virtual cluster handle and configuration.

use std::cell::{Cell, RefCell};
use std::rc::Rc;

use crate::exec::shard::StripeFeedback;
use crate::exec::transport::TransportFaultPlan;
use crate::fault::FaultConfig;
use crate::net::model::NetworkModel;
use crate::trace::TraceCollector;
use crate::util::alloc::{AllocMode, BufferPool};

use super::metrics::MetricsRegistry;

/// Which MapReduce engine executes jobs on this cluster.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum EngineKind {
    /// Blaze's engine (paper §2.3): eager reduction into thread-local
    /// caches, fast (tag-less) serialization, asynchronous shuffle-reduce,
    /// dense small-key-range path.
    #[default]
    Eager,
    /// Conventional MapReduce (the Spark analogue): materialize every
    /// emitted pair, tagged protobuf-style serialization, barrier shuffle,
    /// group-then-reduce.
    Conventional,
}

impl std::fmt::Display for EngineKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            EngineKind::Eager => write!(f, "blaze"),
            EngineKind::Conventional => write!(f, "conventional"),
        }
    }
}

/// How the map+combine phase of a job executes (orthogonal to
/// [`EngineKind`], which selects the *algorithm*).
///
/// * `Simulated` — the historical mode: one host thread walks every
///   virtual worker's block serially and parallelism is *accounted* in
///   virtual time ([`crate::net::vtime`]).
/// * `Threaded(n)` — the [`crate::exec`] backend: one virtual node's map
///   blocks execute for real on `n` OS threads (work-stealing block queue,
///   bounded per-thread eager caches, lock-striped machine-local shard
///   map), and shuffle payloads physically move through the in-process
///   bounded-channel transport ([`crate::exec::transport`]) — virtual
///   time still comes from the calibrated flow model, real wall time
///   lands in `RunStats::phase_wall_ns` and the `transport.*` counters.
///   Results are byte-identical to `Simulated` for the eager and
///   small-key paths, with or without fault injection: fault-tolerant
///   jobs replay killed blocks on the live pool
///   ([`crate::fault::engine`] drives [`crate::exec::pool`]). Only the
///   conventional engine (which models a baseline rather than Blaze)
///   falls back to the simulated path regardless of backend.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    /// Serial execution with virtual-time accounting (the default).
    Simulated,
    /// Real shared-memory execution on this many OS threads per node.
    Threaded(usize),
}

impl Default for Backend {
    fn default() -> Self {
        Backend::Simulated
    }
}

impl std::fmt::Display for Backend {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Backend::Simulated => write!(f, "simulated"),
            Backend::Threaded(n) => write!(f, "threaded:{n}"),
        }
    }
}

impl Backend {
    /// Parse a backend spec: `simulated`, `threaded` (2 threads), or
    /// `threaded:N`.
    pub fn parse(spec: &str) -> Result<Self, String> {
        match spec {
            "simulated" | "sim" => Ok(Self::Simulated),
            "threaded" => Ok(Self::Threaded(2)),
            other => match other.strip_prefix("threaded:") {
                Some(n) => n
                    .parse::<usize>()
                    .map_err(|e| format!("backend threaded:N: {e}"))
                    .map(|n| Self::Threaded(n.max(1))),
                None => Err(format!("unknown backend {other:?} (simulated|threaded[:N])")),
            },
        }
    }

    /// Session default from the `BLAZE_BACKEND` environment variable
    /// (unset/empty = `Simulated`). Panics on an unparseable value: a
    /// silently ignored spec would invalidate a CI matrix leg that thinks
    /// it is running threaded.
    pub fn from_env() -> Self {
        match std::env::var("BLAZE_BACKEND") {
            Ok(s) if !s.is_empty() => {
                Self::parse(&s).unwrap_or_else(|e| panic!("BLAZE_BACKEND: {e}"))
            }
            _ => Self::Simulated,
        }
    }

    /// Worker-thread count when threaded.
    pub fn threads(&self) -> Option<usize> {
        match self {
            Backend::Simulated => None,
            Backend::Threaded(n) => Some(*n),
        }
    }
}

/// Cluster shape and engine policy.
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    /// Virtual node (machine) count.
    pub nodes: usize,
    /// Worker threads per node (r5.xlarge has 4 logical cores).
    pub workers_per_node: usize,
    /// Interconnect model.
    pub network: NetworkModel,
    /// Engine selection.
    pub engine: EngineKind,
    /// Execution backend for the map+combine phase (simulated vs real
    /// threads). Defaults from `BLAZE_BACKEND` so a CI leg can run the
    /// whole suite threaded without touching test code.
    pub backend: Backend,
    /// Scratch allocator mode (Blaze vs Blaze-TCM ablation).
    pub alloc: AllocMode,
    /// Base RNG seed; all workloads derive per-worker streams from it.
    pub seed: u64,
    /// Thread-local eager-combine cache capacity (entries) before overflow
    /// flushes to the node-local map (paper: "popular keys" stay
    /// thread-local).
    pub thread_cache_entries: usize,
    /// Modeled per-record executor overhead for the conventional engine,
    /// seconds — stands in for the JVM/Spark task overhead the paper's
    /// baseline carries (calibrated in DESIGN.md §Substitutions).
    pub conventional_overhead_sec: f64,
    /// Modeled per-job task-launch overhead for the conventional engine,
    /// seconds (Spark job/stage scheduling latency).
    pub conventional_job_latency_sec: f64,
    /// Backpressure window for shuffle transports, bytes. Used by both
    /// the simulated shuffle ([`crate::coordinator::shuffle`]) and the
    /// real channel transport ([`crate::exec::transport`]), where it
    /// also sizes the per-destination bounded channels
    /// (`window / CHUNK_BYTES` frames, floor 1). Shrinking it forces
    /// deterministic stall storms — the transport stress suite pins it
    /// to 1. Defaults to
    /// [`crate::coordinator::backpressure::DEFAULT_WINDOW_BYTES`].
    pub transport_window_bytes: u64,
    /// Fault-tolerance policy: failure injection plan plus checkpoint
    /// cadence. When enabled, jobs run through the recoverable engine
    /// ([`crate::fault::engine`]).
    pub fault: FaultConfig,
    /// Lossy-transport fault model (`--net-fault`): per-frame
    /// drop/corrupt/delay probabilities plus the retry budget and
    /// delivery deadline, applied by the threaded backend's channel
    /// transport ([`crate::exec::transport::execute_lossy`]). `None`
    /// (the default) keeps the lossless transport. The simulated
    /// backend moves no physical frames and ignores the plan; results
    /// stay byte-identical either way because recovered delivery is
    /// element-identical to lossless delivery.
    pub net_fault: Option<TransportFaultPlan>,
    /// Structured event tracing ([`crate::trace`]): when on, every job
    /// records a typed event log into the cluster's
    /// [`TraceCollector`]. Defaults from the `BLAZE_TRACE` env var
    /// (non-empty = on; the CLI `--trace PATH` flag also flips it).
    /// Off by default — the engines' hot paths then pay one branch.
    pub trace: bool,
    /// Pin threaded-backend pool workers to cores
    /// ([`crate::exec::pool::PoolOptions::pin_threads`]) so a block's
    /// RNG-stream work stays on one core. Opt-in (`--pin-threads` or the
    /// `BLAZE_PIN_THREADS` env var, non-empty = on); a no-op where the
    /// platform has no affinity syscall. Never affects results — pinning
    /// is placement only.
    pub pin_threads: bool,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        Self {
            nodes: 1,
            workers_per_node: 4,
            network: NetworkModel::aws_10gbps(),
            engine: EngineKind::Eager,
            backend: Backend::from_env(),
            alloc: AllocMode::System,
            seed: 0xB1A2E,
            thread_cache_entries: 1 << 16,
            conventional_overhead_sec: 250e-9,
            conventional_job_latency_sec: 20e-3,
            transport_window_bytes: crate::coordinator::backpressure::DEFAULT_WINDOW_BYTES,
            fault: FaultConfig::disabled(),
            net_fault: None,
            trace: std::env::var("BLAZE_TRACE").map_or(false, |v| !v.is_empty()),
            pin_threads: std::env::var("BLAZE_PIN_THREADS").map_or(false, |v| !v.is_empty()),
        }
    }
}

impl ClusterConfig {
    /// `nodes` × `workers` with all other settings default.
    pub fn sized(nodes: usize, workers_per_node: usize) -> Self {
        Self { nodes, workers_per_node, ..Self::default() }
    }

    /// Builder-style engine override.
    pub fn with_engine(mut self, engine: EngineKind) -> Self {
        self.engine = engine;
        self
    }

    /// Builder-style backend override.
    pub fn with_backend(mut self, backend: Backend) -> Self {
        self.backend = backend;
        self
    }

    /// Builder-style allocator override.
    pub fn with_alloc(mut self, alloc: AllocMode) -> Self {
        self.alloc = alloc;
        self
    }

    /// Builder-style network override.
    pub fn with_network(mut self, network: NetworkModel) -> Self {
        self.network = network;
        self
    }

    /// Builder-style seed override.
    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Builder-style fault-tolerance policy override.
    pub fn with_fault(mut self, fault: FaultConfig) -> Self {
        self.fault = fault;
        self
    }

    /// Builder-style transport backpressure window override (bytes,
    /// clamped to ≥ 1).
    pub fn with_transport_window(mut self, bytes: u64) -> Self {
        self.transport_window_bytes = bytes.max(1);
        self
    }

    /// Builder-style lossy-transport fault model override (see
    /// [`ClusterConfig::net_fault`]).
    pub fn with_net_fault(mut self, plan: TransportFaultPlan) -> Self {
        self.net_fault = Some(plan);
        self
    }

    /// Builder-style trace toggle.
    pub fn with_trace(mut self, trace: bool) -> Self {
        self.trace = trace;
        self
    }

    /// Builder-style thread-pinning toggle.
    pub fn with_pin_threads(mut self, pin: bool) -> Self {
        self.pin_threads = pin;
        self
    }
}

struct ClusterInner {
    config: ClusterConfig,
    metrics: RefCell<MetricsRegistry>,
    pool: BufferPool,
    /// Fired-event flags (by event position in the failure plan) that
    /// persist across jobs on this cluster, consulted only by
    /// [`crate::fault::FailurePlan::once_per_sequence`] plans so an
    /// iterative job sequence (k-means, PageRank) injects each planned
    /// kill once instead of once per MapReduce job.
    fault_fired: RefCell<Vec<bool>>,
    /// Structured trace event collector ([`crate::trace`]); disabled
    /// (absorbs nothing) unless `config.trace` is on.
    trace: RefCell<TraceCollector>,
    /// Last threaded run's stripe-lock observations, feeding the next
    /// run's [`crate::exec::shard::stripe_count`] decision. Purely a
    /// sizing hint — canonical merge order never depends on it.
    stripe_hint: Cell<Option<StripeFeedback>>,
}

/// Cheap-to-clone handle to a virtual cluster.
///
/// The simulation is single-threaded and deterministic (virtual parallelism
/// is *accounted*, see [`crate::net::vtime`]), so the handle is `Rc`-based
/// and the whole API is `!Send` by design.
#[derive(Clone)]
pub struct Cluster {
    inner: Rc<ClusterInner>,
}

impl Cluster {
    /// Cluster from an explicit config.
    pub fn new(config: ClusterConfig) -> Self {
        let trace = RefCell::new(TraceCollector::new(config.trace));
        Self {
            inner: Rc::new(ClusterInner {
                config,
                metrics: RefCell::new(MetricsRegistry::default()),
                pool: BufferPool::new(),
                fault_fired: RefCell::new(Vec::new()),
                trace,
                stripe_hint: Cell::new(None),
            }),
        }
    }

    /// `nodes` × `workers` local cluster with defaults (loopback network
    /// when `nodes == 1`).
    pub fn local(nodes: usize, workers_per_node: usize) -> Self {
        let mut config = ClusterConfig::sized(nodes, workers_per_node);
        if nodes == 1 {
            config.network = NetworkModel::loopback();
        }
        Self::new(config)
    }

    /// Cluster configuration.
    pub fn config(&self) -> &ClusterConfig {
        &self.inner.config
    }

    /// Node count.
    pub fn nodes(&self) -> usize {
        self.inner.config.nodes
    }

    /// Workers per node.
    pub fn workers(&self) -> usize {
        self.inner.config.workers_per_node
    }

    /// Total virtual worker count.
    pub fn total_workers(&self) -> usize {
        self.nodes() * self.workers()
    }

    /// Mutable access to the metrics registry.
    pub fn metrics(&self) -> std::cell::RefMut<'_, MetricsRegistry> {
        self.inner.metrics.borrow_mut()
    }

    /// Scratch buffer pool (honours the configured [`AllocMode`]).
    pub fn pool(&self) -> &BufferPool {
        &self.inner.pool
    }

    /// Stripe-lock observations from the last threaded run on this
    /// cluster, if any ([`crate::exec::shard::stripe_count`] input).
    pub fn stripe_feedback(&self) -> Option<StripeFeedback> {
        self.inner.stripe_hint.get()
    }

    /// Record a threaded run's stripe-lock observations for the next
    /// run's stripe sizing.
    pub fn note_stripe_feedback(&self, fb: StripeFeedback) {
        self.inner.stripe_hint.set(Some(fb));
    }

    /// True if two handles point at the same cluster.
    pub fn same_cluster(&self, other: &Cluster) -> bool {
        Rc::ptr_eq(&self.inner, &other.inner)
    }

    /// Failure-plan events already fired in earlier jobs on this cluster
    /// (indexed by event position; empty until a
    /// [`crate::fault::FailurePlan::once_per_sequence`] job records some).
    pub fn fault_fired(&self) -> Vec<bool> {
        self.inner.fault_fired.borrow().clone()
    }

    /// Persist fired-event flags for subsequent jobs (the recoverable
    /// engine calls this at job end for `once_per_sequence` plans).
    pub fn set_fault_fired(&self, fired: &[bool]) {
        *self.inner.fault_fired.borrow_mut() = fired.to_vec();
    }

    /// Mutable access to the structured trace collector (engines absorb
    /// per-job [`crate::trace::TraceBuf`]s; exporters read it back).
    pub fn trace(&self) -> std::cell::RefMut<'_, TraceCollector> {
        self.inner.trace.borrow_mut()
    }

    /// Export the collected trace: canonical JSONL at `path` plus the
    /// Chrome view at `<path>.chrome.json` (no-op files when tracing is
    /// off — the collector is then empty).
    pub fn export_trace<P: AsRef<std::path::Path>>(&self, path: P) -> std::io::Result<()> {
        self.inner.trace.borrow().export(path)
    }
}

impl std::fmt::Debug for Cluster {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Cluster")
            .field("nodes", &self.nodes())
            .field("workers_per_node", &self.workers())
            .field("engine", &self.config().engine)
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn local_single_node_uses_loopback() {
        let c = Cluster::local(1, 4);
        assert_eq!(c.config().network, NetworkModel::loopback());
        let c8 = Cluster::local(8, 4);
        assert_eq!(c8.config().network, NetworkModel::aws_10gbps());
        assert_eq!(c8.total_workers(), 32);
    }

    #[test]
    fn builder_chain() {
        let cfg = ClusterConfig::sized(4, 2)
            .with_engine(EngineKind::Conventional)
            .with_alloc(AllocMode::Pool)
            .with_seed(7)
            .with_transport_window(0)
            .with_pin_threads(true);
        assert_eq!(cfg.nodes, 4);
        assert_eq!(cfg.engine, EngineKind::Conventional);
        assert_eq!(cfg.alloc, AllocMode::Pool);
        assert_eq!(cfg.seed, 7);
        assert_eq!(cfg.transport_window_bytes, 1, "window clamps to >= 1");
        assert!(cfg.pin_threads);
        assert_eq!(cfg.net_fault, None, "lossless transport by default");
        let lossy = ClusterConfig::sized(2, 2)
            .with_net_fault(TransportFaultPlan::new(0.2, 0.05, 42));
        assert_eq!(lossy.net_fault, Some(TransportFaultPlan::new(0.2, 0.05, 42)));
        assert_eq!(
            ClusterConfig::default().transport_window_bytes,
            crate::coordinator::backpressure::DEFAULT_WINDOW_BYTES
        );
    }

    #[test]
    fn stripe_feedback_round_trips_on_cluster() {
        let c = Cluster::local(2, 2);
        assert_eq!(c.stripe_feedback(), None);
        let fb = StripeFeedback { stripes: 16, locks: 100, contended: 3 };
        c.note_stripe_feedback(fb);
        assert_eq!(c.clone().stripe_feedback(), Some(fb), "hint is shared by handles");
    }

    #[test]
    fn backend_parse_display_roundtrip() {
        assert_eq!(Backend::parse("simulated"), Ok(Backend::Simulated));
        assert_eq!(Backend::parse("sim"), Ok(Backend::Simulated));
        assert_eq!(Backend::parse("threaded"), Ok(Backend::Threaded(2)));
        assert_eq!(Backend::parse("threaded:4"), Ok(Backend::Threaded(4)));
        // 0 clamps to 1 thread; garbage is a loud error.
        assert_eq!(Backend::parse("threaded:0"), Ok(Backend::Threaded(1)));
        assert!(Backend::parse("warp").is_err());
        assert!(Backend::parse("threaded:x").is_err());
        assert_eq!(Backend::Threaded(4).to_string(), "threaded:4");
        assert_eq!(Backend::Simulated.to_string(), "simulated");
        assert_eq!(Backend::Threaded(3).threads(), Some(3));
        assert_eq!(Backend::Simulated.threads(), None);
    }

    #[test]
    fn fault_fired_state_persists_on_cluster() {
        let c = Cluster::local(2, 2);
        assert!(c.fault_fired().is_empty());
        c.set_fault_fired(&[true, false]);
        assert_eq!(c.clone().fault_fired(), vec![true, false]);
    }

    #[test]
    fn trace_flag_gates_the_collector() {
        let off = Cluster::local(1, 1);
        assert!(!off.trace().enabled(), "tracing is off by default");
        let on = Cluster::new(ClusterConfig::sized(1, 1).with_trace(true));
        assert!(on.trace().enabled());
        let mut buf = crate::trace::TraceBuf::new(true);
        buf.push(crate::trace::TraceEvent::new(
            0,
            None,
            "map+local-reduce",
            crate::trace::TraceEventKind::Reduce { from: 0, pairs: 1 },
        ));
        on.trace().absorb_job("t", buf);
        assert_eq!(on.trace().event_count(), 1);
    }

    #[test]
    fn handles_share_state() {
        let a = Cluster::local(2, 2);
        let b = a.clone();
        assert!(a.same_cluster(&b));
        a.metrics().record_note("x");
        assert_eq!(b.metrics().notes().len(), 1);
    }
}
