//! Virtual cluster handle and configuration.

use std::cell::RefCell;
use std::rc::Rc;

use crate::fault::FaultConfig;
use crate::net::model::NetworkModel;
use crate::util::alloc::{AllocMode, BufferPool};

use super::metrics::MetricsRegistry;

/// Which MapReduce engine executes jobs on this cluster.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum EngineKind {
    /// Blaze's engine (paper §2.3): eager reduction into thread-local
    /// caches, fast (tag-less) serialization, asynchronous shuffle-reduce,
    /// dense small-key-range path.
    #[default]
    Eager,
    /// Conventional MapReduce (the Spark analogue): materialize every
    /// emitted pair, tagged protobuf-style serialization, barrier shuffle,
    /// group-then-reduce.
    Conventional,
}

impl std::fmt::Display for EngineKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            EngineKind::Eager => write!(f, "blaze"),
            EngineKind::Conventional => write!(f, "conventional"),
        }
    }
}

/// Cluster shape and engine policy.
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    /// Virtual node (machine) count.
    pub nodes: usize,
    /// Worker threads per node (r5.xlarge has 4 logical cores).
    pub workers_per_node: usize,
    /// Interconnect model.
    pub network: NetworkModel,
    /// Engine selection.
    pub engine: EngineKind,
    /// Scratch allocator mode (Blaze vs Blaze-TCM ablation).
    pub alloc: AllocMode,
    /// Base RNG seed; all workloads derive per-worker streams from it.
    pub seed: u64,
    /// Thread-local eager-combine cache capacity (entries) before overflow
    /// flushes to the node-local map (paper: "popular keys" stay
    /// thread-local).
    pub thread_cache_entries: usize,
    /// Modeled per-record executor overhead for the conventional engine,
    /// seconds — stands in for the JVM/Spark task overhead the paper's
    /// baseline carries (calibrated in DESIGN.md §Substitutions).
    pub conventional_overhead_sec: f64,
    /// Modeled per-job task-launch overhead for the conventional engine,
    /// seconds (Spark job/stage scheduling latency).
    pub conventional_job_latency_sec: f64,
    /// Fault-tolerance policy: failure injection plan plus checkpoint
    /// cadence. When enabled, jobs run through the recoverable engine
    /// ([`crate::fault::engine`]).
    pub fault: FaultConfig,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        Self {
            nodes: 1,
            workers_per_node: 4,
            network: NetworkModel::aws_10gbps(),
            engine: EngineKind::Eager,
            alloc: AllocMode::System,
            seed: 0xB1A2E,
            thread_cache_entries: 1 << 16,
            conventional_overhead_sec: 250e-9,
            conventional_job_latency_sec: 20e-3,
            fault: FaultConfig::disabled(),
        }
    }
}

impl ClusterConfig {
    /// `nodes` × `workers` with all other settings default.
    pub fn sized(nodes: usize, workers_per_node: usize) -> Self {
        Self { nodes, workers_per_node, ..Self::default() }
    }

    /// Builder-style engine override.
    pub fn with_engine(mut self, engine: EngineKind) -> Self {
        self.engine = engine;
        self
    }

    /// Builder-style allocator override.
    pub fn with_alloc(mut self, alloc: AllocMode) -> Self {
        self.alloc = alloc;
        self
    }

    /// Builder-style network override.
    pub fn with_network(mut self, network: NetworkModel) -> Self {
        self.network = network;
        self
    }

    /// Builder-style seed override.
    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Builder-style fault-tolerance policy override.
    pub fn with_fault(mut self, fault: FaultConfig) -> Self {
        self.fault = fault;
        self
    }
}

struct ClusterInner {
    config: ClusterConfig,
    metrics: RefCell<MetricsRegistry>,
    pool: BufferPool,
}

/// Cheap-to-clone handle to a virtual cluster.
///
/// The simulation is single-threaded and deterministic (virtual parallelism
/// is *accounted*, see [`crate::net::vtime`]), so the handle is `Rc`-based
/// and the whole API is `!Send` by design.
#[derive(Clone)]
pub struct Cluster {
    inner: Rc<ClusterInner>,
}

impl Cluster {
    /// Cluster from an explicit config.
    pub fn new(config: ClusterConfig) -> Self {
        Self {
            inner: Rc::new(ClusterInner {
                config,
                metrics: RefCell::new(MetricsRegistry::default()),
                pool: BufferPool::new(),
            }),
        }
    }

    /// `nodes` × `workers` local cluster with defaults (loopback network
    /// when `nodes == 1`).
    pub fn local(nodes: usize, workers_per_node: usize) -> Self {
        let mut config = ClusterConfig::sized(nodes, workers_per_node);
        if nodes == 1 {
            config.network = NetworkModel::loopback();
        }
        Self::new(config)
    }

    /// Cluster configuration.
    pub fn config(&self) -> &ClusterConfig {
        &self.inner.config
    }

    /// Node count.
    pub fn nodes(&self) -> usize {
        self.inner.config.nodes
    }

    /// Workers per node.
    pub fn workers(&self) -> usize {
        self.inner.config.workers_per_node
    }

    /// Total virtual worker count.
    pub fn total_workers(&self) -> usize {
        self.nodes() * self.workers()
    }

    /// Mutable access to the metrics registry.
    pub fn metrics(&self) -> std::cell::RefMut<'_, MetricsRegistry> {
        self.inner.metrics.borrow_mut()
    }

    /// Scratch buffer pool (honours the configured [`AllocMode`]).
    pub fn pool(&self) -> &BufferPool {
        &self.inner.pool
    }

    /// True if two handles point at the same cluster.
    pub fn same_cluster(&self, other: &Cluster) -> bool {
        Rc::ptr_eq(&self.inner, &other.inner)
    }
}

impl std::fmt::Debug for Cluster {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Cluster")
            .field("nodes", &self.nodes())
            .field("workers_per_node", &self.workers())
            .field("engine", &self.config().engine)
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn local_single_node_uses_loopback() {
        let c = Cluster::local(1, 4);
        assert_eq!(c.config().network, NetworkModel::loopback());
        let c8 = Cluster::local(8, 4);
        assert_eq!(c8.config().network, NetworkModel::aws_10gbps());
        assert_eq!(c8.total_workers(), 32);
    }

    #[test]
    fn builder_chain() {
        let cfg = ClusterConfig::sized(4, 2)
            .with_engine(EngineKind::Conventional)
            .with_alloc(AllocMode::Pool)
            .with_seed(7);
        assert_eq!(cfg.nodes, 4);
        assert_eq!(cfg.engine, EngineKind::Conventional);
        assert_eq!(cfg.alloc, AllocMode::Pool);
        assert_eq!(cfg.seed, 7);
    }

    #[test]
    fn handles_share_state() {
        let a = Cluster::local(2, 2);
        let b = a.clone();
        assert!(a.same_cluster(&b));
        a.metrics().record_note("x");
        assert_eq!(b.metrics().notes().len(), 1);
    }
}
