//! Cluster coordination: topology/config, block scheduling, shuffle
//! orchestration with backpressure, shard rebalancing, metrics.
//!
//! The coordinator owns the *virtual* cluster: `N` nodes × `W` workers whose
//! compute is measured on the host and whose communication runs through the
//! simulated interconnect ([`crate::net`]). Everything is deterministic:
//! given a seed and a cluster shape, a run produces identical results and
//! identical byte counts.

pub mod backpressure;
pub mod cluster;
pub mod collectives;
pub mod metrics;
pub mod rebalance;
pub mod scheduler;
pub mod shuffle;

pub use cluster::{Cluster, ClusterConfig, EngineKind};
pub use metrics::{MetricsRegistry, RunStats};
