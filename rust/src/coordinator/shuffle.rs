//! Shuffle orchestration shared by both MapReduce engines.
//!
//! Takes per-(src → dst) serialized payloads, streams them through the
//! simulated network in bounded chunks (backpressure window per sender),
//! and hands each destination its received buffers. Returns the real flow
//! matrix plus peak in-flight bytes for the memory accounting.

use crate::net::sim::{FlowMatrix, NetSim};

use super::backpressure::WindowAccount;

/// Per-(src,dst) payloads for one shuffle: `payloads[src][dst]`.
/// `src == dst` entries bypass the network (node-local merge).
pub type ShufflePayloads = Vec<Vec<Vec<u8>>>;

/// How shuffle payloads move between virtual nodes. Orthogonal to the
/// engine algorithm: both modes produce byte-identical `delivered`
/// buffers, flows, and stall counts for the same payload matrix.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Transport {
    /// Simulated: payloads pass through [`NetSim`] mailboxes on the
    /// calling thread; cost is a flow-model output (the default, and
    /// the only mode for the simulated backend).
    #[default]
    FlowModel,
    /// Real: frames physically move through per-node bounded channels
    /// ([`crate::exec::transport`]) with measured wall time, queue
    /// peaks, and `FrameSent`/`TransportStall` trace events. Used by
    /// `Backend::Threaded(n)`.
    Channels,
}

/// Outcome of a shuffle execution.
#[derive(Debug)]
pub struct ShuffleResult {
    /// Real byte/message flows.
    pub flows: FlowMatrix,
    /// Per-destination received buffers `(src, chunk)` in delivery order,
    /// node-local payloads included (delivered without touching the net).
    pub delivered: Vec<Vec<(usize, Vec<u8>)>>,
    /// Peak in-flight serialized bytes summed over senders.
    pub peak_in_flight_bytes: u64,
    /// Total sender stalls (backpressure events).
    pub stalls: u64,
}

/// Chunk size for streaming large payloads (1 MiB).
pub const CHUNK_BYTES: usize = 1 << 20;

/// Execute a shuffle: chunk, stream with per-sender windows, deliver.
pub fn execute(payloads: ShufflePayloads, window_bytes: u64) -> ShuffleResult {
    let n = payloads.len();
    let mut net = NetSim::new(n);
    let mut delivered: Vec<Vec<(usize, Vec<u8>)>> = (0..n).map(|_| Vec::new()).collect();
    let mut peak = 0u64;
    let mut stalls = 0u64;

    for (src, dsts) in payloads.into_iter().enumerate() {
        assert_eq!(dsts.len(), n, "payload matrix must be n x n");
        let mut window = WindowAccount::new(window_bytes);
        for (dst, payload) in dsts.into_iter().enumerate() {
            if payload.is_empty() {
                continue;
            }
            if dst == src {
                // Node-local: no serialization transit, direct delivery.
                delivered[dst].push((src, payload));
                continue;
            }
            if payload.len() <= CHUNK_BYTES {
                let len = payload.len() as u64;
                window.push(len);
                net.send(src, dst, payload);
                window.drain(len); // receiver reduces as it lands
            } else {
                for chunk in payload.chunks(CHUNK_BYTES) {
                    window.push(chunk.len() as u64);
                    net.send(src, dst, chunk.to_vec());
                    window.drain(chunk.len() as u64);
                }
            }
        }
        peak += window.peak_bytes();
        stalls += window.stalls();
    }

    for dst in 0..n {
        delivered[dst].extend(net.recv_all(dst));
    }
    ShuffleResult { flows: net.take_flows(), delivered, peak_in_flight_bytes: peak, stalls }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn payloads(n: usize) -> ShufflePayloads {
        (0..n).map(|_| (0..n).map(|_| Vec::new()).collect()).collect()
    }

    #[test]
    fn local_payloads_bypass_network() {
        let mut p = payloads(2);
        p[0][0] = vec![1, 2, 3];
        let res = execute(p, 1 << 20);
        assert_eq!(res.flows.cross_node_bytes(), 0);
        assert_eq!(res.delivered[0], vec![(0, vec![1, 2, 3])]);
    }

    #[test]
    fn cross_node_counted_and_delivered() {
        let mut p = payloads(3);
        p[0][1] = vec![9; 10];
        p[2][1] = vec![8; 5];
        let res = execute(p, 1 << 20);
        assert_eq!(res.flows.cross_node_bytes(), 15);
        let total: usize = res.delivered[1].iter().map(|(_, b)| b.len()).sum();
        assert_eq!(total, 15);
    }

    #[test]
    fn large_payload_chunked() {
        let mut p = payloads(2);
        p[0][1] = vec![0u8; CHUNK_BYTES * 2 + 7];
        let res = execute(p, 1 << 20);
        assert_eq!(res.delivered[1].len(), 3, "3 chunks");
        assert_eq!(res.flows.cross_node_bytes() as usize, CHUNK_BYTES * 2 + 7);
        // Drained chunk-by-chunk → peak is one chunk.
        assert_eq!(res.peak_in_flight_bytes as usize, CHUNK_BYTES);
    }

    #[test]
    fn empty_shuffle() {
        let res = execute(payloads(4), 1 << 20);
        assert_eq!(res.flows.total_bytes(), 0);
        assert_eq!(res.stalls, 0);
    }
}
