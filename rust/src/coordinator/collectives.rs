//! Low-level parallel-computing primitives (the paper's "Blaze parallel
//! computing kernel", §2: "These APIs are built based on the Blaze parallel
//! computing kernel, which provides common low-level parallel computing
//! primitives").
//!
//! Tree-structured collectives over the virtual cluster with real
//! serialization and flow accounting: [`broadcast`], [`gather`],
//! [`reduce`], [`all_reduce`]. The MapReduce engines' tree reduce and the
//! containers' topk merge follow the same schedules; these standalone
//! versions are the substrate a Blaze user (or a new container) builds on.

use crate::coordinator::cluster::Cluster;
use crate::coordinator::metrics::RunStats;
use crate::mapreduce::reducers::Reducer;
use crate::net::sim::FlowMatrix;
use crate::net::vtime::VirtualTime;
use crate::ser::fastser::{FastSer, Reader, Writer};

/// Binomial-tree broadcast of `value` from `root` to every node. Returns
/// the per-node copies (index = node id).
pub fn broadcast<T: FastSer + Clone>(cluster: &Cluster, root: usize, value: &T) -> Vec<T> {
    let nodes = cluster.nodes();
    assert!(root < nodes);
    let mut vt = VirtualTime::new();
    let mut have: Vec<Option<T>> = vec![None; nodes];
    have[root] = Some(value.clone());
    let mut shuffle_bytes = 0u64;
    // Round r: every holder sends to (holder XOR 2^r) relative to root.
    let mut stride = 1usize;
    while stride < nodes {
        let mut flows = FlowMatrix::new(nodes);
        // Binomial: after round r the holders are rel 0..2^r; each holder
        // rel sends to rel + 2^r when in range.
        for rel in 0..stride.min(nodes) {
            let dst_rel = rel + stride;
            if dst_rel >= nodes {
                continue;
            }
            let src = (root + rel) % nodes;
            let dst = (root + dst_rel) % nodes;
            let v = have[src].clone().expect("holder must have value");
            let mut w = Writer::new();
            v.write(&mut w);
            flows.record(src, dst, w.len() as u64);
            shuffle_bytes += w.len() as u64;
            // Deserialize for real: the copy each node gets went through
            // the codec.
            let mut r = Reader::new(w.as_bytes());
            have[dst] = Some(T::read(&mut r).expect("broadcast payload"));
        }
        vt.shuffle_overlapped("bcast-round", &flows, &cluster.config().network, 0.0);
        stride *= 2;
    }
    record(cluster, "collective.broadcast", &vt, shuffle_bytes);
    have.into_iter().map(|v| v.expect("all nodes covered")).collect()
}

/// Gather per-node values to `root` (returned in node order).
pub fn gather<T: FastSer + Clone>(cluster: &Cluster, root: usize, values: &[T]) -> Vec<T> {
    let nodes = cluster.nodes();
    assert_eq!(values.len(), nodes);
    assert!(root < nodes);
    let mut vt = VirtualTime::new();
    let mut flows = FlowMatrix::new(nodes);
    let mut shuffle_bytes = 0u64;
    let mut out = Vec::with_capacity(nodes);
    for (node, v) in values.iter().enumerate() {
        if node == root {
            out.push(v.clone());
            continue;
        }
        let mut w = Writer::new();
        v.write(&mut w);
        flows.record(node, root, w.len() as u64);
        shuffle_bytes += w.len() as u64;
        let mut r = Reader::new(w.as_bytes());
        out.push(T::read(&mut r).expect("gather payload"));
    }
    vt.shuffle_overlapped("gather", &flows, &cluster.config().network, 0.0);
    record(cluster, "collective.gather", &vt, shuffle_bytes);
    out
}

/// Binomial-tree reduce of per-node partials to `root`.
pub fn reduce<T: FastSer + Clone>(
    cluster: &Cluster,
    root: usize,
    values: &[T],
    red: &Reducer<T>,
) -> T {
    let nodes = cluster.nodes();
    assert_eq!(values.len(), nodes);
    assert!(root < nodes);
    let mut vt = VirtualTime::new();
    let mut partials: Vec<Option<T>> =
        (0..nodes).map(|rel| Some(values[(root + rel) % nodes].clone())).collect();
    let mut shuffle_bytes = 0u64;
    let mut stride = 1usize;
    while stride < nodes {
        let mut flows = FlowMatrix::new(nodes);
        for rel in (stride..nodes).step_by(stride * 2) {
            let Some(v) = partials[rel].take() else { continue };
            let src = (root + rel) % nodes;
            let dst = (root + rel - stride) % nodes;
            let mut w = Writer::new();
            v.write(&mut w);
            flows.record(src, dst, w.len() as u64);
            shuffle_bytes += w.len() as u64;
            let mut r = Reader::new(w.as_bytes());
            let decoded = T::read(&mut r).expect("reduce payload");
            let acc = partials[rel - stride].as_mut().expect("destination partial");
            red.apply(acc, &decoded);
        }
        vt.shuffle_overlapped("reduce-round", &flows, &cluster.config().network, 0.0);
        stride *= 2;
    }
    record(cluster, "collective.reduce", &vt, shuffle_bytes);
    partials[0].take().expect("root partial")
}

/// Reduce to node 0, then broadcast the result — every node gets the total.
pub fn all_reduce<T: FastSer + Clone>(
    cluster: &Cluster,
    values: &[T],
    red: &Reducer<T>,
) -> Vec<T> {
    let total = reduce(cluster, 0, values, red);
    broadcast(cluster, 0, &total)
}

fn record(cluster: &Cluster, label: &str, vt: &VirtualTime, shuffle_bytes: u64) {
    cluster.metrics().record_run(RunStats {
        label: label.into(),
        engine: cluster.config().engine.to_string(),
        nodes: cluster.nodes(),
        workers_per_node: cluster.workers(),
        makespan_sec: vt.makespan(),
        shuffle_sec: vt.makespan(),
        shuffle_bytes,
        ..Default::default()
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn broadcast_reaches_every_node() {
        for nodes in [1usize, 2, 3, 5, 8] {
            let c = Cluster::local(nodes, 1);
            let copies = broadcast(&c, 0, &"payload".to_string());
            assert_eq!(copies.len(), nodes);
            assert!(copies.iter().all(|v| v == "payload"), "nodes={nodes}");
        }
    }

    #[test]
    fn broadcast_from_nonzero_root() {
        let c = Cluster::local(5, 1);
        let copies = broadcast(&c, 3, &42u64);
        assert_eq!(copies, vec![42; 5]);
    }

    #[test]
    fn broadcast_tree_is_log_rounds() {
        let c = Cluster::local(8, 1);
        broadcast(&c, 0, &vec![1u64; 1000]);
        let m = c.metrics();
        let run = m.last_run().unwrap();
        // 7 transfers of ~1001-byte payloads.
        assert!(run.shuffle_bytes > 7 * 900 && run.shuffle_bytes < 7 * 1200);
        // Tree depth 3, not a 7-step chain: the virtual time must beat a
        // serial send chain.
        let serial = 7.0 * (run.shuffle_bytes as f64 / 7.0)
            / c.config().network.nic_bytes_per_sec
            + 7.0 * c.config().network.latency_sec;
        assert!(run.makespan_sec < serial, "{} vs {serial}", run.makespan_sec);
    }

    #[test]
    fn gather_preserves_node_order() {
        let c = Cluster::local(4, 1);
        let vals: Vec<u64> = vec![10, 11, 12, 13];
        assert_eq!(gather(&c, 2, &vals), vals);
        assert!(c.metrics().last_run().unwrap().shuffle_bytes > 0);
    }

    #[test]
    fn reduce_sums_partials() {
        for nodes in [1usize, 2, 4, 7] {
            let c = Cluster::local(nodes, 1);
            let vals: Vec<u64> = (1..=nodes as u64).collect();
            let total = reduce(&c, 0, &vals, &Reducer::sum());
            assert_eq!(total, (nodes as u64) * (nodes as u64 + 1) / 2, "nodes={nodes}");
        }
    }

    #[test]
    fn reduce_to_nonzero_root_matches() {
        let c = Cluster::local(6, 1);
        let vals: Vec<u64> = vec![5, 1, 9, 2, 8, 3];
        let a = reduce(&c, 0, &vals, &Reducer::max());
        let b = reduce(&c, 4, &vals, &Reducer::max());
        assert_eq!(a, 9);
        assert_eq!(b, 9);
    }

    #[test]
    fn all_reduce_gives_total_everywhere() {
        let c = Cluster::local(4, 1);
        let vals: Vec<f64> = vec![1.5, 2.5, 3.0, 3.0];
        let out = all_reduce(&c, &vals, &Reducer::sum());
        assert_eq!(out, vec![10.0; 4]);
    }

    #[test]
    fn vector_payloads_reduce_elementwise() {
        let c = Cluster::local(3, 1);
        let vals = vec![vec![1.0f64, 2.0], vec![10.0, 20.0], vec![100.0, 200.0]];
        let total = reduce(&c, 0, &vals, &Reducer::sum());
        assert_eq!(total, vec![111.0, 222.0]);
    }
}
