//! Bounded-window backpressure for the shuffle path.
//!
//! The eager engine streams locally-reduced chunks to their destination
//! while reduce work proceeds asynchronously (paper §2.3.1). A sender may
//! only have `window_bytes` of serialized data in flight; beyond that it
//! stalls until the receiver drains. In virtual time a stall surfaces as the
//! `max(transfer, reduce)` overlap already modeled by
//! [`crate::net::vtime`]; what the window *additionally* bounds is memory:
//! peak in-flight bytes can never exceed the window, which is why the eager
//! engine's Fig-9 footprint stays flat while the conventional engine's grows
//! with the data.

/// In-flight byte window with stall accounting.
#[derive(Debug, Clone)]
pub struct WindowAccount {
    window_bytes: u64,
    in_flight: u64,
    peak: u64,
    stalls: u64,
}

/// Default shuffle window: 4 MiB per sender, matching common transport
/// tuning (MPI eager/rendezvous thresholds live far below this).
pub const DEFAULT_WINDOW_BYTES: u64 = 4 << 20;

impl WindowAccount {
    /// Window of `window_bytes` capacity.
    pub fn new(window_bytes: u64) -> Self {
        Self { window_bytes, in_flight: 0, peak: 0, stalls: 0 }
    }

    /// Would pushing `bytes` exceed the window?
    pub fn would_block(&self, bytes: u64) -> bool {
        self.in_flight + bytes > self.window_bytes
    }

    /// Push `bytes` into flight. If the window is exceeded the push still
    /// succeeds (a chunk is never split) but a stall is recorded — the
    /// virtual-time model charges the wait.
    pub fn push(&mut self, bytes: u64) {
        if self.would_block(bytes) {
            self.stalls += 1;
            // Sender waited for a full drain before pushing.
            self.in_flight = 0;
        }
        self.in_flight += bytes;
        self.peak = self.peak.max(self.in_flight);
    }

    /// Receiver drained `bytes`.
    pub fn drain(&mut self, bytes: u64) {
        self.in_flight = self.in_flight.saturating_sub(bytes);
    }

    /// Drain everything.
    pub fn drain_all(&mut self) {
        self.in_flight = 0;
    }

    /// Bytes currently in flight (the occupancy gauge the transport
    /// sampler snapshots after every push).
    pub fn in_flight(&self) -> u64 {
        self.in_flight
    }

    /// Highest in-flight byte count observed.
    pub fn peak_bytes(&self) -> u64 {
        self.peak
    }

    /// Number of times a sender had to wait for the receiver.
    pub fn stalls(&self) -> u64 {
        self.stalls
    }

    /// Configured window.
    pub fn window(&self) -> u64 {
        self.window_bytes
    }
}

impl Default for WindowAccount {
    fn default() -> Self {
        Self::new(DEFAULT_WINDOW_BYTES)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn peak_bounded_by_window_plus_chunk() {
        let mut w = WindowAccount::new(100);
        for _ in 0..50 {
            w.push(30);
        }
        // Peak can exceed window by at most one chunk (chunks are atomic).
        assert!(w.peak_bytes() <= 100 + 30, "peak {}", w.peak_bytes());
        assert!(w.stalls() > 0);
    }

    #[test]
    fn no_stall_when_drained() {
        let mut w = WindowAccount::new(100);
        for _ in 0..50 {
            w.push(30);
            assert_eq!(w.in_flight(), 30);
            w.drain(30);
            assert_eq!(w.in_flight(), 0);
        }
        assert_eq!(w.stalls(), 0);
        assert_eq!(w.peak_bytes(), 30);
    }

    #[test]
    fn oversized_chunk_records_stall_once() {
        let mut w = WindowAccount::new(10);
        w.push(100);
        assert_eq!(w.stalls(), 1);
        assert_eq!(w.peak_bytes(), 100);
    }
}
