//! Run metrics: virtual timings, byte counts, memory high-water marks.
//!
//! Every MapReduce execution records a [`RunStats`]; benches and the
//! experiment harness read them back to regenerate the paper's tables and
//! figures (throughput from virtual makespans, Fig 9 from the intermediate
//! memory accounting).

use crate::trace::histogram::Histogram;

/// Statistics for one MapReduce (or container-op) execution.
#[derive(Debug, Clone, Default)]
pub struct RunStats {
    /// Operation label ("wordcount.map", "pagerank.iter0.sinks", ...).
    pub label: String,
    /// Engine that ran it ("blaze" / "conventional").
    pub engine: String,
    /// Cluster shape.
    pub nodes: usize,
    /// Workers per node.
    pub workers_per_node: usize,
    /// Virtual makespan, seconds (the number the figures are built from).
    pub makespan_sec: f64,
    /// Virtual compute portion, seconds.
    pub compute_sec: f64,
    /// Virtual shuffle portion, seconds.
    pub shuffle_sec: f64,
    /// Cross-node bytes actually serialized and moved.
    pub shuffle_bytes: u64,
    /// Map-output bytes produced by the engine's serializer, including
    /// node-local blocks when its policy spills them (the conventional
    /// engine serializes every block; eager never serializes locally).
    /// Excludes checkpoint/restore/evacuation traffic.
    pub ser_bytes: u64,
    /// Bytes migrated by recovery-time slot evacuation (0 unless a failure
    /// was recovered with the evacuation policy).
    pub evac_bytes: u64,
    /// Pairs emitted by mappers (before any combining).
    pub pairs_emitted: u64,
    /// Pairs that crossed the network (after eager combine; == emitted for
    /// the conventional engine).
    pub pairs_shuffled: u64,
    /// Peak bytes held in intermediate state (thread caches + materialized
    /// pair buffers + in-flight serialized messages), summed over nodes.
    pub peak_intermediate_bytes: u64,
    /// Real host wall time spent executing the run, seconds.
    pub host_wall_sec: f64,
    /// Execution backend ("simulated", "threaded:N"; empty = simulated in
    /// runs recorded by code that predates the field).
    pub backend: String,
    /// Real wall-clock nanoseconds per engine phase, in phase order.
    /// Under `Backend::Threaded` the compute phases here are *parallel*
    /// wall time (the hybrid accounting's hardware-speed half); the
    /// virtual `makespan_sec` remains the modeled figure.
    pub phase_wall_ns: Vec<(String, u64)>,
    /// Run-global observability counters, sorted by name (flush counts,
    /// pool queue peaks, checkpoint bytes, …; see [`crate::trace::Counters`]).
    /// Observability only — values like queue peaks depend on real
    /// scheduling and are *not* part of any determinism gate.
    pub counters: Vec<(String, u64)>,
    /// Per-node counters (indexed by node), each sorted by name.
    pub node_counters: Vec<Vec<(String, u64)>>,
    /// Run-global latency/size histograms, sorted by name
    /// ([`crate::trace::histogram::Histograms::finish`]). Series without a
    /// `wall.` name prefix record pure functions of the seeded workload
    /// (map-block item counts, flush entry counts, shuffle frame chunk
    /// sizes) and are byte-identical across backends — the equivalence
    /// harness gates their encodings. `wall.`-prefixed series carry real
    /// host time and are observability-only.
    pub histograms: Vec<(String, Histogram)>,
}

impl RunStats {
    /// Items/second throughput for `items` processed in this run.
    pub fn throughput(&self, items: u64) -> f64 {
        items as f64 / self.makespan_sec
    }

    /// Total real wall nanoseconds across all recorded phases.
    pub fn wall_ns_total(&self) -> u64 {
        self.phase_wall_ns.iter().map(|(_, ns)| ns).sum()
    }

    /// Wall nanoseconds of one named phase, if recorded. Duplicate phase
    /// names *sum*: the recoverable engine can run the same phase more
    /// than once (recovery replays), and the first-match behavior this
    /// replaces silently dropped every repeat.
    pub fn wall_ns(&self, phase: &str) -> Option<u64> {
        let mut total = 0u64;
        let mut found = false;
        for (p, ns) in &self.phase_wall_ns {
            if p == phase {
                total += ns;
                found = true;
            }
        }
        found.then_some(total)
    }

    /// One run-global counter by name, if recorded.
    pub fn counter(&self, name: &str) -> Option<u64> {
        self.counters.iter().find(|(n, _)| n == name).map(|&(_, v)| v)
    }

    /// One node's counter by name, if recorded.
    pub fn node_counter(&self, node: usize, name: &str) -> Option<u64> {
        self.node_counters
            .get(node)?
            .iter()
            .find(|(n, _)| n == name)
            .map(|&(_, v)| v)
    }

    /// One run-global histogram by name, if recorded.
    pub fn histogram(&self, name: &str) -> Option<&Histogram> {
        self.histograms.iter().find(|(n, _)| n == name).map(|(_, h)| h)
    }

    /// Internal-consistency checks, run on every `record_run` in debug
    /// builds. Two invariants every engine must hold:
    ///
    /// 1. The sum of per-phase wall times never exceeds the whole-run
    ///    host wall clock — *excluding* the `transport` entry, which is a
    ///    sub-interval of the shuffle phase (threaded backend) and would
    ///    double-count. A microsecond of slack absorbs the f64 rounding
    ///    of `host_wall_sec` (stored as seconds, compared in ns).
    /// 2. No phase name repeats within one engine pass: phase wall times
    ///    are recorded once per phase, and `wall_ns` *sums* duplicates —
    ///    so an engine accidentally recording a phase twice would
    ///    silently inflate its reported time.
    pub fn debug_validate(&self) {
        let host_ns = self.host_wall_sec * 1e9 + 1_000.0;
        let phase_sum: u64 = self
            .phase_wall_ns
            .iter()
            .filter(|(p, _)| p != "transport")
            .map(|(_, ns)| ns)
            .sum();
        debug_assert!(
            phase_sum as f64 <= host_ns,
            "{}: phase wall sum {phase_sum}ns exceeds host wall {:.9}s",
            self.label,
            self.host_wall_sec
        );
        for (i, (name, _)) in self.phase_wall_ns.iter().enumerate() {
            debug_assert!(
                !self.phase_wall_ns[..i].iter().any(|(p, _)| p == name),
                "{}: duplicate phase name {name:?} in phase_wall_ns",
                self.label
            );
        }
    }
}

/// Cluster-wide metrics registry.
#[derive(Debug, Default)]
pub struct MetricsRegistry {
    runs: Vec<RunStats>,
    notes: Vec<String>,
}

impl MetricsRegistry {
    /// Record a completed run (consistency-checked in debug builds).
    pub fn record_run(&mut self, stats: RunStats) {
        stats.debug_validate();
        self.runs.push(stats);
    }

    /// Most recent run, if any.
    pub fn last_run(&self) -> Option<&RunStats> {
        self.runs.last()
    }

    /// All recorded runs.
    pub fn runs(&self) -> &[RunStats] {
        &self.runs
    }

    /// Drop recorded runs (benches reset between configurations).
    pub fn clear(&mut self) {
        self.runs.clear();
        self.notes.clear();
    }

    /// Number of runs since the last clear.
    pub fn len(&self) -> usize {
        self.runs.len()
    }

    /// True if no runs recorded.
    pub fn is_empty(&self) -> bool {
        self.runs.is_empty()
    }

    /// Sum of virtual makespans over runs whose label starts with `prefix`
    /// (a multi-MapReduce job like one PageRank iteration).
    pub fn job_makespan(&self, prefix: &str) -> f64 {
        self.runs
            .iter()
            .filter(|r| r.label.starts_with(prefix))
            .map(|r| r.makespan_sec)
            .sum()
    }

    /// Max peak intermediate bytes over runs with the given label prefix.
    pub fn job_peak_bytes(&self, prefix: &str) -> u64 {
        self.runs
            .iter()
            .filter(|r| r.label.starts_with(prefix))
            .map(|r| r.peak_intermediate_bytes)
            .max()
            .unwrap_or(0)
    }

    /// Total shuffle bytes over runs with the given label prefix.
    pub fn job_shuffle_bytes(&self, prefix: &str) -> u64 {
        self.runs
            .iter()
            .filter(|r| r.label.starts_with(prefix))
            .map(|r| r.shuffle_bytes)
            .sum()
    }

    /// Free-form annotation (experiment provenance).
    pub fn record_note(&mut self, note: impl Into<String>) {
        self.notes.push(note.into());
    }

    /// Recorded annotations.
    pub fn notes(&self) -> &[String] {
        &self.notes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stats(label: &str, makespan: f64, peak: u64) -> RunStats {
        RunStats {
            label: label.into(),
            makespan_sec: makespan,
            peak_intermediate_bytes: peak,
            shuffle_bytes: 10,
            ..Default::default()
        }
    }

    #[test]
    fn job_aggregation_by_prefix() {
        let mut m = MetricsRegistry::default();
        m.record_run(stats("pr.iter0.sinks", 1.0, 100));
        m.record_run(stats("pr.iter0.scores", 2.0, 300));
        m.record_run(stats("pr.iter0.delta", 0.5, 50));
        m.record_run(stats("other", 9.0, 900));
        assert!((m.job_makespan("pr.iter0") - 3.5).abs() < 1e-12);
        assert_eq!(m.job_peak_bytes("pr.iter0"), 300);
        assert_eq!(m.job_shuffle_bytes("pr.iter0"), 30);
    }

    #[test]
    fn throughput() {
        let s = stats("x", 2.0, 0);
        assert!((s.throughput(100) - 50.0).abs() < 1e-12);
    }

    #[test]
    fn wall_ns_helpers() {
        let mut s = stats("x", 1.0, 0);
        s.phase_wall_ns = vec![("map".into(), 100), ("shuffle".into(), 50)];
        assert_eq!(s.wall_ns_total(), 150);
        assert_eq!(s.wall_ns("map"), Some(100));
        assert_eq!(s.wall_ns("none"), None);
    }

    #[test]
    fn wall_ns_sums_duplicate_phases() {
        // Recovery replays record the same phase label more than once; the
        // old first-match lookup silently dropped every repeat.
        let mut s = stats("x", 1.0, 0);
        s.phase_wall_ns =
            vec![("map".into(), 100), ("restore".into(), 30), ("map".into(), 25)];
        assert_eq!(s.wall_ns("map"), Some(125));
        assert_eq!(s.wall_ns("restore"), Some(30));
        assert_eq!(s.wall_ns_total(), 155);
        assert_eq!(s.wall_ns("absent"), None);
    }

    #[test]
    fn counter_lookups() {
        let mut s = stats("x", 1.0, 0);
        s.counters = vec![("cache.flushes".into(), 5), ("pool.queue_peak".into(), 3)];
        s.node_counters = vec![vec![("cache.flushes".into(), 2)], vec![]];
        assert_eq!(s.counter("cache.flushes"), Some(5));
        assert_eq!(s.counter("nope"), None);
        assert_eq!(s.node_counter(0, "cache.flushes"), Some(2));
        assert_eq!(s.node_counter(1, "cache.flushes"), None);
        assert_eq!(s.node_counter(9, "cache.flushes"), None);
    }

    #[test]
    fn histogram_lookup() {
        let mut s = stats("x", 1.0, 0);
        let mut h = Histogram::new();
        h.record(8);
        h.record(100);
        s.histograms = vec![("map.block_items".into(), h)];
        assert_eq!(s.histogram("map.block_items").unwrap().count(), 2);
        assert_eq!(s.histogram("map.block_items").unwrap().max_value(), 100);
        assert!(s.histogram("absent").is_none());
    }

    #[test]
    fn debug_validate_accepts_consistent_stats() {
        let mut s = stats("ok", 1.0, 0);
        s.host_wall_sec = 1.0;
        s.phase_wall_ns = vec![
            ("map+local-reduce".into(), 600_000_000),
            ("shuffle+absorb".into(), 400_000_000),
            // `transport` is a sub-interval of shuffle+absorb on the
            // threaded backend; it is excluded from the sum, so stats
            // where including it would exceed host wall still validate.
            ("transport".into(), 300_000_000),
        ];
        s.debug_validate();
        let mut m = MetricsRegistry::default();
        m.record_run(s);
        assert_eq!(m.len(), 1);
    }

    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "exceeds host wall")]
    fn debug_validate_rejects_phase_sum_over_host_wall() {
        let mut s = stats("bad", 1.0, 0);
        s.host_wall_sec = 0.001;
        s.phase_wall_ns = vec![("map+local-reduce".into(), 2_000_000)];
        s.debug_validate();
    }

    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "duplicate phase name")]
    fn debug_validate_rejects_duplicate_phase_names() {
        let mut s = stats("dup", 1.0, 0);
        s.host_wall_sec = 1.0;
        s.phase_wall_ns = vec![("map".into(), 10), ("map".into(), 20)];
        s.debug_validate();
    }

    #[test]
    fn clear_resets() {
        let mut m = MetricsRegistry::default();
        m.record_run(stats("a", 1.0, 0));
        m.record_note("n");
        m.clear();
        assert!(m.is_empty());
        assert!(m.notes().is_empty());
    }
}
