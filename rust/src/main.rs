//! Blaze CLI — launcher for the paper's workloads on the virtual cluster.
//!
//! Hand-rolled argument parsing (the build is offline; no clap). See
//! `blaze --help` for usage. Each subcommand runs one of the paper's five
//! data-mining tasks (or Monte-Carlo π) on a configurable cluster shape and
//! prints the paper's metric for that task. `blaze report` instead diffs
//! two `BENCH_*.json` artifact sets as a perf regression gate.

use blaze::cli;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    std::process::exit(cli::run(&args));
}
