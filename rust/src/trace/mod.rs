//! Structured tracing — deterministic per-run event logs, per-node
//! counters, and exporters.
//!
//! Observability used to be three disconnected scraps: `RunStats`
//! aggregates, free-form fault-engine notes, and a flat `phase_wall_ns`
//! vector. This module replaces them with one typed event stream per job:
//!
//! * Engines fill a [`TraceBuf`] with [`TraceEvent`]s as they run. The
//!   buffer is a no-op unless tracing is on (`ClusterConfig::trace`,
//!   CLI `--trace PATH`, env `BLAZE_TRACE`).
//! * Sequencing is designed for determinism. The simulated engines push
//!   in their natural order, which *is* the canonical order (node
//!   ascending, worker ascending, flushes interleaved where they
//!   happened). The threaded backend cannot control which OS thread
//!   finishes first, so its map-phase events carry computed sort keys —
//!   [`map_seq`]`(block, flush)` for overflow flushes,
//!   [`block_done_seq`]`(block)` for block completion — and
//!   [`TraceBuf::seal_map`] pins every later (serial, post-map) event
//!   above them. Sorting by key restores exactly the simulated order.
//! * [`TraceCollector`] (owned by `Cluster`, one per run sequence)
//!   absorbs per-job buffers and exports two views:
//!   [`TraceCollector::canonical_jsonl`] — schedule-invariant fields
//!   only, **byte-identical** across the simulated engine and
//!   `threaded:{1,2,4}` for failure-free seeded single-stage runs (gated
//!   by `rust/tests/equivalence.rs`) — and
//!   [`TraceCollector::chrome_json`], a `chrome://tracing` /
//!   `ui.perfetto.dev` loadable timeline carrying the virtual-time
//!   intervals (and real wall-clock stamps where the threaded backend
//!   recorded them). Virtual/wall stamps derive from measured host time,
//!   so they are *excluded* from the canonical view by construction.
//! * [`Counters`] is the per-node counter registry surfaced on
//!   `RunStats::counters` / `node_counters` (map items/emits, cache
//!   flush counts and high-water bytes, pool queue depth and per-thread
//!   block counts, shard-stripe contention, checkpoint/restore/
//!   evacuation bytes). Counters are observability, **not** part of the
//!   determinism gate: queue peaks and lock contention depend on real
//!   scheduling.
//!
//! The fault engine's old free-form notes are now a *rendered view* of
//! typed events ([`TraceEvent::render_note`]): the engine records the
//! event, renders the byte-identical legacy note text from it, and the
//! note-matching tests stay green.

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::io;
use std::path::Path;

use crate::net::vtime::VirtualTime;

pub mod histogram;

/// Typed payload of one trace event.
///
/// Field values in map-phase and shuffle-phase events are pure functions
/// of the seeded workload (never of measured time or thread scheduling),
/// which is what makes the canonical export comparable across backends.
#[derive(Debug, Clone, PartialEq)]
pub enum TraceEventKind {
    /// One map block (one virtual worker's partition slice) finished.
    /// `exec_node`/`epoch` only differ from the home node / 1 under the
    /// recoverable engine (re-execution on a survivor).
    MapBlock { items: u64, emitted: u64, exec_node: usize, epoch: u32 },
    /// A bounded eager cache overflowed and drained into the node-local
    /// map (`entries` keys, `bytes` modeled cache bytes at drain).
    CacheFlush { entries: u64, bytes: u64 },
    /// A serialized cross-node partial left `node` for `dst`.
    Shuffle { dst: usize, bytes: u64, pairs: u64 },
    /// A partial was reduced into `node`'s shard (from node `from`).
    Reduce { from: usize, pairs: u64 },
    /// A checkpoint captured all target shards after `commit` commits.
    Checkpoint { commit: usize, bytes: u64 },
    /// A failure trigger killed `victim`; its shard was restored from the
    /// latest checkpoint (`restore_bytes` driver→replacement traffic).
    Kill { victim: usize, restore_bytes: u64 },
    /// A planned kill was ignored (driver, out of range, already dead).
    KillIgnored { victim: usize },
    /// A planned kill never came due before the job finished; `trigger`
    /// is the debug-rendered trigger (e.g. `AtBlock(7)`).
    KillDropped { victim: usize, trigger: String },
    /// A post-checkpoint commit into the lost shard was rolled back.
    Rollback { block: usize, shard: usize },
    /// A rolled-back block was re-executed on `exec_node`.
    Replay { block: usize, exec_node: usize },
    /// Dead nodes' key spaces were re-homed onto survivors (`--evacuate`).
    Evacuate { victims: Vec<usize>, bytes: u64 },
    /// The target cannot re-home keys; hot-standby restore kept.
    EvacFallback { victims: Vec<usize> },
    /// One migration flow of an evacuation.
    Migrate { src: usize, dst: usize, bytes: u64 },
    /// Real transport: all frames one source shipped to `dst` through its
    /// bounded channel ([`crate::exec::transport`]). Threaded backend
    /// only — **chrome-view only** (see [`TraceEventKind::chrome_only`]):
    /// the simulated backend never emits it, so including it in the
    /// canonical export would break cross-backend byte-identity.
    FrameSent { dst: usize, frames: u64, bytes: u64 },
    /// Real transport: frames from one source that exceeded the
    /// backpressure window toward `dst` and had to wait for a drain.
    /// Chrome-view only, like [`TraceEventKind::FrameSent`].
    TransportStall { dst: usize, stalls: u64 },
    /// A mid-block kill ([`crate::fault::FailureTrigger::AtItem`]) aborted
    /// `victim`'s in-flight map of `block` after `items` input items; the
    /// partial attempt was discarded and the block re-entered the pending
    /// set. Deterministic across backends, so it lives in the canonical
    /// export (the paired Kill event follows it).
    MidblockAbort { block: usize, victim: usize, items: u64 },
    /// Lossy transport: one send attempt of frame `seq` toward `dst` was
    /// dropped (or corrupted and rejected by the receiver's frame
    /// checksum) under the active `TransportFaultPlan`. Chrome-view only.
    FrameDropped { dst: usize, seq: u64, attempt: u32, corrupt: bool },
    /// Lossy transport: frame `seq` toward `dst` was retransmitted as
    /// attempt `attempt` after `backoff_ns` of (virtual) exponential
    /// backoff. Chrome-view only.
    FrameRetried { dst: usize, seq: u64, attempt: u32, backoff_ns: u64 },
    /// Lossy transport: every retry toward `dst` exhausted; the per-node
    /// delivery timeout declared it dead. Chrome-view only.
    NodeTimedOut { dst: usize, attempts: u32 },
    /// End-of-job recovery bookkeeping (the old `fault[...]` note).
    FaultSummary {
        checkpoints: u64,
        checkpoint_bytes: u64,
        failures: u64,
        ignored: u64,
        reassigned: u64,
        replayed: u64,
        restore_bytes: u64,
        evacuations: u64,
        evac_bytes: u64,
        max_epoch: u32,
    },
}

impl TraceEventKind {
    /// Stable kind name used by both exporters.
    pub fn name(&self) -> &'static str {
        match self {
            Self::MapBlock { .. } => "MapBlock",
            Self::CacheFlush { .. } => "CacheFlush",
            Self::Shuffle { .. } => "Shuffle",
            Self::Reduce { .. } => "Reduce",
            Self::Checkpoint { .. } => "Checkpoint",
            Self::Kill { .. } => "Kill",
            Self::KillIgnored { .. } => "KillIgnored",
            Self::KillDropped { .. } => "KillDropped",
            Self::Rollback { .. } => "Rollback",
            Self::Replay { .. } => "Replay",
            Self::Evacuate { .. } => "Evacuate",
            Self::EvacFallback { .. } => "EvacFallback",
            Self::Migrate { .. } => "Migrate",
            Self::FrameSent { .. } => "FrameSent",
            Self::TransportStall { .. } => "TransportStall",
            Self::MidblockAbort { .. } => "MidblockAbort",
            Self::FrameDropped { .. } => "FrameDropped",
            Self::FrameRetried { .. } => "FrameRetried",
            Self::NodeTimedOut { .. } => "NodeTimedOut",
            Self::FaultSummary { .. } => "FaultSummary",
        }
    }

    /// True for kinds that exist only on the real (threaded) transport
    /// and therefore appear only in the Chrome view. The canonical JSONL
    /// export skips them: a simulated run moves no real frames, and the
    /// canonical log must stay byte-identical across backends.
    pub fn chrome_only(&self) -> bool {
        matches!(
            self,
            Self::FrameSent { .. }
                | Self::TransportStall { .. }
                | Self::FrameDropped { .. }
                | Self::FrameRetried { .. }
                | Self::NodeTimedOut { .. }
        )
    }

    /// Append this kind's fields as `,"k":v` JSON pairs.
    fn write_fields(&self, out: &mut String) {
        match self {
            Self::MapBlock { items, emitted, exec_node, epoch } => {
                let _ = write!(
                    out,
                    ",\"items\":{items},\"emitted\":{emitted},\"exec_node\":{exec_node},\"epoch\":{epoch}"
                );
            }
            Self::CacheFlush { entries, bytes } => {
                let _ = write!(out, ",\"entries\":{entries},\"bytes\":{bytes}");
            }
            Self::Shuffle { dst, bytes, pairs } => {
                let _ = write!(out, ",\"dst\":{dst},\"bytes\":{bytes},\"pairs\":{pairs}");
            }
            Self::Reduce { from, pairs } => {
                let _ = write!(out, ",\"from\":{from},\"pairs\":{pairs}");
            }
            Self::Checkpoint { commit, bytes } => {
                let _ = write!(out, ",\"commit\":{commit},\"bytes\":{bytes}");
            }
            Self::Kill { victim, restore_bytes } => {
                let _ = write!(out, ",\"victim\":{victim},\"restore_bytes\":{restore_bytes}");
            }
            Self::KillIgnored { victim } => {
                let _ = write!(out, ",\"victim\":{victim}");
            }
            Self::KillDropped { victim, trigger } => {
                let _ = write!(out, ",\"victim\":{victim},\"trigger\":\"");
                escape_into(trigger, out);
                out.push('"');
            }
            Self::Rollback { block, shard } => {
                let _ = write!(out, ",\"block\":{block},\"shard\":{shard}");
            }
            Self::Replay { block, exec_node } => {
                let _ = write!(out, ",\"block\":{block},\"exec_node\":{exec_node}");
            }
            Self::Evacuate { victims, bytes } => {
                out.push_str(",\"victims\":");
                write_usize_list(victims, out);
                let _ = write!(out, ",\"bytes\":{bytes}");
            }
            Self::EvacFallback { victims } => {
                out.push_str(",\"victims\":");
                write_usize_list(victims, out);
            }
            Self::Migrate { src, dst, bytes } => {
                let _ = write!(out, ",\"src\":{src},\"dst\":{dst},\"bytes\":{bytes}");
            }
            Self::FrameSent { dst, frames, bytes } => {
                let _ = write!(out, ",\"dst\":{dst},\"frames\":{frames},\"bytes\":{bytes}");
            }
            Self::TransportStall { dst, stalls } => {
                let _ = write!(out, ",\"dst\":{dst},\"stalls\":{stalls}");
            }
            Self::MidblockAbort { block, victim, items } => {
                let _ = write!(out, ",\"block\":{block},\"victim\":{victim},\"items\":{items}");
            }
            Self::FrameDropped { dst, seq, attempt, corrupt } => {
                let _ = write!(
                    out,
                    ",\"dst\":{dst},\"seq\":{seq},\"attempt\":{attempt},\"corrupt\":{corrupt}"
                );
            }
            Self::FrameRetried { dst, seq, attempt, backoff_ns } => {
                let _ = write!(
                    out,
                    ",\"dst\":{dst},\"seq\":{seq},\"attempt\":{attempt},\"backoff_ns\":{backoff_ns}"
                );
            }
            Self::NodeTimedOut { dst, attempts } => {
                let _ = write!(out, ",\"dst\":{dst},\"attempts\":{attempts}");
            }
            Self::FaultSummary {
                checkpoints,
                checkpoint_bytes,
                failures,
                ignored,
                reassigned,
                replayed,
                restore_bytes,
                evacuations,
                evac_bytes,
                max_epoch,
            } => {
                let _ = write!(
                    out,
                    ",\"checkpoints\":{checkpoints},\"checkpoint_bytes\":{checkpoint_bytes},\
                     \"failures\":{failures},\"ignored\":{ignored},\"reassigned\":{reassigned},\
                     \"replayed\":{replayed},\"restore_bytes\":{restore_bytes},\
                     \"evacuations\":{evacuations},\"evac_bytes\":{evac_bytes},\
                     \"max_epoch\":{max_epoch}"
                );
            }
        }
    }
}

/// One trace event: a typed payload stamped with where it happened
/// (node, virtual worker), when in the phase plan (`phase`, `phase_ix`
/// for repeated phases like tree-reduce rounds), and — after
/// [`TraceBuf::stamp_phases`] — the virtual-time interval. The threaded
/// backend additionally stamps real wall-clock offsets (ns since the
/// map phase started).
#[derive(Debug, Clone, PartialEq)]
pub struct TraceEvent {
    /// Sort key restoring canonical order (see module docs).
    pub seq: u64,
    /// Home node of the event.
    pub node: usize,
    /// Virtual worker, when the event is worker-scoped.
    pub worker: Option<usize>,
    /// Virtual-time phase label this event belongs to.
    pub phase: &'static str,
    /// Occurrence index for repeated phase labels (tree-reduce rounds).
    pub phase_ix: u16,
    /// Virtual-time interval (seconds since job start), stamped post-hoc.
    pub vt: Option<(f64, f64)>,
    /// Real wall-clock interval (ns offsets), threaded backend only.
    pub wall_ns: Option<(u64, u64)>,
    /// Typed payload.
    pub kind: TraceEventKind,
}

impl TraceEvent {
    /// New event with no sequence key, phase occurrence 0, no stamps.
    pub fn new(
        node: usize,
        worker: Option<usize>,
        phase: &'static str,
        kind: TraceEventKind,
    ) -> Self {
        Self { seq: 0, node, worker, phase, phase_ix: 0, vt: None, wall_ns: None, kind }
    }

    /// Set the phase occurrence index (e.g. the tree-reduce round).
    pub fn at_phase_ix(mut self, ix: u16) -> Self {
        self.phase_ix = ix;
        self
    }

    /// Attach a real wall-clock interval (ns offsets from phase start).
    pub fn with_wall(mut self, start_ns: u64, end_ns: u64) -> Self {
        self.wall_ns = Some((start_ns, end_ns));
        self
    }

    /// Render the legacy free-form metrics note this event replaces, or
    /// `None` for kinds that never had one. Byte-identical to the strings
    /// the fault engine used to format inline — the note-matching tests
    /// in `rust/tests/fault.rs` gate this.
    pub fn render_note(&self, label: &str) -> Option<String> {
        match &self.kind {
            TraceEventKind::KillIgnored { victim } => {
                Some(format!("fault[{label}]: ignored kill of node {victim}"))
            }
            TraceEventKind::KillDropped { victim, trigger } => Some(format!(
                "fault[{label}]: kill of node {victim} never fired ({trigger})"
            )),
            TraceEventKind::EvacFallback { victims } => Some(format!(
                "fault[{label}]: target cannot re-home keys; hot-standby restore kept for nodes {victims:?}"
            )),
            TraceEventKind::FaultSummary {
                checkpoints,
                checkpoint_bytes,
                failures,
                ignored,
                reassigned,
                replayed,
                restore_bytes,
                evacuations,
                evac_bytes,
                max_epoch,
            } => Some(format!(
                "fault[{label}]: checkpoints={checkpoints} ckpt_bytes={checkpoint_bytes} \
                 failures={failures} ignored={ignored} reassigned={reassigned} \
                 replayed={replayed} restore_bytes={restore_bytes} evacuations={evacuations} \
                 evac_bytes={evac_bytes} max_epoch={max_epoch}"
            )),
            _ => None,
        }
    }

    /// One canonical JSONL line: schedule-invariant fields only (no seq,
    /// no virtual/wall stamps), fixed key order.
    fn write_canonical(&self, job: &str, out: &mut String) {
        out.push_str("{\"job\":\"");
        escape_into(job, out);
        out.push_str("\",\"ev\":\"");
        out.push_str(self.kind.name());
        let _ = write!(out, "\",\"node\":{}", self.node);
        match self.worker {
            Some(w) => {
                let _ = write!(out, ",\"worker\":{w}");
            }
            None => out.push_str(",\"worker\":null"),
        }
        out.push_str(",\"phase\":\"");
        escape_into(self.phase, out);
        let _ = write!(out, "\",\"phase_ix\":{}", self.phase_ix);
        self.kind.write_fields(out);
        out.push_str("}\n");
    }

    /// One Chrome trace-event object (`ph:"X"` complete event; `ts`/`dur`
    /// in microseconds of virtual time; wall stamps in `args`).
    fn write_chrome(&self, job: &str, out: &mut String) {
        let (start, end) = self.vt.unwrap_or((0.0, 0.0));
        let ts_us = start * 1e6;
        let dur_us = (end - start).max(0.0) * 1e6;
        out.push_str("{\"name\":\"");
        out.push_str(self.kind.name());
        out.push_str("\",\"cat\":\"");
        escape_into(job, out);
        let _ = write!(
            out,
            "\",\"ph\":\"X\",\"pid\":{},\"tid\":{},\"ts\":{ts_us},\"dur\":{dur_us}",
            self.node,
            self.worker.unwrap_or(0)
        );
        out.push_str(",\"args\":{\"phase\":\"");
        escape_into(self.phase, out);
        let _ = write!(out, "\",\"phase_ix\":{},\"seq\":{}", self.phase_ix, self.seq);
        self.kind.write_fields(out);
        if let Some((ws, we)) = self.wall_ns {
            let _ = write!(out, ",\"wall_start_ns\":{ws},\"wall_end_ns\":{we}");
        }
        out.push_str("}}");
    }
}

/// One occupancy sample: the value of a named gauge (pool queue depth,
/// busy threads, transport in-flight window bytes) observed at one point
/// during a phase. Samples exist for the Chrome view only — occupancy is
/// real-scheduling state, so the canonical export never sees them — and
/// are placed on the virtual-time axis at deterministic ticks by
/// [`TraceBuf::stamp_phases`]: the `i`-th of `n` samples of a series
/// within a phase span lands at `start + (i+1)/(n+1) · span`, preserving
/// observation order without importing wall-clock jitter into `ts`.
#[derive(Debug, Clone, PartialEq)]
pub struct CounterSample {
    /// Node the gauge belongs to (`pid` in the Chrome view).
    pub node: usize,
    /// Virtual-time phase label the sample belongs to.
    pub phase: &'static str,
    /// Occurrence index for repeated phase labels (tree-reduce rounds).
    pub phase_ix: u16,
    /// Gauge name (`pool.queue_depth`, `transport.in_flight_bytes`, …).
    pub name: &'static str,
    /// Observed gauge value.
    pub value: u64,
    /// Virtual timestamp (seconds), stamped by `stamp_phases`.
    pub vt: Option<f64>,
}

/// Sort key for a map-phase worker event: overflow flush `flush` of
/// block `block` (block = `node * workers + worker`).
pub fn map_seq(block: usize, flush: u32) -> u64 {
    ((block as u64) << 32) | flush as u64
}

/// Sort key for a map block's completion event — above every flush of
/// the same block, below every event of later blocks.
pub fn block_done_seq(block: usize) -> u64 {
    ((block as u64) << 32) | u64::from(u32::MAX)
}

/// Per-job event buffer an engine fills as it runs. All recording is a
/// no-op when tracing is disabled, so the hot paths pay one branch.
#[derive(Debug, Default)]
pub struct TraceBuf {
    enabled: bool,
    events: Vec<TraceEvent>,
    samples: Vec<CounterSample>,
    next_seq: u64,
}

impl TraceBuf {
    /// New buffer; `enabled = false` makes every method a no-op.
    pub fn new(enabled: bool) -> Self {
        Self { enabled, events: Vec::new(), samples: Vec::new(), next_seq: 0 }
    }

    /// Whether events are being recorded.
    pub fn enabled(&self) -> bool {
        self.enabled
    }

    /// Record an event with the next serial sequence key (engines whose
    /// natural emission order is already canonical).
    pub fn push(&mut self, mut ev: TraceEvent) {
        if !self.enabled {
            return;
        }
        ev.seq = self.next_seq;
        self.next_seq += 1;
        self.events.push(ev);
    }

    /// Record an event under an explicit sort key (threaded map phase).
    pub fn push_keyed(&mut self, seq: u64, mut ev: TraceEvent) {
        if !self.enabled {
            return;
        }
        ev.seq = seq;
        self.events.push(ev);
    }

    /// Absorb worker-collected events that already carry their keys.
    pub fn extend_keyed(&mut self, evs: Vec<TraceEvent>) {
        if !self.enabled {
            return;
        }
        self.events.extend(evs);
    }

    /// Record one occupancy sample (Chrome counter track). Observation
    /// order within a `(node, phase, phase_ix, name)` series is the only
    /// ordering that matters; timestamps are assigned later.
    pub fn push_sample(
        &mut self,
        node: usize,
        phase: &'static str,
        phase_ix: u16,
        name: &'static str,
        value: u64,
    ) {
        if !self.enabled {
            return;
        }
        self.samples.push(CounterSample { node, phase, phase_ix, name, value, vt: None });
    }

    /// Pin the serial counter above every map-phase key, so post-map
    /// events sort after all `total_blocks` blocks' worker events.
    pub fn seal_map(&mut self, total_blocks: usize) {
        if !self.enabled {
            return;
        }
        self.next_seq = self.next_seq.max((total_blocks as u64) << 32);
    }

    /// Stamp every event's virtual-time interval from the finished phase
    /// plan: event `(phase, phase_ix)` maps to the cumulative interval of
    /// the matching [`VirtualTime`] phase occurrence; unmatched labels
    /// fall back to the whole-job interval.
    pub fn stamp_phases(&mut self, vt: &VirtualTime) {
        if !self.enabled {
            return;
        }
        let mut spans: Vec<(&str, u16, (f64, f64))> = Vec::new();
        let mut occ: BTreeMap<&str, u16> = BTreeMap::new();
        let mut t = 0.0f64;
        for p in vt.phases() {
            let ix = occ.entry(p.label).or_insert(0);
            spans.push((p.label, *ix, (t, t + p.seconds)));
            *ix += 1;
            t += p.seconds;
        }
        let makespan = t;
        for ev in &mut self.events {
            let span = spans
                .iter()
                .find(|(l, ix, _)| *l == ev.phase && *ix == ev.phase_ix)
                .map(|&(_, _, s)| s);
            ev.vt = Some(span.unwrap_or((0.0, makespan)));
        }
        // Samples: spread each (node, phase, phase_ix, name) series evenly
        // across its phase span, in observation order — sample i of n
        // lands at start + (i+1)/(n+1)·len. Two passes: count, then place.
        let mut series: BTreeMap<(usize, &str, u16, &str), (u64, u64)> = BTreeMap::new();
        for s in &self.samples {
            series.entry((s.node, s.phase, s.phase_ix, s.name)).or_insert((0, 0)).0 += 1;
        }
        for s in &mut self.samples {
            let (start, end) = spans
                .iter()
                .find(|(l, ix, _)| *l == s.phase && *ix == s.phase_ix)
                .map(|&(_, _, sp)| sp)
                .unwrap_or((0.0, makespan));
            let e = series.get_mut(&(s.node, s.phase, s.phase_ix, s.name)).expect("counted");
            e.1 += 1;
            let frac = e.1 as f64 / (e.0 + 1) as f64;
            s.vt = Some(start + frac * (end - start));
        }
    }

    /// Number of recorded events.
    pub fn len(&self) -> usize {
        self.events.len()
    }

    /// True when nothing was recorded (always true when disabled).
    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }
}

/// One job's absorbed, canonically-ordered event log.
#[derive(Debug, Clone)]
pub struct JobTrace {
    /// The job label (`RunStats::label`).
    pub label: String,
    /// Events in canonical order.
    pub events: Vec<TraceEvent>,
    /// Occupancy samples in observation order (Chrome view only).
    pub samples: Vec<CounterSample>,
}

/// Collects every job's trace over a cluster's lifetime and exports the
/// canonical JSONL and Chrome views. Owned by `Cluster` behind a
/// `RefCell`; disabled collectors absorb nothing.
#[derive(Debug, Default)]
pub struct TraceCollector {
    enabled: bool,
    jobs: Vec<JobTrace>,
}

impl TraceCollector {
    /// New collector; disabled collectors ignore every absorb.
    pub fn new(enabled: bool) -> Self {
        Self { enabled, jobs: Vec::new() }
    }

    /// Whether tracing is active.
    pub fn enabled(&self) -> bool {
        self.enabled
    }

    /// Absorb one finished job's buffer, sorting into canonical order
    /// (stable, so serially-keyed engines keep their emission order).
    pub fn absorb_job(&mut self, label: &str, buf: TraceBuf) {
        if !self.enabled || !buf.enabled {
            return;
        }
        let mut events = buf.events;
        events.sort_by_key(|e| e.seq);
        self.jobs.push(JobTrace { label: label.to_string(), events, samples: buf.samples });
    }

    /// All absorbed jobs, in run order.
    pub fn jobs(&self) -> &[JobTrace] {
        &self.jobs
    }

    /// Total events across all jobs.
    pub fn event_count(&self) -> usize {
        self.jobs.iter().map(|j| j.events.len()).sum()
    }

    /// The canonical JSONL export: one line per event, schedule-invariant
    /// fields only. For seeded runs this string is byte-identical across
    /// the simulated engines and any `threaded:N` — the equivalence
    /// harness gates it. Transport-only kinds
    /// ([`TraceEventKind::chrome_only`]) are skipped: real frame movement
    /// has no simulated counterpart.
    pub fn canonical_jsonl(&self) -> String {
        let mut out = String::new();
        for job in &self.jobs {
            for ev in &job.events {
                if ev.kind.chrome_only() {
                    continue;
                }
                ev.write_canonical(&job.label, &mut out);
            }
        }
        out
    }

    /// The Chrome trace-event JSON export (`chrome://tracing`,
    /// `ui.perfetto.dev`): complete events on a virtual-time axis
    /// (microseconds), node as `pid`, virtual worker as `tid`, with wall
    /// stamps and payload fields under `args` — plus `ph:"C"` counter
    /// events rendering the occupancy samples as live gauge tracks next
    /// to the spans (queue depth, busy threads, in-flight window bytes).
    pub fn chrome_json(&self) -> String {
        let mut out = String::from("{\"traceEvents\":[");
        let mut first = true;
        for job in &self.jobs {
            for ev in &job.events {
                if !first {
                    out.push(',');
                }
                out.push('\n');
                first = false;
                ev.write_chrome(&job.label, &mut out);
            }
            for s in &job.samples {
                if !first {
                    out.push(',');
                }
                out.push('\n');
                first = false;
                let ts_us = s.vt.unwrap_or(0.0) * 1e6;
                out.push_str("{\"name\":\"");
                out.push_str(s.name);
                out.push_str("\",\"cat\":\"");
                escape_into(&job.label, &mut out);
                let _ = write!(
                    out,
                    "\",\"ph\":\"C\",\"pid\":{},\"ts\":{ts_us},\"args\":{{\"{}\":{}}}}}",
                    s.node, s.name, s.value
                );
            }
        }
        out.push_str("\n]}\n");
        out
    }

    /// Write both exports: canonical JSONL at `path`, Chrome JSON at
    /// `<path>.chrome.json`.
    pub fn export<P: AsRef<Path>>(&self, path: P) -> io::Result<()> {
        let path = path.as_ref();
        std::fs::write(path, self.canonical_jsonl())?;
        let mut chrome = path.as_os_str().to_os_string();
        chrome.push(".chrome.json");
        std::fs::write(chrome, self.chrome_json())
    }
}

/// Per-node counter registry for one run. Names are dotted lowercase
/// (`cache.flushes`, `pool.queue_peak`). `finish` folds per-node values
/// into the global totals and returns both sorted by name, ready for
/// `RunStats::counters` / `node_counters`.
#[derive(Debug)]
pub struct Counters {
    global: BTreeMap<String, u64>,
    per_node: Vec<BTreeMap<String, u64>>,
}

impl Counters {
    /// Fresh registry for a `nodes`-node run.
    pub fn new(nodes: usize) -> Self {
        Self { global: BTreeMap::new(), per_node: (0..nodes).map(|_| BTreeMap::new()).collect() }
    }

    /// Add to a run-global counter.
    pub fn add(&mut self, name: &str, v: u64) {
        *self.global.entry(name.to_string()).or_insert(0) += v;
    }

    /// Take the max of a run-global counter (peaks).
    pub fn max(&mut self, name: &str, v: u64) {
        let e = self.global.entry(name.to_string()).or_insert(0);
        *e = (*e).max(v);
    }

    /// Add to one node's counter.
    pub fn add_node(&mut self, node: usize, name: &str, v: u64) {
        *self.per_node[node].entry(name.to_string()).or_insert(0) += v;
    }

    /// Take the max of one node's counter (peaks).
    pub fn max_node(&mut self, node: usize, name: &str, v: u64) {
        let e = self.per_node[node].entry(name.to_string()).or_insert(0);
        *e = (*e).max(v);
    }

    /// Finish the run: per-node counters sum into the global map (so
    /// `counters` always carries a total for every per-node name), both
    /// returned sorted by name.
    pub fn finish(mut self) -> (Vec<(String, u64)>, Vec<Vec<(String, u64)>>) {
        for node in &self.per_node {
            for (name, v) in node {
                *self.global.entry(name.clone()).or_insert(0) += v;
            }
        }
        let global = self.global.into_iter().collect();
        let per_node =
            self.per_node.into_iter().map(|m| m.into_iter().collect()).collect();
        (global, per_node)
    }
}

/// Minimal JSON string escaping (quotes, backslashes, control chars).
fn escape_into(s: &str, out: &mut String) {
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
}

/// `[1,2,3]` without allocation detours.
fn write_usize_list(xs: &[usize], out: &mut String) {
    out.push('[');
    for (i, x) in xs.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        let _ = write!(out, "{x}");
    }
    out.push(']');
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(node: usize, kind: TraceEventKind) -> TraceEvent {
        TraceEvent::new(node, Some(0), "map+local-reduce", kind)
    }

    #[test]
    fn disabled_buf_records_nothing() {
        let mut buf = TraceBuf::new(false);
        buf.push(ev(0, TraceEventKind::MapBlock { items: 1, emitted: 1, exec_node: 0, epoch: 1 }));
        buf.push_keyed(42, ev(0, TraceEventKind::CacheFlush { entries: 4, bytes: 128 }));
        buf.seal_map(16);
        assert!(buf.is_empty());
        let mut col = TraceCollector::new(false);
        col.absorb_job("job", buf);
        assert_eq!(col.event_count(), 0);
        assert!(col.canonical_jsonl().is_empty());
    }

    #[test]
    fn keyed_events_sort_into_canonical_order() {
        // Simulated order for 2 blocks: flush(b0), done(b0), done(b1),
        // then a serial post-map event. Push them shuffled with keys.
        let mut buf = TraceBuf::new(true);
        buf.push_keyed(block_done_seq(1), {
            let mut e = ev(0, TraceEventKind::MapBlock { items: 2, emitted: 2, exec_node: 0, epoch: 1 });
            e.worker = Some(1);
            e
        });
        buf.seal_map(2);
        buf.push(TraceEvent::new(
            0,
            None,
            "shuffle+async-reduce",
            TraceEventKind::Reduce { from: 0, pairs: 3 },
        ));
        buf.push_keyed(
            map_seq(0, 0),
            ev(0, TraceEventKind::CacheFlush { entries: 4, bytes: 64 }),
        );
        buf.push_keyed(
            block_done_seq(0),
            ev(0, TraceEventKind::MapBlock { items: 5, emitted: 5, exec_node: 0, epoch: 1 }),
        );
        let mut col = TraceCollector::new(true);
        col.absorb_job("j", buf);
        let kinds: Vec<&str> =
            col.jobs()[0].events.iter().map(|e| e.kind.name()).collect();
        assert_eq!(kinds, vec!["CacheFlush", "MapBlock", "MapBlock", "Reduce"]);
        // Post-map serial key sits above every map key.
        assert!(col.jobs()[0].events[3].seq > block_done_seq(1));
    }

    #[test]
    fn canonical_jsonl_excludes_time_stamps() {
        let mut buf = TraceBuf::new(true);
        buf.push(
            ev(1, TraceEventKind::Shuffle { dst: 0, bytes: 100, pairs: 9 }).with_wall(5, 10),
        );
        let mut vt = VirtualTime::new();
        vt.fixed_phase("map+local-reduce", 2.0);
        buf.stamp_phases(&vt);
        let mut col = TraceCollector::new(true);
        col.absorb_job("wc", buf);
        let line = col.canonical_jsonl();
        assert_eq!(
            line,
            "{\"job\":\"wc\",\"ev\":\"Shuffle\",\"node\":1,\"worker\":0,\
             \"phase\":\"map+local-reduce\",\"phase_ix\":0,\"dst\":0,\"bytes\":100,\"pairs\":9}\n"
        );
        // The chrome view carries both stamps.
        let chrome = col.chrome_json();
        assert!(chrome.contains("\"wall_start_ns\":5"));
        assert!(chrome.contains("\"ts\":0"));
    }

    #[test]
    fn stamp_phases_matches_occurrences_and_falls_back() {
        let mut buf = TraceBuf::new(true);
        buf.push(
            TraceEvent::new(0, None, "tree-reduce-round", TraceEventKind::Reduce { from: 1, pairs: 2 })
                .at_phase_ix(1),
        );
        buf.push(TraceEvent::new(
            0,
            None,
            "no-such-phase",
            TraceEventKind::Reduce { from: 2, pairs: 2 },
        ));
        let mut vt = VirtualTime::new();
        vt.fixed_phase("tree-reduce-round", 1.0);
        vt.fixed_phase("tree-reduce-round", 3.0);
        buf.stamp_phases(&vt);
        let mut col = TraceCollector::new(true);
        col.absorb_job("j", buf);
        let evs = &col.jobs()[0].events;
        assert_eq!(evs[0].vt, Some((1.0, 4.0)), "second round spans [1,4)");
        assert_eq!(evs[1].vt, Some((0.0, 4.0)), "unknown label falls back to whole job");
    }

    #[test]
    fn samples_get_deterministic_ticks_and_counter_events() {
        let mut buf = TraceBuf::new(true);
        // Three queue-depth samples on node 0 during the 2s map phase:
        // ticks at 0.5, 1.0, 1.5 (i+1)/(n+1) spacing.
        buf.push_sample(0, "map+local-reduce", 0, "pool.queue_depth", 4);
        buf.push_sample(0, "map+local-reduce", 0, "pool.queue_depth", 2);
        buf.push_sample(0, "map+local-reduce", 0, "pool.queue_depth", 0);
        // One in-flight sample on node 1 in an unknown phase → whole job.
        buf.push_sample(1, "no-such-phase", 0, "transport.in_flight_bytes", 1024);
        let mut vt = VirtualTime::new();
        vt.fixed_phase("map+local-reduce", 2.0);
        buf.stamp_phases(&vt);
        let mut col = TraceCollector::new(true);
        col.absorb_job("j", buf);

        let samples = &col.jobs()[0].samples;
        assert_eq!(samples.len(), 4);
        assert_eq!(samples[0].vt, Some(0.5));
        assert_eq!(samples[1].vt, Some(1.0));
        assert_eq!(samples[2].vt, Some(1.5));
        assert_eq!(samples[3].vt, Some(1.0), "singleton sample centers its span");

        // Chrome view renders them as ph:"C" counter events; canonical
        // JSONL never sees them.
        let chrome = col.chrome_json();
        assert_eq!(chrome.matches("\"ph\":\"C\"").count(), 4);
        assert!(chrome.contains("\"name\":\"pool.queue_depth\""));
        assert!(chrome.contains("\"args\":{\"pool.queue_depth\":4}"));
        assert!(chrome.contains("\"args\":{\"transport.in_flight_bytes\":1024}"));
        assert_eq!(col.canonical_jsonl(), "", "samples are chrome-only");
    }

    #[test]
    fn disabled_buf_drops_samples() {
        let mut buf = TraceBuf::new(false);
        buf.push_sample(0, "map", 0, "pool.queue_depth", 1);
        let mut col = TraceCollector::new(true);
        col.absorb_job("j", buf);
        assert!(col.jobs().is_empty());
    }

    #[test]
    fn empty_and_single_event_exports_round_trip() {
        // Empty collector: no JSONL lines, valid (empty) chrome array.
        let col = TraceCollector::new(true);
        assert_eq!(col.canonical_jsonl(), "");
        let chrome = col.chrome_json();
        assert!(chrome.starts_with("{\"traceEvents\":["));
        assert!(chrome.trim_end().ends_with("]}"));

        // Single event exports to exactly one line / one object.
        let mut buf = TraceBuf::new(true);
        buf.push(ev(0, TraceEventKind::Checkpoint { commit: 4, bytes: 2048 }));
        let mut col = TraceCollector::new(true);
        col.absorb_job("solo", buf);
        let jsonl = col.canonical_jsonl();
        assert_eq!(jsonl.lines().count(), 1);
        assert!(jsonl.contains("\"ev\":\"Checkpoint\""));
        assert!(jsonl.contains("\"commit\":4"));
        let chrome = col.chrome_json();
        assert_eq!(chrome.matches("\"name\":\"Checkpoint\"").count(), 1);
    }

    #[test]
    fn chrome_only_events_excluded_from_canonical() {
        let mut buf = TraceBuf::new(true);
        buf.push(ev(0, TraceEventKind::Reduce { from: 1, pairs: 8 }));
        buf.push(ev(0, TraceEventKind::FrameSent { dst: 1, frames: 3, bytes: 96 }));
        buf.push(ev(0, TraceEventKind::TransportStall { dst: 1, stalls: 2 }));
        buf.push(ev(0, TraceEventKind::FrameDropped { dst: 1, seq: 5, attempt: 0, corrupt: true }));
        buf.push(ev(0, TraceEventKind::FrameRetried { dst: 1, seq: 5, attempt: 1, backoff_ns: 200_000 }));
        buf.push(ev(0, TraceEventKind::NodeTimedOut { dst: 1, attempts: 9 }));
        let mut col = TraceCollector::new(true);
        col.absorb_job("j", buf);
        // Canonical view: only the schedule-invariant Reduce line survives.
        let jsonl = col.canonical_jsonl();
        assert_eq!(jsonl.lines().count(), 1);
        assert!(jsonl.contains("\"ev\":\"Reduce\""));
        assert!(!jsonl.contains("FrameSent"));
        assert!(!jsonl.contains("TransportStall"));
        assert!(!jsonl.contains("FrameDropped"));
        assert!(!jsonl.contains("FrameRetried"));
        assert!(!jsonl.contains("NodeTimedOut"));
        // Chrome view keeps them, with the transport fields in args.
        let chrome = col.chrome_json();
        assert_eq!(chrome.matches("\"name\":\"FrameSent\"").count(), 1);
        assert_eq!(chrome.matches("\"name\":\"TransportStall\"").count(), 1);
        assert_eq!(chrome.matches("\"name\":\"FrameDropped\"").count(), 1);
        assert_eq!(chrome.matches("\"name\":\"FrameRetried\"").count(), 1);
        assert_eq!(chrome.matches("\"name\":\"NodeTimedOut\"").count(), 1);
        assert!(chrome.contains("\"frames\":3"));
        assert!(chrome.contains("\"stalls\":2"));
        assert!(chrome.contains("\"corrupt\":true"));
        assert!(chrome.contains("\"backoff_ns\":200000"));
        assert!(chrome.contains("\"attempts\":9"));
    }

    #[test]
    fn midblock_abort_is_canonical() {
        let mut buf = TraceBuf::new(true);
        buf.push(ev(2, TraceEventKind::MidblockAbort { block: 3, victim: 2, items: 40 }));
        let mut col = TraceCollector::new(true);
        col.absorb_job("j", buf);
        let jsonl = col.canonical_jsonl();
        assert_eq!(jsonl.lines().count(), 1);
        assert!(jsonl.contains("\"ev\":\"MidblockAbort\""));
        assert!(jsonl.contains("\"block\":3,\"victim\":2,\"items\":40"));
    }

    #[test]
    fn render_note_reproduces_legacy_fault_strings() {
        let label = "wordcount.mr";
        let e = ev(2, TraceEventKind::KillIgnored { victim: 0 });
        assert_eq!(
            e.render_note(label).unwrap(),
            "fault[wordcount.mr]: ignored kill of node 0"
        );
        let e = ev(2, TraceEventKind::KillDropped { victim: 3, trigger: "AtBlock(9)".into() });
        assert_eq!(
            e.render_note(label).unwrap(),
            "fault[wordcount.mr]: kill of node 3 never fired (AtBlock(9))"
        );
        let e = ev(0, TraceEventKind::EvacFallback { victims: vec![1, 2] });
        assert_eq!(
            e.render_note(label).unwrap(),
            "fault[wordcount.mr]: target cannot re-home keys; \
             hot-standby restore kept for nodes [1, 2]"
        );
        let e = ev(
            0,
            TraceEventKind::FaultSummary {
                checkpoints: 3,
                checkpoint_bytes: 400,
                failures: 1,
                ignored: 0,
                reassigned: 2,
                replayed: 5,
                restore_bytes: 128,
                evacuations: 1,
                evac_bytes: 64,
                max_epoch: 2,
            },
        );
        assert_eq!(
            e.render_note(label).unwrap(),
            "fault[wordcount.mr]: checkpoints=3 ckpt_bytes=400 failures=1 ignored=0 \
             reassigned=2 replayed=5 restore_bytes=128 evacuations=1 evac_bytes=64 max_epoch=2"
        );
        // Non-fault kinds have no note form.
        assert!(ev(0, TraceEventKind::Reduce { from: 0, pairs: 1 }).render_note(label).is_none());
    }

    #[test]
    fn counters_fold_per_node_into_global() {
        let mut c = Counters::new(2);
        c.add_node(0, "cache.flushes", 3);
        c.add_node(1, "cache.flushes", 2);
        c.max_node(1, "cache.peak_bytes", 100);
        c.max_node(1, "cache.peak_bytes", 40); // max keeps 100
        c.add("pool.queue_peak", 0);
        c.max("pool.queue_peak", 7);
        let (global, per_node) = c.finish();
        assert_eq!(
            global,
            vec![
                ("cache.flushes".to_string(), 5),
                ("cache.peak_bytes".to_string(), 100),
                ("pool.queue_peak".to_string(), 7),
            ]
        );
        assert_eq!(per_node[0], vec![("cache.flushes".to_string(), 3)]);
        assert_eq!(
            per_node[1],
            vec![("cache.flushes".to_string(), 2), ("cache.peak_bytes".to_string(), 100)]
        );
    }

    #[test]
    fn export_writes_both_files() {
        let dir = std::env::temp_dir().join("blaze_trace_test_export");
        let _ = std::fs::create_dir_all(&dir);
        let path = dir.join("run.trace.jsonl");
        let mut buf = TraceBuf::new(true);
        buf.push(ev(0, TraceEventKind::Reduce { from: 1, pairs: 8 }));
        let mut col = TraceCollector::new(true);
        col.absorb_job("j", buf);
        col.export(&path).expect("export writes");
        let jsonl = std::fs::read_to_string(&path).unwrap();
        assert_eq!(jsonl, col.canonical_jsonl());
        let chrome_path = format!("{}.chrome.json", path.display());
        let chrome = std::fs::read_to_string(&chrome_path).unwrap();
        assert_eq!(chrome, col.chrome_json());
        let _ = std::fs::remove_file(&path);
        let _ = std::fs::remove_file(&chrome_path);
    }

    #[test]
    fn escaping_handles_quotes_and_control_chars() {
        let mut buf = TraceBuf::new(true);
        buf.push(ev(
            0,
            TraceEventKind::KillDropped { victim: 1, trigger: "At\"Time\"(0.5)\n".into() },
        ));
        let mut col = TraceCollector::new(true);
        col.absorb_job("a\\b", buf);
        let line = col.canonical_jsonl();
        assert!(line.contains("\"job\":\"a\\\\b\""));
        assert!(line.contains("At\\\"Time\\\"(0.5)\\n"));
    }
}
