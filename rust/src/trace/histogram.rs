//! Deterministic log-bucketed latency/size histograms.
//!
//! The perf loop needs tail behavior (p50/p95/p99), not just sums — but
//! the repo's determinism discipline (DESIGN.md §Observability) forbids
//! anything schedule-dependent in a gated artifact. This module squares
//! that: a [`Histogram`] is a sparse array of power-of-two buckets whose
//! merge is plain element-wise addition — **exact**, hence associative
//! and commutative — so per-worker histograms folded in any thread
//! interleaving produce byte-identical state. Gated series record pure
//! functions of the seeded workload (item counts, flush entry counts,
//! frame chunk sizes); wall-clock series carry a `wall.` name prefix and
//! are excluded from every determinism gate (they exist for
//! observability only).
//!
//! Bucketing: value `0` lands in bucket `0`; a value `v > 0` lands in
//! bucket `i = 64 - v.leading_zeros()`, i.e. bucket `i` spans
//! `[2^(i-1), 2^i - 1]`. Quantiles resolve to the bucket's upper bound —
//! a deterministic over-estimate with ≤ 2× relative error, which is all
//! a regression gate needs.

use std::collections::BTreeMap;

/// Sparse log-bucketed histogram with exact merge.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct Histogram {
    /// Bucket index → occupancy. Sparse: empty buckets are absent.
    /// `BTreeMap` keeps iteration (and thus encoding) deterministic.
    buckets: BTreeMap<u32, u64>,
    /// Total recorded values.
    count: u64,
    /// Saturating sum of recorded values.
    sum: u64,
    /// Exact maximum recorded value (0 when empty).
    max: u64,
}

/// Bucket index for a value: 0 → 0, otherwise `64 - leading_zeros`.
fn bucket_of(v: u64) -> u32 {
    if v == 0 {
        0
    } else {
        64 - v.leading_zeros()
    }
}

/// Inclusive upper bound of a bucket: bucket 0 → 0, bucket i → `2^i - 1`
/// (saturating at `u64::MAX` for bucket 64).
fn bucket_upper(i: u32) -> u64 {
    if i == 0 {
        0
    } else if i >= 64 {
        u64::MAX
    } else {
        (1u64 << i) - 1
    }
}

impl Histogram {
    /// Empty histogram.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one value.
    pub fn record(&mut self, v: u64) {
        *self.buckets.entry(bucket_of(v)).or_insert(0) += 1;
        self.count += 1;
        self.sum = self.sum.saturating_add(v);
        self.max = self.max.max(v);
    }

    /// Merge `other` into `self`. Element-wise bucket addition is exact,
    /// so merge order never matters — the property the threaded backend
    /// leans on (workers fold in arrival order, results are identical).
    pub fn merge(&mut self, other: &Histogram) {
        for (&b, &n) in &other.buckets {
            *self.buckets.entry(b).or_insert(0) += n;
        }
        self.count += other.count;
        self.sum = self.sum.saturating_add(other.sum);
        self.max = self.max.max(other.max);
    }

    /// Number of recorded values.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Saturating sum of recorded values.
    pub fn sum(&self) -> u64 {
        self.sum
    }

    /// Exact maximum recorded value (0 when empty).
    pub fn max_value(&self) -> u64 {
        self.max
    }

    /// True when nothing has been recorded.
    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Quantile `q ∈ (0, 1]` as the upper bound of the bucket holding the
    /// rank-`ceil(q·count)` value; 0 on an empty histogram. The true max
    /// is tracked exactly, so the top bucket reports `max_value()` rather
    /// than its (looser) power-of-two bound.
    pub fn quantile(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let rank = ((q * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut seen = 0u64;
        let top = *self.buckets.keys().next_back().expect("non-empty");
        for (&b, &n) in &self.buckets {
            seen += n;
            if seen >= rank {
                return if b == top { self.max } else { bucket_upper(b) };
            }
        }
        self.max
    }

    /// Median (bucket upper bound).
    pub fn p50(&self) -> u64 {
        self.quantile(0.50)
    }

    /// 95th percentile (bucket upper bound).
    pub fn p95(&self) -> u64 {
        self.quantile(0.95)
    }

    /// 99th percentile (bucket upper bound).
    pub fn p99(&self) -> u64 {
        self.quantile(0.99)
    }

    /// Canonical text encoding: `count:sum:max|b:n,b:n,…` with buckets in
    /// ascending index order. Two histograms encode identically iff their
    /// full state is identical — the byte-identity currency of the
    /// equivalence harness.
    pub fn encode(&self) -> String {
        let mut out = format!("{}:{}:{}|", self.count, self.sum, self.max);
        let mut first = true;
        for (&b, &n) in &self.buckets {
            if !first {
                out.push(',');
            }
            first = false;
            out.push_str(&format!("{b}:{n}"));
        }
        out
    }
}

/// Per-run histogram registry, mirroring [`super::Counters`]: per-node
/// maps folded into a global map at the end of the run. Always collected
/// (like counters) — it is cheap and every `BENCH_*.json` row embeds the
/// quantiles whether or not tracing is on.
#[derive(Debug, Clone)]
pub struct Histograms {
    global: BTreeMap<String, Histogram>,
    per_node: Vec<BTreeMap<String, Histogram>>,
}

impl Histograms {
    /// Registry for a cluster of `nodes` virtual nodes.
    pub fn new(nodes: usize) -> Self {
        Self { global: BTreeMap::new(), per_node: vec![BTreeMap::new(); nodes] }
    }

    /// Record one value into `name` on `node`.
    pub fn record_node(&mut self, node: usize, name: &str, v: u64) {
        self.per_node[node].entry(name.to_string()).or_default().record(v);
    }

    /// Merge a pre-built histogram into the global series `name`
    /// (used for cross-node series like the transport wall-wait).
    pub fn merge_global(&mut self, name: &str, h: &Histogram) {
        if !h.is_empty() {
            self.global.entry(name.to_string()).or_default().merge(h);
        }
    }

    /// Fold every per-node histogram into the global map and return the
    /// merged series sorted by name. Merge is exact, so the fold order
    /// (node 0, 1, …) is a convention, not a correctness requirement.
    pub fn finish(mut self) -> Vec<(String, Histogram)> {
        for node in self.per_node {
            for (name, h) in node {
                self.global.entry(name).or_default().merge(&h);
            }
        }
        self.global.into_iter().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::SplitRng;

    #[test]
    fn bucketing_covers_powers_of_two() {
        assert_eq!(bucket_of(0), 0);
        assert_eq!(bucket_of(1), 1);
        assert_eq!(bucket_of(2), 2);
        assert_eq!(bucket_of(3), 2);
        assert_eq!(bucket_of(4), 3);
        assert_eq!(bucket_of(1023), 10);
        assert_eq!(bucket_of(1024), 11);
        assert_eq!(bucket_of(u64::MAX), 64);
        assert_eq!(bucket_upper(0), 0);
        assert_eq!(bucket_upper(2), 3);
        assert_eq!(bucket_upper(64), u64::MAX);
        // Every value's bucket contains it.
        for v in [0u64, 1, 2, 5, 100, 1 << 20, u64::MAX] {
            let b = bucket_of(v);
            assert!(v <= bucket_upper(b));
            if b > 1 {
                assert!(v > bucket_upper(b - 1), "{v} above bucket {b}'s lower edge");
            }
        }
    }

    #[test]
    fn record_tracks_count_sum_max() {
        let mut h = Histogram::new();
        assert!(h.is_empty());
        for v in [3u64, 0, 9, 1000] {
            h.record(v);
        }
        assert_eq!(h.count(), 4);
        assert_eq!(h.sum(), 1012);
        assert_eq!(h.max_value(), 1000);
        assert!(!h.is_empty());
    }

    #[test]
    fn quantiles_are_bucket_upper_bounds_with_exact_max() {
        let mut h = Histogram::new();
        for v in 1..=100u64 {
            h.record(v);
        }
        // p50 → rank 50 → value 50 lives in bucket 6 ([32, 63]).
        assert_eq!(h.p50(), 63);
        // The top bucket reports the exact max, not 127.
        assert_eq!(h.p99(), 100);
        assert_eq!(h.max_value(), 100);
        // Degenerate single-value histogram: all quantiles = max.
        let mut one = Histogram::new();
        one.record(7);
        assert_eq!(one.p50(), 7);
        assert_eq!(one.p99(), 7);
    }

    #[test]
    fn empty_histogram_exports_cleanly() {
        let h = Histogram::new();
        assert_eq!(h.quantile(0.99), 0);
        assert_eq!(h.p50(), 0);
        assert_eq!(h.max_value(), 0);
        assert_eq!(h.encode(), "0:0:0|");
    }

    #[test]
    fn encode_is_canonical() {
        let mut h = Histogram::new();
        h.record(0);
        h.record(1);
        h.record(5);
        h.record(5);
        // buckets: 0→1, 1→1, 3→2
        assert_eq!(h.encode(), "4:11:5|0:1,1:1,3:2");
    }

    #[test]
    fn merge_is_exact_associative_and_commutative_under_fuzz() {
        // SplitRng-fuzzed inputs: split a value stream three ways, merge
        // the parts in every order/grouping, and require identical state.
        let mut rng = SplitRng::new(0x4157_0061, 0);
        let mut parts = [Histogram::new(), Histogram::new(), Histogram::new()];
        let mut whole = Histogram::new();
        for i in 0..3000 {
            let v = rng.next_u64() >> (rng.next_u64() % 64);
            parts[i % 3].record(v);
            whole.record(v);
        }
        let [a, b, c] = parts;

        // (a+b)+c == a+(b+c)
        let mut ab_c = a.clone();
        ab_c.merge(&b);
        ab_c.merge(&c);
        let mut bc = b.clone();
        bc.merge(&c);
        let mut a_bc = a.clone();
        a_bc.merge(&bc);
        assert_eq!(ab_c, a_bc, "merge associates");

        // c+b+a == a+b+c
        let mut cba = c.clone();
        cba.merge(&b);
        cba.merge(&a);
        assert_eq!(ab_c, cba, "merge commutes");

        // And all equal recording the stream directly.
        assert_eq!(ab_c, whole, "merge is exact");
        assert_eq!(ab_c.encode(), whole.encode(), "encodings agree byte-for-byte");
    }

    #[test]
    fn registry_folds_per_node_into_global() {
        let mut hs = Histograms::new(2);
        hs.record_node(0, "map.block_items", 10);
        hs.record_node(1, "map.block_items", 30);
        hs.record_node(1, "cache.flush_entries", 4);
        let mut wall = Histogram::new();
        wall.record(1234);
        hs.merge_global("wall.transport.frame_wait_ns", &wall);
        // Empty histograms never enter the registry.
        hs.merge_global("wall.unused", &Histogram::new());

        let merged = hs.finish();
        let names: Vec<&str> = merged.iter().map(|(n, _)| n.as_str()).collect();
        assert_eq!(
            names,
            ["cache.flush_entries", "map.block_items", "wall.transport.frame_wait_ns"]
        );
        let items = &merged.iter().find(|(n, _)| n == "map.block_items").unwrap().1;
        assert_eq!(items.count(), 2);
        assert_eq!(items.sum(), 40);
        assert_eq!(items.max_value(), 30);
    }
}
