//! `DistRange` — a distributed arithmetic range (paper §2.1).
//!
//! Stores only start, end and step; elements are generated on the fly, so a
//! `DistRange(0, 10^9)` occupies no memory. The canonical input for
//! embarrassingly-generative workloads (Monte-Carlo π).

use crate::coordinator::cluster::Cluster;
use crate::coordinator::scheduler::block_ranges;
use crate::mapreduce::{BlockCursor, DistInput};

/// Distributed `[start, end)` range with a step.
#[derive(Debug, Clone)]
pub struct DistRange {
    cluster: Cluster,
    start: u64,
    end: u64,
    step: u64,
}

impl DistRange {
    /// Range `[start, end)` with step 1.
    pub fn new(cluster: &Cluster, start: u64, end: u64) -> Self {
        Self::with_step(cluster, start, end, 1)
    }

    /// Range `[start, end)` with an explicit step.
    ///
    /// # Panics
    /// If `step == 0`.
    pub fn with_step(cluster: &Cluster, start: u64, end: u64, step: u64) -> Self {
        assert!(step > 0, "step must be positive");
        Self { cluster: cluster.clone(), start, end: end.max(start), step }
    }

    /// Number of elements.
    pub fn len(&self) -> u64 {
        (self.end - self.start).div_ceil(self.step)
    }

    /// True if the range is empty.
    pub fn is_empty(&self) -> bool {
        self.start >= self.end
    }

    /// The `i`-th element.
    #[inline]
    pub fn nth(&self, i: u64) -> u64 {
        self.start + i * self.step
    }

    /// Apply `f` to every element, in parallel across the cluster
    /// (paper's `foreach`). `f` receives the element value.
    pub fn foreach(&self, mut f: impl FnMut(u64)) {
        let nodes = self.cluster.nodes();
        for node in 0..nodes {
            self.for_each_worker_item(node, self.cluster.workers(), |_, _, v| f(*v));
        }
    }
}

/// Block cursor over one node's sub-range: elements are generated on the
/// fly, one block per call — nothing is ever stored or rescanned.
pub struct RangeBlockCursor {
    /// Range start and step (copied; the cursor owns everything it needs).
    start: u64,
    step: u64,
    /// Global index of the node's first element.
    node_start: usize,
    ranges: std::vec::IntoIter<std::ops::Range<usize>>,
}

impl BlockCursor<u64, u64> for RangeBlockCursor {
    fn next_block<F: FnMut(&u64, &u64)>(&mut self, mut f: F) -> bool {
        let Some(r) = self.ranges.next() else { return false };
        for i in r {
            let global = (self.node_start + i) as u64;
            let value = self.start + global * self.step;
            f(&global, &value);
        }
        true
    }
}

impl DistInput for DistRange {
    type K = u64;
    type V = u64;
    type Cursor<'a>
        = RangeBlockCursor
    where
        Self: 'a;

    fn cluster(&self) -> &Cluster {
        &self.cluster
    }

    fn node_len(&self, node: usize) -> usize {
        let ranges = block_ranges(self.len() as usize, self.cluster.nodes());
        ranges[node].len()
    }

    fn block_cursor(&self, node: usize, workers: usize) -> RangeBlockCursor {
        let node_ranges = block_ranges(self.len() as usize, self.cluster.nodes());
        let node_range = node_ranges[node].clone();
        RangeBlockCursor {
            start: self.start,
            step: self.step,
            node_start: node_range.start,
            ranges: block_ranges(node_range.len(), workers).into_iter(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn len_with_step() {
        let c = Cluster::local(2, 2);
        assert_eq!(DistRange::new(&c, 0, 10).len(), 10);
        assert_eq!(DistRange::with_step(&c, 0, 10, 3).len(), 4); // 0,3,6,9
        assert_eq!(DistRange::new(&c, 5, 5).len(), 0);
    }

    #[test]
    fn foreach_visits_every_element_once() {
        let c = Cluster::local(3, 2);
        let r = DistRange::new(&c, 10, 30);
        let mut seen = Vec::new();
        r.foreach(|v| seen.push(v));
        seen.sort_unstable();
        assert_eq!(seen, (10..30).collect::<Vec<u64>>());
    }

    #[test]
    fn worker_items_partition_node_items() {
        let c = Cluster::local(2, 3);
        let r = DistRange::new(&c, 0, 20);
        let mut per_worker: Vec<Vec<u64>> = vec![Vec::new(); 3];
        r.for_each_worker_item(0, 3, |w, _, v| per_worker[w].push(*v));
        let total: usize = per_worker.iter().map(Vec::len).sum();
        assert_eq!(total, r.node_len(0));
        // Block split: workers get contiguous, near-even chunks.
        let sizes: Vec<usize> = per_worker.iter().map(Vec::len).collect();
        assert!(sizes.iter().max().unwrap() - sizes.iter().min().unwrap() <= 1);
    }

    #[test]
    fn stepped_values() {
        let c = Cluster::local(1, 1);
        let r = DistRange::with_step(&c, 100, 110, 2);
        let mut seen = Vec::new();
        r.foreach(|v| seen.push(v));
        assert_eq!(seen, vec![100, 102, 104, 106, 108]);
    }

    #[test]
    fn block_cursor_generates_blocks_on_the_fly() {
        let c = Cluster::local(2, 3);
        let r = DistRange::with_step(&c, 10, 50, 2); // 20 elements
        let mut all: Vec<u64> = Vec::new();
        for node in 0..2 {
            let mut cur = r.block_cursor(node, 3);
            let mut blocks = 0usize;
            while cur.next_block(|k, v| {
                assert_eq!(*v, 10 + *k * 2, "value derives from global index");
                all.push(*v);
            }) {
                blocks += 1;
            }
            assert_eq!(blocks, 3);
        }
        assert_eq!(all.len(), 20);
        assert_eq!(all, (0..20u64).map(|i| 10 + i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn block_cursor_empty_range_yields_empty_blocks() {
        let c = Cluster::local(2, 2);
        let r = DistRange::new(&c, 5, 5);
        let mut cur = r.block_cursor(0, 2);
        let mut blocks = 0usize;
        while cur.next_block(|_, _| panic!("empty range has no items")) {
            blocks += 1;
        }
        assert_eq!(blocks, 2, "empty blocks still count");
    }
}
