//! `DistRange` — a distributed arithmetic range (paper §2.1).
//!
//! Stores only start, end and step; elements are generated on the fly, so a
//! `DistRange(0, 10^9)` occupies no memory. The canonical input for
//! embarrassingly-generative workloads (Monte-Carlo π).

use crate::coordinator::cluster::Cluster;
use crate::coordinator::scheduler::block_ranges;
use crate::mapreduce::DistInput;

/// Distributed `[start, end)` range with a step.
#[derive(Debug, Clone)]
pub struct DistRange {
    cluster: Cluster,
    start: u64,
    end: u64,
    step: u64,
}

impl DistRange {
    /// Range `[start, end)` with step 1.
    pub fn new(cluster: &Cluster, start: u64, end: u64) -> Self {
        Self::with_step(cluster, start, end, 1)
    }

    /// Range `[start, end)` with an explicit step.
    ///
    /// # Panics
    /// If `step == 0`.
    pub fn with_step(cluster: &Cluster, start: u64, end: u64, step: u64) -> Self {
        assert!(step > 0, "step must be positive");
        Self { cluster: cluster.clone(), start, end: end.max(start), step }
    }

    /// Number of elements.
    pub fn len(&self) -> u64 {
        (self.end - self.start).div_ceil(self.step)
    }

    /// True if the range is empty.
    pub fn is_empty(&self) -> bool {
        self.start >= self.end
    }

    /// The `i`-th element.
    #[inline]
    pub fn nth(&self, i: u64) -> u64 {
        self.start + i * self.step
    }

    /// Apply `f` to every element, in parallel across the cluster
    /// (paper's `foreach`). `f` receives the element value.
    pub fn foreach(&self, mut f: impl FnMut(u64)) {
        let nodes = self.cluster.nodes();
        for node in 0..nodes {
            self.for_each_worker_item(node, self.cluster.workers(), |_, _, v| f(*v));
        }
    }
}

impl DistInput for DistRange {
    type K = u64;
    type V = u64;

    fn cluster(&self) -> &Cluster {
        &self.cluster
    }

    fn node_len(&self, node: usize) -> usize {
        let ranges = block_ranges(self.len() as usize, self.cluster.nodes());
        ranges[node].len()
    }

    fn for_each_worker_item<F: FnMut(usize, &Self::K, &Self::V)>(
        &self,
        node: usize,
        workers: usize,
        mut f: F,
    ) {
        let node_ranges = block_ranges(self.len() as usize, self.cluster.nodes());
        let node_range = node_ranges[node].clone();
        let worker_ranges = block_ranges(node_range.len(), workers);
        for (w, wr) in worker_ranges.into_iter().enumerate() {
            for i in wr {
                let global = (node_range.start + i) as u64;
                let value = self.nth(global);
                f(w, &global, &value);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn len_with_step() {
        let c = Cluster::local(2, 2);
        assert_eq!(DistRange::new(&c, 0, 10).len(), 10);
        assert_eq!(DistRange::with_step(&c, 0, 10, 3).len(), 4); // 0,3,6,9
        assert_eq!(DistRange::new(&c, 5, 5).len(), 0);
    }

    #[test]
    fn foreach_visits_every_element_once() {
        let c = Cluster::local(3, 2);
        let r = DistRange::new(&c, 10, 30);
        let mut seen = Vec::new();
        r.foreach(|v| seen.push(v));
        seen.sort_unstable();
        assert_eq!(seen, (10..30).collect::<Vec<u64>>());
    }

    #[test]
    fn worker_items_partition_node_items() {
        let c = Cluster::local(2, 3);
        let r = DistRange::new(&c, 0, 20);
        let mut per_worker: Vec<Vec<u64>> = vec![Vec::new(); 3];
        r.for_each_worker_item(0, 3, |w, _, v| per_worker[w].push(*v));
        let total: usize = per_worker.iter().map(Vec::len).sum();
        assert_eq!(total, r.node_len(0));
        // Block split: workers get contiguous, near-even chunks.
        let sizes: Vec<usize> = per_worker.iter().map(Vec::len).collect();
        assert!(sizes.iter().max().unwrap() - sizes.iter().min().unwrap() <= 1);
    }

    #[test]
    fn stepped_values() {
        let c = Cluster::local(1, 1);
        let r = DistRange::with_step(&c, 100, 110, 2);
        let mut seen = Vec::new();
        r.foreach(|v| seen.push(v));
        assert_eq!(seen, vec![100, 102, 104, 106, 108]);
    }
}
