//! `DistHashMap` — a hash-slot-partitioned distributed map (paper §2.1).
//!
//! Keys route through [`crate::coordinator::rebalance::NUM_SLOTS`] hash
//! slots; a coordinator-owned slot→node map assigns slots to nodes and can
//! be rebalanced when key skew piles weight onto a few slots.

use std::collections::HashMap;
use std::hash::Hash;

use crate::coordinator::cluster::Cluster;
use crate::coordinator::metrics::RunStats;
use crate::coordinator::rebalance::{self, MovePlan, SlotMap, NUM_SLOTS};
use crate::mapreduce::{DistInput, ReduceTarget, Reducer};
use crate::net::sim::FlowMatrix;
use crate::ser::fastser::FastSer;
use crate::util::hash::{fxhash, FxHashMap};

/// Distributed hash map: key/value pairs partitioned by hash slot.
#[derive(Debug, Clone)]
pub struct DistHashMap<K, V> {
    cluster: Cluster,
    slot_map: SlotMap,
    shards: Vec<FxHashMap<K, V>>,
}

impl<K, V> DistHashMap<K, V>
where
    K: Hash + Eq + Clone,
    V: Clone,
{
    /// Empty map over `cluster`.
    pub fn new(cluster: &Cluster) -> Self {
        Self {
            cluster: cluster.clone(),
            slot_map: SlotMap::even(cluster.nodes()),
            shards: (0..cluster.nodes()).map(|_| FxHashMap::default()).collect(),
        }
    }

    /// Hash slot of `key`.
    #[inline]
    pub fn slot_of(&self, key: &K) -> usize {
        (fxhash(key) % NUM_SLOTS as u64) as usize
    }

    /// Node owning `key` under the current slot map.
    #[inline]
    pub fn owner_of(&self, key: &K) -> usize {
        self.slot_map.node_of(self.slot_of(key))
    }

    /// Entry count across all shards (paper's `words.size()`).
    pub fn len(&self) -> usize {
        self.shards.iter().map(HashMap::len).sum()
    }

    /// True if no entries.
    pub fn is_empty(&self) -> bool {
        self.shards.iter().all(HashMap::is_empty)
    }

    /// Owning cluster.
    pub fn cluster(&self) -> &Cluster {
        &self.cluster
    }

    /// Look up one key.
    pub fn get(&self, key: &K) -> Option<V> {
        self.shards[self.owner_of(key)].get(key).cloned()
    }

    /// Insert or overwrite one key.
    pub fn insert(&mut self, key: K, value: V) {
        let node = self.owner_of(&key);
        self.shards[node].insert(key, value);
    }

    /// Insert-or-reduce one key (the map's native merge operation).
    pub fn merge(&mut self, key: K, value: V, red: &Reducer<V>) {
        let node = self.owner_of(&key);
        match self.shards[node].entry(key) {
            std::collections::hash_map::Entry::Occupied(mut e) => red.apply(e.get_mut(), &value),
            std::collections::hash_map::Entry::Vacant(e) => {
                e.insert(value);
            }
        }
    }

    /// Build from a standard `HashMap` (paper's `distribute`).
    pub fn from_hashmap(cluster: &Cluster, data: HashMap<K, V>) -> Self {
        let mut out = Self::new(cluster);
        for (k, v) in data {
            out.insert(k, v);
        }
        out
    }

    /// Gather into a standard `HashMap` (paper's `collect`).
    pub fn collect(&self) -> HashMap<K, V> {
        let mut out = HashMap::with_capacity(self.len());
        for shard in &self.shards {
            for (k, v) in shard {
                out.insert(k.clone(), v.clone());
            }
        }
        out
    }

    /// Apply `f` to every entry in parallel (paper's `foreach`); values may
    /// be mutated.
    pub fn foreach(&mut self, mut f: impl FnMut(&K, &mut V)) {
        for shard in &mut self.shards {
            for (k, v) in shard.iter_mut() {
                f(k, v);
            }
        }
    }

    /// Node-local shard (read).
    pub fn shard(&self, node: usize) -> &FxHashMap<K, V> {
        &self.shards[node]
    }

    /// Per-slot (entry count, serialized bytes) — the rebalancer's input.
    pub fn slot_weights(&self) -> (Vec<u64>, Vec<u64>)
    where
        K: FastSer,
        V: FastSer,
    {
        let mut counts = vec![0u64; NUM_SLOTS];
        let mut bytes = vec![0u64; NUM_SLOTS];
        for shard in &self.shards {
            for (k, v) in shard {
                let slot = self.slot_of(k);
                counts[slot] += 1;
                bytes[slot] += (k.encoded_len() + v.encoded_len()) as u64;
            }
        }
        (counts, bytes)
    }

    /// Rebalance shards to even out per-node load. Moves are executed for
    /// real (entries re-home, bytes counted through the flow model) and the
    /// plan is returned. No-op on a 1-node cluster.
    pub fn rebalance(&mut self) -> MovePlan
    where
        K: FastSer,
        V: FastSer,
    {
        let nodes = self.cluster.nodes();
        let (counts, bytes) = self.slot_weights();
        let plan = rebalance::plan(&self.slot_map, &counts, &bytes, nodes);
        self.apply_plan(plan, "disthashmap.rebalance")
    }

    /// Evacuate `dead` nodes: recompute the slot map over the survivors
    /// ([`rebalance::plan_with_dead`]) and re-home every affected entry,
    /// with the moved bytes counted through the flow model. After this no
    /// key routes to a dead node. No-op when `dead` is empty and the load
    /// is already balanced.
    pub fn evacuate(&mut self, dead: &[usize]) -> MovePlan
    where
        K: FastSer,
        V: FastSer,
    {
        let nodes = self.cluster.nodes();
        let (counts, bytes) = self.slot_weights();
        let plan = rebalance::plan_with_dead(&self.slot_map, &counts, &bytes, nodes, dead);
        self.apply_plan(plan, "disthashmap.evacuate")
    }

    /// Execute a rebalance plan: move entries, adopt the new map, record
    /// the transfer.
    fn apply_plan(&mut self, plan: MovePlan, label: &str) -> MovePlan
    where
        K: FastSer,
        V: FastSer,
    {
        let nodes = self.cluster.nodes();
        let mut flows = FlowMatrix::new(nodes);
        for mv in &plan.moves {
            // Re-home every entry in the moved slot, serializing for real.
            let moved: Vec<(K, V)> = self.shards[mv.from]
                .iter()
                .filter(|(k, _)| self.slot_of(k) == mv.slot)
                .map(|(k, v)| (k.clone(), v.clone()))
                .collect();
            let mut w = crate::ser::fastser::Writer::new();
            for (k, v) in &moved {
                k.write(&mut w);
                v.write(&mut w);
            }
            flows.record(mv.from, mv.to, w.len() as u64);
            for (k, v) in moved {
                self.shards[mv.from].remove(&k);
                self.shards[mv.to].insert(k, v);
            }
        }
        self.slot_map = plan.new_map.clone();
        let transfer = flows.phase_time(&self.cluster.config().network);
        self.cluster.metrics().record_run(RunStats {
            label: label.into(),
            engine: self.cluster.config().engine.to_string(),
            nodes,
            workers_per_node: self.cluster.workers(),
            makespan_sec: transfer,
            shuffle_sec: transfer,
            shuffle_bytes: flows.cross_node_bytes(),
            ..Default::default()
        });
        plan
    }

    /// Load imbalance (max/mean entries per node).
    pub fn imbalance(&self) -> f64 {
        let loads: Vec<usize> = self.shards.iter().map(HashMap::len).collect();
        let total: usize = loads.iter().sum();
        if total == 0 {
            return 1.0;
        }
        let mean = total as f64 / loads.len() as f64;
        *loads.iter().max().unwrap() as f64 / mean
    }
}

impl<K, V> DistInput for DistHashMap<K, V>
where
    K: Hash + Eq + Clone,
    V: Clone,
{
    type K = K;
    type V = V;

    fn cluster(&self) -> &Cluster {
        &self.cluster
    }

    fn node_len(&self, node: usize) -> usize {
        self.shards[node].len()
    }

    fn for_each_worker_item<F: FnMut(usize, &Self::K, &Self::V)>(
        &self,
        node: usize,
        workers: usize,
        mut f: F,
    ) {
        let n = self.shards[node].len();
        if n == 0 {
            return;
        }
        // One pass; worker assignment by position (block split).
        let ranges = crate::coordinator::scheduler::block_ranges(n, workers);
        let mut w = 0usize;
        for (i, (k, v)) in self.shards[node].iter().enumerate() {
            while i >= ranges[w].end {
                w += 1;
            }
            f(w, k, v);
        }
    }
}

/// Checkpoint support: a shard snapshots as one fast-codec pair batch and
/// restores by *replacing* the shard (the snapshot already contains any
/// merged-into history).
impl<K, V> crate::fault::Recover for DistHashMap<K, V>
where
    K: Hash + Eq + Clone + FastSer,
    V: Clone + FastSer,
{
    fn snapshot_shard(&self, node: usize) -> Option<Vec<u8>> {
        let pairs: Vec<(K, V)> =
            self.shards[node].iter().map(|(k, v)| (k.clone(), v.clone())).collect();
        Some(crate::ser::fastser::encode_pairs(&pairs))
    }

    fn restore_shard(
        &mut self,
        node: usize,
        bytes: &[u8],
    ) -> Result<(), crate::ser::fastser::DecodeError> {
        let pairs = crate::ser::fastser::decode_pairs_exact::<K, V>(bytes)?;
        let mut shard = FxHashMap::default();
        shard.extend(pairs);
        self.shards[node] = shard;
        Ok(())
    }

    fn lose_shard(&mut self, node: usize) {
        self.shards[node] = FxHashMap::default();
    }
}

/// `DistHashMap` as a MapReduce target (the word-count example's `words`).
impl<K, V> ReduceTarget<K, V> for DistHashMap<K, V>
where
    K: Hash + Eq + Clone,
    V: Clone,
{
    fn shard_of(&self, key: &K, _nodes: usize) -> usize {
        self.owner_of(key)
    }

    fn absorb(&mut self, node: usize, pairs: Vec<(K, V)>, red: &Reducer<V>) {
        for (k, v) in pairs {
            debug_assert_eq!(self.owner_of(&k), node);
            match self.shards[node].entry(k) {
                std::collections::hash_map::Entry::Occupied(mut e) => red.apply(e.get_mut(), &v),
                std::collections::hash_map::Entry::Vacant(e) => {
                    e.insert(v);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_get_across_nodes() {
        let c = Cluster::local(4, 1);
        let mut m: DistHashMap<String, u64> = DistHashMap::new(&c);
        for i in 0..100 {
            m.insert(format!("key{i}"), i);
        }
        assert_eq!(m.len(), 100);
        assert_eq!(m.get(&"key42".to_string()), Some(42));
        assert_eq!(m.get(&"nope".to_string()), None);
        // Keys actually spread across shards.
        let occupied = (0..4).filter(|&n| !m.shard(n).is_empty()).count();
        assert!(occupied >= 3, "only {occupied} shards occupied");
    }

    #[test]
    fn merge_reduces() {
        let c = Cluster::local(2, 1);
        let mut m: DistHashMap<String, u64> = DistHashMap::new(&c);
        let red = Reducer::sum();
        m.merge("a".into(), 1, &red);
        m.merge("a".into(), 2, &red);
        assert_eq!(m.get(&"a".to_string()), Some(3));
    }

    #[test]
    fn collect_roundtrip() {
        let c = Cluster::local(3, 1);
        let mut src = HashMap::new();
        for i in 0..50u64 {
            src.insert(format!("k{i}"), i);
        }
        let m = DistHashMap::from_hashmap(&c, src.clone());
        assert_eq!(m.collect(), src);
    }

    #[test]
    fn foreach_mutates() {
        let c = Cluster::local(2, 1);
        let mut m: DistHashMap<u64, u64> = DistHashMap::new(&c);
        for i in 0..20 {
            m.insert(i, i);
        }
        m.foreach(|_, v| *v *= 10);
        assert_eq!(m.get(&7), Some(70));
    }

    #[test]
    fn rebalance_no_moves_when_uniform() {
        let c = Cluster::local(4, 1);
        let mut m: DistHashMap<u64, u64> = DistHashMap::new(&c);
        for i in 0..10_000 {
            m.insert(i, 1);
        }
        let before = m.imbalance();
        assert!(before < 1.2, "uniform keys should balance, got {before}");
        let plan = m.rebalance();
        // Near-balanced already: the plan should barely move anything.
        assert!(
            plan.cost_bytes() < 10_000 * 2,
            "moved {} bytes on balanced input",
            plan.cost_bytes()
        );
    }

    #[test]
    fn lookups_survive_rebalance() {
        let c = Cluster::local(4, 1);
        let mut m: DistHashMap<String, u64> = DistHashMap::new(&c);
        for i in 0..1000 {
            m.insert(format!("key{i}"), i);
        }
        m.rebalance();
        for i in 0..1000 {
            assert_eq!(m.get(&format!("key{i}")), Some(i), "key{i} lost");
        }
        assert_eq!(m.len(), 1000);
    }

    #[test]
    fn evacuate_empties_dead_nodes_and_keeps_lookups() {
        let c = Cluster::local(4, 1);
        let mut m: DistHashMap<String, u64> = DistHashMap::new(&c);
        for i in 0..1000 {
            m.insert(format!("key{i}"), i);
        }
        let plan = m.evacuate(&[1, 3]);
        assert!(plan.cost_bytes() > 0, "dead-node entries must move");
        // Dead shards drained; no key routes to them anymore.
        assert!(m.shard(1).is_empty());
        assert!(m.shard(3).is_empty());
        for i in 0..1000 {
            let key = format!("key{i}");
            let owner = m.owner_of(&key);
            assert!(owner == 0 || owner == 2, "key{i} routed to dead node {owner}");
            assert_eq!(m.get(&key), Some(i), "key{i} lost in evacuation");
        }
        assert_eq!(m.len(), 1000);
        assert!(c.metrics().last_run().unwrap().label.contains("evacuate"));
    }

    #[test]
    fn target_absorb_reduces_into_shard() {
        let c = Cluster::local(2, 1);
        let mut m: DistHashMap<String, u64> = DistHashMap::new(&c);
        let red = Reducer::sum();
        let key = "hello".to_string();
        let node = m.owner_of(&key);
        m.absorb(node, vec![(key.clone(), 2), (key.clone(), 3)], &red);
        assert_eq!(m.get(&key), Some(5));
    }
}
