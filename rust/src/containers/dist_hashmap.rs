//! `DistHashMap` — a hash-slot-partitioned distributed map (paper §2.1).
//!
//! Keys route through [`crate::coordinator::rebalance::NUM_SLOTS`] hash
//! slots; a coordinator-owned slot→node map assigns slots to nodes and can
//! be rebalanced when key skew piles weight onto a few slots.

use std::collections::HashMap;
use std::hash::Hash;

use crate::coordinator::cluster::Cluster;
use crate::coordinator::metrics::RunStats;
use crate::coordinator::rebalance::{self, MovePlan, SlotMap, NUM_SLOTS};
use crate::mapreduce::{BlockCursor, DistInput, ReduceTarget, Reducer};
use crate::net::sim::FlowMatrix;
use crate::ser::fastser::FastSer;
use crate::util::hash::{fxhash, FxHashMap};

/// Distributed hash map: key/value pairs partitioned by hash slot.
#[derive(Debug, Clone)]
pub struct DistHashMap<K, V> {
    cluster: Cluster,
    slot_map: SlotMap,
    shards: Vec<FxHashMap<K, V>>,
}

impl<K, V> DistHashMap<K, V>
where
    K: Hash + Eq + Clone,
    V: Clone,
{
    /// Empty map over `cluster`.
    pub fn new(cluster: &Cluster) -> Self {
        Self {
            cluster: cluster.clone(),
            slot_map: SlotMap::even(cluster.nodes()),
            shards: (0..cluster.nodes()).map(|_| FxHashMap::default()).collect(),
        }
    }

    /// Hash slot of `key`.
    #[inline]
    pub fn slot_of(&self, key: &K) -> usize {
        (fxhash(key) % NUM_SLOTS as u64) as usize
    }

    /// Node owning `key` under the current slot map.
    #[inline]
    pub fn owner_of(&self, key: &K) -> usize {
        self.slot_map.node_of(self.slot_of(key))
    }

    /// Entry count across all shards (paper's `words.size()`).
    pub fn len(&self) -> usize {
        self.shards.iter().map(HashMap::len).sum()
    }

    /// True if no entries.
    pub fn is_empty(&self) -> bool {
        self.shards.iter().all(HashMap::is_empty)
    }

    /// Owning cluster.
    pub fn cluster(&self) -> &Cluster {
        &self.cluster
    }

    /// Look up one key.
    pub fn get(&self, key: &K) -> Option<V> {
        self.shards[self.owner_of(key)].get(key).cloned()
    }

    /// Insert or overwrite one key.
    pub fn insert(&mut self, key: K, value: V) {
        let node = self.owner_of(&key);
        self.shards[node].insert(key, value);
    }

    /// Insert-or-reduce one key (the map's native merge operation).
    pub fn merge(&mut self, key: K, value: V, red: &Reducer<V>) {
        let node = self.owner_of(&key);
        match self.shards[node].entry(key) {
            std::collections::hash_map::Entry::Occupied(mut e) => red.apply(e.get_mut(), &value),
            std::collections::hash_map::Entry::Vacant(e) => {
                e.insert(value);
            }
        }
    }

    /// Build from a standard `HashMap` (paper's `distribute`).
    pub fn from_hashmap(cluster: &Cluster, data: HashMap<K, V>) -> Self {
        let mut out = Self::new(cluster);
        for (k, v) in data {
            out.insert(k, v);
        }
        out
    }

    /// Gather into a standard `HashMap` (paper's `collect`).
    pub fn collect(&self) -> HashMap<K, V> {
        let mut out = HashMap::with_capacity(self.len());
        for shard in &self.shards {
            for (k, v) in shard {
                out.insert(k.clone(), v.clone());
            }
        }
        out
    }

    /// Apply `f` to every entry in parallel (paper's `foreach`); values may
    /// be mutated.
    pub fn foreach(&mut self, mut f: impl FnMut(&K, &mut V)) {
        for shard in &mut self.shards {
            for (k, v) in shard.iter_mut() {
                f(k, v);
            }
        }
    }

    /// Node-local shard (read).
    pub fn shard(&self, node: usize) -> &FxHashMap<K, V> {
        &self.shards[node]
    }

    /// Per-slot (entry count, serialized bytes) — the rebalancer's input.
    pub fn slot_weights(&self) -> (Vec<u64>, Vec<u64>)
    where
        K: FastSer,
        V: FastSer,
    {
        let mut counts = vec![0u64; NUM_SLOTS];
        let mut bytes = vec![0u64; NUM_SLOTS];
        for shard in &self.shards {
            for (k, v) in shard {
                let slot = self.slot_of(k);
                counts[slot] += 1;
                bytes[slot] += (k.encoded_len() + v.encoded_len()) as u64;
            }
        }
        (counts, bytes)
    }

    /// Rebalance shards to even out per-node load. Moves are executed for
    /// real (entries re-home, bytes counted through the flow model) and the
    /// plan is returned. No-op on a 1-node cluster.
    pub fn rebalance(&mut self) -> MovePlan
    where
        K: FastSer,
        V: FastSer,
    {
        let nodes = self.cluster.nodes();
        let (counts, bytes) = self.slot_weights();
        let plan = rebalance::plan(&self.slot_map, &counts, &bytes, nodes);
        self.apply_plan(plan, "disthashmap.rebalance")
    }

    /// Plan an evacuation of `dead` nodes from measured slot weights —
    /// the shared planning step behind [`Self::evacuate`] and the recovery
    /// engine's [`crate::fault::Recover::evacuate_dead`] hook.
    fn evacuation_plan(&self, dead: &[usize]) -> MovePlan
    where
        K: FastSer,
        V: FastSer,
    {
        let (counts, bytes) = self.slot_weights();
        rebalance::plan_with_dead(&self.slot_map, &counts, &bytes, self.cluster.nodes(), dead)
    }

    /// Evacuate `dead` nodes: recompute the slot map over the survivors
    /// ([`rebalance::plan_with_dead`]) and re-home every affected entry,
    /// with the moved bytes counted through the flow model. After this no
    /// key routes to a dead node. No-op when `dead` is empty and the load
    /// is already balanced.
    pub fn evacuate(&mut self, dead: &[usize]) -> MovePlan
    where
        K: FastSer,
        V: FastSer,
    {
        let plan = self.evacuation_plan(dead);
        self.apply_plan(plan, "disthashmap.evacuate")
    }

    /// Execute a rebalance plan: move entries between shards (serializing
    /// for real) and adopt the new slot map. Returns one `(from, to,
    /// bytes)` flow per executed move; no metrics are recorded — callers
    /// charge the transfer themselves ([`Self::apply_plan`] as a
    /// standalone run, the recovery engine into its job's virtual time).
    pub(crate) fn execute_plan(&mut self, plan: &MovePlan) -> Vec<(usize, usize, u64)>
    where
        K: FastSer,
        V: FastSer,
    {
        let mut flows = Vec::with_capacity(plan.moves.len());
        for mv in &plan.moves {
            // Re-home every entry in the moved slot, serializing for real.
            let moved: Vec<(K, V)> = self.shards[mv.from]
                .iter()
                .filter(|(k, _)| self.slot_of(k) == mv.slot)
                .map(|(k, v)| (k.clone(), v.clone()))
                .collect();
            let mut w = crate::ser::fastser::Writer::new();
            for (k, v) in &moved {
                k.write(&mut w);
                v.write(&mut w);
            }
            flows.push((mv.from, mv.to, w.len() as u64));
            for (k, v) in moved {
                self.shards[mv.from].remove(&k);
                self.shards[mv.to].insert(k, v);
            }
        }
        self.slot_map = plan.new_map.clone();
        flows
    }

    /// Execute a rebalance plan as a standalone operation: move entries,
    /// adopt the new map, record the transfer as its own run.
    fn apply_plan(&mut self, plan: MovePlan, label: &str) -> MovePlan
    where
        K: FastSer,
        V: FastSer,
    {
        let nodes = self.cluster.nodes();
        let mut flows = FlowMatrix::new(nodes);
        for (from, to, bytes) in self.execute_plan(&plan) {
            flows.record(from, to, bytes);
        }
        let transfer = flows.phase_time(&self.cluster.config().network);
        self.cluster.metrics().record_run(RunStats {
            label: label.into(),
            engine: self.cluster.config().engine.to_string(),
            nodes,
            workers_per_node: self.cluster.workers(),
            makespan_sec: transfer,
            shuffle_sec: transfer,
            shuffle_bytes: flows.cross_node_bytes(),
            ..Default::default()
        });
        plan
    }

    /// Load imbalance (max/mean entries per node).
    pub fn imbalance(&self) -> f64 {
        let loads: Vec<usize> = self.shards.iter().map(HashMap::len).collect();
        let total: usize = loads.iter().sum();
        if total == 0 {
            return 1.0;
        }
        let mean = total as f64 / loads.len() as f64;
        *loads.iter().max().unwrap() as f64 / mean
    }
}

/// Block cursor over one hash shard: a single persistent shard iterator
/// sliced into per-worker blocks by position, so walking all blocks in
/// order touches every entry exactly once (no per-block skip rescans).
pub struct HashBlockCursor<'a, K, V> {
    iter: std::collections::hash_map::Iter<'a, K, V>,
    sizes: std::vec::IntoIter<usize>,
}

impl<K, V> BlockCursor<K, V> for HashBlockCursor<'_, K, V> {
    fn next_block<F: FnMut(&K, &V)>(&mut self, mut f: F) -> bool {
        let Some(len) = self.sizes.next() else { return false };
        for _ in 0..len {
            let (k, v) = self.iter.next().expect("block sizes cover the shard");
            f(k, v);
        }
        true
    }
}

impl<K, V> DistInput for DistHashMap<K, V>
where
    K: Hash + Eq + Clone,
    V: Clone,
{
    type K = K;
    type V = V;
    type Cursor<'a>
        = HashBlockCursor<'a, K, V>
    where
        Self: 'a;

    fn cluster(&self) -> &Cluster {
        &self.cluster
    }

    fn node_len(&self, node: usize) -> usize {
        self.shards[node].len()
    }

    fn block_cursor(&self, node: usize, workers: usize) -> HashBlockCursor<'_, K, V> {
        // Worker assignment by position in iteration order (block split).
        let sizes: Vec<usize> =
            crate::coordinator::scheduler::block_ranges(self.shards[node].len(), workers)
                .into_iter()
                .map(|r| r.len())
                .collect();
        HashBlockCursor { iter: self.shards[node].iter(), sizes: sizes.into_iter() }
    }
}

/// Checkpoint support: a shard snapshots as one fast-codec pair batch and
/// restores by *replacing* the shard (the snapshot already contains any
/// merged-into history).
impl<K, V> crate::fault::Recover for DistHashMap<K, V>
where
    K: Hash + Eq + Clone + FastSer,
    V: Clone + FastSer,
{
    fn snapshot_shard(&self, node: usize) -> Option<Vec<u8>> {
        // The shared `encode_pairs` batch frame, written straight from the
        // shard iterator — no clone of the entries on the checkpoint hot
        // path.
        let shard = &self.shards[node];
        let mut w = crate::ser::fastser::Writer::new();
        crate::ser::fastser::write_pairs(&mut w, shard.len(), shard.iter());
        Some(w.take())
    }

    fn restore_shard(
        &mut self,
        node: usize,
        bytes: &[u8],
    ) -> Result<(), crate::ser::fastser::DecodeError> {
        let pairs = crate::ser::fastser::decode_pairs_exact::<K, V>(bytes)?;
        let mut shard = FxHashMap::default();
        shard.extend(pairs);
        self.shards[node] = shard;
        Ok(())
    }

    fn lose_shard(&mut self, node: usize) {
        self.shards[node] = FxHashMap::default();
    }

    /// Recovery-time evacuation: recompute the slot map over the survivors
    /// and relocate every affected entry, returning the real serialized
    /// bytes per move for the recovery engine to charge. Entries are moved,
    /// never re-reduced, so results are unchanged.
    fn evacuate_dead(&mut self, dead: &[usize]) -> Option<Vec<(usize, usize, u64)>> {
        let plan = self.evacuation_plan(dead);
        Some(self.execute_plan(&plan))
    }
}

/// `DistHashMap` as a MapReduce target (the word-count example's `words`).
impl<K, V> ReduceTarget<K, V> for DistHashMap<K, V>
where
    K: Hash + Eq + Clone,
    V: Clone,
{
    fn shard_of(&self, key: &K, _nodes: usize) -> usize {
        self.owner_of(key)
    }

    fn absorb(&mut self, node: usize, pairs: Vec<(K, V)>, red: &Reducer<V>) {
        for (k, v) in pairs {
            debug_assert_eq!(self.owner_of(&k), node);
            match self.shards[node].entry(k) {
                std::collections::hash_map::Entry::Occupied(mut e) => red.apply(e.get_mut(), &v),
                std::collections::hash_map::Entry::Vacant(e) => {
                    e.insert(v);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_get_across_nodes() {
        let c = Cluster::local(4, 1);
        let mut m: DistHashMap<String, u64> = DistHashMap::new(&c);
        for i in 0..100 {
            m.insert(format!("key{i}"), i);
        }
        assert_eq!(m.len(), 100);
        assert_eq!(m.get(&"key42".to_string()), Some(42));
        assert_eq!(m.get(&"nope".to_string()), None);
        // Keys actually spread across shards.
        let occupied = (0..4).filter(|&n| !m.shard(n).is_empty()).count();
        assert!(occupied >= 3, "only {occupied} shards occupied");
    }

    #[test]
    fn merge_reduces() {
        let c = Cluster::local(2, 1);
        let mut m: DistHashMap<String, u64> = DistHashMap::new(&c);
        let red = Reducer::sum();
        m.merge("a".into(), 1, &red);
        m.merge("a".into(), 2, &red);
        assert_eq!(m.get(&"a".to_string()), Some(3));
    }

    #[test]
    fn collect_roundtrip() {
        let c = Cluster::local(3, 1);
        let mut src = HashMap::new();
        for i in 0..50u64 {
            src.insert(format!("k{i}"), i);
        }
        let m = DistHashMap::from_hashmap(&c, src.clone());
        assert_eq!(m.collect(), src);
    }

    #[test]
    fn foreach_mutates() {
        let c = Cluster::local(2, 1);
        let mut m: DistHashMap<u64, u64> = DistHashMap::new(&c);
        for i in 0..20 {
            m.insert(i, i);
        }
        m.foreach(|_, v| *v *= 10);
        assert_eq!(m.get(&7), Some(70));
    }

    #[test]
    fn rebalance_no_moves_when_uniform() {
        let c = Cluster::local(4, 1);
        let mut m: DistHashMap<u64, u64> = DistHashMap::new(&c);
        for i in 0..10_000 {
            m.insert(i, 1);
        }
        let before = m.imbalance();
        assert!(before < 1.2, "uniform keys should balance, got {before}");
        let plan = m.rebalance();
        // Near-balanced already: the plan should barely move anything.
        assert!(
            plan.cost_bytes() < 10_000 * 2,
            "moved {} bytes on balanced input",
            plan.cost_bytes()
        );
    }

    #[test]
    fn lookups_survive_rebalance() {
        let c = Cluster::local(4, 1);
        let mut m: DistHashMap<String, u64> = DistHashMap::new(&c);
        for i in 0..1000 {
            m.insert(format!("key{i}"), i);
        }
        m.rebalance();
        for i in 0..1000 {
            assert_eq!(m.get(&format!("key{i}")), Some(i), "key{i} lost");
        }
        assert_eq!(m.len(), 1000);
    }

    #[test]
    fn evacuate_empties_dead_nodes_and_keeps_lookups() {
        let c = Cluster::local(4, 1);
        let mut m: DistHashMap<String, u64> = DistHashMap::new(&c);
        for i in 0..1000 {
            m.insert(format!("key{i}"), i);
        }
        let plan = m.evacuate(&[1, 3]);
        assert!(plan.cost_bytes() > 0, "dead-node entries must move");
        // Dead shards drained; no key routes to them anymore.
        assert!(m.shard(1).is_empty());
        assert!(m.shard(3).is_empty());
        for i in 0..1000 {
            let key = format!("key{i}");
            let owner = m.owner_of(&key);
            assert!(owner == 0 || owner == 2, "key{i} routed to dead node {owner}");
            assert_eq!(m.get(&key), Some(i), "key{i} lost in evacuation");
        }
        assert_eq!(m.len(), 1000);
        assert!(c.metrics().last_run().unwrap().label.contains("evacuate"));
    }

    #[test]
    fn target_absorb_reduces_into_shard() {
        let c = Cluster::local(2, 1);
        let mut m: DistHashMap<String, u64> = DistHashMap::new(&c);
        let red = Reducer::sum();
        let key = "hello".to_string();
        let node = m.owner_of(&key);
        m.absorb(node, vec![(key.clone(), 2), (key.clone(), 3)], &red);
        assert_eq!(m.get(&key), Some(5));
    }

    #[test]
    fn block_cursor_single_pass_covers_every_entry_once() {
        let c = Cluster::local(3, 4);
        let mut m: DistHashMap<u64, u64> = DistHashMap::new(&c);
        for i in 0..100 {
            m.insert(i, i * 2);
        }
        for node in 0..3 {
            let mut seen: Vec<u64> = Vec::new();
            let mut cur = m.block_cursor(node, 4);
            let mut blocks = 0usize;
            while cur.next_block(|k, v| {
                assert_eq!(*v, *k * 2);
                seen.push(*k);
            }) {
                blocks += 1;
            }
            assert_eq!(blocks, 4, "one block per worker even when the shard is small");
            assert_eq!(seen.len(), m.node_len(node), "every entry exactly once");
            let mut dedup = seen.clone();
            dedup.sort_unstable();
            dedup.dedup();
            assert_eq!(dedup.len(), seen.len(), "no entry visited twice");
        }
    }

    #[test]
    fn evacuate_dead_moves_entries_and_reports_flows() {
        use crate::fault::Recover;
        let c = Cluster::local(4, 1);
        let mut m: DistHashMap<String, u64> = DistHashMap::new(&c);
        for i in 0..500 {
            m.insert(format!("key{i}"), i);
        }
        let before = m.collect();
        let flows = m.evacuate_dead(&[2]).expect("hash maps support re-homing");
        let from_dead: u64 =
            flows.iter().filter(|(src, _, _)| *src == 2).map(|(_, _, b)| b).sum();
        assert!(from_dead > 0, "dead node's entries must be charged as moved bytes");
        for (_, dst, _) in &flows {
            assert_ne!(*dst, 2, "no slot may move onto the dead node");
        }
        assert!(m.shard(2).is_empty());
        for i in 0..500 {
            assert_ne!(m.owner_of(&format!("key{i}")), 2, "key{i} still routed to dead node");
        }
        assert_eq!(m.collect(), before, "evacuation relocates, never changes entries");
        // Unlike `evacuate`, the recovery hook records no standalone run —
        // the engine charges the flows into its own job.
        assert!(c.metrics().runs().iter().all(|r| !r.label.contains("evacuate")));
    }
}
