//! `DistVector` — a block-partitioned distributed array (paper §2.1).

use std::time::Instant;

use crate::coordinator::cluster::Cluster;
use crate::coordinator::metrics::RunStats;
use crate::coordinator::scheduler::block_ranges;
use crate::mapreduce::{BlockCursor, DistInput, ReduceTarget, Reducer};
use crate::net::sim::FlowMatrix;
use crate::net::vtime::VirtualTime;
use crate::ser::fastser::FastSer;
use crate::util::topk::TopK;

/// Distributed vector: elements block-partitioned across nodes.
#[derive(Debug, Clone)]
pub struct DistVector<T> {
    cluster: Cluster,
    shards: Vec<Vec<T>>,
}

impl<T> DistVector<T> {
    /// Empty distributed vector.
    pub fn new(cluster: &Cluster) -> Self {
        Self { cluster: cluster.clone(), shards: (0..cluster.nodes()).map(|_| Vec::new()).collect() }
    }

    /// Distribute `data` across the cluster in contiguous blocks
    /// (the paper's `distribute` utility).
    pub fn from_vec(cluster: &Cluster, mut data: Vec<T>) -> Self {
        let ranges = block_ranges(data.len(), cluster.nodes());
        let mut shards: Vec<Vec<T>> = Vec::with_capacity(cluster.nodes());
        // Split back-to-front so each shard is a cheap tail split.
        for range in ranges.iter().rev() {
            shards.push(data.split_off(range.start));
        }
        shards.reverse();
        Self { cluster: cluster.clone(), shards }
    }

    /// `n` copies of `value` distributed across the cluster.
    pub fn filled(cluster: &Cluster, n: usize, value: T) -> Self
    where
        T: Clone,
    {
        let ranges = block_ranges(n, cluster.nodes());
        Self {
            cluster: cluster.clone(),
            shards: ranges.iter().map(|r| vec![value.clone(); r.len()]).collect(),
        }
    }

    /// Build directly from per-node shards (data that is *already*
    /// distributed — e.g. per-node computation outputs).
    pub fn from_shards(cluster: &Cluster, shards: Vec<Vec<T>>) -> Self {
        assert_eq!(shards.len(), cluster.nodes(), "one shard per node");
        Self { cluster: cluster.clone(), shards }
    }

    /// Element-wise zip of two equally-partitioned vectors (used by the
    /// paper-structured GMM to pair points with memberships).
    pub fn zip<B: Clone>(a: &DistVector<T>, b: &DistVector<B>) -> DistVector<(T, B)>
    where
        T: Clone,
    {
        assert!(a.cluster.same_cluster(&b.cluster), "zip across clusters");
        assert_eq!(a.len(), b.len(), "zip length mismatch");
        DistVector {
            cluster: a.cluster.clone(),
            shards: a
                .shards
                .iter()
                .zip(&b.shards)
                .map(|(sa, sb)| sa.iter().cloned().zip(sb.iter().cloned()).collect())
                .collect(),
        }
    }

    /// Build from a generator called with each global index.
    pub fn from_fn(cluster: &Cluster, n: usize, mut f: impl FnMut(usize) -> T) -> Self {
        let ranges = block_ranges(n, cluster.nodes());
        Self {
            cluster: cluster.clone(),
            shards: ranges.iter().map(|r| r.clone().map(&mut f).collect()).collect(),
        }
    }

    /// Total element count.
    pub fn len(&self) -> usize {
        self.shards.iter().map(Vec::len).sum()
    }

    /// True if no elements.
    pub fn is_empty(&self) -> bool {
        self.shards.iter().all(Vec::is_empty)
    }

    /// Owning cluster.
    pub fn cluster(&self) -> &Cluster {
        &self.cluster
    }

    /// Per-node global start offsets (shard sizes may be uneven after
    /// [`Self::from_shards`]).
    pub fn offsets(&self) -> Vec<usize> {
        let mut out = Vec::with_capacity(self.shards.len());
        let mut acc = 0;
        for s in &self.shards {
            out.push(acc);
            acc += s.len();
        }
        out
    }

    /// Element at global index `i`.
    pub fn get(&self, i: usize) -> Option<&T> {
        let mut rem = i;
        for shard in &self.shards {
            if rem < shard.len() {
                return shard.get(rem);
            }
            rem -= shard.len();
        }
        None
    }

    /// Gather all elements to the driver (paper's `collect`).
    pub fn collect(&self) -> Vec<T>
    where
        T: Clone,
    {
        let mut out = Vec::with_capacity(self.len());
        for shard in &self.shards {
            out.extend(shard.iter().cloned());
        }
        out
    }

    /// Node-local shard (read).
    pub fn shard(&self, node: usize) -> &[T] {
        &self.shards[node]
    }

    /// Apply `f` to every element in parallel (paper's `foreach`); `f` may
    /// mutate elements in place. Measured and recorded as a compute phase.
    pub fn foreach(&mut self, mut f: impl FnMut(usize, &mut T)) {
        let nodes = self.cluster.nodes();
        let workers = self.cluster.workers();
        let n = self.len();
        let ranges = block_ranges(n, nodes);
        let mut per_node_secs = vec![0.0f64; nodes];
        for node in 0..nodes {
            let t0 = Instant::now();
            let start = ranges[node].start;
            for (i, item) in self.shards[node].iter_mut().enumerate() {
                f(start + i, item);
            }
            per_node_secs[node] = t0.elapsed().as_secs_f64();
        }
        let mut vt = VirtualTime::new();
        vt.compute_phase("foreach", &per_node_secs, workers);
        self.record(&vt, "distvector.foreach", 0);
    }

    /// Top-`k` elements under `cmp` (`Greater` = higher priority), computed
    /// with per-node bounded heaps and a tree merge — `O(n + k log k)` time,
    /// `O(k)` space per node (paper §2.1).
    pub fn topk(&self, k: usize, cmp: impl Fn(&T, &T) -> std::cmp::Ordering + Copy) -> Vec<T>
    where
        T: Clone + FastSer,
    {
        self.topk_labeled(k, cmp, "distvector.topk")
    }

    /// [`Self::topk`] with an explicit metrics label.
    pub fn topk_labeled(
        &self,
        k: usize,
        cmp: impl Fn(&T, &T) -> std::cmp::Ordering + Copy,
        label: &str,
    ) -> Vec<T>
    where
        T: Clone + FastSer,
    {
        let nodes = self.cluster.nodes();
        let workers = self.cluster.workers();
        let mut per_node_secs = vec![0.0f64; nodes];
        let mut partials: Vec<Option<TopK<T, _>>> = Vec::with_capacity(nodes);
        for node in 0..nodes {
            let t0 = Instant::now();
            // Per-worker heaps merged locally — same plan as the map phase.
            let worker_ranges = block_ranges(self.shards[node].len(), workers);
            let mut worker_heaps: Vec<TopK<T, _>> =
                (0..workers).map(|_| TopK::new(k, cmp)).collect();
            for (w, wr) in worker_ranges.into_iter().enumerate() {
                for item in &self.shards[node][wr] {
                    worker_heaps[w].push(item.clone());
                }
            }
            let mut iter = worker_heaps.into_iter();
            let mut acc = iter.next().expect("at least one worker");
            for heap in iter {
                acc.merge(heap);
            }
            per_node_secs[node] = t0.elapsed().as_secs_f64();
            partials.push(Some(acc));
        }
        let mut vt = VirtualTime::new();
        vt.compute_phase("topk-local", &per_node_secs, workers);

        // Binomial tree merge across nodes; candidates serialize for real.
        let mut shuffle_bytes = 0u64;
        let mut stride = 1usize;
        while stride < nodes {
            let mut flows = FlowMatrix::new(nodes);
            let mut merge_secs = 0.0f64;
            for src in (stride..nodes).step_by(stride * 2) {
                let dst = src - stride;
                let Some(part) = partials[src].take() else { continue };
                let candidates = part.into_sorted();
                let mut w = crate::ser::fastser::Writer::new();
                candidates.write(&mut w);
                flows.record(src, dst, w.len() as u64);
                shuffle_bytes += w.len() as u64;
                let t0 = Instant::now();
                let acc = partials[dst].as_mut().expect("merge destination");
                for item in candidates {
                    acc.push(item);
                }
                merge_secs = merge_secs.max(t0.elapsed().as_secs_f64());
            }
            vt.shuffle_overlapped("topk-tree-merge", &flows, &self.cluster.config().network, merge_secs);
            stride *= 2;
        }
        let result = partials[0].take().expect("driver partial").into_sorted();
        self.record(&vt, label, shuffle_bytes);
        result
    }

    fn record(&self, vt: &VirtualTime, label: &str, shuffle_bytes: u64) {
        self.cluster.metrics().record_run(RunStats {
            label: label.into(),
            engine: self.cluster.config().engine.to_string(),
            nodes: self.cluster.nodes(),
            workers_per_node: self.cluster.workers(),
            makespan_sec: vt.makespan(),
            compute_sec: vt.makespan(),
            shuffle_sec: 0.0,
            shuffle_bytes,
            ..Default::default()
        });
    }
}

/// Block cursor over one shard: worker blocks are contiguous slices, so
/// each block is yielded in O(its length) with no rescans.
pub struct VectorBlockCursor<'a, T> {
    shard: &'a [T],
    /// Global index of the shard's first element.
    start: usize,
    ranges: std::vec::IntoIter<std::ops::Range<usize>>,
}

impl<T> BlockCursor<usize, T> for VectorBlockCursor<'_, T> {
    fn next_block<F: FnMut(&usize, &T)>(&mut self, mut f: F) -> bool {
        let Some(r) = self.ranges.next() else { return false };
        for i in r {
            f(&(self.start + i), &self.shard[i]);
        }
        true
    }
}

impl<T> DistInput for DistVector<T> {
    type K = usize;
    type V = T;
    type Cursor<'a>
        = VectorBlockCursor<'a, T>
    where
        Self: 'a;

    fn cluster(&self) -> &Cluster {
        &self.cluster
    }

    fn node_len(&self, node: usize) -> usize {
        self.shards[node].len()
    }

    fn block_cursor(&self, node: usize, workers: usize) -> VectorBlockCursor<'_, T> {
        VectorBlockCursor {
            shard: &self.shards[node],
            start: self.offsets()[node],
            ranges: block_ranges(self.shards[node].len(), workers).into_iter(),
        }
    }
}

/// Checkpoint support: a shard snapshots as its element vector (fast
/// codec) and restores by replacement, preserving the shard's length and
/// block boundaries.
impl<V: Clone + FastSer> crate::fault::Recover for DistVector<V> {
    fn snapshot_shard(&self, node: usize) -> Option<Vec<u8>> {
        let mut w = crate::ser::fastser::Writer::new();
        self.shards[node].write(&mut w);
        Some(w.take())
    }

    fn restore_shard(
        &mut self,
        node: usize,
        bytes: &[u8],
    ) -> Result<(), crate::ser::fastser::DecodeError> {
        let mut r = crate::ser::fastser::Reader::new(bytes);
        let shard = Vec::<V>::read(&mut r)?;
        r.expect_end()?;
        self.shards[node] = shard;
        Ok(())
    }

    fn lose_shard(&mut self, node: usize) {
        self.shards[node] = Vec::new();
    }
}

/// `DistVector` as a MapReduce target: keys are global element indices,
/// routed to the owning node's shard (PageRank's score vector).
impl<V: Clone> ReduceTarget<usize, V> for DistVector<V> {
    fn shard_of(&self, key: &usize, _nodes: usize) -> usize {
        let mut rem = *key;
        for (node, shard) in self.shards.iter().enumerate() {
            if rem < shard.len() {
                return node;
            }
            rem -= shard.len();
        }
        panic!("key {key} outside DistVector target of length {}", self.len())
    }

    fn absorb(&mut self, node: usize, pairs: Vec<(usize, V)>, red: &Reducer<V>) {
        let start = self.offsets()[node];
        let shard = &mut self.shards[node];
        for (k, v) in pairs {
            let local = k - start;
            assert!(local < shard.len(), "key {k} not owned by node {node}");
            red.apply(&mut shard[local], &v);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn distribute_collect_roundtrip() {
        let c = Cluster::local(3, 2);
        let data: Vec<u64> = (0..100).collect();
        let dv = DistVector::from_vec(&c, data.clone());
        assert_eq!(dv.len(), 100);
        assert_eq!(dv.collect(), data);
        // Block partitioning: shards are contiguous and near-even.
        assert_eq!(dv.shard(0).len(), 34);
        assert_eq!(dv.shard(1).len(), 33);
        assert_eq!(dv.shard(2).len(), 33);
        assert_eq!(dv.shard(1)[0], 34);
    }

    #[test]
    fn get_global_index() {
        let c = Cluster::local(4, 1);
        let dv = DistVector::from_vec(&c, (0..10u64).collect());
        for i in 0..10 {
            assert_eq!(*dv.get(i).unwrap(), i as u64);
        }
        assert!(dv.get(10).is_none());
    }

    #[test]
    fn foreach_mutates_all() {
        let c = Cluster::local(2, 2);
        let mut dv = DistVector::from_vec(&c, vec![1u64; 50]);
        dv.foreach(|i, v| *v += i as u64);
        let collected = dv.collect();
        for (i, v) in collected.iter().enumerate() {
            assert_eq!(*v, 1 + i as u64);
        }
        assert!(c.metrics().last_run().unwrap().label.contains("foreach"));
    }

    #[test]
    fn topk_matches_sort_oracle() {
        let c = Cluster::local(4, 2);
        let data: Vec<u64> = (0..1000).map(|i| (i * 7919) % 1000).collect();
        let dv = DistVector::from_vec(&c, data.clone());
        let top = dv.topk(10, |a, b| a.cmp(b));
        let mut oracle = data;
        oracle.sort_unstable_by(|a, b| b.cmp(a));
        oracle.truncate(10);
        assert_eq!(top, oracle);
        // Tree merge must have shuffled candidate bytes.
        assert!(c.metrics().last_run().unwrap().shuffle_bytes > 0);
    }

    #[test]
    fn reduce_target_routes_to_owner() {
        let c = Cluster::local(2, 1);
        let mut dv = DistVector::filled(&c, 10, 0u64);
        let red = Reducer::sum();
        // Node 0 owns 0..5, node 1 owns 5..10.
        <DistVector<u64> as ReduceTarget<usize, u64>>::absorb(
            &mut dv,
            0,
            vec![(0, 5), (4, 2)],
            &red,
        );
        <DistVector<u64> as ReduceTarget<usize, u64>>::absorb(
            &mut dv,
            1,
            vec![(9, 7)],
            &red,
        );
        assert_eq!(dv.collect(), vec![5, 0, 0, 0, 2, 0, 0, 0, 0, 7]);
        assert_eq!(
            <DistVector<u64> as ReduceTarget<usize, u64>>::shard_of(&dv, &9, 2),
            1
        );
    }

    #[test]
    fn from_fn_generates_in_order() {
        let c = Cluster::local(3, 1);
        let dv = DistVector::from_fn(&c, 10, |i| i * i);
        assert_eq!(dv.collect(), (0..10).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn block_cursor_yields_worker_blocks_in_order() {
        let c = Cluster::local(3, 4);
        let dv = DistVector::from_vec(&c, (0..50u64).collect());
        for node in 0..3 {
            let mut via_cursor: Vec<(usize, usize, u64)> = Vec::new();
            let mut cur = dv.block_cursor(node, 4);
            let mut w = 0usize;
            while cur.next_block(|k, v| via_cursor.push((w, *k, *v))) {
                w += 1;
            }
            assert_eq!(w, 4, "one block per worker, empty blocks included");
            assert!(!cur.next_block(|_, _| panic!("exhausted cursor must not visit")));
            let mut via_items: Vec<(usize, usize, u64)> = Vec::new();
            dv.for_each_worker_item(node, 4, |w, k, v| via_items.push((w, *k, *v)));
            assert_eq!(via_cursor, via_items, "cursor and tagged walk agree");
            assert_eq!(via_cursor.len(), dv.node_len(node));
        }
    }
}
