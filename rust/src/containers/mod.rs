//! Distributed data containers (paper §2.1).
//!
//! * [`DistRange`] — start/end/step only; nothing stored.
//! * [`DistVector`] — block-partitioned element array with `foreach`,
//!   `topk`, `distribute`/`collect`.
//! * [`DistHashMap`] — hash-slot-partitioned key/value store with
//!   `foreach`, `collect`, and coordinator-driven rebalancing.
//!
//! Utilities mirror the paper: [`distribute`] / [`collect_vector`] /
//! [`collect_hashmap`] convert to and from standard containers;
//! [`load_file`] loads a text file in parallel into a distributed vector of
//! lines.
//!
//! Threaded-backend handoff ([`crate::exec`]): containers themselves stay
//! `!Send` (they hold the `Rc`-based [`Cluster`] handle) and are only
//! touched by the feeder on the calling thread — the engine drains each
//! node's block cursor once and clones items into owned per-block buffers
//! for the worker pool. That is why MapReduce input item types need
//! `Clone + Send` (`usize`/`u64` indices, `String` lines, point tuples —
//! every paper workload qualifies); reduce *targets* never cross threads,
//! so they carry no extra bounds.

pub mod dist_hashmap;
pub mod dist_range;
pub mod dist_vector;

pub use dist_hashmap::DistHashMap;
pub use dist_range::DistRange;
pub use dist_vector::DistVector;

use crate::coordinator::cluster::Cluster;

/// Convert a standard `Vec` into a [`DistVector`] (paper's `distribute`).
pub fn distribute<T: Clone>(cluster: &Cluster, data: Vec<T>) -> DistVector<T> {
    DistVector::from_vec(cluster, data)
}

/// Gather a [`DistVector`] back into a standard `Vec` (paper's `collect`).
pub fn collect_vector<T: Clone>(v: &DistVector<T>) -> Vec<T> {
    v.collect()
}

/// Gather a [`DistHashMap`] into a standard `HashMap` (paper's `collect`).
pub fn collect_hashmap<K, V>(m: &DistHashMap<K, V>) -> std::collections::HashMap<K, V>
where
    K: std::hash::Hash + Eq + Clone,
    V: Clone,
{
    m.collect()
}

/// Load a text file in parallel into a distributed vector of lines
/// (paper's `load_file`).
pub fn load_file(cluster: &Cluster, path: impl AsRef<std::path::Path>) -> std::io::Result<DistVector<String>> {
    let content = std::fs::read_to_string(path)?;
    Ok(DistVector::from_vec(
        cluster,
        content.lines().map(str::to_string).collect(),
    ))
}
