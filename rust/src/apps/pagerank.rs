//! PageRank (paper §3.1.2, Fig 5).
//!
//! Three MapReduce operations per iteration, exactly as the paper
//! describes: (1) total score of all sinks, (2) new scores via Eq. 1,
//! (3) maximum score change for the convergence test. Links live
//! distributed in a `DistVector<(u32, u32)>` *aligned with score
//! ownership*: edge `(src, dst)` is stored on the node that owns
//! `scores[src]`, so the mapper's score read is node-local and the only
//! cross-node traffic is MR 2's `(dst, contribution)` shuffle — the same
//! data layout an MPI implementation would use.
//!
//! Note on the damping constant: the paper states `d = 0.15` in Eq. 1,
//! where `d` multiplies the link sum — the standard damping factor in that
//! position is 0.85 (Brin & Page), and with d=0.15 PageRank degenerates to
//! near-uniform. We read the paper's `d` as the *teleport* probability and
//! use damping 0.85.

use crate::containers::DistVector;
use crate::coordinator::cluster::Cluster;
use crate::data::graph500::Graph;
use crate::mapreduce::{mapreduce_labeled, Reducer};

use super::TaskReport;

/// Damping factor (probability of following a link).
pub const DAMPING: f64 = 0.85;

/// PageRank state and outcome.
#[derive(Debug, Clone)]
pub struct PageRankResult {
    /// Final scores, indexed by vertex.
    pub scores: Vec<f64>,
    /// Iterations to convergence.
    pub iterations: usize,
    /// Final max score delta.
    pub delta: f64,
}

/// Run PageRank to convergence (`tol`, capped at `max_iters`).
pub fn pagerank(
    cluster: &Cluster,
    graph: &Graph,
    tol: f64,
    max_iters: usize,
) -> (TaskReport, PageRankResult) {
    let n = graph.n_vertices;
    // Align edges and sinks with the block partition of the score vector:
    // node = owner of the source vertex. Score reads stay node-local.
    let owner_of = |v: u32| {
        crate::coordinator::scheduler::block_owner(n, cluster.nodes(), v as usize)
    };
    let mut edge_shards: Vec<Vec<(u32, u32)>> =
        (0..cluster.nodes()).map(|_| Vec::new()).collect();
    for &e in &graph.edges {
        edge_shards[owner_of(e.0)].push(e);
    }
    let edges: DistVector<(u32, u32)> = DistVector::from_shards(cluster, edge_shards);
    let mut sink_shards: Vec<Vec<u32>> =
        (0..cluster.nodes()).map(|_| Vec::new()).collect();
    for s in graph.sinks() {
        sink_shards[owner_of(s)].push(s);
    }
    let sinks: DistVector<u32> = DistVector::from_shards(cluster, sink_shards);
    let degrees: Vec<u32> = graph.out_degree.clone();

    let mut scores = vec![1.0f64 / n as f64; n];
    let mut iterations = 0;
    let mut delta = f64::INFINITY;

    while iterations < max_iters && delta > tol {
        let iter_label = |step: &str| format!("pagerank.i{iterations}.{step}");

        // MR 1: total score held by sinks (they redistribute uniformly).
        let mut sink_total = vec![0.0f64; 1];
        {
            let scores_ref = &scores;
            mapreduce_labeled(
                &iter_label("sinks"),
                &sinks,
                |_, v: &u32, emit| emit(0usize, scores_ref[*v as usize]),
                "sum",
                &mut sink_total,
            );
        }

        // MR 2: new scores per Eq. 1 (+ sink mass spread uniformly).
        let base = (1.0 - DAMPING) / n as f64 + DAMPING * sink_total[0] / n as f64;
        let mut new_scores: DistVector<f64> = DistVector::filled(cluster, n, base);
        {
            let scores_ref = &scores;
            let deg_ref = &degrees;
            mapreduce_labeled(
                &iter_label("scores"),
                &edges,
                |_, e: &(u32, u32), emit| {
                    let (src, dst) = (e.0 as usize, e.1 as usize);
                    emit(dst, DAMPING * scores_ref[src] / f64::from(deg_ref[src]));
                },
                "sum",
                &mut new_scores,
            );
        }

        // MR 3: max |new - old| for convergence.
        let mut max_delta = vec![0.0f64; 1];
        {
            let scores_ref = &scores;
            mapreduce_labeled(
                &iter_label("delta"),
                &new_scores,
                |i: &usize, v: &f64, emit| emit(0usize, (v - scores_ref[*i]).abs()),
                Reducer::max(),
                &mut max_delta,
            );
        }

        scores = new_scores.collect();
        delta = max_delta[0];
        iterations += 1;
    }

    let report = TaskReport::from_metrics(
        cluster,
        "pagerank",
        "pagerank.",
        graph.n_edges() as u64,
        iterations,
        delta,
    );
    (report, PageRankResult { scores, iterations, delta })
}

/// Reference serial PageRank (oracle for tests).
pub fn pagerank_serial(graph: &Graph, tol: f64, max_iters: usize) -> (Vec<f64>, usize) {
    let n = graph.n_vertices;
    let mut scores = vec![1.0f64 / n as f64; n];
    for iter in 0..max_iters {
        let sink_total: f64 = graph
            .out_degree
            .iter()
            .enumerate()
            .filter(|(_, &d)| d == 0)
            .map(|(v, _)| scores[v])
            .sum();
        let base = (1.0 - DAMPING) / n as f64 + DAMPING * sink_total / n as f64;
        let mut new_scores = vec![base; n];
        for &(src, dst) in &graph.edges {
            new_scores[dst as usize] +=
                DAMPING * scores[src as usize] / f64::from(graph.out_degree[src as usize]);
        }
        let delta = new_scores
            .iter()
            .zip(&scores)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f64, f64::max);
        scores = new_scores;
        if delta <= tol {
            return (scores, iter + 1);
        }
    }
    (scores, max_iters)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::cluster::{ClusterConfig, EngineKind};

    fn tiny_graph() -> Graph {
        // 0 -> 1, 0 -> 2, 1 -> 2, 3 is a sink pointing nowhere; 2 -> 0.
        let edges = vec![(0u32, 1u32), (0, 2), (1, 2), (2, 0)];
        let mut out_degree = vec![0u32; 4];
        for &(s, _) in &edges {
            out_degree[s as usize] += 1;
        }
        Graph { n_vertices: 4, edges, out_degree }
    }

    #[test]
    fn matches_serial_oracle() {
        let g = tiny_graph();
        let c = Cluster::local(2, 2);
        let (_, result) = pagerank(&c, &g, 1e-10, 200);
        let (oracle, _) = pagerank_serial(&g, 1e-10, 200);
        for (a, b) in result.scores.iter().zip(&oracle) {
            assert!((a - b).abs() < 1e-9, "{a} vs {b}");
        }
    }

    #[test]
    fn scores_sum_to_one() {
        let g = Graph::graph500(8, 8, 3);
        let c = Cluster::local(4, 2);
        let (_, result) = pagerank(&c, &g, 1e-8, 100);
        let total: f64 = result.scores.iter().sum();
        assert!((total - 1.0).abs() < 1e-6, "sum={total}");
        assert!(result.iterations > 2);
    }

    #[test]
    fn engines_agree() {
        let g = Graph::graph500(7, 6, 1);
        let eager = Cluster::local(2, 2);
        let conv =
            Cluster::new(ClusterConfig::sized(2, 2).with_engine(EngineKind::Conventional));
        let (_, re) = pagerank(&eager, &g, 1e-8, 50);
        let (_, rc) = pagerank(&conv, &g, 1e-8, 50);
        assert_eq!(re.iterations, rc.iterations);
        for (a, b) in re.scores.iter().zip(&rc.scores) {
            assert!((a - b).abs() < 1e-9);
        }
    }

    #[test]
    fn report_covers_all_iterations() {
        let g = Graph::graph500(6, 4, 2);
        let c = Cluster::local(2, 1);
        let (report, result) = pagerank(&c, &g, 1e-6, 30);
        assert_eq!(report.iterations, result.iterations);
        assert!(report.makespan_sec > 0.0);
        assert!(report.shuffle_bytes > 0, "multi-node run must shuffle");
    }
}
