//! Expectation-Maximization for the Gaussian Mixture Model
//! (paper §3.1.4, Fig 7, Eqs. 2–7).
//!
//! Two implementations share the M-step and the convergence loop:
//!
//! * [`gmm_fused`] — one MapReduce per iteration: the mapper hands each
//!   point block to the AOT-compiled Layer-2 E-step graph (Pallas
//!   log-density kernel inside) and emits the full sufficient-statistics
//!   vector. This is the production path; without a runtime it falls back
//!   to an identical scalar loop.
//! * [`gmm_paper_structured`] — the paper's exact decomposition into **six**
//!   MapReduce operations per iteration (density, membership, Nk, μ-sums,
//!   Σ-sums, log-likelihood) over per-point containers. Kept as the
//!   fidelity reference and as the L2-fusion ablation baseline.

use crate::containers::DistVector;
use crate::coordinator::cluster::Cluster;
use crate::data::points::PointSet;
use crate::mapreduce::{mapreduce_labeled, Reducer};
use crate::runtime::Runtime;
use crate::util::linalg;

use super::kmeans::{distribute_blocks, PointBlock};
use super::TaskReport;

const LOG_2PI: f64 = 1.837_877_066_409_345_3;
/// Covariance ridge keeping Σ positive-definite through the M-step.
const COV_RIDGE: f64 = 1e-6;

/// Mixture model state (f64 master copy; f32 views feed the kernels).
#[derive(Debug, Clone)]
pub struct GmmModel {
    /// Component weights α (K).
    pub weights: Vec<f64>,
    /// Means, row-major (K, D).
    pub means: Vec<f64>,
    /// Covariances, row-major (K, D, D).
    pub covs: Vec<f64>,
    /// Dimension.
    pub dim: usize,
}

impl GmmModel {
    /// Uniform-weight, identity-covariance init at the given centers.
    pub fn init(centers: &[f32], k: usize, dim: usize) -> Self {
        assert_eq!(centers.len(), k * dim);
        let mut covs = vec![0.0f64; k * dim * dim];
        for c in 0..k {
            for d in 0..dim {
                covs[c * dim * dim + d * dim + d] = 1.0;
            }
        }
        Self {
            weights: vec![1.0 / k as f64; k],
            means: centers.iter().map(|&v| f64::from(v)).collect(),
            covs,
            dim,
        }
    }

    /// Component count.
    pub fn k(&self) -> usize {
        self.weights.len()
    }

    /// Per-component (precision, logdet) from the current covariances.
    fn precisions(&self) -> (Vec<f64>, Vec<f64>) {
        let (k, d) = (self.k(), self.dim);
        let mut precs = vec![0.0f64; k * d * d];
        let mut logdets = vec![0.0f64; k];
        for c in 0..k {
            let cov = &self.covs[c * d * d..(c + 1) * d * d];
            let l = linalg::cholesky(cov, d).expect("covariance must stay SPD");
            logdets[c] = linalg::logdet_from_cholesky(&l, d);
            let inv = linalg::spd_inverse(cov, d).expect("covariance must stay SPD");
            precs[c * d * d..(c + 1) * d * d].copy_from_slice(&inv);
        }
        (precs, logdets)
    }

    /// M-step from accumulated sufficient statistics.
    fn mstep(&mut self, nk: &[f64], mu_sums: &[f64], cov_sums: &[f64], n: f64) {
        let (k, d) = (self.k(), self.dim);
        for c in 0..k {
            let m = nk[c].max(1e-12);
            self.weights[c] = nk[c] / n; // Eq. 4
            for i in 0..d {
                self.means[c * d + i] = mu_sums[c * d + i] / m; // Eq. 5
            }
            for i in 0..d {
                for j in 0..d {
                    // Eq. 6: E[xxᵀ] - μμᵀ (+ ridge on the diagonal).
                    let e_xx = cov_sums[c * d * d + i * d + j] / m;
                    let mut v = e_xx - self.means[c * d + i] * self.means[c * d + j];
                    if i == j {
                        v += COV_RIDGE;
                    }
                    self.covs[c * d * d + i * d + j] = v;
                }
            }
        }
    }
}

/// EM outcome.
#[derive(Debug, Clone)]
pub struct GmmResult {
    /// Final model.
    pub model: GmmModel,
    /// Iterations executed.
    pub iterations: usize,
    /// Final log-likelihood.
    pub loglik: f64,
}

/// Stats vector layout: `[nk (k) | mu (k*d) | cov (k*d*d) | loglik (1)]`.
fn stats_len(k: usize, d: usize) -> usize {
    k + k * d + k * d * d + 1
}

/// Fused EM: one MapReduce per iteration over point blocks.
#[allow(clippy::too_many_arguments)]
pub fn gmm_fused(
    cluster: &Cluster,
    blocks: &DistVector<PointBlock>,
    n_points: usize,
    dim: usize,
    init: GmmModel,
    tol: f64,
    max_iters: usize,
    runtime: Option<&Runtime>,
) -> (TaskReport, GmmResult) {
    let k = init.k();
    if let Some(rt) = runtime {
        assert_eq!(rt.dim(), dim);
        assert_eq!(rt.k(), k);
    }
    let mut model = init;
    let mut iterations = 0;
    let mut loglik = f64::NEG_INFINITY;

    while iterations < max_iters {
        let (precs, logdets) = model.precisions();
        let logw: Vec<f64> = model.weights.iter().map(|w| w.max(1e-300).ln()).collect();
        let mut stats: Vec<Vec<f64>> = vec![vec![0.0; stats_len(k, dim)]];
        {
            let (model_ref, precs_ref, logdets_ref, logw_ref) =
                (&model, &precs, &logdets, &logw);
            mapreduce_labeled(
                &format!("gmm.i{iterations}"),
                blocks,
                |_, block: &PointBlock, emit| {
                    let partial = match runtime {
                        Some(rt) => {
                            estep_block_pjrt(rt, block, model_ref, precs_ref, logdets_ref, logw_ref)
                        }
                        None => estep_block_scalar(
                            block, model_ref, precs_ref, logdets_ref, logw_ref, dim, k,
                        ),
                    };
                    emit(0usize, partial);
                },
                "sum",
                &mut stats,
            );
        }
        let stats = &stats[0];
        let new_ll = stats[stats_len(k, dim) - 1];
        model.mstep(
            &stats[..k],
            &stats[k..k + k * dim],
            &stats[k + k * dim..k + k * dim + k * dim * dim],
            n_points as f64,
        );
        iterations += 1;
        if (new_ll - loglik).abs() < tol * new_ll.abs().max(1.0) {
            loglik = new_ll;
            break;
        }
        loglik = new_ll;
    }

    let report = TaskReport::from_metrics(
        cluster, "gmm", "gmm.", n_points as u64, iterations, loglik,
    );
    (report, GmmResult { model, iterations, loglik })
}

/// PJRT E-step for one block.
fn estep_block_pjrt(
    rt: &Runtime,
    block: &PointBlock,
    model: &GmmModel,
    precs: &[f64],
    logdets: &[f64],
    logw: &[f64],
) -> Vec<f64> {
    let (k, d, batch) = (model.k(), model.dim, rt.batch());
    let n = block.len() / d;
    let mut padded = vec![0.0f32; batch * d];
    padded[..block.len()].copy_from_slice(block);
    let mut valid = vec![0.0f32; batch];
    for v in valid.iter_mut().take(n) {
        *v = 1.0;
    }
    let to_f32 = |s: &[f64]| s.iter().map(|&v| v as f32).collect::<Vec<f32>>();
    let means32 = to_f32(&model.means);
    let out = rt
        .gmm_estep(&padded, &means32, &to_f32(precs), &to_f32(logdets), &to_f32(logw), &valid)
        .expect("gmm_estep artifact must execute");
    let mut stats = vec![0.0f64; stats_len(k, d)];
    for c in 0..k {
        stats[c] = f64::from(out.nk[c]);
    }
    for i in 0..k * d {
        stats[k + i] = f64::from(out.mu_sums[i]);
    }
    for i in 0..k * d * d {
        stats[k + k * d + i] = f64::from(out.cov_sums[i]);
    }
    stats[stats_len(k, d) - 1] = f64::from(out.loglik);
    stats
}

/// Test hook: run the scalar E-step over a flat coordinate slice (used by
/// the PJRT integration tests to cross-check the compiled graph).
pub fn scalar_estep_for_tests(
    coords: &[f32],
    model: &GmmModel,
    precs: &[f64],
    logdets: &[f64],
    logw: &[f64],
) -> Vec<f64> {
    estep_block_scalar(coords, model, precs, logdets, logw, model.dim, model.k())
}

/// Scalar E-step (fallback and oracle).
#[allow(clippy::too_many_arguments)]
pub(crate) fn estep_block_scalar(
    block: &[f32],
    model: &GmmModel,
    precs: &[f64],
    logdets: &[f64],
    logw: &[f64],
    dim: usize,
    k: usize,
) -> Vec<f64> {
    let mut stats = vec![0.0f64; stats_len(k, dim)];
    let mut logp = vec![0.0f64; k];
    for p in block.chunks_exact(dim) {
        for c in 0..k {
            // Quadratic form (x-μ)ᵀ Σ⁻¹ (x-μ).
            let mut quad = 0.0f64;
            for i in 0..dim {
                let di = f64::from(p[i]) - model.means[c * dim + i];
                for j in 0..dim {
                    let dj = f64::from(p[j]) - model.means[c * dim + j];
                    quad += di * precs[c * dim * dim + i * dim + j] * dj;
                }
            }
            logp[c] = logw[c] - 0.5 * (dim as f64 * LOG_2PI + logdets[c] + quad);
        }
        let m = logp.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let lse = m + logp.iter().map(|l| (l - m).exp()).sum::<f64>().ln();
        for c in 0..k {
            let r = (logp[c] - lse).exp();
            stats[c] += r;
            for i in 0..dim {
                stats[k + c * dim + i] += r * f64::from(p[i]);
            }
            for i in 0..dim {
                for j in 0..dim {
                    stats[k + k * dim + c * dim * dim + i * dim + j] +=
                        r * f64::from(p[i]) * f64::from(p[j]);
                }
            }
        }
        stats[stats_len(k, dim) - 1] += lse;
    }
    stats
}

/// The paper's exact six-MapReduce-per-iteration decomposition, over
/// per-point containers. Used as the fidelity reference and the L2-fusion
/// ablation baseline (`benches/ablations.rs`).
pub fn gmm_paper_structured(
    cluster: &Cluster,
    points: &PointSet,
    init: GmmModel,
    tol: f64,
    max_iters: usize,
) -> (TaskReport, GmmResult) {
    let (dim, k, n) = (points.dim, init.k(), points.n);
    let pts: DistVector<Vec<f32>> = DistVector::from_fn(cluster, n, |i| {
        points.coords[i * dim..(i + 1) * dim].to_vec()
    });
    let replace = || Reducer::custom(|a: &mut Vec<f64>, b: &Vec<f64>| a.clone_from(b));

    let mut model = init;
    let mut iterations = 0;
    let mut loglik = f64::NEG_INFINITY;

    while iterations < max_iters {
        let (precs, logdets) = model.precisions();
        let logw: Vec<f64> = model.weights.iter().map(|w| w.max(1e-300).ln()).collect();
        let label = |step: &str| format!("gmm6.i{iterations}.{step}");

        // MR 1 (Eq. 2): weighted log-density of every point per component.
        let mut logdens: DistVector<Vec<f64>> =
            DistVector::filled(cluster, n, Vec::new());
        {
            let (model_ref, precs_ref, logdets_ref, logw_ref) =
                (&model, &precs, &logdets, &logw);
            mapreduce_labeled(
                &label("density"),
                &pts,
                |i: &usize, p: &Vec<f32>, emit| {
                    let mut row = vec![0.0f64; k];
                    for c in 0..k {
                        let mut quad = 0.0f64;
                        for a in 0..dim {
                            let da = f64::from(p[a]) - model_ref.means[c * dim + a];
                            for b in 0..dim {
                                let db = f64::from(p[b]) - model_ref.means[c * dim + b];
                                quad += da * precs_ref[c * dim * dim + a * dim + b] * db;
                            }
                        }
                        row[c] =
                            logw_ref[c] - 0.5 * (dim as f64 * LOG_2PI + logdets_ref[c] + quad);
                    }
                    emit(*i, row);
                },
                replace(),
                &mut logdens,
            );
        }

        // MR 2 (Eq. 3): membership w_ik = normalized responsibilities.
        let mut resp: DistVector<Vec<f64>> = DistVector::filled(cluster, n, Vec::new());
        mapreduce_labeled(
            &label("membership"),
            &logdens,
            |i: &usize, row: &Vec<f64>, emit| {
                let m = row.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
                let lse = m + row.iter().map(|l| (l - m).exp()).sum::<f64>().ln();
                emit(*i, row.iter().map(|l| (l - lse).exp()).collect::<Vec<f64>>())
            },
            replace(),
            &mut resp,
        );

        // MR 3: Nk = Σ_i w_ik.
        let mut nk: Vec<Vec<f64>> = vec![vec![0.0; k]];
        mapreduce_labeled(
            &label("nk"),
            &resp,
            |_, row: &Vec<f64>, emit| emit(0usize, row.clone()),
            "sum",
            &mut nk,
        );

        // MR 4 (Eq. 5): μ-sums over zipped (point, membership).
        let zipped = DistVector::zip(&pts, &resp);
        let mut mu_sums: Vec<Vec<f64>> = vec![vec![0.0; k * dim]];
        mapreduce_labeled(
            &label("musum"),
            &zipped,
            |_, (p, w): &(Vec<f32>, Vec<f64>), emit| {
                let mut out = vec![0.0f64; k * dim];
                for c in 0..k {
                    for d2 in 0..dim {
                        out[c * dim + d2] = w[c] * f64::from(p[d2]);
                    }
                }
                emit(0usize, out)
            },
            "sum",
            &mut mu_sums,
        );

        // MR 5 (Eq. 6): Σ-sums.
        let mut cov_sums: Vec<Vec<f64>> = vec![vec![0.0; k * dim * dim]];
        mapreduce_labeled(
            &label("covsum"),
            &zipped,
            |_, (p, w): &(Vec<f32>, Vec<f64>), emit| {
                let mut out = vec![0.0f64; k * dim * dim];
                for c in 0..k {
                    for a in 0..dim {
                        for b in 0..dim {
                            out[c * dim * dim + a * dim + b] =
                                w[c] * f64::from(p[a]) * f64::from(p[b]);
                        }
                    }
                }
                emit(0usize, out)
            },
            "sum",
            &mut cov_sums,
        );

        // MR 6 (Eq. 7): log-likelihood.
        let mut ll: Vec<f64> = vec![0.0];
        mapreduce_labeled(
            &label("loglik"),
            &logdens,
            |_, row: &Vec<f64>, emit| {
                let m = row.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
                emit(0usize, m + row.iter().map(|l| (l - m).exp()).sum::<f64>().ln())
            },
            "sum",
            &mut ll,
        );

        let new_ll = ll[0];
        model.mstep(&nk[0], &mu_sums[0], &cov_sums[0], n as f64);
        iterations += 1;
        if (new_ll - loglik).abs() < tol * new_ll.abs().max(1.0) {
            loglik = new_ll;
            break;
        }
        loglik = new_ll;
    }

    let report =
        TaskReport::from_metrics(cluster, "gmm6", "gmm6.", n as u64, iterations, loglik);
    (report, GmmResult { model, iterations, loglik })
}

/// Convenience: blocks + fused EM from a raw [`PointSet`].
pub fn gmm_from_points(
    cluster: &Cluster,
    points: &PointSet,
    k: usize,
    tol: f64,
    max_iters: usize,
    runtime: Option<&Runtime>,
) -> (TaskReport, GmmResult) {
    let batch = runtime.map_or(1024, Runtime::batch);
    let blocks = distribute_blocks(cluster, points, batch);
    let init = GmmModel::init(&points.coords[..k * points.dim], k, points.dim);
    gmm_fused(cluster, &blocks, points.n, points.dim, init, tol, max_iters, runtime)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_set() -> PointSet {
        PointSet::clustered(1200, 3, 4, 0.5, 21)
    }

    #[test]
    fn loglik_increases_monotonically() {
        let ps = small_set();
        let c = Cluster::local(2, 2);
        let blocks = distribute_blocks(&c, &ps, 256);
        let init = GmmModel::init(&ps.true_centers.iter().map(|v| v + 0.5).collect::<Vec<f32>>(), 4, 3);
        // Track per-iteration loglik via repeated 1-iteration runs.
        let mut model = init;
        let mut lls = Vec::new();
        for _ in 0..6 {
            let (_, r) = gmm_fused(&c, &blocks, ps.n, ps.dim, model.clone(), 0.0, 1, None);
            lls.push(r.loglik);
            model = r.model;
        }
        for w in lls.windows(2) {
            assert!(w[1] >= w[0] - 1e-6, "EM must not decrease loglik: {lls:?}");
        }
    }

    #[test]
    fn recovers_separated_mixture() {
        let ps = PointSet::clustered(2000, 2, 3, 0.3, 5);
        let c = Cluster::local(2, 2);
        let blocks = distribute_blocks(&c, &ps, 512);
        let init = GmmModel::init(
            &ps.true_centers.iter().map(|v| v + 0.4).collect::<Vec<f32>>(),
            3,
            2,
        );
        let (_, r) = gmm_fused(&c, &blocks, ps.n, ps.dim, init, 1e-8, 60, None);
        for tc in ps.true_centers.chunks_exact(2) {
            let best = r
                .model
                .means
                .chunks_exact(2)
                .map(|m| {
                    ((m[0] - f64::from(tc[0])).powi(2) + (m[1] - f64::from(tc[1])).powi(2)).sqrt()
                })
                .fold(f64::INFINITY, f64::min);
            assert!(best < 0.2, "mean unrecovered ({best})");
        }
        // Weights sum to one.
        let wsum: f64 = r.model.weights.iter().sum();
        assert!((wsum - 1.0).abs() < 1e-9);
    }

    #[test]
    fn paper_structured_matches_fused() {
        let ps = PointSet::clustered(600, 2, 3, 0.4, 9);
        let c1 = Cluster::local(2, 2);
        let c2 = Cluster::local(2, 2);
        let init = GmmModel::init(&ps.true_centers.clone(), 3, 2);
        let blocks = distribute_blocks(&c1, &ps, 128);
        let (_, fused) = gmm_fused(&c1, &blocks, ps.n, ps.dim, init.clone(), 0.0, 3, None);
        let (_, six) = gmm_paper_structured(&c2, &ps, init, 0.0, 3);
        assert_eq!(fused.iterations, six.iterations);
        assert!(
            (fused.loglik - six.loglik).abs() < 1e-6 * six.loglik.abs(),
            "{} vs {}",
            fused.loglik,
            six.loglik
        );
        for (a, b) in fused.model.means.iter().zip(&six.model.means) {
            assert!((a - b).abs() < 1e-6, "{a} vs {b}");
        }
    }
}
