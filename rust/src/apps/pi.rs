//! Monte-Carlo π estimation (paper §2.3.3, Table 1 and Appendix A.2).
//!
//! The canonical small-fixed-key-range workload: every sample reduces onto
//! key 0. [`pi_blaze`] is the paper's 8-line MapReduce program;
//! [`pi_hand_optimized`] is the MPI+OpenMP-style parallel for-loop with
//! thread-local counters it is benchmarked against. Table 1's claim is that
//! the two have the same execution plan and hence the same speed.

use std::time::Instant;

use crate::containers::DistRange;
use crate::coordinator::cluster::Cluster;
use crate::coordinator::metrics::RunStats;
use crate::mapreduce::mapreduce_range_labeled;
use crate::net::vtime::VirtualTime;
use crate::util::rng::SplitRng;

use super::TaskReport;

/// π via Blaze MapReduce — mirrors Appendix A.2 line-for-line. The mapper
/// uses [`crate::util::random::uniform`], the paper's worker-local
/// `blaze::random::uniform()`; the engine publishes each worker's stream.
pub fn pi_blaze(cluster: &Cluster, n_samples: u64) -> TaskReport {
    let samples = DistRange::new(cluster, 0, n_samples);
    let mut count = vec![0u64; 1];
    mapreduce_range_labeled(
        "pi.blaze",
        &samples,
        |_, emit| {
            // Random function in std is not thread safe (paper comment).
            let (x, y) = crate::util::random::uniform2();
            // Map points within circle to key 0.
            if x * x + y * y < 1.0 {
                emit(0usize, 1u64);
            }
        },
        "sum",
        &mut count,
    );
    let pi = 4.0 * count[0] as f64 / n_samples as f64;
    TaskReport::from_metrics(cluster, "pi", "pi.blaze", n_samples, 1, pi)
}

/// π via a hand-optimized parallel for-loop: per-worker local counters,
/// tree-combined — the MPI+OpenMP comparator from Table 1. Runs on the same
/// virtual cluster and is accounted identically.
pub fn pi_hand_optimized(cluster: &Cluster, n_samples: u64) -> TaskReport {
    let nodes = cluster.nodes();
    let workers = cluster.workers();
    let seed = cluster.config().seed;
    let node_ranges = crate::coordinator::scheduler::block_ranges(n_samples as usize, nodes);
    let mut per_node_secs = vec![0.0f64; nodes];
    let mut node_counts = vec![0u64; nodes];
    for node in 0..nodes {
        let t0 = Instant::now();
        let worker_ranges =
            crate::coordinator::scheduler::block_ranges(node_ranges[node].len(), workers);
        let mut node_total = 0u64;
        for (w, wr) in worker_ranges.into_iter().enumerate() {
            // Thread-local counter: the whole point of the comparison.
            let mut local = 0u64;
            let mut rng = SplitRng::new(seed, (node * workers + w) as u64);
            for _ in wr {
                let x = rng.uniform();
                let y = rng.uniform();
                if x * x + y * y < 1.0 {
                    local += 1;
                }
            }
            node_total += local;
        }
        node_counts[node] = node_total;
        per_node_secs[node] = t0.elapsed().as_secs_f64();
    }
    // MPI_Reduce of one u64: log2(nodes) rounds of 8 bytes.
    let mut vt = VirtualTime::new();
    vt.compute_phase("parallel-for", &per_node_secs, workers);
    let mut stride = 1usize;
    let mut total: u64 = 0;
    for &c in &node_counts {
        total += c;
    }
    while stride < nodes {
        let mut flows = crate::net::sim::FlowMatrix::new(nodes);
        for src in (stride..nodes).step_by(stride * 2) {
            flows.record(src, src - stride, 8);
        }
        vt.shuffle_overlapped("mpi-reduce", &flows, &cluster.config().network, 0.0);
        stride *= 2;
    }
    let makespan = vt.makespan();
    cluster.metrics().record_run(RunStats {
        label: "pi.hand".into(),
        engine: "mpi+openmp".into(),
        nodes,
        workers_per_node: workers,
        makespan_sec: makespan,
        compute_sec: per_node_secs.iter().cloned().fold(0.0, f64::max),
        shuffle_bytes: 8 * (nodes.saturating_sub(1)) as u64,
        pairs_emitted: total,
        ..Default::default()
    });
    let pi = 4.0 * total as f64 / n_samples as f64;
    let mut report =
        TaskReport::from_metrics(cluster, "pi-hand", "pi.hand", n_samples, 1, pi);
    report.engine = "mpi+openmp".into();
    report
}

/// Source lines of code for the paper's Table 1 SLOC row: counted from the
/// paper's Appendix A.2 listing (Blaze) and a canonical MPI+OpenMP π
/// implementation (the paper reports 8 vs 24).
pub const SLOC_BLAZE: usize = 8;
/// See [`SLOC_BLAZE`].
pub const SLOC_MPI_OPENMP: usize = 24;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn blaze_pi_converges() {
        let c = Cluster::local(2, 2);
        let report = pi_blaze(&c, 200_000);
        assert!((report.result - std::f64::consts::PI).abs() < 0.02, "pi={}", report.result);
        assert_eq!(report.items, 200_000);
    }

    #[test]
    fn hand_pi_converges() {
        let c = Cluster::local(2, 2);
        let report = pi_hand_optimized(&c, 200_000);
        assert!((report.result - std::f64::consts::PI).abs() < 0.02, "pi={}", report.result);
    }

    #[test]
    fn blaze_and_hand_agree_exactly_same_streams() {
        // Same seed, same worker streams → identical counts, identical π.
        let c1 = Cluster::local(2, 2);
        let c2 = Cluster::local(2, 2);
        let a = pi_blaze(&c1, 50_000);
        let b = pi_hand_optimized(&c2, 50_000);
        assert_eq!(a.result, b.result, "same sample streams must agree");
    }

    #[test]
    fn smallkey_path_shuffles_almost_nothing() {
        let c = Cluster::local(4, 2);
        let report = pi_blaze(&c, 100_000);
        // Tree reduce of one key: a few bytes per round, nothing like the
        // sample count.
        assert!(report.shuffle_bytes < 1024, "shuffled {}B", report.shuffle_bytes);
    }

    #[test]
    fn conventional_engine_also_correct_but_shuffles_more() {
        use crate::coordinator::cluster::{ClusterConfig, EngineKind};
        let c = Cluster::new(
            ClusterConfig::sized(4, 2).with_engine(EngineKind::Conventional),
        );
        let report = pi_blaze(&c, 100_000);
        assert!((report.result - std::f64::consts::PI).abs() < 0.05);
        // Materializing ~78k hit-pairs costs real intermediate memory.
        assert!(report.peak_bytes > 100_000, "peak={}B", report.peak_bytes);
    }
}
