//! The paper's workloads (§3.1), each written against the public Blaze API.
//!
//! Every app runs unchanged under both engines ([`EngineKind::Eager`] /
//! [`EngineKind::Conventional`]) — the benches flip the cluster config to
//! regenerate the paper's Blaze-vs-Spark comparisons with everything else
//! held fixed.
//!
//! [`EngineKind::Eager`]: crate::coordinator::EngineKind::Eager
//! [`EngineKind::Conventional`]: crate::coordinator::EngineKind::Conventional

pub mod gmm;
pub mod kmeans;
pub mod knn;
pub mod pagerank;
pub mod pi;
pub mod wordcount;

/// Common result of one workload run, assembled from the cluster metrics.
#[derive(Debug, Clone, Default)]
pub struct TaskReport {
    /// Task label ("wordcount", "pagerank", ...).
    pub task: String,
    /// Engine that ran it.
    pub engine: String,
    /// Cluster shape.
    pub nodes: usize,
    /// Items processed (words, links, points — the paper's per-task unit).
    pub items: u64,
    /// Iterations executed (1 for non-iterative tasks).
    pub iterations: usize,
    /// Virtual makespan of the whole job, seconds.
    pub makespan_sec: f64,
    /// Paper metric: items per second **per iteration** for iterative
    /// tasks, plain items/second otherwise.
    pub throughput: f64,
    /// Peak intermediate memory over the job (Fig 9), bytes.
    pub peak_bytes: u64,
    /// Cross-node bytes shuffled over the job.
    pub shuffle_bytes: u64,
    /// Task-specific result value (π estimate, final loss, ...).
    pub result: f64,
}

impl TaskReport {
    /// Assemble a report from all runs recorded under `prefix`.
    pub fn from_metrics(
        cluster: &crate::coordinator::Cluster,
        task: &str,
        prefix: &str,
        items: u64,
        iterations: usize,
        result: f64,
    ) -> Self {
        let metrics = cluster.metrics();
        let makespan = metrics.job_makespan(prefix);
        let per_iter = makespan / iterations.max(1) as f64;
        Self {
            task: task.to_string(),
            engine: cluster.config().engine.to_string(),
            nodes: cluster.nodes(),
            items,
            iterations,
            makespan_sec: makespan,
            throughput: items as f64 / per_iter,
            peak_bytes: metrics.job_peak_bytes(prefix),
            shuffle_bytes: metrics.job_shuffle_bytes(prefix),
            result,
        }
    }

    /// One human-readable summary line.
    pub fn line(&self) -> String {
        format!(
            "{:<10} {:<13} n={:<2} items={:<12} iters={:<3} makespan={:>9.4}s thpt={:>12.0}/s peak={:>10}B shuffle={:>10}B result={:.6}",
            self.task,
            self.engine,
            self.nodes,
            self.items,
            self.iterations,
            self.makespan_sec,
            self.throughput,
            self.peak_bytes,
            self.shuffle_bytes,
            self.result
        )
    }
}
