//! Nearest-100-neighbors search (paper §3.1.5, Fig 8).
//!
//! Exactly the paper's structure: compute each point's distance to the
//! query, then use the distributed container's `topk` with a custom
//! comparison function (smaller distance = higher priority). Distances are
//! computed per block through the PJRT pairwise kernel when a runtime is
//! available, else with a scalar loop.

use std::time::Instant;

use crate::containers::DistVector;
use crate::coordinator::cluster::Cluster;
use crate::coordinator::metrics::RunStats;
use crate::data::points::PointSet;
use crate::net::vtime::VirtualTime;
use crate::runtime::Runtime;

use super::kmeans::distribute_blocks;
use super::TaskReport;

/// One neighbor candidate.
pub type Neighbor = (f32, u32); // (squared distance, point index)

/// Find the `k` nearest neighbors of `query` among `points`.
pub fn knn(
    cluster: &Cluster,
    points: &PointSet,
    query: &[f32],
    k: usize,
    runtime: Option<&Runtime>,
) -> (TaskReport, Vec<Neighbor>) {
    assert_eq!(query.len(), points.dim);
    let dim = points.dim;
    let batch = runtime.map_or(4096, Runtime::batch);
    let blocks = distribute_blocks(cluster, points, batch);

    // Distance pass: per node, per block — measured as a compute phase.
    let nodes = cluster.nodes();
    let mut per_node_secs = vec![0.0f64; nodes];
    let mut shards: Vec<Vec<Neighbor>> = Vec::with_capacity(nodes);
    let mut global_base = 0u32;
    for node in 0..nodes {
        let t0 = Instant::now();
        let mut shard: Vec<Neighbor> = Vec::new();
        for block in blocks.shard(node) {
            let n = block.len() / dim;
            match runtime {
                Some(rt) => {
                    let mut padded = vec![0.0f32; rt.batch() * dim];
                    padded[..block.len()].copy_from_slice(block);
                    let d2 = rt.knn_dist(&padded, query).expect("knn_dist must execute");
                    for (i, &d) in d2.iter().take(n).enumerate() {
                        shard.push((d, global_base + i as u32));
                    }
                }
                None => {
                    for (i, p) in block.chunks_exact(dim).enumerate() {
                        let d2: f32 = p
                            .iter()
                            .zip(query)
                            .map(|(a, b)| (a - b) * (a - b))
                            .sum();
                        shard.push((d2, global_base + i as u32));
                    }
                }
            }
            global_base += n as u32;
        }
        per_node_secs[node] = t0.elapsed().as_secs_f64();
        shards.push(shard);
    }
    let mut vt = VirtualTime::new();
    vt.compute_phase("knn-distances", &per_node_secs, cluster.workers());
    cluster.metrics().record_run(RunStats {
        label: "knn.dist".into(),
        engine: cluster.config().engine.to_string(),
        nodes,
        workers_per_node: cluster.workers(),
        makespan_sec: vt.makespan(),
        compute_sec: vt.makespan(),
        pairs_emitted: points.n as u64,
        ..Default::default()
    });

    // Top-k with the custom comparator (paper: "provide custom comparison
    // functions to determine the priority ... based on Euclidean-distance").
    let candidates: DistVector<Neighbor> = DistVector::from_shards(cluster, shards);
    let neighbors = candidates.topk_labeled(
        k,
        |a: &Neighbor, b: &Neighbor| b.0.partial_cmp(&a.0).unwrap_or(std::cmp::Ordering::Equal),
        "knn.topk",
    );

    let report = TaskReport::from_metrics(
        cluster,
        "knn",
        "knn.",
        points.n as u64,
        1,
        f64::from(neighbors.first().map_or(f32::NAN, |n| n.0)),
    );
    (report, neighbors)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn oracle(points: &PointSet, query: &[f32], k: usize) -> Vec<Neighbor> {
        let mut all: Vec<Neighbor> = (0..points.n)
            .map(|i| (points.dist2(i, query), i as u32))
            .collect();
        all.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1)));
        all.truncate(k);
        all
    }

    #[test]
    fn matches_oracle() {
        let ps = PointSet::uniform(5000, 3, 13);
        let c = Cluster::local(4, 2);
        let query = vec![0.5f32, 0.5, 0.5];
        let (report, got) = knn(&c, &ps, &query, 100, None);
        let want = oracle(&ps, &query, 100);
        assert_eq!(got.len(), 100);
        // Same distances (indices may tie-break differently).
        let gd: Vec<f32> = got.iter().map(|n| n.0).collect();
        let wd: Vec<f32> = want.iter().map(|n| n.0).collect();
        assert_eq!(gd, wd);
        assert_eq!(report.items, 5000);
    }

    #[test]
    fn k_larger_than_n() {
        let ps = PointSet::uniform(10, 2, 1);
        let c = Cluster::local(2, 1);
        let (_, got) = knn(&c, &ps, &[0.0, 0.0], 100, None);
        assert_eq!(got.len(), 10);
    }

    #[test]
    fn nearest_is_exact_on_plant() {
        let mut ps = PointSet::uniform(1000, 2, 3);
        // Plant an exact match at index 500.
        ps.coords[500 * 2] = 0.25;
        ps.coords[500 * 2 + 1] = 0.75;
        let c = Cluster::local(3, 2);
        let (_, got) = knn(&c, &ps, &[0.25, 0.75], 5, None);
        assert_eq!(got[0].1, 500);
        assert_eq!(got[0].0, 0.0);
    }
}
