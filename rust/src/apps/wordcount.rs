//! Word frequency count (paper §3.1.1, Fig 4, Appendix A.1).
//!
//! Mapper splits a line into words and emits `(word, 1)`; reducer is
//! `"sum"`; target is a `DistHashMap<String, u64>`. The Zipf key skew makes
//! this the showcase for eager reduction: the thread-local caches absorb
//! the hot head words, so the shuffle carries one pair per *distinct* word
//! per node instead of one pair per *token*.

use crate::containers::{DistHashMap, DistVector};
use crate::coordinator::cluster::Cluster;
use crate::mapreduce::mapreduce_labeled;

use super::TaskReport;

/// Count word frequencies over distributed `lines`; returns the report and
/// the populated map.
pub fn wordcount(
    cluster: &Cluster,
    lines: &DistVector<String>,
) -> (TaskReport, DistHashMap<String, u64>) {
    let mut words: DistHashMap<String, u64> = DistHashMap::new(cluster);
    let mut total_words = 0u64;
    // Count tokens while mapping (the paper's metric is words/second).
    mapreduce_labeled(
        "wordcount.mr",
        lines,
        |_, line: &String, emit| {
            for w in line.split_whitespace() {
                emit(w.to_string(), 1u64);
            }
        },
        "sum",
        &mut words,
    );
    // Token count = sum of all counts (exact, and cheap vs. re-tokenizing).
    for node in 0..cluster.nodes() {
        for (_, c) in words.shard(node) {
            total_words += *c;
        }
    }
    let unique = words.len() as f64;
    let report = TaskReport::from_metrics(
        cluster,
        "wordcount",
        "wordcount.mr",
        total_words,
        1,
        unique,
    );
    (report, words)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::cluster::{ClusterConfig, EngineKind};

    fn tiny_corpus(cluster: &Cluster) -> DistVector<String> {
        DistVector::from_vec(
            cluster,
            vec![
                "the quick brown fox".to_string(),
                "the lazy dog and the quick cat".to_string(),
                "dog eat dog".to_string(),
            ],
        )
    }

    #[test]
    fn counts_are_exact() {
        let c = Cluster::local(2, 2);
        let lines = tiny_corpus(&c);
        let (report, words) = wordcount(&c, &lines);
        assert_eq!(words.get(&"the".to_string()), Some(3));
        assert_eq!(words.get(&"dog".to_string()), Some(3));
        assert_eq!(words.get(&"fox".to_string()), Some(1));
        assert_eq!(words.get(&"cat".to_string()), Some(1));
        assert_eq!(report.items, 14);
        assert_eq!(report.result as usize, 9); // unique words
    }

    #[test]
    fn engines_agree_on_results() {
        let eager = Cluster::local(3, 2);
        let conv = Cluster::new(
            ClusterConfig::sized(3, 2).with_engine(EngineKind::Conventional),
        );
        let lines_e = crate::data::corpus_lines(200, 8, 7);
        let (_, we) = wordcount(&eager, &DistVector::from_vec(&eager, lines_e.clone()));
        let (_, wc) = wordcount(&conv, &DistVector::from_vec(&conv, lines_e));
        assert_eq!(we.collect(), wc.collect());
    }

    #[test]
    fn eager_shuffles_far_fewer_pairs_than_conventional() {
        let eager = Cluster::local(4, 2);
        let conv = Cluster::new(
            ClusterConfig::sized(4, 2).with_engine(EngineKind::Conventional),
        );
        let lines = crate::data::corpus_lines(2000, 10, 3);
        let (re, _) = wordcount(&eager, &DistVector::from_vec(&eager, lines.clone()));
        let (rc, _) = wordcount(&conv, &DistVector::from_vec(&conv, lines));
        // 20k tokens, Zipf over 30k vocab → conventional shuffles every
        // token, eager shuffles ≤ distinct-per-node.
        let me = eager.metrics().runs()[0].pairs_shuffled;
        let mc = conv.metrics().runs()[0].pairs_shuffled;
        assert!(me * 2 < mc, "eager {me} vs conventional {mc}");
        assert!(re.peak_bytes < rc.peak_bytes, "memory should also shrink");
    }

    #[test]
    fn repeated_run_merges_into_target() {
        // Target is not cleared (paper §2.2): running twice doubles counts.
        let c = Cluster::local(2, 1);
        let lines = tiny_corpus(&c);
        let mut words: DistHashMap<String, u64> = DistHashMap::new(&c);
        for _ in 0..2 {
            crate::mapreduce::mapreduce(
                &lines,
                |_, line: &String, emit| {
                    for w in line.split_whitespace() {
                        emit(w.to_string(), 1u64);
                    }
                },
                "sum",
                &mut words,
            );
        }
        assert_eq!(words.get(&"the".to_string()), Some(6));
    }
}
