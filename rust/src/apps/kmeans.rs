//! K-Means (paper §3.1.3, Fig 6).
//!
//! One MapReduce per iteration performs the assignment step; the update
//! (refinement) step is serial on the driver, exactly as the paper
//! describes. Points are distributed in fixed-size blocks so the mapper can
//! hand each block to the AOT-compiled PJRT executable (Layer 2 JAX model
//! wrapping the Layer 1 Pallas pairwise-distance kernel). Without a runtime
//! the mapper falls back to a scalar rust loop — used by tests and as the
//! no-artifact path.

use crate::containers::DistVector;
use crate::coordinator::cluster::Cluster;
use crate::data::points::PointSet;
use crate::mapreduce::mapreduce_labeled;
use crate::runtime::Runtime;

use super::TaskReport;

/// A block of up to `batch` points, stored flat (row-major f32).
pub type PointBlock = Vec<f32>;

/// Chop a [`PointSet`] into distributed blocks of `batch` points.
pub fn distribute_blocks(
    cluster: &Cluster,
    points: &PointSet,
    batch: usize,
) -> DistVector<PointBlock> {
    let blocks: Vec<PointBlock> = points
        .coords
        .chunks(batch * points.dim)
        .map(<[f32]>::to_vec)
        .collect();
    DistVector::from_vec(cluster, blocks)
}

/// K-Means outcome.
#[derive(Debug, Clone)]
pub struct KmeansResult {
    /// Final centers, row-major `(k, dim)`.
    pub centers: Vec<f32>,
    /// Iterations executed.
    pub iterations: usize,
    /// Final inertia (sum of squared distances to assigned centers).
    pub inertia: f64,
}

/// Lloyd's algorithm: `k` centers, stop when centers move less than `tol`
/// (L2) or after `max_iters`.
#[allow(clippy::too_many_arguments)]
pub fn kmeans(
    cluster: &Cluster,
    blocks: &DistVector<PointBlock>,
    n_points: usize,
    dim: usize,
    k: usize,
    init_centers: Vec<f32>,
    tol: f64,
    max_iters: usize,
    runtime: Option<&Runtime>,
) -> (TaskReport, KmeansResult) {
    assert_eq!(init_centers.len(), k * dim);
    if let Some(rt) = runtime {
        assert_eq!(rt.dim(), dim, "runtime compiled for dim {}", rt.dim());
        assert_eq!(rt.k(), k, "runtime compiled for k {}", rt.k());
    }
    let mut centers = init_centers;
    let mut iterations = 0;
    let mut inertia = f64::INFINITY;

    while iterations < max_iters {
        // stats layout: [counts (k) | sums (k*dim) | inertia (1)] as f64.
        let mut stats: Vec<Vec<f64>> = vec![vec![0.0; k + k * dim + 1]];
        let centers_ref = &centers;
        mapreduce_labeled(
            &format!("kmeans.i{iterations}"),
            blocks,
            |_, block: &PointBlock, emit| {
                let partial = match runtime {
                    Some(rt) => assign_block_pjrt(rt, block, centers_ref, dim, k),
                    None => assign_block_scalar(block, centers_ref, dim, k),
                };
                emit(0usize, partial);
            },
            "sum",
            &mut stats,
        );
        let stats = &stats[0];

        // Serial update step (paper: "The update step is implemented in
        // serial.").
        let mut moved2 = 0.0f64;
        for c in 0..k {
            let count = stats[c];
            if count <= 0.0 {
                continue; // empty cluster: keep the old center
            }
            for d in 0..dim {
                let new = (stats[k + c * dim + d] / count) as f32;
                let delta = f64::from(new - centers[c * dim + d]);
                moved2 += delta * delta;
                centers[c * dim + d] = new;
            }
        }
        inertia = stats[k + k * dim];
        iterations += 1;
        if moved2.sqrt() < tol {
            break;
        }
    }

    let report = TaskReport::from_metrics(
        cluster,
        "kmeans",
        "kmeans.",
        n_points as u64,
        iterations,
        inertia,
    );
    (report, KmeansResult { centers, iterations, inertia })
}

/// PJRT assignment path: pad the block to the AOT batch and run the
/// compiled Layer-2 graph.
fn assign_block_pjrt(
    rt: &Runtime,
    block: &PointBlock,
    centers: &[f32],
    dim: usize,
    k: usize,
) -> Vec<f64> {
    let batch = rt.batch();
    let n = block.len() / dim;
    debug_assert!(n <= batch, "block larger than AOT batch");
    let mut padded = vec![0.0f32; batch * dim];
    padded[..block.len()].copy_from_slice(block);
    let mut valid = vec![0.0f32; batch];
    for v in valid.iter_mut().take(n) {
        *v = 1.0;
    }
    let out = rt
        .kmeans_assign(&padded, centers, &valid)
        .expect("kmeans_assign artifact must execute");
    let mut stats = vec![0.0f64; k + k * dim + 1];
    for c in 0..k {
        stats[c] = f64::from(out.counts[c]);
        for d in 0..dim {
            stats[k + c * dim + d] = f64::from(out.sums[c * dim + d]);
        }
    }
    stats[k + k * dim] = f64::from(out.inertia);
    stats
}

/// Scalar fallback (and test oracle for the PJRT path).
pub(crate) fn assign_block_scalar(
    block: &[f32],
    centers: &[f32],
    dim: usize,
    k: usize,
) -> Vec<f64> {
    let mut stats = vec![0.0f64; k + k * dim + 1];
    for p in block.chunks_exact(dim) {
        let mut best = 0usize;
        let mut best_d2 = f32::INFINITY;
        for c in 0..k {
            let mut d2 = 0.0f32;
            for d in 0..dim {
                let diff = p[d] - centers[c * dim + d];
                d2 += diff * diff;
            }
            if d2 < best_d2 {
                best_d2 = d2;
                best = c;
            }
        }
        stats[best] += 1.0;
        for d in 0..dim {
            stats[k + best * dim + d] += f64::from(p[d]);
        }
        stats[k + k * dim] += f64::from(best_d2);
    }
    stats
}

/// Deterministic initialization: first `k` points of the set.
pub fn init_first_k(points: &PointSet, k: usize) -> Vec<f32> {
    points.coords[..k * points.dim].to_vec()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::cluster::{ClusterConfig, EngineKind};

    fn small_set() -> PointSet {
        PointSet::clustered(2000, 4, 5, 0.4, 11)
    }

    #[test]
    fn converges_and_recovers_centers() {
        let ps = small_set();
        let c = Cluster::local(2, 2);
        let blocks = distribute_blocks(&c, &ps, 256);
        // Init: perturbed true centers (deterministic recovery check).
        let init: Vec<f32> = ps.true_centers.iter().map(|v| v + 0.8).collect();
        let (report, result) =
            kmeans(&c, &blocks, ps.n, ps.dim, 5, init, 1e-4, 50, None);
        assert!(result.iterations < 50, "did not converge");
        for tc in ps.true_centers.chunks_exact(ps.dim) {
            let best = result
                .centers
                .chunks_exact(ps.dim)
                .map(|ec| {
                    ec.iter()
                        .zip(tc)
                        .map(|(a, b)| f64::from(a - b).powi(2))
                        .sum::<f64>()
                        .sqrt()
                })
                .fold(f64::INFINITY, f64::min);
            assert!(best < 0.15, "center unrecovered (dist {best})");
        }
        assert_eq!(report.items, 2000);
    }

    #[test]
    fn engines_agree_bitwise_on_assignment_counts() {
        let ps = small_set();
        let init = init_first_k(&ps, 5);
        let eager = Cluster::local(3, 2);
        let conv =
            Cluster::new(ClusterConfig::sized(3, 2).with_engine(EngineKind::Conventional));
        let be = distribute_blocks(&eager, &ps, 128);
        let bc = distribute_blocks(&conv, &ps, 128);
        let (_, re) = kmeans(&eager, &be, ps.n, ps.dim, 5, init.clone(), 1e-4, 10, None);
        let (_, rc) = kmeans(&conv, &bc, ps.n, ps.dim, 5, init, 1e-4, 10, None);
        assert_eq!(re.iterations, rc.iterations);
        assert_eq!(re.centers, rc.centers);
    }

    #[test]
    fn single_iteration_inertia_matches_manual() {
        // One block, one center: inertia = sum |x - c|^2.
        let ps = PointSet { n: 3, dim: 2, coords: vec![0.0, 0.0, 1.0, 0.0, 0.0, 2.0], true_centers: vec![] };
        let c = Cluster::local(1, 1);
        let blocks = distribute_blocks(&c, &ps, 8);
        let (_, result) = kmeans(&c, &blocks, 3, 2, 1, vec![0.0, 0.0], 1e9, 1, None);
        assert!((result.inertia - 5.0).abs() < 1e-6, "inertia {}", result.inertia);
    }

    #[test]
    fn block_distribution_covers_all_points() {
        let ps = small_set();
        let c = Cluster::local(4, 1);
        let blocks = distribute_blocks(&c, &ps, 300);
        let total: usize = blocks.collect().iter().map(|b| b.len() / ps.dim).sum();
        assert_eq!(total, ps.n);
    }
}
