//! `blaze report` — the perf regression gate over `BENCH_*.json`.
//!
//! Loads two bench artifact sets (files or directories of the
//! [`crate::bench::report`] JSON shape), aligns rows by `(series, tags)`,
//! and diffs the numeric fields:
//!
//! - **Deterministic fields** (counters, histogram digests, byte/pair
//!   tallies) must match *exactly* — any drift is a gated regression.
//!   These are schedule-invariant by the repo's determinism discipline,
//!   so an exact gate has zero flake risk.
//! - **Wall-clock fields** (names containing `wall`, `sec`, `mean`, …)
//!   are host-load dependent: a candidate value more than `--threshold`
//!   percent *above* baseline is flagged, and gates only when
//!   `--deterministic-only` is off. Improvements never flag.
//!
//! Structure-only baselines (rows with tags but no numeric fields, as
//! committed under `benches/baseline/`) gate row *presence*: a missing
//! series/config row fails, numbers are not compared. The JSON reader is
//! hand-rolled like the writer — the build is offline, no serde.

use std::fmt::Write as _;
use std::path::{Path, PathBuf};

/// Field-name substrings marking a value as host-timing dependent
/// (threshold-compared) rather than deterministic (exact-gated).
const NONDETERMINISTIC_MARKERS: &[&str] = &[
    "wall",
    "sec",
    "makespan",
    "mean",
    "std",
    "ratio",
    "per_sec",
    "pool.",
    "queue_peak",
    "contended",
    "hist.wall.",
    // Stripe count is sized from the thread count and the previous run's
    // observed contention, so it varies across backends and hosts.
    "shard.stripes",
];

/// Is `field` exact-gated (schedule-invariant) rather than
/// threshold-compared?
pub fn is_deterministic_field(field: &str) -> bool {
    !NONDETERMINISTIC_MARKERS.iter().any(|m| field.contains(m))
}

// ---------------------------------------------------------------------
// Minimal JSON reader (mirror of bench::report's hand-rolled writer).
// ---------------------------------------------------------------------

/// Parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    /// `null` (bench reports use it for non-finite numbers).
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any JSON number.
    Num(f64),
    /// String.
    Str(String),
    /// Array.
    Arr(Vec<Value>),
    /// Object, in source order (bench field order is meaningful).
    Obj(Vec<(String, Value)>),
}

impl Value {
    /// Parse one JSON document (trailing whitespace allowed).
    pub fn parse(src: &str) -> Result<Value, String> {
        let mut p = Parser { b: src.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(format!("trailing bytes at offset {}", p.i));
        }
        Ok(v)
    }

    fn get<'a>(&'a self, key: &str) -> Option<&'a Value> {
        match self {
            Value::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl Parser<'_> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected {:?} at offset {}", c as char, self.i))
        }
    }

    fn eat_lit(&mut self, lit: &str) -> Result<(), String> {
        if self.b[self.i..].starts_with(lit.as_bytes()) {
            self.i += lit.len();
            Ok(())
        } else {
            Err(format!("expected {lit:?} at offset {}", self.i))
        }
    }

    fn value(&mut self) -> Result<Value, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.eat_lit("true").map(|()| Value::Bool(true)),
            Some(b'f') => self.eat_lit("false").map(|()| Value::Bool(false)),
            Some(b'n') => self.eat_lit("null").map(|()| Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {other:?} at offset {}", self.i)),
        }
    }

    fn object(&mut self) -> Result<Value, String> {
        self.eat(b'{')?;
        let mut fields = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Value::Obj(fields));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let val = self.value()?;
            fields.push((key, val));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Value::Obj(fields));
                }
                other => return Err(format!("expected ',' or '}}', got {other:?}")),
            }
        }
    }

    fn array(&mut self) -> Result<Value, String> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Value::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Value::Arr(items));
                }
                other => return Err(format!("expected ',' or ']', got {other:?}")),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .b
                                .get(self.i + 1..self.i + 5)
                                .ok_or("truncated \\u escape")?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|e| e.to_string())?,
                                16,
                            )
                            .map_err(|e| e.to_string())?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        other => return Err(format!("bad escape {other:?}")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar (multi-byte sequences pass
                    // through unchanged).
                    let start = self.i;
                    self.i += 1;
                    while self.i < self.b.len() && (self.b[self.i] & 0xc0) == 0x80 {
                        self.i += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|e| e.to_string())?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value, String> {
        let start = self.i;
        while self
            .peek()
            .is_some_and(|c| c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E'))
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).map_err(|e| e.to_string())?;
        s.parse::<f64>().map(Value::Num).map_err(|e| format!("bad number {s:?}: {e}"))
    }
}

// ---------------------------------------------------------------------
// Bench artifact model
// ---------------------------------------------------------------------

/// One row of a bench report: series, sorted string tags, numeric fields
/// in source order (`None` = JSON `null`, a non-finite measurement).
#[derive(Debug, Clone)]
pub struct BenchRow {
    /// Series label (`"blaze"`, `"conventional"`, …).
    pub series: String,
    /// String tags, sorted by key (alignment identity).
    pub tags: Vec<(String, String)>,
    /// Numeric fields, in file order.
    pub nums: Vec<(String, Option<f64>)>,
}

impl BenchRow {
    /// Alignment key: `series{k=v,…}` over the sorted tags.
    pub fn key(&self) -> String {
        let mut out = self.series.clone();
        out.push('{');
        for (i, (k, v)) in self.tags.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            let _ = write!(out, "{k}={v}");
        }
        out.push('}');
        out
    }

    fn num(&self, field: &str) -> Option<&Option<f64>> {
        self.nums.iter().find(|(k, _)| k == field).map(|(_, v)| v)
    }
}

/// One parsed `BENCH_<name>.json`.
#[derive(Debug, Clone)]
pub struct BenchReport {
    /// The bench's report name (`fig4_wordcount`, …).
    pub name: String,
    /// Provenance metadata (backend, scale, …).
    pub meta: Vec<(String, String)>,
    /// Data rows.
    pub rows: Vec<BenchRow>,
}

impl BenchReport {
    /// Decode one report document.
    pub fn from_json(src: &str) -> Result<BenchReport, String> {
        let v = Value::parse(src)?;
        let name = match v.get("name") {
            Some(Value::Str(s)) => s.clone(),
            _ => return Err("report is missing a string \"name\"".into()),
        };
        let mut meta = Vec::new();
        if let Some(Value::Obj(fields)) = v.get("meta") {
            for (k, mv) in fields {
                if let Value::Str(s) = mv {
                    meta.push((k.clone(), s.clone()));
                }
            }
        }
        let mut rows = Vec::new();
        let Some(Value::Arr(raw_rows)) = v.get("rows") else {
            return Err("report is missing a \"rows\" array".into());
        };
        for (i, raw) in raw_rows.iter().enumerate() {
            let Value::Obj(fields) = raw else {
                return Err(format!("row {i} is not an object"));
            };
            let mut row = BenchRow { series: String::new(), tags: Vec::new(), nums: Vec::new() };
            for (k, fv) in fields {
                match fv {
                    Value::Str(s) if k == "series" => row.series = s.clone(),
                    Value::Str(s) => row.tags.push((k.clone(), s.clone())),
                    Value::Num(n) => row.nums.push((k.clone(), Some(*n))),
                    Value::Null => row.nums.push((k.clone(), None)),
                    Value::Bool(b) => row.tags.push((k.clone(), b.to_string())),
                    _ => return Err(format!("row {i} field {k:?} has a nested value")),
                }
            }
            if row.series.is_empty() {
                return Err(format!("row {i} is missing a \"series\""));
            }
            row.tags.sort();
            rows.push(row);
        }
        Ok(BenchReport { name, meta, rows })
    }
}

/// Load bench reports from `path`: a single JSON file, or a directory
/// scanned for `BENCH_*.json` (sorted by file name).
pub fn load(path: &Path) -> Result<Vec<BenchReport>, String> {
    let read_one = |p: &Path| -> Result<BenchReport, String> {
        let src =
            std::fs::read_to_string(p).map_err(|e| format!("{}: {e}", p.display()))?;
        BenchReport::from_json(&src).map_err(|e| format!("{}: {e}", p.display()))
    };
    if path.is_dir() {
        let mut files: Vec<PathBuf> = std::fs::read_dir(path)
            .map_err(|e| format!("{}: {e}", path.display()))?
            .filter_map(|e| e.ok().map(|e| e.path()))
            .filter(|p| {
                p.file_name()
                    .and_then(|n| n.to_str())
                    .is_some_and(|n| n.starts_with("BENCH_") && n.ends_with(".json"))
            })
            .collect();
        files.sort();
        if files.is_empty() {
            return Err(format!("{}: no BENCH_*.json files", path.display()));
        }
        files.iter().map(|p| read_one(p)).collect()
    } else {
        Ok(vec![read_one(path)?])
    }
}

// ---------------------------------------------------------------------
// Diff engine
// ---------------------------------------------------------------------

/// Diff policy.
#[derive(Debug, Clone, Copy)]
pub struct DiffOptions {
    /// Allowed relative increase (percent) on wall-clock fields.
    pub threshold_pct: f64,
    /// Gate only on deterministic fields; wall drift stays advisory.
    pub deterministic_only: bool,
}

impl Default for DiffOptions {
    fn default() -> Self {
        Self { threshold_pct: 10.0, deterministic_only: false }
    }
}

/// One detected difference.
#[derive(Debug, Clone)]
pub struct Finding {
    /// Report name the difference is in.
    pub report: String,
    /// Row alignment key (empty for report-level findings).
    pub row: String,
    /// Field name (empty for presence findings).
    pub field: String,
    /// Rendered baseline value.
    pub baseline: String,
    /// Rendered candidate value.
    pub candidate: String,
    /// What kind of difference.
    pub class: &'static str,
    /// Does this finding fail the gate?
    pub gating: bool,
}

fn fmt_opt(v: Option<&Option<f64>>) -> String {
    match v {
        None => "—".into(),
        Some(None) => "null".into(),
        Some(Some(x)) => format!("{x}"),
    }
}

/// Diff `cand` against `base`. Findings are ordered baseline-first:
/// report order, then row order, then field order.
pub fn diff(base: &[BenchReport], cand: &[BenchReport], opts: DiffOptions) -> Vec<Finding> {
    let mut findings = Vec::new();
    for b in base {
        let Some(c) = cand.iter().find(|c| c.name == b.name) else {
            findings.push(Finding {
                report: b.name.clone(),
                row: String::new(),
                field: String::new(),
                baseline: format!("{} rows", b.rows.len()),
                candidate: "missing report".into(),
                class: "missing-report",
                gating: true,
            });
            continue;
        };
        // Align by key, pairing duplicates positionally.
        let mut used = vec![false; c.rows.len()];
        for brow in &b.rows {
            let key = brow.key();
            let Some(ci) = c
                .rows
                .iter()
                .enumerate()
                .position(|(i, r)| !used[i] && r.key() == key)
            else {
                findings.push(Finding {
                    report: b.name.clone(),
                    row: key,
                    field: String::new(),
                    baseline: "present".into(),
                    candidate: "missing row".into(),
                    class: "missing-row",
                    gating: true,
                });
                continue;
            };
            used[ci] = true;
            let crow = &c.rows[ci];
            for (field, bval) in &brow.nums {
                let det = is_deterministic_field(field);
                let cval = crow.num(field);
                let Some(cval) = cval else {
                    findings.push(Finding {
                        report: b.name.clone(),
                        row: key.clone(),
                        field: field.clone(),
                        baseline: fmt_opt(Some(bval)),
                        candidate: "—".into(),
                        class: if det { "missing-field" } else { "missing-wall-field" },
                        gating: det,
                    });
                    continue;
                };
                match (bval, cval) {
                    (None, None) => {}
                    (Some(bv), Some(cv)) if det => {
                        if bv != cv {
                            findings.push(Finding {
                                report: b.name.clone(),
                                row: key.clone(),
                                field: field.clone(),
                                baseline: format!("{bv}"),
                                candidate: format!("{cv}"),
                                class: "deterministic-drift",
                                gating: true,
                            });
                        }
                    }
                    (Some(bv), Some(cv)) => {
                        // Wall-clock: one-sided relative threshold.
                        let limit = bv * (1.0 + opts.threshold_pct / 100.0);
                        if *cv > limit {
                            findings.push(Finding {
                                report: b.name.clone(),
                                row: key.clone(),
                                field: field.clone(),
                                baseline: format!("{bv:.6}"),
                                candidate: format!("{cv:.6}"),
                                class: "wall-regression",
                                gating: !opts.deterministic_only,
                            });
                        }
                    }
                    (bv, cv) => {
                        // null vs number in either direction.
                        findings.push(Finding {
                            report: b.name.clone(),
                            row: key.clone(),
                            field: field.clone(),
                            baseline: fmt_opt(Some(bv)),
                            candidate: fmt_opt(Some(cv)),
                            class: "null-drift",
                            gating: det,
                        });
                    }
                }
            }
        }
        // Candidate rows with no baseline counterpart: advisory.
        for (i, crow) in c.rows.iter().enumerate() {
            if !used[i] {
                findings.push(Finding {
                    report: b.name.clone(),
                    row: crow.key(),
                    field: String::new(),
                    baseline: "—".into(),
                    candidate: "extra row".into(),
                    class: "extra-row",
                    gating: false,
                });
            }
        }
    }
    findings
}

/// Render the findings as a markdown report.
pub fn markdown(
    base_label: &str,
    cand_label: &str,
    findings: &[Finding],
    opts: DiffOptions,
) -> String {
    let gated = findings.iter().filter(|f| f.gating).count();
    let advisory = findings.len() - gated;
    let mut out = String::new();
    let _ = writeln!(out, "# blaze report\n");
    let _ = writeln!(out, "- baseline: `{base_label}`");
    let _ = writeln!(out, "- candidate: `{cand_label}`");
    let _ = writeln!(
        out,
        "- policy: exact on deterministic fields, +{:.1}% ceiling on wall fields{}\n",
        opts.threshold_pct,
        if opts.deterministic_only { " (wall advisory-only)" } else { "" },
    );
    if findings.is_empty() {
        let _ = writeln!(out, "No differences.");
        return out;
    }
    let _ = writeln!(out, "| report | row | field | baseline | candidate | class | gates |");
    let _ = writeln!(out, "|---|---|---|---|---|---|---|");
    for f in findings {
        let _ = writeln!(
            out,
            "| {} | {} | {} | {} | {} | {} | {} |",
            f.report,
            f.row,
            f.field,
            f.baseline,
            f.candidate,
            f.class,
            if f.gating { "yes" } else { "no" },
        );
    }
    let _ = writeln!(out, "\n**{gated} gated regression(s), {advisory} advisory note(s).**");
    out
}

// ---------------------------------------------------------------------
// CLI entry
// ---------------------------------------------------------------------

const USAGE: &str = "usage: blaze report <BASELINE> <CANDIDATE> \
[--gate] [--deterministic-only] [--threshold PCT] [--out PATH]

  BASELINE / CANDIDATE   a BENCH_*.json file or a directory of them
  --gate                 exit 1 when a gated regression is found
  --deterministic-only   wall-clock drift is advisory, never gated
  --threshold PCT        wall-clock ceiling in percent (default 10)
  --out PATH             also write the markdown diff to PATH

examples:
  blaze report benches/baseline bench-out --gate --deterministic-only
  blaze report BENCH_table1_pi.json bench-out/BENCH_table1_pi.json --threshold 25";

/// Run `blaze report` (args exclude the literal `report`). Returns the
/// process exit code: 0 clean, 1 gated regression, 2 usage/load error.
pub fn run_report(args: &[String]) -> i32 {
    let mut paths: Vec<&String> = Vec::new();
    let mut opts = DiffOptions::default();
    let mut gate = false;
    let mut out_path: Option<String> = None;
    let mut it = args.iter();
    while let Some(arg) = it.next() {
        match arg.as_str() {
            "--help" | "-h" => {
                eprintln!("{USAGE}");
                return 2;
            }
            "--gate" => gate = true,
            "--deterministic-only" => opts.deterministic_only = true,
            "--threshold" => {
                let Some(v) = it.next() else {
                    eprintln!("--threshold needs a percent\n{USAGE}");
                    return 2;
                };
                match v.parse::<f64>() {
                    Ok(p) if p >= 0.0 => opts.threshold_pct = p,
                    _ => {
                        eprintln!("--threshold wants a non-negative percent, got {v:?}");
                        return 2;
                    }
                }
            }
            "--out" => {
                let Some(v) = it.next() else {
                    eprintln!("--out needs a path\n{USAGE}");
                    return 2;
                };
                out_path = Some(v.clone());
            }
            other if other.starts_with('-') => {
                eprintln!("unknown flag {other:?}\n{USAGE}");
                return 2;
            }
            _ => paths.push(arg),
        }
    }
    let [base_path, cand_path] = paths.as_slice() else {
        eprintln!("{USAGE}");
        return 2;
    };
    let base = match load(Path::new(base_path.as_str())) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("baseline: {e}");
            return 2;
        }
    };
    let cand = match load(Path::new(cand_path.as_str())) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("candidate: {e}");
            return 2;
        }
    };
    let findings = diff(&base, &cand, opts);
    let md = markdown(base_path, cand_path, &findings, opts);
    print!("{md}");
    if let Some(p) = out_path {
        if let Err(e) = std::fs::write(&p, &md) {
            eprintln!("--out {p:?}: {e}");
            return 2;
        }
    }
    let gated = findings.iter().any(|f| f.gating);
    if gate && gated {
        1
    } else {
        0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report(name: &str, rows: Vec<BenchRow>) -> BenchReport {
        BenchReport { name: name.into(), meta: Vec::new(), rows }
    }

    fn row(series: &str, tags: &[(&str, &str)], nums: &[(&str, f64)]) -> BenchRow {
        let mut tags: Vec<(String, String)> =
            tags.iter().map(|(k, v)| (k.to_string(), v.to_string())).collect();
        tags.sort();
        BenchRow {
            series: series.into(),
            tags,
            nums: nums.iter().map(|(k, v)| (k.to_string(), Some(*v))).collect(),
        }
    }

    #[test]
    fn field_classification() {
        assert!(is_deterministic_field("map.items"));
        assert!(is_deterministic_field("hist.map.block_items.p99"));
        assert!(is_deterministic_field("node2.cache.flush_entries"));
        assert!(!is_deterministic_field("wall_mean"));
        assert!(!is_deterministic_field("virtual_makespan_mean"));
        assert!(!is_deterministic_field("hist.wall.transport.frame_wait_ns.p50"));
        assert!(!is_deterministic_field("pool.queue_peak"));
        assert!(!is_deterministic_field("node0.shard.contended"));
        // The hot-path additions: pool allocator stats and pin counts ride
        // the "pool." marker; stripe sizing is feedback-driven.
        assert!(!is_deterministic_field("alloc.pool.hits"));
        assert!(!is_deterministic_field("alloc.pool.pooled_bytes"));
        assert!(!is_deterministic_field("pool.pinned_threads"));
        assert!(!is_deterministic_field("shard.stripes"));
        assert!(is_deterministic_field("shard.absorbed_pairs"));
    }

    #[test]
    fn json_roundtrip_through_bench_writer() {
        use crate::bench::report::{Report, Row};
        let mut rep = Report::new("rt");
        rep.meta("backend", "simulated");
        rep.push(
            Row::new("blaze")
                .tag("nodes", 4)
                .num("map.items", 100.0)
                .num("broken", f64::NAN),
        );
        let parsed = BenchReport::from_json(&rep.to_json()).expect("parse own writer");
        assert_eq!(parsed.name, "rt");
        assert_eq!(parsed.meta, vec![("backend".to_string(), "simulated".to_string())]);
        assert_eq!(parsed.rows.len(), 1);
        assert_eq!(parsed.rows[0].key(), "blaze{nodes=4}");
        assert_eq!(parsed.rows[0].num("map.items"), Some(&Some(100.0)));
        assert_eq!(parsed.rows[0].num("broken"), Some(&None), "NaN → null → None");
    }

    #[test]
    fn json_parser_handles_escapes_and_nesting() {
        let v = Value::parse(r#"{"a":"q\"\nA","b":[1,-2.5e3,null,true]}"#).unwrap();
        assert_eq!(v.get("a"), Some(&Value::Str("q\"\nA".into())));
        let Some(Value::Arr(items)) = v.get("b") else { panic!("array") };
        assert_eq!(items[0], Value::Num(1.0));
        assert_eq!(items[1], Value::Num(-2500.0));
        assert_eq!(items[2], Value::Null);
        assert_eq!(items[3], Value::Bool(true));
        assert!(Value::parse("{\"a\":1} junk").is_err());
        assert!(Value::parse("{\"a\":}").is_err());
    }

    #[test]
    fn identical_sets_have_no_findings() {
        let rows = || {
            vec![
                row("blaze", &[("nodes", "2")], &[("map.items", 64.0), ("wall_mean", 0.5)]),
                row("conventional", &[("nodes", "2")], &[("map.items", 64.0)]),
            ]
        };
        let f = diff(
            &[report("fig", rows())],
            &[report("fig", rows())],
            DiffOptions::default(),
        );
        assert!(f.is_empty(), "{f:?}");
    }

    #[test]
    fn deterministic_drift_gates_exactly() {
        let base = [report("fig", vec![row("blaze", &[], &[("map.items", 64.0)])])];
        let cand = [report("fig", vec![row("blaze", &[], &[("map.items", 65.0)])])];
        let f = diff(&base, &cand, DiffOptions::default());
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].class, "deterministic-drift");
        assert!(f[0].gating);
    }

    #[test]
    fn wall_drift_respects_threshold_and_sidedness() {
        let opts = DiffOptions { threshold_pct: 10.0, deterministic_only: false };
        let base = [report("fig", vec![row("blaze", &[], &[("wall_mean", 1.0)])])];
        // +5%: inside the ceiling.
        let ok = [report("fig", vec![row("blaze", &[], &[("wall_mean", 1.05)])])];
        assert!(diff(&base, &ok, opts).is_empty());
        // 2x faster: improvements never flag.
        let faster = [report("fig", vec![row("blaze", &[], &[("wall_mean", 0.5)])])];
        assert!(diff(&base, &faster, opts).is_empty());
        // +50%: flagged, and gating flips with --deterministic-only.
        let slow = [report("fig", vec![row("blaze", &[], &[("wall_mean", 1.5)])])];
        let f = diff(&base, &slow, opts);
        assert_eq!(f.len(), 1);
        assert!(f[0].gating);
        let advisory = diff(
            &base,
            &slow,
            DiffOptions { deterministic_only: true, ..opts },
        );
        assert!(!advisory[0].gating, "wall drift is advisory under --deterministic-only");
    }

    #[test]
    fn structure_only_baseline_gates_row_presence() {
        // Rows with tags but no nums: only presence is checked.
        let base = [report(
            "fig",
            vec![
                BenchRow { series: "blaze".into(), tags: vec![("nodes".into(), "4".into())], nums: vec![] },
                BenchRow { series: "blaze".into(), tags: vec![("nodes".into(), "8".into())], nums: vec![] },
            ],
        )];
        let cand = [report(
            "fig",
            vec![row("blaze", &[("nodes", "4")], &[("map.items", 7.0)])],
        )];
        let f = diff(&base, &cand, DiffOptions::default());
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].class, "missing-row");
        assert!(f[0].gating);
        assert_eq!(f[0].row, "blaze{nodes=8}");
    }

    #[test]
    fn missing_report_and_extra_rows() {
        let base = [report("a", vec![]), report("b", vec![])];
        let cand = [report(
            "a",
            vec![row("blaze", &[], &[("map.items", 1.0)])],
        )];
        let f = diff(&base, &cand, DiffOptions::default());
        let classes: Vec<&str> = f.iter().map(|x| x.class).collect();
        assert!(classes.contains(&"extra-row"));
        assert!(classes.contains(&"missing-report"));
        assert!(f.iter().find(|x| x.class == "extra-row").is_some_and(|x| !x.gating));
        assert!(f.iter().find(|x| x.class == "missing-report").is_some_and(|x| x.gating));
    }

    #[test]
    fn markdown_mentions_counts_and_policy() {
        let base = [report("fig", vec![row("blaze", &[], &[("map.items", 1.0)])])];
        let cand = [report("fig", vec![row("blaze", &[], &[("map.items", 2.0)])])];
        let opts = DiffOptions::default();
        let md = markdown("base", "cand", &diff(&base, &cand, opts), opts);
        assert!(md.contains("# blaze report"), "{md}");
        assert!(md.contains("deterministic-drift"), "{md}");
        assert!(md.contains("**1 gated regression(s), 0 advisory note(s).**"), "{md}");
        let clean = markdown("base", "cand", &[], opts);
        assert!(clean.contains("No differences."), "{clean}");
    }

    #[test]
    fn run_report_usage_errors() {
        let argv = |s: &str| s.split_whitespace().map(str::to_string).collect::<Vec<_>>();
        assert_eq!(run_report(&argv("")), 2, "missing paths");
        assert_eq!(run_report(&argv("a b c")), 2, "too many paths");
        assert_eq!(run_report(&argv("a b --threshold nope")), 2);
        assert_eq!(run_report(&argv("a b --frobnicate")), 2);
        assert_eq!(run_report(&argv("/definitely/missing /also/missing --gate")), 2);
    }
}
