//! Offline stub for the PJRT runtime (built without the `pjrt` feature).
//!
//! Same public surface as the real [`super::pjrt`] runtime, but `load`
//! always fails, so a stub `Runtime` can never be constructed. Callers
//! treat a failed load as "no runtime" and fall back to the scalar mapper
//! paths; the PJRT integration tests print a SKIP line and pass.

use std::path::Path;

use crate::util::error::{anyhow, Result};

use super::{GmmBatch, KmeansBatch};

/// Uninhabitable stand-in for the compiled-executable registry.
pub struct Runtime {
    never: std::convert::Infallible,
}

impl Runtime {
    /// Always errs: PJRT support is not compiled in.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        Err(anyhow!(
            "PJRT runtime unavailable for {}: built without the `pjrt` cargo feature \
             (requires the `xla` crate; see Cargo.toml)",
            dir.as_ref().display()
        ))
    }

    /// AOT batch size — callers pad the last batch up to this.
    pub fn batch(&self) -> usize {
        match self.never {}
    }

    /// AOT point dimension.
    pub fn dim(&self) -> usize {
        match self.never {}
    }

    /// AOT component/center count.
    pub fn k(&self) -> usize {
        match self.never {}
    }

    /// PJRT platform name (diagnostics).
    pub fn platform(&self) -> String {
        match self.never {}
    }

    /// Artifact names available.
    pub fn artifact_names(&self) -> Vec<&str> {
        match self.never {}
    }

    /// One k-means assignment batch.
    pub fn kmeans_assign(
        &self,
        _points: &[f32],
        _centers: &[f32],
        _valid: &[f32],
    ) -> Result<KmeansBatch> {
        match self.never {}
    }

    /// One GMM E-step batch.
    pub fn gmm_estep(
        &self,
        _points: &[f32],
        _means: &[f32],
        _precisions: &[f32],
        _logdets: &[f32],
        _logweights: &[f32],
        _valid: &[f32],
    ) -> Result<GmmBatch> {
        match self.never {}
    }

    /// Squared distances from a padded point batch to the AOT queries.
    pub fn knn_dist(&self, _points: &[f32], _queries: &[f32]) -> Result<Vec<f32>> {
        match self.never {}
    }

    /// Raw pairwise distances `(batch, K)`.
    pub fn pairwise_dist(&self, _points: &[f32], _centers: &[f32]) -> Result<Vec<f32>> {
        match self.never {}
    }
}

impl std::fmt::Debug for Runtime {
    fn fmt(&self, _f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self.never {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn load_always_errs_offline() {
        let err = Runtime::load("artifacts").unwrap_err();
        assert!(err.to_string().contains("pjrt"), "{err}");
    }
}
