//! PJRT runtime: load and execute the AOT-compiled JAX/Pallas artifacts.
//!
//! The compile path (`make artifacts`) runs python once; this module makes
//! the rust binary self-contained afterwards: it parses
//! `artifacts/manifest.json`, loads each `*.hlo.txt` (HLO **text** — the
//! id-safe interchange format, see DESIGN.md), compiles it on the PJRT CPU
//! client once, and exposes typed entry points the map hot path calls per
//! batch. Python never runs at request time.
//!
//! The PJRT bridge needs the `xla` crate, which the offline build does not
//! vendor; it compiles only under the `pjrt` cargo feature. Without the
//! feature a [`Runtime`] stub with the same API is built whose `load`
//! always errs, so every caller falls back to the scalar mappers and the
//! PJRT tests skip.

pub mod manifest;

#[cfg(feature = "pjrt")]
mod pjrt;
#[cfg(feature = "pjrt")]
pub use pjrt::Runtime;

#[cfg(not(feature = "pjrt"))]
mod stub;
#[cfg(not(feature = "pjrt"))]
pub use stub::Runtime;

pub use manifest::Manifest;

/// Result of one k-means assignment batch (sufficient statistics).
#[derive(Debug, Clone)]
pub struct KmeansBatch {
    /// Nearest center per point (padding rows included; mask them off).
    pub assign: Vec<i32>,
    /// Masked per-center point counts.
    pub counts: Vec<f32>,
    /// Masked per-center coordinate sums, row-major `(K, D)`.
    pub sums: Vec<f32>,
    /// Masked sum of min squared distances.
    pub inertia: f32,
}

/// Result of one GMM E-step batch (sufficient statistics, paper Eqs. 2–7).
#[derive(Debug, Clone)]
pub struct GmmBatch {
    /// Responsibility masses per component `(K,)`.
    pub nk: Vec<f32>,
    /// Responsibility-weighted coordinate sums `(K, D)`.
    pub mu_sums: Vec<f32>,
    /// Responsibility-weighted outer-product sums `(K, D, D)`.
    pub cov_sums: Vec<f32>,
    /// Masked log-likelihood.
    pub loglik: f32,
}
