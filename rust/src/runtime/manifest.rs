//! Artifact manifest parsing.
//!
//! `python/compile/aot.py` writes a small flat JSON manifest next to the
//! HLO artifacts. The build is offline (no serde), so this is a minimal
//! hand-rolled parser for exactly that manifest shape — it rejects anything
//! it does not understand rather than guessing.

use std::path::Path;

use crate::util::error::{anyhow, Context, Result};

/// Parsed `manifest.json`.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// AOT batch size (rows per executable invocation).
    pub batch: usize,
    /// Point dimension.
    pub dim: usize,
    /// Center/component count.
    pub k: usize,
    /// k-NN query count per invocation.
    pub queries: usize,
    /// Pallas point-tile size (documentation/validation only).
    pub tile_n: usize,
    /// Artifact base names (e.g. `kmeans_assign`).
    names: Vec<String>,
}

impl Manifest {
    /// Read and parse `path`.
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading {}", path.as_ref().display()))?;
        Self::parse(&text)
    }

    /// Parse manifest JSON text.
    pub fn parse(text: &str) -> Result<Self> {
        let batch = json_usize(text, "batch")?;
        let dim = json_usize(text, "dim")?;
        let k = json_usize(text, "k")?;
        let queries = json_usize(text, "queries")?;
        let tile_n = json_usize(text, "tile_n")?;
        // Artifact names: keys of the "artifacts" object — find `"name": {`.
        let artifacts_at = text
            .find("\"artifacts\"")
            .ok_or_else(|| anyhow!("manifest missing \"artifacts\""))?;
        let tail = &text[artifacts_at..];
        let mut names = Vec::new();
        let mut search = tail;
        // Skip the "artifacts" key itself, then collect object-valued keys.
        if let Some(brace) = search.find('{') {
            search = &search[brace + 1..];
        }
        while let Some(q0) = search.find('"') {
            let rest = &search[q0 + 1..];
            let Some(q1) = rest.find('"') else { break };
            let key = &rest[..q1];
            let after = rest[q1 + 1..].trim_start();
            if let Some(after) = after.strip_prefix(':') {
                if after.trim_start().starts_with('{') && key != "artifacts" {
                    names.push(key.to_string());
                }
            }
            search = &rest[q1 + 1..];
        }
        if names.is_empty() {
            return Err(anyhow!("manifest lists no artifacts"));
        }
        names.sort();
        Ok(Self { batch, dim, k, queries, tile_n, names })
    }

    /// Artifact base names, sorted.
    pub fn artifact_names(&self) -> impl Iterator<Item = &str> {
        self.names.iter().map(String::as_str)
    }
}

fn json_usize(text: &str, key: &str) -> Result<usize> {
    let pat = format!("\"{key}\"");
    let at = text
        .find(&pat)
        .ok_or_else(|| anyhow!("manifest missing {key:?}"))?;
    let rest = &text[at + pat.len()..];
    let rest = rest
        .trim_start()
        .strip_prefix(':')
        .ok_or_else(|| anyhow!("manifest {key:?} not followed by ':'"))?
        .trim_start();
    let digits: String = rest.chars().take_while(char::is_ascii_digit).collect();
    digits
        .parse()
        .with_context(|| format!("manifest {key:?} is not an integer"))
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
  "batch": 4096,
  "dim": 4,
  "k": 5,
  "queries": 1,
  "tile_n": 512,
  "artifacts": {
    "kmeans_assign": { "file": "kmeans_assign.hlo.txt", "hlo_bytes": 9000 },
    "gmm_estep": { "file": "gmm_estep.hlo.txt", "hlo_bytes": 15000 }
  }
}"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.batch, 4096);
        assert_eq!(m.dim, 4);
        assert_eq!(m.k, 5);
        assert_eq!(m.queries, 1);
        assert_eq!(m.tile_n, 512);
        let names: Vec<&str> = m.artifact_names().collect();
        assert_eq!(names, vec!["gmm_estep", "kmeans_assign"]);
    }

    #[test]
    fn missing_key_rejected() {
        assert!(Manifest::parse(r#"{"batch": 1}"#).is_err());
    }

    #[test]
    fn no_artifacts_rejected() {
        let text = r#"{"batch":1,"dim":1,"k":1,"queries":1,"tile_n":1,"artifacts":{}}"#;
        assert!(Manifest::parse(text).is_err());
    }

    #[test]
    fn parses_real_manifest_if_built() {
        // Integration hook: when `make artifacts` has run, validate the
        // real manifest too.
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/manifest.json");
        if let Ok(m) = Manifest::load(path) {
            assert!(m.batch >= 512);
            assert_eq!(m.artifact_names().count(), 4);
        }
    }
}
