//! PJRT-backed runtime (built with the `pjrt` feature; requires the `xla`
//! crate as an added dependency — the offline build compiles
//! [`super::stub`] instead).

use std::collections::HashMap;
use std::path::Path;

use crate::util::error::{anyhow, bail, Context, Result};

use super::{GmmBatch, KmeansBatch, Manifest};

/// Compiled-executable registry over one PJRT client.
pub struct Runtime {
    client: xla::PjRtClient,
    manifest: Manifest,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Runtime {
    /// Load every artifact listed in `dir/manifest.json` and compile it on
    /// a fresh PJRT CPU client.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref();
        let manifest = Manifest::load(dir.join("manifest.json"))
            .with_context(|| format!("loading manifest from {}", dir.display()))?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        let mut executables = HashMap::new();
        for name in manifest.artifact_names() {
            let path = dir.join(format!("{name}.hlo.txt"));
            let proto = xla::HloModuleProto::from_text_file(&path)
                .map_err(|e| anyhow!("parsing {}: {e:?}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
            executables.insert(name.to_string(), exe);
        }
        Ok(Self { client, manifest, executables })
    }

    /// AOT batch size — callers pad the last batch up to this.
    pub fn batch(&self) -> usize {
        self.manifest.batch
    }

    /// AOT point dimension.
    pub fn dim(&self) -> usize {
        self.manifest.dim
    }

    /// AOT component/center count.
    pub fn k(&self) -> usize {
        self.manifest.k
    }

    /// PJRT platform name (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Artifact names available.
    pub fn artifact_names(&self) -> Vec<&str> {
        self.executables.keys().map(String::as_str).collect()
    }

    fn exe(&self, name: &str) -> Result<&xla::PjRtLoadedExecutable> {
        self.executables
            .get(name)
            .ok_or_else(|| anyhow!("artifact {name:?} not in manifest"))
    }

    fn run(&self, name: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let exe = self.exe(name)?;
        let result = exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow!("executing {name}: {e:?}"))?;
        let literal = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching {name} result: {e:?}"))?;
        // Lowered with return_tuple=True: always a tuple.
        literal.to_tuple().map_err(|e| anyhow!("untupling {name}: {e:?}"))
    }

    fn f32_input(&self, data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
        let expect: i64 = dims.iter().product();
        if expect as usize != data.len() {
            bail!("input has {} elements, shape {:?} wants {}", data.len(), dims, expect);
        }
        xla::Literal::vec1(data)
            .reshape(dims)
            .map_err(|e| anyhow!("reshape {dims:?}: {e:?}"))
    }

    /// One k-means assignment batch.
    ///
    /// `points` is `(batch, dim)` row-major and must be padded to the AOT
    /// batch size; `valid` marks real rows with 1.0.
    pub fn kmeans_assign(
        &self,
        points: &[f32],
        centers: &[f32],
        valid: &[f32],
    ) -> Result<KmeansBatch> {
        let (b, d, k) = (self.batch() as i64, self.dim() as i64, self.k() as i64);
        let outs = self.run(
            "kmeans_assign",
            &[
                self.f32_input(points, &[b, d])?,
                self.f32_input(centers, &[k, d])?,
                self.f32_input(valid, &[b])?,
            ],
        )?;
        let [assign, counts, sums, inertia]: [xla::Literal; 4] = outs
            .try_into()
            .map_err(|v: Vec<_>| anyhow!("kmeans_assign returned {} outputs", v.len()))?;
        Ok(KmeansBatch {
            assign: assign.to_vec::<i32>().map_err(|e| anyhow!("{e:?}"))?,
            counts: counts.to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?,
            sums: sums.to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?,
            inertia: inertia.get_first_element::<f32>().map_err(|e| anyhow!("{e:?}"))?,
        })
    }

    /// One GMM E-step batch. `precisions` is `(K, D, D)`, `logdets`/
    /// `logweights` are `(K,)`.
    pub fn gmm_estep(
        &self,
        points: &[f32],
        means: &[f32],
        precisions: &[f32],
        logdets: &[f32],
        logweights: &[f32],
        valid: &[f32],
    ) -> Result<GmmBatch> {
        let (b, d, k) = (self.batch() as i64, self.dim() as i64, self.k() as i64);
        let outs = self.run(
            "gmm_estep",
            &[
                self.f32_input(points, &[b, d])?,
                self.f32_input(means, &[k, d])?,
                self.f32_input(precisions, &[k, d, d])?,
                self.f32_input(logdets, &[k])?,
                self.f32_input(logweights, &[k])?,
                self.f32_input(valid, &[b])?,
            ],
        )?;
        let [nk, mu_sums, cov_sums, loglik]: [xla::Literal; 4] = outs
            .try_into()
            .map_err(|v: Vec<_>| anyhow!("gmm_estep returned {} outputs", v.len()))?;
        Ok(GmmBatch {
            nk: nk.to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?,
            mu_sums: mu_sums.to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?,
            cov_sums: cov_sums.to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))?,
            loglik: loglik.get_first_element::<f32>().map_err(|e| anyhow!("{e:?}"))?,
        })
    }

    /// Squared distances from a padded point batch to `queries`
    /// (`(Q, dim)`, Q fixed at AOT time). Returns `(batch, Q)` row-major.
    pub fn knn_dist(&self, points: &[f32], queries: &[f32]) -> Result<Vec<f32>> {
        let (b, d, q) = (
            self.batch() as i64,
            self.dim() as i64,
            self.manifest.queries as i64,
        );
        let outs = self.run(
            "knn_dist",
            &[self.f32_input(points, &[b, d])?, self.f32_input(queries, &[q, d])?],
        )?;
        let [d2]: [xla::Literal; 1] = outs
            .try_into()
            .map_err(|v: Vec<_>| anyhow!("knn_dist returned {} outputs", v.len()))?;
        d2.to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))
    }

    /// Raw pairwise distances `(batch, K)` — the bare L1 kernel, used by
    /// tests to validate the full python→rust numerics bridge.
    pub fn pairwise_dist(&self, points: &[f32], centers: &[f32]) -> Result<Vec<f32>> {
        let (b, d, k) = (self.batch() as i64, self.dim() as i64, self.k() as i64);
        let outs = self.run(
            "pairwise_dist",
            &[self.f32_input(points, &[b, d])?, self.f32_input(centers, &[k, d])?],
        )?;
        let [d2]: [xla::Literal; 1] = outs
            .try_into()
            .map_err(|v: Vec<_>| anyhow!("pairwise_dist returned {} outputs", v.len()))?;
        d2.to_vec::<f32>().map_err(|e| anyhow!("{e:?}"))
    }
}

impl std::fmt::Debug for Runtime {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Runtime")
            .field("platform", &self.platform())
            .field("batch", &self.batch())
            .field("dim", &self.dim())
            .field("k", &self.k())
            .field("artifacts", &self.executables.len())
            .finish()
    }
}
