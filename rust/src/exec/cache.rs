//! Bounded per-thread eager-reduction caches (paper §2.3.1) for the
//! threaded backend.
//!
//! Replicates the simulated eager engine's per-worker cache semantics
//! *exactly* — same entry/apply behavior, same capacity check after every
//! emit, same whole-cache drain on overflow — so a block's sequence of
//! locally-reduced partials is bit-identical no matter which backend runs
//! it. The difference is where drains go: the simulated engine merges them
//! straight into a node-local map; here each drain becomes a
//! [`FlushBatch`] tagged with its canonical position
//! ([`super::shard::partial_order`]) and lands in the lock-striped
//! [`super::shard::ShardedMap`], which restores the simulated merge order
//! at canonical-merge time regardless of thread interleaving.
//!
//! Two hot-path mechanics live here rather than in the shard: each drain
//! hashes its keys in one batched pass ([`crate::util::hash::hash_batch_by`])
//! so stripe selection downstream reuses the lane instead of re-hashing
//! per pair, and drain buffers come from a [`FlushScratch`] (per-thread
//! [`BufferPool`]s under `AllocMode::Pool`) so the flush storm recycles
//! two allocations per drain instead of hitting the global allocator.

use std::collections::hash_map::Entry;
use std::hash::Hash;

use crate::mapreduce::eager::HASH_ENTRY_OVERHEAD;
use crate::mapreduce::reducers::Reducer;
use crate::ser::fastser::FastSer;
use crate::util::alloc::{AllocMode, BufferPool, Scratch};
use crate::util::hash::{hash_batch_by, FxHashMap};

use super::shard::partial_order;

/// One drained batch of locally-reduced pairs (each key at most once),
/// tagged with its canonical merge position.
pub struct FlushBatch<K, V> {
    /// Canonical order key ([`partial_order`]).
    pub order: u64,
    /// Modeled cache bytes at the drain moment (same formula as the
    /// simulated engine's per-worker byte accounting) — what the
    /// `CacheFlush` trace event reports.
    pub bytes: u64,
    /// The drained pairs.
    pub pairs: Vec<(K, V)>,
    /// Batched key hashes: `hashes[i] == fxhash(&pairs[i].0)`, computed
    /// once at drain time and reused for stripe selection.
    pub hashes: Vec<u64>,
}

/// Buffer source for flush drains: pair buffers and hash lanes, each
/// routed through its own typed pool. Under `AllocMode::System` this
/// degenerates to plain `Vec::with_capacity` — byte-identical behavior,
/// no pooling — which is exactly the blaze-vs-blaze-TCM ablation axis.
pub struct FlushScratch<'a, K, V> {
    pairs: Scratch<'a, (K, V)>,
    hashes: Scratch<'a, u64>,
}

impl<'a, K, V> FlushScratch<'a, K, V> {
    /// Scratch over a worker's private pools in `mode`.
    pub fn new(
        mode: AllocMode,
        pairs: &'a BufferPool<(K, V)>,
        hashes: &'a BufferPool<u64>,
    ) -> Self {
        Self { pairs: Scratch::new(mode, pairs), hashes: Scratch::new(mode, hashes) }
    }

    /// Return a fully-absorbed batch's buffers to the pools (no-op under
    /// `System`). Call after [`super::shard::ShardedMap::absorb_prehashed`]
    /// has drained the pairs.
    pub fn recycle(&self, batch: FlushBatch<K, V>) {
        self.pairs.put(batch.pairs);
        self.hashes.put(batch.hashes);
    }

    /// Drop an aborted worker's drained batch *without* absorbing it:
    /// the pairs never reach a shard and the buffers go straight back to
    /// the pools (length-cleared, so a later reuse cannot observe stale
    /// tail entries). Returns `(pairs, bytes)` drop accounting for the
    /// abort bookkeeping. Same mechanics as [`FlushScratch::recycle`];
    /// it exists as its own verb so call sites that *must not* absorb
    /// read as such.
    pub fn discard(&self, batch: FlushBatch<K, V>) -> (u64, u64) {
        let dropped = (batch.pairs.len() as u64, batch.bytes);
        self.recycle(batch);
        dropped
    }
}

/// A bounded eager-combine cache for one map block (= one virtual worker).
pub struct EagerCache<K, V> {
    worker: usize,
    cap: usize,
    next_seq: u32,
    map: FxHashMap<K, V>,
    /// Encoded-payload byte accounting (same formula as the simulated
    /// engine: payload + per-entry overhead), high-water tracked.
    bytes: u64,
    peak_bytes: u64,
}

impl<K: Hash + Eq + FastSer, V: FastSer> EagerCache<K, V> {
    /// Cache for virtual worker `worker` holding at most `cap` entries.
    pub fn new(worker: usize, cap: usize) -> Self {
        Self {
            worker,
            cap: cap.max(1),
            next_seq: 0,
            map: FxHashMap::default(),
            bytes: 0,
            peak_bytes: 0,
        }
    }

    /// Eagerly reduce one emitted pair into the cache. Returns the drained
    /// overflow batch when this emit filled the cache (the simulated
    /// engine's flush-into-node-map moment); popular keys re-enter the
    /// empty cache on their next emission, exactly as in the paper.
    pub fn reduce(
        &mut self,
        key: K,
        value: V,
        red: &Reducer<V>,
        scratch: &FlushScratch<'_, K, V>,
    ) -> Option<FlushBatch<K, V>> {
        match self.map.entry(key) {
            Entry::Occupied(mut e) => red.apply(e.get_mut(), &value),
            Entry::Vacant(e) => {
                self.bytes += HASH_ENTRY_OVERHEAD
                    + e.key().encoded_len() as u64
                    + value.encoded_len() as u64;
                e.insert(value);
            }
        }
        self.peak_bytes = self.peak_bytes.max(self.bytes);
        (self.map.len() >= self.cap).then(|| self.drain(false, scratch))
    }

    /// Drain whatever remains at block end as the worker's *final* partial
    /// (canonically merged after every worker's overflow flushes, like the
    /// simulated engine's end-of-map cache merge). May be empty.
    pub fn finish(mut self, scratch: &FlushScratch<'_, K, V>) -> FlushBatch<K, V> {
        self.drain(true, scratch)
    }

    /// High-water cache bytes (memory accounting).
    pub fn peak_bytes(&self) -> u64 {
        self.peak_bytes
    }

    /// Poison the cache on a mid-block abort (its node was killed while
    /// the block was still mapping): every pending partial is dropped on
    /// the floor — no [`FlushBatch`] is produced, so the attempt cannot
    /// leak into any shard — and `(entries, bytes)` pending at the abort
    /// moment come back as drop accounting. This is the threaded half of
    /// the [`crate::fault::engine`] discard contract: an aborted attempt
    /// contributes *zero* to every gated counter, and the block's
    /// re-execution starts from a fresh cache with the same
    /// [`partial_order`] sequence space, so failure and failure-free
    /// runs stay byte-identical.
    pub fn poison(self) -> (u64, u64) {
        (self.map.len() as u64, self.bytes)
    }

    fn drain(&mut self, final_drain: bool, scratch: &FlushScratch<'_, K, V>) -> FlushBatch<K, V> {
        // A worker has exactly one final drain, so finals always carry
        // sequence 0 — only overflow flushes consume the counter.
        let seq = if final_drain { 0 } else { self.next_seq };
        let order = partial_order(final_drain, self.worker, seq);
        if !final_drain {
            self.next_seq += 1;
        }
        let bytes = self.bytes;
        self.bytes = 0;
        let mut pairs = scratch.pairs.get(self.map.len());
        pairs.extend(self.map.drain());
        let mut hashes = scratch.hashes.get(pairs.len());
        hash_batch_by(&pairs, |p| &p.0, &mut hashes);
        FlushBatch { order, bytes, pairs, hashes }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::hash::fxhash;

    fn scratch_pools<K, V>() -> (BufferPool<(K, V)>, BufferPool<u64>) {
        (BufferPool::new(), BufferPool::new())
    }

    #[test]
    fn overflow_drains_whole_cache_after_capacity_insert() {
        let red = Reducer::sum();
        let (pp, hp) = scratch_pools::<u64, u64>();
        let scratch = FlushScratch::new(AllocMode::System, &pp, &hp);
        let mut cache: EagerCache<u64, u64> = EagerCache::new(0, 2);
        assert!(cache.reduce(1, 10, &red, &scratch).is_none());
        // Occupied apply: no growth, no flush.
        assert!(cache.reduce(1, 5, &red, &scratch).is_none());
        // Second distinct key hits the cap: whole cache drains.
        let batch = cache.reduce(2, 7, &red, &scratch).expect("overflow flush");
        // Hash lane is parallel to the pairs, scalar-parity.
        assert_eq!(batch.hashes.len(), batch.pairs.len());
        for (p, h) in batch.pairs.iter().zip(&batch.hashes) {
            assert_eq!(*h, fxhash(&p.0));
        }
        let mut pairs = batch.pairs;
        pairs.sort_unstable();
        assert_eq!(pairs, vec![(1, 15), (2, 7)]);
        assert_eq!(batch.order, partial_order(false, 0, 0));
        // Cache is empty again; the next overflow gets the next sequence.
        assert!(cache.reduce(3, 1, &red, &scratch).is_none());
        let batch2 = cache.reduce(4, 1, &red, &scratch).expect("second flush");
        assert_eq!(batch2.order, partial_order(false, 0, 1));
        let fin = cache.finish(&scratch);
        assert!(fin.pairs.is_empty());
        assert_eq!(fin.order, partial_order(true, 0, 0));
    }

    #[test]
    fn capacity_one_flushes_every_emit() {
        let red = Reducer::sum();
        let (pp, hp) = scratch_pools::<u64, u64>();
        let scratch = FlushScratch::new(AllocMode::System, &pp, &hp);
        let mut cache: EagerCache<u64, u64> = EagerCache::new(3, 1);
        for i in 0..5u64 {
            let batch = cache.reduce(i % 2, 1, &red, &scratch).expect("cap-1 always flushes");
            assert_eq!(batch.pairs.len(), 1);
            assert_eq!(batch.hashes, vec![fxhash(&batch.pairs[0].0)]);
            assert_eq!(batch.order, partial_order(false, 3, i as u32));
        }
    }

    #[test]
    fn pooled_scratch_recycles_drain_buffers() {
        let red = Reducer::sum();
        let (pp, hp) = scratch_pools::<u64, u64>();
        let scratch = FlushScratch::new(AllocMode::Pool, &pp, &hp);
        let mut cache: EagerCache<u64, u64> = EagerCache::new(0, 1);
        for i in 0..10u64 {
            let batch = cache.reduce(i, 1, &red, &scratch).expect("cap-1 always flushes");
            scratch.recycle(batch);
        }
        let (hits, misses) = pp.stats();
        assert!(hits >= 8, "drain buffers recycle through the pool: {hits}/{misses}");
        assert!(hp.stats().0 >= 8);
    }

    #[test]
    fn poison_drops_pending_partials_with_accounting() {
        let red = Reducer::sum();
        let (pp, hp) = scratch_pools::<u64, u64>();
        let scratch = FlushScratch::new(AllocMode::System, &pp, &hp);
        let mut cache: EagerCache<u64, u64> = EagerCache::new(0, 8);
        for i in 0..5u64 {
            assert!(cache.reduce(i, 1, &red, &scratch).is_none());
        }
        let pending_bytes = 5 * (HASH_ENTRY_OVERHEAD + 1 + 1);
        let (entries, bytes) = cache.poison();
        assert_eq!(entries, 5, "every pending partial is dropped");
        assert_eq!(bytes, pending_bytes, "drop accounting matches the byte formula");
        // `poison` consumes the cache: no FlushBatch existed and none can
        // be produced afterwards, so nothing from the aborted attempt can
        // reach a shard.
    }

    #[test]
    fn poison_after_overflow_accounts_only_the_residue() {
        let red = Reducer::sum();
        let (pp, hp) = scratch_pools::<u64, u64>();
        let scratch = FlushScratch::new(AllocMode::System, &pp, &hp);
        let mut cache: EagerCache<u64, u64> = EagerCache::new(0, 2);
        assert!(cache.reduce(1, 1, &red, &scratch).is_none());
        let flushed = cache.reduce(2, 1, &red, &scratch).expect("overflow flush");
        scratch.recycle(flushed);
        // One entry re-enters the empty cache, then the node dies.
        assert!(cache.reduce(3, 1, &red, &scratch).is_none());
        let (entries, bytes) = cache.poison();
        assert_eq!(entries, 1, "already-flushed entries are not re-dropped");
        assert_eq!(bytes, HASH_ENTRY_OVERHEAD + 1 + 1);
    }

    #[test]
    fn discard_recycles_buffers_without_absorbing() {
        let red = Reducer::sum();
        let (pp, hp) = scratch_pools::<u64, u64>();
        let scratch = FlushScratch::new(AllocMode::Pool, &pp, &hp);
        let mut cache: EagerCache<u64, u64> = EagerCache::new(0, 1);
        let batch = cache.reduce(7, 9, &red, &scratch).expect("cap-1 flushes");
        let batch_bytes = batch.bytes;
        let (pairs, bytes) = scratch.discard(batch);
        assert_eq!((pairs, bytes), (1, batch_bytes));
        // The discarded batch's buffers really went back to the pools:
        // the next drain reuses them (length-cleared) instead of
        // allocating.
        let batch2 = cache.reduce(8, 1, &red, &scratch).expect("cap-1 flushes");
        assert_eq!(pp.stats().0, 1, "pair buffer recycled through the pool");
        assert_eq!(hp.stats().0, 1, "hash lane recycled through the pool");
        assert_eq!(batch2.pairs, vec![(8, 1)], "no stale tail from the discarded batch");
        assert_eq!(batch2.hashes.len(), 1);
    }

    #[test]
    fn byte_accounting_tracks_high_water() {
        let red = Reducer::sum();
        let (pp, hp) = scratch_pools::<u64, u64>();
        let scratch = FlushScratch::new(AllocMode::System, &pp, &hp);
        let mut cache: EagerCache<u64, u64> = EagerCache::new(0, 8);
        assert_eq!(cache.peak_bytes(), 0);
        cache.reduce(1, 1, &red, &scratch);
        let one = cache.peak_bytes();
        assert!(one > HASH_ENTRY_OVERHEAD);
        cache.reduce(2, 1, &red, &scratch);
        assert!(cache.peak_bytes() > one);
    }
}
