//! Bounded per-thread eager-reduction caches (paper §2.3.1) for the
//! threaded backend.
//!
//! Replicates the simulated eager engine's per-worker cache semantics
//! *exactly* — same entry/apply behavior, same capacity check after every
//! emit, same whole-cache drain on overflow — so a block's sequence of
//! locally-reduced partials is bit-identical no matter which backend runs
//! it. The difference is where drains go: the simulated engine merges them
//! straight into a node-local map; here each drain becomes a
//! [`FlushBatch`] tagged with its canonical position
//! ([`super::shard::partial_order`]) and lands in the lock-striped
//! [`super::shard::ShardedMap`], which restores the simulated merge order
//! at canonical-merge time regardless of thread interleaving.

use std::collections::hash_map::Entry;
use std::hash::Hash;

use crate::mapreduce::eager::HASH_ENTRY_OVERHEAD;
use crate::mapreduce::reducers::Reducer;
use crate::ser::fastser::FastSer;
use crate::util::hash::FxHashMap;

use super::shard::partial_order;

/// One drained batch of locally-reduced pairs (each key at most once),
/// tagged with its canonical merge position.
pub struct FlushBatch<K, V> {
    /// Canonical order key ([`partial_order`]).
    pub order: u64,
    /// Modeled cache bytes at the drain moment (same formula as the
    /// simulated engine's per-worker byte accounting) — what the
    /// `CacheFlush` trace event reports.
    pub bytes: u64,
    /// The drained pairs.
    pub pairs: Vec<(K, V)>,
}

/// A bounded eager-combine cache for one map block (= one virtual worker).
pub struct EagerCache<K, V> {
    worker: usize,
    cap: usize,
    next_seq: u32,
    map: FxHashMap<K, V>,
    /// Encoded-payload byte accounting (same formula as the simulated
    /// engine: payload + per-entry overhead), high-water tracked.
    bytes: u64,
    peak_bytes: u64,
}

impl<K: Hash + Eq + FastSer, V: FastSer> EagerCache<K, V> {
    /// Cache for virtual worker `worker` holding at most `cap` entries.
    pub fn new(worker: usize, cap: usize) -> Self {
        Self {
            worker,
            cap: cap.max(1),
            next_seq: 0,
            map: FxHashMap::default(),
            bytes: 0,
            peak_bytes: 0,
        }
    }

    /// Eagerly reduce one emitted pair into the cache. Returns the drained
    /// overflow batch when this emit filled the cache (the simulated
    /// engine's flush-into-node-map moment); popular keys re-enter the
    /// empty cache on their next emission, exactly as in the paper.
    pub fn reduce(&mut self, key: K, value: V, red: &Reducer<V>) -> Option<FlushBatch<K, V>> {
        match self.map.entry(key) {
            Entry::Occupied(mut e) => red.apply(e.get_mut(), &value),
            Entry::Vacant(e) => {
                self.bytes += HASH_ENTRY_OVERHEAD
                    + e.key().encoded_len() as u64
                    + value.encoded_len() as u64;
                e.insert(value);
            }
        }
        self.peak_bytes = self.peak_bytes.max(self.bytes);
        (self.map.len() >= self.cap).then(|| self.drain(false))
    }

    /// Drain whatever remains at block end as the worker's *final* partial
    /// (canonically merged after every worker's overflow flushes, like the
    /// simulated engine's end-of-map cache merge). May be empty.
    pub fn finish(mut self) -> FlushBatch<K, V> {
        self.drain(true)
    }

    /// High-water cache bytes (memory accounting).
    pub fn peak_bytes(&self) -> u64 {
        self.peak_bytes
    }

    fn drain(&mut self, final_drain: bool) -> FlushBatch<K, V> {
        // A worker has exactly one final drain, so finals always carry
        // sequence 0 — only overflow flushes consume the counter.
        let seq = if final_drain { 0 } else { self.next_seq };
        let order = partial_order(final_drain, self.worker, seq);
        if !final_drain {
            self.next_seq += 1;
        }
        let bytes = self.bytes;
        self.bytes = 0;
        FlushBatch { order, bytes, pairs: self.map.drain().collect() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn overflow_drains_whole_cache_after_capacity_insert() {
        let red = Reducer::sum();
        let mut cache: EagerCache<u64, u64> = EagerCache::new(0, 2);
        assert!(cache.reduce(1, 10, &red).is_none());
        // Occupied apply: no growth, no flush.
        assert!(cache.reduce(1, 5, &red).is_none());
        // Second distinct key hits the cap: whole cache drains.
        let batch = cache.reduce(2, 7, &red).expect("overflow flush");
        let mut pairs = batch.pairs;
        pairs.sort_unstable();
        assert_eq!(pairs, vec![(1, 15), (2, 7)]);
        assert_eq!(batch.order, partial_order(false, 0, 0));
        // Cache is empty again; the next overflow gets the next sequence.
        assert!(cache.reduce(3, 1, &red).is_none());
        let batch2 = cache.reduce(4, 1, &red).expect("second flush");
        assert_eq!(batch2.order, partial_order(false, 0, 1));
        let fin = cache.finish();
        assert!(fin.pairs.is_empty());
        assert_eq!(fin.order, partial_order(true, 0, 0));
    }

    #[test]
    fn capacity_one_flushes_every_emit() {
        let red = Reducer::sum();
        let mut cache: EagerCache<u64, u64> = EagerCache::new(3, 1);
        for i in 0..5u64 {
            let batch = cache.reduce(i % 2, 1, &red).expect("cap-1 always flushes");
            assert_eq!(batch.pairs.len(), 1);
            assert_eq!(batch.order, partial_order(false, 3, i as u32));
        }
    }

    #[test]
    fn byte_accounting_tracks_high_water() {
        let red = Reducer::sum();
        let mut cache: EagerCache<u64, u64> = EagerCache::new(0, 8);
        assert_eq!(cache.peak_bytes(), 0);
        cache.reduce(1, 1, &red);
        let one = cache.peak_bytes();
        assert!(one > HASH_ENTRY_OVERHEAD);
        cache.reduce(2, 1, &red);
        assert!(cache.peak_bytes() > one);
    }
}
