//! Scoped worker pool with a work-stealing block queue.
//!
//! The threaded backend's execution substrate: the calling thread *feeds*
//! map blocks (drained one at a time from the engine's
//! [`crate::mapreduce::DistInput::block_cursor`]) into a bounded shared
//! queue, while `n` scoped OS threads self-schedule — each idle worker
//! steals the next block from the queue head. Blocks are the work unit;
//! they are never split, so a block's items run in partition order on one
//! thread with that virtual worker's RNG stream, which is what keeps
//! threaded runs byte-identical to the simulated engines.
//!
//! The queue is bounded (backpressure: the feeder blocks while `cap`
//! blocks are in flight), so the materialized handoff memory is
//! `O(threads)` blocks, not `O(nodes × workers)`.
//!
//! Observability is deliberately cheap so it does not perturb the path it
//! measures: queue depth and peak are relaxed atomics maintained inside
//! push/pop (no extra lock acquisition to read a gauge), and occupancy
//! snapshots are taken every [`SAMPLE_EVERY`]-th stolen block per thread
//! rather than on all of them. The canonical JSONL trace is sample-free,
//! so sampling cadence never touches byte-identity.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex};
use std::time::Instant;

/// Occupancy sampling stride: each worker snapshots the queue on its 1st,
/// (k+1)-th, (2k+1)-th … stolen block. Deterministic per thread, but the
/// resulting series still depends on real scheduling — observability only.
const SAMPLE_EVERY: u64 = 8;

/// Bounded MPMC queue of pending blocks.
pub struct BlockQueue<T> {
    state: Mutex<State<T>>,
    not_full: Condvar,
    not_empty: Condvar,
    cap: usize,
    /// Blocks queued and not yet stolen — relaxed mirror of
    /// `state.items.len()`, so gauges never take the queue lock.
    depth: AtomicUsize,
    /// High-water queue depth observed after any push.
    peak: AtomicUsize,
}

struct State<T> {
    items: VecDeque<T>,
    closed: bool,
}

impl<T> BlockQueue<T> {
    /// Queue admitting at most `cap` (≥ 1) in-flight blocks.
    pub fn bounded(cap: usize) -> Self {
        Self {
            state: Mutex::new(State { items: VecDeque::new(), closed: false }),
            not_full: Condvar::new(),
            not_empty: Condvar::new(),
            cap: cap.max(1),
            depth: AtomicUsize::new(0),
            peak: AtomicUsize::new(0),
        }
    }

    /// High-water queue depth (the `pool.queue_peak` run counter).
    /// Scheduling-dependent: observability only.
    pub fn peak(&self) -> usize {
        self.peak.load(Ordering::Relaxed)
    }

    /// Current queue depth (blocks queued, not yet stolen). A live gauge
    /// for the occupancy sampler — scheduling-dependent, observability
    /// only, like [`BlockQueue::peak`]. Lock-free: reading it cannot
    /// stall a worker mid-steal.
    pub fn depth(&self) -> usize {
        self.depth.load(Ordering::Relaxed)
    }

    /// Enqueue a block, blocking while the queue is full. Returns `false`
    /// (dropping `item`) if the queue was closed underneath the feeder —
    /// that only happens when a worker died.
    pub fn push(&self, item: T) -> bool {
        let mut st = self.state.lock().expect("block queue poisoned");
        while st.items.len() >= self.cap && !st.closed {
            st = self.not_full.wait(st).expect("block queue poisoned");
        }
        if st.closed {
            return false;
        }
        st.items.push_back(item);
        let depth = st.items.len();
        self.depth.store(depth, Ordering::Relaxed);
        self.peak.fetch_max(depth, Ordering::Relaxed);
        drop(st);
        self.not_empty.notify_one();
        true
    }

    /// Steal the next block, blocking while the queue is empty and still
    /// open. `None` once the queue is closed and drained.
    pub fn pop(&self) -> Option<T> {
        let mut st = self.state.lock().expect("block queue poisoned");
        loop {
            if let Some(item) = st.items.pop_front() {
                self.depth.store(st.items.len(), Ordering::Relaxed);
                drop(st);
                self.not_full.notify_one();
                return Some(item);
            }
            if st.closed {
                return None;
            }
            st = self.not_empty.wait(st).expect("block queue poisoned");
        }
    }

    /// Close the queue: queued blocks still drain, pushes stop succeeding,
    /// and every blocked thread wakes.
    pub fn close(&self) {
        self.state.lock().expect("block queue poisoned").closed = true;
        self.not_full.notify_all();
        self.not_empty.notify_all();
    }

    /// Close the queue *and discard everything still queued*: the abort
    /// path for a dying worker. Blocks already stolen are the worker's
    /// problem (their partials die with its unwind); blocks still queued
    /// must not run either — the pool is failing the whole batch, so
    /// surviving workers drain to `None` immediately instead of mapping
    /// work whose output would be thrown away. Harmless after a normal
    /// `close()`: by then the queue is already empty.
    pub fn abort(&self) {
        let mut st = self.state.lock().expect("block queue poisoned");
        st.closed = true;
        st.items.clear();
        self.depth.store(0, Ordering::Relaxed);
        drop(st);
        self.not_full.notify_all();
        self.not_empty.notify_all();
    }
}

/// Close the queue when the feeder unwinds, so workers drain out and the
/// panic propagates instead of deadlocking. Drain-close: blocks already
/// queued still execute.
struct CloseOnDrop<'a, T> {
    queue: &'a BlockQueue<T>,
}

impl<T> Drop for CloseOnDrop<'_, T> {
    fn drop(&mut self) {
        self.queue.close();
    }
}

/// Abort the queue when a *worker* unwinds: a blocked feeder wakes (the
/// panic propagates instead of deadlocking) and queued blocks are
/// discarded rather than drained — the batch is failing, so surviving
/// workers must not keep mapping work whose output dies with it.
struct AbortOnDrop<'a, T> {
    queue: &'a BlockQueue<T>,
}

impl<T> Drop for AbortOnDrop<'_, T> {
    fn drop(&mut self) {
        // Harmless on the normal exit path: workers only return after the
        // queue is already closed and drained, so there is nothing left
        // to discard.
        self.queue.abort();
    }
}

/// One occupancy snapshot, taken by the worker that just stole a block:
/// how deep the queue was and how many threads were busy at that moment.
/// Everything here depends on real scheduling — Chrome-view material
/// (`pool.queue_depth` / `pool.busy_threads` counter tracks), never gated.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PoolSample {
    /// Nanoseconds since the pool started.
    pub wall_ns: u64,
    /// Blocks queued and not yet stolen.
    pub queue_depth: u64,
    /// Worker threads currently executing a block (includes the sampler).
    pub busy_threads: u64,
}

/// Observability counters from one [`execute`] run. All values depend on
/// real thread scheduling — report them, never gate determinism on them.
#[derive(Debug, Clone, Default)]
pub struct PoolStats {
    /// High-water count of blocks queued and not yet stolen.
    pub queue_peak: u64,
    /// Blocks each OS thread ended up executing (work-stealing balance).
    pub per_thread_blocks: Vec<u64>,
    /// Occupancy time-series: one snapshot per [`SAMPLE_EVERY`] stolen
    /// blocks per thread, in steal-completion order.
    pub samples: Vec<PoolSample>,
    /// Worker threads successfully pinned to a core (0 unless
    /// [`PoolOptions::pin_threads`] was set and the platform supports it).
    pub pinned_threads: u64,
}

/// Knobs for [`execute_with`].
#[derive(Debug, Clone, Copy)]
pub struct PoolOptions {
    /// Worker thread count (clamped to ≥ 1).
    pub threads: usize,
    /// Bounded queue capacity (clamped to ≥ 1).
    pub queue_cap: usize,
    /// Pin worker `i` to core `i % cores`. Opt-in; a no-op (with
    /// `pinned_threads == 0`) on platforms without `sched_setaffinity`.
    /// Pinning is pure placement: block→thread assignment is still
    /// work-stealing, so results stay byte-identical either way.
    pub pin_threads: bool,
}

/// Pin the calling thread to `core` (mod the visible CPU count) via
/// `sched_setaffinity`. Returns whether the syscall succeeded.
#[cfg(target_os = "linux")]
fn pin_current_thread(core: usize) -> bool {
    // 16 × u64 = room for 1024 CPUs, same layout as libc's cpu_set_t.
    let mut mask = [0u64; 16];
    let cpus = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let cpu = core % cpus.min(16 * 64).max(1);
    mask[cpu / 64] |= 1u64 << (cpu % 64);
    extern "C" {
        fn sched_setaffinity(pid: i32, cpusetsize: usize, mask: *const u64) -> i32;
    }
    // pid 0 = the calling thread. std already links libc; no crate needed.
    unsafe { sched_setaffinity(0, std::mem::size_of_val(&mask), mask.as_ptr()) == 0 }
}

#[cfg(not(target_os = "linux"))]
fn pin_current_thread(_core: usize) -> bool {
    false
}

/// Run every block yielded by `produce` (called on *this* thread until it
/// returns `None`) through `work` on `opts.threads` scoped worker threads,
/// giving each worker a private state built by `init(thread_index)` —
/// the hook the engines use for thread-local scratch [`crate::util::alloc::BufferPool`]s.
/// Returns the pool's observability counters plus every worker's final
/// state (in thread-index order) so per-thread pool stats can be folded
/// into run counters.
///
/// Worker panics propagate to the caller with their original payload, so
/// mapper contract violations (e.g. a dense key outside the target range)
/// fail the same way they do on the simulated engines.
pub fn execute_with<T, S, P, Init, W>(
    opts: PoolOptions,
    mut produce: P,
    init: Init,
    work: W,
) -> (PoolStats, Vec<S>)
where
    T: Send,
    S: Send,
    P: FnMut() -> Option<T>,
    Init: Fn(usize) -> S + Sync,
    W: Fn(&mut S, T) + Sync,
{
    let threads = opts.threads.max(1);
    let queue = BlockQueue::bounded(opts.queue_cap);
    let start = Instant::now();
    let busy = AtomicU64::new(0);
    let pinned = AtomicU64::new(0);
    let samples = Mutex::new(Vec::new());
    let (mut stats, states) = std::thread::scope(|s| {
        let queue = &queue;
        let busy = &busy;
        let pinned = &pinned;
        let samples = &samples;
        let init = &init;
        let work = &work;
        let handles: Vec<_> = (0..threads)
            .map(|i| {
                s.spawn(move || {
                    let _guard = AbortOnDrop { queue };
                    if opts.pin_threads && pin_current_thread(i) {
                        pinned.fetch_add(1, Ordering::Relaxed);
                    }
                    let mut state = init(i);
                    let mut blocks = 0u64;
                    while let Some(block) = queue.pop() {
                        let now_busy = busy.fetch_add(1, Ordering::Relaxed) + 1;
                        if blocks % SAMPLE_EVERY == 0 {
                            samples.lock().expect("pool samples poisoned").push(PoolSample {
                                wall_ns: start.elapsed().as_nanos() as u64,
                                queue_depth: queue.depth() as u64,
                                busy_threads: now_busy,
                            });
                        }
                        work(&mut state, block);
                        busy.fetch_sub(1, Ordering::Relaxed);
                        blocks += 1;
                    }
                    (blocks, state)
                })
            })
            .collect();
        {
            // Guard the feeder as well: if `produce` panics, the queue
            // still closes so workers drain out and the scope can join
            // them before propagating the panic.
            let _feed_guard = CloseOnDrop { queue };
            while let Some(block) = produce() {
                if !queue.push(block) {
                    break; // a worker died; fall through to the joins below
                }
            }
        }
        let mut per_thread_blocks = Vec::with_capacity(handles.len());
        let mut states = Vec::with_capacity(handles.len());
        for h in handles {
            match h.join() {
                Ok((blocks, state)) => {
                    per_thread_blocks.push(blocks);
                    states.push(state);
                }
                Err(payload) => std::panic::resume_unwind(payload),
            }
        }
        let stats = PoolStats {
            queue_peak: queue.peak() as u64,
            per_thread_blocks,
            samples: Vec::new(),
            pinned_threads: pinned.load(Ordering::Relaxed),
        };
        (stats, states)
    });
    // Scoped borrows end with the scope; only then can the sample vec
    // move out of its mutex.
    stats.samples = samples.into_inner().expect("pool samples poisoned");
    (stats, states)
}

/// Stateless convenience wrapper over [`execute_with`]: no per-thread
/// state, no pinning.
pub fn execute<T, P, W>(threads: usize, queue_cap: usize, produce: P, work: W) -> PoolStats
where
    T: Send,
    P: FnMut() -> Option<T>,
    W: Fn(T) + Sync,
{
    let (stats, _) = execute_with(
        PoolOptions { threads, queue_cap, pin_threads: false },
        produce,
        |_| (),
        |_: &mut (), block| work(block),
    );
    stats
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};

    #[test]
    fn all_blocks_processed_exactly_once() {
        let sum = AtomicU64::new(0);
        let mut next = 0u64;
        let stats = execute(
            4,
            2,
            || {
                if next < 1000 {
                    next += 1;
                    Some(next)
                } else {
                    None
                }
            },
            |v| {
                sum.fetch_add(v, Ordering::Relaxed);
            },
        );
        assert_eq!(sum.load(Ordering::Relaxed), 1000 * 1001 / 2);
        assert_eq!(stats.per_thread_blocks.len(), 4);
        assert_eq!(stats.per_thread_blocks.iter().sum::<u64>(), 1000);
        assert!(stats.queue_peak >= 1 && stats.queue_peak <= 2);
        // One occupancy snapshot per SAMPLE_EVERY stolen blocks per
        // thread: Σ ceil(b_t / 8) over 4 threads with Σ b_t = 1000 lies
        // in [125, 128].
        assert!(
            stats.samples.len() >= 125 && stats.samples.len() <= 128,
            "got {} samples",
            stats.samples.len()
        );
        assert!(stats.samples.iter().all(|s| s.queue_depth <= 2));
        assert!(stats.samples.iter().all(|s| s.busy_threads >= 1 && s.busy_threads <= 4));
        assert_eq!(stats.pinned_threads, 0, "pinning is opt-in");
    }

    #[test]
    fn zero_blocks_yields_no_samples() {
        let stats = execute(2, 1, || None::<u64>, |_| {});
        assert!(stats.samples.is_empty());
        assert_eq!(stats.queue_peak, 0);
    }

    #[test]
    fn zero_blocks_is_fine() {
        execute(3, 1, || None::<u64>, |_| panic!("no work expected"));
    }

    #[test]
    fn single_thread_still_drains() {
        let sum = AtomicU64::new(0);
        let mut it = (1..=10u64).collect::<Vec<_>>().into_iter();
        execute(1, 1, || it.next(), |v| {
            sum.fetch_add(v, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 55);
    }

    #[test]
    fn pinned_run_completes_and_counts() {
        let sum = AtomicU64::new(0);
        let mut next = 0u64;
        let (stats, states) = execute_with(
            PoolOptions { threads: 4, queue_cap: 2, pin_threads: true },
            || {
                if next < 200 {
                    next += 1;
                    Some(next)
                } else {
                    None
                }
            },
            |i| (i, 0u64),
            |state: &mut (usize, u64), v| {
                state.1 += v;
                sum.fetch_add(v, Ordering::Relaxed);
            },
        );
        assert_eq!(sum.load(Ordering::Relaxed), 200 * 201 / 2);
        // Per-thread states come back in thread-index order and their
        // private sums add up to the total.
        assert_eq!(states.len(), 4);
        for (i, (idx, _)) in states.iter().enumerate() {
            assert_eq!(*idx, i);
        }
        assert_eq!(states.iter().map(|(_, s)| s).sum::<u64>(), 200 * 201 / 2);
        // Pinning is best-effort: bounded above by the thread count.
        assert!(stats.pinned_threads <= 4);
    }

    #[test]
    #[should_panic(expected = "worker exploded")]
    fn worker_panic_propagates_with_payload_and_unblocks_feeder() {
        // More blocks than queue capacity: without the close-on-unwind
        // guard the feeder would deadlock on the full queue.
        let mut next = 0u64;
        execute(
            2,
            1,
            || {
                next += 1;
                (next <= 100).then_some(next)
            },
            |v| {
                if v == 3 {
                    panic!("worker exploded");
                }
            },
        );
    }

    #[test]
    fn closed_queue_rejects_push_and_drains_pop() {
        let q = BlockQueue::bounded(4);
        assert!(q.push(1u64));
        assert!(q.push(2));
        q.close();
        assert!(!q.push(3));
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn aborted_queue_discards_queued_blocks() {
        let q = BlockQueue::bounded(4);
        assert!(q.push(1u64));
        assert!(q.push(2));
        q.abort();
        assert!(!q.push(3));
        assert_eq!(q.pop(), None, "abort discards, close drains");
        assert_eq!(q.depth(), 0);
        // Idempotent, and harmless after the queue is already empty.
        q.abort();
        assert_eq!(q.pop(), None);
    }

    #[test]
    #[should_panic(expected = "worker exploded")]
    fn worker_panic_aborts_queued_blocks() {
        // One worker: after it dies on block 1, the queued blocks must be
        // discarded, not executed — an executed block would trip the
        // second panic branch and change the payload.
        let mut next = 0u64;
        execute(
            1,
            4,
            || {
                next += 1;
                (next <= 100).then_some(next)
            },
            |v| {
                if v == 1 {
                    panic!("worker exploded");
                }
                panic!("queued block ran after abort");
            },
        );
    }
}
