//! Real threaded execution backend (`Backend::Threaded(n)`).
//!
//! Everything else in this reproduction *models* workers: engines walk
//! blocks serially and charge virtual time. This subsystem is the first
//! that **executes** — one virtual node's map+combine runs on actual OS
//! threads, validating that the paper's §2.3.1 design (eager reduction
//! into bounded per-worker caches + a machine-local combine) is
//! implementable at hardware speed, not just accountable.
//!
//! * [`pool`] — scoped worker pool: a bounded work-stealing block queue
//!   fed by the engine's single cursor walk; idle threads steal whole
//!   blocks (a block is never split, preserving per-worker item order and
//!   RNG streams).
//! * [`cache`] — bounded per-thread eager-reduction caches with the exact
//!   flush semantics of the simulated eager engine.
//! * [`shard`] — the lock-striped sharded machine-local map. Flushes only
//!   *append* order-tagged partials (no reduction under a lock), and the
//!   single-threaded canonical merge folds each key's partials in
//!   simulated-engine order — confluence by construction, so results are
//!   byte-identical at any thread count, floats included.
//! * [`transport`] — the real in-process shuffle transport: per-node
//!   bounded channels carrying actual `fastser` frames, with a
//!   deterministic window-accounting mirror so flows/stalls/delivery
//!   order stay byte-identical to the simulated shuffle while `wall_ns`
//!   and queue peaks become measured quantities.
//! * [`engine`] — the hybrid engine: threaded map+combine, then the same
//!   partition/serialize/shuffle/absorb pipeline as the simulated
//!   engines, with the bytes physically moved through [`transport`]
//!   channels (virtual time still comes from the calibrated flow model).
//!   Real per-phase wall clock lands in `RunStats::phase_wall_ns`; the
//!   virtual makespan stays the modeled figure (see DESIGN.md
//!   §Execution backends and §Transport for when each number is
//!   comparable to the paper's).
//!
//! Select with `ClusterConfig::backend`, CLI `--backend threaded:N`, or
//! the `BLAZE_BACKEND` environment variable (used by the CI matrix leg
//! that runs the whole suite threaded). Fault-enabled jobs replay
//! blocks on the live pool too (`fault::engine` drives [`pool`] when
//! the backend is threaded). Gated by `rust/tests/equivalence.rs`
//! (threaded{1,2,4} eager + small-key paths vs the simulated reference,
//! single-stage and chained/iterative, plus fault rows), the
//! `rust/tests/exec.rs` stress suite (hostile key skew, flush storms,
//! 1/2/4 threads), and the `rust/tests/transport.rs` transport stress
//! suite (stall storms, skewed fan-in, capacity-1 windows).

pub mod cache;
pub mod engine;
pub mod pool;
pub mod shard;
pub mod transport;
