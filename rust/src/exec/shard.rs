//! Lock-striped sharded machine-local map with a canonical merge order.
//!
//! The threaded backend's answer to the determinism problem: worker
//! threads flush locally-reduced partials concurrently, but **reducers
//! never run under a stripe lock**. A flush only *appends* each pair to
//! its key's partial list, tagged with the batch's canonical position
//! ([`partial_order`]) — appends to disjoint keys commute, and appends to
//! the same key carry their order with them. The single-threaded
//! [`ShardedMap::into_canonical`] drain then sorts each key's partials by
//! that order and folds them with the reducer, reproducing byte-for-byte
//! the application order of the simulated eager engine (every worker's
//! overflow flushes in worker-then-sequence order, then every worker's
//! final cache in worker order). Confluence by construction, not by luck —
//! bit-identical even for non-associative float reductions.

use std::hash::Hash;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard, TryLockError};

use crate::mapreduce::reducers::Reducer;
use crate::util::hash::{fxhash, hash_batch_by, FxHashMap};

/// Upper bound on stripe count — past this, stripe headers outgrow any
/// realistic contention win.
pub const MAX_STRIPES: usize = 256;

/// One run's stripe-lock observations, fed back into the next run's
/// [`stripe_count`] decision. Scheduling-dependent (observability-grade
/// numbers), which is fine: stripe count only changes *where* pairs park
/// between flush and drain, never the canonical fold order, so any
/// feedback value yields byte-identical results.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StripeFeedback {
    /// Stripe count the observed run used.
    pub stripes: usize,
    /// Total `shard.locks` across nodes.
    pub locks: u64,
    /// Total `shard.contended` across nodes.
    pub contended: u64,
}

/// Stripe count for a run: per-core sizing from the thread count, nudged
/// by the previous run's observed contention when available.
///
/// Cold start is `threads × 4` (rounded up to a power of two): enough
/// slack that two workers flushing hash-adjacent keys usually land on
/// different locks. With feedback, ≥ 2% contended acquisitions doubles
/// the count, zero contention sheds stripes back toward the
/// `threads`-sized floor, and anything in between keeps the observed
/// count. Always a power of two in `[threads.next_power_of_two(),
/// MAX_STRIPES]`.
pub fn stripe_count(threads: usize, feedback: Option<StripeFeedback>) -> usize {
    let threads = threads.max(1);
    let base = (threads * 4).next_power_of_two().min(MAX_STRIPES);
    let Some(fb) = feedback else { return base };
    let floor = threads.next_power_of_two().min(MAX_STRIPES);
    let stripes = fb.stripes.next_power_of_two().clamp(floor, MAX_STRIPES);
    if fb.locks > 0 && fb.contended * 50 >= fb.locks {
        (stripes * 2).min(MAX_STRIPES)
    } else if fb.contended == 0 && stripes > floor {
        stripes / 2
    } else {
        stripes
    }
}

/// Canonical order key for one locally-reduced partial.
///
/// Matches the simulated eager engine, where workers run in index order:
/// every overflow flush lands in the node-local map before any worker's
/// final cache merges, flushes sort by `(worker, seq)`, finals by
/// `worker`. Orders are unique per key — a key appears at most once per
/// drained batch, and every batch has a distinct `(final, worker, seq)`.
#[inline]
pub fn partial_order(final_drain: bool, worker: usize, seq: u32) -> u64 {
    assert!(worker < (1 << 31), "worker id overflows the order key");
    ((final_drain as u64) << 63) | ((worker as u64) << 32) | u64::from(seq)
}

/// Machine-local reduce map for one virtual node, striped over `S`
/// mutexes so concurrent flushes from different workers rarely contend.
pub struct ShardedMap<K, V> {
    stripes: Vec<Mutex<FxHashMap<K, Vec<(u64, V)>>>>,
    mask: usize,
    /// Stripe lock acquisitions on the absorb path (observability).
    locks: AtomicU64,
    /// Acquisitions that found the stripe held and had to block.
    contended: AtomicU64,
}

impl<K: Hash + Eq, V> ShardedMap<K, V> {
    /// Map with `stripes` lock stripes (rounded up to a power of two).
    pub fn new(stripes: usize) -> Self {
        let n = stripes.next_power_of_two().max(1);
        Self {
            stripes: (0..n).map(|_| Mutex::new(FxHashMap::default())).collect(),
            mask: n - 1,
            locks: AtomicU64::new(0),
            contended: AtomicU64::new(0),
        }
    }

    /// `(lock acquisitions, contended acquisitions)` on the absorb path —
    /// the `shard.locks` / `shard.contended` run counters. Scheduling-
    /// dependent: observability only, never part of a determinism gate.
    pub fn contention(&self) -> (u64, u64) {
        (self.locks.load(Ordering::Relaxed), self.contended.load(Ordering::Relaxed))
    }

    /// Lock one stripe, counting the acquisition and whether it contended.
    fn lock_stripe(&self, s: usize) -> MutexGuard<'_, FxHashMap<K, Vec<(u64, V)>>> {
        self.locks.fetch_add(1, Ordering::Relaxed);
        match self.stripes[s].try_lock() {
            Ok(g) => g,
            Err(TryLockError::WouldBlock) => {
                self.contended.fetch_add(1, Ordering::Relaxed);
                self.stripes[s].lock().expect("shard stripe poisoned")
            }
            Err(TryLockError::Poisoned(_)) => panic!("shard stripe poisoned"),
        }
    }

    /// Absorb one flush batch: sort the pairs by stripe so each touched
    /// stripe locks exactly once, then append. No reduction happens here,
    /// so the outcome is independent of flush interleaving. (The unstable
    /// sort cannot reorder anything observable: a key appears at most
    /// once per batch and every pair carries the same `order` tag.)
    ///
    /// Convenience form: hashes the keys itself (batched). The threaded
    /// engines call [`ShardedMap::absorb_prehashed`] instead, reusing the
    /// hash lane computed once per flush batch at cache-drain time.
    pub fn absorb(&self, order: u64, mut pairs: Vec<(K, V)>) {
        if pairs.len() <= 1 {
            // Tiny-batch fast path: one hash, one lock, no scratch.
            let Some((k, v)) = pairs.pop() else { return };
            let s = (fxhash(&k) as usize) & self.mask;
            let mut stripe = self.lock_stripe(s);
            stripe.entry(k).or_default().push((order, v));
            return;
        }
        let mut hashes = Vec::new();
        hash_batch_by(&pairs, |p| &p.0, &mut hashes);
        self.absorb_prehashed(order, &mut pairs, &hashes);
    }

    /// [`ShardedMap::absorb`] with the key hashes already computed —
    /// `hashes[i]` must equal `fxhash(&pairs[i].0)`. Drains `pairs`
    /// (leaving its capacity intact so the caller can recycle the buffer
    /// through its scratch pool). Stripe selection is `hash & mask`,
    /// identical to the scalar path.
    pub fn absorb_prehashed(&self, order: u64, pairs: &mut Vec<(K, V)>, hashes: &[u64]) {
        debug_assert_eq!(pairs.len(), hashes.len());
        // Fast path for the flush-storm shape (tiny caches drain one pair
        // per emit): one lock, no scratch allocation.
        if pairs.len() <= 1 {
            let Some((k, v)) = pairs.pop() else { return };
            let s = (hashes[0] as usize) & self.mask;
            let mut stripe = self.lock_stripe(s);
            stripe.entry(k).or_default().push((order, v));
            return;
        }
        let mut tagged: Vec<(usize, K, V)> = pairs
            .drain(..)
            .zip(hashes)
            .map(|((k, v), h)| ((*h as usize) & self.mask, k, v))
            .collect();
        tagged.sort_unstable_by_key(|t| t.0);
        let mut it = tagged.into_iter().peekable();
        while let Some((s, k, v)) = it.next() {
            let mut stripe = self.lock_stripe(s);
            stripe.entry(k).or_default().push((order, v));
            while it.peek().is_some_and(|t| t.0 == s) {
                let (_, k, v) = it.next().expect("peeked same-stripe pair");
                stripe.entry(k).or_default().push((order, v));
            }
        }
    }

    /// Total distinct keys across stripes (diagnostics/tests).
    pub fn len(&self) -> usize {
        self.stripes
            .iter()
            .map(|s| s.lock().expect("shard stripe poisoned").len())
            .sum()
    }

    /// True when no key holds any partial.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Drain into the node-local reduced map: per key, sort partials by
    /// canonical order and fold front-to-back (first partial is the
    /// initial value, like the simulated engine's vacant insert).
    /// Single-threaded and deterministic regardless of how flushes
    /// interleaved.
    pub fn into_canonical(self, red: &Reducer<V>) -> FxHashMap<K, V> {
        let mut out = FxHashMap::default();
        for stripe in self.stripes {
            let stripe = stripe.into_inner().expect("shard stripe poisoned");
            for (k, mut partials) in stripe {
                partials.sort_unstable_by_key(|&(order, _)| order);
                let mut it = partials.into_iter();
                let (_, mut acc) = it.next().expect("partial lists are never empty");
                for (_, v) in it {
                    red.apply(&mut acc, &v);
                }
                out.insert(k, acc);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn order_key_sorts_flushes_before_finals() {
        let mut keys = vec![
            partial_order(true, 0, 0),
            partial_order(false, 1, 0),
            partial_order(false, 0, 2),
            partial_order(true, 1, 0),
            partial_order(false, 0, 0),
        ];
        keys.sort_unstable();
        assert_eq!(
            keys,
            vec![
                partial_order(false, 0, 0),
                partial_order(false, 0, 2),
                partial_order(false, 1, 0),
                partial_order(true, 0, 0),
                partial_order(true, 1, 0),
            ]
        );
    }

    #[test]
    fn canonical_fold_is_insertion_order_independent() {
        // Non-associative floats: the fold order must come from the order
        // keys, not from absorb order.
        let batches: Vec<(u64, Vec<(u64, f64)>)> = vec![
            (partial_order(false, 0, 0), vec![(7, 0.1), (8, 1.0)]),
            (partial_order(false, 1, 0), vec![(7, 0.2)]),
            (partial_order(true, 0, 0), vec![(7, 0.3), (8, 2.0)]),
            (partial_order(true, 1, 0), vec![(7, 1e-17)]),
        ];
        let red = Reducer::sum();
        let oracle = ((0.1f64 + 0.2) + 0.3) + 1e-17;

        // Absorb in canonical order and in reverse: identical bits.
        for reversed in [false, true] {
            let map: ShardedMap<u64, f64> = ShardedMap::new(8);
            let mut order: Vec<usize> = (0..batches.len()).collect();
            if reversed {
                order.reverse();
            }
            for i in order {
                map.absorb(batches[i].0, batches[i].1.clone());
            }
            let merged = map.into_canonical(&red);
            assert_eq!(merged[&7].to_bits(), oracle.to_bits());
            assert_eq!(merged[&8].to_bits(), 3.0f64.to_bits());
        }
    }

    #[test]
    fn concurrent_flushes_fold_canonically() {
        // 4 threads racing per-worker flush streams at one hot key; the
        // canonical fold must equal the serial worker-order oracle.
        let map: ShardedMap<u64, f64> = ShardedMap::new(4);
        let red = Reducer::sum();
        std::thread::scope(|s| {
            for w in 0..4usize {
                let map = &map;
                s.spawn(move || {
                    for seq in 0..50u32 {
                        let v = (w as f64 + 1.0) / f64::from(seq + 1);
                        map.absorb(partial_order(false, w, seq), vec![(42, v)]);
                    }
                    map.absorb(partial_order(true, w, 0), vec![(42, 0.125 * w as f64)]);
                });
            }
        });
        let mut oracle = f64::NAN;
        let mut first = true;
        for w in 0..4usize {
            for seq in 0..50u32 {
                let v = (w as f64 + 1.0) / f64::from(seq + 1);
                if first {
                    oracle = v;
                    first = false;
                } else {
                    oracle += v;
                }
            }
        }
        for w in 0..4usize {
            oracle += 0.125 * w as f64;
        }
        let merged = map.into_canonical(&red);
        assert_eq!(merged.len(), 1);
        assert_eq!(merged[&42].to_bits(), oracle.to_bits());
    }

    #[test]
    fn contention_counts_absorb_locks() {
        let map: ShardedMap<u64, u64> = ShardedMap::new(2);
        map.absorb(partial_order(false, 0, 0), vec![(1, 1)]);
        map.absorb(partial_order(false, 0, 1), vec![(2, 2), (3, 3)]);
        let (locks, contended) = map.contention();
        // Single-threaded: every acquisition succeeds uncontended. The
        // two-pair batch may touch one or two stripes.
        assert!(locks >= 2 && locks <= 3, "locks = {locks}");
        assert_eq!(contended, 0);
    }

    #[test]
    fn empty_batches_and_len() {
        let map: ShardedMap<u64, u64> = ShardedMap::new(2);
        assert!(map.is_empty());
        map.absorb(partial_order(false, 0, 0), Vec::new());
        assert!(map.is_empty());
        map.absorb(partial_order(true, 0, 0), vec![(1, 1), (2, 2)]);
        assert_eq!(map.len(), 2);
    }

    #[test]
    fn prehashed_matches_self_hashing_absorb() {
        let red = Reducer::sum();
        let pairs: Vec<(u64, f64)> =
            (0..23).map(|k| (k % 7, 0.1 * k as f64 + 1e-17)).collect();
        // Make per-batch keys unique (a key appears at most once per
        // batch) by splitting into 7-key batches.
        let batches: Vec<Vec<(u64, f64)>> =
            pairs.chunks(7).map(|c| c.to_vec()).collect();

        let plain: ShardedMap<u64, f64> = ShardedMap::new(4);
        for (i, b) in batches.iter().enumerate() {
            plain.absorb(partial_order(false, 0, i as u32), b.clone());
        }
        let pre: ShardedMap<u64, f64> = ShardedMap::new(4);
        for (i, b) in batches.iter().enumerate() {
            let mut buf = b.clone();
            let mut hashes = Vec::new();
            crate::util::hash::hash_batch_by(&buf, |p| &p.0, &mut hashes);
            pre.absorb_prehashed(partial_order(false, 0, i as u32), &mut buf, &hashes);
            assert!(buf.is_empty(), "prehashed absorb drains the pair buffer");
            assert!(buf.capacity() > 0, "capacity survives for recycling");
        }
        let a = plain.into_canonical(&red);
        let b = pre.into_canonical(&red);
        assert_eq!(a.len(), b.len());
        for (k, v) in &a {
            assert_eq!(v.to_bits(), b[k].to_bits(), "key {k}");
        }
    }

    #[test]
    fn stripe_count_cold_start_scales_with_threads() {
        assert_eq!(stripe_count(1, None), 4);
        assert_eq!(stripe_count(2, None), 8);
        assert_eq!(stripe_count(4, None), 16);
        assert_eq!(stripe_count(8, None), 32);
        assert_eq!(stripe_count(128, None), MAX_STRIPES);
        assert_eq!(stripe_count(0, None), 4, "clamped to one thread");
    }

    #[test]
    fn stripe_count_feedback_grows_and_sheds() {
        let fb = |stripes, locks, contended| StripeFeedback { stripes, locks, contended };
        // ≥2% contention doubles…
        assert_eq!(stripe_count(4, Some(fb(16, 1000, 20))), 32);
        // …but never past the cap…
        assert_eq!(stripe_count(4, Some(fb(MAX_STRIPES, 1000, 500))), MAX_STRIPES);
        // …zero contention sheds toward the per-thread floor…
        assert_eq!(stripe_count(4, Some(fb(32, 1000, 0))), 16);
        assert_eq!(stripe_count(4, Some(fb(4, 1000, 0))), 4, "floor holds");
        // …mild contention keeps the observed count…
        assert_eq!(stripe_count(4, Some(fb(16, 1000, 5))), 16);
        // …and a zero-lock run (empty input) counts as uncontended.
        assert_eq!(stripe_count(4, Some(fb(16, 0, 0))), 8);
    }
}
