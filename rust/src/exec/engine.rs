//! The threaded execution engine: `Backend::Threaded(n)`.
//!
//! Hybrid execution — one virtual node's map+combine runs *for real* on
//! `n` OS threads and shuffle frames physically move through the
//! bounded-channel transport ([`super::transport`]), while virtual time
//! still comes from the calibrated flow model:
//!
//! 1. The calling thread drains each node's
//!    [`DistInput::block_cursor`] once, materializing every virtual
//!    worker's block as an owned `Vec<(K, V)>` (the `Send` handoff — the
//!    only clone the backend adds), and feeds the blocks into the
//!    work-stealing queue ([`super::pool`]).
//! 2. Worker threads execute blocks: publish the block's worker RNG
//!    stream, run the mapper, and eagerly reduce into a bounded per-thread
//!    cache ([`super::cache::EagerCache`]) whose overflow flushes land in
//!    the node's lock-striped shard map ([`super::shard::ShardedMap`]).
//! 3. The canonical merge folds each key's partials in simulated-engine
//!    order, and from there the *same* partition/serialize/shuffle/absorb
//!    code as the simulated engines runs ([`eager::shuffle_and_absorb`],
//!    [`smallkey::tree_reduce_into_target`]).
//!
//! Determinism: block boundaries, RNG streams, cache capacity, flush
//! policy, and per-key reducer application order are all identical to the
//! simulated engines, so results are byte-identical at any thread count —
//! including non-associative float reductions (gated by
//! `rust/tests/equivalence.rs` and `rust/tests/exec.rs`).
//!
//! Accounting is hybrid: virtual time is still charged from measured
//! per-block seconds (summed per node, i.e. the serial-equivalent work),
//! while the real parallel wall clock of each phase is recorded in
//! [`RunStats::phase_wall_ns`] and the real shuffle movement in the
//! `transport.*` counter family (frames, bytes, stalls, queue peak).
//! Fault-tolerant jobs replay killed blocks on the live pool
//! ([`crate::fault::engine`] drives [`super::pool`]); the conventional
//! engine models a baseline and is never threaded.

use std::hash::Hash;
use std::sync::Mutex;
use std::time::Instant;

use crate::coordinator::metrics::RunStats;
use crate::coordinator::shuffle::Transport;
use crate::mapreduce::eager::{self, HASH_ENTRY_OVERHEAD};
use crate::mapreduce::reducers::Reducer;
use crate::mapreduce::smallkey;
use crate::mapreduce::{BlockCursor, DenseKey, DistInput, Emit, ReduceTarget, RunRecorder};
use crate::net::vtime::VirtualTime;
use crate::ser::fastser::FastSer;
use crate::trace::histogram::Histograms;
use crate::trace::{block_done_seq, map_seq, Counters, TraceBuf, TraceEvent, TraceEventKind};
use crate::util::alloc::BufferPool;
use crate::util::hash::FxHashMap;

use super::cache::{EagerCache, FlushScratch};
use super::pool::{self, PoolOptions};
use super::shard::{self, ShardedMap, StripeFeedback};
use super::transport::TransportTotals;

/// Per-pool-worker private scratch: typed buffer pools backing the
/// [`FlushScratch`] every block on this thread drains through. Thread-
/// local by construction ([`pool::execute_with`] builds one per worker),
/// mirroring TCMalloc's thread caches — no cross-thread synchronization
/// on the get/put path.
struct EagerWorkerState<K2, V2> {
    pairs: BufferPool<(K2, V2)>,
    hashes: BufferPool<u64>,
}

impl<K2, V2> EagerWorkerState<K2, V2> {
    fn new() -> Self {
        Self { pairs: BufferPool::new(), hashes: BufferPool::new() }
    }
}

/// One materialized map block: virtual worker `worker` of `node`'s
/// partition, with its items cloned out of the input for the `Send`
/// handoff.
struct BlockTask<K, V> {
    node: usize,
    worker: usize,
    items: Vec<(K, V)>,
}

/// Per-run accumulators shared by the pool workers (locked once per
/// block, not per item).
struct MapAcc {
    /// Serial-equivalent seconds per node: each block's wall time summed
    /// into its home node's bucket (feeds the virtual-time model).
    per_node_secs: Vec<f64>,
    emitted: u64,
    /// Largest single block cache high-water mark. At most `threads`
    /// caches are live at once, so `max × min(threads, blocks)` bounds
    /// the live cache bytes — comparable to the simulated engine's
    /// high-water accounting, unlike a sum over all blocks (which would
    /// overstate peak memory by the block count).
    max_cache_peak_bytes: u64,
    /// Per-node observability tallies (fold into [`Counters`] post-pool).
    per_node_items: Vec<u64>,
    per_node_emitted: Vec<u64>,
    per_node_flushes: Vec<u64>,
    per_node_flush_entries: Vec<u64>,
    per_node_cache_peak: Vec<u64>,
    /// Gated latency histograms. Recording order varies with scheduling,
    /// but histogram merge is commutative, so the folded result is
    /// byte-identical to the simulated engine's.
    hist: Histograms,
}

/// Feeder closure over every node's cursor: walks each partition exactly
/// once, yielding `workers` owned blocks per node — empty blocks
/// included, so every virtual worker exists at any thread count.
fn feed_blocks<I: DistInput>(
    input: &I,
    nodes: usize,
    workers: usize,
) -> impl FnMut() -> Option<BlockTask<I::K, I::V>> + '_
where
    I::K: Clone,
    I::V: Clone,
{
    let mut node = 0usize;
    let mut w = 0usize;
    let mut cur: Option<I::Cursor<'_>> = None;
    move || loop {
        if node >= nodes {
            return None;
        }
        if w >= workers {
            node += 1;
            w = 0;
            cur = None;
            continue;
        }
        let c = cur.get_or_insert_with(|| input.block_cursor(node, workers));
        let mut items = Vec::new();
        let advanced = c.next_block(|k, v| items.push((k.clone(), v.clone())));
        debug_assert!(advanced, "cursor yields one block per worker");
        let task = BlockTask { node, worker: w, items };
        w += 1;
        return Some(task);
    }
}

/// Threaded general path: eager reduction into per-thread caches, flushes
/// into the lock-striped node shard maps, canonical merge, then the
/// shared shuffle pipeline.
pub fn run_eager<I, F, K2, V2, T>(
    label: &str,
    input: &I,
    mapper: &F,
    red: &Reducer<V2>,
    target: &mut T,
    threads: usize,
) where
    I: DistInput,
    I::K: Clone + Send,
    I::V: Clone + Send,
    F: Fn(&I::K, &I::V, Emit<'_, K2, V2>) + Sync,
    K2: Hash + Eq + Clone + FastSer + Send,
    V2: Clone + FastSer + Send,
    T: ReduceTarget<K2, V2>,
{
    let rec = RunRecorder::new(label);
    let cluster = input.cluster().clone();
    let cfg = cluster.config().clone();
    let (nodes, workers) = (cfg.nodes, cfg.workers_per_node);
    let threads = threads.max(1);
    let cache_cap = cfg.thread_cache_entries.max(1);
    // Per-core stripe sizing, nudged by the previous run's observed
    // contention (recorded on the cluster below). Stripe count only moves
    // where pairs park between flush and drain — canonical merge order is
    // untouched, so results stay byte-identical at any count.
    let stripes = shard::stripe_count(threads, cluster.stripe_feedback());

    let mut vt = VirtualTime::new();

    // ---- Map + eager local reduce, on real threads ----------------------
    let t_map = Instant::now();
    let shard_maps: Vec<ShardedMap<K2, V2>> =
        (0..nodes).map(|_| ShardedMap::new(stripes)).collect();
    let acc = Mutex::new(MapAcc {
        per_node_secs: vec![0.0f64; nodes],
        emitted: 0,
        max_cache_peak_bytes: 0,
        per_node_items: vec![0; nodes],
        per_node_emitted: vec![0; nodes],
        per_node_flushes: vec![0; nodes],
        per_node_flush_entries: vec![0; nodes],
        per_node_cache_peak: vec![0; nodes],
        hist: Histograms::new(nodes),
    });
    // Worker-collected trace events: each carries a computed sort key
    // ([`map_seq`]/[`block_done_seq`]) so the canonical order is
    // independent of which OS thread finished first.
    let trace_on = cfg.trace;
    let worker_events: Mutex<Vec<TraceEvent>> = Mutex::new(Vec::new());
    let (pool_stats, worker_states) = {
        let work = |state: &mut EagerWorkerState<K2, V2>, task: BlockTask<I::K, I::V>| {
            let t0 = Instant::now();
            let block = task.node * workers + task.worker;
            let block_start_ns = t_map.elapsed().as_nanos() as u64;
            // The worker's random stream is keyed by its *virtual* worker
            // identity, not the OS thread — same streams as the simulated
            // engines no matter which thread steals the block.
            crate::util::random::set_stream(cfg.seed, (task.node * workers + task.worker) as u64);
            // Flush drains route through this thread's private pools
            // (plain allocations under `AllocMode::System`).
            let scratch = FlushScratch::new(cfg.alloc, &state.pairs, &state.hashes);
            let mut cache: EagerCache<K2, V2> = EagerCache::new(task.worker, cache_cap);
            let mut emitted = 0u64;
            let mut flushes = 0u32;
            let mut flush_entries = 0u64;
            let mut flush_sizes: Vec<u64> = Vec::new();
            let mut evs: Vec<TraceEvent> = Vec::new();
            let shard = &shard_maps[task.node];
            for (k, v) in &task.items {
                let mut emit = |k2: K2, v2: V2| {
                    emitted += 1;
                    if let Some(mut batch) = cache.reduce(k2, v2, red, &scratch) {
                        let entries = batch.pairs.len() as u64;
                        if trace_on {
                            let now = t_map.elapsed().as_nanos() as u64;
                            let mut e = TraceEvent::new(
                                task.node,
                                Some(task.worker),
                                "map+local-reduce",
                                TraceEventKind::CacheFlush { entries, bytes: batch.bytes },
                            )
                            .with_wall(now, now);
                            e.seq = map_seq(block, flushes);
                            evs.push(e);
                        }
                        flushes += 1;
                        flush_entries += entries;
                        flush_sizes.push(entries);
                        // Stripe selection reuses the hash lane computed
                        // at drain time; the emptied buffers recycle.
                        shard.absorb_prehashed(batch.order, &mut batch.pairs, &batch.hashes);
                        scratch.recycle(batch);
                    }
                };
                mapper(k, v, &mut emit);
            }
            let peak = cache.peak_bytes();
            let mut fin = cache.finish(&scratch);
            shard.absorb_prehashed(fin.order, &mut fin.pairs, &fin.hashes);
            scratch.recycle(fin);
            if trace_on {
                let mut e = TraceEvent::new(
                    task.node,
                    Some(task.worker),
                    "map+local-reduce",
                    TraceEventKind::MapBlock {
                        items: task.items.len() as u64,
                        emitted,
                        exec_node: task.node,
                        epoch: 1,
                    },
                )
                .with_wall(block_start_ns, t_map.elapsed().as_nanos() as u64);
                e.seq = block_done_seq(block);
                evs.push(e);
                worker_events.lock().expect("trace events poisoned").append(&mut evs);
            }
            let secs = t0.elapsed().as_secs_f64();
            let mut a = acc.lock().expect("map accumulator poisoned");
            a.per_node_secs[task.node] += secs;
            a.emitted += emitted;
            a.max_cache_peak_bytes = a.max_cache_peak_bytes.max(peak);
            a.per_node_items[task.node] += task.items.len() as u64;
            a.per_node_emitted[task.node] += emitted;
            a.per_node_flushes[task.node] += u64::from(flushes);
            a.per_node_flush_entries[task.node] += flush_entries;
            a.per_node_cache_peak[task.node] = a.per_node_cache_peak[task.node].max(peak);
            a.hist.record_node(task.node, "map.block_items", task.items.len() as u64);
            for entries in flush_sizes {
                a.hist.record_node(task.node, "cache.flush_entries", entries);
            }
        };
        pool::execute_with(
            PoolOptions { threads, queue_cap: threads * 2, pin_threads: cfg.pin_threads },
            feed_blocks(input, nodes, workers),
            |_| EagerWorkerState::new(),
            work,
        )
    };
    let map_wall_ns = t_map.elapsed().as_nanos() as u64;
    let MapAcc {
        mut per_node_secs,
        emitted: pairs_emitted,
        max_cache_peak_bytes,
        per_node_items,
        per_node_emitted,
        per_node_flushes,
        per_node_flush_entries,
        per_node_cache_peak,
        mut hist,
    } = acc.into_inner().expect("map accumulator poisoned");
    let mut trace = TraceBuf::new(trace_on);
    trace.extend_keyed(worker_events.into_inner().expect("trace events poisoned"));
    trace.seal_map(nodes * workers);
    // Pool occupancy time-series: Chrome counter tracks, never canonical.
    for s in &pool_stats.samples {
        trace.push_sample(0, "map+local-reduce", 0, "pool.queue_depth", s.queue_depth);
        trace.push_sample(0, "map+local-reduce", 0, "pool.busy_threads", s.busy_threads);
    }
    let mut counters = Counters::new(nodes);
    for node in 0..nodes {
        counters.add_node(node, "map.items", per_node_items[node]);
        counters.add_node(node, "map.emitted", per_node_emitted[node]);
        counters.add_node(node, "cache.flushes", per_node_flushes[node]);
        counters.add_node(node, "cache.flush_entries", per_node_flush_entries[node]);
        counters.max_node(node, "cache.peak_bytes", per_node_cache_peak[node]);
    }
    counters.max("pool.queue_peak", pool_stats.queue_peak);
    counters.add("pool.pinned_threads", pool_stats.pinned_threads);
    counters.add("shard.stripes", stripes as u64);
    for (t, blocks) in pool_stats.per_thread_blocks.iter().enumerate() {
        counters.add(&format!("pool.thread{t}.blocks"), *blocks);
    }
    // Thread-local scratch-pool traffic (zero under `AllocMode::System`):
    // the mechanism the blaze-vs-blaze-TCM ablation measures.
    let (mut pool_hits, mut pool_misses, mut pool_bytes) = (0u64, 0u64, 0u64);
    for st in &worker_states {
        let (h, m) = st.pairs.stats();
        pool_hits += h;
        pool_misses += m;
        let (h, m) = st.hashes.stats();
        pool_hits += h;
        pool_misses += m;
        pool_bytes += (st.pairs.pooled_bytes() + st.hashes.pooled_bytes()) as u64;
    }
    // Live worker caches are bounded by the pool width (see MapAcc docs).
    let live_cache_bytes = max_cache_peak_bytes * threads.min(nodes * workers) as u64;

    // ---- Canonical merge (restores simulated application order) ---------
    let t_merge = Instant::now();
    let mut node_maps: Vec<FxHashMap<K2, V2>> = Vec::with_capacity(nodes);
    let mut local_bytes = 0u64;
    let (mut total_locks, mut total_contended) = (0u64, 0u64);
    for (node, sm) in shard_maps.into_iter().enumerate() {
        let t0 = Instant::now();
        let (locks, contended) = sm.contention();
        total_locks += locks;
        total_contended += contended;
        counters.add_node(node, "shard.locks", locks);
        counters.add_node(node, "shard.contended", contended);
        let local = sm.into_canonical(red);
        // Node-local map bytes, same per-entry formula as the simulated
        // engine's accounting.
        local_bytes += local
            .iter()
            .map(|(k, v)| {
                HASH_ENTRY_OVERHEAD + k.encoded_len() as u64 + v.encoded_len() as u64
            })
            .sum::<u64>();
        node_maps.push(local);
        // The machine-local combine is node work: fold it into the node's
        // serial-equivalent budget.
        per_node_secs[node] += t0.elapsed().as_secs_f64();
    }
    let merge_wall_ns = t_merge.elapsed().as_nanos() as u64;
    vt.compute_phase("map+local-reduce", &per_node_secs, workers);
    // Feed this run's contention back into the next run's stripe sizing.
    cluster.note_stripe_feedback(StripeFeedback {
        stripes,
        locks: total_locks,
        contended: total_contended,
    });

    // ---- Shared shuffle pipeline, bytes moved through real channels -----
    // The cluster's byte pool backs serialization + transport scratch;
    // delta its cumulative stats around the phase to attribute this run's
    // traffic.
    let (cp_hits0, cp_misses0) = cluster.pool().stats();
    let out = eager::shuffle_and_absorb(
        &cluster,
        node_maps,
        red,
        target,
        &mut vt,
        &mut trace,
        &mut hist,
        Transport::Channels,
    );
    let (cp_hits1, cp_misses1) = cluster.pool().stats();
    counters.add("alloc.pool.hits", pool_hits + (cp_hits1 - cp_hits0));
    counters.add("alloc.pool.misses", pool_misses + (cp_misses1 - cp_misses0));
    counters.max(
        "alloc.pool.pooled_bytes",
        pool_bytes + cluster.pool().pooled_bytes() as u64,
    );

    // ---- Record ----------------------------------------------------------
    let mut phase_wall_ns = vec![
        ("map+local-reduce".into(), map_wall_ns),
        ("canonical-merge".into(), merge_wall_ns),
        ("shuffle+absorb".into(), out.wall_ns),
    ];
    if let Some(t) = out.transport {
        record_transport_counters(&mut counters, &mut phase_wall_ns, t);
    }
    trace.stamp_phases(&vt);
    cluster.trace().absorb_job(&rec.label, trace);
    let (run_counters, node_counters) = counters.finish();
    let compute_sec = vt.compute_sec();
    let makespan = vt.makespan();
    cluster.metrics().record_run(RunStats {
        label: rec.label,
        engine: "blaze".into(),
        backend: format!("threaded:{threads}"),
        nodes,
        workers_per_node: workers,
        makespan_sec: makespan,
        compute_sec,
        shuffle_sec: makespan - compute_sec,
        shuffle_bytes: out.shuffle_bytes,
        ser_bytes: out.shuffle_bytes,
        pairs_emitted,
        pairs_shuffled: out.pairs_shuffled,
        peak_intermediate_bytes: live_cache_bytes + local_bytes + out.peak_bytes,
        host_wall_sec: rec.started.elapsed().as_secs_f64(),
        phase_wall_ns,
        counters: run_counters,
        node_counters,
        histograms: hist.finish(),
        ..Default::default()
    });
}

/// Fold a phase's real-transport measurements into the `transport.*`
/// counter family plus a dedicated `phase_wall_ns` entry (entries with
/// the same name sum across phases — `RunStats::wall_ns` semantics).
fn record_transport_counters(
    counters: &mut Counters,
    phase_wall_ns: &mut Vec<(String, u64)>,
    t: TransportTotals,
) {
    counters.add("transport.frames", t.frames);
    counters.add("transport.bytes", t.bytes);
    counters.add("transport.stalls", t.stalls);
    counters.max("transport.queue_peak_bytes", t.queue_peak_bytes);
    if t.faulted {
        // Reliability cost, recorded only when a fault plan was active so
        // lossless runs keep their counter set unchanged.
        counters.add("transport.retries", t.retries);
        counters.add("transport.drops", t.drops);
        counters.add("transport.corrupt", t.corrupt);
        counters.add("transport.timeouts", t.timeouts);
        counters.add("transport.backoff_ns", t.backoff_ns);
    }
    phase_wall_ns.push(("transport".into(), t.wall_ns));
}

/// Threaded small-fixed-key-range path: per-block dense caches on real
/// threads, canonical per-node worker-order merge, then the shared
/// binomial tree reduce.
pub fn run_smallkey<I, F, K2, V2, T>(
    label: &str,
    input: &I,
    mapper: &F,
    red: &Reducer<V2>,
    target: &mut T,
    threads: usize,
) where
    I: DistInput,
    I::K: Clone + Send,
    I::V: Clone + Send,
    F: Fn(&I::K, &I::V, Emit<'_, K2, V2>) + Sync,
    K2: Hash + Eq + Clone + FastSer + DenseKey + Send,
    V2: Clone + FastSer + Send,
    T: ReduceTarget<K2, V2>,
{
    let rec = RunRecorder::new(label);
    let cluster = input.cluster().clone();
    let cfg = cluster.config().clone();
    let (nodes, workers) = (cfg.nodes, cfg.workers_per_node);
    let threads = threads.max(1);
    let range = target.dense_len().expect("smallkey path requires a dense target");

    let mut vt = VirtualTime::new();

    // ---- Map with per-block dense caches, on real threads ---------------
    // Each finished block merges into its node's accumulator *as soon as
    // worker order allows* (canonical order: worker 0, 1, …), under a
    // per-node lock. Retained memory is one accumulator per node plus
    // only the out-of-order caches still pending — not all
    // `nodes × workers` caches until a barrier.
    let t_map = Instant::now();
    struct NodeDense<V> {
        /// Next worker index the accumulator may merge (canonical order).
        next_worker: usize,
        /// Worker-order fold so far (`None` until worker 0 lands).
        acc: Option<Vec<Option<V>>>,
        /// Finished caches waiting for their worker-order turn.
        pending: std::collections::BTreeMap<usize, Vec<Option<V>>>,
    }
    struct DenseStats {
        per_node_secs: Vec<f64>,
        emitted: u64,
        per_node_items: Vec<u64>,
        per_node_emitted: Vec<u64>,
        /// Gated histograms (commutative merge — scheduling-invariant).
        hist: Histograms,
    }
    let dense: Vec<Mutex<NodeDense<V2>>> = (0..nodes)
        .map(|_| {
            Mutex::new(NodeDense {
                next_worker: 0,
                acc: None,
                pending: std::collections::BTreeMap::new(),
            })
        })
        .collect();
    let stats = Mutex::new(DenseStats {
        per_node_secs: vec![0.0f64; nodes],
        emitted: 0,
        per_node_items: vec![0; nodes],
        per_node_emitted: vec![0; nodes],
        hist: Histograms::new(nodes),
    });
    let trace_on = cfg.trace;
    let worker_events: Mutex<Vec<TraceEvent>> = Mutex::new(Vec::new());
    let pool_stats = {
        let work = |task: BlockTask<I::K, I::V>| {
            let t0 = Instant::now();
            let block = task.node * workers + task.worker;
            let block_start_ns = t_map.elapsed().as_nanos() as u64;
            crate::util::random::set_stream(cfg.seed, (task.node * workers + task.worker) as u64);
            let mut cache: Vec<Option<V2>> = vec![None; range];
            let mut emitted = 0u64;
            for (k, v) in &task.items {
                let mut emit = |k2: K2, v2: V2| {
                    emitted += 1;
                    smallkey::dense_reduce(&mut cache, range, &k2, v2, red);
                };
                mapper(k, v, &mut emit);
            }
            if trace_on {
                let mut e = TraceEvent::new(
                    task.node,
                    Some(task.worker),
                    "map+dense-local-reduce",
                    TraceEventKind::MapBlock {
                        items: task.items.len() as u64,
                        emitted,
                        exec_node: task.node,
                        epoch: 1,
                    },
                )
                .with_wall(block_start_ns, t_map.elapsed().as_nanos() as u64);
                e.seq = block_done_seq(block);
                worker_events.lock().expect("trace events poisoned").push(e);
            }
            // In-node combine, strictly in worker order (the simulated
            // engine's serial fold — byte-identity depends on it).
            {
                let mut guard = dense[task.node].lock().expect("dense node state poisoned");
                // Reborrow through the guard once so the field borrows
                // below are disjoint.
                let nd = &mut *guard;
                nd.pending.insert(task.worker, cache);
                while let Some(entry) = nd.pending.first_entry() {
                    if *entry.key() != nd.next_worker {
                        break;
                    }
                    let cache = entry.remove();
                    match nd.acc.as_mut() {
                        None => nd.acc = Some(cache),
                        Some(acc) => smallkey::merge_dense(acc, cache, red),
                    }
                    nd.next_worker += 1;
                }
            }
            let secs = t0.elapsed().as_secs_f64();
            let mut st = stats.lock().expect("dense stats poisoned");
            st.per_node_secs[task.node] += secs;
            st.emitted += emitted;
            st.per_node_items[task.node] += task.items.len() as u64;
            st.per_node_emitted[task.node] += emitted;
            st.hist.record_node(task.node, "map.block_items", task.items.len() as u64);
        };
        // Dense caches are consumed cross-thread under the node lock, so
        // they cannot round-trip through a creator thread's pool — the
        // smallkey path only opts into pinning here; its pooled scratch
        // lives in the tree-reduce phase (cluster byte pool).
        let (stats, _) = pool::execute_with(
            PoolOptions { threads, queue_cap: threads * 2, pin_threads: cfg.pin_threads },
            feed_blocks(input, nodes, workers),
            |_| (),
            |_: &mut (), task| work(task),
        );
        stats
    };
    let map_wall_ns = t_map.elapsed().as_nanos() as u64;
    let DenseStats {
        per_node_secs,
        emitted: pairs_emitted,
        per_node_items,
        per_node_emitted,
        mut hist,
    } = stats.into_inner().expect("dense stats poisoned");
    let mut trace = TraceBuf::new(trace_on);
    trace.extend_keyed(worker_events.into_inner().expect("trace events poisoned"));
    trace.seal_map(nodes * workers);
    // Pool occupancy time-series: Chrome counter tracks, never canonical.
    for s in &pool_stats.samples {
        trace.push_sample(0, "map+dense-local-reduce", 0, "pool.queue_depth", s.queue_depth);
        trace.push_sample(0, "map+dense-local-reduce", 0, "pool.busy_threads", s.busy_threads);
    }
    let mut counters = Counters::new(nodes);
    for node in 0..nodes {
        counters.add_node(node, "map.items", per_node_items[node]);
        counters.add_node(node, "map.emitted", per_node_emitted[node]);
    }
    counters.max("pool.queue_peak", pool_stats.queue_peak);
    counters.add("pool.pinned_threads", pool_stats.pinned_threads);
    for (t, blocks) in pool_stats.per_thread_blocks.iter().enumerate() {
        counters.add(&format!("pool.thread{t}.blocks"), *blocks);
    }

    // ---- Collect the per-node worker-order folds ------------------------
    let t_merge = Instant::now();
    let mut node_partials: Vec<Vec<Option<V2>>> = Vec::with_capacity(nodes);
    for (node, nd) in dense.into_iter().enumerate() {
        let nd = nd.into_inner().expect("dense node state poisoned");
        debug_assert!(nd.pending.is_empty(), "node {node} has unmerged caches");
        debug_assert_eq!(nd.next_worker, workers, "node {node} missing worker caches");
        node_partials.push(nd.acc.expect("at least one worker per node"));
    }
    let merge_wall_ns = t_merge.elapsed().as_nanos() as u64;
    vt.compute_phase("map+dense-local-reduce", &per_node_secs, workers);

    // ---- Shared binomial tree reduce, frames through real channels ------
    // Attribute this run's scratch-pool traffic (fastser frames +
    // transport chunks ride the cluster byte pool) by deltaing its
    // cumulative stats around the phase.
    let (cp_hits0, cp_misses0) = cluster.pool().stats();
    let out = smallkey::tree_reduce_into_target(
        &cluster,
        node_partials,
        red,
        target,
        &mut vt,
        &mut trace,
        &mut hist,
        Transport::Channels,
    );
    let (cp_hits1, cp_misses1) = cluster.pool().stats();
    counters.add("alloc.pool.hits", cp_hits1 - cp_hits0);
    counters.add("alloc.pool.misses", cp_misses1 - cp_misses0);
    counters.max("alloc.pool.pooled_bytes", cluster.pool().pooled_bytes() as u64);

    // ---- Record ----------------------------------------------------------
    let mut phase_wall_ns = vec![
        ("map+dense-local-reduce".into(), map_wall_ns),
        ("canonical-merge".into(), merge_wall_ns),
        ("tree-reduce".into(), out.wall_ns),
    ];
    if let Some(t) = out.transport {
        record_transport_counters(&mut counters, &mut phase_wall_ns, t);
    }
    trace.stamp_phases(&vt);
    cluster.trace().absorb_job(&rec.label, trace);
    let (run_counters, node_counters) = counters.finish();
    let compute_sec = vt.compute_sec();
    let makespan = vt.makespan();
    let (pairs_shuffled, dense_cache_bytes) = smallkey::dense_stats::<V2>(nodes, workers, range);
    cluster.metrics().record_run(RunStats {
        label: rec.label,
        engine: "blaze".into(),
        backend: format!("threaded:{threads}"),
        nodes,
        workers_per_node: workers,
        makespan_sec: makespan,
        compute_sec,
        shuffle_sec: makespan - compute_sec,
        shuffle_bytes: out.shuffle_bytes,
        ser_bytes: out.shuffle_bytes,
        pairs_emitted,
        pairs_shuffled,
        peak_intermediate_bytes: dense_cache_bytes + out.round_flow_peak,
        host_wall_sec: rec.started.elapsed().as_secs_f64(),
        phase_wall_ns,
        counters: run_counters,
        node_counters,
        histograms: hist.finish(),
        ..Default::default()
    });
}
