//! Real in-process shuffle transport for the threaded backend.
//!
//! [`coordinator::shuffle::execute`](crate::coordinator::shuffle::execute)
//! *models* a shuffle: payloads pass through [`NetSim`] mailboxes and the
//! cost comes out of the calibrated flow model. This module *moves* the
//! same frames: one bounded channel per destination node, one sender
//! thread per source node, real byte hand-off with real blocking when a
//! destination queue fills. The coordinator keeps a deterministic
//! accounting mirror of the exact `shuffle::execute` loop — same
//! [`FlowMatrix`] records per chunk, same per-sender [`WindowAccount`]
//! push/drain — so flows, `peak_in_flight_bytes`, and `stalls` are
//! byte-identical to the simulated shuffle at any thread count, while
//! `wall_ns` and `queue_peak_bytes` report what physically happened.
//!
//! Determinism contract:
//!
//! * **Delivery order** — frames land on receiver threads in scheduler
//!   order, but [`execute`] sorts each destination's frames by
//!   `(src, seq)` before returning and prepends node-local payloads, so
//!   `delivered` is element-for-element identical to
//!   `shuffle::execute`'s (src-ascending send loop, chunks in order,
//!   locals delivered inline). Downstream absorb code cannot tell the
//!   backends apart.
//! * **Stalls** — the `transport.stalls` counter uses the same
//!   [`WindowAccount`] semantics as the simulated window (a stall fires
//!   iff a chunk would overflow the window), so it is deterministic and
//!   testable (`transport_window_bytes = 1` forces a stall per frame).
//!   Physical waiting on a full channel is real but scheduling-dependent;
//!   it surfaces only in `wall_ns` and `queue_peak_bytes`, never in
//!   gated output.
//!
//! Channel capacity derives from the window: `window_bytes / CHUNK_BYTES`
//! frames, floor 1, so shrinking the window genuinely narrows the pipe.
//! Receivers always drain (a frame is admitted even when it alone
//! exceeds the window), so the transport cannot deadlock: senders block
//! only on a full queue that a live receiver is emptying.
//!
//! [`NetSim`]: crate::net::sim::NetSim

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::sync_channel;
use std::sync::Mutex;
use std::time::Instant;

use crate::coordinator::backpressure::WindowAccount;
use crate::coordinator::shuffle::{ShufflePayloads, CHUNK_BYTES};
use crate::net::sim::FlowMatrix;
use crate::trace::histogram::Histogram;
use crate::util::alloc::{AllocMode, BufferPool, Scratch};

/// Per-(src → dst) frame tallies, for `FrameSent`/`TransportStall`
/// trace events. Cross-node pairs with traffic only, src-major order.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PairStats {
    pub src: usize,
    pub dst: usize,
    /// Frames (chunks) shipped src → dst.
    pub frames: u64,
    /// Payload bytes shipped src → dst.
    pub bytes: u64,
    /// Window-accounting stalls charged to this pair.
    pub stalls: u64,
}

/// Scalar transport measurements the engines fold into the
/// `transport.*` counter family and `phase_wall_ns`. Additive: phases
/// (or tree-reduce rounds) accumulate with [`TransportTotals::merge`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TransportTotals {
    /// Cross-node frames physically moved (`transport.frames`).
    pub frames: u64,
    /// Cross-node payload bytes physically moved (`transport.bytes`).
    pub bytes: u64,
    /// Window-accounting stalls (`transport.stalls` — deterministic).
    pub stalls: u64,
    /// Peak bytes resident in destination queues
    /// (`transport.queue_peak_bytes` — measured).
    pub queue_peak_bytes: u64,
    /// Wall-clock nanoseconds spent in transport (measured).
    pub wall_ns: u64,
}

impl TransportTotals {
    /// Accumulate another phase/round: counts and wall time add, queue
    /// peak takes the max.
    pub fn merge(&mut self, other: TransportTotals) {
        self.frames += other.frames;
        self.bytes += other.bytes;
        self.stalls += other.stalls;
        self.queue_peak_bytes = self.queue_peak_bytes.max(other.queue_peak_bytes);
        self.wall_ns += other.wall_ns;
    }
}

/// Outcome of a real transport run. `flows` / `delivered` /
/// `peak_in_flight_bytes` / `stalls` are byte-identical to
/// [`crate::coordinator::shuffle::ShuffleResult`] for the same payload
/// matrix; the rest are transport-only measurements.
#[derive(Debug)]
pub struct TransportResult {
    /// Real byte/message flows (recorded per chunk, like the simulation).
    pub flows: FlowMatrix,
    /// Per-destination `(src, frame)` buffers in simulated delivery
    /// order: node-local payloads first, then cross-node frames by
    /// `(src, seq)`.
    pub delivered: Vec<Vec<(usize, Vec<u8>)>>,
    /// Peak in-flight serialized bytes summed over senders
    /// (window-accounting mirror).
    pub peak_in_flight_bytes: u64,
    /// Total sender stalls (window-accounting mirror — deterministic).
    pub stalls: u64,
    /// Cross-node frames physically moved through channels.
    pub frames: u64,
    /// Cross-node payload bytes physically moved through channels.
    pub bytes: u64,
    /// Peak bytes resident in destination queues (measured, not gated).
    pub queue_peak_bytes: u64,
    /// Wall-clock nanoseconds for the whole transport phase (measured).
    pub wall_ns: u64,
    /// Per-(src,dst) tallies for trace events.
    pub pair_stats: Vec<PairStats>,
    /// Window-occupancy gauge: `(src, in-flight bytes)` after every
    /// chunk push of the deterministic mirror, in the mirror's
    /// src-ascending loop order. Feeds the `transport.in_flight_bytes`
    /// Chrome counter track — deterministic, but Chrome-view only like
    /// the rest of the sample machinery.
    pub in_flight_samples: Vec<(usize, u64)>,
    /// Per-frame channel-send wait (wall ns), merged across sender
    /// threads. Surfaces as the `wall.transport.frame_wait_ns` histogram
    /// — measured time, observability only, never gated.
    pub frame_wait: Histogram,
}

impl TransportResult {
    /// The scalar totals for counters/phase accounting.
    pub fn totals(&self) -> TransportTotals {
        TransportTotals {
            frames: self.frames,
            bytes: self.bytes,
            stalls: self.stalls,
            queue_peak_bytes: self.queue_peak_bytes,
            wall_ns: self.wall_ns,
        }
    }
}

/// One frame in flight. `seq` increases along the source's
/// dst-ascending send loop, so sorting a destination's frames by
/// `(src, seq)` reconstructs the simulated arrival order.
struct Frame {
    src: usize,
    dst: usize,
    seq: u64,
    payload: Vec<u8>,
}

/// Execute a shuffle over real bounded channels. Drop-in for
/// [`crate::coordinator::shuffle::execute`]: identical `delivered` /
/// `flows` / `peak_in_flight_bytes` / `stalls`, plus real measurements.
///
/// Convenience form with system-allocated chunk buffers; the engines
/// call [`execute_pooled`] with the cluster's scratch so chunk copies
/// recycle.
pub fn execute(payloads: ShufflePayloads, window_bytes: u64) -> TransportResult {
    let pool = BufferPool::new();
    let scratch = Scratch::new(AllocMode::System, &pool);
    execute_pooled(payloads, window_bytes, &scratch)
}

/// [`execute`] with chunk-copy buffers drawn from `scratch`. Every
/// scratch operation happens on the *calling* thread (the deterministic
/// mirror loop runs before the sender/receiver threads spawn), so a
/// single-threaded [`BufferPool`] behind the scratch is safe; the chunk
/// buffers themselves travel through the channels and come back to the
/// caller inside `delivered`, where the absorb loops return them to the
/// same scratch.
pub fn execute_pooled(
    payloads: ShufflePayloads,
    window_bytes: u64,
    scratch: &Scratch<'_, u8>,
) -> TransportResult {
    let n = payloads.len();
    let start = Instant::now();

    // Split the matrix into node-local payloads (delivered inline, like
    // the simulation) and per-src cross-node frame lists, while running
    // the deterministic accounting mirror of `shuffle::execute`.
    let mut locals: Vec<Option<Vec<u8>>> = (0..n).map(|_| None).collect();
    let mut sends: Vec<Vec<Frame>> = (0..n).map(|_| Vec::new()).collect();
    let mut flows = FlowMatrix::new(n);
    let mut peak = 0u64;
    let mut stalls = 0u64;
    let mut frames_total = 0u64;
    let mut bytes_total = 0u64;
    let mut pair_stats: Vec<PairStats> = Vec::new();
    let mut in_flight_samples: Vec<(usize, u64)> = Vec::new();

    for (src, dsts) in payloads.into_iter().enumerate() {
        assert_eq!(dsts.len(), n, "payload matrix must be n x n");
        let mut window = WindowAccount::new(window_bytes);
        let mut seq = 0u64;
        for (dst, payload) in dsts.into_iter().enumerate() {
            if payload.is_empty() {
                continue;
            }
            if dst == src {
                locals[dst] = Some(payload);
                continue;
            }
            let stalls_before = window.stalls();
            let mut pair_frames = 0u64;
            let pair_bytes = payload.len() as u64;
            if payload.len() <= CHUNK_BYTES {
                window.push(pair_bytes);
                in_flight_samples.push((src, window.in_flight()));
                flows.record(src, dst, pair_bytes);
                sends[src].push(Frame { src, dst, seq, payload });
                seq += 1;
                pair_frames += 1;
                window.drain(pair_bytes);
            } else {
                for chunk in payload.chunks(CHUNK_BYTES) {
                    window.push(chunk.len() as u64);
                    in_flight_samples.push((src, window.in_flight()));
                    flows.record(src, dst, chunk.len() as u64);
                    let mut copy = scratch.get(chunk.len());
                    copy.extend_from_slice(chunk);
                    sends[src].push(Frame { src, dst, seq, payload: copy });
                    seq += 1;
                    pair_frames += 1;
                    window.drain(chunk.len() as u64);
                }
                // The chunked original served only as the copy source.
                scratch.put(payload);
            }
            frames_total += pair_frames;
            bytes_total += pair_bytes;
            pair_stats.push(PairStats {
                src,
                dst,
                frames: pair_frames,
                bytes: pair_bytes,
                stalls: window.stalls() - stalls_before,
            });
        }
        peak += window.peak_bytes();
        stalls += window.stalls();
    }

    // Physically move the cross-node frames: one bounded channel per
    // destination, one sender thread per source with traffic.
    let queue_peak = AtomicU64::new(0);
    let frame_wait_shared = Mutex::new(Histogram::new());
    let mut received: Vec<Vec<(usize, u64, Vec<u8>)>> = (0..n).map(|_| Vec::new()).collect();
    if frames_total > 0 {
        let cap = ((window_bytes as usize) / CHUNK_BYTES).max(1);
        let queued = AtomicU64::new(0);
        let mut txs = Vec::with_capacity(n);
        let mut rxs = Vec::with_capacity(n);
        for _ in 0..n {
            let (tx, rx) = sync_channel::<Frame>(cap);
            txs.push(tx);
            rxs.push(rx);
        }
        let recv_slots: Vec<_> = received.iter_mut().collect();
        std::thread::scope(|scope| {
            for (rx, slot) in rxs.into_iter().zip(recv_slots) {
                let queued = &queued;
                scope.spawn(move || {
                    while let Ok(frame) = rx.recv() {
                        queued.fetch_sub(frame.payload.len() as u64, Ordering::Relaxed);
                        slot.push((frame.src, frame.seq, frame.payload));
                    }
                });
            }
            for frames in sends.into_iter().filter(|f| !f.is_empty()) {
                let txs = txs.clone();
                let queued = &queued;
                let queue_peak = &queue_peak;
                let frame_wait_shared = &frame_wait_shared;
                scope.spawn(move || {
                    // Per-thread histogram, merged once at the end: the
                    // exact merge makes the fold order irrelevant.
                    let mut wait = Histogram::new();
                    for frame in frames {
                        let len = frame.payload.len() as u64;
                        let now = queued.fetch_add(len, Ordering::Relaxed) + len;
                        queue_peak.fetch_max(now, Ordering::Relaxed);
                        let sent_at = Instant::now();
                        txs[frame.dst].send(frame).expect("receiver alive");
                        wait.record(sent_at.elapsed().as_nanos() as u64);
                    }
                    frame_wait_shared.lock().expect("frame-wait lock").merge(&wait);
                });
            }
            // Drop the coordinator's senders so receivers terminate once
            // every sender thread finishes.
            drop(txs);
        });
    }

    // Reconstruct the simulated delivery order: locals first, then
    // cross-node frames sorted by (src, seq).
    let mut delivered: Vec<Vec<(usize, Vec<u8>)>> = (0..n).map(|_| Vec::new()).collect();
    for (dst, local) in locals.into_iter().enumerate() {
        if let Some(payload) = local {
            delivered[dst].push((dst, payload));
        }
    }
    for (dst, mut frames) in received.into_iter().enumerate() {
        frames.sort_by_key(|&(src, seq, _)| (src, seq));
        delivered[dst].extend(frames.into_iter().map(|(src, _, payload)| (src, payload)));
    }

    TransportResult {
        flows,
        delivered,
        peak_in_flight_bytes: peak,
        stalls,
        frames: frames_total,
        bytes: bytes_total,
        queue_peak_bytes: queue_peak.load(Ordering::Relaxed),
        wall_ns: start.elapsed().as_nanos() as u64,
        pair_stats,
        in_flight_samples,
        frame_wait: frame_wait_shared.into_inner().expect("frame-wait lock"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::shuffle;

    fn payloads(n: usize) -> ShufflePayloads {
        (0..n).map(|_| (0..n).map(|_| Vec::new()).collect()).collect()
    }

    /// The transport is a drop-in for the simulated shuffle: identical
    /// delivered buffers, flows, peak, and stalls.
    #[test]
    fn parity_with_simulated_shuffle() {
        let mut p = payloads(3);
        p[0][1] = vec![9; 10];
        p[0][2] = vec![7; 4];
        p[1][1] = vec![5; 3]; // node-local
        p[2][1] = vec![8; 5];
        let sim = shuffle::execute(p.clone(), 1 << 20);
        let real = execute(p, 1 << 20);
        assert_eq!(real.delivered, sim.delivered);
        assert_eq!(real.flows.total_bytes(), sim.flows.total_bytes());
        assert_eq!(real.flows.cross_node_bytes(), sim.flows.cross_node_bytes());
        assert_eq!(real.peak_in_flight_bytes, sim.peak_in_flight_bytes);
        assert_eq!(real.stalls, sim.stalls);
        assert_eq!(real.frames, 3);
        assert_eq!(real.bytes, 19);
        // One occupancy sample per cross-node chunk push, in mirror
        // order; one frame-wait record per physical frame.
        assert_eq!(
            real.in_flight_samples,
            vec![(0, 10), (0, 4), (2, 5)],
            "gauge snapshots follow the deterministic mirror"
        );
        assert_eq!(real.frame_wait.count(), 3);
    }

    #[test]
    fn large_payload_chunked_like_simulation() {
        let mut p = payloads(2);
        p[0][1] = vec![0u8; CHUNK_BYTES * 2 + 7];
        let sim = shuffle::execute(p.clone(), 1 << 20);
        let real = execute(p, 1 << 20);
        assert_eq!(real.delivered, sim.delivered);
        assert_eq!(real.frames, 3, "3 chunks moved for real");
        assert_eq!(real.peak_in_flight_bytes as usize, CHUNK_BYTES);
        // Something actually sat in a destination queue.
        assert!(real.queue_peak_bytes > 0);
    }

    /// A one-byte window forces the window-accounting mirror to stall
    /// on every frame — the exact-count contract the stress suite and
    /// `transport_window_bytes = 1` runs rely on.
    #[test]
    fn capacity_one_window_stalls_every_frame() {
        let mut p = payloads(3);
        p[0][1] = vec![9; 10];
        p[0][2] = vec![7; 4];
        p[2][0] = vec![8; 5];
        let real = execute(p, 1);
        assert_eq!(real.frames, 3);
        assert_eq!(real.stalls, 3, "every frame exceeds a 1-byte window");
        assert_eq!(
            real.pair_stats,
            vec![
                PairStats { src: 0, dst: 1, frames: 1, bytes: 10, stalls: 1 },
                PairStats { src: 0, dst: 2, frames: 1, bytes: 4, stalls: 1 },
                PairStats { src: 2, dst: 0, frames: 1, bytes: 5, stalls: 1 },
            ]
        );
    }

    #[test]
    fn locals_bypass_channels_and_come_first() {
        let mut p = payloads(2);
        p[1][1] = vec![1, 2];
        p[0][1] = vec![3, 4];
        let real = execute(p, 1 << 20);
        assert_eq!(real.delivered[1], vec![(1, vec![1, 2]), (0, vec![3, 4])]);
        assert_eq!(real.frames, 1, "only the cross payload moved");
    }

    #[test]
    fn empty_matrix_moves_nothing() {
        let real = execute(payloads(4), 1 << 20);
        assert_eq!(real.frames, 0);
        assert_eq!(real.bytes, 0);
        assert_eq!(real.stalls, 0);
        assert_eq!(real.queue_peak_bytes, 0);
        assert!(real.delivered.iter().all(Vec::is_empty));
        assert!(real.pair_stats.is_empty());
        assert!(real.in_flight_samples.is_empty());
        assert!(real.frame_wait.is_empty(), "no frames, no wait records");
        assert_eq!(real.frame_wait.encode(), "0:0:0|", "empty histogram exports cleanly");
    }

    /// Many sources hammering one destination through a one-frame-deep
    /// queue: the sort restores deterministic (src, seq) order no matter
    /// how the scheduler interleaved the sends.
    #[test]
    fn skewed_fan_in_restores_deterministic_order() {
        let n = 6;
        let mut p = payloads(n);
        for src in 0..n {
            if src != 3 {
                p[src][3] = vec![src as u8; 64 + src];
            }
        }
        let sim = shuffle::execute(p.clone(), 1);
        let real = execute(p, 1);
        assert_eq!(real.delivered, sim.delivered);
        let srcs: Vec<usize> = real.delivered[3].iter().map(|&(s, _)| s).collect();
        assert_eq!(srcs, vec![0, 1, 2, 4, 5]);
    }
}
