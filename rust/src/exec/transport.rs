//! Real in-process shuffle transport for the threaded backend.
//!
//! [`coordinator::shuffle::execute`](crate::coordinator::shuffle::execute)
//! *models* a shuffle: payloads pass through [`NetSim`] mailboxes and the
//! cost comes out of the calibrated flow model. This module *moves* the
//! same frames: one bounded channel per destination node, one sender
//! thread per source node, real byte hand-off with real blocking when a
//! destination queue fills. The coordinator keeps a deterministic
//! accounting mirror of the exact `shuffle::execute` loop — same
//! [`FlowMatrix`] records per chunk, same per-sender [`WindowAccount`]
//! push/drain — so flows, `peak_in_flight_bytes`, and `stalls` are
//! byte-identical to the simulated shuffle at any thread count, while
//! `wall_ns` and `queue_peak_bytes` report what physically happened.
//!
//! Determinism contract:
//!
//! * **Delivery order** — frames land on receiver threads in scheduler
//!   order, but [`execute`] sorts each destination's frames by
//!   `(src, seq)` before returning and prepends node-local payloads, so
//!   `delivered` is element-for-element identical to
//!   `shuffle::execute`'s (src-ascending send loop, chunks in order,
//!   locals delivered inline). Downstream absorb code cannot tell the
//!   backends apart.
//! * **Stalls** — the `transport.stalls` counter uses the same
//!   [`WindowAccount`] semantics as the simulated window (a stall fires
//!   iff a chunk would overflow the window), so it is deterministic and
//!   testable (`transport_window_bytes = 1` forces a stall per frame).
//!   Physical waiting on a full channel is real but scheduling-dependent;
//!   it surfaces only in `wall_ns` and `queue_peak_bytes`, never in
//!   gated output.
//!
//! Channel capacity derives from the window: `window_bytes / CHUNK_BYTES`
//! frames, floor 1, so shrinking the window genuinely narrows the pipe.
//! Receivers always drain (a frame is admitted even when it alone
//! exceeds the window), so the transport cannot deadlock: senders block
//! only on a full queue that a live receiver is emptying.
//!
//! [`NetSim`]: crate::net::sim::NetSim

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::sync_channel;
use std::sync::Mutex;
use std::time::Instant;

use crate::coordinator::backpressure::WindowAccount;
use crate::coordinator::shuffle::{ShufflePayloads, CHUNK_BYTES};
use crate::net::sim::FlowMatrix;
use crate::ser::fastser::{decode_frame, encode_frame_into, FRAME_HEADER_BYTES};
use crate::trace::histogram::Histogram;
use crate::util::alloc::{AllocMode, BufferPool, Scratch};
use crate::util::rng::SplitRng;

/// Virtual backoff before retry `k` (1-based): `BACKOFF_BASE_NS · 2^(k-1)`,
/// capped at [`BACKOFF_CAP_NS`].
pub const BACKOFF_BASE_NS: u64 = 100_000;
/// Exponential-backoff ceiling.
pub const BACKOFF_CAP_NS: u64 = 10_000_000;
/// Virtual latency charged to a delayed (but delivered) frame attempt.
pub const DELAY_NS: u64 = 250_000;
/// Default retransmissions per frame before the destination is declared
/// dead. With drop ≤ 0.2 and corrupt ≤ 0.05 the chance of 9 consecutive
/// failed attempts is (0.25)⁹ ≈ 4·10⁻⁶ per frame — the chaos legs never
/// trip it; adversarial plans (drop = 1.0) trip it deterministically.
pub const DEFAULT_RETRY_MAX: u32 = 8;
/// Default per-frame delivery deadline (virtual backoff budget).
pub const DEFAULT_TIMEOUT_NS: u64 = 100_000_000;

/// Virtual backoff before the `attempt`-th send of a frame (attempt ≥ 1).
#[inline]
pub fn backoff_ns(attempt: u32) -> u64 {
    BACKOFF_BASE_NS
        .saturating_mul(1u64 << (attempt.saturating_sub(1)).min(32))
        .min(BACKOFF_CAP_NS)
}

/// Deterministic fate of one frame send attempt under a
/// [`TransportFaultPlan`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AttemptFate {
    /// Delivered intact.
    Deliver,
    /// Never arrives; sender retries after backoff.
    Drop,
    /// Arrives with one flipped bit; the receiver's frame checksum
    /// rejects it and the sender retries after backoff.
    Corrupt,
    /// Delivered intact after an extra [`DELAY_NS`] of virtual latency.
    Delay,
}

/// SplitRng-seeded per-frame fault model for the lossy transport.
///
/// The fate of attempt `a` of frame `(src, dst, seq)` is a pure function
/// of `(seed, src, dst, seq, a)` — no shared RNG state, no scheduling
/// dependence — so the full retry timeline of every frame is known to the
/// deterministic mirror before any thread spawns, and counters, backoff
/// clocks, and trace events are byte-identical at any thread count.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TransportFaultPlan {
    /// Probability an attempt is dropped outright.
    pub drop_p: f64,
    /// Probability an attempt arrives with one flipped bit.
    pub corrupt_p: f64,
    /// Probability an attempt is delayed by [`DELAY_NS`] (still delivered).
    pub delay_p: f64,
    /// Seed for the per-attempt fate stream.
    pub seed: u64,
    /// Retransmissions per frame before the destination is declared dead.
    pub retry_max: u32,
    /// Per-frame virtual-backoff budget; exceeding it declares the
    /// destination dead even with retries remaining.
    pub timeout_ns: u64,
}

impl TransportFaultPlan {
    /// Plan with the given loss probabilities and default retry/timeout
    /// policy.
    pub fn new(drop_p: f64, corrupt_p: f64, seed: u64) -> Self {
        Self {
            drop_p,
            corrupt_p,
            delay_p: 0.0,
            seed,
            retry_max: DEFAULT_RETRY_MAX,
            timeout_ns: DEFAULT_TIMEOUT_NS,
        }
    }

    /// Builder-style delay probability.
    pub fn with_delay(mut self, p: f64) -> Self {
        self.delay_p = p;
        self
    }

    /// Builder-style retry budget.
    pub fn with_retry_max(mut self, n: u32) -> Self {
        self.retry_max = n;
        self
    }

    /// Builder-style per-frame delivery deadline.
    pub fn with_timeout_ns(mut self, ns: u64) -> Self {
        self.timeout_ns = ns;
        self
    }

    /// Independent stream id for one `(src, dst, seq, attempt)` draw.
    fn stream(src: usize, dst: usize, seq: u64, attempt: u32) -> u64 {
        ((src as u64) << 52)
            ^ ((dst as u64) << 40)
            ^ ((seq & 0xFFFF_FFFF) << 8)
            ^ u64::from(attempt & 0xFF)
    }

    /// Fate of one send attempt (pure function — see type docs).
    pub fn fate(&self, src: usize, dst: usize, seq: u64, attempt: u32) -> AttemptFate {
        let u = SplitRng::new(self.seed, Self::stream(src, dst, seq, attempt)).uniform();
        if u < self.drop_p {
            AttemptFate::Drop
        } else if u < self.drop_p + self.corrupt_p {
            AttemptFate::Corrupt
        } else if u < self.drop_p + self.corrupt_p + self.delay_p {
            AttemptFate::Delay
        } else {
            AttemptFate::Deliver
        }
    }

    /// Deterministic bit position flipped by a corrupt attempt on a
    /// frame of `nbits` bits.
    pub fn corrupt_bit(&self, src: usize, dst: usize, seq: u64, attempt: u32, nbits: u64) -> u64 {
        SplitRng::new(self.seed ^ 0xB17_F11F, Self::stream(src, dst, seq, attempt))
            .below(nbits.max(1))
    }
}

/// One fault-plan decision on the retry timeline, in deterministic mirror
/// order. Rendered as chrome-only `FrameDropped` / `FrameRetried` trace
/// events by the engines.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum FrameFault {
    /// Attempt `attempt` of frame `(src, dst, seq)` was lost — dropped
    /// outright, or (when `corrupt`) physically sent with one flipped bit
    /// and rejected by the receiver's frame checksum.
    Dropped { src: usize, dst: usize, seq: u64, attempt: u32, corrupt: bool },
    /// The frame was retransmitted as attempt `attempt` after
    /// `backoff_ns` of virtual exponential backoff.
    Retried { src: usize, dst: usize, seq: u64, attempt: u32, backoff_ns: u64 },
}

/// Structured failure of a lossy transport run: every retry toward a
/// destination exhausted (retry budget or delivery deadline), so the
/// destination is declared dead. Returned before any physical frame
/// moves — the timeline is fully known to the deterministic mirror — so
/// the caller can degrade gracefully instead of hanging.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TransportError {
    /// Destination declared dead.
    pub node: usize,
    /// Sender that gave up.
    pub src: usize,
    /// Frame sequence number that exhausted its budget.
    pub seq: u64,
    /// Send attempts consumed (initial send + retries).
    pub attempts: u32,
    /// Virtual backoff accumulated on the fatal frame.
    pub backoff_ns: u64,
}

impl std::fmt::Display for TransportError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "transport: node {} timed out (frame {}->{} seq {}: {} attempts, {} ns backoff)",
            self.node, self.src, self.node, self.seq, self.attempts, self.backoff_ns
        )
    }
}

impl std::error::Error for TransportError {}

/// Per-(src → dst) frame tallies, for `FrameSent`/`TransportStall`
/// trace events. Cross-node pairs with traffic only, src-major order.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PairStats {
    pub src: usize,
    pub dst: usize,
    /// Frames (chunks) shipped src → dst.
    pub frames: u64,
    /// Payload bytes shipped src → dst.
    pub bytes: u64,
    /// Window-accounting stalls charged to this pair.
    pub stalls: u64,
}

/// Scalar transport measurements the engines fold into the
/// `transport.*` counter family and `phase_wall_ns`. Additive: phases
/// (or tree-reduce rounds) accumulate with [`TransportTotals::merge`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TransportTotals {
    /// Cross-node frames physically moved (`transport.frames`).
    pub frames: u64,
    /// Cross-node payload bytes physically moved (`transport.bytes`).
    pub bytes: u64,
    /// Window-accounting stalls (`transport.stalls` — deterministic).
    pub stalls: u64,
    /// Peak bytes resident in destination queues
    /// (`transport.queue_peak_bytes` — measured).
    pub queue_peak_bytes: u64,
    /// Wall-clock nanoseconds spent in transport (measured).
    pub wall_ns: u64,
    /// Retransmissions under a fault plan (`transport.retries` —
    /// deterministic mirror count).
    pub retries: u64,
    /// Attempts dropped outright (`transport.drops` — deterministic).
    pub drops: u64,
    /// Attempts corrupted and checksum-rejected (`transport.corrupt` —
    /// deterministic).
    pub corrupt: u64,
    /// Destinations declared dead by retry/deadline exhaustion
    /// (`transport.timeouts`). Zero on every successful run; set by the
    /// engine when it absorbs a [`TransportError`].
    pub timeouts: u64,
    /// Virtual backoff accumulated by the busiest sender (ns) — the
    /// length of the `transport-backoff` virtual-time phase.
    pub backoff_ns: u64,
    /// True when a fault plan was active (the engines record the
    /// `transport.{retries,drops,corrupt,timeouts}` counter family only
    /// for faulted runs, so lossless runs keep their counter set).
    pub faulted: bool,
}

impl TransportTotals {
    /// Accumulate another phase/round: counts, wall time, and backoff
    /// add, queue peak takes the max.
    pub fn merge(&mut self, other: TransportTotals) {
        self.frames += other.frames;
        self.bytes += other.bytes;
        self.stalls += other.stalls;
        self.queue_peak_bytes = self.queue_peak_bytes.max(other.queue_peak_bytes);
        self.wall_ns += other.wall_ns;
        self.retries += other.retries;
        self.drops += other.drops;
        self.corrupt += other.corrupt;
        self.timeouts += other.timeouts;
        self.backoff_ns += other.backoff_ns;
        self.faulted |= other.faulted;
    }
}

/// Outcome of a real transport run. `flows` / `delivered` /
/// `peak_in_flight_bytes` / `stalls` are byte-identical to
/// [`crate::coordinator::shuffle::ShuffleResult`] for the same payload
/// matrix; the rest are transport-only measurements.
#[derive(Debug)]
pub struct TransportResult {
    /// Real byte/message flows (recorded per chunk, like the simulation).
    pub flows: FlowMatrix,
    /// Per-destination `(src, frame)` buffers in simulated delivery
    /// order: node-local payloads first, then cross-node frames by
    /// `(src, seq)`.
    pub delivered: Vec<Vec<(usize, Vec<u8>)>>,
    /// Peak in-flight serialized bytes summed over senders
    /// (window-accounting mirror).
    pub peak_in_flight_bytes: u64,
    /// Total sender stalls (window-accounting mirror — deterministic).
    pub stalls: u64,
    /// Cross-node frames physically moved through channels.
    pub frames: u64,
    /// Cross-node payload bytes physically moved through channels.
    pub bytes: u64,
    /// Peak bytes resident in destination queues (measured, not gated).
    pub queue_peak_bytes: u64,
    /// Wall-clock nanoseconds for the whole transport phase (measured).
    pub wall_ns: u64,
    /// Per-(src,dst) tallies for trace events.
    pub pair_stats: Vec<PairStats>,
    /// Window-occupancy gauge: `(src, in-flight bytes)` after every
    /// chunk push of the deterministic mirror, in the mirror's
    /// src-ascending loop order. Feeds the `transport.in_flight_bytes`
    /// Chrome counter track — deterministic, but Chrome-view only like
    /// the rest of the sample machinery.
    pub in_flight_samples: Vec<(usize, u64)>,
    /// Per-frame channel-send wait (wall ns), merged across sender
    /// threads. Surfaces as the `wall.transport.frame_wait_ns` histogram
    /// — measured time, observability only, never gated.
    pub frame_wait: Histogram,
    /// Fault-plan decisions in deterministic mirror order (empty without
    /// a plan). Feeds the chrome-only `FrameDropped`/`FrameRetried`
    /// trace events.
    pub faults: Vec<FrameFault>,
    /// Retransmissions the mirror scheduled (`transport.retries`).
    pub retries: u64,
    /// Attempts the mirror dropped (`transport.drops`).
    pub drops: u64,
    /// Attempts the mirror corrupted (`transport.corrupt`).
    pub corrupt: u64,
    /// Corrupted physical frames the *receivers* actually rejected via
    /// the frame checksum. Equals `corrupt` on every run — the physical
    /// plane really sent each corrupted copy and really rejected it —
    /// and the transport tests assert the equality.
    pub corrupt_rejects: u64,
    /// Virtual backoff of the busiest sender (ns).
    pub backoff_ns: u64,
    /// True when a fault plan was active.
    pub faulted: bool,
}

impl TransportResult {
    /// The scalar totals for counters/phase accounting.
    pub fn totals(&self) -> TransportTotals {
        TransportTotals {
            frames: self.frames,
            bytes: self.bytes,
            stalls: self.stalls,
            queue_peak_bytes: self.queue_peak_bytes,
            wall_ns: self.wall_ns,
            retries: self.retries,
            drops: self.drops,
            corrupt: self.corrupt,
            timeouts: 0,
            backoff_ns: self.backoff_ns,
            faulted: self.faulted,
        }
    }
}

/// One frame in flight. `seq` increases along the source's
/// dst-ascending send loop, so sorting a destination's frames by
/// `(src, seq)` reconstructs the simulated arrival order.
struct Frame {
    src: usize,
    dst: usize,
    seq: u64,
    payload: Vec<u8>,
}

/// Execute a shuffle over real bounded channels. Drop-in for
/// [`crate::coordinator::shuffle::execute`]: identical `delivered` /
/// `flows` / `peak_in_flight_bytes` / `stalls`, plus real measurements.
///
/// Convenience form with system-allocated chunk buffers; the engines
/// call [`execute_pooled`] with the cluster's scratch so chunk copies
/// recycle.
pub fn execute(payloads: ShufflePayloads, window_bytes: u64) -> TransportResult {
    let pool = BufferPool::new();
    let scratch = Scratch::new(AllocMode::System, &pool);
    execute_pooled(payloads, window_bytes, &scratch)
}

/// [`execute`] with chunk-copy buffers drawn from `scratch`. Every
/// scratch operation happens on the *calling* thread (the deterministic
/// mirror loop runs before the sender/receiver threads spawn), so a
/// single-threaded [`BufferPool`] behind the scratch is safe; the chunk
/// buffers themselves travel through the channels and come back to the
/// caller inside `delivered`, where the absorb loops return them to the
/// same scratch.
pub fn execute_pooled(
    payloads: ShufflePayloads,
    window_bytes: u64,
    scratch: &Scratch<'_, u8>,
) -> TransportResult {
    execute_inner(payloads, window_bytes, scratch, None)
        .expect("lossless transport cannot time out")
}

/// [`execute_pooled`] under a [`TransportFaultPlan`]: every physical
/// frame travels as a checksummed [`crate::ser::fastser`] frame, attempts
/// are dropped / bit-flipped / delayed per the plan, corrupted arrivals
/// are rejected by the receivers' frame checksum, and the sender
/// retransmits with capped exponential (virtual) backoff. Delivered
/// payloads, flows, stalls, and `peak_in_flight_bytes` remain
/// byte-identical to the lossless transport — reliability costs surface
/// only in the `retries`/`drops`/`corrupt` counters, the virtual backoff
/// clock, and the fault records. Returns [`TransportError`] — before any
/// physical frame moves, so never a hang — when some frame's retry
/// budget or delivery deadline exhausts.
pub fn execute_lossy(
    payloads: ShufflePayloads,
    window_bytes: u64,
    plan: &TransportFaultPlan,
    scratch: &Scratch<'_, u8>,
) -> Result<TransportResult, TransportError> {
    execute_inner(payloads, window_bytes, scratch, Some(plan))
}

/// Deterministic retry timeline of one frame under a fault plan.
#[derive(Debug, Default)]
struct FrameTimeline {
    /// Attempts physically sent as bit-flipped copies.
    corrupt_attempts: Vec<u32>,
    /// Fault records in attempt order.
    faults: Vec<FrameFault>,
    drops: u64,
    corrupt: u64,
    retries: u64,
    /// Virtual backoff accumulated across this frame's retries.
    backoff_ns: u64,
    /// Final attempt delivered with the extra [`DELAY_NS`] charge.
    delayed: bool,
}

/// Walk attempts `0, 1, …` of frame `(src, dst, seq)` until one
/// delivers, or the retry budget / delivery deadline exhausts.
fn frame_timeline(
    plan: &TransportFaultPlan,
    src: usize,
    dst: usize,
    seq: u64,
) -> Result<FrameTimeline, TransportError> {
    let mut tl = FrameTimeline::default();
    let mut attempt = 0u32;
    loop {
        match plan.fate(src, dst, seq, attempt) {
            AttemptFate::Deliver => return Ok(tl),
            AttemptFate::Delay => {
                tl.delayed = true;
                return Ok(tl);
            }
            bad => {
                let corrupt = bad == AttemptFate::Corrupt;
                if corrupt {
                    tl.corrupt += 1;
                    tl.corrupt_attempts.push(attempt);
                } else {
                    tl.drops += 1;
                }
                tl.faults.push(FrameFault::Dropped { src, dst, seq, attempt, corrupt });
                if attempt >= plan.retry_max {
                    return Err(TransportError {
                        node: dst,
                        src,
                        seq,
                        attempts: attempt + 1,
                        backoff_ns: tl.backoff_ns,
                    });
                }
                attempt += 1;
                let b = backoff_ns(attempt);
                tl.backoff_ns += b;
                if tl.backoff_ns > plan.timeout_ns {
                    return Err(TransportError {
                        node: dst,
                        src,
                        seq,
                        attempts: attempt,
                        backoff_ns: tl.backoff_ns,
                    });
                }
                tl.retries += 1;
                tl.faults.push(FrameFault::Retried { src, dst, seq, attempt, backoff_ns: b });
            }
        }
    }
}

/// Fault bookkeeping accumulated across the mirror loop.
struct FaultAcc {
    faults: Vec<FrameFault>,
    retries: u64,
    drops: u64,
    corrupt: u64,
    backoff_per_src: Vec<u64>,
}

/// Run one frame's fault timeline and push its physical sends: each
/// corrupted attempt as a bit-flipped checksummed copy, then the one
/// good checksummed frame. Dropped attempts are never physically sent.
fn push_lossy(
    plan: &TransportFaultPlan,
    scratch: &Scratch<'_, u8>,
    sends: &mut Vec<Frame>,
    src: usize,
    dst: usize,
    seq: u64,
    chunk: &[u8],
    acc: &mut FaultAcc,
) -> Result<(), TransportError> {
    let tl = frame_timeline(plan, src, dst, seq)?;
    let framed = encode_frame_into(chunk, scratch.get(FRAME_HEADER_BYTES + chunk.len()));
    for &attempt in &tl.corrupt_attempts {
        let mut bad = scratch.get(framed.len());
        bad.extend_from_slice(&framed);
        let bit = plan.corrupt_bit(src, dst, seq, attempt, (framed.len() as u64) * 8);
        bad[(bit / 8) as usize] ^= 1 << (bit % 8);
        sends.push(Frame { src, dst, seq, payload: bad });
    }
    sends.push(Frame { src, dst, seq, payload: framed });
    acc.faults.extend(tl.faults);
    acc.retries += tl.retries;
    acc.drops += tl.drops;
    acc.corrupt += tl.corrupt;
    acc.backoff_per_src[src] += tl.backoff_ns + if tl.delayed { DELAY_NS } else { 0 };
    Ok(())
}

fn execute_inner(
    payloads: ShufflePayloads,
    window_bytes: u64,
    scratch: &Scratch<'_, u8>,
    plan: Option<&TransportFaultPlan>,
) -> Result<TransportResult, TransportError> {
    let n = payloads.len();
    let start = Instant::now();

    // Split the matrix into node-local payloads (delivered inline, like
    // the simulation) and per-src cross-node frame lists, while running
    // the deterministic accounting mirror of `shuffle::execute`.
    let mut locals: Vec<Option<Vec<u8>>> = (0..n).map(|_| None).collect();
    let mut sends: Vec<Vec<Frame>> = (0..n).map(|_| Vec::new()).collect();
    let mut flows = FlowMatrix::new(n);
    let mut peak = 0u64;
    let mut stalls = 0u64;
    let mut frames_total = 0u64;
    let mut bytes_total = 0u64;
    let mut pair_stats: Vec<PairStats> = Vec::new();
    let mut in_flight_samples: Vec<(usize, u64)> = Vec::new();
    let mut acc = FaultAcc {
        faults: Vec::new(),
        retries: 0,
        drops: 0,
        corrupt: 0,
        backoff_per_src: vec![0; n],
    };

    for (src, dsts) in payloads.into_iter().enumerate() {
        assert_eq!(dsts.len(), n, "payload matrix must be n x n");
        let mut window = WindowAccount::new(window_bytes);
        let mut seq = 0u64;
        for (dst, payload) in dsts.into_iter().enumerate() {
            if payload.is_empty() {
                continue;
            }
            if dst == src {
                locals[dst] = Some(payload);
                continue;
            }
            let stalls_before = window.stalls();
            let mut pair_frames = 0u64;
            let pair_bytes = payload.len() as u64;
            if payload.len() <= CHUNK_BYTES {
                window.push(pair_bytes);
                in_flight_samples.push((src, window.in_flight()));
                flows.record(src, dst, pair_bytes);
                match plan {
                    None => sends[src].push(Frame { src, dst, seq, payload }),
                    Some(pl) => {
                        push_lossy(pl, scratch, &mut sends[src], src, dst, seq, &payload, &mut acc)?;
                        // The original served only as the framing source.
                        scratch.put(payload);
                    }
                }
                seq += 1;
                pair_frames += 1;
                window.drain(pair_bytes);
            } else {
                for chunk in payload.chunks(CHUNK_BYTES) {
                    window.push(chunk.len() as u64);
                    in_flight_samples.push((src, window.in_flight()));
                    flows.record(src, dst, chunk.len() as u64);
                    match plan {
                        None => {
                            let mut copy = scratch.get(chunk.len());
                            copy.extend_from_slice(chunk);
                            sends[src].push(Frame { src, dst, seq, payload: copy });
                        }
                        Some(pl) => {
                            push_lossy(pl, scratch, &mut sends[src], src, dst, seq, chunk, &mut acc)?;
                        }
                    }
                    seq += 1;
                    pair_frames += 1;
                    window.drain(chunk.len() as u64);
                }
                // The chunked original served only as the copy source.
                scratch.put(payload);
            }
            frames_total += pair_frames;
            bytes_total += pair_bytes;
            pair_stats.push(PairStats {
                src,
                dst,
                frames: pair_frames,
                bytes: pair_bytes,
                stalls: window.stalls() - stalls_before,
            });
        }
        peak += window.peak_bytes();
        stalls += window.stalls();
    }

    // Physically move the cross-node frames: one bounded channel per
    // destination, one sender thread per source with traffic. Under a
    // fault plan every frame travels checksummed and receivers verify
    // before accepting — a corrupted copy is really rejected, and only
    // the one good copy of each (src, seq) survives to delivery.
    let lossy = plan.is_some();
    let queue_peak = AtomicU64::new(0);
    let corrupt_rejects = AtomicU64::new(0);
    let frame_wait_shared = Mutex::new(Histogram::new());
    let mut received: Vec<Vec<(usize, u64, Vec<u8>)>> = (0..n).map(|_| Vec::new()).collect();
    if sends.iter().any(|s| !s.is_empty()) {
        let cap = ((window_bytes as usize) / CHUNK_BYTES).max(1);
        let queued = AtomicU64::new(0);
        let mut txs = Vec::with_capacity(n);
        let mut rxs = Vec::with_capacity(n);
        for _ in 0..n {
            let (tx, rx) = sync_channel::<Frame>(cap);
            txs.push(tx);
            rxs.push(rx);
        }
        let recv_slots: Vec<_> = received.iter_mut().collect();
        std::thread::scope(|scope| {
            for (rx, slot) in rxs.into_iter().zip(recv_slots) {
                let queued = &queued;
                let corrupt_rejects = &corrupt_rejects;
                scope.spawn(move || {
                    while let Ok(mut frame) = rx.recv() {
                        queued.fetch_sub(frame.payload.len() as u64, Ordering::Relaxed);
                        if lossy {
                            if decode_frame(&frame.payload).is_err() {
                                corrupt_rejects.fetch_add(1, Ordering::Relaxed);
                                continue;
                            }
                            frame.payload.drain(..FRAME_HEADER_BYTES);
                        }
                        slot.push((frame.src, frame.seq, frame.payload));
                    }
                });
            }
            for frames in sends.into_iter().filter(|f| !f.is_empty()) {
                let txs = txs.clone();
                let queued = &queued;
                let queue_peak = &queue_peak;
                let frame_wait_shared = &frame_wait_shared;
                scope.spawn(move || {
                    // Per-thread histogram, merged once at the end: the
                    // exact merge makes the fold order irrelevant.
                    let mut wait = Histogram::new();
                    for frame in frames {
                        let len = frame.payload.len() as u64;
                        let now = queued.fetch_add(len, Ordering::Relaxed) + len;
                        queue_peak.fetch_max(now, Ordering::Relaxed);
                        let sent_at = Instant::now();
                        txs[frame.dst].send(frame).expect("receiver alive");
                        wait.record(sent_at.elapsed().as_nanos() as u64);
                    }
                    frame_wait_shared.lock().expect("frame-wait lock").merge(&wait);
                });
            }
            // Drop the coordinator's senders so receivers terminate once
            // every sender thread finishes.
            drop(txs);
        });
    }

    // Reconstruct the simulated delivery order: locals first, then
    // cross-node frames sorted by (src, seq).
    let mut delivered: Vec<Vec<(usize, Vec<u8>)>> = (0..n).map(|_| Vec::new()).collect();
    for (dst, local) in locals.into_iter().enumerate() {
        if let Some(payload) = local {
            delivered[dst].push((dst, payload));
        }
    }
    for (dst, mut frames) in received.into_iter().enumerate() {
        frames.sort_by_key(|&(src, seq, _)| (src, seq));
        delivered[dst].extend(frames.into_iter().map(|(src, _, payload)| (src, payload)));
    }

    Ok(TransportResult {
        flows,
        delivered,
        peak_in_flight_bytes: peak,
        stalls,
        frames: frames_total,
        bytes: bytes_total,
        queue_peak_bytes: queue_peak.load(Ordering::Relaxed),
        wall_ns: start.elapsed().as_nanos() as u64,
        pair_stats,
        in_flight_samples,
        frame_wait: frame_wait_shared.into_inner().expect("frame-wait lock"),
        faults: acc.faults,
        retries: acc.retries,
        drops: acc.drops,
        corrupt: acc.corrupt,
        corrupt_rejects: corrupt_rejects.load(Ordering::Relaxed),
        backoff_ns: acc.backoff_per_src.into_iter().max().unwrap_or(0),
        faulted: lossy,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::shuffle;

    fn payloads(n: usize) -> ShufflePayloads {
        (0..n).map(|_| (0..n).map(|_| Vec::new()).collect()).collect()
    }

    /// The transport is a drop-in for the simulated shuffle: identical
    /// delivered buffers, flows, peak, and stalls.
    #[test]
    fn parity_with_simulated_shuffle() {
        let mut p = payloads(3);
        p[0][1] = vec![9; 10];
        p[0][2] = vec![7; 4];
        p[1][1] = vec![5; 3]; // node-local
        p[2][1] = vec![8; 5];
        let sim = shuffle::execute(p.clone(), 1 << 20);
        let real = execute(p, 1 << 20);
        assert_eq!(real.delivered, sim.delivered);
        assert_eq!(real.flows.total_bytes(), sim.flows.total_bytes());
        assert_eq!(real.flows.cross_node_bytes(), sim.flows.cross_node_bytes());
        assert_eq!(real.peak_in_flight_bytes, sim.peak_in_flight_bytes);
        assert_eq!(real.stalls, sim.stalls);
        assert_eq!(real.frames, 3);
        assert_eq!(real.bytes, 19);
        // One occupancy sample per cross-node chunk push, in mirror
        // order; one frame-wait record per physical frame.
        assert_eq!(
            real.in_flight_samples,
            vec![(0, 10), (0, 4), (2, 5)],
            "gauge snapshots follow the deterministic mirror"
        );
        assert_eq!(real.frame_wait.count(), 3);
    }

    #[test]
    fn large_payload_chunked_like_simulation() {
        let mut p = payloads(2);
        p[0][1] = vec![0u8; CHUNK_BYTES * 2 + 7];
        let sim = shuffle::execute(p.clone(), 1 << 20);
        let real = execute(p, 1 << 20);
        assert_eq!(real.delivered, sim.delivered);
        assert_eq!(real.frames, 3, "3 chunks moved for real");
        assert_eq!(real.peak_in_flight_bytes as usize, CHUNK_BYTES);
        // Something actually sat in a destination queue.
        assert!(real.queue_peak_bytes > 0);
    }

    /// A one-byte window forces the window-accounting mirror to stall
    /// on every frame — the exact-count contract the stress suite and
    /// `transport_window_bytes = 1` runs rely on.
    #[test]
    fn capacity_one_window_stalls_every_frame() {
        let mut p = payloads(3);
        p[0][1] = vec![9; 10];
        p[0][2] = vec![7; 4];
        p[2][0] = vec![8; 5];
        let real = execute(p, 1);
        assert_eq!(real.frames, 3);
        assert_eq!(real.stalls, 3, "every frame exceeds a 1-byte window");
        assert_eq!(
            real.pair_stats,
            vec![
                PairStats { src: 0, dst: 1, frames: 1, bytes: 10, stalls: 1 },
                PairStats { src: 0, dst: 2, frames: 1, bytes: 4, stalls: 1 },
                PairStats { src: 2, dst: 0, frames: 1, bytes: 5, stalls: 1 },
            ]
        );
    }

    #[test]
    fn locals_bypass_channels_and_come_first() {
        let mut p = payloads(2);
        p[1][1] = vec![1, 2];
        p[0][1] = vec![3, 4];
        let real = execute(p, 1 << 20);
        assert_eq!(real.delivered[1], vec![(1, vec![1, 2]), (0, vec![3, 4])]);
        assert_eq!(real.frames, 1, "only the cross payload moved");
    }

    #[test]
    fn empty_matrix_moves_nothing() {
        let real = execute(payloads(4), 1 << 20);
        assert_eq!(real.frames, 0);
        assert_eq!(real.bytes, 0);
        assert_eq!(real.stalls, 0);
        assert_eq!(real.queue_peak_bytes, 0);
        assert!(real.delivered.iter().all(Vec::is_empty));
        assert!(real.pair_stats.is_empty());
        assert!(real.in_flight_samples.is_empty());
        assert!(real.frame_wait.is_empty(), "no frames, no wait records");
        assert_eq!(real.frame_wait.encode(), "0:0:0|", "empty histogram exports cleanly");
    }

    // ---- Lossy transport -------------------------------------------------

    fn lossy_payloads() -> ShufflePayloads {
        let n = 4;
        let mut p = payloads(n);
        for src in 0..n {
            for dst in 0..n {
                if src != dst {
                    p[src][dst] = (0..200 + src * 17 + dst * 5).map(|i| i as u8).collect();
                }
            }
        }
        p[1][1] = vec![42; 9]; // a local rides along untouched
        p
    }

    fn run_lossy(plan: &TransportFaultPlan) -> TransportResult {
        let pool = BufferPool::new();
        let scratch = Scratch::new(AllocMode::System, &pool);
        execute_lossy(lossy_payloads(), 1 << 20, plan, &scratch).expect("plan survivable")
    }

    /// Loss, corruption, and delay change nothing the determinism gates
    /// see: delivered payloads, flows, stalls, and peak are byte-identical
    /// to the lossless transport; the cost surfaces only in the fault
    /// counters and the virtual backoff clock.
    #[test]
    fn lossy_delivery_is_byte_identical_to_lossless() {
        let plan = TransportFaultPlan::new(0.3, 0.1, 77).with_delay(0.05).with_retry_max(16);
        let clean = execute(lossy_payloads(), 1 << 20);
        let noisy = run_lossy(&plan);
        assert_eq!(noisy.delivered, clean.delivered);
        assert_eq!(noisy.flows.total_bytes(), clean.flows.total_bytes());
        assert_eq!(noisy.stalls, clean.stalls);
        assert_eq!(noisy.peak_in_flight_bytes, clean.peak_in_flight_bytes);
        assert_eq!(noisy.frames, clean.frames, "frames counts the payload mirror");
        assert_eq!(noisy.bytes, clean.bytes);
        // 12 cross frames under 25% loss: overwhelmingly likely ≥ 1 retry.
        assert!(noisy.retries > 0, "seed 77 must exercise the retry path");
        assert_eq!(
            noisy.retries,
            noisy.drops + noisy.corrupt,
            "every failed attempt schedules exactly one retry"
        );
        assert!(noisy.faulted && !clean.faulted);
    }

    /// The receiver really rejects every corrupted physical frame: the
    /// measured reject count equals the mirror's corrupt count exactly.
    #[test]
    fn receivers_reject_exactly_the_corrupted_frames() {
        let plan = TransportFaultPlan::new(0.0, 0.4, 123).with_retry_max(16);
        let noisy = run_lossy(&plan);
        assert!(noisy.corrupt > 0, "seed 123 at 40% must corrupt something");
        assert_eq!(noisy.corrupt_rejects, noisy.corrupt);
        assert_eq!(noisy.drops, 0);
        // Fault records pair up: one Dropped{corrupt:true} per corrupt
        // attempt, one Retried per retry.
        let dropped = noisy
            .faults
            .iter()
            .filter(|f| matches!(f, FrameFault::Dropped { corrupt: true, .. }))
            .count() as u64;
        let retried =
            noisy.faults.iter().filter(|f| matches!(f, FrameFault::Retried { .. })).count() as u64;
        assert_eq!(dropped, noisy.corrupt);
        assert_eq!(retried, noisy.retries);
    }

    /// Same plan, two runs: counters, fault records, and backoff clocks
    /// are identical — the timeline is a pure function of the seed.
    #[test]
    fn lossy_runs_are_deterministic() {
        let plan = TransportFaultPlan::new(0.15, 0.1, 9).with_delay(0.1).with_retry_max(16);
        let a = run_lossy(&plan);
        let b = run_lossy(&plan);
        assert_eq!(a.delivered, b.delivered);
        assert_eq!(
            (a.retries, a.drops, a.corrupt, a.backoff_ns),
            (b.retries, b.drops, b.corrupt, b.backoff_ns)
        );
        assert_eq!(a.faults, b.faults);
        // The timeline accounting matches replaying the pure fate
        // function over the recorded fault stream.
        for f in &a.faults {
            if let FrameFault::Dropped { src, dst, seq, attempt, corrupt } = *f {
                let fate = plan.fate(src, dst, seq, attempt);
                assert_eq!(fate == AttemptFate::Corrupt, corrupt, "fault record matches fate");
                assert!(matches!(fate, AttemptFate::Drop | AttemptFate::Corrupt));
            }
        }
    }

    /// drop = 1.0 exhausts the retry budget on the very first frame: a
    /// structured error, returned before any thread spawns — no hang, no
    /// panic, no partial delivery.
    #[test]
    fn retry_exhaustion_is_a_structured_error() {
        let plan = TransportFaultPlan::new(1.0, 0.0, 1).with_retry_max(3);
        let pool = BufferPool::new();
        let scratch = Scratch::new(AllocMode::System, &pool);
        let err = execute_lossy(lossy_payloads(), 1 << 20, &plan, &scratch).unwrap_err();
        assert_eq!(err.attempts, 4, "initial send + retry_max retries");
        assert_eq!(err.src, 0);
        assert_eq!(err.node, 1, "first cross frame in mirror order is 0→1");
        let msg = err.to_string();
        assert!(msg.contains("timed out"), "{msg}");
    }

    /// A tiny delivery deadline trips before the retry budget does.
    #[test]
    fn delivery_deadline_beats_retry_budget() {
        let plan = TransportFaultPlan::new(1.0, 0.0, 1).with_retry_max(1000).with_timeout_ns(1);
        let pool = BufferPool::new();
        let scratch = Scratch::new(AllocMode::System, &pool);
        let err = execute_lossy(lossy_payloads(), 1 << 20, &plan, &scratch).unwrap_err();
        assert_eq!(err.attempts, 1, "first backoff already exceeds the deadline");
        assert!(err.backoff_ns > plan.timeout_ns);
    }

    /// Fault-free plan: identical to lossless in every observable except
    /// the checksummed wire format (which the receiver strips).
    #[test]
    fn zero_probability_plan_is_transparent() {
        let plan = TransportFaultPlan::new(0.0, 0.0, 5);
        let clean = execute(lossy_payloads(), 1 << 20);
        let noisy = run_lossy(&plan);
        assert_eq!(noisy.delivered, clean.delivered);
        assert_eq!((noisy.retries, noisy.drops, noisy.corrupt, noisy.backoff_ns), (0, 0, 0, 0));
        assert!(noisy.faults.is_empty());
        assert_eq!(noisy.corrupt_rejects, 0);
    }

    /// Chunked payloads frame per chunk; loss on individual chunks still
    /// reassembles the exact payload.
    #[test]
    fn lossy_chunked_payload_reassembles() {
        let mut p = payloads(2);
        p[0][1] = (0..CHUNK_BYTES * 2 + 7).map(|i| (i * 31) as u8).collect();
        let clean = shuffle::execute(p.clone(), 1 << 20);
        let plan = TransportFaultPlan::new(0.3, 0.1, 4242).with_retry_max(16);
        let pool = BufferPool::new();
        let scratch = Scratch::new(AllocMode::System, &pool);
        let noisy = execute_lossy(p, 1 << 20, &plan, &scratch).expect("survivable");
        assert_eq!(noisy.delivered, clean.delivered);
        assert_eq!(noisy.frames, 3);
    }

    /// Exponential backoff doubles up to the cap.
    #[test]
    fn backoff_schedule_doubles_and_caps() {
        assert_eq!(backoff_ns(1), BACKOFF_BASE_NS);
        assert_eq!(backoff_ns(2), BACKOFF_BASE_NS * 2);
        assert_eq!(backoff_ns(3), BACKOFF_BASE_NS * 4);
        assert_eq!(backoff_ns(40), BACKOFF_CAP_NS);
        assert!(backoff_ns(7) <= BACKOFF_CAP_NS);
    }

    /// Many sources hammering one destination through a one-frame-deep
    /// queue: the sort restores deterministic (src, seq) order no matter
    /// how the scheduler interleaved the sends.
    #[test]
    fn skewed_fan_in_restores_deterministic_order() {
        let n = 6;
        let mut p = payloads(n);
        for src in 0..n {
            if src != 3 {
                p[src][3] = vec![src as u8; 64 + src];
            }
        }
        let sim = shuffle::execute(p.clone(), 1);
        let real = execute(p, 1);
        assert_eq!(real.delivered, sim.delivered);
        let srcs: Vec<usize> = real.delivered[3].iter().map(|&(s, _)| s).collect();
        assert_eq!(srcs, vec![0, 1, 2, 4, 5]);
    }
}
