//! Tagged (protobuf-analog) codec — the serialization baseline.
//!
//! Mirrors Protocol Buffers' wire format: every field is prefixed with a
//! `(field_number << 3) | wire_type` tag byte. This buys missing-field
//! tolerance and arbitrary field order — flexibility MapReduce messages never
//! use — at the cost of one byte per field. For a `(small int, small int)`
//! pair the message is 4 bytes where the Blaze fast codec needs 2 (§2.3.2).
//!
//! The conventional (Spark-analog) engine shuffles with this codec so the
//! serialization ablation in `benches/ser_codec.rs` isolates exactly the
//! paper's claimed effect.

use super::fastser::{varint_len, zigzag_decode, zigzag_encode, DecodeError};

/// Protobuf wire types (subset used here).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WireType {
    /// Varint-encoded integer.
    Varint = 0,
    /// 8-byte fixed (f64).
    Fixed64 = 1,
    /// Length-delimited (strings, bytes, nested messages).
    LengthDelimited = 2,
    /// 4-byte fixed (f32).
    Fixed32 = 5,
}

impl WireType {
    fn from_u8(v: u8) -> Option<Self> {
        match v {
            0 => Some(WireType::Varint),
            1 => Some(WireType::Fixed64),
            2 => Some(WireType::LengthDelimited),
            5 => Some(WireType::Fixed32),
            _ => None,
        }
    }
}

/// Encode buffer that prefixes every field with a protobuf-style tag.
#[derive(Default, Debug)]
pub struct TaggedWriter {
    buf: Vec<u8>,
    next_field: u32,
}

impl TaggedWriter {
    /// New empty writer; field numbers start at 1 like protobuf.
    pub fn new() -> Self {
        Self { buf: Vec::new(), next_field: 1 }
    }

    /// Encoded bytes.
    pub fn as_bytes(&self) -> &[u8] {
        &self.buf
    }

    /// Take the buffer.
    pub fn take(&mut self) -> Vec<u8> {
        self.next_field = 1;
        std::mem::take(&mut self.buf)
    }

    /// Encoded length.
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    /// True if empty.
    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Reset for reuse.
    pub fn clear(&mut self) {
        self.buf.clear();
        self.next_field = 1;
    }

    fn put_tag(&mut self, wt: WireType) {
        let field = self.next_field;
        self.next_field += 1;
        self.put_varint_raw((u64::from(field) << 3) | wt as u64);
    }

    fn put_varint_raw(&mut self, mut v: u64) {
        while v >= 0x80 {
            self.buf.push((v as u8) | 0x80);
            v >>= 7;
        }
        self.buf.push(v as u8);
    }

    /// Tag + unsigned varint.
    pub fn put_varint(&mut self, v: u64) {
        self.put_tag(WireType::Varint);
        self.put_varint_raw(v);
    }

    /// Tag + zigzag signed varint.
    pub fn put_signed(&mut self, v: i64) {
        self.put_tag(WireType::Varint);
        self.put_varint_raw(zigzag_encode(v));
    }

    /// Tag + fixed64.
    pub fn put_f64(&mut self, v: f64) {
        self.put_tag(WireType::Fixed64);
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Tag + fixed32.
    pub fn put_f32(&mut self, v: f32) {
        self.put_tag(WireType::Fixed32);
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Tag + length-delimited bytes.
    pub fn put_bytes(&mut self, b: &[u8]) {
        self.put_tag(WireType::LengthDelimited);
        self.put_varint_raw(b.len() as u64);
        self.buf.extend_from_slice(b);
    }
}

/// Decode cursor for [`TaggedWriter`] output: checks each field's tag.
#[derive(Debug)]
pub struct TaggedReader<'a> {
    buf: &'a [u8],
    pos: usize,
    next_field: u32,
}

impl<'a> TaggedReader<'a> {
    /// Cursor at the start of `buf`.
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0, next_field: 1 }
    }

    /// True when fully consumed.
    pub fn is_at_end(&self) -> bool {
        self.pos == self.buf.len()
    }

    /// Bytes remaining.
    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn get_varint_raw(&mut self) -> Result<u64, DecodeError> {
        let mut result: u64 = 0;
        let mut shift = 0u32;
        loop {
            let Some(&byte) = self.buf.get(self.pos) else {
                return Err(DecodeError { at: self.pos, what: "varint truncated" });
            };
            self.pos += 1;
            result |= u64::from(byte & 0x7f) << shift;
            if byte & 0x80 == 0 {
                return Ok(result);
            }
            shift += 7;
            if shift > 63 {
                return Err(DecodeError { at: self.pos, what: "varint too long" });
            }
        }
    }

    fn expect_tag(&mut self, want: WireType) -> Result<(), DecodeError> {
        let at = self.pos;
        let tag = self.get_varint_raw()?;
        let field = (tag >> 3) as u32;
        let wt = WireType::from_u8((tag & 7) as u8)
            .ok_or(DecodeError { at, what: "unknown wire type" })?;
        if field != self.next_field {
            return Err(DecodeError { at, what: "unexpected field number" });
        }
        if wt != want {
            return Err(DecodeError { at, what: "wire type mismatch" });
        }
        self.next_field += 1;
        Ok(())
    }

    /// Tagged unsigned varint.
    pub fn get_varint(&mut self) -> Result<u64, DecodeError> {
        self.expect_tag(WireType::Varint)?;
        self.get_varint_raw()
    }

    /// Tagged zigzag signed varint.
    pub fn get_signed(&mut self) -> Result<i64, DecodeError> {
        Ok(zigzag_decode(self.get_varint()?))
    }

    /// Tagged fixed64.
    pub fn get_f64(&mut self) -> Result<f64, DecodeError> {
        self.expect_tag(WireType::Fixed64)?;
        let raw = self.get_exact(8)?;
        Ok(f64::from_le_bytes(raw.try_into().unwrap()))
    }

    /// Tagged fixed32.
    pub fn get_f32(&mut self) -> Result<f32, DecodeError> {
        self.expect_tag(WireType::Fixed32)?;
        let raw = self.get_exact(4)?;
        Ok(f32::from_le_bytes(raw.try_into().unwrap()))
    }

    /// Tagged length-delimited bytes (borrowed).
    pub fn get_bytes(&mut self) -> Result<&'a [u8], DecodeError> {
        self.expect_tag(WireType::LengthDelimited)?;
        let len = self.get_varint_raw()? as usize;
        self.get_exact(len)
    }

    fn get_exact(&mut self, n: usize) -> Result<&'a [u8], DecodeError> {
        if self.remaining() < n {
            return Err(DecodeError { at: self.pos, what: "buffer truncated" });
        }
        let out = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(out)
    }
}

/// Types serializable with the tagged baseline codec.
///
/// Deliberately mirrors [`super::fastser::FastSer`] so the two engines can be
/// swapped under the same workloads for the serialization ablation.
pub trait TaggedSer: Sized {
    /// Append as tagged field(s).
    fn write_tagged(&self, w: &mut TaggedWriter);
    /// Decode tagged field(s).
    fn read_tagged(r: &mut TaggedReader<'_>) -> Result<Self, DecodeError>;

    /// Exact encoded size including tags.
    fn tagged_len(&self) -> usize {
        let mut w = TaggedWriter::new();
        self.write_tagged(&mut w);
        w.len()
    }
}

macro_rules! impl_tagged_uint {
    ($($t:ty),*) => {$(
        impl TaggedSer for $t {
            fn write_tagged(&self, w: &mut TaggedWriter) {
                w.put_varint(*self as u64);
            }
            fn read_tagged(r: &mut TaggedReader<'_>) -> Result<Self, DecodeError> {
                let v = r.get_varint()?;
                <$t>::try_from(v).map_err(|_| DecodeError { at: 0, what: "uint out of range" })
            }
        }
    )*};
}

macro_rules! impl_tagged_sint {
    ($($t:ty),*) => {$(
        impl TaggedSer for $t {
            fn write_tagged(&self, w: &mut TaggedWriter) {
                w.put_signed(*self as i64);
            }
            fn read_tagged(r: &mut TaggedReader<'_>) -> Result<Self, DecodeError> {
                let v = r.get_signed()?;
                <$t>::try_from(v).map_err(|_| DecodeError { at: 0, what: "sint out of range" })
            }
        }
    )*};
}

impl_tagged_uint!(u8, u16, u32, u64, usize);
impl_tagged_sint!(i8, i16, i32, i64, isize);

impl TaggedSer for f64 {
    fn write_tagged(&self, w: &mut TaggedWriter) {
        w.put_f64(*self);
    }
    fn read_tagged(r: &mut TaggedReader<'_>) -> Result<Self, DecodeError> {
        r.get_f64()
    }
}

impl TaggedSer for f32 {
    fn write_tagged(&self, w: &mut TaggedWriter) {
        w.put_f32(*self);
    }
    fn read_tagged(r: &mut TaggedReader<'_>) -> Result<Self, DecodeError> {
        r.get_f32()
    }
}

impl TaggedSer for String {
    fn write_tagged(&self, w: &mut TaggedWriter) {
        w.put_bytes(self.as_bytes());
    }
    fn read_tagged(r: &mut TaggedReader<'_>) -> Result<Self, DecodeError> {
        let bytes = r.get_bytes()?;
        String::from_utf8(bytes.to_vec()).map_err(|_| DecodeError { at: 0, what: "invalid utf-8" })
    }
}

impl<A: TaggedSer, B: TaggedSer> TaggedSer for (A, B) {
    fn write_tagged(&self, w: &mut TaggedWriter) {
        self.0.write_tagged(w);
        self.1.write_tagged(w);
    }
    fn read_tagged(r: &mut TaggedReader<'_>) -> Result<Self, DecodeError> {
        Ok((A::read_tagged(r)?, B::read_tagged(r)?))
    }
}

impl<T: TaggedSer> TaggedSer for Vec<T> {
    fn write_tagged(&self, w: &mut TaggedWriter) {
        // Length as its own tagged field, then each element's fields.
        w.put_varint(self.len() as u64);
        for item in self {
            item.write_tagged(w);
        }
    }
    fn read_tagged(r: &mut TaggedReader<'_>) -> Result<Self, DecodeError> {
        let len = r.get_varint()? as usize;
        let mut out = Vec::with_capacity(len.min(r.remaining().max(1)));
        for _ in 0..len {
            out.push(T::read_tagged(r)?);
        }
        Ok(out)
    }
}

/// Encode a key/value batch with per-pair tagged messages. Each pair is a
/// fresh "message" (field numbers restart), as a shuffle file of protobuf
/// records would be.
pub fn encode_pairs_tagged<K: TaggedSer, V: TaggedSer>(pairs: &[(K, V)]) -> Vec<u8> {
    let mut out = Vec::with_capacity(pairs.len() * 6);
    let mut w = TaggedWriter::new();
    for (k, v) in pairs {
        w.clear();
        k.write_tagged(&mut w);
        v.write_tagged(&mut w);
        // Length-prefix each record (protobuf framing).
        let mut len = w.len() as u64;
        while len >= 0x80 {
            out.push((len as u8) | 0x80);
            len >>= 7;
        }
        out.push(len as u8);
        out.extend_from_slice(w.as_bytes());
    }
    out
}

/// Decode a batch produced by [`encode_pairs_tagged`].
pub fn decode_pairs_tagged<K: TaggedSer, V: TaggedSer>(
    buf: &[u8],
) -> Result<Vec<(K, V)>, DecodeError> {
    let mut out = Vec::new();
    let mut pos = 0usize;
    while pos < buf.len() {
        // record length varint
        let mut len: u64 = 0;
        let mut shift = 0u32;
        loop {
            let Some(&byte) = buf.get(pos) else {
                return Err(DecodeError { at: pos, what: "record length truncated" });
            };
            pos += 1;
            len |= u64::from(byte & 0x7f) << shift;
            if byte & 0x80 == 0 {
                break;
            }
            shift += 7;
        }
        let len = len as usize;
        if buf.len() - pos < len {
            return Err(DecodeError { at: pos, what: "record truncated" });
        }
        let mut r = TaggedReader::new(&buf[pos..pos + len]);
        let k = K::read_tagged(&mut r)?;
        let v = V::read_tagged(&mut r)?;
        out.push((k, v));
        pos += len;
    }
    Ok(out)
}

/// Exact tagged size of `v` including the per-field tag byte(s).
pub fn tagged_varint_field_len(field: u32, v: u64) -> usize {
    varint_len((u64::from(field) << 3) | WireType::Varint as u64) + varint_len(v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_int_pair_is_four_bytes() {
        // The paper's §2.3.2 example: protobuf-style message for
        // (small int, small int) is 4 bytes — 2× the fast codec.
        let pair = (0u64, 1u64);
        assert_eq!(pair.tagged_len(), 4);
    }

    #[test]
    fn tagged_roundtrip_pair() {
        let pair = ("word".to_string(), 42u64);
        let mut w = TaggedWriter::new();
        pair.write_tagged(&mut w);
        let mut r = TaggedReader::new(w.as_bytes());
        assert_eq!(<(String, u64)>::read_tagged(&mut r).unwrap(), pair);
        assert!(r.is_at_end());
    }

    #[test]
    fn wrong_field_order_rejected() {
        // Encode field 1 as varint, then try to read it as f64 (fixed64 tag
        // expected) — the tag check must reject it.
        let mut w = TaggedWriter::new();
        w.put_varint(7);
        let mut r = TaggedReader::new(w.as_bytes());
        assert!(r.get_f64().is_err());
    }

    #[test]
    fn batch_roundtrip_and_overhead() {
        let pairs: Vec<(u64, u64)> = (0..100).map(|i| (i % 5, 1)).collect();
        let buf = encode_pairs_tagged(&pairs);
        assert_eq!(decode_pairs_tagged::<u64, u64>(&buf).unwrap(), pairs);
        // Each record: 1 length byte + 2 tag bytes + 2 value bytes = 5.
        assert_eq!(buf.len(), pairs.len() * 5);
        // Fast codec for the same batch: batch-count varint + 2 bytes/pair.
        let fast = crate::ser::fastser::encode_pairs(&pairs);
        assert!(fast.len() * 2 < buf.len(), "fast {} vs tagged {}", fast.len(), buf.len());
    }

    #[test]
    fn truncated_record_errors() {
        let pairs = vec![(1u64, 2u64)];
        let buf = encode_pairs_tagged(&pairs);
        assert!(decode_pairs_tagged::<u64, u64>(&buf[..buf.len() - 1]).is_err());
    }
}
