//! Fast serialization (paper §2.3.2).
//!
//! Varint/zigzag binary codec in a fixed field order, with **no field tags
//! and no wire types**. Protobuf spends one tag byte per field to support
//! missing fields and arbitrary field order; MapReduce messages always carry
//! every field in the same order, so Blaze drops the tags. For a pair of
//! small integers this halves the message: 2 bytes instead of protobuf's 4.
//!
//! The codec is append-only into a caller-owned `Vec<u8>` ([`Writer`]) and
//! zero-copy on the read side ([`Reader`] borrows the byte slice). Nothing
//! here allocates on the encode hot path beyond the output buffer itself.

use std::collections::HashMap;
use std::hash::Hash;

/// Append-only encode buffer.
///
/// A thin wrapper over `Vec<u8>` so the encode API mirrors [`Reader`]. The
/// buffer can be reused across messages via [`Writer::clear`] to keep the
/// shuffle path allocation-free.
#[derive(Default, Debug)]
pub struct Writer {
    buf: Vec<u8>,
}

impl Writer {
    /// New empty writer.
    pub fn new() -> Self {
        Self::default()
    }

    /// New writer with `cap` bytes preallocated.
    pub fn with_capacity(cap: usize) -> Self {
        Self { buf: Vec::with_capacity(cap) }
    }

    /// Wrap an existing (possibly pooled) buffer; the buffer is cleared.
    /// Pairs with [`crate::util::alloc::BufferPool`] for the "Blaze TCM"
    /// allocator ablation.
    pub fn from_vec(mut buf: Vec<u8>) -> Self {
        buf.clear();
        Self { buf }
    }

    /// Encoded bytes so far.
    pub fn as_bytes(&self) -> &[u8] {
        &self.buf
    }

    /// Take the underlying buffer, leaving the writer empty.
    pub fn take(&mut self) -> Vec<u8> {
        std::mem::take(&mut self.buf)
    }

    /// Number of encoded bytes.
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    /// True if nothing has been written.
    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Reset for reuse without freeing capacity.
    pub fn clear(&mut self) {
        self.buf.clear();
    }

    /// LEB128 unsigned varint: 7 bits per byte, MSB = continuation.
    #[inline]
    pub fn put_varint(&mut self, mut v: u64) {
        while v >= 0x80 {
            self.buf.push((v as u8) | 0x80);
            v >>= 7;
        }
        self.buf.push(v as u8);
    }

    /// Zigzag-mapped signed varint (small magnitudes stay small).
    #[inline]
    pub fn put_signed(&mut self, v: i64) {
        self.put_varint(zigzag_encode(v));
    }

    /// IEEE-754 little-endian f64 (8 bytes; floats do not varint well).
    #[inline]
    pub fn put_f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// IEEE-754 little-endian f32 (4 bytes).
    #[inline]
    pub fn put_f32(&mut self, v: f32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Length-prefixed byte string.
    #[inline]
    pub fn put_bytes(&mut self, b: &[u8]) {
        self.put_varint(b.len() as u64);
        self.buf.extend_from_slice(b);
    }

    /// Raw bytes with no length prefix (caller knows the length).
    #[inline]
    pub fn put_raw(&mut self, b: &[u8]) {
        self.buf.extend_from_slice(b);
    }
}

/// Zero-copy decode cursor over an encoded byte slice.
#[derive(Debug)]
pub struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

/// Decode error: message truncated or malformed.
#[derive(Debug, PartialEq, Eq)]
pub struct DecodeError {
    /// Byte offset at which decoding failed.
    pub at: usize,
    /// Human-readable cause.
    pub what: &'static str,
}

impl std::fmt::Display for DecodeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "decode error at byte {}: {}", self.at, self.what)
    }
}

impl std::error::Error for DecodeError {}

impl<'a> Reader<'a> {
    /// Cursor at the start of `buf`.
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    /// Bytes remaining.
    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    /// True when the cursor has consumed the whole buffer.
    pub fn is_at_end(&self) -> bool {
        self.pos == self.buf.len()
    }

    /// Current byte offset.
    pub fn position(&self) -> usize {
        self.pos
    }

    /// Error unless the whole buffer has been consumed — for buffers that
    /// must hold exactly one message (checkpoint shards, single payloads),
    /// where leftover bytes mean corruption or splicing.
    pub fn expect_end(&self) -> Result<(), DecodeError> {
        if self.is_at_end() {
            Ok(())
        } else {
            Err(DecodeError { at: self.pos, what: "trailing bytes after message" })
        }
    }

    /// Decode an unsigned LEB128 varint.
    ///
    /// Only *minimal* encodings are accepted: a terminal `0x00` byte after
    /// any continuation byte (e.g. `0x80 0x00` for zero) re-encodes the
    /// same value in more bytes and is rejected. [`Writer::put_varint`]
    /// never produces such encodings, so accepting them would let two
    /// different byte strings decode to the same frame — poison for the
    /// byte-identity invariants the equivalence gates rely on.
    #[inline]
    pub fn get_varint(&mut self) -> Result<u64, DecodeError> {
        let mut result: u64 = 0;
        let mut shift = 0u32;
        loop {
            let Some(&byte) = self.buf.get(self.pos) else {
                return Err(DecodeError { at: self.pos, what: "varint truncated" });
            };
            self.pos += 1;
            if byte == 0 && shift != 0 {
                return Err(DecodeError { at: self.pos, what: "varint overlong encoding" });
            }
            if shift == 63 && byte > 1 {
                return Err(DecodeError { at: self.pos, what: "varint overflows u64" });
            }
            result |= u64::from(byte & 0x7f) << shift;
            if byte & 0x80 == 0 {
                return Ok(result);
            }
            shift += 7;
            if shift > 63 {
                return Err(DecodeError { at: self.pos, what: "varint too long" });
            }
        }
    }

    /// Decode a zigzag signed varint.
    #[inline]
    pub fn get_signed(&mut self) -> Result<i64, DecodeError> {
        Ok(zigzag_decode(self.get_varint()?))
    }

    /// Decode a little-endian f64.
    #[inline]
    pub fn get_f64(&mut self) -> Result<f64, DecodeError> {
        let raw = self.get_exact(8)?;
        Ok(f64::from_le_bytes(raw.try_into().unwrap()))
    }

    /// Decode a little-endian f32.
    #[inline]
    pub fn get_f32(&mut self) -> Result<f32, DecodeError> {
        let raw = self.get_exact(4)?;
        Ok(f32::from_le_bytes(raw.try_into().unwrap()))
    }

    /// Decode a length-prefixed byte string (borrowed).
    #[inline]
    pub fn get_bytes(&mut self) -> Result<&'a [u8], DecodeError> {
        let len = self.get_varint()? as usize;
        self.get_exact(len)
    }

    #[inline]
    fn get_exact(&mut self, n: usize) -> Result<&'a [u8], DecodeError> {
        if self.remaining() < n {
            return Err(DecodeError { at: self.pos, what: "buffer truncated" });
        }
        let out = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(out)
    }
}

/// Map signed to unsigned so small magnitudes encode in one byte.
#[inline]
pub fn zigzag_encode(v: i64) -> u64 {
    ((v << 1) ^ (v >> 63)) as u64
}

/// Inverse of [`zigzag_encode`].
#[inline]
pub fn zigzag_decode(v: u64) -> i64 {
    ((v >> 1) as i64) ^ -((v & 1) as i64)
}

/// Types serializable with the Blaze fast codec (paper §2.3.2).
///
/// Implemented for all primitive key/value types, strings, tuples and
/// vectors. Custom key/value types implement `write`/`read` in a fixed field
/// order — mirroring the paper's "users only need to provide the
/// corresponding serialize/parse methods".
pub trait FastSer: Sized {
    /// Append this value to `w` in the fixed field order.
    fn write(&self, w: &mut Writer);
    /// Decode one value from `r`.
    fn read(r: &mut Reader<'_>) -> Result<Self, DecodeError>;

    /// Encoded size in bytes (exact; used by the network byte accounting).
    fn encoded_len(&self) -> usize {
        let mut w = Writer::new();
        self.write(&mut w);
        w.len()
    }
}

macro_rules! impl_fastser_uint {
    ($($t:ty),*) => {$(
        impl FastSer for $t {
            #[inline]
            fn write(&self, w: &mut Writer) {
                w.put_varint(*self as u64);
            }
            #[inline]
            fn read(r: &mut Reader<'_>) -> Result<Self, DecodeError> {
                let v = r.get_varint()?;
                <$t>::try_from(v).map_err(|_| DecodeError { at: r.position(), what: "uint out of range" })
            }
            #[inline]
            fn encoded_len(&self) -> usize {
                varint_len(*self as u64)
            }
        }
    )*};
}

macro_rules! impl_fastser_sint {
    ($($t:ty),*) => {$(
        impl FastSer for $t {
            #[inline]
            fn write(&self, w: &mut Writer) {
                w.put_signed(*self as i64);
            }
            #[inline]
            fn read(r: &mut Reader<'_>) -> Result<Self, DecodeError> {
                let v = r.get_signed()?;
                <$t>::try_from(v).map_err(|_| DecodeError { at: r.position(), what: "sint out of range" })
            }
            #[inline]
            fn encoded_len(&self) -> usize {
                varint_len(zigzag_encode(*self as i64))
            }
        }
    )*};
}

impl_fastser_uint!(u8, u16, u32, u64, usize);
impl_fastser_sint!(i8, i16, i32, i64, isize);

/// Exact LEB128 length of `v`.
#[inline]
pub fn varint_len(v: u64) -> usize {
    // 1 + floor(bits/7); bits of 0 treated as 1.
    let bits = 64 - (v | 1).leading_zeros() as usize;
    bits.div_ceil(7).max(1)
}

impl FastSer for bool {
    #[inline]
    fn write(&self, w: &mut Writer) {
        w.put_varint(u64::from(*self));
    }
    #[inline]
    fn read(r: &mut Reader<'_>) -> Result<Self, DecodeError> {
        Ok(r.get_varint()? != 0)
    }
    #[inline]
    fn encoded_len(&self) -> usize {
        1
    }
}

impl FastSer for f64 {
    #[inline]
    fn write(&self, w: &mut Writer) {
        w.put_f64(*self);
    }
    #[inline]
    fn read(r: &mut Reader<'_>) -> Result<Self, DecodeError> {
        r.get_f64()
    }
    #[inline]
    fn encoded_len(&self) -> usize {
        8
    }
}

impl FastSer for f32 {
    #[inline]
    fn write(&self, w: &mut Writer) {
        w.put_f32(*self);
    }
    #[inline]
    fn read(r: &mut Reader<'_>) -> Result<Self, DecodeError> {
        r.get_f32()
    }
    #[inline]
    fn encoded_len(&self) -> usize {
        4
    }
}

impl FastSer for String {
    #[inline]
    fn write(&self, w: &mut Writer) {
        w.put_bytes(self.as_bytes());
    }
    #[inline]
    fn read(r: &mut Reader<'_>) -> Result<Self, DecodeError> {
        let at = r.position();
        let bytes = r.get_bytes()?;
        String::from_utf8(bytes.to_vec())
            .map_err(|_| DecodeError { at, what: "invalid utf-8" })
    }
    #[inline]
    fn encoded_len(&self) -> usize {
        varint_len(self.len() as u64) + self.len()
    }
}

impl<T: FastSer> FastSer for Vec<T> {
    fn write(&self, w: &mut Writer) {
        w.put_varint(self.len() as u64);
        for item in self {
            item.write(w);
        }
    }
    fn read(r: &mut Reader<'_>) -> Result<Self, DecodeError> {
        let len = r.get_varint()? as usize;
        // Guard against hostile length prefixes: cap the preallocation.
        let mut out = Vec::with_capacity(len.min(r.remaining().max(1)));
        for _ in 0..len {
            out.push(T::read(r)?);
        }
        Ok(out)
    }
    fn encoded_len(&self) -> usize {
        varint_len(self.len() as u64) + self.iter().map(FastSer::encoded_len).sum::<usize>()
    }
}

impl<A: FastSer, B: FastSer> FastSer for (A, B) {
    #[inline]
    fn write(&self, w: &mut Writer) {
        self.0.write(w);
        self.1.write(w);
    }
    #[inline]
    fn read(r: &mut Reader<'_>) -> Result<Self, DecodeError> {
        Ok((A::read(r)?, B::read(r)?))
    }
    #[inline]
    fn encoded_len(&self) -> usize {
        self.0.encoded_len() + self.1.encoded_len()
    }
}

impl<A: FastSer, B: FastSer, C: FastSer> FastSer for (A, B, C) {
    #[inline]
    fn write(&self, w: &mut Writer) {
        self.0.write(w);
        self.1.write(w);
        self.2.write(w);
    }
    #[inline]
    fn read(r: &mut Reader<'_>) -> Result<Self, DecodeError> {
        Ok((A::read(r)?, B::read(r)?, C::read(r)?))
    }
    #[inline]
    fn encoded_len(&self) -> usize {
        self.0.encoded_len() + self.1.encoded_len() + self.2.encoded_len()
    }
}

impl<K, V> FastSer for HashMap<K, V>
where
    K: FastSer + Eq + Hash,
    V: FastSer,
{
    fn write(&self, w: &mut Writer) {
        w.put_varint(self.len() as u64);
        for (k, v) in self {
            k.write(w);
            v.write(w);
        }
    }
    fn read(r: &mut Reader<'_>) -> Result<Self, DecodeError> {
        let len = r.get_varint()? as usize;
        let mut out = HashMap::with_capacity(len.min(r.remaining().max(1)));
        for _ in 0..len {
            let k = K::read(r)?;
            let v = V::read(r)?;
            out.insert(k, v);
        }
        Ok(out)
    }
    fn encoded_len(&self) -> usize {
        varint_len(self.len() as u64)
            + self
                .iter()
                .map(|(k, v)| k.encoded_len() + v.encoded_len())
                .sum::<usize>()
    }
}

/// Encode a whole key/value batch into one message (fixed order, no tags).
pub fn encode_pairs<K: FastSer, V: FastSer>(pairs: &[(K, V)]) -> Vec<u8> {
    encode_pairs_into(pairs, Vec::with_capacity(pairs.len() * 4))
}

/// [`encode_pairs`] into a caller-provided (possibly pooled) buffer.
pub fn encode_pairs_into<K: FastSer, V: FastSer>(pairs: &[(K, V)], buf: Vec<u8>) -> Vec<u8> {
    let mut w = Writer::from_vec(buf);
    write_pairs(&mut w, pairs.len(), pairs.iter().map(|(k, v)| (k, v)));
    w.take()
}

/// Append one batch frame — count varint, then each pair in fixed order —
/// from any pair iterator. The single definition of the batch wire framing
/// shared by [`encode_pairs`]/[`decode_pairs`] and clone-free producers
/// (e.g. checkpointing a hash shard straight from its iterator).
pub fn write_pairs<'a, K, V>(
    w: &mut Writer,
    len: usize,
    pairs: impl Iterator<Item = (&'a K, &'a V)>,
) where
    K: FastSer + 'a,
    V: FastSer + 'a,
{
    w.put_varint(len as u64);
    for (k, v) in pairs {
        k.write(w);
        v.write(w);
    }
}

/// Decode a batch produced by [`encode_pairs`]. Trailing bytes after the
/// batch are ignored (streams may concatenate further messages); use
/// [`decode_pairs_exact`] when the buffer must hold exactly one batch.
pub fn decode_pairs<K: FastSer, V: FastSer>(buf: &[u8]) -> Result<Vec<(K, V)>, DecodeError> {
    let mut r = Reader::new(buf);
    decode_pairs_from(&mut r)
}

/// Decode one batch and require the buffer be fully consumed.
///
/// Checkpoint shards and single-message payloads are exactly one batch
/// long; leftover bytes there mean the buffer was corrupted or spliced, so
/// this variant rejects them instead of silently dropping data.
pub fn decode_pairs_exact<K: FastSer, V: FastSer>(
    buf: &[u8],
) -> Result<Vec<(K, V)>, DecodeError> {
    let mut r = Reader::new(buf);
    let out = decode_pairs_from(&mut r)?;
    r.expect_end()?;
    Ok(out)
}

/// Decode one batch from an open cursor, leaving it just past the batch.
fn decode_pairs_from<K: FastSer, V: FastSer>(
    r: &mut Reader<'_>,
) -> Result<Vec<(K, V)>, DecodeError> {
    let n = r.get_varint()? as usize;
    let mut out = Vec::with_capacity(n.min(r.remaining().max(1)));
    for _ in 0..n {
        let k = K::read(r)?;
        let v = V::read(r)?;
        out.push((k, v));
    }
    Ok(out)
}

// ---- Checksummed frames (lossy-transport wire unit) ------------------------
//
// The bare pair-batch encoding cannot promise to reject arbitrary bit
// corruption: a flipped bit inside a varint *value* still decodes to a
// well-formed (wrong) number. A transport that may corrupt bytes therefore
// wraps each physical frame in a 16-byte header — fixed-width little-endian
// payload length + FNV-1a checksum — and verifies both before the payload is
// allowed anywhere near the pair decoder. FNV-1a's per-byte step
// (`h = (h ^ byte) * PRIME`) composes xor-with-constant and multiply-by-odd,
// both bijections on u64, so two payloads differing in exactly one byte can
// never collide: every single-bit (indeed single-byte) corruption of a valid
// frame — header or payload — is detected with certainty, not probability.

/// Bytes of frame header prepended by [`encode_frame_into`]: 8-byte LE
/// payload length + 8-byte LE FNV-1a checksum.
pub const FRAME_HEADER_BYTES: usize = 16;

/// FNV-1a 64-bit checksum over `bytes`.
#[inline]
pub fn frame_checksum(bytes: &[u8]) -> u64 {
    const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const PRIME: u64 = 0x0000_0100_0000_01b3;
    let mut h = OFFSET;
    for &b in bytes {
        h = (h ^ u64::from(b)).wrapping_mul(PRIME);
    }
    h
}

/// Wrap `payload` in a checksummed frame, writing into a caller-provided
/// (possibly pooled) buffer. The buffer is length-reset first so a recycled
/// longer buffer can never leak stale tail bytes into a shorter frame.
pub fn encode_frame_into(payload: &[u8], mut buf: Vec<u8>) -> Vec<u8> {
    buf.clear();
    buf.reserve(FRAME_HEADER_BYTES + payload.len());
    buf.extend_from_slice(&(payload.len() as u64).to_le_bytes());
    buf.extend_from_slice(&frame_checksum(payload).to_le_bytes());
    buf.extend_from_slice(payload);
    buf
}

/// [`encode_frame_into`] with a fresh buffer.
pub fn encode_frame(payload: &[u8]) -> Vec<u8> {
    encode_frame_into(payload, Vec::new())
}

/// Verify a checksummed frame and return its payload slice.
///
/// The slice must hold exactly one frame: the header length must equal the
/// bytes that actually follow (no over-read from a corrupted length prefix,
/// no silent truncation) and the checksum must match. Any single-bit
/// corruption — length, checksum, or payload — yields a structured
/// [`DecodeError`], never a panic or a misparse.
pub fn decode_frame(frame: &[u8]) -> Result<&[u8], DecodeError> {
    if frame.len() < FRAME_HEADER_BYTES {
        return Err(DecodeError { at: frame.len(), what: "frame header truncated" });
    }
    let len = u64::from_le_bytes(frame[0..8].try_into().unwrap());
    let sum = u64::from_le_bytes(frame[8..16].try_into().unwrap());
    let payload = &frame[FRAME_HEADER_BYTES..];
    if len != payload.len() as u64 {
        return Err(DecodeError { at: 0, what: "frame length mismatch" });
    }
    if frame_checksum(payload) != sum {
        return Err(DecodeError { at: 8, what: "frame checksum mismatch" });
    }
    Ok(payload)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn varint_roundtrip_boundaries() {
        let cases = [0u64, 1, 127, 128, 255, 300, 16383, 16384, u32::MAX as u64, u64::MAX];
        for &v in &cases {
            let mut w = Writer::new();
            w.put_varint(v);
            assert_eq!(w.len(), varint_len(v), "len mismatch for {v}");
            let mut r = Reader::new(w.as_bytes());
            assert_eq!(r.get_varint().unwrap(), v);
            assert!(r.is_at_end());
        }
    }

    #[test]
    fn zigzag_small_magnitudes_are_small() {
        assert_eq!(zigzag_encode(0), 0);
        assert_eq!(zigzag_encode(-1), 1);
        assert_eq!(zigzag_encode(1), 2);
        assert_eq!(zigzag_encode(-2), 3);
        for v in [-1000i64, -1, 0, 1, 1000, i64::MIN, i64::MAX] {
            assert_eq!(zigzag_decode(zigzag_encode(v)), v);
        }
    }

    #[test]
    fn small_int_pair_is_two_bytes() {
        // The paper's headline: (small int, small int) = 2 bytes with
        // fastser vs 4 with protobuf-style tags.
        let pair = (0u64, 1u64);
        assert_eq!(pair.encoded_len(), 2);
        let mut w = Writer::new();
        pair.write(&mut w);
        assert_eq!(w.len(), 2);
    }

    #[test]
    fn string_roundtrip() {
        let s = "hello blaze — ünïcode".to_string();
        let mut w = Writer::new();
        s.write(&mut w);
        assert_eq!(w.len(), s.encoded_len());
        let mut r = Reader::new(w.as_bytes());
        assert_eq!(String::read(&mut r).unwrap(), s);
    }

    #[test]
    fn vec_and_tuple_roundtrip() {
        let v: Vec<(String, i64)> = vec![("a".into(), -5), ("bb".into(), 700)];
        let mut w = Writer::new();
        v.write(&mut w);
        let mut r = Reader::new(w.as_bytes());
        assert_eq!(Vec::<(String, i64)>::read(&mut r).unwrap(), v);
        assert!(r.is_at_end());
    }

    #[test]
    fn floats_roundtrip_bitexact() {
        for v in [0.0f64, -0.0, 1.5, f64::MAX, f64::MIN_POSITIVE, f64::NAN] {
            let mut w = Writer::new();
            v.write(&mut w);
            let mut r = Reader::new(w.as_bytes());
            let back = f64::read(&mut r).unwrap();
            assert_eq!(back.to_bits(), v.to_bits());
        }
    }

    #[test]
    fn truncated_buffer_errors() {
        let mut w = Writer::new();
        (12345u64, "hello".to_string()).write(&mut w);
        let bytes = w.as_bytes();
        for cut in 0..bytes.len() {
            let mut r = Reader::new(&bytes[..cut]);
            assert!(<(u64, String)>::read(&mut r).is_err(), "cut at {cut} should fail");
        }
    }

    #[test]
    fn continuation_bit_overflow_rejected() {
        // 11 continuation bytes: too long for u64.
        let bad = [0xffu8; 11];
        let mut r = Reader::new(&bad);
        assert!(r.get_varint().is_err());
    }

    #[test]
    fn overlong_varint_encodings_rejected() {
        // Each of these re-encodes a small value in extra bytes (terminal
        // 0x00 after a continuation byte) — legal LEB128 shapes, but not
        // minimal, so the decoder must reject them.
        let cases: [&[u8]; 4] =
            [&[0x80, 0x00], &[0xff, 0x00], &[0x80, 0x80, 0x00], &[0x81, 0x80, 0x00]];
        for bad in cases {
            let mut r = Reader::new(bad);
            let err = r.get_varint().unwrap_err();
            assert_eq!(err.what, "varint overlong encoding", "input {bad:?}");
        }
        // The single-byte zero IS the minimal encoding of 0.
        let mut r = Reader::new(&[0x00]);
        assert_eq!(r.get_varint().unwrap(), 0);
        // 0x80 continuation bytes are legal when the terminal byte is
        // non-zero: this is the minimal encoding of 16384.
        let mut r = Reader::new(&[0x80, 0x80, 0x01]);
        assert_eq!(r.get_varint().unwrap(), 16384);
    }

    #[test]
    fn encode_decode_pairs_batch() {
        let pairs: Vec<(u32, u64)> = (0..1000).map(|i| (i % 7, u64::from(i) * 3)).collect();
        let buf = encode_pairs(&pairs);
        assert_eq!(decode_pairs::<u32, u64>(&buf).unwrap(), pairs);
    }

    #[test]
    fn write_pairs_matches_encode_pairs_framing() {
        let pairs: Vec<(String, u64)> = vec![("a".into(), 1), ("bb".into(), 300)];
        let mut w = Writer::new();
        write_pairs(&mut w, pairs.len(), pairs.iter().map(|(k, v)| (k, v)));
        assert_eq!(w.as_bytes(), encode_pairs(&pairs).as_slice());
        assert_eq!(decode_pairs_exact::<String, u64>(w.as_bytes()).unwrap(), pairs);
    }

    #[test]
    fn exact_decode_rejects_trailing_bytes() {
        let pairs: Vec<(u64, u64)> = vec![(1, 2), (3, 4)];
        let mut buf = encode_pairs(&pairs);
        assert_eq!(decode_pairs_exact::<u64, u64>(&buf).unwrap(), pairs);
        buf.push(0x00); // spliced/corrupt tail
        assert_eq!(decode_pairs::<u64, u64>(&buf).unwrap(), pairs, "lenient keeps working");
        let err = decode_pairs_exact::<u64, u64>(&buf).unwrap_err();
        assert_eq!(err.what, "trailing bytes after message");
    }

    #[test]
    fn exact_decode_rejects_every_truncation() {
        let pairs: Vec<(String, u64)> = vec![("alpha".into(), 1), ("beta".into(), 300)];
        let buf = encode_pairs(&pairs);
        for cut in 0..buf.len() {
            assert!(
                decode_pairs_exact::<String, u64>(&buf[..cut]).is_err(),
                "cut at {cut} must fail"
            );
        }
    }

    #[test]
    fn exact_decode_rejects_corrupt_count() {
        // Count claims 5 pairs but only 2 follow: must err, not panic.
        let pairs: Vec<(u64, u64)> = vec![(1, 2), (3, 4)];
        let mut buf = encode_pairs(&pairs);
        buf[0] = 5;
        assert!(decode_pairs_exact::<u64, u64>(&buf).is_err());
        assert!(decode_pairs::<u64, u64>(&buf).is_err());
    }

    #[test]
    fn hashmap_roundtrip() {
        let mut m = HashMap::new();
        m.insert("x".to_string(), 1u64);
        m.insert("yy".to_string(), 2u64);
        let mut w = Writer::new();
        m.write(&mut w);
        let mut r = Reader::new(w.as_bytes());
        assert_eq!(HashMap::<String, u64>::read(&mut r).unwrap(), m);
    }

    // ---- SplitRng-driven roundtrip fuzzing -----------------------------

    use crate::util::rng::SplitRng;

    fn random_string(rng: &mut SplitRng, max_len: u64) -> String {
        let len = rng.below(max_len + 1) as usize; // empty strings included
        (0..len)
            .map(|_| char::from(b'a' + (rng.below(26) as u8)))
            .collect()
    }

    #[test]
    fn fuzz_pair_batches_roundtrip_and_reject_corruption() {
        let mut rng = SplitRng::new(0xF0_55ED, 0);
        for case in 0..200 {
            let n = rng.below(40) as usize; // empty batches included
            let pairs: Vec<(String, i64)> = (0..n)
                .map(|_| {
                    let k = random_string(&mut rng, 12);
                    // Full signed range, zigzag boundaries included.
                    let v = rng.next_u64() as i64;
                    (k, v)
                })
                .collect();
            let buf = encode_pairs(&pairs);
            // Exact length accounting: the encoded batch is the count
            // varint plus each pair's own encoded_len.
            let expect_len = varint_len(pairs.len() as u64)
                + pairs.iter().map(FastSer::encoded_len).sum::<usize>();
            assert_eq!(buf.len(), expect_len, "case {case}: encoded_len drifted");
            assert_eq!(
                decode_pairs_exact::<String, i64>(&buf).unwrap(),
                pairs,
                "case {case}: roundtrip"
            );
            // Truncation at a random cut must error, never panic or
            // silently succeed (a shorter buffer cannot hold the batch).
            if !buf.is_empty() {
                let cut = rng.below(buf.len() as u64) as usize;
                assert!(
                    decode_pairs_exact::<String, i64>(&buf[..cut]).is_err(),
                    "case {case}: cut {cut}/{} accepted",
                    buf.len()
                );
            }
            // Overlong buffers: exact decode rejects, lenient ignores.
            let mut noisy = buf.clone();
            noisy.extend_from_slice(&[0u8; 3]);
            assert!(decode_pairs_exact::<String, i64>(&noisy).is_err(), "case {case}");
            assert_eq!(decode_pairs::<String, i64>(&noisy).unwrap(), pairs, "case {case}");
        }
    }

    #[test]
    fn fuzz_empty_payload_shapes() {
        // A zero-pair batch is one byte (count 0) and decodes exactly.
        let empty: Vec<(String, u64)> = Vec::new();
        let buf = encode_pairs(&empty);
        assert_eq!(buf, vec![0u8]);
        assert_eq!(decode_pairs_exact::<String, u64>(&buf).unwrap(), empty);
        // A zero-length buffer is a truncated count, not an empty batch.
        assert!(decode_pairs_exact::<String, u64>(&[]).is_err());
        // Pairs of empty payloads (empty keys, zero values) roundtrip.
        let hollow: Vec<(String, u64)> = vec![(String::new(), 0); 17];
        let buf = encode_pairs(&hollow);
        assert_eq!(buf.len(), 1 + 2 * 17, "1 count byte + 2 bytes per hollow pair");
        assert_eq!(decode_pairs_exact::<String, u64>(&buf).unwrap(), hollow);
    }

    // ---- Checksummed-frame hardening -----------------------------------

    #[test]
    fn frame_roundtrip_and_header_shape() {
        let payload = encode_pairs(&[(1u64, 2u64), (3, 4)]);
        let frame = encode_frame(&payload);
        assert_eq!(frame.len(), FRAME_HEADER_BYTES + payload.len());
        assert_eq!(decode_frame(&frame).unwrap(), payload.as_slice());
        // Empty payloads are valid frames.
        let empty = encode_frame(&[]);
        assert_eq!(empty.len(), FRAME_HEADER_BYTES);
        assert_eq!(decode_frame(&empty).unwrap(), &[] as &[u8]);
        // Sub-header buffers are a structured error.
        assert_eq!(decode_frame(&frame[..7]).unwrap_err().what, "frame header truncated");
    }

    #[test]
    fn frame_rejects_every_single_bit_flip_exhaustively() {
        // The lossy transport's corruption model flips one bit per corrupt
        // attempt; the receiver must reject *every* such frame. Exhaustive
        // over all bit positions of a realistic frame — header included.
        let mut rng = SplitRng::new(0xC0FFEE, 0);
        let pairs: Vec<(String, i64)> = (0..20)
            .map(|_| (random_string(&mut rng, 10), rng.next_u64() as i64))
            .collect();
        let frame = encode_frame(&encode_pairs(&pairs));
        for bit in 0..frame.len() * 8 {
            let mut bad = frame.clone();
            bad[bit / 8] ^= 1 << (bit % 8);
            assert!(
                decode_frame(&bad).is_err(),
                "bit flip at {bit} (byte {}) accepted",
                bit / 8
            );
        }
    }

    #[test]
    fn frame_rejects_sampled_bit_flips_of_large_payloads() {
        let mut rng = SplitRng::new(0xC0FFEE, 1);
        let payload: Vec<u8> = (0..128 * 1024).map(|_| rng.next_u64() as u8).collect();
        let frame = encode_frame(&payload);
        assert_eq!(decode_frame(&frame).unwrap(), payload.as_slice());
        for _ in 0..2000 {
            let bit = rng.below((frame.len() * 8) as u64) as usize;
            let mut bad = frame.clone();
            bad[bit / 8] ^= 1 << (bit % 8);
            assert!(decode_frame(&bad).is_err(), "bit flip at {bit} accepted");
        }
    }

    #[test]
    fn frame_rejects_truncation_and_extension() {
        let frame = encode_frame(b"blaze frame payload");
        for cut in 0..frame.len() {
            assert!(decode_frame(&frame[..cut]).is_err(), "cut {cut} accepted");
        }
        let mut long = frame.clone();
        long.push(0);
        assert_eq!(decode_frame(&long).unwrap_err().what, "frame length mismatch");
    }

    #[test]
    fn frame_length_prefix_cannot_over_read() {
        // A corrupted length prefix claiming more bytes than follow must be
        // rejected up front — the payload slice is never sized from the
        // untrusted header.
        let mut frame = encode_frame(b"short");
        frame[0] = 0xFF;
        frame[7] = 0x7F;
        assert_eq!(decode_frame(&frame).unwrap_err().what, "frame length mismatch");
    }

    #[test]
    fn encode_frame_into_length_resets_pooled_buffers() {
        // Regression (retry path): a pooled buffer that previously held a
        // longer frame must not leak stale tail bytes into a shorter one.
        let mut stale = encode_frame(&[0xAAu8; 256]);
        assert!(stale.len() > FRAME_HEADER_BYTES + 4);
        stale.extend_from_slice(&[0xBB; 32]); // simulate un-cleared reuse
        let frame = encode_frame_into(b"tiny", stale);
        assert_eq!(frame.len(), FRAME_HEADER_BYTES + 4);
        assert_eq!(decode_frame(&frame).unwrap(), b"tiny");
    }

    #[test]
    fn fuzz_single_giant_value() {
        // One pair whose value dwarfs the frame: length prefixes must hold
        // up and truncation anywhere inside the payload must error.
        let mut rng = SplitRng::new(0xB16, 1);
        let giant: String = (0..256 * 1024)
            .map(|_| char::from(b'a' + (rng.below(26) as u8)))
            .collect();
        let pairs = vec![(42u64, giant)];
        let buf = encode_pairs(&pairs);
        assert!(buf.len() > 256 * 1024);
        assert_eq!(decode_pairs_exact::<u64, String>(&buf).unwrap(), pairs);
        for cut in [1usize, 5, 1024, buf.len() / 2, buf.len() - 1] {
            assert!(
                decode_pairs_exact::<u64, String>(&buf[..cut]).is_err(),
                "giant-value cut {cut} accepted"
            );
        }
    }
}
