//! Serialization codecs (paper §2.3.2).
//!
//! Blaze ships two wire formats:
//!
//! * [`fastser`] — the paper's *fast serialization*: varint/zigzag encoding
//!   in a **fixed field order with no field tags and no wire types**. A
//!   `(small int key, small int value)` pair costs 2 bytes. This is the
//!   codec used by the eager engine's shuffle.
//! * [`tagged`] — the protobuf-analog baseline: every field is prefixed with
//!   a `(field_number << 3) | wire_type` tag byte, exactly like Protocol
//!   Buffers. The same small-int pair costs 4 bytes (2× larger), which is
//!   the paper's headline serialization comparison. The conventional
//!   (Spark-analog) engine shuffles with this codec.

pub mod fastser;
pub mod tagged;

pub use fastser::{FastSer, Reader, Writer};
pub use tagged::{TaggedReader, TaggedSer, TaggedWriter};
