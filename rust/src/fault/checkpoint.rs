//! Target checkpointing: per-shard snapshots with a manifest.
//!
//! A [`Checkpoint`] captures a reduce target's per-node shards as
//! [`crate::ser::fastser`]-encoded buffers plus a [`CheckpointManifest`]
//! describing when it was taken and which `(block, shard)` partials it
//! already contains (the commit ledger). The recoverable engine
//! ([`super::engine`]) replicates each shard's bytes to the driver
//! (node 0, the stable store — never killed) through the flow model, so
//! checkpoint cost shows up in the virtual makespan and a replica cannot
//! be lost to a later failure; a dead node's shard restores driver→node
//! from the latest snapshot.
//!
//! Targets opt in through [`Recover`]: `snapshot_shard` / `restore_shard`
//! / `lose_shard`. Driver-resident targets (`Vec<V>`, gathered at node 0)
//! return `None` from `snapshot_shard` — the driver is durable and node 0
//! is never killed, so there is nothing to snapshot.

use std::collections::BTreeSet;

use crate::ser::fastser::DecodeError;

/// How a reduce target participates in checkpointing and recovery.
///
/// Implemented by [`crate::containers::DistHashMap`] (hash shards),
/// [`crate::containers::DistVector`] (block shards) and `Vec<V>`
/// (driver-resident, durable).
pub trait Recover {
    /// Serialized content of `node`'s shard, or `None` when the shard is
    /// driver-resident and never lost.
    fn snapshot_shard(&self, node: usize) -> Option<Vec<u8>>;

    /// Replace `node`'s shard with a buffer from [`Recover::snapshot_shard`].
    /// Must reject truncated or corrupt buffers rather than panicking.
    fn restore_shard(&mut self, node: usize, bytes: &[u8]) -> Result<(), DecodeError>;

    /// Drop `node`'s shard content (simulates losing the worker's memory).
    fn lose_shard(&mut self, node: usize);

    /// Re-home every key owned by a `dead` node onto the survivors, so no
    /// key routes to a dead node afterwards. Returns the executed moves as
    /// `(src, dst, serialized_bytes)` flows for the caller to charge
    /// through its network model, or `None` when the target cannot re-home
    /// keys (block-addressed or driver-resident targets) and recovery must
    /// keep the hot-standby restore policy instead.
    ///
    /// Implementations must not re-reduce values — evacuation relocates
    /// entries, it never changes them — so results stay byte-identical
    /// under either recovery policy.
    fn evacuate_dead(&mut self, dead: &[usize]) -> Option<Vec<(usize, usize, u64)>> {
        let _ = dead;
        None
    }
}

/// `Vec<V>` targets gather at the driver (node 0, never killed): durable,
/// nothing to snapshot or lose.
impl<V> Recover for Vec<V> {
    fn snapshot_shard(&self, _node: usize) -> Option<Vec<u8>> {
        None
    }

    fn restore_shard(&mut self, _node: usize, _bytes: &[u8]) -> Result<(), DecodeError> {
        Err(DecodeError { at: 0, what: "driver-resident target has no shards to restore" })
    }

    fn lose_shard(&mut self, _node: usize) {}
}

/// Commit ledger: the set of `(block, shard)` partials already reduced
/// into the target. A `BTreeSet` so iteration (and therefore recovery
/// replay order) is deterministic.
pub type Ledger = BTreeSet<(usize, usize)>;

/// Descriptive header of one checkpoint.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CheckpointManifest {
    /// Globally committed block count when the snapshot was taken
    /// (0 = the mandatory job-start checkpoint).
    pub at_commit: usize,
    /// Encoded size of each node's shard (`None` = driver-resident).
    pub shard_bytes: Vec<Option<u64>>,
}

/// One captured checkpoint: manifest + shard buffers + ledger state.
#[derive(Debug, Clone)]
pub struct Checkpoint {
    /// Snapshot header.
    pub manifest: CheckpointManifest,
    /// Per-node encoded shard content.
    pub shards: Vec<Option<Vec<u8>>>,
    /// Ledger state at snapshot time — partials the snapshot contains.
    pub ledger: Ledger,
}

impl Checkpoint {
    /// Capture every shard of `target` on an `nodes`-node cluster.
    pub fn capture<T: Recover + ?Sized>(
        target: &T,
        nodes: usize,
        at_commit: usize,
        ledger: &Ledger,
    ) -> Self {
        let shards: Vec<Option<Vec<u8>>> =
            (0..nodes).map(|n| target.snapshot_shard(n)).collect();
        let manifest = CheckpointManifest {
            at_commit,
            shard_bytes: shards.iter().map(|s| s.as_ref().map(|b| b.len() as u64)).collect(),
        };
        Self { manifest, shards, ledger: ledger.clone() }
    }

    /// Total bytes across all captured shards.
    pub fn total_bytes(&self) -> u64 {
        self.manifest.shard_bytes.iter().flatten().sum()
    }

    /// Restore `node`'s shard into `target`; returns the bytes moved, or 0
    /// when the shard is driver-resident (nothing to restore).
    pub fn restore_shard_into<T: Recover + ?Sized>(
        &self,
        target: &mut T,
        node: usize,
    ) -> Result<u64, DecodeError> {
        match &self.shards[node] {
            Some(bytes) => {
                target.restore_shard(node, bytes)?;
                Ok(bytes.len() as u64)
            }
            None => Ok(0),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::containers::DistHashMap;
    use crate::coordinator::cluster::Cluster;

    fn populated_map(c: &Cluster) -> DistHashMap<String, u64> {
        let mut m = DistHashMap::new(c);
        for i in 0..200u64 {
            m.insert(format!("key{i}"), i);
        }
        m
    }

    #[test]
    fn capture_restore_roundtrip() {
        let c = Cluster::local(3, 2);
        let mut m = populated_map(&c);
        let before = m.collect();
        let ckpt = Checkpoint::capture(&m, 3, 0, &Ledger::new());
        assert!(ckpt.total_bytes() > 0);
        assert_eq!(ckpt.manifest.at_commit, 0);
        // Lose and restore every shard; content must be identical.
        for node in 0..3 {
            m.lose_shard(node);
        }
        assert!(m.is_empty());
        for node in 0..3 {
            ckpt.restore_shard_into(&mut m, node).unwrap();
        }
        assert_eq!(m.collect(), before);
    }

    #[test]
    fn manifest_sizes_match_shards() {
        let c = Cluster::local(4, 1);
        let m = populated_map(&c);
        let ckpt = Checkpoint::capture(&m, 4, 7, &Ledger::new());
        for (size, shard) in ckpt.manifest.shard_bytes.iter().zip(&ckpt.shards) {
            assert_eq!(*size, shard.as_ref().map(|b| b.len() as u64));
        }
        assert_eq!(ckpt.manifest.at_commit, 7);
    }

    #[test]
    fn truncated_shard_rejected_not_panicking() {
        let c = Cluster::local(2, 1);
        let mut m = populated_map(&c);
        let ckpt = Checkpoint::capture(&m, 2, 0, &Ledger::new());
        let bytes = ckpt.shards[0].as_ref().unwrap();
        // Every truncation of a non-empty shard must surface as Err.
        assert!(!bytes.is_empty());
        for cut in 0..bytes.len().min(32) {
            assert!(m.restore_shard(0, &bytes[..cut]).is_err(), "cut {cut} accepted");
        }
        // Trailing garbage is corruption too.
        let mut noisy = bytes.clone();
        noisy.extend_from_slice(&[0x7f, 0x7f]);
        assert!(m.restore_shard(0, &noisy).is_err());
    }

    #[test]
    fn driver_resident_target_has_no_shards() {
        let v: Vec<u64> = vec![1, 2, 3];
        assert!(v.snapshot_shard(0).is_none());
        let ckpt = Checkpoint::capture(&v, 2, 0, &Ledger::new());
        assert_eq!(ckpt.total_bytes(), 0);
        let mut v = v;
        assert_eq!(ckpt.restore_shard_into(&mut v, 1).unwrap(), 0);
        v.lose_shard(1); // no-op
        assert_eq!(v, vec![1, 2, 3]);
    }
}
