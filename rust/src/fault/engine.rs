//! The recoverable MapReduce engine: block-granular execution with
//! checkpoints, failure injection, and deterministic re-execution.
//!
//! Selected by [`crate::mapreduce::mapreduce`] whenever the cluster's
//! [`FaultConfig`](super::FaultConfig) is enabled. The job is decomposed
//! into `nodes × workers` *map blocks* (the same per-worker item ranges
//! the ordinary engines use, with the same `(seed, block)` RNG streams, so
//! a block's output is identical no matter which node executes it or how
//! many times). Blocks commit **in block-id order**; a commit eagerly
//! reduces the block's locally-combined partials into the target, shard by
//! shard, and records `(block, shard)` in the commit ledger. Because every
//! shard therefore absorbs partials in the same ascending block order in
//! every run, final results are *byte-identical* with and without
//! failures — even for non-associative float reductions.
//!
//! **Checkpointing.** A mandatory checkpoint at job start (epoch 0) plus
//! one every `checkpoint_every_blocks` commits captures all target shards
//! ([`Checkpoint`]) and the ledger. Shard bytes replicate to the *driver*
//! (node 0 — the stable store, never killed) through the flow model, so
//! checkpoint cost is visible in the virtual makespan and a replica can
//! never be lost to a later failure.
//!
//! **Input iteration.** Blocks pull their items through the
//! [`DistInput::block_cursor`] API: one cached cursor per home node,
//! advanced one block at a time as blocks execute in id order, so the
//! failure-free path walks each node's partition **exactly once per job**
//! (the old scheme re-walked it once per worker block — O(workers · items)
//! host overhead). Only recovery replays, which revisit lower-id blocks out
//! of order, rebuild a cursor and skip to their block.
//!
//! **Recovery.** When the [`FailurePlan`](super::FailurePlan) kills a node
//! at a commit boundary: (1) its still-pending map blocks are reassigned
//! round-robin to survivors and re-executed from the (durable) input; (2)
//! its reduce shard is dropped and restored from the latest checkpoint,
//! with restore bytes charged driver→node — the restored shard lives on a
//! hot-standby *replacement* that adopts the dead node's identity, so key
//! routing is unchanged and the dead node executes no further map blocks;
//! (3) ledger entries for that shard
//! newer than the checkpoint are rolled back and their blocks re-executed
//! as *replays* that re-reduce **only** the lost shard's partial — the
//! ledger dedupes every other shard's already-absorbed partials, which is
//! what preserves the paper's "targets are merged into, never cleared"
//! semantics without double counting.
//!
//! **Mid-block kills.** An [`FailureTrigger::AtItem`] trigger has
//! sub-task granularity: it comes due the moment its block's map attempt
//! finishes, *before* any of that output can commit. When the victim is
//! the node executing the block, the attempt is aborted — the serial
//! path and the pool worker stop mapping at the doomed item, the partial
//! block-local reduction is discarded wholesale (never reaching a
//! shard; see [`crate::exec::cache::EagerCache::poison`] for the
//! threaded cache contract), and the block re-enters `pending` so kill
//! step (1) reassigns it to a survivor. The aborted attempt contributes
//! **zero** to every gated counter; only the canonical `MidblockAbort`
//! event, the `fault.midblock_aborts` counter, and a deterministic
//! trigger-clock charge of `min(item, block_items)` record it — so
//! failure and failure-free runs stay byte-identical at any thread
//! count. A kill whose victim is *not* the executing node still runs the
//! ordinary machinery mid-block; the block's own commit then proceeds
//! under post-restore routing.
//!
//! **Evacuation policy.** With [`FaultConfig::evacuate`](super::FaultConfig)
//! set (CLI `--evacuate`), step (2)'s hot standby is only transitional:
//! once the dead node's rollback replays drain, the engine re-homes its
//! key space onto the survivors ([`Recover::evacuate_dead`], backed by
//! [`crate::coordinator::rebalance::plan_with_dead`] for hash targets),
//! charges the migrated bytes through the flow model, and takes a
//! re-stabilization checkpoint so any later failure rolls back against the
//! post-evacuation routing. All subsequent reduce traffic routes to the
//! survivors. Targets that cannot re-home keys (block-addressed
//! `DistVector`, driver-resident `Vec`) fall back to hot-standby with a
//! metrics note. Both policies produce byte-identical results — evacuation
//! relocates entries without re-reducing them.
//!
//! **Backends.** Under `Backend::Threaded(n)` (with a non-conventional
//! engine) the map side of every block — fresh executions *and* recovery
//! replays — runs on the live worker pool ([`crate::exec::pool`]): each
//! time the next block to commit has no buffered map output, the engine
//! collects every pending block still missing one (coordinator-side, with
//! the same cursor discipline as the serial path, so walk counts are
//! unchanged) and speculatively maps the batch on `n` OS threads. Commits
//! then drain the buffer strictly in block-id order through the unchanged
//! ledger/checkpoint/trigger/evacuation logic. A block's map output
//! depends only on `(seed, block, input)`, so speculating ahead of
//! failure triggers is safe: a kill only changes exec-node *attribution*
//! (applied at commit time), and rollback replays re-enter `pending`
//! after their buffer entry was consumed, forcing re-execution on the
//! pool — the kill → rollback → replay → evacuate timeline is preserved
//! byte-for-byte. The buffer trades memory (pending blocks' map outputs
//! are materialized at once) for real parallelism; the conventional
//! engine models the Spark baseline and always runs serial.

use std::collections::hash_map::Entry;
use std::collections::{BTreeMap, BTreeSet};
use std::hash::Hash;
use std::sync::Mutex;
use std::time::Instant;

use crate::exec::pool;

use crate::coordinator::cluster::{Cluster, EngineKind};
use crate::coordinator::metrics::RunStats;
use crate::mapreduce::reducers::Reducer;
use crate::mapreduce::{BlockCursor, DistInput, Emit, ReduceTarget, RunRecorder};
use crate::net::sim::FlowMatrix;
use crate::net::vtime::VirtualTime;
use crate::ser::fastser::{decode_pairs_exact, encode_pairs, FastSer};
use crate::ser::tagged::{decode_pairs_tagged, encode_pairs_tagged, TaggedSer};
use crate::trace::histogram::Histograms;
use crate::trace::{Counters, TraceBuf, TraceEvent, TraceEventKind};
use crate::util::hash::FxHashMap;

use super::checkpoint::{Checkpoint, Ledger, Recover};
use super::plan::{FailureTrigger, ATTIME_SEC_PER_ITEM};

/// Recovery bookkeeping for one job, surfaced as the `fault[<label>]`
/// metrics note (no public accessor yet — promote to a returned value if
/// callers outgrow the note).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub(crate) struct FtStats {
    /// Checkpoints taken (including the mandatory epoch-0 one).
    pub checkpoints: usize,
    /// Total bytes captured across all checkpoints.
    pub checkpoint_bytes: u64,
    /// Failures actually injected (in-range, live, non-driver victims).
    pub failures: usize,
    /// Planned failures ignored (driver, out of range, already dead).
    pub failures_ignored: usize,
    /// Pending map blocks reassigned from dead nodes to survivors.
    pub blocks_reassigned: usize,
    /// Committed blocks re-executed to rebuild a lost shard.
    pub blocks_replayed: usize,
    /// Bytes moved restoring shards from checkpoints.
    pub restore_bytes: u64,
    /// Dead nodes whose key space was re-homed onto survivors.
    pub evacuations: usize,
    /// Bytes migrated by recovery-time evacuation.
    pub evacuation_bytes: u64,
    /// Map attempts aborted mid-block by sub-task (`AtItem`) kills. Each
    /// aborted attempt's partials were discarded wholesale — they
    /// contribute zero to every gated counter.
    pub midblock_aborts: usize,
}

/// A block waiting to execute (or re-execute).
#[derive(Debug, Clone)]
struct PendingBlock {
    /// Node whose compute budget the execution is charged to.
    exec_node: usize,
    /// `None` = commit every shard's partial; `Some(shards)` = a replay
    /// that re-reduces only the listed (restored) shards.
    only: Option<BTreeSet<usize>>,
}

/// One block's pure map output, buffered between (possibly speculative)
/// execution and its in-order commit. `pairs` is in the engine's
/// canonical pre-partition order — emit order under conventional
/// semantics, eager-cache drain order otherwise; partitioning by target
/// shard happens at commit time.
struct MappedBlock<K2, V2> {
    items: u64,
    emitted: u64,
    pairs: Vec<(K2, V2)>,
    /// Measured host seconds for the map (worker-thread time under the
    /// threaded backend). Observability only — the deterministic trigger
    /// clock derives from `items`, never from this.
    exec_secs: f64,
}

/// Run the pure map for one block. `visit` yields the block's items in
/// partition order; the result is the canonical pre-partition pair list
/// (see [`MappedBlock`]). Shared by the serial path and the pool workers
/// so the two backends cannot drift.
fn map_block<K, V, F, K2, V2>(
    visit: impl FnOnce(&mut dyn FnMut(&K, &V)),
    mapper: &F,
    red: &Reducer<V2>,
    conventional: bool,
) -> (u64, u64, Vec<(K2, V2)>)
where
    F: Fn(&K, &V, Emit<'_, K2, V2>),
    K2: Hash + Eq + Clone,
    V2: Clone,
{
    let mut items = 0u64;
    let mut emitted = 0u64;
    let mut pairs: Vec<(K2, V2)> = Vec::new();
    if conventional {
        // Conventional semantics: materialize every emitted pair.
        visit(&mut |k, v| {
            items += 1;
            let mut emit = |k2: K2, v2: V2| {
                emitted += 1;
                pairs.push((k2, v2));
            };
            mapper(k, v, &mut emit);
        });
    } else {
        // Eager semantics: block-local reduction into a cache first.
        let mut cache: FxHashMap<K2, V2> = FxHashMap::default();
        visit(&mut |k, v| {
            items += 1;
            let mut emit = |k2: K2, v2: V2| {
                emitted += 1;
                match cache.entry(k2) {
                    Entry::Occupied(mut e) => red.apply(e.get_mut(), &v2),
                    Entry::Vacant(e) => {
                        e.insert(v2);
                    }
                }
            };
            mapper(k, v, &mut emit);
        });
        pairs.extend(cache.drain());
    }
    (items, emitted, pairs)
}

/// Mutable engine state the kill machinery threads through
/// [`inject_kill`] — bundled so the commit-boundary trigger loop and the
/// mid-block abort pass drive the exact same steps (1)–(4).
struct KillCtx<'a> {
    nodes: usize,
    alive: &'a mut [bool],
    stats: &'a mut FtStats,
    pending: &'a mut BTreeMap<usize, PendingBlock>,
    rr: &'a mut usize,
    latest: &'a Checkpoint,
    restore_flows: &'a mut FlowMatrix,
    ledger: &'a mut Ledger,
    trace: &'a mut TraceBuf,
    evacuate_on: bool,
    evac_queue: &'a mut Vec<usize>,
}

/// Kill node `d` now: validity check (driver / range / liveness), then
/// the recovery timeline — (1) reassign the victim's pending map blocks
/// round-robin to survivors, (2) lose its shard and restore it from the
/// latest checkpoint, (3) roll back its post-checkpoint commits into
/// replays, (4) queue it for evacuation under that policy. Shared by the
/// commit-boundary trigger loop and the mid-block (`AtItem`) abort pass
/// so both granularities drive one machinery. Returns whether the kill
/// was injected (`false` ⇒ `KillIgnored`).
fn inject_kill<T: Recover + ?Sized>(
    label: &str,
    cluster: &Cluster,
    target: &mut T,
    d: usize,
    ctx: KillCtx<'_>,
) -> bool {
    if d == 0 || d >= ctx.nodes || !ctx.alive[d] {
        ctx.stats.failures_ignored += 1;
        let ev_t =
            TraceEvent::new(d, None, "map+block-reduce", TraceEventKind::KillIgnored { victim: d });
        let note = ev_t.render_note(label).expect("KillIgnored renders a note");
        cluster.metrics().record_note(note);
        ctx.trace.push(ev_t);
        return false;
    }
    ctx.alive[d] = false;
    ctx.stats.failures += 1;

    // (1) Reassign the dead node's pending map blocks to survivors.
    let orphaned: Vec<usize> =
        ctx.pending.iter().filter(|(_, pb)| pb.exec_node == d).map(|(&b2, _)| b2).collect();
    for b2 in orphaned {
        let s = next_alive_rr(ctx.alive, ctx.rr);
        ctx.pending.get_mut(&b2).expect("orphaned block pending").exec_node = s;
        ctx.stats.blocks_reassigned += 1;
    }

    // (2) Lose the shard, restore it from the latest checkpoint —
    // fetched from the driver replica (node 0 holds every shard's
    // checkpoint and is never killed, so the source always exists).
    target.lose_shard(d);
    let restored =
        ctx.latest.restore_shard_into(target, d).expect("checkpoint shard must decode");
    if restored > 0 {
        ctx.restore_flows.record(0, d, restored);
        ctx.stats.restore_bytes += restored;
    }
    ctx.trace.push(TraceEvent::new(
        d,
        None,
        "map+block-reduce",
        TraceEventKind::Kill { victim: d, restore_bytes: restored },
    ));

    // (3) Roll back post-checkpoint commits into that shard and replay
    // their blocks on survivors (only the lost shard's partial
    // re-reduces; the ledger keeps every other shard's).
    let rollback: Vec<usize> = ctx
        .ledger
        .iter()
        .filter(|&&(b2, dst)| dst == d && !ctx.latest.ledger.contains(&(b2, dst)))
        .map(|&(b2, _)| b2)
        .collect();
    for b2 in rollback {
        ctx.ledger.remove(&(b2, d));
        ctx.stats.blocks_replayed += 1;
        ctx.trace.push(TraceEvent::new(
            d,
            None,
            "map+block-reduce",
            TraceEventKind::Rollback { block: b2, shard: d },
        ));
        let s = next_alive_rr(ctx.alive, ctx.rr);
        ctx.pending
            .entry(b2)
            .and_modify(|pb| {
                if let Some(set) = pb.only.as_mut() {
                    set.insert(d);
                }
            })
            .or_insert_with(|| PendingBlock { exec_node: s, only: Some(BTreeSet::from([d])) });
    }

    // (4) Under the evacuation policy the hot standby is only
    // transitional: queue the victim for re-homing once its rollback
    // replays drain.
    if ctx.evacuate_on {
        ctx.evac_queue.push(d);
    }
    true
}

/// Deterministic round-robin pick over live nodes.
fn next_alive_rr(alive: &[bool], rr: &mut usize) -> usize {
    let n = alive.len();
    for _ in 0..n {
        let cand = *rr % n;
        *rr += 1;
        if alive[cand] {
            return cand;
        }
    }
    0 // node 0 is never killed
}

/// Run one MapReduce through the recoverable engine.
#[allow(clippy::too_many_lines)]
pub fn run<I, F, K2, V2, T>(label: &str, input: &I, mapper: &F, red: &Reducer<V2>, target: &mut T)
where
    I: DistInput,
    I::K: Clone + Send,
    I::V: Clone + Send,
    F: Fn(&I::K, &I::V, Emit<'_, K2, V2>) + Sync,
    K2: Hash + Eq + Clone + FastSer + TaggedSer + Send,
    V2: Clone + FastSer + TaggedSer + Send,
    T: ReduceTarget<K2, V2> + Recover,
{
    let rec = RunRecorder::new(label);
    let cluster = input.cluster().clone();
    let cfg = cluster.config().clone();
    let (nodes, workers) = (cfg.nodes, cfg.workers_per_node);
    let fault = cfg.fault.clone();
    let conventional = cfg.engine == EngineKind::Conventional;
    let n_blocks = nodes * workers;

    let mut vt = VirtualTime::new();
    if conventional {
        vt.fixed_phase("job-launch", cfg.conventional_job_latency_sec);
    }

    let mut alive = vec![true; nodes];
    let mut ledger = Ledger::new();
    let mut ckpt_flows = FlowMatrix::new(nodes);
    let mut shuffle_flows = FlowMatrix::new(nodes);
    let mut restore_flows = FlowMatrix::new(nodes);
    let mut stats = FtStats::default();
    let mut peak_ckpt_bytes = 0u64;
    let mut trace = TraceBuf::new(cfg.trace);
    let mut counters = Counters::new(nodes);
    let mut hist = Histograms::new(nodes);

    // The fault engine is serial, so its natural emission order is the
    // canonical trace order; the phase labels used on shuffle/reduce
    // events depend on which baseline engine semantics it mimics.
    let commit_phase: &'static str =
        if conventional { "shuffle-barrier+reduce" } else { "shuffle+async-reduce" };

    // Mandatory epoch-0 checkpoint: guarantees any pre-existing
    // (merged-into) target state is restorable.
    let mut latest = Checkpoint::capture(&*target, nodes, 0, &ledger);
    account_checkpoint(&latest, 0, &mut ckpt_flows, &mut stats, &mut peak_ckpt_bytes, &mut trace);

    let mut pending: BTreeMap<usize, PendingBlock> = (0..n_blocks)
        .map(|b| (b, PendingBlock { exec_node: b / workers, only: None }))
        .collect();
    let mut exec_epoch = vec![0u32; n_blocks];
    // A block's *first successful commit* is what advances the trigger
    // and checkpoint cadences ("fresh"): epochs can be consumed by
    // mid-block-aborted attempts, so epoch 1 is not a reliable marker.
    let mut committed_once = vec![false; n_blocks];
    let mut fired = vec![false; fault.plan.events().len()];
    // Once-per-sequence plans: seed fired flags from the cluster's
    // persisted state so a kill already injected by an earlier job in the
    // sequence (e.g. a previous k-means iteration) does not re-fire.
    if fault.plan.is_once_per_sequence() {
        let prev = cluster.fault_fired();
        for (i, f) in fired.iter_mut().enumerate() {
            *f = prev.get(i).copied().unwrap_or(false);
        }
    }
    let mut rr = 0usize;

    // Evacuation policy state: victims queued until their rollback replays
    // drain, plus the migration flows once they are re-homed.
    let evacuate_on = fault.evacuate;
    let mut evac_queue: Vec<usize> = Vec::new();
    let mut evac_flows = FlowMatrix::new(nodes);

    // Per-home cached block cursor `(cursor, next_block_in_node)`. Blocks
    // execute in id order, so the failure-free pass advances each node's
    // cursor one block at a time — a single walk of the partition per job.
    // Recovery replays revisit lower-id blocks out of order; only those
    // rebuild the cursor and skip forward.
    let mut cursors: Vec<Option<(I::Cursor<'_>, usize)>> = (0..nodes).map(|_| None).collect();

    // Threaded backend (non-conventional engines only): map work runs on
    // the live pool in speculative batches, commits stay serial (see the
    // module docs). Pool observability accumulates across batches.
    let threads = if conventional { None } else { cfg.backend.threads() };
    let mut spec: BTreeMap<usize, MappedBlock<K2, V2>> = BTreeMap::new();
    let mut pool_queue_peak = 0u64;
    let mut pool_thread_blocks: Vec<u64> = Vec::new();

    let mut per_node_secs = vec![0.0f64; nodes];
    let mut per_node_reduce_secs = vec![0.0f64; nodes];
    // Deterministic block-progress clock for AtTime triggers (plan.rs):
    // items executed per node × a fixed virtual per-item cost. Replays
    // advance it too (they are deterministic work), measured host time
    // never does.
    let mut det_secs = vec![0.0f64; nodes];
    let mut pairs_emitted = 0u64;
    let mut pairs_shuffled = 0u64;
    let mut ser_bytes = 0u64;
    let mut peak_staged_bytes = 0u64;
    // Total block executions (replays included) vs *distinct* blocks
    // committed at least once. Triggers and the checkpoint cadence count
    // fresh commits only, so `AtBlock(n)` means "after n map blocks" even
    // when an earlier recovery inflated the execution count with replays.
    let mut committed = 0usize;
    let mut fresh_committed = 0usize;

    loop {
        let Some(b) = pending.keys().next().copied() else { break };
        let p = pending.remove(&b).expect("pending block present");
        let (home, w) = (b / workers, b % workers);
        exec_epoch[b] += 1;

        // Will an AtItem kill interrupt this very attempt? Resolved
        // before execution — trigger state and exec-node attribution are
        // both fixed by now — so the serial path and the pool worker can
        // genuinely stop mapping at the doomed item. Whatever prefix the
        // victim maps, the abort pass below discards it wholesale.
        let abort_at: Option<u64> = fault.plan.events().iter().enumerate().find_map(|(i, ev)| {
            if fired[i] {
                return None;
            }
            let FailureTrigger::AtItem { block, item } = ev.trigger else { return None };
            (block == b
                && ev.node == p.exec_node
                && ev.node != 0
                && ev.node < nodes
                && alive[ev.node])
                .then_some(item)
        });

        // ---- Execute block `b` on `p.exec_node` -------------------------
        // The RNG stream is keyed by the block's *home* identity, matching
        // the ordinary engines, so re-execution elsewhere is identical.
        let mapped = match threads {
            // Serial (simulated backend, and always the conventional
            // engine): map straight off the cursor, no materialization.
            None => {
                let t0 = Instant::now();
                crate::util::random::set_stream(cfg.seed, b as u64);
                let in_order = matches!(&cursors[home], Some((_, next)) if *next == w);
                if !in_order {
                    // Out-of-order (a recovery replay, or the first block
                    // after one): rebuild the node's cursor and skip to
                    // block `w`.
                    let mut cur = input.block_cursor(home, workers);
                    for _ in 0..w {
                        cur.next_block(|_, _| {});
                    }
                    cursors[home] = Some((cur, w));
                }
                let (cur, next) = cursors[home].as_mut().expect("cursor installed");
                let (items, emitted, pairs) = match abort_at {
                    None => map_block(
                        |f| {
                            cur.next_block(|k, v| f(k, v));
                        },
                        mapper,
                        red,
                        conventional,
                    ),
                    // Doomed attempt: the whole block still walks (the
                    // cursor discipline is unchanged) but only the prefix
                    // the victim reaches before dying is mapped.
                    Some(stop) => {
                        let mut walked = 0u64;
                        let (_, emitted, pairs) = map_block(
                            |f| {
                                cur.next_block(|k, v| {
                                    if walked < stop {
                                        f(k, v);
                                    }
                                    walked += 1;
                                });
                            },
                            mapper,
                            red,
                            conventional,
                        );
                        (walked, emitted, pairs)
                    }
                };
                *next = w + 1;
                MappedBlock { items, emitted, pairs, exec_secs: t0.elapsed().as_secs_f64() }
            }
            // Threaded backend: consume the block's buffered map output,
            // running a speculative batch on the live pool first if it
            // (a fresh frontier, or a kill-induced replay) has none yet.
            Some(tn) => {
                if !spec.contains_key(&b) {
                    // `b` plus every pending block still missing output,
                    // in id order (`b` was the minimum pending id).
                    // Collection reuses the serial cursor discipline, so
                    // walk counts — replay rebuild+skip included — are
                    // identical to the simulated engine's.
                    let mut need = vec![b];
                    need.extend(pending.keys().copied().filter(|b2| !spec.contains_key(b2)));
                    let mut queue = need.into_iter();
                    let produce = || {
                        let b2 = queue.next()?;
                        let (home2, w2) = (b2 / workers, b2 % workers);
                        let in_order =
                            matches!(&cursors[home2], Some((_, next)) if *next == w2);
                        if !in_order {
                            let mut cur = input.block_cursor(home2, workers);
                            for _ in 0..w2 {
                                cur.next_block(|_, _| {});
                            }
                            cursors[home2] = Some((cur, w2));
                        }
                        let (cur, next) = cursors[home2].as_mut().expect("cursor installed");
                        let mut items: Vec<(I::K, I::V)> = Vec::new();
                        cur.next_block(|k, v| items.push((k.clone(), v.clone())));
                        *next = w2 + 1;
                        Some((b2, items))
                    };
                    let seed = cfg.seed;
                    let mapped_out: Mutex<BTreeMap<usize, MappedBlock<K2, V2>>> =
                        Mutex::new(BTreeMap::new());
                    let work = |(b2, items): (usize, Vec<(I::K, I::V)>)| {
                        let t0 = Instant::now();
                        // Same home-keyed stream as the serial path, on
                        // whichever OS thread stole the block.
                        crate::util::random::set_stream(seed, b2 as u64);
                        // Only the head block `b` can be a doomed attempt
                        // (its exec-node attribution is fixed by now);
                        // speculative blocks always map in full — their
                        // output stays valid wherever commit-time
                        // attribution lands them. The pool worker
                        // genuinely stops mapping at the kill item; the
                        // abort pass discards the prefix it produced.
                        let stop = if b2 == b { abort_at } else { None };
                        let (n_items, emitted, pairs) = match stop {
                            None => map_block(
                                |f| {
                                    for (k, v) in &items {
                                        f(k, v);
                                    }
                                },
                                mapper,
                                red,
                                conventional,
                            ),
                            Some(stop) => {
                                let (_, emitted, pairs) = map_block(
                                    |f| {
                                        for (k, v) in items.iter().take(stop as usize) {
                                            f(k, v);
                                        }
                                    },
                                    mapper,
                                    red,
                                    conventional,
                                );
                                (items.len() as u64, emitted, pairs)
                            }
                        };
                        debug_assert_eq!(n_items, items.len() as u64);
                        mapped_out.lock().expect("map batch poisoned").insert(
                            b2,
                            MappedBlock {
                                items: n_items,
                                emitted,
                                pairs,
                                exec_secs: t0.elapsed().as_secs_f64(),
                            },
                        );
                    };
                    let (ps, _) = pool::execute_with(
                        pool::PoolOptions {
                            threads: tn,
                            queue_cap: tn * 2,
                            pin_threads: cfg.pin_threads,
                        },
                        produce,
                        |_| (),
                        |_: &mut (), block| work(block),
                    );
                    pool_queue_peak = pool_queue_peak.max(ps.queue_peak);
                    if pool_thread_blocks.len() < ps.per_thread_blocks.len() {
                        pool_thread_blocks.resize(ps.per_thread_blocks.len(), 0);
                    }
                    for (t, blocks) in ps.per_thread_blocks.iter().enumerate() {
                        pool_thread_blocks[t] += *blocks;
                    }
                    spec.append(&mut mapped_out.into_inner().expect("map batch poisoned"));
                }
                spec.remove(&b).expect("map batch buffers every pending block")
            }
        };
        // ---- Mid-block failure triggers (sub-task granularity) ----------
        // An AtItem trigger for block `b` comes due the moment `b`'s map
        // attempt finishes — before any of its output can commit. When
        // the victim is the executing node, the attempt is discarded
        // wholesale: partial block-local reductions never reach a shard,
        // gated counters see nothing, and the block re-enters `pending`
        // still attributed to the victim so kill step (1) reassigns it
        // to a survivor. A kill with any other victim runs the ordinary
        // machinery; `b`'s own commit then proceeds under post-restore
        // routing (hot-standby restore never changes key routing).
        let mut aborted = false;
        for (i, ev) in fault.plan.events().iter().enumerate() {
            if fired[i] {
                continue;
            }
            let FailureTrigger::AtItem { block, item } = ev.trigger else { continue };
            if block != b {
                continue;
            }
            fired[i] = true;
            let d = ev.node;
            if !aborted && d == p.exec_node && d != 0 && d < nodes && alive[d] {
                aborted = true;
                // The deterministic trigger clock charges the items the
                // victim actually mapped; measured seconds stay on the
                // victim (observability only). Nothing else from the
                // attempt is recorded.
                let charged = item.min(mapped.items);
                det_secs[d] += charged as f64 * ATTIME_SEC_PER_ITEM;
                per_node_secs[d] += mapped.exec_secs;
                stats.midblock_aborts += 1;
                counters.add_node(d, "fault.midblock_aborts", 1);
                trace.push(TraceEvent::new(
                    home,
                    Some(w),
                    "map+block-reduce",
                    TraceEventKind::MidblockAbort { block: b, victim: d, items: charged },
                ));
                pending.insert(b, PendingBlock { exec_node: d, only: p.only.clone() });
            }
            inject_kill(
                label,
                &cluster,
                target,
                d,
                KillCtx {
                    nodes,
                    alive: &mut alive,
                    stats: &mut stats,
                    pending: &mut pending,
                    rr: &mut rr,
                    latest: &latest,
                    restore_flows: &mut restore_flows,
                    ledger: &mut ledger,
                    trace: &mut trace,
                    evacuate_on,
                    evac_queue: &mut evac_queue,
                },
            );
        }
        if aborted {
            continue;
        }

        let items_here = mapped.items;
        let emitted_here = mapped.emitted;
        // Partition by target shard at commit time (post-evacuation
        // routing applies automatically to replays).
        let mut parts: Vec<Vec<(K2, V2)>> = (0..nodes).map(|_| Vec::new()).collect();
        {
            let t_ref: &T = &*target;
            for (k2, v2) in mapped.pairs {
                parts[t_ref.shard_of(&k2, nodes)].push((k2, v2));
            }
        }
        let mut exec_secs = mapped.exec_secs;
        if conventional {
            exec_secs += emitted_here as f64 * cfg.conventional_overhead_sec;
        }
        per_node_secs[p.exec_node] += exec_secs;
        det_secs[p.exec_node] += items_here as f64 * ATTIME_SEC_PER_ITEM;
        pairs_emitted += emitted_here;
        counters.add_node(p.exec_node, "map.items", items_here);
        counters.add_node(p.exec_node, "map.emitted", emitted_here);
        // Recorded at commit time in block-id order, so replays and the
        // threaded backend land the same histogram as the serial path.
        hist.record_node(p.exec_node, "map.block_items", items_here);
        if p.only.is_some() {
            trace.push(TraceEvent::new(
                p.exec_node,
                None,
                "map+block-reduce",
                TraceEventKind::Replay { block: b, exec_node: p.exec_node },
            ));
        }
        trace.push(TraceEvent::new(
            home,
            Some(w),
            "map+block-reduce",
            TraceEventKind::MapBlock {
                items: items_here,
                emitted: emitted_here,
                exec_node: p.exec_node,
                epoch: exec_epoch[b],
            },
        ));

        // ---- Commit: eager-reduce each shard's partial once -------------
        let mut staged_bytes = 0u64;
        for (dst, part) in parts.into_iter().enumerate() {
            if part.is_empty() {
                continue;
            }
            if let Some(only) = &p.only {
                if !only.contains(&dst) {
                    continue;
                }
            }
            if ledger.contains(&(b, dst)) {
                continue; // dedupe re-emitted partials
            }
            let n_pairs = part.len() as u64;
            pairs_shuffled += n_pairs;
            let t1 = Instant::now();
            if conventional {
                // Conventional spills every block — node-local ones
                // included, like the ordinary conventional engine — with
                // the tagged codec; only cross-node bytes enter the flow
                // model.
                let buf = encode_pairs_tagged(&part);
                staged_bytes += buf.len() as u64;
                ser_bytes += buf.len() as u64;
                counters.add_node(p.exec_node, "ser.bytes", buf.len() as u64);
                if dst != p.exec_node {
                    shuffle_flows.record(p.exec_node, dst, buf.len() as u64);
                    crate::mapreduce::eager::record_frame_chunks(
                        &mut hist,
                        p.exec_node,
                        buf.len(),
                    );
                    trace.push(TraceEvent::new(
                        p.exec_node,
                        None,
                        commit_phase,
                        TraceEventKind::Shuffle { dst, bytes: buf.len() as u64, pairs: n_pairs },
                    ));
                }
                let decoded =
                    decode_pairs_tagged::<K2, V2>(&buf).expect("ft shuffle payload must decode");
                target.absorb(dst, decoded, red);
            } else if dst == p.exec_node {
                // Node-local partials never serialize (eager semantics).
                target.absorb(dst, part, red);
            } else {
                // Cross-node eager: really serialize, count, and decode
                // with the tag-less fast codec.
                let buf = encode_pairs(&part);
                staged_bytes += buf.len() as u64;
                ser_bytes += buf.len() as u64;
                counters.add_node(p.exec_node, "ser.bytes", buf.len() as u64);
                shuffle_flows.record(p.exec_node, dst, buf.len() as u64);
                crate::mapreduce::eager::record_frame_chunks(&mut hist, p.exec_node, buf.len());
                trace.push(TraceEvent::new(
                    p.exec_node,
                    None,
                    commit_phase,
                    TraceEventKind::Shuffle { dst, bytes: buf.len() as u64, pairs: n_pairs },
                ));
                let decoded =
                    decode_pairs_exact::<K2, V2>(&buf).expect("ft shuffle payload must decode");
                target.absorb(dst, decoded, red);
            }
            trace.push(TraceEvent::new(
                dst,
                None,
                commit_phase,
                TraceEventKind::Reduce { from: p.exec_node, pairs: n_pairs },
            ));
            per_node_reduce_secs[dst] += t1.elapsed().as_secs_f64();
            ledger.insert((b, dst));
        }
        peak_staged_bytes = peak_staged_bytes.max(staged_bytes);
        committed += 1;
        // First *commit* of this block, not first execution: an aborted
        // attempt consumes an epoch without committing, so epoch counting
        // would mis-classify the eventual commit as a replay.
        let was_fresh = !committed_once[b];
        committed_once[b] = true;
        if was_fresh {
            fresh_committed += 1;
        }

        // ---- Periodic checkpoint (fresh-commit cadence) -----------------
        if let Some(every) = fault.checkpoint_every_blocks {
            if every > 0 && was_fresh && fresh_committed % every == 0 && !pending.is_empty() {
                latest = Checkpoint::capture(&*target, nodes, committed, &ledger);
                account_checkpoint(
                    &latest,
                    committed,
                    &mut ckpt_flows,
                    &mut stats,
                    &mut peak_ckpt_bytes,
                    &mut trace,
                );
            }
        }

        // ---- Failure triggers (block boundaries only) -------------------
        // AtTime compares against the deterministic block-progress clock
        // (worker-scaled like a compute phase, max over nodes) so the
        // trigger quantizes to the same commit boundary in every run —
        // no host-load dependence (see plan.rs).
        let elapsed = det_secs
            .iter()
            .map(|&s| VirtualTime::scaled_compute(s, workers))
            .fold(0.0f64, f64::max);
        for (i, ev) in fault.plan.events().iter().enumerate() {
            if fired[i] {
                continue;
            }
            let due = match ev.trigger {
                // Fresh commits only: replays never advance the boundary.
                FailureTrigger::AtBlock(n) => fresh_committed >= n,
                FailureTrigger::AtTime(secs) => elapsed >= secs,
                // Sub-task granularity: evaluated by the mid-block pass
                // above, never at a commit boundary.
                FailureTrigger::AtItem { .. } => false,
            };
            if !due {
                continue;
            }
            fired[i] = true;
            inject_kill(
                label,
                &cluster,
                target,
                ev.node,
                KillCtx {
                    nodes,
                    alive: &mut alive,
                    stats: &mut stats,
                    pending: &mut pending,
                    rr: &mut rr,
                    latest: &latest,
                    restore_flows: &mut restore_flows,
                    ledger: &mut ledger,
                    trace: &mut trace,
                    evacuate_on,
                    evac_queue: &mut evac_queue,
                },
            );
        }

        // ---- Deferred evacuation (the `--evacuate` recovery policy) -----
        // Runs once no replay is pending: replay ids all precede
        // unexecuted fresh blocks, so from here on no partial is routed
        // under the pre-failure map. The *full* dead set is passed so a
        // prior evacuation's victims can never be re-assigned slots.
        if !evac_queue.is_empty() && pending.values().all(|pb| pb.only.is_none()) {
            let dead_all: Vec<usize> = (0..nodes).filter(|&n| !alive[n]).collect();
            match target.evacuate_dead(&dead_all) {
                Some(moves) => {
                    let mut moved = 0u64;
                    for (src, dst, bytes) in moves {
                        if bytes > 0 {
                            evac_flows.record(src, dst, bytes);
                            stats.evacuation_bytes += bytes;
                            moved += bytes;
                            trace.push(TraceEvent::new(
                                src,
                                None,
                                "evacuate",
                                TraceEventKind::Migrate { src, dst, bytes },
                            ));
                        }
                    }
                    stats.evacuations += evac_queue.len();
                    trace.push(TraceEvent::new(
                        0,
                        None,
                        "evacuate",
                        TraceEventKind::Evacuate { victims: evac_queue.clone(), bytes: moved },
                    ));
                    // Re-stabilization checkpoint: a later failure must
                    // roll back against post-evacuation routing, and a
                    // survivor's restore must include the keys it adopted.
                    // Pointless (and not charged) when no blocks remain —
                    // failures only fire at commit boundaries, so nothing
                    // can be lost after the last commit.
                    if !pending.is_empty() {
                        latest = Checkpoint::capture(&*target, nodes, committed, &ledger);
                        account_checkpoint(
                            &latest,
                            committed,
                            &mut ckpt_flows,
                            &mut stats,
                            &mut peak_ckpt_bytes,
                            &mut trace,
                        );
                    }
                }
                None => {
                    let ev_t = TraceEvent::new(
                        0,
                        None,
                        "evacuate",
                        TraceEventKind::EvacFallback { victims: evac_queue.clone() },
                    );
                    let note = ev_t.render_note(label).expect("EvacFallback renders a note");
                    cluster.metrics().record_note(note);
                    trace.push(ev_t);
                }
            }
            evac_queue.clear();
        }
    }

    // Planned failures whose trigger never came due (e.g. a block count
    // past the job's last commit) would otherwise vanish silently — note
    // them so overhead measurements can't mistake a dropped kill for a
    // survived one.
    for (i, ev) in fault.plan.events().iter().enumerate() {
        if !fired[i] {
            stats.failures_ignored += 1;
            let ev_t = TraceEvent::new(
                ev.node,
                None,
                "map+block-reduce",
                TraceEventKind::KillDropped {
                    victim: ev.node,
                    trigger: format!("{:?}", ev.trigger),
                },
            );
            let note = ev_t.render_note(label).expect("KillDropped renders a note");
            cluster.metrics().record_note(note);
            trace.push(ev_t);
        }
    }

    // Persist fired flags for once-per-sequence plans: the next job on
    // this cluster skips events that already fired here. Events that
    // never came due stay unfired and may still fire in a later job.
    if fault.plan.is_once_per_sequence() {
        cluster.set_fault_fired(&fired);
    }

    // ---- Virtual-time phases --------------------------------------------
    vt.compute_phase("map+block-reduce", &per_node_secs, workers);
    let reduce_cpu = per_node_reduce_secs
        .iter()
        .map(|&s| VirtualTime::scaled_compute(s, workers))
        .fold(0.0f64, f64::max);
    if conventional {
        vt.shuffle_barrier("shuffle-barrier+reduce", &shuffle_flows, &cfg.network, reduce_cpu);
    } else {
        vt.shuffle_overlapped("shuffle+async-reduce", &shuffle_flows, &cfg.network, reduce_cpu);
    }
    let ckpt_secs = ckpt_flows.phase_time(&cfg.network);
    if ckpt_secs > 0.0 {
        vt.fixed_phase("checkpoint", ckpt_secs);
    }
    let restore_secs = restore_flows.phase_time(&cfg.network);
    if restore_secs > 0.0 {
        vt.fixed_phase("restore", restore_secs);
    }
    let evac_secs = evac_flows.phase_time(&cfg.network);
    if evac_secs > 0.0 {
        vt.fixed_phase("evacuate", evac_secs);
    }

    // ---- Record -----------------------------------------------------------
    let compute_sec = vt.compute_sec();
    let makespan = vt.makespan();
    let evac_bytes = evac_flows.cross_node_bytes();
    let shuffle_bytes = shuffle_flows.cross_node_bytes()
        + ckpt_flows.cross_node_bytes()
        + restore_flows.cross_node_bytes()
        + evac_bytes;
    let max_epoch = exec_epoch.iter().copied().max().unwrap_or(0);
    let summary = TraceEvent::new(
        0,
        None,
        "summary",
        TraceEventKind::FaultSummary {
            checkpoints: stats.checkpoints as u64,
            checkpoint_bytes: stats.checkpoint_bytes,
            failures: stats.failures as u64,
            ignored: stats.failures_ignored as u64,
            reassigned: stats.blocks_reassigned as u64,
            replayed: stats.blocks_replayed as u64,
            restore_bytes: stats.restore_bytes,
            evacuations: stats.evacuations as u64,
            evac_bytes: stats.evacuation_bytes,
            max_epoch,
        },
    );
    let summary_note = summary.render_note(label).expect("FaultSummary renders a note");
    trace.push(summary);
    trace.stamp_phases(&vt);
    cluster.trace().absorb_job(&rec.label, trace);
    counters.add("ckpt.count", stats.checkpoints as u64);
    counters.add("ckpt.bytes", stats.checkpoint_bytes);
    counters.add("restore.bytes", stats.restore_bytes);
    counters.add("evac.bytes", stats.evacuation_bytes);
    counters.add("replay.blocks", stats.blocks_replayed as u64);
    counters.add("reassign.blocks", stats.blocks_reassigned as u64);
    counters.add("fault.midblock_aborts", stats.midblock_aborts as u64);
    if threads.is_some() {
        counters.max("pool.queue_peak", pool_queue_peak);
        for (t, blocks) in pool_thread_blocks.iter().enumerate() {
            counters.add(&format!("pool.thread{t}.blocks"), *blocks);
        }
    }
    let (run_counters, node_counters) = counters.finish();
    // Measure once: host_wall_sec must bound the "total" phase entry.
    let host_wall = rec.started.elapsed();
    cluster.metrics().record_run(RunStats {
        label: rec.label,
        engine: format!("{}+ft", cfg.engine),
        // Conventional+ft always executes serial, whatever the backend.
        backend: match threads {
            None => "simulated".into(),
            Some(tn) => format!("threaded:{tn}"),
        },
        nodes,
        workers_per_node: workers,
        makespan_sec: makespan,
        compute_sec,
        shuffle_sec: makespan - compute_sec,
        shuffle_bytes,
        ser_bytes,
        evac_bytes,
        pairs_emitted,
        pairs_shuffled,
        peak_intermediate_bytes: peak_staged_bytes + peak_ckpt_bytes,
        host_wall_sec: host_wall.as_secs_f64(),
        // One whole-job entry: the recoverable engine interleaves map,
        // commit, checkpoint, and recovery work per block, so there is no
        // meaningful per-phase wall split to report.
        phase_wall_ns: vec![("total".into(), host_wall.as_nanos() as u64)],
        counters: run_counters,
        node_counters,
        histograms: hist.finish(),
    });
    cluster.metrics().record_note(summary_note);
}

/// Replicate a fresh checkpoint's shards to the driver (node 0, the
/// stable store) and fold the cost into the running stats. Node 0's own
/// shard is driver-local and free. `commit` is the commit count the
/// checkpoint was captured at (stamped on the trace event).
fn account_checkpoint(
    ckpt: &Checkpoint,
    commit: usize,
    ckpt_flows: &mut FlowMatrix,
    stats: &mut FtStats,
    peak_ckpt_bytes: &mut u64,
    trace: &mut TraceBuf,
) {
    stats.checkpoints += 1;
    stats.checkpoint_bytes += ckpt.total_bytes();
    *peak_ckpt_bytes = (*peak_ckpt_bytes).max(ckpt.total_bytes());
    trace.push(TraceEvent::new(
        0,
        None,
        "checkpoint",
        TraceEventKind::Checkpoint { commit, bytes: ckpt.total_bytes() },
    ));
    for (node, size) in ckpt.manifest.shard_bytes.iter().enumerate() {
        if let Some(bytes) = size {
            if node != 0 {
                ckpt_flows.record(node, 0, *bytes);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_robin_skips_dead_nodes() {
        let alive = [true, false, false, true];
        let mut rr = 0usize;
        let picks: Vec<usize> = (0..4).map(|_| next_alive_rr(&alive, &mut rr)).collect();
        assert_eq!(picks, vec![0, 3, 0, 3]);
    }

    #[test]
    fn map_block_modes_share_one_contract() {
        let red = Reducer::<u64>::by_name("sum");
        let items: Vec<(u64, u64)> = (0..10u64).map(|i| (i, 1)).collect();
        let mapper = |k: &u64, v: &u64, emit: Emit<'_, u64, u64>| emit(k % 3, *v);

        // Conventional: every emitted pair materializes, in emit order.
        let (n, emitted, pairs) = map_block(
            |f| {
                for (k, v) in &items {
                    f(k, v);
                }
            },
            &mapper,
            &red,
            true,
        );
        assert_eq!((n, emitted), (10, 10));
        assert_eq!(pairs.len(), 10, "conventional materializes every pair");
        assert_eq!(pairs[0], (0, 1), "emit order preserved");

        // Eager: block-local reduction first — 3 keys survive, same mass.
        let (n, emitted, reduced) = map_block(
            |f| {
                for (k, v) in &items {
                    f(k, v);
                }
            },
            &mapper,
            &red,
            false,
        );
        assert_eq!((n, emitted), (10, 10));
        assert_eq!(reduced.len(), 3, "eager cache folds per key");
        assert_eq!(reduced.iter().map(|&(_, v)| v).sum::<u64>(), 10);
    }
}
