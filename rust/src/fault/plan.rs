//! Deterministic failure injection plans.
//!
//! A [`FailurePlan`] declares, ahead of a job, which virtual nodes die and
//! when. Block-granular triggers fire at *map-block commit boundaries* —
//! either after a chosen number of globally committed blocks
//! ([`FailureTrigger::AtBlock`]) or once the job's virtual makespan passes
//! a chosen time ([`FailureTrigger::AtTime`]). Sub-task granularity is
//! [`FailureTrigger::AtItem`]: the kill lands *inside* a chosen block's
//! map, after a chosen number of input items, and the interrupted attempt
//! is aborted and discarded before anything commits. Plans can also be
//! drawn from a [`SplitRng`] stream ([`FailurePlan::random`]) so failure
//! benchmarks are reproducible from a single seed.
//!
//! **`AtTime` semantics (deterministic block quantization).** An
//! `AtTime(secs)` trigger is evaluated only at block commit boundaries,
//! against the job's *deterministic block-progress clock* — not measured
//! host time. Every executed block advances its executing node's clock by
//! `items_in_block × `[`ATTIME_SEC_PER_ITEM`], the per-node clocks are
//! scaled by the worker count exactly like a compute phase, and the
//! trigger fires at the first boundary where the max over nodes reaches
//! `secs`. Block item counts are a pure function of the input partition,
//! so the same `AtTime` lands on the same commit boundary in every run
//! and on every engine — no host-load dependence (this replaced the
//! measured-time comparison, whose boundary shifted with load; results
//! were byte-identical either way, but recovery-overhead numbers were
//! not reproducible). `AtTime(0.0)` fires at the first commit boundary.
//! The clock is engine-independent by design: it deliberately ignores
//! modeled conventional-engine overheads so `AtTime` selects the same
//! boundary under every engine × backend combination the equivalence
//! harness compares.
//!
//! `AtBlock` triggers (including every event in a [`FailurePlan::random`]
//! plan) fire after a chosen number of *fresh* commits and are the
//! natural choice when the boundary itself is the quantity under study.
//!
//! Node 0 hosts the driver and is never killed; events naming it (or a
//! node outside the cluster) are ignored with a metrics note rather than
//! panicking, so one plan can be reused across cluster shapes.

use crate::util::rng::SplitRng;

/// Virtual seconds one input item contributes to the deterministic
/// block-progress clock that `AtTime` triggers compare against (see the
/// module docs). The value matches the conventional engine's modeled
/// per-record overhead order of magnitude so `AtTime` thresholds read
/// like plausible virtual timestamps, but any positive constant yields
/// the same *determinism* — only the boundary↔seconds mapping shifts.
pub const ATTIME_SEC_PER_ITEM: f64 = 250e-9;

/// When a planned failure fires.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FailureTrigger {
    /// Fire once `n` *distinct* map blocks have committed globally (0 and
    /// 1 both mean "after the first block commits"). Recovery replays
    /// re-commit already-counted blocks and do not advance the boundary,
    /// so `n` keeps its meaning in multi-failure runs.
    AtBlock(usize),
    /// Fire at the first block commit boundary where the job's
    /// deterministic block-progress clock (items executed ×
    /// [`ATTIME_SEC_PER_ITEM`], worker-scaled, max over nodes) reaches
    /// `secs`. Quantized to commit boundaries and independent of host
    /// load — the same boundary in every run.
    AtTime(f64),
    /// Fire *inside* the map of block-id `block`, after `item` input items
    /// of that block have been mapped — sub-task granularity. When the
    /// victim is the block's executing node, its in-flight map attempt is
    /// aborted: already-emitted pairs and partial eager-cache flushes are
    /// discarded (never reaching any shard), the block re-enters the
    /// pending set, and the ordinary kill→rollback→replay machinery runs
    /// before anything from the interrupted attempt commits. The aborted
    /// attempt contributes nothing to the gated `map.*` counters (only
    /// `fault.midblock_aborts`), so serial and threaded backends stay
    /// byte-identical. `item` is clamped to the block's item count when it
    /// overshoots; if `block` is never executed fresh the event is dropped
    /// at job end like any other unfired trigger.
    AtItem {
        /// Block-id whose map is interrupted.
        block: usize,
        /// Input items of that block mapped before the abort.
        item: u64,
    },
}

/// One planned node death.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FailureEvent {
    /// Virtual node to kill.
    pub node: usize,
    /// When to kill it.
    pub trigger: FailureTrigger,
}

/// An ordered set of planned failures for one job.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FailurePlan {
    events: Vec<FailureEvent>,
    /// When set, each event fires at most once per *job sequence* on a
    /// shared cluster instead of once per MapReduce job: the recoverable
    /// engine seeds its fired flags from the cluster's persisted state
    /// ([`crate::coordinator::cluster::Cluster::fault_fired`], keyed by
    /// event position) and writes them back at job end. Iterative jobs
    /// (k-means, PageRank) use this to model "the node died once", not
    /// "a node dies every iteration". Reusing one cluster with a
    /// *different* plan resets nothing — keep one plan per cluster when
    /// sequencing.
    once_per_sequence: bool,
}

impl FailurePlan {
    /// No failures (checkpointing may still be on).
    pub fn none() -> Self {
        Self::default()
    }

    /// Kill `node` after `block` blocks have committed.
    pub fn kill_at_block(node: usize, block: usize) -> Self {
        Self::none().and_kill_at_block(node, block)
    }

    /// Kill `node` at virtual time `secs`.
    pub fn kill_at_time(node: usize, secs: f64) -> Self {
        Self::none().and_kill_at_time(node, secs)
    }

    /// Add a block-boundary kill (builder style).
    pub fn and_kill_at_block(mut self, node: usize, block: usize) -> Self {
        self.events.push(FailureEvent { node, trigger: FailureTrigger::AtBlock(block) });
        self
    }

    /// Add a virtual-time kill (builder style).
    pub fn and_kill_at_time(mut self, node: usize, secs: f64) -> Self {
        self.events.push(FailureEvent { node, trigger: FailureTrigger::AtTime(secs) });
        self
    }

    /// Kill `node` mid-map, after `item` items of block `block` have been
    /// mapped (sub-task granularity — see [`FailureTrigger::AtItem`]).
    pub fn kill_at_item(node: usize, block: usize, item: u64) -> Self {
        Self::none().and_kill_at_item(node, block, item)
    }

    /// Add a mid-block kill (builder style).
    pub fn and_kill_at_item(mut self, node: usize, block: usize, item: u64) -> Self {
        self.events.push(FailureEvent { node, trigger: FailureTrigger::AtItem { block, item } });
        self
    }

    /// `failures` block-boundary kills drawn deterministically from
    /// `(seed)`: victims uniform over nodes `1..nodes` (the driver
    /// survives), boundaries uniform over `1..=max_block`.
    pub fn random(seed: u64, nodes: usize, failures: usize, max_block: usize) -> Self {
        let mut rng = SplitRng::new(seed, 0xFA_17);
        let mut plan = Self::none();
        if nodes < 2 || max_block == 0 {
            return plan;
        }
        for _ in 0..failures {
            let node = 1 + rng.below(nodes as u64 - 1) as usize;
            let block = 1 + rng.below(max_block as u64) as usize;
            plan = plan.and_kill_at_block(node, block);
        }
        plan
    }

    /// Fire each event at most once across all jobs run on the same
    /// cluster (builder style) — see the field docs for semantics.
    pub fn once_per_sequence(mut self) -> Self {
        self.once_per_sequence = true;
        self
    }

    /// True when events fire once per job *sequence* rather than once per
    /// job.
    pub fn is_once_per_sequence(&self) -> bool {
        self.once_per_sequence
    }

    /// Planned events, in declaration order.
    pub fn events(&self) -> &[FailureEvent] {
        &self.events
    }

    /// True when no failures are planned.
    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }
}

/// Cluster-level fault-tolerance policy, carried in
/// [`crate::coordinator::cluster::ClusterConfig`].
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultConfig {
    /// Failures to inject.
    pub plan: FailurePlan,
    /// Checkpoint the reduce target every this many committed map blocks
    /// (`None` = only the mandatory job-start checkpoint when the fault
    /// engine is active).
    ///
    /// Note: setting a cadence *alone* (no failure plan) already routes
    /// jobs through the recoverable engine — the intended failure-free
    /// baseline for recovery-overhead ablations. Integer reductions are
    /// unaffected, but float reductions there run in block-id order, which
    /// can differ in low bits from the ordinary engines' combine order.
    pub checkpoint_every_blocks: Option<usize>,
    /// Recovery policy for a dead node's reduce shard. `false` (default):
    /// hot-standby — the restored shard keeps the dead node's identity and
    /// routing is unchanged. `true`: after the dead node's rollback replays
    /// drain, its key space is re-homed onto the survivors
    /// ([`crate::fault::Recover::evacuate_dead`], backed by
    /// [`crate::coordinator::rebalance::plan_with_dead`]) with the migrated
    /// bytes charged through the flow model; all subsequent reduce traffic
    /// routes to the survivors. Targets that cannot re-home keys
    /// (block-addressed `DistVector`, driver-resident `Vec`) fall back to
    /// hot-standby with a metrics note. Results are byte-identical under
    /// either policy.
    pub evacuate: bool,
}

impl FaultConfig {
    /// Fault tolerance off: jobs run on the ordinary engines.
    pub fn disabled() -> Self {
        Self::default()
    }

    /// True when jobs must run through the recoverable engine.
    pub fn enabled(&self) -> bool {
        !self.plan.is_empty() || self.checkpoint_every_blocks.is_some()
    }

    /// Builder-style failure-plan override.
    pub fn with_plan(mut self, plan: FailurePlan) -> Self {
        self.plan = plan;
        self
    }

    /// Builder-style checkpoint cadence override.
    pub fn with_checkpoint_every(mut self, blocks: usize) -> Self {
        self.checkpoint_every_blocks = Some(blocks.max(1));
        self
    }

    /// Builder-style recovery-policy override: `true` re-homes a dead
    /// node's keys onto survivors instead of the hot-standby restore.
    pub fn with_evacuation(mut self, evacuate: bool) -> Self {
        self.evacuate = evacuate;
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_accumulates_events() {
        let plan = FailurePlan::kill_at_block(1, 3).and_kill_at_time(2, 0.5);
        assert_eq!(plan.events().len(), 2);
        assert_eq!(plan.events()[0].node, 1);
        assert_eq!(plan.events()[0].trigger, FailureTrigger::AtBlock(3));
        assert_eq!(plan.events()[1].trigger, FailureTrigger::AtTime(0.5));
    }

    #[test]
    fn at_item_builder_and_identity() {
        let plan = FailurePlan::kill_at_item(2, 3, 40).and_kill_at_block(1, 5);
        assert_eq!(plan.events().len(), 2);
        assert_eq!(plan.events()[0].node, 2);
        assert_eq!(plan.events()[0].trigger, FailureTrigger::AtItem { block: 3, item: 40 });
        // Copy + PartialEq survive the struct variant.
        let t = plan.events()[0].trigger;
        assert_eq!(t, t);
        assert_ne!(t, FailureTrigger::AtItem { block: 3, item: 41 });
    }

    #[test]
    fn random_is_deterministic_and_spares_driver() {
        let a = FailurePlan::random(42, 8, 5, 100);
        let b = FailurePlan::random(42, 8, 5, 100);
        assert_eq!(a, b);
        assert_eq!(a.events().len(), 5);
        for ev in a.events() {
            assert!(ev.node >= 1 && ev.node < 8, "victim {}", ev.node);
            match ev.trigger {
                FailureTrigger::AtBlock(b) => assert!((1..=100).contains(&b)),
                _ => panic!("random plans are block-based"),
            }
        }
        assert_ne!(a, FailurePlan::random(43, 8, 5, 100));
    }

    #[test]
    fn random_degenerate_shapes_are_empty() {
        assert!(FailurePlan::random(1, 1, 3, 10).is_empty());
        assert!(FailurePlan::random(1, 4, 3, 0).is_empty());
    }

    #[test]
    fn once_per_sequence_is_a_plan_property() {
        let plan = FailurePlan::kill_at_block(1, 3);
        assert!(!plan.is_once_per_sequence(), "per-job firing is the default");
        let seq = plan.clone().once_per_sequence();
        assert!(seq.is_once_per_sequence());
        assert_eq!(seq.events(), plan.events(), "events unchanged");
        assert_ne!(seq, plan, "firing policy is part of plan identity");
    }

    #[test]
    fn config_enablement() {
        assert!(!FaultConfig::disabled().enabled());
        assert!(FaultConfig::disabled().with_checkpoint_every(8).enabled());
        assert!(FaultConfig::disabled()
            .with_plan(FailurePlan::kill_at_block(1, 1))
            .enabled());
        // Cadence of 0 clamps to 1 (checkpoint after every block).
        assert_eq!(
            FaultConfig::disabled().with_checkpoint_every(0).checkpoint_every_blocks,
            Some(1)
        );
        // Evacuation is a policy toggle, not an enabler: it only matters
        // once a plan or cadence routes jobs through the recoverable engine.
        let evac = FaultConfig::disabled().with_evacuation(true);
        assert!(evac.evacuate);
        assert!(!evac.enabled());
        assert!(!FaultConfig::default().evacuate, "hot-standby is the default");
    }
}
