//! Fault tolerance: failure injection, checkpointed containers, and
//! deterministic task re-execution.
//!
//! The one axis where Spark-class systems beat hand-tuned MPI code is
//! surviving worker loss. This subsystem adds recovery to Blaze without
//! giving up eager reduction, in three layers:
//!
//! * [`plan`] — deterministic, [`crate::util::SplitRng`]-seeded
//!   [`FailurePlan`]s that kill virtual nodes at chosen virtual-time
//!   points, map-block boundaries, or *mid-block*
//!   ([`FailureTrigger::AtItem`]: the kill lands a chosen number of
//!   items into one block's map, aborting and discarding the in-flight
//!   attempt before anything commits), carried on the cluster config as
//!   a [`FaultConfig`]. Network faults are separate: a lossy-transport
//!   plan ([`crate::exec::transport::TransportFaultPlan`], CLI
//!   `--net-fault`) afflicts the threaded backend's shuffle channels
//!   with seeded drop/corrupt/delay fates, checksum-verified frames,
//!   capped-backoff retries, and timeout-driven node death — inert
//!   under this engine, whose shuffle is flow-model only.
//! * [`checkpoint`] — per-shard snapshots of the reduce targets
//!   ([`Checkpoint`], with a manifest and the commit [`Ledger`]), encoded
//!   with the [`crate::ser::fastser`] codec and replicated to the driver
//!   (node 0, the stable store) through the network model, so checkpoint
//!   cost shows up in the virtual makespan. Targets opt in via the
//!   [`Recover`] trait.
//! * [`engine`] — the recoverable MapReduce engine: block-granular
//!   execution committed in block-id order (pulling input through the
//!   single-pass [`crate::mapreduce::DistInput::block_cursor`] API — each
//!   node's partition is walked exactly once per failure-free job),
//!   re-assignment of a dead node's unfinished map blocks to survivors,
//!   shard recovery under either the hot-standby restore policy or
//!   [`FaultConfig::evacuate`] slot re-homing (with migration charged
//!   through the flow model), and per-block-epoch dedupe of re-emitted
//!   partials — preserving the paper's "targets are merged into, never
//!   cleared" semantics while keeping failure and failure-free runs
//!   byte-identical under every policy.
//!
//! Enable it per cluster:
//!
//! ```
//! use blaze::prelude::*;
//! use blaze::fault::{FailurePlan, FaultConfig};
//!
//! let cluster = Cluster::new(ClusterConfig::sized(4, 2).with_fault(
//!     FaultConfig::default()
//!         .with_checkpoint_every(4)
//!         .with_plan(FailurePlan::kill_at_block(2, 3)),
//! ));
//! // Every mapreduce on `cluster` now checkpoints every 4 blocks and
//! // survives node 2 dying after the third block commits.
//! ```

pub mod checkpoint;
pub mod engine;
pub mod plan;

pub use checkpoint::{Checkpoint, CheckpointManifest, Ledger, Recover};
pub use plan::{FailureEvent, FailurePlan, FailureTrigger, FaultConfig};
