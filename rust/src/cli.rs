//! Command-line launcher (hand-rolled parsing; the build is offline).
//!
//! ```text
//! blaze <task> [--nodes N] [--workers W] [--engine blaze|conventional]
//!              [--backend simulated|threaded[:N]] [--scale S]
//!              [--artifacts DIR] [--seed SEED]
//!              [--fail-at NODE@BLOCK[.ITEM] ...] [--checkpoint-every BLOCKS]
//!              [--evacuate] [--net-fault drop=P,corrupt=P[,delay=P][,seed=S]]
//!              [--retry-max N] [--net-timeout NS]
//!              [--transport-window BYTES] [--pin-threads]
//! blaze report <BASELINE> <CANDIDATE> [--gate] [--deterministic-only]
//!              [--threshold PCT] [--out PATH]
//! ```
//!
//! Tasks: `pi`, `wordcount`, `pagerank`, `kmeans`, `gmm`, `knn`, `all`.
//! The `report` subcommand is the perf regression gate over `BENCH_*.json`
//! artifacts ([`crate::regress`]): e.g.
//! `blaze report benches/baseline bench-out --gate --deterministic-only`
//! exits 1 if a deterministic counter/histogram field drifted or an
//! expected series/config row went missing, while wall-clock deltas stay
//! advisory.
//! `--fail-at 2@5` kills virtual node 2 after 5 map blocks commit;
//! `--fail-at 2@5.100` kills it *mid-block* — while block 5's map is 100
//! items in, discarding the in-flight partials (repeatable); either fault
//! flag routes the job through the recoverable engine ([`crate::fault`]).
//! `--net-fault drop=0.2,corrupt=0.05,seed=9` runs the threaded backend's
//! shuffle over the lossy channel transport
//! ([`crate::exec::transport`]): frames drop, arrive bit-flipped (and are
//! rejected by the frame checksum), and retry under capped exponential
//! backoff. `--retry-max` bounds retransmissions per frame and
//! `--net-timeout` sets the per-frame delivery deadline in virtual
//! nanoseconds; exhausting either declares the destination dead and the
//! run degrades gracefully — a structured fallback, never a hang. Results
//! stay byte-identical to the lossless run; the simulated backend ignores
//! the plan entirely. `--evacuate` re-homes a dead node's keys onto
//! the survivors (slot evacuation) instead of the default hot-standby
//! restore — both policies produce identical results, so each stays
//! benchmarkable against the other. `--backend threaded:N` executes the
//! eager/small-key map+combine on N real OS threads ([`crate::exec`])
//! with byte-identical results; the default (overridable via the
//! `BLAZE_BACKEND` environment variable) is the simulated backend.
//! `--pin-threads` pins pool workers to cores on the threaded backend
//! (best-effort affinity; a silent no-op where unsupported — results are
//! byte-identical either way). `--transport-window BYTES` sets the
//! shuffle backpressure window
//! (simulated accounting *and* the threaded backend's real channel
//! capacity — see [`crate::exec::transport`]); tiny windows force stall
//! storms, surfaced as `transport.stalls`. Setting `BLAZE_PIN_THREADS`
//! to any non-empty value turns pinning on without the flag; the flag
//! only ever turns it *on*, never off.

use crate::apps;
use crate::coordinator::cluster::{Backend, Cluster, ClusterConfig, EngineKind};
use crate::data::{corpus_lines, Graph, PointSet};
use crate::exec::transport::TransportFaultPlan;
use crate::fault::{FailurePlan, FaultConfig};
use crate::runtime::Runtime;

/// Parsed CLI options.
#[derive(Debug, Clone)]
pub struct Options {
    /// Task name.
    pub task: String,
    /// Virtual node count.
    pub nodes: usize,
    /// Workers per node.
    pub workers: usize,
    /// Engine selection.
    pub engine: EngineKind,
    /// Execution backend (simulated vs real threads).
    pub backend: Backend,
    /// Workload scale multiplier (1 = quick demo sizes).
    pub scale: usize,
    /// Artifacts directory (PJRT workloads); empty string disables.
    pub artifacts: String,
    /// RNG seed.
    pub seed: u64,
    /// Injected failures as `(node, block)` pairs (`--fail-at NODE@BLOCK`).
    pub fail_at: Vec<(usize, usize)>,
    /// Injected mid-block failures as `(node, block, item)` triples
    /// (`--fail-at NODE@BLOCK.ITEM`).
    pub fail_at_item: Vec<(usize, usize, u64)>,
    /// Lossy-transport fault model as `(drop_p, corrupt_p, delay_p, seed)`
    /// (`--net-fault drop=P,corrupt=P[,delay=P][,seed=S]`); a `None` seed
    /// falls back to the run seed, whatever flag order argv used.
    pub net_fault: Option<(f64, f64, f64, Option<u64>)>,
    /// Retransmission budget per frame (`--retry-max N`).
    pub retry_max: Option<u32>,
    /// Per-frame delivery deadline in virtual ns (`--net-timeout NS`).
    pub net_timeout: Option<u64>,
    /// Checkpoint cadence in committed blocks (`--checkpoint-every N`).
    pub checkpoint_every: Option<usize>,
    /// Recovery policy: re-home a dead node's keys onto survivors instead
    /// of the hot-standby restore (`--evacuate`).
    pub evacuate: bool,
    /// Shuffle backpressure window override in bytes
    /// (`--transport-window BYTES`); `None` keeps the 4 MiB default.
    pub transport_window: Option<u64>,
    /// Trace output path (`--trace PATH`, default from `BLAZE_TRACE`):
    /// enables the structured event collector and exports the canonical
    /// JSONL log (plus `PATH.chrome.json`) after the run.
    pub trace: Option<String>,
    /// Pin threaded-backend pool workers to cores (`--pin-threads`).
    pub pin_threads: bool,
}

impl Default for Options {
    fn default() -> Self {
        Self {
            task: String::new(),
            nodes: 4,
            workers: 4,
            engine: EngineKind::Eager,
            backend: Backend::from_env(),
            scale: 1,
            artifacts: "artifacts".into(),
            seed: 42,
            fail_at: Vec::new(),
            fail_at_item: Vec::new(),
            net_fault: None,
            retry_max: None,
            net_timeout: None,
            checkpoint_every: None,
            evacuate: false,
            transport_window: None,
            trace: std::env::var("BLAZE_TRACE").ok().filter(|p| !p.is_empty()),
            pin_threads: false,
        }
    }
}

impl Options {
    /// Fault policy assembled from the fault flags.
    pub fn fault_config(&self) -> FaultConfig {
        let mut plan = FailurePlan::none();
        for &(node, block) in &self.fail_at {
            plan = plan.and_kill_at_block(node, block);
        }
        for &(node, block, item) in &self.fail_at_item {
            plan = plan.and_kill_at_item(node, block, item);
        }
        let mut fault = FaultConfig::disabled().with_plan(plan).with_evacuation(self.evacuate);
        if let Some(every) = self.checkpoint_every {
            fault = fault.with_checkpoint_every(every);
        }
        fault
    }

    /// Lossy transport plan assembled from `--net-fault`/`--retry-max`/
    /// `--net-timeout`; `None` when the transport stays lossless.
    pub fn net_fault_plan(&self) -> Option<TransportFaultPlan> {
        let (drop_p, corrupt_p, delay_p, seed) = self.net_fault?;
        let mut plan = TransportFaultPlan::new(drop_p, corrupt_p, seed.unwrap_or(self.seed))
            .with_delay(delay_p);
        if let Some(n) = self.retry_max {
            plan = plan.with_retry_max(n);
        }
        if let Some(ns) = self.net_timeout {
            plan = plan.with_timeout_ns(ns);
        }
        Some(plan)
    }
}

const USAGE: &str = "usage: blaze <pi|wordcount|pagerank|kmeans|gmm|knn|all> \
[--nodes N] [--workers W] [--engine blaze|conventional] \
[--backend simulated|threaded[:N]] [--scale S] \
[--artifacts DIR|none] [--seed SEED] [--fail-at NODE@BLOCK[.ITEM] ...] \
[--checkpoint-every BLOCKS] [--evacuate] \
[--net-fault drop=P,corrupt=P[,delay=P][,seed=S]] [--retry-max N] \
[--net-timeout NS] [--transport-window BYTES] \
[--trace PATH] [--pin-threads]
       blaze report <BASELINE> <CANDIDATE> [--gate] [--deterministic-only] \
[--threshold PCT] [--out PATH]";

/// Parse argv (without the program name).
pub fn parse(args: &[String]) -> Result<Options, String> {
    let mut opts = Options::default();
    let mut it = args.iter();
    let Some(task) = it.next() else {
        return Err(USAGE.to_string());
    };
    if task == "--help" || task == "-h" {
        return Err(USAGE.to_string());
    }
    opts.task = task.clone();
    while let Some(flag) = it.next() {
        let mut next = |what: &str| {
            it.next()
                .cloned()
                .ok_or_else(|| format!("{flag} needs a {what}"))
        };
        match flag.as_str() {
            "--nodes" => opts.nodes = next("count")?.parse().map_err(|e| format!("{e}"))?,
            "--workers" => opts.workers = next("count")?.parse().map_err(|e| format!("{e}"))?,
            "--scale" => opts.scale = next("factor")?.parse().map_err(|e| format!("{e}"))?,
            "--seed" => opts.seed = next("seed")?.parse().map_err(|e| format!("{e}"))?,
            "--artifacts" => opts.artifacts = next("dir")?,
            "--checkpoint-every" => {
                opts.checkpoint_every =
                    Some(next("block count")?.parse().map_err(|e| format!("{e}"))?)
            }
            "--evacuate" => opts.evacuate = true,
            "--transport-window" => {
                opts.transport_window =
                    Some(next("byte count")?.parse().map_err(|e| format!("{e}"))?)
            }
            "--trace" => opts.trace = Some(next("path")?),
            "--pin-threads" => opts.pin_threads = true,
            "--fail-at" => {
                let spec = next("NODE@BLOCK[.ITEM] spec")?;
                let Some((node, rest)) = spec.split_once('@') else {
                    return Err(format!("--fail-at wants NODE@BLOCK[.ITEM], got {spec:?}"));
                };
                let node = node.parse().map_err(|e| format!("--fail-at node: {e}"))?;
                match rest.split_once('.') {
                    // NODE@BLOCK.ITEM: a mid-block (sub-task) kill.
                    Some((block, item)) => opts.fail_at_item.push((
                        node,
                        block.parse().map_err(|e| format!("--fail-at block: {e}"))?,
                        item.parse().map_err(|e| format!("--fail-at item: {e}"))?,
                    )),
                    None => opts.fail_at.push((
                        node,
                        rest.parse().map_err(|e| format!("--fail-at block: {e}"))?,
                    )),
                }
            }
            "--net-fault" => {
                let spec = next("drop=P,corrupt=P[,delay=P][,seed=S] spec")?;
                let (mut drop_p, mut corrupt_p, mut delay_p) = (0.0f64, 0.0f64, 0.0f64);
                let mut fault_seed: Option<u64> = None;
                for kv in spec.split(',') {
                    let Some((key, val)) = kv.split_once('=') else {
                        return Err(format!("--net-fault wants key=value pairs, got {kv:?}"));
                    };
                    match key {
                        "drop" => {
                            drop_p = val.parse().map_err(|e| format!("--net-fault drop: {e}"))?
                        }
                        "corrupt" => {
                            corrupt_p =
                                val.parse().map_err(|e| format!("--net-fault corrupt: {e}"))?
                        }
                        "delay" => {
                            delay_p =
                                val.parse().map_err(|e| format!("--net-fault delay: {e}"))?
                        }
                        "seed" => {
                            fault_seed = Some(
                                val.parse().map_err(|e| format!("--net-fault seed: {e}"))?,
                            )
                        }
                        other => return Err(format!("--net-fault: unknown key {other:?}")),
                    }
                }
                for (name, p) in [("drop", drop_p), ("corrupt", corrupt_p), ("delay", delay_p)] {
                    if !(0.0..=1.0).contains(&p) {
                        return Err(format!("--net-fault {name} must be in [0, 1], got {p}"));
                    }
                }
                opts.net_fault = Some((drop_p, corrupt_p, delay_p, fault_seed));
            }
            "--retry-max" => {
                opts.retry_max = Some(next("count")?.parse().map_err(|e| format!("{e}"))?)
            }
            "--net-timeout" => {
                opts.net_timeout =
                    Some(next("nanoseconds")?.parse().map_err(|e| format!("{e}"))?)
            }
            "--engine" => {
                opts.engine = match next("name")?.as_str() {
                    "blaze" | "eager" => EngineKind::Eager,
                    "conventional" | "spark" => EngineKind::Conventional,
                    other => return Err(format!("unknown engine {other:?}")),
                }
            }
            "--backend" => opts.backend = Backend::parse(&next("spec")?)?,
            other => return Err(format!("unknown flag {other:?}\n{USAGE}")),
        }
    }
    if opts.nodes == 0 || opts.workers == 0 || opts.scale == 0 {
        return Err("--nodes/--workers/--scale must be positive".into());
    }
    Ok(opts)
}

fn make_cluster(opts: &Options) -> Cluster {
    let mut cfg = ClusterConfig::sized(opts.nodes, opts.workers)
        .with_engine(opts.engine)
        .with_backend(opts.backend)
        .with_seed(opts.seed)
        .with_fault(opts.fault_config())
        .with_trace(opts.trace.is_some());
    if let Some(bytes) = opts.transport_window {
        cfg = cfg.with_transport_window(bytes);
    }
    if let Some(plan) = opts.net_fault_plan() {
        cfg = cfg.with_net_fault(plan);
    }
    // Only set when the flag is present, so the BLAZE_PIN_THREADS env
    // default baked into ClusterConfig survives unflagged runs.
    if opts.pin_threads {
        cfg = cfg.with_pin_threads(true);
    }
    Cluster::new(cfg)
}

fn load_runtime(opts: &Options) -> Option<Runtime> {
    if opts.artifacts.is_empty() || opts.artifacts == "none" {
        return None;
    }
    match Runtime::load(&opts.artifacts) {
        Ok(rt) => {
            eprintln!("loaded PJRT runtime: {rt:?}");
            Some(rt)
        }
        Err(e) => {
            eprintln!("no PJRT runtime ({e:#}); falling back to scalar mappers");
            None
        }
    }
}

/// Run the CLI; returns the process exit code.
pub fn run(args: &[String]) -> i32 {
    if args.first().map(String::as_str) == Some("report") {
        return crate::regress::run_report(&args[1..]);
    }
    let opts = match parse(args) {
        Ok(o) => o,
        Err(msg) => {
            eprintln!("{msg}");
            return 2;
        }
    };
    let tasks: Vec<&str> = if opts.task == "all" {
        vec!["pi", "wordcount", "pagerank", "kmeans", "gmm", "knn"]
    } else {
        vec![opts.task.as_str()]
    };
    let runtime = load_runtime(&opts);
    for task in tasks {
        let cluster = make_cluster(&opts);
        let report = match task {
            "pi" => apps::pi::pi_blaze(&cluster, 1_000_000 * opts.scale as u64),
            "wordcount" => {
                let lines = corpus_lines(20_000 * opts.scale, 10, opts.seed);
                let dv = crate::containers::DistVector::from_vec(&cluster, lines);
                apps::wordcount::wordcount(&cluster, &dv).0
            }
            "pagerank" => {
                let g = Graph::graph500(12 + opts.scale.ilog2(), 16, opts.seed);
                apps::pagerank::pagerank(&cluster, &g, 1e-5, 100).0
            }
            "kmeans" => {
                let (dim, k) = runtime
                    .as_ref()
                    .map_or((4, 5), |rt| (rt.dim(), rt.k()));
                let ps = PointSet::clustered(50_000 * opts.scale, dim, k, 0.6, opts.seed);
                let blocks = apps::kmeans::distribute_blocks(
                    &cluster,
                    &ps,
                    runtime.as_ref().map_or(4096, Runtime::batch),
                );
                let init = apps::kmeans::init_first_k(&ps, k);
                apps::kmeans::kmeans(
                    &cluster, &blocks, ps.n, dim, k, init, 1e-4, 30, runtime.as_ref(),
                )
                .0
            }
            "gmm" => {
                let (dim, k) = runtime
                    .as_ref()
                    .map_or((4, 5), |rt| (rt.dim(), rt.k()));
                let ps = PointSet::clustered(10_000 * opts.scale, dim, k, 0.6, opts.seed);
                apps::gmm::gmm_from_points(&cluster, &ps, k, 1e-6, 30, runtime.as_ref()).0
            }
            "knn" => {
                let dim = runtime.as_ref().map_or(4, Runtime::dim);
                let ps = PointSet::uniform(100_000 * opts.scale, dim, opts.seed);
                let query = vec![0.5f32; dim];
                apps::knn::knn(&cluster, &ps, &query, 100, runtime.as_ref()).0
            }
            other => {
                eprintln!("unknown task {other:?}\n{USAGE}");
                return 2;
            }
        };
        println!("{}", report.line());
        if let Some(base) = &opts.trace {
            // One trace per task: `all` runs get per-task suffixes so the
            // logs don't clobber each other.
            let path =
                if tasks.len() > 1 { format!("{base}.{task}") } else { base.clone() };
            if let Err(e) = cluster.export_trace(&path) {
                eprintln!("trace export to {path:?} failed: {e}");
                return 1;
            }
            eprintln!("trace written: {path} (+ {path}.chrome.json)");
        }
    }
    0
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(str::to_string).collect()
    }

    #[test]
    fn parse_full_flags() {
        let o = parse(&argv(
            "kmeans --nodes 8 --workers 2 --engine conventional --scale 3 --seed 9 --artifacts none",
        ))
        .unwrap();
        assert_eq!(o.task, "kmeans");
        assert_eq!(o.nodes, 8);
        assert_eq!(o.workers, 2);
        assert_eq!(o.engine, EngineKind::Conventional);
        assert_eq!(o.scale, 3);
        assert_eq!(o.seed, 9);
        assert_eq!(o.artifacts, "none");
    }

    #[test]
    fn parse_backend_flag() {
        let o = parse(&argv("pi --backend threaded:3")).unwrap();
        assert_eq!(o.backend, Backend::Threaded(3));
        let o = parse(&argv("pi --backend threaded")).unwrap();
        assert_eq!(o.backend, Backend::Threaded(2));
        let o = parse(&argv("pi --backend simulated")).unwrap();
        assert_eq!(o.backend, Backend::Simulated);
        assert!(parse(&argv("pi --backend warp")).is_err());
        assert!(parse(&argv("pi --backend")).is_err());
    }

    #[test]
    fn parse_transport_window_flag() {
        let o = parse(&argv("pi --transport-window 1")).unwrap();
        assert_eq!(o.transport_window, Some(1));
        assert_eq!(parse(&argv("pi")).unwrap().transport_window, None);
        assert!(parse(&argv("pi --transport-window")).is_err());
        assert!(parse(&argv("pi --transport-window lots")).is_err());
    }

    #[test]
    fn run_wordcount_threaded_narrow_window_end_to_end() {
        // Stall storm through the real transport: window 1 forces a stall
        // per cross-node frame, and the run must still succeed.
        assert_eq!(
            run(&argv(
                "wordcount --nodes 2 --workers 2 --scale 1 --artifacts none \
                 --backend threaded:2 --transport-window 1"
            )),
            0
        );
    }

    #[test]
    fn run_wordcount_threaded_end_to_end() {
        assert_eq!(
            run(&argv(
                "wordcount --nodes 2 --workers 2 --scale 1 --artifacts none \
                 --backend threaded:2"
            )),
            0
        );
    }

    #[test]
    fn parse_pin_threads_flag() {
        assert!(parse(&argv("pi --pin-threads")).unwrap().pin_threads);
        assert!(!parse(&argv("pi")).unwrap().pin_threads);
    }

    #[test]
    fn run_wordcount_threaded_pinned_end_to_end() {
        // Pinning is best-effort: the run must succeed (and stay
        // byte-identical) whether or not the affinity calls land.
        assert_eq!(
            run(&argv(
                "wordcount --nodes 2 --workers 2 --scale 1 --artifacts none \
                 --backend threaded:2 --pin-threads"
            )),
            0
        );
    }

    #[test]
    fn parse_rejects_bad_input() {
        assert!(parse(&argv("")).is_err());
        assert!(parse(&argv("pi --engine warp")).is_err());
        assert!(parse(&argv("pi --nodes")).is_err());
        assert!(parse(&argv("pi --nodes 0")).is_err());
        assert!(parse(&argv("pi --frobnicate 1")).is_err());
        assert!(parse(&argv("pi --fail-at 2")).is_err());
        assert!(parse(&argv("pi --fail-at two@1")).is_err());
        assert!(parse(&argv("pi --checkpoint-every x")).is_err());
    }

    #[test]
    fn parse_fault_flags() {
        let o = parse(&argv(
            "wordcount --fail-at 1@3 --fail-at 2@7 --checkpoint-every 4 --evacuate",
        ))
        .unwrap();
        assert_eq!(o.fail_at, vec![(1, 3), (2, 7)]);
        assert_eq!(o.checkpoint_every, Some(4));
        assert!(o.evacuate);
        let fault = o.fault_config();
        assert!(fault.enabled());
        assert!(fault.evacuate);
        assert_eq!(fault.plan.events().len(), 2);
        assert_eq!(fault.checkpoint_every_blocks, Some(4));
        // No fault flags → the ordinary engines run, hot-standby default.
        let plain = parse(&argv("wordcount")).unwrap().fault_config();
        assert!(!plain.enabled());
        assert!(!plain.evacuate);
    }

    #[test]
    fn parse_fail_at_item_spec() {
        let o = parse(&argv("wordcount --fail-at 1@3 --fail-at 2@5.100")).unwrap();
        assert_eq!(o.fail_at, vec![(1, 3)]);
        assert_eq!(o.fail_at_item, vec![(2, 5, 100)]);
        let fault = o.fault_config();
        assert!(fault.enabled());
        assert_eq!(fault.plan.events().len(), 2);
        assert!(parse(&argv("pi --fail-at 2@5.")).is_err());
        assert!(parse(&argv("pi --fail-at 2@.7")).is_err());
        assert!(parse(&argv("pi --fail-at 2@5.x")).is_err());
    }

    #[test]
    fn parse_net_fault_flags() {
        let o = parse(&argv(
            "wordcount --net-fault drop=0.2,corrupt=0.05,seed=9 --retry-max 16 \
             --net-timeout 500000000",
        ))
        .unwrap();
        assert_eq!(o.net_fault, Some((0.2, 0.05, 0.0, Some(9))));
        assert_eq!(o.retry_max, Some(16));
        assert_eq!(o.net_timeout, Some(500_000_000));
        let plan = o.net_fault_plan().expect("plan assembled");
        assert_eq!(plan.retry_max, 16);
        assert_eq!(plan.timeout_ns, 500_000_000);
        // Unflagged runs stay lossless.
        assert_eq!(parse(&argv("pi")).unwrap().net_fault_plan(), None);
        // Without seed=, the run seed feeds the plan — flag order free.
        let o = parse(&argv("pi --net-fault drop=0.1,corrupt=0 --seed 7")).unwrap();
        assert_eq!(o.net_fault_plan().unwrap().seed, 7);
        assert!(parse(&argv("pi --net-fault drop=2.0,corrupt=0")).is_err());
        assert!(parse(&argv("pi --net-fault dorp=0.1")).is_err());
        assert!(parse(&argv("pi --net-fault drop")).is_err());
        assert!(parse(&argv("pi --retry-max x")).is_err());
        assert!(parse(&argv("pi --net-timeout")).is_err());
    }

    #[test]
    fn run_wordcount_threaded_lossy_end_to_end() {
        // Lossy channel transport through the whole CLI path: drops,
        // corruptions (checksum rejects), retries — and the run succeeds.
        assert_eq!(
            run(&argv(
                "wordcount --nodes 3 --workers 2 --scale 1 --artifacts none \
                 --backend threaded:2 --net-fault drop=0.2,corrupt=0.05,seed=9 \
                 --retry-max 16"
            )),
            0
        );
    }

    #[test]
    fn run_wordcount_threaded_midblock_kill_end_to_end() {
        // A mid-block kill on the threaded backend: the in-flight map
        // aborts, partials are discarded, and recovery replays the block.
        assert_eq!(
            run(&argv(
                "wordcount --nodes 3 --workers 2 --scale 1 --artifacts none \
                 --backend threaded:2 --fail-at 1@2.50 --checkpoint-every 3"
            )),
            0
        );
    }

    #[test]
    fn run_wordcount_with_failure_end_to_end() {
        assert_eq!(
            run(&argv(
                "wordcount --nodes 3 --workers 2 --scale 1 --artifacts none \
                 --fail-at 1@2 --checkpoint-every 3"
            )),
            0
        );
    }

    #[test]
    fn run_wordcount_with_evacuation_end_to_end() {
        assert_eq!(
            run(&argv(
                "wordcount --nodes 3 --workers 2 --scale 1 --artifacts none \
                 --fail-at 1@2 --checkpoint-every 3 --evacuate"
            )),
            0
        );
    }

    #[test]
    fn run_wordcount_threaded_recovery_with_evacuation_end_to_end() {
        // --fail-at + --evacuate on the threaded backend: kill, rollback,
        // replay on the live pool, then slot evacuation — full CLI path.
        assert_eq!(
            run(&argv(
                "wordcount --nodes 3 --workers 2 --scale 1 --artifacts none \
                 --backend threaded:2 --fail-at 1@2 --checkpoint-every 3 --evacuate"
            )),
            0
        );
    }

    #[test]
    fn run_pi_end_to_end() {
        // Tiny scale, no artifacts: exercises the whole CLI path.
        assert_eq!(run(&argv("pi --nodes 2 --workers 2 --scale 1 --artifacts none")), 0);
    }

    #[test]
    fn parse_trace_flag() {
        let o = parse(&argv("pi --trace /tmp/t.jsonl")).unwrap();
        assert_eq!(o.trace.as_deref(), Some("/tmp/t.jsonl"));
        assert!(parse(&argv("pi --trace")).is_err());
    }

    #[test]
    fn run_pi_with_trace_writes_both_files() {
        let dir = std::env::temp_dir().join("blaze-cli-trace-test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("pi.trace.jsonl");
        let path_s = path.to_str().unwrap().to_string();
        let args: Vec<String> =
            argv("pi --nodes 2 --workers 2 --scale 1 --artifacts none --trace")
                .into_iter()
                .chain([path_s.clone()])
                .collect();
        assert_eq!(run(&args), 0);
        let jsonl = std::fs::read_to_string(&path).unwrap();
        assert!(!jsonl.is_empty(), "trace log has events");
        assert!(jsonl.lines().all(|l| l.starts_with('{') && l.ends_with('}')));
        let chrome = std::fs::read_to_string(format!("{path_s}.chrome.json")).unwrap();
        assert!(
            chrome.starts_with("{\"traceEvents\":["),
            "chrome trace is a traceEvents object"
        );
        std::fs::remove_file(&path).ok();
        std::fs::remove_file(format!("{path_s}.chrome.json")).ok();
    }

    #[test]
    fn unknown_task_fails() {
        assert_eq!(run(&argv("sort --artifacts none")), 2);
    }

    #[test]
    fn run_report_gates_bench_artifacts_end_to_end() {
        use crate::bench::report::{Report, Row};

        let dir = std::env::temp_dir().join("blaze-report-e2e");
        let base_dir = dir.join("base");
        let cand_dir = dir.join("cand");
        std::fs::create_dir_all(&base_dir).unwrap();
        std::fs::create_dir_all(&cand_dir).unwrap();

        // Two independent clusters with the same seeded config: every
        // deterministic field (counters + histogram digests) must match.
        let stats_for = || {
            let cluster = Cluster::new(ClusterConfig::sized(2, 2).with_seed(7));
            apps::pi::pi_blaze(&cluster, 10_000);
            cluster.metrics().last_run().expect("run recorded").clone()
        };
        let write = |d: &std::path::Path, bump: f64| {
            let stats = stats_for();
            let mut rep = Report::new("e2e_pi");
            rep.meta("backend", "simulated");
            rep.push(
                Row::new("blaze")
                    .tag("nodes", 2)
                    .num("pairs_emitted", stats.pairs_emitted as f64 + bump)
                    .counters(&stats),
            );
            rep.write_to(d).expect("write bench json");
        };
        let report_args = |extra: &[&str]| -> Vec<String> {
            ["report", base_dir.to_str().unwrap(), cand_dir.to_str().unwrap()]
                .iter()
                .copied()
                .chain(extra.iter().copied())
                .map(str::to_string)
                .collect()
        };

        write(&base_dir, 0.0);
        write(&cand_dir, 0.0);
        assert_eq!(
            run(&report_args(&["--gate", "--deterministic-only"])),
            0,
            "two seeded same-config runs diff clean"
        );

        // Perturb one deterministic field → gated regression.
        write(&cand_dir, 1.0);
        assert_eq!(run(&report_args(&["--gate"])), 1, "perturbed counter must gate");
        assert_eq!(run(&report_args(&[])), 0, "without --gate the diff only reports");

        std::fs::remove_dir_all(&dir).ok();
    }
}
