//! Network performance model: bandwidth, latency, cross-rack cap.

/// Analytical model of the cluster interconnect.
///
/// Transfer time for a node = serialized bytes over NIC bandwidth plus a
/// per-message latency; an optional bisection cap throttles the aggregate
/// when all nodes shuffle at once (the paper's "cross-rack bandwidth becomes
/// the bottleneck" regime, §2.3.2).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NetworkModel {
    /// Per-node NIC bandwidth, bytes/second.
    pub nic_bytes_per_sec: f64,
    /// One-way per-message latency, seconds.
    pub latency_sec: f64,
    /// Aggregate bisection bandwidth cap, bytes/second (None = full).
    pub bisection_bytes_per_sec: Option<f64>,
    /// Fixed per-message software overhead (serialization envelope, MPI
    /// matching), seconds.
    pub per_message_overhead_sec: f64,
}

impl NetworkModel {
    /// AWS `r5.xlarge`-like: "up to 10 Gbps", ~50 µs RTT/2 in-VPC latency.
    pub fn aws_10gbps() -> Self {
        Self {
            nic_bytes_per_sec: 10.0e9 / 8.0,
            latency_sec: 50e-6,
            bisection_bytes_per_sec: None,
            per_message_overhead_sec: 5e-6,
        }
    }

    /// Same NIC but with a cross-rack bisection cap (large-cluster regime).
    pub fn aws_10gbps_cross_rack(bisection_gbps: f64) -> Self {
        Self {
            bisection_bytes_per_sec: Some(bisection_gbps * 1e9 / 8.0),
            ..Self::aws_10gbps()
        }
    }

    /// Loopback: effectively infinite bandwidth, used for 1-node runs.
    pub fn loopback() -> Self {
        Self {
            nic_bytes_per_sec: 50.0e9,
            latency_sec: 1e-6,
            bisection_bytes_per_sec: None,
            per_message_overhead_sec: 1e-7,
        }
    }

    /// Time for one node to push `bytes` in `messages` messages.
    pub fn node_send_time(&self, bytes: u64, messages: u64) -> f64 {
        bytes as f64 / self.nic_bytes_per_sec
            + messages as f64 * (self.latency_sec + self.per_message_overhead_sec)
    }

    /// Extra time if the aggregate cross-node traffic exceeds the bisection
    /// cap: aggregate bytes over bisection bandwidth.
    pub fn bisection_time(&self, aggregate_bytes: u64) -> f64 {
        match self.bisection_bytes_per_sec {
            Some(b) => aggregate_bytes as f64 / b,
            None => 0.0,
        }
    }
}

impl Default for NetworkModel {
    fn default() -> Self {
        Self::aws_10gbps()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ten_gbps_moves_1_25_gb_per_sec() {
        let m = NetworkModel::aws_10gbps();
        let t = m.node_send_time(1_250_000_000, 0);
        assert!((t - 1.0).abs() < 1e-9, "t={t}");
    }

    #[test]
    fn latency_dominates_small_messages() {
        let m = NetworkModel::aws_10gbps();
        let many_small = m.node_send_time(1000, 1000);
        let one_big = m.node_send_time(1000, 1);
        assert!(many_small > 100.0 * one_big);
    }

    #[test]
    fn bisection_cap_binds_only_when_set() {
        let free = NetworkModel::aws_10gbps();
        assert_eq!(free.bisection_time(1 << 30), 0.0);
        let capped = NetworkModel::aws_10gbps_cross_rack(10.0);
        assert!(capped.bisection_time(1 << 30) > 0.0);
    }
}
