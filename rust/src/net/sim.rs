//! Flow accounting for the simulated shuffle.
//!
//! Every shuffle really serializes its messages; [`NetSim`] records the
//! resulting `(src, dst, bytes, messages)` flows and [`FlowMatrix`] turns
//! them into a phase time under a [`super::NetworkModel`]: each node's send
//! and receive sides are half-duplex-summed independently, the phase takes
//! the max over nodes (all nodes shuffle concurrently), and an optional
//! bisection cap binds on the aggregate.

use super::model::NetworkModel;

/// Per-(src,dst) byte/message accounting for one shuffle phase.
#[derive(Debug, Clone)]
pub struct FlowMatrix {
    n: usize,
    bytes: Vec<u64>,
    messages: Vec<u64>,
}

impl FlowMatrix {
    /// Empty matrix over `n` nodes.
    pub fn new(n: usize) -> Self {
        Self { n, bytes: vec![0; n * n], messages: vec![0; n * n] }
    }

    /// Number of nodes.
    pub fn nodes(&self) -> usize {
        self.n
    }

    /// Record one message of `bytes` from `src` to `dst`.
    pub fn record(&mut self, src: usize, dst: usize, bytes: u64) {
        let i = src * self.n + dst;
        self.bytes[i] += bytes;
        self.messages[i] += 1;
    }

    /// Bytes sent from `src` to `dst`.
    pub fn bytes_between(&self, src: usize, dst: usize) -> u64 {
        self.bytes[src * self.n + dst]
    }

    /// Total bytes crossing node boundaries (src ≠ dst).
    pub fn cross_node_bytes(&self) -> u64 {
        let mut total = 0;
        for s in 0..self.n {
            for d in 0..self.n {
                if s != d {
                    total += self.bytes[s * self.n + d];
                }
            }
        }
        total
    }

    /// Total bytes including node-local (loopback) traffic.
    pub fn total_bytes(&self) -> u64 {
        self.bytes.iter().sum()
    }

    /// Phase transfer time under `model`. Local (src == dst) flows are free:
    /// they never leave the node.
    pub fn phase_time(&self, model: &NetworkModel) -> f64 {
        let mut worst = 0.0f64;
        for node in 0..self.n {
            let (mut tx_b, mut tx_m, mut rx_b, mut rx_m) = (0u64, 0u64, 0u64, 0u64);
            for other in 0..self.n {
                if other == node {
                    continue;
                }
                tx_b += self.bytes[node * self.n + other];
                tx_m += self.messages[node * self.n + other];
                rx_b += self.bytes[other * self.n + node];
                rx_m += self.messages[other * self.n + node];
            }
            let t = model
                .node_send_time(tx_b, tx_m)
                .max(model.node_send_time(rx_b, rx_m));
            worst = worst.max(t);
        }
        worst.max(model.bisection_time(self.cross_node_bytes()))
    }

    /// Merge another matrix (e.g. accumulate several rounds).
    pub fn merge(&mut self, other: &FlowMatrix) {
        assert_eq!(self.n, other.n);
        for i in 0..self.bytes.len() {
            self.bytes[i] += other.bytes[i];
            self.messages[i] += other.messages[i];
        }
    }
}

/// Simulated network endpoint set: moves real serialized buffers between
/// virtual nodes while recording flows.
#[derive(Debug)]
pub struct NetSim {
    flows: FlowMatrix,
    /// In-flight mailboxes: `mailbox[dst]` holds (src, payload).
    mailboxes: Vec<Vec<(usize, Vec<u8>)>>,
}

impl NetSim {
    /// Network over `n` virtual nodes.
    pub fn new(n: usize) -> Self {
        Self { flows: FlowMatrix::new(n), mailboxes: (0..n).map(|_| Vec::new()).collect() }
    }

    /// Number of nodes.
    pub fn nodes(&self) -> usize {
        self.flows.nodes()
    }

    /// Send a serialized payload; the bytes are really moved (and counted).
    pub fn send(&mut self, src: usize, dst: usize, payload: Vec<u8>) {
        self.flows.record(src, dst, payload.len() as u64);
        self.mailboxes[dst].push((src, payload));
    }

    /// Drain everything delivered to `dst`.
    pub fn recv_all(&mut self, dst: usize) -> Vec<(usize, Vec<u8>)> {
        std::mem::take(&mut self.mailboxes[dst])
    }

    /// Flow accounting so far.
    pub fn flows(&self) -> &FlowMatrix {
        &self.flows
    }

    /// Take the flow matrix and reset the accounting (mailboxes must be
    /// empty — all messages consumed).
    pub fn take_flows(&mut self) -> FlowMatrix {
        debug_assert!(self.mailboxes.iter().all(Vec::is_empty), "undelivered messages");
        std::mem::replace(&mut self.flows, FlowMatrix::new(self.mailboxes.len()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flows_recorded_and_delivered() {
        let mut net = NetSim::new(3);
        net.send(0, 1, vec![0u8; 100]);
        net.send(0, 2, vec![0u8; 50]);
        net.send(2, 1, vec![0u8; 25]);
        assert_eq!(net.flows().bytes_between(0, 1), 100);
        assert_eq!(net.flows().cross_node_bytes(), 175);
        let at1 = net.recv_all(1);
        assert_eq!(at1.len(), 2);
        assert_eq!(at1.iter().map(|(_, p)| p.len()).sum::<usize>(), 125);
        assert!(net.recv_all(1).is_empty());
    }

    #[test]
    fn local_traffic_is_free() {
        let model = NetworkModel::aws_10gbps();
        let mut m = FlowMatrix::new(2);
        m.record(0, 0, 1 << 30);
        assert_eq!(m.phase_time(&model), 0.0);
        m.record(0, 1, 1 << 20);
        assert!(m.phase_time(&model) > 0.0);
    }

    #[test]
    fn phase_time_is_max_over_nodes() {
        let model = NetworkModel {
            nic_bytes_per_sec: 1e6,
            latency_sec: 0.0,
            bisection_bytes_per_sec: None,
            per_message_overhead_sec: 0.0,
        };
        let mut m = FlowMatrix::new(3);
        // Node 0 sends 1 MB to node 1 and 1 MB to node 2 → tx = 2 s.
        m.record(0, 1, 1_000_000);
        m.record(0, 2, 1_000_000);
        let t = m.phase_time(&model);
        assert!((t - 2.0).abs() < 1e-9, "t={t}");
    }

    #[test]
    fn receive_side_can_dominate() {
        let model = NetworkModel {
            nic_bytes_per_sec: 1e6,
            latency_sec: 0.0,
            bisection_bytes_per_sec: None,
            per_message_overhead_sec: 0.0,
        };
        let mut m = FlowMatrix::new(3);
        // All-to-one: node 2 receives 2 MB → rx = 2 s even though each
        // sender only spends 1 s.
        m.record(0, 2, 1_000_000);
        m.record(1, 2, 1_000_000);
        assert!((m.phase_time(&model) - 2.0).abs() < 1e-9);
    }

    #[test]
    fn merge_accumulates() {
        let mut a = FlowMatrix::new(2);
        a.record(0, 1, 10);
        let mut b = FlowMatrix::new(2);
        b.record(0, 1, 5);
        a.merge(&b);
        assert_eq!(a.bytes_between(0, 1), 15);
    }
}
