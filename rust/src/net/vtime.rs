//! Virtual-time accounting for the simulated cluster.
//!
//! Per-virtual-node compute is measured for real (wall time of that node's
//! work, executed alone on the host); network transfer is charged by the
//! model. A run is a sequence of phases:
//!
//! * **Compute** — all nodes work concurrently: phase time = max over nodes
//!   of (node compute / workers-per-node parallel efficiency).
//! * **Shuffle** — transfer time from the [`super::FlowMatrix`], optionally
//!   *overlapped* with the destination-side reduce compute (the eager
//!   engine's asynchronous reduce, paper §2.3.1): overlapped phase time =
//!   max(transfer, reduce); the conventional engine takes the sum (barrier).
//!
//! The virtual makespan is the sum of phase times.

use super::model::NetworkModel;
use super::sim::FlowMatrix;

/// What a phase represents (for reporting).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PhaseKind {
    /// Parallel per-node compute (map, local reduce, generation...).
    Compute,
    /// Cross-node transfer, reduce overlapped (eager engine).
    ShuffleOverlapped,
    /// Cross-node transfer then reduce, barrier between (conventional).
    ShuffleBarrier,
}

/// One accounted phase.
#[derive(Debug, Clone)]
pub struct Phase {
    /// Kind of phase.
    pub kind: PhaseKind,
    /// Label for reports ("map", "shuffle", ...).
    pub label: &'static str,
    /// Virtual duration, seconds.
    pub seconds: f64,
    /// Cross-node bytes if this was a shuffle.
    pub shuffle_bytes: u64,
}

/// Virtual-time accumulator for one distributed operation.
#[derive(Debug, Clone, Default)]
pub struct VirtualTime {
    phases: Vec<Phase>,
}

/// Fraction of linear speedup attained by intra-node threading. The paper's
/// workloads scale near-linearly over 4-core nodes; 0.95 models scheduling
/// + memory-bandwidth losses.
pub const INTRA_NODE_EFFICIENCY: f64 = 0.95;

impl VirtualTime {
    /// Fresh accumulator.
    pub fn new() -> Self {
        Self::default()
    }

    /// Account a compute phase from measured per-node single-thread seconds.
    ///
    /// `per_node_seconds[i]` is the wall time node `i`'s work took executed
    /// serially; with `workers` threads per node it would take
    /// `t / (workers * eff)`. Phase time is the slowest node.
    pub fn compute_phase(
        &mut self,
        label: &'static str,
        per_node_seconds: &[f64],
        workers_per_node: usize,
    ) -> f64 {
        let eff = if workers_per_node > 1 { INTRA_NODE_EFFICIENCY } else { 1.0 };
        let t = per_node_seconds
            .iter()
            .fold(0.0f64, |acc, &s| acc.max(s / (workers_per_node as f64 * eff)));
        self.phases.push(Phase { kind: PhaseKind::Compute, label, seconds: t, shuffle_bytes: 0 });
        t
    }

    /// Account an eager-engine shuffle: transfer overlapped with the
    /// destination reduce work (`reduce_seconds`, already per-node-max and
    /// worker-scaled by the caller via [`Self::scaled_compute`]).
    pub fn shuffle_overlapped(
        &mut self,
        label: &'static str,
        flows: &FlowMatrix,
        model: &NetworkModel,
        reduce_seconds: f64,
    ) -> f64 {
        let transfer = flows.phase_time(model);
        let t = transfer.max(reduce_seconds);
        self.phases.push(Phase {
            kind: PhaseKind::ShuffleOverlapped,
            label,
            seconds: t,
            shuffle_bytes: flows.cross_node_bytes(),
        });
        t
    }

    /// Account a conventional shuffle: transfer, barrier, then reduce.
    pub fn shuffle_barrier(
        &mut self,
        label: &'static str,
        flows: &FlowMatrix,
        model: &NetworkModel,
        reduce_seconds: f64,
    ) -> f64 {
        let t = flows.phase_time(model) + reduce_seconds;
        self.phases.push(Phase {
            kind: PhaseKind::ShuffleBarrier,
            label,
            seconds: t,
            shuffle_bytes: flows.cross_node_bytes(),
        });
        t
    }

    /// Worker-scale a measured serial time: `t / (workers * eff)`.
    pub fn scaled_compute(serial_seconds: f64, workers_per_node: usize) -> f64 {
        let eff = if workers_per_node > 1 { INTRA_NODE_EFFICIENCY } else { 1.0 };
        serial_seconds / (workers_per_node as f64 * eff)
    }

    /// Append an already-computed phase duration (e.g. a fixed barrier
    /// latency).
    pub fn fixed_phase(&mut self, label: &'static str, seconds: f64) {
        self.phases.push(Phase { kind: PhaseKind::Compute, label, seconds, shuffle_bytes: 0 });
    }

    /// Total virtual makespan.
    pub fn makespan(&self) -> f64 {
        self.phases.iter().map(|p| p.seconds).sum()
    }

    /// Virtual seconds spent in compute phases (the engines' shared
    /// `RunStats::compute_sec`; `makespan - compute_sec` is the shuffle
    /// portion).
    pub fn compute_sec(&self) -> f64 {
        self.phases
            .iter()
            .filter(|p| matches!(p.kind, PhaseKind::Compute))
            .map(|p| p.seconds)
            .sum()
    }

    /// Total cross-node shuffle bytes.
    pub fn total_shuffle_bytes(&self) -> u64 {
        self.phases.iter().map(|p| p.shuffle_bytes).sum()
    }

    /// All recorded phases.
    pub fn phases(&self) -> &[Phase] {
        &self.phases
    }

    /// Fold another operation's phases into this one (multi-step jobs).
    pub fn extend(&mut self, other: VirtualTime) {
        self.phases.extend(other.phases);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn compute_phase_takes_slowest_node() {
        let mut vt = VirtualTime::new();
        let t = vt.compute_phase("map", &[1.0, 4.0, 2.0], 1);
        assert!((t - 4.0).abs() < 1e-12);
        assert!((vt.makespan() - 4.0).abs() < 1e-12);
    }

    #[test]
    fn worker_scaling() {
        let mut vt = VirtualTime::new();
        let t = vt.compute_phase("map", &[4.0], 4);
        assert!((t - 4.0 / (4.0 * INTRA_NODE_EFFICIENCY)).abs() < 1e-12);
        // Single worker: no efficiency penalty.
        let t1 = VirtualTime::scaled_compute(4.0, 1);
        assert!((t1 - 4.0).abs() < 1e-12);
    }

    #[test]
    fn overlap_vs_barrier() {
        let model = NetworkModel {
            nic_bytes_per_sec: 1e6,
            latency_sec: 0.0,
            bisection_bytes_per_sec: None,
            per_message_overhead_sec: 0.0,
        };
        let mut flows = FlowMatrix::new(2);
        flows.record(0, 1, 1_000_000); // 1 s transfer
        let mut eager = VirtualTime::new();
        let te = eager.shuffle_overlapped("sh", &flows, &model, 0.6);
        assert!((te - 1.0).abs() < 1e-12, "overlapped = max(1.0, 0.6)");
        let mut conv = VirtualTime::new();
        let tc = conv.shuffle_barrier("sh", &flows, &model, 0.6);
        assert!((tc - 1.6).abs() < 1e-12, "barrier = 1.0 + 0.6");
    }

    #[test]
    fn makespan_sums_phases() {
        let mut vt = VirtualTime::new();
        vt.fixed_phase("a", 1.0);
        vt.fixed_phase("b", 2.5);
        let mut other = VirtualTime::new();
        other.fixed_phase("c", 0.5);
        vt.extend(other);
        assert!((vt.makespan() - 4.0).abs() < 1e-12);
        assert_eq!(vt.phases().len(), 3);
    }
}
