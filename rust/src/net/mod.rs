//! Simulated cluster interconnect.
//!
//! The paper benchmarks on 1–16 AWS `r5.xlarge` nodes (up to 10 Gbps NICs)
//! over MPICH. This host has one core, so cross-node parallelism is
//! *accounted* rather than executed: every MapReduce run really performs all
//! the per-node work and really serializes every shuffle message, but
//! per-virtual-node compute is *measured* and network transfer is *charged*
//! against a calibrated [`model::NetworkModel`]. The resulting virtual
//! makespan drives Figs 4–8. See DESIGN.md §Substitutions.

pub mod model;
pub mod sim;
pub mod vtime;

pub use model::NetworkModel;
pub use sim::{FlowMatrix, NetSim};
pub use vtime::{PhaseKind, VirtualTime};
