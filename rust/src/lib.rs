//! # Blaze — simplified high performance cluster computing
//!
//! Rust reproduction of *"Blaze: Simplified High Performance Cluster
//! Computing"* (Junhao Li, Hang Zhang, 2019): an in-memory MapReduce library
//! for compute-intensive workloads whose data fits distributedly in memory.
//!
//! The library is organised in layers (bottom-up):
//!
//! * [`ser`] — the paper's §2.3.2 *fast serialization*: a protobuf-like
//!   varint codec **without** field tags / wire types, plus the tagged
//!   baseline codec used by the conventional engine.
//! * [`util`] — deterministic splittable RNG, bounded top-k selection,
//!   the batched `fxhash` lanes feeding flush routing and stripe
//!   selection ([`util::hash::hash_batch`] — bit-identical to the scalar
//!   hash), the generic pooled-buffer allocator toggle (the "Blaze TCM"
//!   analogue, [`util::alloc::BufferPool`]), cognitive-load accounting.
//! * [`net`] — the simulated cluster interconnect: per-link bandwidth and
//!   latency, real byte accounting, virtual-time makespan model.
//! * [`containers`] — §2.1 distributed containers: [`containers::DistRange`],
//!   [`containers::DistVector`], [`containers::DistHashMap`] and the
//!   `distribute` / `collect` / `load_file` utilities.
//! * [`mapreduce`] — §2.2/§2.3 the core contribution: the eager-reduction
//!   MapReduce engine, the small-fixed-key-range fast path, built-in
//!   reducers, and the conventional (Spark-analog) baseline engine. Inputs
//!   feed every engine through the single-pass block-cursor API
//!   ([`mapreduce::DistInput::block_cursor`]): one cursor per node walks
//!   the partition exactly once per job, yielding one block per worker.
//! * [`exec`] — the real threaded execution backend
//!   (`ClusterConfig::backend = Backend::Threaded(n)`, CLI
//!   `--backend threaded:N`): a node's map+combine runs on actual OS
//!   threads (work-stealing block queue, bounded per-thread eager caches,
//!   lock-striped shard map with canonical merge order), and shuffle
//!   frames physically move through [`exec::transport`] — one bounded
//!   channel per destination node, backpressure window from
//!   `--transport-window`, stalls/frames/bytes surfaced as `transport.*`
//!   counters with real shuffle wall clock in `phase_wall_ns` — while a
//!   deterministic accounting mirror keeps flows and stall counts
//!   byte-identical to the simulated flow model. The channels can be
//!   made *lossy* ([`exec::transport::TransportFaultPlan`], CLI
//!   `--net-fault`): seeded per-attempt drop/corrupt/delay fates,
//!   checksummed frames, capped exponential-backoff retries, and
//!   timeout-driven node death that degrades gracefully to the
//!   flow-model path — byte-identical results either way. Fault-tolerant
//!   jobs replay killed blocks on the same live pool. The node-local hot
//!   path batches its hashing, recycles flush/frame/chunk buffers
//!   through per-worker and cluster pools under `AllocMode::Pool`
//!   (`alloc.pool.*` counters), sizes shard stripes from the thread
//!   count plus observed contention, and optionally pins pool workers
//!   to cores (`--pin-threads`). Byte-identical results at any thread
//!   count (DESIGN.md §Execution backends, §Transport, §Node-local
//!   hot path).
//! * [`coordinator`] — cluster topology/config, block scheduler, shuffle
//!   orchestration with backpressure, shard rebalancing, metrics.
//! * [`trace`] — structured observability: every engine records typed
//!   events (`MapBlock`, `CacheFlush`, `Shuffle`, `Reduce`, recovery
//!   events…) into a per-cluster [`trace::TraceCollector`] when tracing
//!   is on (`--trace PATH` / `BLAZE_TRACE`), exported as deterministic
//!   canonical JSONL (byte-identical across backends for failure-free
//!   seeded runs — an equivalence-harness gate) and as Chrome
//!   trace-event JSON with occupancy counter tracks (`"ph":"C"`); plus
//!   the per-node counter registry surfaced on `RunStats::counters` and
//!   the deterministic latency histograms ([`trace::histogram`]) on
//!   `RunStats::histograms` (DESIGN.md §Observability).
//! * [`regress`] — the `blaze report` perf gate: loads two `BENCH_*.json`
//!   artifact sets, aligns rows by series+tags, exact-gates deterministic
//!   fields and threshold-checks wall-clock ones, and emits a markdown
//!   diff (nonzero exit under `--gate` on regression).
//! * [`fault`] — fault tolerance: deterministic failure injection
//!   ([`fault::FailurePlan`]) at block-commit, virtual-time, and
//!   mid-block granularity (`AtItem` kills abort the in-flight map,
//!   discard the partial flush, and charge the wasted items before
//!   recovery runs — DESIGN.md §Failure spectrum), per-shard target
//!   checkpoints replicated
//!   through the network model, and a recoverable engine that re-executes
//!   a dead node's map blocks on survivors and recovers its reduce shard
//!   under one of two policies — the default *hot-standby* restore (the
//!   replacement keeps the dead node's identity; routing unchanged) or
//!   `--evacuate` *slot evacuation* (the dead node's key space is re-homed
//!   onto the survivors via [`coordinator::rebalance::plan_with_dead`],
//!   with migration bytes charged through the flow model, and subsequent
//!   reduce traffic routes to the survivors). Failure and failure-free
//!   runs produce byte-identical results under either policy; the
//!   cross-engine equivalence harness (`rust/tests/equivalence.rs`) gates
//!   this for every engine × fault × policy combination.
//! * [`runtime`] — PJRT runtime: loads AOT-compiled JAX/Pallas artifacts
//!   (`artifacts/*.hlo.txt`) and executes them from the map hot path.
//! * [`apps`] — the paper's five data-mining workloads plus Monte-Carlo π,
//!   each written against the Blaze API and against the baseline engine.
//! * [`data`] — deterministic workload generators (Zipf corpus, graph500
//!   Kronecker graphs, Gaussian point clusters).
//!
//! ## Quickstart (word frequency count, paper appendix A.1)
//!
//! ```
//! use blaze::prelude::*;
//!
//! let cluster = Cluster::local(2, 2); // 2 virtual nodes x 2 workers
//! let lines = DistVector::from_vec(
//!     &cluster,
//!     vec!["the quick brown fox".to_string(), "the lazy dog".to_string()],
//! );
//! let mut words: DistHashMap<String, u64> = DistHashMap::new(&cluster);
//! blaze::mapreduce::mapreduce(
//!     &lines,
//!     |_, line: &String, emit| {
//!         for w in line.split_whitespace() {
//!             emit(w.to_string(), 1u64);
//!         }
//!     },
//!     "sum", // built-in reducers by name, like the paper
//!     &mut words,
//! );
//! assert_eq!(words.get(&"the".to_string()), Some(2));
//! ```
//!
//! ## Checkpoint and recover (fault tolerance)
//!
//! Flip on the [`fault`] layer and the same job survives a worker dying
//! mid-run, with identical results:
//!
//! ```
//! use blaze::prelude::*;
//!
//! let cluster = Cluster::new(ClusterConfig::sized(2, 2).with_fault(
//!     FaultConfig::default().with_checkpoint_every(2).with_plan(FailurePlan::kill_at_block(1, 1)),
//! ));
//! let lines = DistVector::from_vec(&cluster, vec!["the quick brown fox".to_string(); 8]);
//! let mut words: DistHashMap<String, u64> = DistHashMap::new(&cluster);
//! blaze::mapreduce::mapreduce(
//!     &lines,
//!     |_, line: &String, emit| {
//!         for w in line.split_whitespace() {
//!             emit(w.to_string(), 1u64);
//!         }
//!     },
//!     "sum",
//!     &mut words,
//! );
//! assert_eq!(words.get(&"the".to_string()), Some(8)); // node 1 died; counts exact
//! ```

pub mod apps;
pub mod bench;
pub mod cli;
pub mod containers;
pub mod coordinator;
pub mod data;
pub mod exec;
pub mod fault;
pub mod mapreduce;
pub mod net;
pub mod regress;
pub mod runtime;
pub mod ser;
pub mod trace;
pub mod util;

/// Convenience re-exports covering the whole public Blaze API surface.
///
/// The paper's "cognitive load" claim (Fig. 10) is that Blaze needs only the
/// `mapreduce` function plus a handful of utilities; this prelude is that
/// surface.
pub mod prelude {
    pub use crate::containers::{
        collect_hashmap, collect_vector, distribute, load_file, DistHashMap, DistRange,
        DistVector,
    };
    pub use crate::coordinator::cluster::{Backend, Cluster, ClusterConfig};
    pub use crate::fault::{FailurePlan, FaultConfig};
    pub use crate::mapreduce::{mapreduce, mapreduce_range, Reducer};
    pub use crate::net::model::NetworkModel;
    pub use crate::ser::fastser::FastSer;
}
