//! Table 1: Monte-Carlo π — Blaze MapReduce vs hand-optimized MPI+OpenMP.
//!
//! Paper: Blaze 0.14/1.44/14.2 s vs MPI+OpenMP 0.14/1.42/14.6 s at
//! 10^7/10^8/10^9 samples (i7-8550U); SLOC 8 vs 24. The claim under test is
//! **parity**: the small-key-range path compiles down to the same execution
//! plan as the hand-written loop, so the ratio must stay ≈1 at every scale.
//! (Absolute times differ from the paper's testbed; the ratio is the
//! reproduced quantity.)
//!
//! `--backend threaded:N` (or `BLAZE_BACKEND`) runs the Blaze MapReduce
//! side on N real OS threads — the closest this reproduction gets to the
//! paper's actual Table-1 measurement. Datapoints (host wall mean/std,
//! virtual makespan, run counters) append to `BENCH_table1_pi.json`.
//! `--trace PATH` (or `BLAZE_TRACE`) runs one extra *untimed* traced rep
//! per sample count and exports its event log to `PATH.n<samples>` — the
//! timed reps never pay the tracing overhead.

use blaze::apps::pi::{pi_blaze, pi_hand_optimized, SLOC_BLAZE, SLOC_MPI_OPENMP};
use blaze::bench;
use blaze::coordinator::cluster::ClusterConfig;
use blaze::net::model::NetworkModel;
use blaze::prelude::*;

fn pi_cluster(backend: Backend) -> Cluster {
    pi_cluster_traced(backend, false)
}

fn pi_cluster_traced(backend: Backend, trace: bool) -> Cluster {
    Cluster::new(
        ClusterConfig::sized(1, 4)
            .with_network(NetworkModel::loopback())
            .with_backend(backend)
            .with_trace(trace),
    )
}

fn main() {
    bench::figure_header(
        "Table 1: Monte Carlo Pi Estimation Performance",
        "Blaze MapReduce ~= hand-optimized MPI+OpenMP at every sample count; SLOC 8 vs 24",
    );
    let backend = bench::backend();
    let reps = bench::reps();
    let trace = bench::trace_path();
    // Paper scales 1e7..1e9; default here 1e6..1e8 (single host core),
    // override with BLAZE_BENCH_SCALE=10 for the paper's sizes.
    let scale = bench::scale() as u64;
    let sample_counts = [1_000_000 * scale, 10_000_000 * scale, 100_000_000 * scale];
    println!("backend: {backend}\n");

    let mut rep = bench::report::Report::new("table1_pi");
    rep.meta("backend", backend);
    rep.meta("scale", scale);
    rep.meta("reps", reps);

    println!(
        "{:<12} {:>22} {:>22} {:>9}",
        "samples", "Blaze MapReduce (s)", "MPI+OpenMP (s)", "ratio"
    );
    for &n in &sample_counts {
        let mut makespans: Vec<f64> = Vec::new();
        let mut last_stats = None;
        let blaze = bench::time_host(reps, || {
            let c = pi_cluster(backend);
            let report = pi_blaze(&c, n);
            makespans.push(report.makespan_sec);
            last_stats = c.metrics().last_run().cloned();
            report
        });
        // One extra untimed rep with the collector on, so the trace
        // artifact exists without perturbing the wall statistics above.
        if let Some(base) = &trace {
            let c = pi_cluster_traced(backend, true);
            pi_blaze(&c, n);
            let path = format!("{base}.n{n}");
            match c.export_trace(&path) {
                Ok(()) => println!("trace written: {path}"),
                Err(e) => eprintln!("trace export to {path:?} failed: {e}"),
            }
        }
        let hand = bench::time_host(reps, || {
            let c = pi_cluster(Backend::Simulated);
            pi_hand_optimized(&c, n)
        });
        // time_host runs one discarded warmup before the timed reps; drop
        // its makespan too so the virtual figure is the mean over the
        // same reps the wall statistics cover.
        let timed = &makespans[makespans.len().min(1)..];
        let makespan = bench::summarize(timed).mean;
        let mut row = bench::report::Row::new("blaze-mapreduce")
            .tag("samples", n)
            .num("host_wall_mean_sec", blaze.mean)
            .num("host_wall_std_sec", blaze.std)
            .num("virtual_makespan_mean_sec", makespan)
            .num("ratio_vs_hand", blaze.mean / hand.mean);
        if let Some(stats) = &last_stats {
            row = row.counters(stats);
        }
        rep.push(row);
        rep.push(
            bench::report::Row::new("hand-optimized")
                .tag("samples", n)
                .num("host_wall_mean_sec", hand.mean)
                .num("host_wall_std_sec", hand.std),
        );
        println!(
            "{:<12} {:>22} {:>22} {:>8.3}x",
            format!("{:.0e}", n as f64),
            blaze.to_string(),
            hand.to_string(),
            blaze.mean / hand.mean
        );
    }
    println!("\nSLOC: Blaze {SLOC_BLAZE} vs MPI+OpenMP {SLOC_MPI_OPENMP} (paper: 8 vs 24)");

    match rep.write() {
        Ok(path) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("could not write bench json: {e}"),
    }
}
