//! Table 1: Monte-Carlo π — Blaze MapReduce vs hand-optimized MPI+OpenMP.
//!
//! Paper: Blaze 0.14/1.44/14.2 s vs MPI+OpenMP 0.14/1.42/14.6 s at
//! 10^7/10^8/10^9 samples (i7-8550U); SLOC 8 vs 24. The claim under test is
//! **parity**: the small-key-range path compiles down to the same execution
//! plan as the hand-written loop, so the ratio must stay ≈1 at every scale.
//! (Absolute times differ from the paper's testbed; the ratio is the
//! reproduced quantity.)

use blaze::apps::pi::{pi_blaze, pi_hand_optimized, SLOC_BLAZE, SLOC_MPI_OPENMP};
use blaze::bench;
use blaze::prelude::*;

fn main() {
    bench::figure_header(
        "Table 1: Monte Carlo Pi Estimation Performance",
        "Blaze MapReduce ~= hand-optimized MPI+OpenMP at every sample count; SLOC 8 vs 24",
    );
    let reps = bench::reps();
    // Paper scales 1e7..1e9; default here 1e6..1e8 (single host core),
    // override with BLAZE_BENCH_SCALE=10 for the paper's sizes.
    let scale = bench::scale() as u64;
    let sample_counts = [1_000_000 * scale, 10_000_000 * scale, 100_000_000 * scale];

    println!(
        "{:<12} {:>22} {:>22} {:>9}",
        "samples", "Blaze MapReduce (s)", "MPI+OpenMP (s)", "ratio"
    );
    for &n in &sample_counts {
        let blaze = bench::time_host(reps, || {
            let c = Cluster::local(1, 4);
            pi_blaze(&c, n)
        });
        let hand = bench::time_host(reps, || {
            let c = Cluster::local(1, 4);
            pi_hand_optimized(&c, n)
        });
        println!(
            "{:<12} {:>22} {:>22} {:>8.3}x",
            format!("{:.0e}", n as f64),
            blaze.to_string(),
            hand.to_string(),
            blaze.mean / hand.mean
        );
    }
    println!("\nSLOC: Blaze {SLOC_BLAZE} vs MPI+OpenMP {SLOC_MPI_OPENMP} (paper: 8 vs 24)");
}
