//! Figure 5: PageRank — links processed per second per iteration vs nodes.
//!
//! Paper: graph500 input (10M links), convergence 1e-5 (27 iterations);
//! Blaze >> Spark GraphX. Series: blaze, blaze-tcm, conventional.
//! Datapoints (throughput, iterations, run counters) append to
//! `BENCH_fig5_pagerank.json` via [`bench::report`].

use blaze::apps::pagerank::pagerank;
use blaze::bench;
use blaze::coordinator::cluster::{Cluster, ClusterConfig, EngineKind};
use blaze::data::Graph;
use blaze::util::alloc::AllocMode;

fn main() {
    bench::figure_header(
        "Figure 5: PageRank (links/second/iteration)",
        "Blaze >> Spark GraphX on a graph500 power-law graph, tol=1e-5",
    );
    // Default: 2^16 vertices, ~1M links. The paper's 10M-link input is
    // BLAZE_BENCH_SCALE=8 (scale 19); host time grows linearly.
    let scale = bench::scale();
    let g = Graph::graph500(16 + scale.ilog2(), 16, 42);
    println!(
        "graph500: {} vertices, {} links, {} sinks\n",
        g.n_vertices,
        g.n_edges(),
        g.sinks().len()
    );

    let mut rep = bench::report::Report::new("fig5_pagerank");
    rep.meta("scale", scale);
    rep.meta("links", g.n_edges());

    println!(
        "{:<6} {:>10} {:>16} {:>16} {:>16} {:>9}",
        "nodes", "iters", "blaze (l/s/it)", "blaze-tcm", "conv (l/s/it)", "speedup"
    );
    for nodes in bench::node_sweep() {
        let run = |engine: EngineKind, alloc: AllocMode| {
            let c = Cluster::new(
                ClusterConfig::sized(nodes, 4).with_engine(engine).with_alloc(alloc),
            );
            let (report, result) = pagerank(&c, &g, 1e-5, 100);
            let stats = c.metrics().last_run().cloned().expect("pagerank records runs");
            (report.throughput, result.iterations, stats)
        };
        let (blaze, iters, blaze_stats) = run(EngineKind::Eager, AllocMode::System);
        let (tcm, _, tcm_stats) = run(EngineKind::Eager, AllocMode::Pool);
        let (conv, _, conv_stats) = run(EngineKind::Conventional, AllocMode::System);
        for (series, tput, stats) in [
            ("blaze", blaze, &blaze_stats),
            ("blaze-tcm", tcm, &tcm_stats),
            ("conventional", conv, &conv_stats),
        ] {
            rep.push(
                bench::report::Row::new(series)
                    .tag("nodes", nodes)
                    .num("links_per_sec_per_iter", tput)
                    .num("iterations", iters as f64)
                    .counters(stats),
            );
        }
        println!(
            "{:<6} {:>10} {:>16.0} {:>16.0} {:>16.0} {:>8.1}x",
            nodes, iters, blaze, tcm, conv, blaze / conv
        );
    }

    match rep.write() {
        Ok(path) => println!("\nwrote {}", path.display()),
        Err(e) => eprintln!("\ncould not write bench json: {e}"),
    }
}
