//! Figure 6: K-Means — points processed per second per iteration vs nodes.
//!
//! Paper: 100M points around 5 centers; Blaze >> Spark MLlib. The
//! assignment step runs through the AOT-compiled PJRT executable (Pallas
//! pairwise kernel) when `make artifacts` has been run. Datapoints
//! (throughput, iterations, run counters) append to
//! `BENCH_fig6_kmeans.json` via [`bench::report`].

use blaze::apps::kmeans::{distribute_blocks, init_first_k, kmeans};
use blaze::bench;
use blaze::coordinator::cluster::{Cluster, ClusterConfig, EngineKind};
use blaze::data::PointSet;
use blaze::runtime::Runtime;
use blaze::util::alloc::AllocMode;

fn main() {
    bench::figure_header(
        "Figure 6: K-Means (points/second/iteration)",
        "Blaze >> Spark MLlib; 5 Gaussian clusters; assignment on PJRT",
    );
    let runtime = Runtime::load("artifacts").ok();
    let (dim, k) = runtime.as_ref().map_or((4, 5), |rt| (rt.dim(), rt.k()));
    let batch = runtime.as_ref().map_or(4096, Runtime::batch);
    let scale = bench::scale();
    let ps = PointSet::clustered(60_000 * scale, dim, k, 0.6, 42);
    let init = init_first_k(&ps, k);
    println!(
        "{} points, dim={dim}, k={k}, pjrt={}\n",
        ps.n,
        runtime.is_some()
    );

    let mut rep = bench::report::Report::new("fig6_kmeans");
    rep.meta("scale", scale);
    rep.meta("points", ps.n);
    rep.meta("pjrt", runtime.is_some());

    println!(
        "{:<6} {:>8} {:>16} {:>16} {:>16} {:>9}",
        "nodes", "iters", "blaze (p/s/it)", "blaze-tcm", "conv (p/s/it)", "speedup"
    );
    for nodes in bench::node_sweep() {
        let run = |engine: EngineKind, alloc: AllocMode| {
            let c = Cluster::new(
                ClusterConfig::sized(nodes, 4).with_engine(engine).with_alloc(alloc),
            );
            let blocks = distribute_blocks(&c, &ps, batch);
            let (report, result) = kmeans(
                &c, &blocks, ps.n, dim, k, init.clone(), 1e-4, 20, runtime.as_ref(),
            );
            let stats = c.metrics().last_run().cloned().expect("kmeans records runs");
            (report.throughput, result.iterations, stats)
        };
        let (blaze, iters, blaze_stats) = run(EngineKind::Eager, AllocMode::System);
        let (tcm, _, tcm_stats) = run(EngineKind::Eager, AllocMode::Pool);
        let (conv, _, conv_stats) = run(EngineKind::Conventional, AllocMode::System);
        for (series, tput, stats) in [
            ("blaze", blaze, &blaze_stats),
            ("blaze-tcm", tcm, &tcm_stats),
            ("conventional", conv, &conv_stats),
        ] {
            rep.push(
                bench::report::Row::new(series)
                    .tag("nodes", nodes)
                    .num("points_per_sec_per_iter", tput)
                    .num("iterations", iters as f64)
                    .counters(stats),
            );
        }
        println!(
            "{:<6} {:>8} {:>16.0} {:>16.0} {:>16.0} {:>8.1}x",
            nodes, iters, blaze, tcm, conv, blaze / conv
        );
    }

    match rep.write() {
        Ok(path) => println!("\nwrote {}", path.display()),
        Err(e) => eprintln!("\ncould not write bench json: {e}"),
    }
}
