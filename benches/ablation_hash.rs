//! Ablation: the node-local hot path — batched hashing and pooled scratch.
//!
//! 1. Batched vs scalar hashing: `hash_batch`/`shard_batch` against a
//!    per-key `fxhash` loop, over u64 and short-string keys. The batch
//!    is a 4-lane unroll of the same scalar hash (bit-identical outputs),
//!    so the only thing this measures is the wall delta.
//! 2. Pooled vs system flush scratch on the threaded eager path: a
//!    word-count whose flush buffers either round-trip through the
//!    per-worker `BufferPool` or hit the system allocator every flush.
//!    Counters (`alloc.pool.*`, `shard.stripes`) and histogram digests
//!    ride along in the rows.
//!
//! Datapoints land in `BENCH_ablation_hash.json` via [`bench::report`].

use blaze::bench;
use blaze::bench::report::{Report, Row};
use blaze::containers::{DistHashMap, DistVector};
use blaze::coordinator::cluster::{Backend, Cluster, ClusterConfig};
use blaze::data::corpus_lines;
use blaze::mapreduce::mapreduce_labeled;
use blaze::util::alloc::AllocMode;
use blaze::util::hash::{fxhash, hash_batch, shard_batch};
use blaze::util::rng::SplitRng;

/// Push one scalar/batched row pair and print the comparison line.
fn emit_pair(
    rep: &mut Report,
    series: &str,
    kind: &str,
    keys: usize,
    scalar: &bench::Sample,
    batched: &bench::Sample,
) {
    for (variant, sample) in [("scalar", scalar), ("batched", batched)] {
        rep.push(
            Row::new(series)
                .tag("kind", kind)
                .tag("variant", variant)
                .num("host_wall_mean_sec", sample.mean)
                .num("host_wall_std_sec", sample.std)
                .num("keys_per_sec", keys as f64 / sample.mean),
        );
    }
    println!(
        "  {:>12} {:>6}: scalar {:>10}s   batched {:>10}s   {:.2}x",
        series,
        kind,
        scalar,
        batched,
        scalar.mean / batched.mean
    );
}

fn ablation_batch_vs_scalar(rep: &mut Report) {
    println!("--- ablation A: batched vs scalar hashing ---");
    let n = 1_000_000 * bench::scale();
    let reps = bench::reps();
    let mut rng = SplitRng::new(0x4A58, 0);
    let u64_keys: Vec<u64> = (0..n).map(|_| rng.next_u64()).collect();
    let str_keys: Vec<String> = (0..n / 8)
        .map(|_| {
            let len = 3 + rng.below(10) as usize;
            (0..len).map(|_| char::from(b'a' + rng.below(26) as u8)).collect()
        })
        .collect();
    let mut out: Vec<u64> = Vec::new();

    // XOR-fold the hashes so the loops cannot be optimized away; the
    // equality asserts double as parity checks on these exact inputs.
    hash_batch(&u64_keys, &mut out);
    let want = u64_keys.iter().map(fxhash).fold(0u64, |a, h| a ^ h);
    assert_eq!(out.iter().fold(0u64, |a, h| a ^ h), want, "u64 batch diverged");
    let s = bench::time_host(reps, || {
        u64_keys.iter().map(fxhash).fold(0u64, |a, h| a ^ h)
    });
    let b = bench::time_host(reps, || {
        hash_batch(&u64_keys, &mut out);
        out.iter().fold(0u64, |a, h| a ^ h)
    });
    emit_pair(rep, "hash-batch", "u64", u64_keys.len(), &s, &b);

    hash_batch(&str_keys, &mut out);
    let want = str_keys.iter().map(fxhash).fold(0u64, |a, h| a ^ h);
    assert_eq!(out.iter().fold(0u64, |a, h| a ^ h), want, "str batch diverged");
    let s = bench::time_host(reps, || {
        str_keys.iter().map(fxhash).fold(0u64, |a, h| a ^ h)
    });
    let b = bench::time_host(reps, || {
        hash_batch(&str_keys, &mut out);
        out.iter().fold(0u64, |a, h| a ^ h)
    });
    emit_pair(rep, "hash-batch", "str", str_keys.len(), &s, &b);

    // Stripe selection (hash & mask) — the shard absorb inner loop.
    let mask = 255usize;
    let mut stripes: Vec<usize> = Vec::new();
    let s = bench::time_host(reps, || {
        u64_keys.iter().map(|k| (fxhash(k) as usize) & mask).fold(0usize, |a, x| a ^ x)
    });
    let b = bench::time_host(reps, || {
        shard_batch(&u64_keys, mask, &mut stripes);
        stripes.iter().fold(0usize, |a, x| a ^ x)
    });
    emit_pair(rep, "shard-batch", "u64", u64_keys.len(), &s, &b);
    println!();
}

fn ablation_pooled_scratch(rep: &mut Report) {
    println!("--- ablation B: pooled vs system flush scratch (threaded wordcount) ---");
    let lines = corpus_lines(30_000 * bench::scale(), 10, 42);
    let reps = bench::reps();
    println!(
        "  {:>8} {:>12} {:>12} {:>12} {:>8}",
        "alloc", "host (s)", "pool hits", "misses", "stripes"
    );
    for alloc in [AllocMode::System, AllocMode::Pool] {
        // Small cache → heavy flush traffic → the scratch buffers matter.
        let mut cfg = ClusterConfig::sized(4, 4)
            .with_backend(Backend::Threaded(4))
            .with_alloc(alloc);
        cfg.thread_cache_entries = 256;
        let cluster = Cluster::new(cfg);
        let sample = bench::time_host(reps, || {
            let dv = DistVector::from_vec(&cluster, lines.clone());
            let mut words: DistHashMap<String, u64> = DistHashMap::new(&cluster);
            mapreduce_labeled(
                "abl.hash_scratch",
                &dv,
                |_, line: &String, emit| {
                    for w in line.split_whitespace() {
                        emit(w.to_string(), 1u64);
                    }
                },
                "sum",
                &mut words,
            );
            words.len()
        });
        let m = cluster.metrics();
        let run = m.last_run().unwrap();
        rep.push(
            Row::new("pooled-scratch")
                .tag("alloc", alloc)
                .tag("backend", "threaded:4")
                .num("host_wall_mean_sec", sample.mean)
                .num("host_wall_std_sec", sample.std)
                .counters(run),
        );
        println!(
            "  {:>8} {:>12} {:>12} {:>12} {:>8}",
            alloc.to_string(),
            sample,
            run.counter("alloc.pool.hits").unwrap_or(0),
            run.counter("alloc.pool.misses").unwrap_or(0),
            run.counter("shard.stripes").unwrap_or(0),
        );
    }
    println!();
}

fn main() {
    bench::figure_header(
        "Node-local hot path ablations",
        "batched vs scalar hashing; pooled vs system flush scratch",
    );
    let mut rep = Report::new("ablation_hash");
    rep.meta("scale", bench::scale());
    rep.meta("reps", bench::reps());
    ablation_batch_vs_scalar(&mut rep);
    ablation_pooled_scratch(&mut rep);
    match rep.write() {
        Ok(path) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("could not write bench json: {e}"),
    }
}
